//! Direct transcriptions of the paper's layer equations (Eq. 1–6).
//!
//! Deliberately unspecialized: runtime loop bounds, heap weights, no
//! fusion. See module docs in [`super`].

use crate::graph::Padding;
use crate::tensor::Tensor;
use anyhow::{bail, Result};

/// 2-d convolution, paper Eq. 2 with zero padding per Eq. 1.
///
/// * `x` — input `[h_in, w_in, c_in]`
/// * `w` — weights `[h_k, w_k, c_in, c_out]` (HWIO)
/// * `b` — bias `[c_out]`
pub fn conv2d(x: &Tensor, w: &Tensor, b: &Tensor, stride: (usize, usize), padding: Padding) -> Result<Tensor> {
    let (h_in, w_in, c_in) = (x.dims()[0], x.dims()[1], x.dims()[2]);
    let wd = w.dims();
    let (h_k, w_k, c_out) = (wd[0], wd[1], wd[3]);
    if wd[2] != c_in {
        bail!("conv c_in mismatch: input {c_in}, weights {}", wd[2]);
    }
    let (h_out, p_h) = padding.resolve(h_in, h_k, stride.0)?;
    let (w_out, p_w) = padding.resolve(w_in, w_k, stride.1)?;

    let mut y = Tensor::zeros(&[h_out, w_out, c_out]);
    for i in 0..h_out {
        for j in 0..w_out {
            for k in 0..c_out {
                let mut acc = b.data()[k];
                for n in 0..h_k {
                    for m in 0..w_k {
                        // Eq. 1: zero outside bounds.
                        let ii = (i * stride.0 + n) as isize - p_h as isize;
                        let jj = (j * stride.1 + m) as isize - p_w as isize;
                        if ii < 0 || jj < 0 || ii >= h_in as isize || jj >= w_in as isize {
                            continue;
                        }
                        for o in 0..c_in {
                            acc += w.at4(n, m, o, k) * x.at3(ii as usize, jj as usize, o);
                        }
                    }
                }
                *y.at3_mut(i, j, k) = acc;
            }
        }
    }
    Ok(y)
}

/// Max pooling, paper Eq. 3 (valid semantics: windows fully inside).
pub fn maxpool2d(x: &Tensor, pool: (usize, usize), stride: (usize, usize)) -> Result<Tensor> {
    let (h_in, w_in, c) = (x.dims()[0], x.dims()[1], x.dims()[2]);
    if pool.0 > h_in || pool.1 > w_in {
        bail!("pool window {:?} larger than input [{h_in},{w_in}]", pool);
    }
    let h_out = (h_in - pool.0) / stride.0 + 1;
    let w_out = (w_in - pool.1) / stride.1 + 1;
    let mut y = Tensor::zeros(&[h_out, w_out, c]);
    for i in 0..h_out {
        for j in 0..w_out {
            for k in 0..c {
                let mut best = f32::NEG_INFINITY;
                for n in 0..pool.0 {
                    for m in 0..pool.1 {
                        best = best.max(x.at3(i * stride.0 + n, j * stride.1 + m, k));
                    }
                }
                *y.at3_mut(i, j, k) = best;
            }
        }
    }
    Ok(y)
}

/// Average pooling over valid windows.
pub fn avgpool2d(x: &Tensor, pool: (usize, usize), stride: (usize, usize)) -> Result<Tensor> {
    let (h_in, w_in, c) = (x.dims()[0], x.dims()[1], x.dims()[2]);
    if pool.0 > h_in || pool.1 > w_in {
        bail!("pool window {:?} larger than input [{h_in},{w_in}]", pool);
    }
    let h_out = (h_in - pool.0) / stride.0 + 1;
    let w_out = (w_in - pool.1) / stride.1 + 1;
    let inv = 1.0 / (pool.0 * pool.1) as f32;
    let mut y = Tensor::zeros(&[h_out, w_out, c]);
    for i in 0..h_out {
        for j in 0..w_out {
            for k in 0..c {
                let mut acc = 0.0;
                for n in 0..pool.0 {
                    for m in 0..pool.1 {
                        acc += x.at3(i * stride.0 + n, j * stride.1 + m, k);
                    }
                }
                *y.at3_mut(i, j, k) = acc * inv;
            }
        }
    }
    Ok(y)
}

/// Depthwise convolution (multiplier 1): one filter per input channel.
///
/// * `x` — input `[h_in, w_in, c]`
/// * `w` — weights `[h_k, w_k, c]`
/// * `b` — bias `[c]`
pub fn depthwise_conv2d(x: &Tensor, w: &Tensor, b: &Tensor, stride: (usize, usize), padding: Padding) -> Result<Tensor> {
    let (h_in, w_in, c) = (x.dims()[0], x.dims()[1], x.dims()[2]);
    let wd = w.dims();
    let (h_k, w_k) = (wd[0], wd[1]);
    if wd[2] != c {
        bail!("depthwise channel mismatch: input {c}, weights {}", wd[2]);
    }
    let (h_out, p_h) = padding.resolve(h_in, h_k, stride.0)?;
    let (w_out, p_w) = padding.resolve(w_in, w_k, stride.1)?;
    let mut y = Tensor::zeros(&[h_out, w_out, c]);
    for i in 0..h_out {
        for j in 0..w_out {
            for k in 0..c {
                let mut acc = b.data()[k];
                for n in 0..h_k {
                    for m in 0..w_k {
                        let ii = (i * stride.0 + n) as isize - p_h as isize;
                        let jj = (j * stride.1 + m) as isize - p_w as isize;
                        if ii < 0 || jj < 0 || ii >= h_in as isize || jj >= w_in as isize {
                            continue;
                        }
                        acc += w.data()[(n * w_k + m) * c + k] * x.at3(ii as usize, jj as usize, k);
                    }
                }
                *y.at3_mut(i, j, k) = acc;
            }
        }
    }
    Ok(y)
}

/// ReLU, paper Eq. 4.
pub fn relu(x: &Tensor) -> Tensor {
    let mut y = x.clone();
    for v in y.data_mut() {
        *v = v.max(0.0);
    }
    y
}

/// Leaky ReLU, paper Eq. 5.
pub fn leaky_relu(x: &Tensor, alpha: f32) -> Tensor {
    let mut y = x.clone();
    for v in y.data_mut() {
        if *v <= 0.0 {
            *v *= alpha;
        }
    }
    y
}

/// Numerically stable softmax over the *entire* tensor (the paper's
/// classifier heads end in a 1×1×2 map, so "channel" softmax and "flat"
/// softmax coincide; for larger maps this is the flattened-logits variant
/// the generated C also implements).
pub fn softmax(x: &Tensor) -> Tensor {
    let mut y = x.clone();
    let max = y.data().iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let mut sum = 0.0;
    for v in y.data_mut() {
        *v = (*v - max).exp();
        sum += *v;
    }
    for v in y.data_mut() {
        *v /= sum;
    }
    y
}

/// Batch normalization at inference, paper Eq. 6 with learned affine:
/// `y = gamma * (x - mean) / sqrt(var + eps) + beta`, per channel.
pub fn batchnorm(x: &Tensor, gamma: &Tensor, beta: &Tensor, mean: &Tensor, variance: &Tensor, eps: f32) -> Result<Tensor> {
    let c = x.dims()[x.dims().len() - 1];
    if gamma.numel() != c {
        bail!("batchnorm expects {c} channels, gamma has {}", gamma.numel());
    }
    let mut y = x.clone();
    // Precompute per-channel scale/shift (this is also what fold_bn bakes
    // into conv weights).
    let scales: Vec<f32> = (0..c)
        .map(|k| gamma.data()[k] / (variance.data()[k] + eps).sqrt())
        .collect();
    let shifts: Vec<f32> = (0..c).map(|k| beta.data()[k] - mean.data()[k] * scales[k]).collect();
    for (idx, v) in y.data_mut().iter_mut().enumerate() {
        let k = idx % c;
        *v = *v * scales[k] + shifts[k];
    }
    Ok(y)
}

/// Dense layer: `y = W^T x + b`, weights `[in, out]`.
pub fn dense(x: &Tensor, w: &Tensor, b: &Tensor) -> Result<Tensor> {
    let n_in = x.numel();
    let wd = w.dims();
    if wd[0] != n_in {
        bail!("dense in mismatch: input {n_in}, weights {}", wd[0]);
    }
    let n_out = wd[1];
    let mut y = Tensor::zeros(&[n_out]);
    for j in 0..n_out {
        let mut acc = b.data()[j];
        for i in 0..n_in {
            acc += x.data()[i] * w.data()[i * n_out + j];
        }
        y.data_mut()[j] = acc;
    }
    Ok(y)
}

// ---------------------------------------------------------------------------
// int8 reference path (`--dtype int8` oracle)
//
// Bit-exact mirrors of what the quantized emitters generate: int32
// accumulation over int8 activations/weights (saturation-free by the
// QuantPlan's accumulator proof, so summation order is irrelevant),
// multiply-shift requantization at layer boundaries via the shared
// `passes::requant` helper, and integer ReLU/leaky-ReLU. Zero is its own
// quantized value (symmetric scheme), so skipping out-of-bounds taps is
// exactly zero padding, same as the f32 ops above.
// ---------------------------------------------------------------------------

use crate::passes::{qavg, qleaky, requant, QuantArith};

/// Quantized 2-d convolution. `x` is `[h,w,c]` int8 (dims in `xd`), the
/// weights/bias/requant parameters come from the layer's [`QuantArith`]
/// (weights in original HWIO order, `wd = [h_k, w_k, c_in, c_out]`).
/// Returns the requantized int8 output and its dims.
pub fn qconv2d(
    x: &[i8],
    xd: [usize; 3],
    wd: [usize; 4],
    a: &QuantArith,
    stride: (usize, usize),
    padding: Padding,
) -> Result<(Vec<i8>, [usize; 3])> {
    let (h_in, w_in, c_in) = (xd[0], xd[1], xd[2]);
    let (h_k, w_k, c_out) = (wd[0], wd[1], wd[3]);
    if wd[2] != c_in {
        bail!("qconv c_in mismatch: input {c_in}, weights {}", wd[2]);
    }
    let (h_out, p_h) = padding.resolve(h_in, h_k, stride.0)?;
    let (w_out, p_w) = padding.resolve(w_in, w_k, stride.1)?;
    let mut y = vec![0i8; h_out * w_out * c_out];
    for i in 0..h_out {
        for j in 0..w_out {
            for k in 0..c_out {
                let mut acc: i32 = a.qb[k];
                for n in 0..h_k {
                    for m in 0..w_k {
                        let ii = (i * stride.0 + n) as isize - p_h as isize;
                        let jj = (j * stride.1 + m) as isize - p_w as isize;
                        if ii < 0 || jj < 0 || ii >= h_in as isize || jj >= w_in as isize {
                            continue;
                        }
                        let xrow = (ii as usize * w_in + jj as usize) * c_in;
                        let wrow = ((n * w_k + m) * c_in) * c_out;
                        for o in 0..c_in {
                            acc += a.qw[wrow + o * c_out + k] as i32 * x[xrow + o] as i32;
                        }
                    }
                }
                y[(i * w_out + j) * c_out + k] = requant(acc, a.m[k], a.pre, a.post);
            }
        }
    }
    Ok((y, [h_out, w_out, c_out]))
}

/// Quantized depthwise convolution, weights `[h_k, w_k, c]`.
pub fn qdepthwise_conv2d(
    x: &[i8],
    xd: [usize; 3],
    wd: [usize; 3],
    a: &QuantArith,
    stride: (usize, usize),
    padding: Padding,
) -> Result<(Vec<i8>, [usize; 3])> {
    let (h_in, w_in, c) = (xd[0], xd[1], xd[2]);
    let (h_k, w_k) = (wd[0], wd[1]);
    if wd[2] != c {
        bail!("qdepthwise channel mismatch: input {c}, weights {}", wd[2]);
    }
    let (h_out, p_h) = padding.resolve(h_in, h_k, stride.0)?;
    let (w_out, p_w) = padding.resolve(w_in, w_k, stride.1)?;
    let mut y = vec![0i8; h_out * w_out * c];
    for i in 0..h_out {
        for j in 0..w_out {
            for k in 0..c {
                let mut acc: i32 = a.qb[k];
                for n in 0..h_k {
                    for m in 0..w_k {
                        let ii = (i * stride.0 + n) as isize - p_h as isize;
                        let jj = (j * stride.1 + m) as isize - p_w as isize;
                        if ii < 0 || jj < 0 || ii >= h_in as isize || jj >= w_in as isize {
                            continue;
                        }
                        acc += a.qw[(n * w_k + m) * c + k] as i32
                            * x[(ii as usize * w_in + jj as usize) * c + k] as i32;
                    }
                }
                y[(i * w_out + j) * c + k] = requant(acc, a.m[k], a.pre, a.post);
            }
        }
    }
    Ok((y, [h_out, w_out, c]))
}

/// Quantized max pooling — pure int8 comparisons, scale unchanged.
pub fn qmaxpool2d(
    x: &[i8],
    xd: [usize; 3],
    pool: (usize, usize),
    stride: (usize, usize),
) -> Result<(Vec<i8>, [usize; 3])> {
    let (h_in, w_in, c) = (xd[0], xd[1], xd[2]);
    if pool.0 > h_in || pool.1 > w_in {
        bail!("pool window {:?} larger than input [{h_in},{w_in}]", pool);
    }
    let h_out = (h_in - pool.0) / stride.0 + 1;
    let w_out = (w_in - pool.1) / stride.1 + 1;
    let mut y = vec![0i8; h_out * w_out * c];
    for i in 0..h_out {
        for j in 0..w_out {
            for k in 0..c {
                let mut best = i8::MIN;
                for n in 0..pool.0 {
                    for m in 0..pool.1 {
                        let v = x[((i * stride.0 + n) * w_in + (j * stride.1 + m)) * c + k];
                        if v > best {
                            best = v;
                        }
                    }
                }
                y[(i * w_out + j) * c + k] = best;
            }
        }
    }
    Ok((y, [h_out, w_out, c]))
}

/// Quantized average pooling: int32 window sum, Q15 multiply-shift mean
/// (scale unchanged; mirrors the emitted `(sum * AM + AR) >> 15` form).
pub fn qavgpool2d(
    x: &[i8],
    xd: [usize; 3],
    pool: (usize, usize),
    stride: (usize, usize),
) -> Result<(Vec<i8>, [usize; 3])> {
    let (h_in, w_in, c) = (xd[0], xd[1], xd[2]);
    if pool.0 > h_in || pool.1 > w_in {
        bail!("pool window {:?} larger than input [{h_in},{w_in}]", pool);
    }
    let h_out = (h_in - pool.0) / stride.0 + 1;
    let w_out = (w_in - pool.1) / stride.1 + 1;
    let mult = crate::passes::avg_mult(pool.0 * pool.1);
    let mut y = vec![0i8; h_out * w_out * c];
    for i in 0..h_out {
        for j in 0..w_out {
            for k in 0..c {
                let mut sum: i32 = 0;
                for n in 0..pool.0 {
                    for m in 0..pool.1 {
                        sum += x[((i * stride.0 + n) * w_in + (j * stride.1 + m)) * c + k] as i32;
                    }
                }
                y[(i * w_out + j) * c + k] = qavg(sum, mult);
            }
        }
    }
    Ok((y, [h_out, w_out, c]))
}

/// Quantized dense layer, weights `[in, out]` in the [`QuantArith`].
pub fn qdense(x: &[i8], n_in: usize, n_out: usize, a: &QuantArith) -> Result<Vec<i8>> {
    if x.len() != n_in {
        bail!("qdense in mismatch: input {}, weights {n_in}", x.len());
    }
    let mut y = vec![0i8; n_out];
    for j in 0..n_out {
        let mut acc: i32 = a.qb[j];
        for i in 0..n_in {
            acc += x[i] as i32 * a.qw[i * n_out + j] as i32;
        }
        y[j] = requant(acc, a.m[j], a.pre, a.post);
    }
    Ok(y)
}

/// Integer ReLU (in place).
pub fn qrelu(x: &mut [i8]) {
    for v in x {
        if *v < 0 {
            *v = 0;
        }
    }
}

/// Integer leaky ReLU (in place); `mult` from [`crate::passes::leaky_mult`].
pub fn qleaky_relu(x: &mut [i8], mult: i32) {
    for v in x {
        *v = qleaky(*v as i32, mult);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conv_identity_kernel() {
        // 1x1 kernel with weight 1 reproduces the input.
        let x = Tensor::from_vec(&[2, 2, 1], vec![1., 2., 3., 4.]).unwrap();
        let w = Tensor::from_vec(&[1, 1, 1, 1], vec![1.0]).unwrap();
        let b = Tensor::zeros(&[1]);
        let y = conv2d(&x, &w, &b, (1, 1), Padding::Valid).unwrap();
        assert_eq!(y.data(), x.data());
    }

    #[test]
    fn conv_known_values_same_padding() {
        // 3x3 input, 3x3 all-ones kernel, same padding: center output is the
        // sum of all 9; corner output sums the 4 in-bounds values.
        let x = Tensor::from_vec(&[3, 3, 1], (1..=9).map(|v| v as f32).collect()).unwrap();
        let w = Tensor::from_vec(&[3, 3, 1, 1], vec![1.0; 9]).unwrap();
        let b = Tensor::zeros(&[1]);
        let y = conv2d(&x, &w, &b, (1, 1), Padding::Same).unwrap();
        assert_eq!(y.at3(1, 1, 0), 45.0);
        assert_eq!(y.at3(0, 0, 0), 1. + 2. + 4. + 5.);
        assert_eq!(y.at3(2, 2, 0), 5. + 6. + 8. + 9.);
    }

    #[test]
    fn conv_stride_two() {
        let x = Tensor::from_vec(&[4, 4, 1], (0..16).map(|v| v as f32).collect()).unwrap();
        let w = Tensor::from_vec(&[1, 1, 1, 1], vec![2.0]).unwrap();
        let b = Tensor::from_vec(&[1], vec![1.0]).unwrap();
        let y = conv2d(&x, &w, &b, (2, 2), Padding::Valid).unwrap();
        assert_eq!(y.dims(), &[2, 2, 1]);
        assert_eq!(y.data(), &[1., 5., 17., 21.]); // 2*x + 1 at (0,0),(0,2),(2,0),(2,2)
    }

    #[test]
    fn conv_bias_applied_per_output_channel() {
        let x = Tensor::from_vec(&[1, 1, 1], vec![0.0]).unwrap();
        let w = Tensor::zeros(&[1, 1, 1, 3]);
        let b = Tensor::from_vec(&[3], vec![1.0, 2.0, 3.0]).unwrap();
        let y = conv2d(&x, &w, &b, (1, 1), Padding::Valid).unwrap();
        assert_eq!(y.data(), &[1.0, 2.0, 3.0]);
    }

    #[test]
    fn maxpool_known() {
        let x = Tensor::from_vec(&[2, 2, 1], vec![1., 5., 3., 2.]).unwrap();
        let y = maxpool2d(&x, (2, 2), (2, 2)).unwrap();
        assert_eq!(y.data(), &[5.0]);
    }

    #[test]
    fn maxpool_with_negative_values() {
        let x = Tensor::from_vec(&[2, 2, 1], vec![-1., -5., -3., -2.]).unwrap();
        let y = maxpool2d(&x, (2, 2), (2, 2)).unwrap();
        assert_eq!(y.data(), &[-1.0]);
    }

    #[test]
    fn maxpool_channels_independent() {
        let x = Tensor::from_vec(&[2, 2, 2], vec![1., 10., 2., 20., 3., 30., 4., 40.]).unwrap();
        let y = maxpool2d(&x, (2, 2), (2, 2)).unwrap();
        assert_eq!(y.data(), &[4.0, 40.0]);
    }

    #[test]
    fn softmax_sums_to_one_and_is_stable() {
        let x = Tensor::from_vec(&[3], vec![1000.0, 1001.0, 1002.0]).unwrap();
        let y = softmax(&x);
        let sum: f32 = y.data().iter().sum();
        assert!((sum - 1.0).abs() < 1e-6);
        assert!(y.data().iter().all(|v| v.is_finite()));
        assert!(y.data()[2] > y.data()[1] && y.data()[1] > y.data()[0]);
    }

    #[test]
    fn batchnorm_known_values() {
        // gamma=2, beta=1, mean=3, var=4, eps=0 → y = 2*(x-3)/2 + 1 = x - 2
        let x = Tensor::from_vec(&[1, 1, 1], vec![5.0]).unwrap();
        let y = batchnorm(
            &x,
            &Tensor::from_vec(&[1], vec![2.0]).unwrap(),
            &Tensor::from_vec(&[1], vec![1.0]).unwrap(),
            &Tensor::from_vec(&[1], vec![3.0]).unwrap(),
            &Tensor::from_vec(&[1], vec![4.0]).unwrap(),
            0.0,
        )
        .unwrap();
        assert!((y.data()[0] - 3.0).abs() < 1e-6);
    }

    #[test]
    fn dense_known() {
        let x = Tensor::from_vec(&[2], vec![1.0, 2.0]).unwrap();
        let w = Tensor::from_vec(&[2, 2], vec![1., 2., 3., 4.]).unwrap(); // [in,out]
        let b = Tensor::from_vec(&[2], vec![0.5, -0.5]).unwrap();
        let y = dense(&x, &w, &b).unwrap();
        // y0 = 1*1 + 2*3 + 0.5 = 7.5 ; y1 = 1*2 + 2*4 - 0.5 = 9.5
        assert_eq!(y.data(), &[7.5, 9.5]);
    }

    #[test]
    fn avgpool_known() {
        let x = Tensor::from_vec(&[2, 2, 1], vec![1., 5., 3., 3.]).unwrap();
        let y = avgpool2d(&x, (2, 2), (2, 2)).unwrap();
        assert_eq!(y.data(), &[3.0]);
    }

    #[test]
    fn avgpool_rejects_oversize_window() {
        let x = Tensor::zeros(&[2, 2, 1]);
        assert!(avgpool2d(&x, (3, 3), (1, 1)).is_err());
    }

    #[test]
    fn depthwise_identity_kernel() {
        // 1x1 depthwise with weight 1 per channel reproduces the input.
        let x = Tensor::from_vec(&[2, 2, 2], vec![1., 10., 2., 20., 3., 30., 4., 40.]).unwrap();
        let w = Tensor::from_vec(&[1, 1, 2], vec![1.0, 1.0]).unwrap();
        let b = Tensor::zeros(&[2]);
        let y = depthwise_conv2d(&x, &w, &b, (1, 1), Padding::Valid).unwrap();
        assert_eq!(y.data(), x.data());
    }

    #[test]
    fn depthwise_channels_do_not_mix() {
        // channel 0 filter zero, channel 1 filter one: channel 0 output is
        // pure bias, channel 1 passes through.
        let x = Tensor::from_vec(&[1, 1, 2], vec![7.0, 9.0]).unwrap();
        let w = Tensor::from_vec(&[1, 1, 2], vec![0.0, 1.0]).unwrap();
        let b = Tensor::from_vec(&[2], vec![0.5, 0.0]).unwrap();
        let y = depthwise_conv2d(&x, &w, &b, (1, 1), Padding::Valid).unwrap();
        assert_eq!(y.data(), &[0.5, 9.0]);
    }

    #[test]
    fn depthwise_same_padding() {
        // 3x3 ones kernel on 3x3 ones input, same pad: corner=4, center=9
        let x = Tensor::from_vec(&[3, 3, 1], vec![1.0; 9]).unwrap();
        let w = Tensor::from_vec(&[3, 3, 1], vec![1.0; 9]).unwrap();
        let b = Tensor::zeros(&[1]);
        let y = depthwise_conv2d(&x, &w, &b, (1, 1), Padding::Same).unwrap();
        assert_eq!(y.at3(0, 0, 0), 4.0);
        assert_eq!(y.at3(1, 1, 0), 9.0);
    }

    #[test]
    fn leaky_relu_matches_eq5() {
        let x = Tensor::from_vec(&[2], vec![-10.0, 10.0]).unwrap();
        let y = leaky_relu(&x, 0.1);
        assert_eq!(y.data(), &[-1.0, 10.0]);
    }

    /// Unit requant (m = 2^post, pre = 0) makes qconv a plain int dot.
    fn unit_arith(qw: Vec<i8>, qb: Vec<i32>, n_ch: usize) -> QuantArith {
        QuantArith {
            w_scales: vec![1.0; n_ch],
            qw,
            qb,
            m: vec![1 << 10; n_ch],
            pre: 0,
            post: 10,
        }
    }

    #[test]
    fn qconv_identity_kernel() {
        let x: Vec<i8> = vec![1, 2, 3, 4];
        let a = unit_arith(vec![1], vec![0], 1);
        let (y, yd) = qconv2d(&x, [2, 2, 1], [1, 1, 1, 1], &a, (1, 1), Padding::Valid).unwrap();
        assert_eq!(yd, [2, 2, 1]);
        assert_eq!(y, x);
    }

    #[test]
    fn qconv_same_padding_skips_oob_taps_like_zero_pad() {
        // all-ones 3x3 kernel over 1..9: center sums all nine, corner the
        // four in-bounds values — identical to the f32 zero-pad semantics.
        let x: Vec<i8> = (1..=9).collect();
        let a = unit_arith(vec![1; 9], vec![0], 1);
        let (y, _) = qconv2d(&x, [3, 3, 1], [3, 3, 1, 1], &a, (1, 1), Padding::Same).unwrap();
        assert_eq!(y[4], 45);
        assert_eq!(y[0], 1 + 2 + 4 + 5);
    }

    #[test]
    fn qconv_requant_saturates_at_127() {
        // acc = 127*127 = 16129, identity requant would overflow i8 → clamps.
        let x: Vec<i8> = vec![127];
        let a = unit_arith(vec![127], vec![0], 1);
        let (y, _) = qconv2d(&x, [1, 1, 1], [1, 1, 1, 1], &a, (1, 1), Padding::Valid).unwrap();
        assert_eq!(y[0], 127);
    }

    #[test]
    fn qmaxpool_and_qavgpool_known() {
        let x: Vec<i8> = vec![1, 5, 3, 3];
        let (y, _) = qmaxpool2d(&x, [2, 2, 1], (2, 2), (2, 2)).unwrap();
        assert_eq!(y, vec![5]);
        let (y, _) = qavgpool2d(&x, [2, 2, 1], (2, 2), (2, 2)).unwrap();
        assert_eq!(y, vec![3]);
        // negative values survive the int8 max (no unsigned confusion)
        let x: Vec<i8> = vec![-1, -5, -3, -2];
        let (y, _) = qmaxpool2d(&x, [2, 2, 1], (2, 2), (2, 2)).unwrap();
        assert_eq!(y, vec![-1]);
    }

    #[test]
    fn qdense_known() {
        let x: Vec<i8> = vec![1, 2];
        let a = unit_arith(vec![1, 2, 3, 4], vec![5, -5], 2);
        let y = qdense(&x, 2, 2, &a).unwrap();
        // y0 = 1*1 + 2*3 + 5 = 12 ; y1 = 1*2 + 2*4 - 5 = 5
        assert_eq!(y, vec![12, 5]);
    }

    #[test]
    fn q_activations_in_place() {
        let mut x: Vec<i8> = vec![-10, 0, 10];
        qrelu(&mut x);
        assert_eq!(x, vec![0, 0, 10]);
        let mut x: Vec<i8> = vec![-10, 0, 10];
        qleaky_relu(&mut x, crate::passes::leaky_mult(0.5));
        assert_eq!(x, vec![-5, 0, 10]);
    }
}
