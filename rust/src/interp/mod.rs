//! Naive runtime interpreter.
//!
//! Two roles:
//! 1. **Correctness oracle** on the Rust side: a direct transcription of the
//!    paper's Eq. 1–6, kept as simple as possible, against which generated C
//!    and the XLA runtime are compared.
//! 2. **Framework baseline** ("Glow column" stand-in): this is exactly the
//!    execution model the paper attributes to generic frameworks — weights
//!    in heap arrays, loop bounds read from layer structs at run time, no
//!    model-specific specialization. Measuring it quantifies what NNCG's
//!    specialization buys.

mod ops;

pub use ops::{avgpool2d, batchnorm, conv2d, dense, depthwise_conv2d, leaky_relu, maxpool2d, relu, softmax};

use crate::graph::{check_input, Activation, Layer, Model};
use crate::tensor::Tensor;
use anyhow::Result;

/// Run a full model on one input image, returning the final output tensor.
pub fn run(model: &Model, input: &Tensor) -> Result<Tensor> {
    check_input(model, input)?;
    model.validate()?;
    let mut x = input.clone();
    for layer in &model.layers {
        x = run_layer(layer, &x)?;
    }
    Ok(x)
}

/// Run a single layer.
pub fn run_layer(layer: &Layer, x: &Tensor) -> Result<Tensor> {
    Ok(match layer {
        Layer::Conv2D { weights, bias, stride, padding, activation } => {
            let y = conv2d(x, weights, bias, *stride, *padding)?;
            apply_activation(&y, *activation)
        }
        Layer::MaxPool2D { pool, stride } => maxpool2d(x, *pool, *stride)?,
        Layer::AvgPool2D { pool, stride } => avgpool2d(x, *pool, *stride)?,
        Layer::DepthwiseConv2D { weights, bias, stride, padding, activation } => {
            let y = depthwise_conv2d(x, weights, bias, *stride, *padding)?;
            apply_activation(&y, *activation)
        }
        Layer::Activation(a) => apply_activation(x, *a),
        Layer::BatchNorm { gamma, beta, mean, variance, epsilon } => {
            batchnorm(x, gamma, beta, mean, variance, *epsilon)?
        }
        Layer::Dropout { .. } => x.clone(), // inference: identity
        Layer::Flatten => {
            let mut y = x.clone();
            let n = y.numel();
            y.reshape(&[n])?;
            y
        }
        Layer::Dense { weights, bias, activation } => {
            let y = dense(x, weights, bias)?;
            apply_activation(&y, *activation)
        }
    })
}

fn apply_activation(x: &Tensor, a: Activation) -> Tensor {
    match a {
        Activation::None => x.clone(),
        Activation::Relu => relu(x),
        Activation::LeakyRelu(alpha) => leaky_relu(x, alpha),
        Activation::Softmax => softmax(x),
    }
}

/// Engine wrapper so the interpreter plugs into the coordinator's
/// [`crate::runtime::InferenceEngine`] trait.
pub struct InterpEngine {
    model: Model,
}

impl InterpEngine {
    pub fn new(model: Model) -> Result<Self> {
        model.validate()?;
        Ok(InterpEngine { model })
    }

    pub fn model(&self) -> &Model {
        &self.model
    }
}

impl crate::runtime::InferenceEngine for InterpEngine {
    fn name(&self) -> &str {
        "interp"
    }

    fn infer(&self, input: &Tensor) -> Result<Tensor> {
        run(&self.model, input)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::zoo;
    use crate::util::XorShift64;

    #[test]
    fn run_all_paper_models() {
        let mut rng = XorShift64::new(10);
        for name in zoo::PAPER_MODELS {
            let m = zoo::by_name(name).unwrap().with_random_weights(7);
            let input = Tensor::rand(m.input.dims(), 0.0, 1.0, &mut rng);
            let out = run(&m, &input).unwrap();
            assert_eq!(out.dims(), m.output_shape().unwrap().dims(), "{name}");
            assert!(out.data().iter().all(|v| v.is_finite()), "{name}");
        }
    }

    #[test]
    fn classifier_outputs_are_probabilities() {
        let mut rng = XorShift64::new(11);
        let m = zoo::ball_classifier().with_random_weights(8);
        let input = Tensor::rand(&[16, 16, 1], 0.0, 1.0, &mut rng);
        let out = run(&m, &input).unwrap();
        let sum: f32 = out.data().iter().sum();
        assert!((sum - 1.0).abs() < 1e-5, "softmax should sum to 1, got {sum}");
        assert!(out.data().iter().all(|&p| (0.0..=1.0).contains(&p)));
    }

    #[test]
    fn rejects_wrong_input_shape() {
        let m = zoo::ball_classifier().with_random_weights(9);
        let bad = Tensor::zeros(&[8, 8, 1]);
        assert!(run(&m, &bad).is_err());
    }

    #[test]
    fn dropout_is_identity() {
        let x = Tensor::from_vec(&[1, 1, 2], vec![3.0, -4.0]).unwrap();
        let y = run_layer(&Layer::Dropout { rate: 0.5 }, &x).unwrap();
        assert_eq!(x, y);
    }
}
