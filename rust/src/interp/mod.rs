//! Naive runtime interpreter.
//!
//! Two roles:
//! 1. **Correctness oracle** on the Rust side: a direct transcription of the
//!    paper's Eq. 1–6, kept as simple as possible, against which generated C
//!    and the XLA runtime are compared.
//! 2. **Framework baseline** ("Glow column" stand-in): this is exactly the
//!    execution model the paper attributes to generic frameworks — weights
//!    in heap arrays, loop bounds read from layer structs at run time, no
//!    model-specific specialization. Measuring it quantifies what NNCG's
//!    specialization buys.

mod ops;

pub use ops::{avgpool2d, batchnorm, conv2d, dense, depthwise_conv2d, leaky_relu, maxpool2d, relu, softmax};
pub use ops::{qavgpool2d, qconv2d, qdense, qdepthwise_conv2d, qleaky_relu, qmaxpool2d, qrelu};

use crate::graph::{check_input, Activation, Layer, Model};
use crate::passes::{leaky_mult, quantize_input, LayerQuant, QuantPlan};
use crate::tensor::Tensor;
use anyhow::{bail, Result};

/// Run a full model on one input image, returning the final output tensor.
pub fn run(model: &Model, input: &Tensor) -> Result<Tensor> {
    check_input(model, input)?;
    model.validate()?;
    let mut x = input.clone();
    for layer in &model.layers {
        x = run_layer(layer, &x)?;
    }
    Ok(x)
}

/// Run a single layer.
pub fn run_layer(layer: &Layer, x: &Tensor) -> Result<Tensor> {
    Ok(match layer {
        Layer::Conv2D { weights, bias, stride, padding, activation } => {
            let y = conv2d(x, weights, bias, *stride, *padding)?;
            apply_activation(&y, *activation)
        }
        Layer::MaxPool2D { pool, stride } => maxpool2d(x, *pool, *stride)?,
        Layer::AvgPool2D { pool, stride } => avgpool2d(x, *pool, *stride)?,
        Layer::DepthwiseConv2D { weights, bias, stride, padding, activation } => {
            let y = depthwise_conv2d(x, weights, bias, *stride, *padding)?;
            apply_activation(&y, *activation)
        }
        Layer::Activation(a) => apply_activation(x, *a),
        Layer::BatchNorm { gamma, beta, mean, variance, epsilon } => {
            batchnorm(x, gamma, beta, mean, variance, *epsilon)?
        }
        Layer::Dropout { .. } => x.clone(), // inference: identity
        Layer::Flatten => {
            let mut y = x.clone();
            let n = y.numel();
            y.reshape(&[n])?;
            y
        }
        Layer::Dense { weights, bias, activation } => {
            let y = dense(x, weights, bias)?;
            apply_activation(&y, *activation)
        }
    })
}

/// Run a model through the **int8 reference path**: quantize the input
/// with the plan's input scale, execute the integer chain (requantizing at
/// layer boundaries exactly as the generated C does), dequantize, and —
/// when the model ends in softmax — apply the float softmax epilogue the
/// int8 emitter also appends. This is the bit-exact oracle for
/// `--dtype int8` codegen: every integer step here is the same shared
/// `passes::{requant, qleaky, qavg, quantize_input}` arithmetic the
/// emitters print. (The softmax epilogue itself is float and therefore
/// libm-exact rather than bit-exact; everything before it is integers.)
pub fn run_quantized(model: &Model, qp: &QuantPlan, input: &Tensor) -> Result<Tensor> {
    check_input(model, input)?;
    if qp.layers.len() != model.layers.len() {
        bail!("quant plan has {} layers, model has {}", qp.layers.len(), model.layers.len());
    }
    let inv = 1.0 / qp.input_scale;
    let mut q: Vec<i8> = input.data().iter().map(|&v| quantize_input(v, inv)).collect();
    let mut dims: Vec<usize> = input.dims().to_vec();

    for (layer, lq) in model.layers.iter().zip(&qp.layers) {
        let arith = match lq {
            LayerQuant::Mac { arith, .. } => Some(arith),
            LayerQuant::Passthrough { .. } => None,
        };
        match layer {
            Layer::Conv2D { weights, stride, padding, activation, .. } => {
                let a = arith.ok_or_else(|| anyhow::anyhow!("conv needs a Mac quant record"))?;
                let d = weights.dims();
                let (y, yd) = ops::qconv2d(
                    &q,
                    [dims[0], dims[1], dims[2]],
                    [d[0], d[1], d[2], d[3]],
                    a,
                    *stride,
                    *padding,
                )?;
                q = y;
                dims = yd.to_vec();
                apply_qactivation(&mut q, *activation);
            }
            Layer::DepthwiseConv2D { weights, stride, padding, activation, .. } => {
                let a =
                    arith.ok_or_else(|| anyhow::anyhow!("depthwise needs a Mac quant record"))?;
                let d = weights.dims();
                let (y, yd) = ops::qdepthwise_conv2d(
                    &q,
                    [dims[0], dims[1], dims[2]],
                    [d[0], d[1], d[2]],
                    a,
                    *stride,
                    *padding,
                )?;
                q = y;
                dims = yd.to_vec();
                apply_qactivation(&mut q, *activation);
            }
            Layer::Dense { weights, activation, .. } => {
                let a = arith.ok_or_else(|| anyhow::anyhow!("dense needs a Mac quant record"))?;
                let d = weights.dims();
                q = ops::qdense(&q, d[0], d[1], a)?;
                dims = vec![d[1]];
                apply_qactivation(&mut q, *activation);
            }
            Layer::MaxPool2D { pool, stride } => {
                let (y, yd) = ops::qmaxpool2d(&q, [dims[0], dims[1], dims[2]], *pool, *stride)?;
                q = y;
                dims = yd.to_vec();
            }
            Layer::AvgPool2D { pool, stride } => {
                let (y, yd) = ops::qavgpool2d(&q, [dims[0], dims[1], dims[2]], *pool, *stride)?;
                q = y;
                dims = yd.to_vec();
            }
            Layer::Activation(a) => apply_qactivation(&mut q, *a),
            Layer::Flatten => dims = vec![q.len()],
            other => bail!("int8 path cannot run {} (optimize the model first)", other.kind_name()),
        }
    }

    // Dequantize with the final layer's scale, then the float softmax
    // epilogue if the model ends in one (mirrors the generated epilogue:
    // f32 max-subtract, f64 exp cast back to f32, in-order f32 sum).
    let s_out = qp.layers.last().map(|l| l.out_scale()).unwrap_or(qp.input_scale);
    let mut out: Vec<f32> = q.iter().map(|&v| v as f32 * s_out).collect();
    if qp.trailing_softmax {
        let mx = out.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut sum = 0f32;
        for v in out.iter_mut() {
            *v = ((*v - mx) as f64).exp() as f32;
            sum += *v;
        }
        for v in out.iter_mut() {
            *v /= sum;
        }
    }
    Tensor::from_vec(&dims, out)
}

/// Integer activation between layers (softmax is never integer: it only
/// appears trailing, handled by the float epilogue above).
fn apply_qactivation(q: &mut [i8], a: Activation) {
    match a {
        Activation::None | Activation::Softmax => {}
        Activation::Relu => ops::qrelu(q),
        Activation::LeakyRelu(alpha) => ops::qleaky_relu(q, leaky_mult(alpha)),
    }
}

fn apply_activation(x: &Tensor, a: Activation) -> Tensor {
    match a {
        Activation::None => x.clone(),
        Activation::Relu => relu(x),
        Activation::LeakyRelu(alpha) => leaky_relu(x, alpha),
        Activation::Softmax => softmax(x),
    }
}

/// Engine wrapper so the interpreter plugs into the coordinator's
/// [`crate::runtime::InferenceEngine`] trait.
pub struct InterpEngine {
    model: Model,
}

impl InterpEngine {
    pub fn new(model: Model) -> Result<Self> {
        model.validate()?;
        Ok(InterpEngine { model })
    }

    pub fn model(&self) -> &Model {
        &self.model
    }
}

impl crate::runtime::InferenceEngine for InterpEngine {
    fn name(&self) -> &str {
        "interp"
    }

    fn infer(&self, input: &Tensor) -> Result<Tensor> {
        run(&self.model, input)
    }

    /// Real batch support: validate the model once, then run each image
    /// through the same per-layer path [`run`] uses — output is
    /// bit-identical to N single `infer` calls while skipping the repeated
    /// per-call `Model::validate` walk.
    fn infer_batch(&self, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        self.model.validate()?;
        let mut outs = Vec::with_capacity(inputs.len());
        for input in inputs {
            check_input(&self.model, input)?;
            let mut x = input.clone();
            for layer in &self.model.layers {
                x = run_layer(layer, &x)?;
            }
            outs.push(x);
        }
        Ok(outs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::zoo;
    use crate::util::XorShift64;

    #[test]
    fn run_all_paper_models() {
        let mut rng = XorShift64::new(10);
        for name in zoo::PAPER_MODELS {
            let m = zoo::by_name(name).unwrap().with_random_weights(7);
            let input = Tensor::rand(m.input.dims(), 0.0, 1.0, &mut rng);
            let out = run(&m, &input).unwrap();
            assert_eq!(out.dims(), m.output_shape().unwrap().dims(), "{name}");
            assert!(out.data().iter().all(|v| v.is_finite()), "{name}");
        }
    }

    #[test]
    fn classifier_outputs_are_probabilities() {
        let mut rng = XorShift64::new(11);
        let m = zoo::ball_classifier().with_random_weights(8);
        let input = Tensor::rand(&[16, 16, 1], 0.0, 1.0, &mut rng);
        let out = run(&m, &input).unwrap();
        let sum: f32 = out.data().iter().sum();
        assert!((sum - 1.0).abs() < 1e-5, "softmax should sum to 1, got {sum}");
        assert!(out.data().iter().all(|&p| (0.0..=1.0).contains(&p)));
    }

    #[test]
    fn rejects_wrong_input_shape() {
        let m = zoo::ball_classifier().with_random_weights(9);
        let bad = Tensor::zeros(&[8, 8, 1]);
        assert!(run(&m, &bad).is_err());
    }

    #[test]
    fn dropout_is_identity() {
        let x = Tensor::from_vec(&[1, 1, 2], vec![3.0, -4.0]).unwrap();
        let y = run_layer(&Layer::Dropout { rate: 0.5 }, &x).unwrap();
        assert_eq!(x, y);
    }

    #[test]
    fn quantized_run_tracks_f32_reference() {
        let mut rng = XorShift64::new(12);
        for name in zoo::PAPER_MODELS {
            let m = zoo::by_name(name).unwrap().with_random_weights(7);
            let opt = crate::passes::optimize(m).unwrap();
            let qp = crate::passes::quantize_model(&opt).unwrap();
            let x = Tensor::rand(opt.input.dims(), -1.0, 1.0, &mut rng);
            let yf = run(&opt, &x).unwrap();
            let yq = run_quantized(&opt, &qp, &x).unwrap();
            assert_eq!(yf.dims(), yq.dims(), "{name}");
            assert!(yq.data().iter().all(|v| v.is_finite()), "{name}");
            // Loose smoke bound here; the per-model documented bounds live
            // in the cross-engine suite.
            let err = yf.max_abs_diff(&yq).unwrap();
            assert!(err < 0.5, "{name}: int8 drifted err={err}");
        }
    }

    #[test]
    fn batch_matches_single_bit_identical() {
        use crate::runtime::InferenceEngine;
        let mut rng = XorShift64::new(13);
        let eng = InterpEngine::new(zoo::ball_classifier().with_random_weights(5)).unwrap();
        let inputs: Vec<Tensor> =
            (0..4).map(|_| Tensor::rand(&[16, 16, 1], -1.0, 1.0, &mut rng)).collect();
        let batched = eng.infer_batch(&inputs).unwrap();
        assert_eq!(batched.len(), 4);
        for (i, x) in inputs.iter().enumerate() {
            let single = eng.infer(x).unwrap();
            assert_eq!(single.data(), batched[i].data(), "image {i} diverged");
        }
        assert!(eng.infer_batch(&[]).unwrap().is_empty());
        // A bad shape anywhere in the batch is an error, same as single.
        let bad = vec![Tensor::zeros(&[8, 8, 1])];
        assert!(eng.infer_batch(&bad).is_err());
    }

    #[test]
    fn quantized_run_is_deterministic() {
        let m = zoo::ball_classifier().with_random_weights(3);
        let opt = crate::passes::optimize(m).unwrap();
        let qp = crate::passes::quantize_model(&opt).unwrap();
        let mut rng = XorShift64::new(4);
        let x = Tensor::rand(opt.input.dims(), -1.0, 1.0, &mut rng);
        let a = run_quantized(&opt, &qp, &x).unwrap();
        let b = run_quantized(&opt, &qp, &x).unwrap();
        assert_eq!(a.data(), b.data());
    }
}
