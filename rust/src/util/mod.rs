//! Small shared utilities: deterministic PRNG, hashing, timing helpers.

pub mod prng;
pub mod fxhash;

pub use prng::XorShift64;

/// Format a duration in the paper's unit (µs) with sensible precision.
pub fn fmt_us(us: f64) -> String {
    if us >= 1000.0 {
        format!("{:.0}\u{b5}s", us)
    } else if us >= 100.0 {
        format!("{:.1}\u{b5}s", us)
    } else {
        format!("{:.2}\u{b5}s", us)
    }
}

/// Best-effort human-readable message from a `catch_unwind` payload.
pub fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

/// Integer ceiling division.
#[inline]
pub fn div_ceil(a: usize, b: usize) -> usize {
    (a + b - 1) / b
}

/// Greatest common divisor (Euclid). `gcd(n, 0) == gcd(0, n) == n`.
#[inline]
pub fn gcd(a: usize, b: usize) -> usize {
    let (mut a, mut b) = (a, b);
    while b != 0 {
        let t = a % b;
        a = b;
        b = t;
    }
    a
}

/// Least common multiple; 0 if either argument is 0.
#[inline]
pub fn lcm(a: usize, b: usize) -> usize {
    if a == 0 || b == 0 {
        0
    } else {
        a / gcd(a, b) * b
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fmt_us_scales_precision() {
        assert_eq!(fmt_us(2.1), "2.10\u{b5}s");
        assert_eq!(fmt_us(135.7), "135.7\u{b5}s");
        assert_eq!(fmt_us(5630.0), "5630\u{b5}s");
    }

    #[test]
    fn panic_message_extracts_payloads() {
        let p = std::panic::catch_unwind(|| panic!("boom")).unwrap_err();
        assert_eq!(panic_message(&*p), "boom");
        let p = std::panic::catch_unwind(|| panic!("boom {}", 7)).unwrap_err();
        assert_eq!(panic_message(&*p), "boom 7");
        let p = std::panic::catch_unwind(|| std::panic::panic_any(42i32)).unwrap_err();
        assert_eq!(panic_message(&*p), "non-string panic payload");
    }

    #[test]
    fn div_ceil_basic() {
        assert_eq!(div_ceil(0, 4), 0);
        assert_eq!(div_ceil(1, 4), 1);
        assert_eq!(div_ceil(4, 4), 1);
        assert_eq!(div_ceil(5, 4), 2);
    }

    #[test]
    fn gcd_lcm_basics() {
        assert_eq!(gcd(12, 8), 4);
        assert_eq!(gcd(8, 12), 4);
        assert_eq!(gcd(7, 3), 1);
        assert_eq!(gcd(5, 0), 5);
        assert_eq!(gcd(0, 5), 5);
        assert_eq!(lcm(4, 6), 12);
        assert_eq!(lcm(3, 3), 3);
        assert_eq!(lcm(1, 9), 9);
        assert_eq!(lcm(0, 9), 0);
    }
}
