//! Small shared utilities: deterministic PRNG, hashing, timing helpers.

pub mod prng;
pub mod fxhash;

pub use prng::XorShift64;

/// Format a duration in the paper's unit (µs) with sensible precision.
pub fn fmt_us(us: f64) -> String {
    if us >= 1000.0 {
        format!("{:.0}\u{b5}s", us)
    } else if us >= 100.0 {
        format!("{:.1}\u{b5}s", us)
    } else {
        format!("{:.2}\u{b5}s", us)
    }
}

/// Integer ceiling division.
#[inline]
pub fn div_ceil(a: usize, b: usize) -> usize {
    (a + b - 1) / b
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fmt_us_scales_precision() {
        assert_eq!(fmt_us(2.1), "2.10\u{b5}s");
        assert_eq!(fmt_us(135.7), "135.7\u{b5}s");
        assert_eq!(fmt_us(5630.0), "5630\u{b5}s");
    }

    #[test]
    fn div_ceil_basic() {
        assert_eq!(div_ceil(0, 4), 0);
        assert_eq!(div_ceil(1, 4), 1);
        assert_eq!(div_ceil(4, 4), 1);
        assert_eq!(div_ceil(5, 4), 2);
    }
}
