//! Deterministic xorshift64* PRNG.
//!
//! The offline crate set has no `rand`, and determinism matters more than
//! statistical quality here: the same seed must produce the same weights on
//! the Rust and test sides so generated-C vs interpreter comparisons are
//! reproducible.

/// xorshift64* generator. Never returns the zero state.
#[derive(Debug, Clone)]
pub struct XorShift64 {
    state: u64,
}

impl XorShift64 {
    /// Create a generator from a seed. A zero seed is remapped to a fixed
    /// non-zero constant (xorshift has a zero fixed point).
    pub fn new(seed: u64) -> Self {
        Self {
            state: if seed == 0 { 0x9E37_79B9_7F4A_7C15 } else { seed },
        }
    }

    /// Next raw 64-bit value.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.state;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.state = x;
        x.wrapping_mul(0x2545_F491_4F6C_DD1D)
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        // 24 mantissa bits of uniformity.
        (self.next_u64() >> 40) as f32 / (1u64 << 24) as f32
    }

    /// Uniform f32 in [lo, hi).
    #[inline]
    pub fn uniform(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.next_f32()
    }

    /// Uniform usize in [0, n). `n` must be > 0.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        (self.next_u64() % n as u64) as usize
    }

    /// Approximate standard normal via sum of 4 uniforms (Irwin–Hall),
    /// adequate for weight initialization.
    pub fn normal(&mut self) -> f32 {
        let s: f32 = (0..4).map(|_| self.next_f32()).sum();
        (s - 2.0) * (12.0f32 / 4.0).sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = XorShift64::new(7);
        let mut b = XorShift64::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn zero_seed_ok() {
        let mut r = XorShift64::new(0);
        assert_ne!(r.next_u64(), 0);
    }

    #[test]
    fn f32_in_unit_interval() {
        let mut r = XorShift64::new(3);
        for _ in 0..10_000 {
            let v = r.next_f32();
            assert!((0.0..1.0).contains(&v), "{v}");
        }
    }

    #[test]
    fn uniform_respects_bounds() {
        let mut r = XorShift64::new(11);
        for _ in 0..10_000 {
            let v = r.uniform(-0.5, 0.5);
            assert!((-0.5..0.5).contains(&v));
        }
    }

    #[test]
    fn normal_roughly_centered() {
        let mut r = XorShift64::new(5);
        let n = 20_000;
        let mean: f32 = (0..n).map(|_| r.normal()).sum::<f32>() / n as f32;
        assert!(mean.abs() < 0.05, "mean={mean}");
    }

    #[test]
    fn below_in_range() {
        let mut r = XorShift64::new(9);
        for _ in 0..1000 {
            assert!(r.below(7) < 7);
        }
    }
}
