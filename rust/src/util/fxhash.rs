//! FxHash-style 64-bit content hash, used to key the codegen/object cache
//! (`cc::cache`) on generated source text + compiler flags.

const SEED: u64 = 0x51_7c_c1_b7_27_22_0a_95;

/// Hash a byte slice to 64 bits. Stable across runs and platforms.
pub fn hash_bytes(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    let mut chunks = bytes.chunks_exact(8);
    for c in &mut chunks {
        let v = u64::from_le_bytes(c.try_into().unwrap());
        h = (h.rotate_left(5) ^ v).wrapping_mul(SEED);
    }
    for &b in chunks.remainder() {
        h = (h.rotate_left(5) ^ b as u64).wrapping_mul(SEED);
    }
    h
}

/// Hash a str.
pub fn hash_str(s: &str) -> u64 {
    hash_bytes(s.as_bytes())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stable_and_distinguishing() {
        assert_eq!(hash_str("abc"), hash_str("abc"));
        assert_ne!(hash_str("abc"), hash_str("abd"));
        assert_ne!(hash_str(""), hash_str("a"));
    }

    #[test]
    fn remainder_bytes_matter() {
        assert_ne!(hash_bytes(&[1, 2, 3, 4, 5, 6, 7, 8, 9]), hash_bytes(&[1, 2, 3, 4, 5, 6, 7, 8, 10]));
    }
}
