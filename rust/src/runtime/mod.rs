//! XLA/PJRT runtime — executes the JAX-lowered artifacts from
//! `artifacts/*.hlo.txt` on the request path, entirely from Rust.
//!
//! This is the reproduction's **TensorFlow XLA baseline** (same compiler
//! lineage, same AOT workflow as the paper's `tfcompile`) *and* the bridge
//! that proves the three-layer architecture: Python/JAX/Pallas authored the
//! computation at build time; Rust loads the HLO text, compiles it once via
//! PJRT, and executes it with zero Python at run time.
//!
//! Interchange is HLO *text*, not serialized protos: jax ≥ 0.5 emits
//! 64-bit instruction ids that xla_extension 0.5.1 rejects; the text parser
//! reassigns ids (see /opt/xla-example/README.md).

mod engine;

pub use engine::XlaEngine;

use crate::tensor::Tensor;
use anyhow::Result;

/// The interface every execution backend implements; the coordinator
/// routes requests to `dyn InferenceEngine`.
pub trait InferenceEngine: Send + Sync {
    /// Engine label for metrics/tables.
    fn name(&self) -> &str;

    /// Run one inference.
    fn infer(&self, input: &Tensor) -> Result<Tensor>;

    /// Run a batch. The default loops `infer` (what a latency-oriented
    /// embedded deployment does); engines with real batch support (XLA,
    /// GPU models) override.
    fn infer_batch(&self, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        inputs.iter().map(|x| self.infer(x)).collect()
    }
}

/// Engine selector used across CLI / benches / coordinator.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EngineKind {
    /// NNCG generated C via cc + dlopen.
    Nncg,
    /// Naive runtime interpreter (framework baseline / Glow stand-in).
    Interp,
    /// XLA via PJRT CPU client (TF-XLA baseline).
    Xla,
}

impl EngineKind {
    pub fn name(&self) -> &'static str {
        match self {
            EngineKind::Nncg => "nncg",
            EngineKind::Interp => "interp",
            EngineKind::Xla => "xla",
        }
    }

    pub fn from_name(s: &str) -> Option<Self> {
        Some(match s {
            "nncg" => EngineKind::Nncg,
            "interp" => EngineKind::Interp,
            "xla" => EngineKind::Xla,
            _ => return None,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn engine_kind_names_round_trip() {
        for k in [EngineKind::Nncg, EngineKind::Interp, EngineKind::Xla] {
            assert_eq!(EngineKind::from_name(k.name()), Some(k));
        }
        assert_eq!(EngineKind::from_name("tf"), None);
    }
}
