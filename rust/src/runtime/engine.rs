//! PJRT-backed engine: load HLO text → compile once → execute many.

use super::InferenceEngine;
use crate::tensor::Tensor;
use anyhow::{bail, Context, Result};
use std::path::Path;
use std::sync::Mutex;

/// An inference engine backed by the XLA PJRT CPU client.
///
/// The artifact is the HLO text written by `python/compile/aot.py`; it was
/// lowered with `return_tuple=True`, so execution results unwrap with
/// `to_tuple1`.
pub struct XlaEngine {
    // xla::PjRtLoadedExecutable is not Sync; executions are serialized.
    // (PJRT CPU execution is single-threaded here anyway — the container
    // has one core, and the paper's latency story is single-image.)
    exe: Mutex<xla::PjRtLoadedExecutable>,
    name: String,
    input_dims: Vec<usize>,
    output_dims: Vec<usize>,
}

// SAFETY: the `xla` crate's executable holds raw PJRT pointers and an `Rc`
// to the client, making it neither Send nor Sync by default. Every access
// in this engine goes through the `Mutex` (including drop order: the
// executable and its client are owned exclusively by this struct), and the
// PJRT *CPU* client has no thread affinity, so serialized cross-thread use
// is sound.
unsafe impl Send for XlaEngine {}
unsafe impl Sync for XlaEngine {}

impl XlaEngine {
    /// Load an HLO-text artifact and compile it on the CPU PJRT client.
    ///
    /// `input_dims`/`output_dims` are the logical HWC shapes of the model;
    /// the artifact itself operates on the flattened f32 buffer (the AOT
    /// path exports `f(x: f32[numel]) -> f32[out_numel]` to keep the ABI
    /// layout-free).
    pub fn load(hlo_path: &Path, name: &str, input_dims: &[usize], output_dims: &[usize]) -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        let proto = xla::HloModuleProto::from_text_file(
            hlo_path.to_str().context("non-utf8 artifact path")?,
        )
        .with_context(|| format!("parsing HLO text {}", hlo_path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client.compile(&comp).context("XLA compile")?;
        Ok(XlaEngine {
            exe: Mutex::new(exe),
            name: format!("xla:{name}"),
            input_dims: input_dims.to_vec(),
            output_dims: output_dims.to_vec(),
        })
    }

    /// Standard artifact location for a model name.
    pub fn artifact_path(artifacts_dir: &Path, model: &str) -> std::path::PathBuf {
        artifacts_dir.join(format!("{model}.hlo.txt"))
    }

    /// Execute on a raw f32 buffer (flattened HWC).
    pub fn infer_flat(&self, input: &[f32]) -> Result<Vec<f32>> {
        let expect: usize = self.input_dims.iter().product();
        if input.len() != expect {
            bail!("input has {} values, model wants {expect}", input.len());
        }
        let lit = xla::Literal::vec1(input);
        let exe = self.exe.lock().unwrap();
        let result = exe.execute::<xla::Literal>(&[lit])?[0][0].to_literal_sync()?;
        let out = result.to_tuple1()?;
        Ok(out.to_vec::<f32>()?)
    }
}

impl InferenceEngine for XlaEngine {
    fn name(&self) -> &str {
        &self.name
    }

    fn infer(&self, input: &Tensor) -> Result<Tensor> {
        if input.dims() != self.input_dims {
            bail!("input shape {:?} != expected {:?}", input.dims(), self.input_dims);
        }
        let out = self.infer_flat(input.data())?;
        Tensor::from_vec(&self.output_dims, out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Round-trip an identity-ish HLO module through PJRT. Written as HLO
    /// text by hand (the same format aot.py produces), so this test runs
    /// without the Python artifacts.
    const DOUBLE_HLO: &str = r#"
HloModule jit_f, entry_computation_layout={(f32[4]{0})->(f32[4]{0})}

ENTRY main.5 {
  Arg_0.1 = f32[4]{0} parameter(0)
  constant.2 = f32[] constant(2)
  broadcast.3 = f32[4]{0} broadcast(constant.2), dimensions={}
  multiply.4 = f32[4]{0} multiply(Arg_0.1, broadcast.3)
  ROOT tuple.5 = (f32[4]{0}) tuple(multiply.4)
}
"#;

    fn write_artifact() -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("nncg-runtime-tests");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("double.hlo.txt");
        std::fs::write(&p, DOUBLE_HLO).unwrap();
        p
    }

    #[test]
    fn loads_and_executes_hlo_text() {
        let p = write_artifact();
        let eng = XlaEngine::load(&p, "double", &[2, 2, 1], &[2, 2, 1]).unwrap();
        let x = Tensor::from_vec(&[2, 2, 1], vec![1.0, 2.0, 3.0, 4.0]).unwrap();
        let y = eng.infer(&x).unwrap();
        assert_eq!(y.data(), &[2.0, 4.0, 6.0, 8.0]);
    }

    #[test]
    fn rejects_wrong_shapes() {
        let p = write_artifact();
        let eng = XlaEngine::load(&p, "double", &[2, 2, 1], &[2, 2, 1]).unwrap();
        assert!(eng.infer(&Tensor::zeros(&[3, 1, 1])).is_err());
        assert!(eng.infer_flat(&[0.0; 7]).is_err());
    }

    #[test]
    fn missing_artifact_is_a_clean_error() {
        let err = XlaEngine::load(Path::new("/nonexistent/x.hlo.txt"), "x", &[1], &[1]);
        assert!(err.is_err());
    }
}
