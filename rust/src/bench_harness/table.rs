//! ASCII table printer matching the paper's result tables.

/// A simple left-header table: rows are platforms, columns engines.
#[derive(Debug, Default)]
pub struct Table {
    title: String,
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "row width mismatch");
        self.rows.push(cells);
    }

    /// Render with column auto-width.
    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.chars().count()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.chars().count());
            }
        }
        let sep: String = widths.iter().map(|w| "-".repeat(w + 2)).collect::<Vec<_>>().join("+");
        let fmt_row = |cells: &[String]| {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!(" {:<w$} ", c, w = widths[i]))
                .collect::<Vec<_>>()
                .join("|")
        };
        let mut out = String::new();
        out.push_str(&format!("{}\n", self.title));
        out.push_str(&format!("{}\n", fmt_row(&self.headers)));
        out.push_str(&format!("{sep}\n"));
        for row in &self.rows {
            out.push_str(&format!("{}\n", fmt_row(row)));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("TABLE IV", &["Platform", "NNCG", "XLA"]);
        t.row(vec!["Intel i7".into(), "2.10µs".into(), "24.81µs".into()]);
        t.row(vec!["Atom".into(), "17.51µs".into(), "N/A".into()]);
        let r = t.render();
        assert!(r.contains("TABLE IV"));
        assert!(r.contains("Intel i7"));
        assert!(r.lines().count() >= 5);
    }

    #[test]
    #[should_panic]
    fn wrong_width_panics() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(vec!["only-one".into()]);
    }
}
