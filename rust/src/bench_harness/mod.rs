//! Hand-rolled micro-benchmark harness (criterion is unavailable in the
//! offline crate set).
//!
//! Follows the paper's methodology (§III-C): warm up, run N iterations
//! (100,000 for the small classifiers, 1,000 for the robot detector), and
//! report the mean; we additionally keep median/p95/stddev because single
//! shared-machine runs are noisy.

mod stats;
mod table;

pub use stats::Stats;
pub use table::Table;

use std::time::Instant;

/// Benchmark configuration.
#[derive(Debug, Clone, Copy)]
pub struct BenchConfig {
    pub warmup_iters: usize,
    pub iters: usize,
    /// Batch inner iterations per timestamp to amortize clock overhead for
    /// sub-µs functions.
    pub inner: usize,
}

impl BenchConfig {
    /// Paper settings for the small classifiers ("ran small networks
    /// 100.000 times"), scaled down 10× to keep the full suite fast; the
    /// mean is stable well before that.
    pub fn small() -> Self {
        BenchConfig { warmup_iters: 200, iters: 10_000, inner: 1 }
    }

    /// Paper settings for the larger robot detector ("1000 times").
    pub fn large() -> Self {
        BenchConfig { warmup_iters: 20, iters: 1_000, inner: 1 }
    }

    /// Quick settings for tests.
    pub fn quick() -> Self {
        BenchConfig { warmup_iters: 5, iters: 50, inner: 1 }
    }
}

/// Time a closure per the config; returns per-call statistics in µs.
pub fn bench<F: FnMut()>(cfg: &BenchConfig, mut f: F) -> Stats {
    for _ in 0..cfg.warmup_iters {
        f();
    }
    let mut samples_us = Vec::with_capacity(cfg.iters);
    for _ in 0..cfg.iters {
        let t0 = Instant::now();
        for _ in 0..cfg.inner {
            f();
        }
        let el = t0.elapsed();
        samples_us.push(el.as_secs_f64() * 1e6 / cfg.inner as f64);
    }
    Stats::from_samples(samples_us)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_counts_calls() {
        let mut calls = 0usize;
        let cfg = BenchConfig { warmup_iters: 3, iters: 10, inner: 2 };
        let s = bench(&cfg, || calls += 1);
        assert_eq!(calls, 3 + 10 * 2);
        assert_eq!(s.n, 10);
        assert!(s.mean_us >= 0.0);
    }

    #[test]
    fn bench_measures_sleeps_roughly() {
        let cfg = BenchConfig { warmup_iters: 1, iters: 20, inner: 1 };
        let s = bench(&cfg, || std::thread::sleep(std::time::Duration::from_micros(200)));
        assert!(s.mean_us > 150.0 && s.mean_us < 5000.0, "mean={}", s.mean_us);
        assert!(s.median_us > 150.0);
    }
}
