//! Hand-rolled micro-benchmark harness (criterion is unavailable in the
//! offline crate set).
//!
//! Follows the paper's methodology (§III-C): warm up, run N iterations
//! (100,000 for the small classifiers, 1,000 for the robot detector), and
//! report the mean; we additionally keep median/p95/stddev because single
//! shared-machine runs are noisy.
//!
//! Sub-µs kernels (the ball classifier runs in ~2µs) would otherwise be
//! dominated by `Instant::now()` overhead, so each timestamped sample
//! batches `inner` calls. `inner == AUTO_INNER` (the preset default)
//! calibrates that batch size from a short probe run instead of
//! hardcoding 1.

mod stats;
mod table;

pub use stats::Stats;
pub use table::Table;

use std::time::Instant;

/// Sentinel: calibrate `inner` from a probe run (see [`BenchConfig`]).
pub const AUTO_INNER: usize = 0;

/// Probe calls used by the auto-calibration.
const CAL_PROBES: usize = 9;

/// Target wall-clock per timestamped batch, µs. Large against clock
/// overhead (~20ns), small against the shortest test budgets.
const CAL_TARGET_US: f64 = 64.0;

/// Upper bound on the calibrated batch size.
const CAL_MAX_INNER: usize = 4096;

/// Benchmark configuration.
#[derive(Debug, Clone, Copy)]
pub struct BenchConfig {
    pub warmup_iters: usize,
    pub iters: usize,
    /// Batch inner iterations per timestamp to amortize clock overhead for
    /// sub-µs functions. [`AUTO_INNER`] (0) calibrates it from a probe run
    /// after warmup; any other value is used as-is.
    pub inner: usize,
}

impl BenchConfig {
    /// Paper settings for the small classifiers ("ran small networks
    /// 100.000 times"), scaled down 10× to keep the full suite fast; the
    /// mean is stable well before that.
    pub fn small() -> Self {
        BenchConfig { warmup_iters: 200, iters: 10_000, inner: AUTO_INNER }
    }

    /// Paper settings for the larger robot detector ("1000 times").
    pub fn large() -> Self {
        BenchConfig { warmup_iters: 20, iters: 1_000, inner: AUTO_INNER }
    }

    /// Quick settings for tests (fixed inner keeps call counts exact).
    pub fn quick() -> Self {
        BenchConfig { warmup_iters: 5, iters: 50, inner: 1 }
    }
}

/// Pick an inner-batch size so one timestamped batch takes about
/// [`CAL_TARGET_US`]: median single-call time over a few probes, clamped
/// to `[1, CAL_MAX_INNER]`.
fn calibrate_inner<F: FnMut()>(f: &mut F) -> usize {
    let mut probes = [0.0f64; CAL_PROBES];
    for p in probes.iter_mut() {
        let t0 = Instant::now();
        f();
        *p = t0.elapsed().as_secs_f64() * 1e6;
    }
    probes.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let median = probes[CAL_PROBES / 2];
    if median <= 0.0 {
        return CAL_MAX_INNER;
    }
    ((CAL_TARGET_US / median).ceil() as usize).clamp(1, CAL_MAX_INNER)
}

/// Time a closure per the config; returns per-call statistics in µs.
pub fn bench<F: FnMut()>(cfg: &BenchConfig, mut f: F) -> Stats {
    for _ in 0..cfg.warmup_iters {
        f();
    }
    let inner = if cfg.inner == AUTO_INNER { calibrate_inner(&mut f) } else { cfg.inner };
    let mut samples_us = Vec::with_capacity(cfg.iters);
    for _ in 0..cfg.iters {
        let t0 = Instant::now();
        for _ in 0..inner {
            f();
        }
        let el = t0.elapsed();
        samples_us.push(el.as_secs_f64() * 1e6 / inner as f64);
    }
    Stats::from_samples(samples_us)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_counts_calls() {
        let mut calls = 0usize;
        let cfg = BenchConfig { warmup_iters: 3, iters: 10, inner: 2 };
        let s = bench(&cfg, || calls += 1);
        assert_eq!(calls, 3 + 10 * 2);
        assert_eq!(s.n, 10);
        assert!(s.mean_us >= 0.0);
    }

    #[test]
    fn bench_measures_sleeps_roughly() {
        let cfg = BenchConfig { warmup_iters: 1, iters: 20, inner: 1 };
        let s = bench(&cfg, || std::thread::sleep(std::time::Duration::from_micros(200)));
        assert!(s.mean_us > 150.0 && s.mean_us < 5000.0, "mean={}", s.mean_us);
        assert!(s.median_us > 150.0);
    }

    #[test]
    fn auto_inner_scales_up_for_fast_functions() {
        // A ~ns closure: calibration must batch many calls per timestamp.
        let mut calls = 0usize;
        let cfg = BenchConfig { warmup_iters: 1, iters: 5, inner: AUTO_INNER };
        let s = bench(&cfg, || calls += 1);
        assert_eq!(s.n, 5);
        // warmup(1) + probes(9) + iters*inner; inner > 1 for a no-op body.
        assert!(calls > 1 + 9 + 5, "auto inner did not batch: {calls} calls");
    }

    #[test]
    fn auto_inner_stays_at_one_for_slow_functions() {
        let mut calls = 0usize;
        let cfg = BenchConfig { warmup_iters: 0, iters: 3, inner: AUTO_INNER };
        bench(&cfg, || {
            calls += 1;
            std::thread::sleep(std::time::Duration::from_micros(300));
        });
        // probes(9) + iters*1 — a >CAL_TARGET_US call must not be batched.
        assert_eq!(calls, 9 + 3);
    }

    #[test]
    fn presets_use_auto_inner() {
        assert_eq!(BenchConfig::small().inner, AUTO_INNER);
        assert_eq!(BenchConfig::large().inner, AUTO_INNER);
        assert_eq!(BenchConfig::quick().inner, 1);
    }
}
