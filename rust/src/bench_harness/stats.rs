//! Sample statistics for benchmark runs.

/// Summary statistics over per-call samples (µs).
#[derive(Debug, Clone)]
pub struct Stats {
    pub n: usize,
    pub mean_us: f64,
    pub median_us: f64,
    pub p95_us: f64,
    pub min_us: f64,
    pub max_us: f64,
    pub stddev_us: f64,
}

impl Stats {
    pub fn from_samples(mut samples: Vec<f64>) -> Stats {
        assert!(!samples.is_empty(), "no samples");
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = samples.len();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let var = samples.iter().map(|s| (s - mean) * (s - mean)).sum::<f64>() / n as f64;
        Stats {
            n,
            mean_us: mean,
            median_us: percentile(&samples, 50.0),
            p95_us: percentile(&samples, 95.0),
            min_us: samples[0],
            max_us: samples[n - 1],
            stddev_us: var.sqrt(),
        }
    }

    /// One-line human summary.
    pub fn summary(&self) -> String {
        format!(
            "mean {} | median {} | p95 {} | min {} | sd {:.2} (n={})",
            crate::util::fmt_us(self.mean_us),
            crate::util::fmt_us(self.median_us),
            crate::util::fmt_us(self.p95_us),
            crate::util::fmt_us(self.min_us),
            self.stddev_us,
            self.n
        )
    }
}

/// Percentile over a pre-sorted slice (nearest-rank with interpolation).
fn percentile(sorted: &[f64], p: f64) -> f64 {
    if sorted.len() == 1 {
        return sorted[0];
    }
    let rank = p / 100.0 * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_values() {
        let s = Stats::from_samples(vec![1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.mean_us, 3.0);
        assert_eq!(s.median_us, 3.0);
        assert_eq!(s.min_us, 1.0);
        assert_eq!(s.max_us, 5.0);
        assert!((s.stddev_us - 2.0f64.sqrt()).abs() < 1e-9);
    }

    #[test]
    fn unsorted_input_ok() {
        let s = Stats::from_samples(vec![5.0, 1.0, 3.0]);
        assert_eq!(s.median_us, 3.0);
    }

    #[test]
    fn percentile_interpolates() {
        let v = vec![0.0, 10.0];
        assert_eq!(percentile(&v, 50.0), 5.0);
        assert_eq!(percentile(&v, 0.0), 0.0);
        assert_eq!(percentile(&v, 100.0), 10.0);
    }

    #[test]
    #[should_panic]
    fn empty_panics() {
        Stats::from_samples(vec![]);
    }
}
