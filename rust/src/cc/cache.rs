//! Content-addressed cache of generated C files and compiled objects.
//!
//! Keyed on a hash of (source text, option tag, compiler). Benches sweep
//! many option combinations over the same models; recompiling identical
//! sources would dominate wall-clock otherwise.
//!
//! Robustness: objects are published atomically (compile to a tmp sibling,
//! then `rename` — a crashed/killed compiler can never leave a truncated
//! `.so` under the final name), and cache hits are validated (ELF magic)
//! so an object corrupted on disk falls through to a recompile instead of
//! being `dlopen`-ed.

use super::driver::{CcDriver, CcTarget};
use crate::faults::{FaultPlan, FaultSite};
use crate::util::fxhash;
use anyhow::{Context, Result};
use std::path::{Path, PathBuf};
use std::sync::Arc;

/// Minimal sanity check on a cached object: non-truncated, and on Linux
/// the ELF magic is intact. Read-only — a cache hit must not rewrite the
/// object (mtime is part of the "no recompile" contract).
pub fn object_is_valid(path: &Path) -> bool {
    use std::io::Read;
    let mut magic = [0u8; 4];
    match std::fs::File::open(path).and_then(|mut f| f.read_exact(&mut magic)) {
        Ok(()) => {
            if cfg!(target_os = "linux") {
                magic == [0x7f, b'E', b'L', b'F']
            } else {
                true
            }
        }
        Err(_) => false,
    }
}

/// Cache rooted at a working directory.
pub struct ObjectCache {
    root: PathBuf,
    faults: Option<Arc<FaultPlan>>,
}

impl ObjectCache {
    pub fn new(root: impl AsRef<Path>) -> Self {
        ObjectCache { root: root.as_ref().to_path_buf(), faults: None }
    }

    /// Attach a fault-injection plan (chaos testing: `CacheCorrupt` scribbles
    /// over a cached object right before the validity check).
    pub fn with_faults(mut self, plan: Arc<FaultPlan>) -> Self {
        self.faults = Some(plan);
        self
    }

    /// Path pair for a cache key.
    fn paths(&self, ident: &str, tag: &str, key: u64) -> (PathBuf, PathBuf) {
        let stem = format!("{ident}-{tag}-{key:016x}");
        (self.root.join(format!("{stem}.c")), self.root.join(format!("{stem}.so")))
    }

    /// Return (c_path, so_path), compiling only if the object is absent or
    /// fails validation.
    pub fn get_or_compile(&self, ident: &str, tag: &str, source: &str, driver: &CcDriver) -> Result<(PathBuf, PathBuf)> {
        std::fs::create_dir_all(&self.root)
            .with_context(|| format!("creating cache dir {}", self.root.display()))?;
        let key = fxhash::hash_str(&format!("{source}\x00{tag}\x00{}", driver.cc));
        let (c_path, so_path) = self.paths(ident, tag, key);
        if so_path.exists() {
            if let Some(plan) = &self.faults {
                if plan.should_fire(FaultSite::CacheCorrupt) {
                    // Simulate a torn write / bad flash on the cached object.
                    let _ = std::fs::write(&so_path, b"not an object file");
                }
            }
            if object_is_valid(&so_path) {
                return Ok((c_path, so_path));
            }
            // Corrupted object: discard and fall through to a recompile.
            eprintln!("[nncg] cached object {} failed validation; recompiling", so_path.display());
            let _ = std::fs::remove_file(&so_path);
        }
        std::fs::write(&c_path, source)?;
        // Atomic publish: compile to a tmp sibling, rename into place. A
        // concurrent or killed compile can never expose a partial object.
        let tmp = so_path.with_extension(format!("so.tmp-{}", std::process::id()));
        let compiled = driver.compile(&c_path, Some(&tmp), CcTarget::NativeShared);
        if compiled.is_err() {
            let _ = std::fs::remove_file(&tmp);
        }
        compiled?;
        std::fs::rename(&tmp, &so_path)
            .with_context(|| format!("publishing {}", so_path.display()))?;
        Ok((c_path, so_path))
    }

    /// Remove all cached artifacts (tests).
    pub fn clear(&self) -> Result<()> {
        if self.root.exists() {
            for entry in std::fs::read_dir(&self.root)? {
                let p = entry?.path();
                let ext_matches = p
                    .extension()
                    .map_or(false, |e| e == "c" || e == "so" || e.to_string_lossy().starts_with("tmp-"));
                if ext_matches {
                    std::fs::remove_file(p)?;
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::faults::FaultSpec;

    #[test]
    fn different_sources_get_different_objects() {
        let dir = std::env::temp_dir().join("nncg-cache-distinct");
        let cache = ObjectCache::new(&dir);
        cache.clear().unwrap();
        let driver = CcDriver::detect().unwrap();
        let src_a = "void a_inference(const float *x, float *y) { y[0] = x[0]; }\n";
        let src_b = "void a_inference(const float *x, float *y) { y[0] = x[0] * 2.0f; }\n";
        let (_, so_a) = cache.get_or_compile("a", "t", src_a, &driver).unwrap();
        let (_, so_b) = cache.get_or_compile("a", "t", src_b, &driver).unwrap();
        assert_ne!(so_a, so_b);
        assert!(so_a.exists() && so_b.exists());
    }

    #[test]
    fn same_source_reuses_object() {
        let dir = std::env::temp_dir().join("nncg-cache-reuse");
        let cache = ObjectCache::new(&dir);
        cache.clear().unwrap();
        let driver = CcDriver::detect().unwrap();
        let src = "void r_inference(const float *x, float *y) { y[0] = x[0]; }\n";
        let (_, so1) = cache.get_or_compile("r", "t", src, &driver).unwrap();
        let mtime1 = std::fs::metadata(&so1).unwrap().modified().unwrap();
        let (_, so2) = cache.get_or_compile("r", "t", src, &driver).unwrap();
        let mtime2 = std::fs::metadata(&so2).unwrap().modified().unwrap();
        assert_eq!(so1, so2);
        assert_eq!(mtime1, mtime2, "object must not be recompiled");
    }

    #[test]
    fn corrupted_object_is_recompiled() {
        let dir = std::env::temp_dir().join("nncg-cache-corrupt");
        let cache = ObjectCache::new(&dir);
        cache.clear().unwrap();
        let driver = CcDriver::detect().unwrap();
        let src = "void k_inference(const float *x, float *y) { y[0] = x[0]; }\n";
        let (_, so) = cache.get_or_compile("k", "t", src, &driver).unwrap();
        assert!(object_is_valid(&so));
        std::fs::write(&so, b"garbage, definitely not ELF").unwrap();
        assert!(!object_is_valid(&so));
        let (_, so2) = cache.get_or_compile("k", "t", src, &driver).unwrap();
        assert_eq!(so, so2);
        assert!(object_is_valid(&so2), "corrupted object must be replaced by a fresh compile");
    }

    #[test]
    fn injected_corruption_heals_transparently() {
        let dir = std::env::temp_dir().join("nncg-cache-inject");
        let plan = FaultPlan::builder(31).site(FaultSite::CacheCorrupt, FaultSpec::First(1)).build();
        let cache = ObjectCache::new(&dir).with_faults(plan.clone());
        cache.clear().unwrap();
        let driver = CcDriver::detect().unwrap();
        let src = "void j_inference(const float *x, float *y) { y[0] = x[0]; }\n";
        let (_, _) = cache.get_or_compile("j", "t", src, &driver).unwrap();
        // Hit path: injection corrupts, validation catches, recompile heals.
        let (_, so) = cache.get_or_compile("j", "t", src, &driver).unwrap();
        assert_eq!(plan.fired(FaultSite::CacheCorrupt), 1);
        assert!(object_is_valid(&so));
    }

    #[test]
    fn failed_compile_leaves_no_partial_object() {
        let dir = std::env::temp_dir().join("nncg-cache-atomic");
        let cache = ObjectCache::new(&dir);
        cache.clear().unwrap();
        let driver = CcDriver::detect().unwrap();
        let src = "this is not C\n";
        assert!(cache.get_or_compile("p", "t", src, &driver).is_err());
        for entry in std::fs::read_dir(&dir).unwrap() {
            let p = entry.unwrap().path();
            assert!(
                p.extension().map_or(true, |e| e != "so"),
                "no .so may be published for a failed compile: {}",
                p.display()
            );
        }
    }
}
