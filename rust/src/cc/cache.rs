//! Content-addressed cache of generated C files and compiled objects.
//!
//! Keyed on a hash of (source text, option tag, compiler). Benches sweep
//! many option combinations over the same models; recompiling identical
//! sources would dominate wall-clock otherwise.

use super::driver::{CcDriver, CcTarget};
use crate::util::fxhash;
use anyhow::{Context, Result};
use std::path::{Path, PathBuf};

/// Cache rooted at a working directory.
pub struct ObjectCache {
    root: PathBuf,
}

impl ObjectCache {
    pub fn new(root: impl AsRef<Path>) -> Self {
        ObjectCache { root: root.as_ref().to_path_buf() }
    }

    /// Path pair for a cache key.
    fn paths(&self, ident: &str, tag: &str, key: u64) -> (PathBuf, PathBuf) {
        let stem = format!("{ident}-{tag}-{key:016x}");
        (self.root.join(format!("{stem}.c")), self.root.join(format!("{stem}.so")))
    }

    /// Return (c_path, so_path), compiling only if the object is absent.
    pub fn get_or_compile(&self, ident: &str, tag: &str, source: &str, driver: &CcDriver) -> Result<(PathBuf, PathBuf)> {
        std::fs::create_dir_all(&self.root)
            .with_context(|| format!("creating cache dir {}", self.root.display()))?;
        let key = fxhash::hash_str(&format!("{source}\x00{tag}\x00{}", driver.cc));
        let (c_path, so_path) = self.paths(ident, tag, key);
        if so_path.exists() {
            return Ok((c_path, so_path));
        }
        std::fs::write(&c_path, source)?;
        driver.compile(&c_path, Some(&so_path), CcTarget::NativeShared)?;
        Ok((c_path, so_path))
    }

    /// Remove all cached artifacts (tests).
    pub fn clear(&self) -> Result<()> {
        if self.root.exists() {
            for entry in std::fs::read_dir(&self.root)? {
                let p = entry?.path();
                if p.extension().map_or(false, |e| e == "c" || e == "so") {
                    std::fs::remove_file(p)?;
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn different_sources_get_different_objects() {
        let dir = std::env::temp_dir().join("nncg-cache-distinct");
        let cache = ObjectCache::new(&dir);
        cache.clear().unwrap();
        let driver = CcDriver::detect().unwrap();
        let src_a = "void a_inference(const float *x, float *y) { y[0] = x[0]; }\n";
        let src_b = "void a_inference(const float *x, float *y) { y[0] = x[0] * 2.0f; }\n";
        let (_, so_a) = cache.get_or_compile("a", "t", src_a, &driver).unwrap();
        let (_, so_b) = cache.get_or_compile("a", "t", src_b, &driver).unwrap();
        assert_ne!(so_a, so_b);
        assert!(so_a.exists() && so_b.exists());
    }

    #[test]
    fn same_source_reuses_object() {
        let dir = std::env::temp_dir().join("nncg-cache-reuse");
        let cache = ObjectCache::new(&dir);
        cache.clear().unwrap();
        let driver = CcDriver::detect().unwrap();
        let src = "void r_inference(const float *x, float *y) { y[0] = x[0]; }\n";
        let (_, so1) = cache.get_or_compile("r", "t", src, &driver).unwrap();
        let mtime1 = std::fs::metadata(&so1).unwrap().modified().unwrap();
        let (_, so2) = cache.get_or_compile("r", "t", src, &driver).unwrap();
        let mtime2 = std::fs::metadata(&so2).unwrap().modified().unwrap();
        assert_eq!(so1, so2);
        assert_eq!(mtime1, mtime2, "object must not be recompiled");
    }
}
