//! Compile-and-load execution engine for generated C.
//!
//! `CompiledCnn` is the deployment path the paper measures: NNCG emits a C
//! file, a C compiler turns it into machine code, and the coordinator calls
//! the single inference function directly (here via `dlopen` into our own
//! process — zero marshalling on the hot path).
//!
//! Also provides the cross-compilation checks behind the paper's §III-B
//! deployment matrix (strict ANSI, 32-bit, `-march` variants).

mod cache;
mod driver;

pub use cache::{object_is_valid, ObjectCache};
pub use driver::{
    detect_compiler, detect_compiler_from, CcDriver, CcTarget, CompileLimits, CompileStats,
};

use crate::codegen::{c_ident, generate_c, CodegenOptions};
use crate::faults::FaultSite;
use crate::graph::Model;
use crate::tensor::Tensor;
use anyhow::{Context, Result};
use std::path::{Path, PathBuf};

/// A generated, compiled and dlopen-ed CNN.
///
/// The `libloading::Library` must outlive the symbol; we keep both and only
/// hand out safe wrappers.
pub struct CompiledCnn {
    _lib: libloading::Library,
    func: unsafe extern "C" fn(*const f32, *mut f32),
    /// The batched entry point (`<ident>_inference_batch`) emitted alongside
    /// the single-image function since PR 9. `None` when loading a stale
    /// cached object compiled before the batch entry existed — everything
    /// then degrades to per-image calls through the trait default.
    batch_func: Option<unsafe extern "C" fn(*const f32, *mut f32, std::os::raw::c_int)>,
    /// The generated C keeps its intermediates in `static` scratch buffers
    /// (the paper's deployment model is a single-threaded embedded loop),
    /// so concurrent calls into one loaded object would race. This lock
    /// serializes them; uncontended cost is ~20 ns against multi-µs
    /// inferences.
    call_guard: std::sync::Mutex<()>,
    input_dims: Vec<usize>,
    output_dims: Vec<usize>,
    name: String,
    /// Path of the generated C source (kept for inspection/debugging).
    pub c_path: PathBuf,
    /// Path of the shared object.
    pub so_path: PathBuf,
}

impl CompiledCnn {
    /// Generate C for `model` with `opts`, compile it into `work_dir`, and
    /// load the inference symbol. Results are content-cached: the same
    /// model+options pair compiles only once per `work_dir`.
    pub fn build(model: &Model, opts: &CodegenOptions, work_dir: impl AsRef<Path>) -> Result<Self> {
        let source = generate_c(model, opts)?;
        Self::from_source(model, opts, &source, work_dir)
    }

    /// Same as [`CompiledCnn::build`] with an explicit (possibly hardened /
    /// fault-injected) compiler driver.
    pub fn build_with(
        model: &Model,
        opts: &CodegenOptions,
        work_dir: impl AsRef<Path>,
        driver: &CcDriver,
    ) -> Result<Self> {
        let source = generate_c(model, opts)?;
        Self::from_source_with(model, opts, &source, work_dir, driver)
    }

    /// Same as [`CompiledCnn::build`] but with pre-generated source.
    pub fn from_source(model: &Model, opts: &CodegenOptions, source: &str, work_dir: impl AsRef<Path>) -> Result<Self> {
        let driver = CcDriver::detect()?;
        Self::from_source_with(model, opts, source, work_dir, &driver)
    }

    /// Core build path with an explicit driver; the driver's fault plan (if
    /// any) also covers the cache-validation and dlopen seams.
    pub fn from_source_with(
        model: &Model,
        opts: &CodegenOptions,
        source: &str,
        work_dir: impl AsRef<Path>,
        driver: &CcDriver,
    ) -> Result<Self> {
        let mut cache = ObjectCache::new(work_dir.as_ref());
        if let Some(plan) = driver.faults() {
            cache = cache.with_faults(std::sync::Arc::clone(plan));
        }
        let ident = c_ident(&model.name);
        let (c_path, so_path) = cache
            .get_or_compile(&ident, &opts.tag(), source, driver)
            .context("compiling generated C")?;

        if let Some(plan) = driver.faults() {
            if plan.should_fire(FaultSite::DlopenFail) {
                anyhow::bail!("injected dlopen failure for {}", so_path.display());
            }
        }
        let lib = unsafe { libloading::Library::new(&so_path) }
            .with_context(|| format!("dlopen {}", so_path.display()))?;
        let func = unsafe {
            let sym: libloading::Symbol<unsafe extern "C" fn(*const f32, *mut f32)> =
                lib.get(format!("{ident}_inference\0").as_bytes())?;
            *sym
        };
        let batch_func = unsafe {
            lib.get::<unsafe extern "C" fn(*const f32, *mut f32, std::os::raw::c_int)>(
                format!("{ident}_inference_batch\0").as_bytes(),
            )
            .ok()
            .map(|sym| *sym)
        };
        Ok(CompiledCnn {
            _lib: lib,
            func,
            batch_func,
            call_guard: std::sync::Mutex::new(()),
            input_dims: model.input.dims().to_vec(),
            output_dims: model.output_shape()?.dims().to_vec(),
            name: model.name.clone(),
            c_path,
            so_path,
        })
    }

    /// Run one inference. Allocates the output tensor.
    pub fn infer(&self, input: &Tensor) -> Result<Tensor> {
        if input.dims() != self.input_dims {
            anyhow::bail!("input shape {:?} != expected {:?}", input.dims(), self.input_dims);
        }
        let mut out = Tensor::zeros(&self.output_dims);
        self.infer_into(input.data(), out.data_mut());
        Ok(out)
    }

    /// Zero-allocation hot-path variant: caller provides the output slice.
    ///
    /// # Panics
    /// Debug-asserts the slice lengths; release callers must size correctly.
    #[inline]
    pub fn infer_into(&self, input: &[f32], output: &mut [f32]) {
        debug_assert_eq!(input.len(), self.input_dims.iter().product::<usize>());
        debug_assert_eq!(output.len(), self.output_dims.iter().product::<usize>());
        let _guard = self.call_guard.lock().unwrap();
        unsafe { (self.func)(input.as_ptr(), output.as_mut_ptr()) };
    }

    pub fn input_dims(&self) -> &[usize] {
        &self.input_dims
    }

    pub fn output_dims(&self) -> &[usize] {
        &self.output_dims
    }

    /// Whether the loaded object exports the batched entry point (objects
    /// cached before the batch entry existed do not).
    pub fn has_batch_entry(&self) -> bool {
        self.batch_func.is_some()
    }

    /// Run `inputs` through the generated `<ident>_inference_batch` entry:
    /// one symbol dispatch and one `call_guard` acquisition for the whole
    /// batch, with the static weight arrays staying cache-warm across
    /// images. Output is bit-identical to `inputs.len()` single [`infer`]
    /// calls — the entry point is a plain loop over the same function body.
    ///
    /// Falls back to per-image calls when the loaded object predates the
    /// batched entry point.
    pub fn infer_batch(&self, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        if inputs.is_empty() {
            return Ok(Vec::new());
        }
        for input in inputs {
            check_input_dims(&self.input_dims, input)?;
        }
        let Some(batch_func) = self.batch_func else {
            return inputs.iter().map(|x| CompiledCnn::infer(self, x)).collect();
        };
        let in_sz: usize = self.input_dims.iter().product();
        let out_sz: usize = self.output_dims.iter().product();
        let n = inputs.len();
        // The C contract wants contiguous input/output planes; pack once,
        // run once, split once.
        let mut packed_in = vec![0.0f32; in_sz * n];
        for (i, input) in inputs.iter().enumerate() {
            packed_in[i * in_sz..(i + 1) * in_sz].copy_from_slice(input.data());
        }
        let mut packed_out = vec![0.0f32; out_sz * n];
        {
            let _guard = self.call_guard.lock().unwrap();
            unsafe {
                (batch_func)(packed_in.as_ptr(), packed_out.as_mut_ptr(), n as std::os::raw::c_int)
            };
        }
        let mut outs = Vec::with_capacity(n);
        for i in 0..n {
            let mut out = Tensor::zeros(&self.output_dims);
            out.data_mut().copy_from_slice(&packed_out[i * out_sz..(i + 1) * out_sz]);
            outs.push(out);
        }
        Ok(outs)
    }
}

impl crate::runtime::InferenceEngine for CompiledCnn {
    fn name(&self) -> &str {
        &self.name
    }

    fn infer(&self, input: &Tensor) -> Result<Tensor> {
        check_input_dims(&self.input_dims, input)?;
        CompiledCnn::infer(self, input)
    }

    fn infer_batch(&self, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        CompiledCnn::infer_batch(self, inputs)
    }
}

fn check_input_dims(dims: &[usize], input: &Tensor) -> Result<()> {
    if input.dims() != dims {
        anyhow::bail!("input shape {:?} != expected {:?}", input.dims(), dims);
    }
    Ok(())
}

/// Convenience used by tests/benches: build and compare against the
/// interpreter on `trials` random inputs, returning the max abs error seen.
pub fn verify_against_interp(model: &Model, opts: &CodegenOptions, work_dir: impl AsRef<Path>, trials: usize, seed: u64) -> Result<f32> {
    let cnn = CompiledCnn::build(model, opts, work_dir)?;
    let mut rng = crate::util::XorShift64::new(seed);
    let mut worst = 0.0f32;
    for _ in 0..trials {
        let x = Tensor::rand(model.input.dims(), -1.0, 1.0, &mut rng);
        let y_ref = crate::interp::run(model, &x)?;
        let y_c = cnn.infer(&x)?;
        worst = worst.max(y_ref.max_abs_diff(&y_c)?);
    }
    Ok(worst)
}

/// int8 counterpart of [`verify_against_interp`]: compile the `--dtype
/// int8` C and compare it against the interpreter's int8 reference path
/// ([`crate::interp::run_quantized`]) over the **same** optimized model
/// and quant plan codegen derives. Models without a trailing softmax
/// must match bit-exactly (0.0); a trailing softmax adds only the float
/// epilogue's libm-level term (< 1e-6), since everything before it is
/// the identical integer arithmetic on both sides.
pub fn verify_int8_against_oracle(model: &Model, opts: &CodegenOptions, work_dir: impl AsRef<Path>, trials: usize, seed: u64) -> Result<f32> {
    let cnn = CompiledCnn::build(model, opts, work_dir)?;
    let opt = crate::passes::optimize(model.clone())?;
    let qp = crate::passes::quantize_model(&opt)?;
    let mut rng = crate::util::XorShift64::new(seed);
    let mut worst = 0.0f32;
    for _ in 0..trials {
        let x = Tensor::rand(model.input.dims(), -1.0, 1.0, &mut rng);
        let y_ref = crate::interp::run_quantized(&opt, &qp, &x)?;
        let y_c = cnn.infer(&x)?;
        worst = worst.max(y_ref.max_abs_diff(&y_c)?);
    }
    Ok(worst)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codegen::{CodegenOptions, Isa, Unroll};
    use crate::graph::zoo;

    fn workdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("nncg-cc-tests-{tag}"));
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    /// THE core correctness test of the whole reproduction: generated C
    /// matches the interpreter bit-for-nearly-bit across the option matrix
    /// on the tiny net (fast) — the full paper models are covered in the
    /// integration suite.
    #[test]
    fn generated_c_matches_interp_across_option_matrix() {
        let m = zoo::tiny_test_net().with_random_weights(1234);
        let dir = workdir("matrix");
        for isa in [Isa::Generic, Isa::Sse3] {
            for unroll in [Unroll::None, Unroll::KeepOuter2, Unroll::KeepOuter1, Unroll::Full] {
                let opts = CodegenOptions { isa, unroll, ..Default::default() };
                let err = verify_against_interp(&m, &opts, &dir, 3, 99).unwrap();
                assert!(err < 1e-5, "isa={isa:?} unroll={unroll:?}: err={err}");
            }
        }
    }

    #[test]
    fn ball_classifier_compiles_and_matches() {
        let m = zoo::ball_classifier().with_random_weights(42);
        let err = verify_against_interp(&m, &CodegenOptions::sse3(), workdir("ball"), 3, 5).unwrap();
        assert!(err < 1e-5, "err={err}");
    }

    #[test]
    fn infer_checks_shape() {
        let m = zoo::tiny_test_net().with_random_weights(7);
        let cnn = CompiledCnn::build(&m, &CodegenOptions::general(), workdir("shape")).unwrap();
        assert!(cnn.infer(&Tensor::zeros(&[4, 4, 1])).is_err());
        assert!(cnn.infer(&Tensor::zeros(&[8, 8, 1])).is_ok());
    }

    /// Batched entry bit-identity (ISSUE 9 acceptance): the emitted
    /// `<ident>_inference_batch` must produce *bit-identical* output to N
    /// single calls — it is a loop over the very same function body, so any
    /// difference means the packing/offset math is wrong. Covered fused and
    /// unfused since fusion rewrites the function body the batch loop calls.
    #[test]
    fn compiled_batch_matches_single_bit_identical() {
        use crate::codegen::FuseMode;
        let m = zoo::tiny_test_net().with_random_weights(31);
        for (tag, fuse) in [("unfused", FuseMode::Off), ("fused", FuseMode::Auto)] {
            let opts = CodegenOptions { fuse, ..CodegenOptions::sse3() };
            let cnn = CompiledCnn::build(&m, &opts, workdir("batch-id")).unwrap();
            assert!(cnn.has_batch_entry(), "{tag}: batch symbol missing from fresh object");
            let mut rng = crate::util::XorShift64::new(77);
            let inputs: Vec<Tensor> =
                (0..5).map(|_| Tensor::rand(m.input.dims(), -1.0, 1.0, &mut rng)).collect();
            let batched = cnn.infer_batch(&inputs).unwrap();
            assert_eq!(batched.len(), inputs.len());
            for (i, x) in inputs.iter().enumerate() {
                let single = cnn.infer(x).unwrap();
                assert_eq!(
                    single.data(),
                    batched[i].data(),
                    "{tag}: image {i} not bit-identical to single call"
                );
            }
        }
        // Empty batch is a no-op, not an error.
        let cnn = CompiledCnn::build(&m, &CodegenOptions::sse3(), workdir("batch-id")).unwrap();
        assert!(cnn.infer_batch(&[]).unwrap().is_empty());
    }

    #[test]
    fn cache_hits_on_second_build() {
        let m = zoo::tiny_test_net().with_random_weights(8);
        let dir = workdir("cachehit");
        let a = CompiledCnn::build(&m, &CodegenOptions::general(), &dir).unwrap();
        let t0 = std::time::Instant::now();
        let b = CompiledCnn::build(&m, &CodegenOptions::general(), &dir).unwrap();
        let cached_time = t0.elapsed();
        assert_eq!(a.so_path, b.so_path);
        // A cache hit must not invoke the compiler (sub-50ms vs ~100ms+).
        assert!(cached_time.as_millis() < 100, "cache hit took {cached_time:?}");
    }
}
