//! C compiler detection and invocation.
//!
//! Mirrors the paper's deployment scenarios (§III-B): native optimized
//! builds for the host, strict-ANSI checks (any "ANSI C compiler" must
//! accept the generic output), 32-bit cross builds (the Nao's Atom Z530)
//! and `-march` retargeting (the Atom J1900's bonnell).

use anyhow::{bail, Context, Result};
use std::path::Path;
use std::process::Command;

/// Compilation target flavor.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CcTarget {
    /// Native shared object, `-O3 -march=native` (the benchmark path).
    NativeShared,
    /// Native standalone executable (generated harness `main()`).
    NativeExe,
    /// Strict ANSI conformance check: `-std=c89 -pedantic -Werror`,
    /// compile-only. Proves "any ANSI C compiler can take the file".
    StrictAnsiCheck,
    /// 32-bit compile (`-m32`), compile-only — the Nao scenario.
    M32Check,
    /// Retarget to a named micro-architecture, compile-only — the J1900
    /// scenario (`-march=bonnell`-style cross builds).
    MarchCheck(&'static str),
}

/// A detected C compiler.
#[derive(Debug, Clone)]
pub struct CcDriver {
    /// Compiler executable (cc/gcc/clang).
    pub cc: String,
}

/// Find a working C compiler on PATH. Prefers `cc`, falls back to gcc/clang.
pub fn detect_compiler() -> Result<String> {
    for cand in ["cc", "gcc", "clang"] {
        if Command::new(cand)
            .arg("--version")
            .output()
            .map(|o| o.status.success())
            .unwrap_or(false)
        {
            return Ok(cand.to_string());
        }
    }
    bail!("no C compiler found on PATH (tried cc, gcc, clang)")
}

impl CcDriver {
    pub fn detect() -> Result<Self> {
        Ok(CcDriver { cc: detect_compiler()? })
    }

    /// Flags for a target flavor.
    pub fn flags(&self, target: CcTarget) -> Vec<String> {
        let s = |v: &[&str]| v.iter().map(|x| x.to_string()).collect::<Vec<_>>();
        match target {
            CcTarget::NativeShared => s(&["-O3", "-march=native", "-shared", "-fPIC", "-lm"]),
            CcTarget::NativeExe => s(&["-O3", "-march=native", "-lm"]),
            CcTarget::StrictAnsiCheck => s(&["-std=c89", "-pedantic", "-Werror", "-fsyntax-only"]),
            CcTarget::M32Check => s(&["-m32", "-O2", "-fsyntax-only"]),
            CcTarget::MarchCheck(arch) => {
                vec!["-O2".into(), format!("-march={arch}"), "-c".into(), "-o".into(), "/dev/null".into()]
            }
        }
    }

    /// Compile `c_path` to `out_path` (ignored for compile-only targets).
    /// Returns the compiler's stderr on failure.
    pub fn compile(&self, c_path: &Path, out_path: Option<&Path>, target: CcTarget) -> Result<()> {
        let mut cmd = Command::new(&self.cc);
        cmd.arg(c_path);
        // Output file comes before -l flags; libs go last for ld ordering.
        let flags = self.flags(target);
        let (libs, opts): (Vec<_>, Vec<_>) = flags.into_iter().partition(|f| f.starts_with("-l"));
        cmd.args(&opts);
        if let Some(out) = out_path {
            cmd.arg("-o").arg(out);
        }
        cmd.args(&libs);
        let out = cmd.output().with_context(|| format!("running {}", self.cc))?;
        if !out.status.success() {
            bail!(
                "{} failed on {} ({:?}):\n{}",
                self.cc,
                c_path.display(),
                target,
                String::from_utf8_lossy(&out.stderr)
            );
        }
        Ok(())
    }

    /// Probe whether a compile-only target is supported by the toolchain
    /// (e.g. `-m32` needs multilib). Returns Ok(true/false) rather than an
    /// error so the deploy matrix can report "toolchain gate".
    pub fn probe(&self, target: CcTarget) -> Result<bool> {
        let dir = std::env::temp_dir().join("nncg-cc-probe");
        std::fs::create_dir_all(&dir)?;
        let probe = dir.join("probe.c");
        std::fs::write(&probe, "int nncg_probe(int x) { return x + 1; }\n")?;
        Ok(self.compile(&probe, None, target).is_ok())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn detects_a_compiler() {
        let cc = detect_compiler().unwrap();
        assert!(!cc.is_empty());
    }

    #[test]
    fn strict_ansi_accepts_ansi_and_rejects_c99() {
        let driver = CcDriver::detect().unwrap();
        let dir = std::env::temp_dir().join("nncg-cc-ansi");
        std::fs::create_dir_all(&dir).unwrap();

        let good = dir.join("good.c");
        std::fs::write(&good, "int f(int x) { int y; y = x + 1; return y; }\n").unwrap();
        assert!(driver.compile(&good, None, CcTarget::StrictAnsiCheck).is_ok());

        let bad = dir.join("bad.c");
        // C99 declaration-after-statement + // comment: must be rejected.
        std::fs::write(&bad, "int f(int x) { x += 1; int y = x; // c99\n return y; }\n").unwrap();
        assert!(driver.compile(&bad, None, CcTarget::StrictAnsiCheck).is_err());
    }

    #[test]
    fn compile_error_includes_stderr() {
        let driver = CcDriver::detect().unwrap();
        let dir = std::env::temp_dir().join("nncg-cc-err");
        std::fs::create_dir_all(&dir).unwrap();
        let bad = dir.join("syntax.c");
        std::fs::write(&bad, "this is not C\n").unwrap();
        let err = driver.compile(&bad, None, CcTarget::StrictAnsiCheck).unwrap_err().to_string();
        assert!(err.contains("error"), "{err}");
    }

    #[test]
    fn probe_reports_bool() {
        let driver = CcDriver::detect().unwrap();
        // Native syntax-only must always work.
        assert!(driver.probe(CcTarget::StrictAnsiCheck).unwrap());
        // m32 may or may not be available; must not error either way.
        let _ = driver.probe(CcTarget::M32Check).unwrap();
    }
}
