//! C compiler detection and invocation.
//!
//! Mirrors the paper's deployment scenarios (§III-B): native optimized
//! builds for the host, strict-ANSI checks (any "ANSI C compiler" must
//! accept the generic output), 32-bit cross builds (the Nao's Atom Z530)
//! and `-march` retargeting (the Atom J1900's bonnell).
//!
//! The invocation path is hardened for unattended serving: wall-clock
//! timeouts (spawn + poll + kill — a hung cross-compiler must not wedge a
//! healing recompile), bounded retry with exponential backoff for
//! transient failures (timeouts, signals, injected faults), and captured
//! stderr on permanent failures. [`CompileStats`] counts attempts /
//! retries / timeouts for the serving metrics snapshot.

use crate::faults::{FaultPlan, FaultSite};
use anyhow::{bail, Result};
use std::path::Path;
use std::process::{Command, Stdio};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Compilation target flavor.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CcTarget {
    /// Native shared object, `-O3 -march=native` (the benchmark path).
    NativeShared,
    /// Native standalone executable (generated harness `main()`).
    NativeExe,
    /// Strict ANSI conformance check: `-std=c89 -pedantic -Werror`,
    /// compile-only. Proves "any ANSI C compiler can take the file".
    StrictAnsiCheck,
    /// 32-bit compile (`-m32`), compile-only — the Nao scenario.
    M32Check,
    /// Retarget to a named micro-architecture, compile-only — the J1900
    /// scenario (`-march=bonnell`-style cross builds).
    MarchCheck(&'static str),
}

/// Wall-clock and retry limits for compiler invocations.
#[derive(Debug, Clone)]
pub struct CompileLimits {
    /// Kill the compiler child after this long.
    pub timeout: Duration,
    /// Extra attempts after the first for *transient* failures (timeout,
    /// killed-by-signal, injected). Permanent diagnostics never retry.
    pub max_retries: u32,
    /// First retry delay; doubles per retry, capped at 2 s.
    pub backoff_base: Duration,
}

impl Default for CompileLimits {
    fn default() -> Self {
        CompileLimits {
            timeout: Duration::from_secs(60),
            max_retries: 2,
            backoff_base: Duration::from_millis(50),
        }
    }
}

impl CompileLimits {
    /// Defaults overridden by `NNCG_CC_TIMEOUT_MS` / `NNCG_CC_RETRIES`.
    pub fn from_env() -> Self {
        let mut limits = CompileLimits::default();
        if let Ok(ms) = std::env::var("NNCG_CC_TIMEOUT_MS") {
            if let Ok(ms) = ms.trim().parse::<u64>() {
                limits.timeout = Duration::from_millis(ms.max(1));
            }
        }
        if let Ok(n) = std::env::var("NNCG_CC_RETRIES") {
            if let Ok(n) = n.trim().parse::<u32>() {
                limits.max_retries = n;
            }
        }
        limits
    }
}

/// Compile-pipeline counters, surfaced in [`crate::coordinator::MetricsSnapshot`].
#[derive(Debug, Default)]
pub struct CompileStats {
    /// Compiler invocations (including retries).
    pub attempts: AtomicU64,
    /// Attempts that were retries of a transient failure.
    pub retries: AtomicU64,
    /// Children killed by the wall-clock timeout.
    pub timeouts: AtomicU64,
    /// Compilations that failed permanently (after retries, or on a
    /// non-retryable diagnostic).
    pub failures: AtomicU64,
}

/// One attempt's failure, classified for the retry loop.
struct AttemptError {
    transient: bool,
    msg: String,
}

impl AttemptError {
    fn transient(msg: String) -> Self {
        AttemptError { transient: true, msg }
    }

    fn permanent(msg: String) -> Self {
        AttemptError { transient: false, msg }
    }
}

/// A detected C compiler plus invocation policy.
#[derive(Debug, Clone)]
pub struct CcDriver {
    /// Compiler executable (cc/gcc/clang or an env override).
    pub cc: String,
    limits: CompileLimits,
    stats: Arc<CompileStats>,
    faults: Option<Arc<FaultPlan>>,
}

fn answers_version(cand: &str) -> bool {
    Command::new(cand)
        .arg("--version")
        .stdin(Stdio::null())
        .output()
        .map(|o| o.status.success())
        .unwrap_or(false)
}

/// Probe candidates in order; first one that answers `--version` wins.
fn probe_candidates(cands: &[String]) -> Result<String> {
    for cand in cands {
        if answers_version(cand) {
            return Ok(cand.clone());
        }
    }
    bail!(
        "no working C compiler found (tried: {}); set NNCG_CC or CC to a working compiler",
        cands.join(", ")
    )
}

/// Compiler detection with explicit override values (pure — the env-free
/// core of [`detect_compiler`], also used by tests to avoid `set_var`
/// races). Overrides are probed before the `cc`/`gcc`/`clang` defaults; a
/// broken override falls through, and the error lists everything tried.
pub fn detect_compiler_from(nncg_cc: Option<&str>, cc_var: Option<&str>) -> Result<String> {
    let mut cands: Vec<String> = Vec::new();
    for over in [nncg_cc, cc_var].into_iter().flatten() {
        let over = over.trim();
        if !over.is_empty() && !cands.iter().any(|c| c == over) {
            cands.push(over.to_string());
        }
    }
    for default in ["cc", "gcc", "clang"] {
        if !cands.iter().any(|c| c == default) {
            cands.push(default.to_string());
        }
    }
    probe_candidates(&cands)
}

/// Find a working C compiler: `NNCG_CC`, then `CC`, then PATH probing of
/// `cc`/`gcc`/`clang`.
pub fn detect_compiler() -> Result<String> {
    let nncg_cc = std::env::var("NNCG_CC").ok();
    let cc_var = std::env::var("CC").ok();
    detect_compiler_from(nncg_cc.as_deref(), cc_var.as_deref())
}

impl CcDriver {
    pub fn detect() -> Result<Self> {
        Ok(CcDriver {
            cc: detect_compiler()?,
            limits: CompileLimits::from_env(),
            stats: Arc::new(CompileStats::default()),
            faults: None,
        })
    }

    /// Replace the invocation limits.
    pub fn with_limits(mut self, limits: CompileLimits) -> Self {
        self.limits = limits;
        self
    }

    /// Attach a fault-injection plan (chaos testing).
    pub fn with_faults(mut self, plan: Arc<FaultPlan>) -> Self {
        self.faults = Some(plan);
        self
    }

    pub fn limits(&self) -> &CompileLimits {
        &self.limits
    }

    pub fn stats(&self) -> &Arc<CompileStats> {
        &self.stats
    }

    pub fn faults(&self) -> Option<&Arc<FaultPlan>> {
        self.faults.as_ref()
    }

    /// Flags for a target flavor.
    pub fn flags(&self, target: CcTarget) -> Vec<String> {
        let s = |v: &[&str]| v.iter().map(|x| x.to_string()).collect::<Vec<_>>();
        match target {
            CcTarget::NativeShared => s(&["-O3", "-march=native", "-shared", "-fPIC", "-lm"]),
            CcTarget::NativeExe => s(&["-O3", "-march=native", "-lm"]),
            CcTarget::StrictAnsiCheck => s(&["-std=c89", "-pedantic", "-Werror", "-fsyntax-only"]),
            CcTarget::M32Check => s(&["-m32", "-O2", "-fsyntax-only"]),
            CcTarget::MarchCheck(arch) => {
                vec!["-O2".into(), format!("-march={arch}"), "-c".into(), "-o".into(), "/dev/null".into()]
            }
        }
    }

    /// Compile `c_path` to `out_path` (ignored for compile-only targets),
    /// with wall-clock timeout and bounded retry for transient failures.
    /// Permanent failures carry the compiler's stderr.
    pub fn compile(&self, c_path: &Path, out_path: Option<&Path>, target: CcTarget) -> Result<()> {
        let mut backoff = self.limits.backoff_base;
        let mut last: Option<String> = None;
        for attempt in 0..=self.limits.max_retries {
            if attempt > 0 {
                CompileStats::bump(&self.stats.retries);
                std::thread::sleep(backoff);
                backoff = (backoff * 2).min(Duration::from_secs(2));
            }
            CompileStats::bump(&self.stats.attempts);
            match self.compile_once(c_path, out_path, target) {
                Ok(()) => return Ok(()),
                Err(e) if e.transient => last = Some(e.msg),
                Err(e) => {
                    CompileStats::bump(&self.stats.failures);
                    bail!(e.msg);
                }
            }
        }
        CompileStats::bump(&self.stats.failures);
        bail!(
            "{} failed after {} attempts (last: {})",
            self.cc,
            self.limits.max_retries + 1,
            last.unwrap_or_else(|| "unknown".into())
        )
    }

    /// One spawn + poll + kill cycle.
    fn compile_once(
        &self,
        c_path: &Path,
        out_path: Option<&Path>,
        target: CcTarget,
    ) -> std::result::Result<(), AttemptError> {
        if let Some(plan) = &self.faults {
            if plan.should_fire(FaultSite::CompileFail) {
                return Err(AttemptError::transient(format!(
                    "injected compile failure ({} on {})",
                    self.cc,
                    c_path.display()
                )));
            }
        }
        // An injected hang swaps the compiler for a `sleep` child, so the
        // real spawn/poll/kill machinery is what the chaos suite exercises.
        let hang = self.faults.as_ref().and_then(|p| p.maybe_delay(FaultSite::CompileSlow));
        let mut cmd = match hang {
            Some(d) => {
                let mut c = Command::new("sleep");
                c.arg(format!("{}", d.as_secs_f64()));
                c
            }
            None => {
                let mut c = Command::new(&self.cc);
                c.arg(c_path);
                // Output file comes before -l flags; libs go last for ld
                // ordering.
                let flags = self.flags(target);
                let (libs, opts): (Vec<_>, Vec<_>) =
                    flags.into_iter().partition(|f| f.starts_with("-l"));
                c.args(&opts);
                if let Some(out) = out_path {
                    c.arg("-o").arg(out);
                }
                c.args(&libs);
                c
            }
        };
        cmd.stdin(Stdio::null()).stdout(Stdio::null()).stderr(Stdio::piped());
        let mut child = cmd
            .spawn()
            .map_err(|e| AttemptError::permanent(format!("spawning {}: {e}", self.cc)))?;
        // Drain stderr on a separate thread so a chatty compiler can't
        // deadlock against a full pipe while we poll.
        let stderr_pipe = child.stderr.take();
        let stderr_reader = std::thread::spawn(move || {
            let mut buf = String::new();
            if let Some(mut pipe) = stderr_pipe {
                use std::io::Read;
                let _ = pipe.read_to_string(&mut buf);
            }
            buf
        });

        let started = Instant::now();
        loop {
            match child.try_wait() {
                Ok(Some(status)) => {
                    let stderr = stderr_reader.join().unwrap_or_default();
                    return if status.success() {
                        Ok(())
                    } else if status.code().is_none() {
                        // Killed by a signal (OOM killer, etc.): transient.
                        Err(AttemptError::transient(format!(
                            "{} killed by signal on {}",
                            self.cc,
                            c_path.display()
                        )))
                    } else {
                        Err(AttemptError::permanent(format!(
                            "{} failed on {} ({:?}):\n{}",
                            self.cc,
                            c_path.display(),
                            target,
                            stderr
                        )))
                    };
                }
                Ok(None) => {
                    if started.elapsed() >= self.limits.timeout {
                        CompileStats::bump(&self.stats.timeouts);
                        let _ = child.kill();
                        let _ = child.wait();
                        let _ = stderr_reader.join();
                        return Err(AttemptError::transient(format!(
                            "{} timed out after {:?} on {}",
                            self.cc,
                            self.limits.timeout,
                            c_path.display()
                        )));
                    }
                    std::thread::sleep(Duration::from_millis(5));
                }
                Err(e) => {
                    let _ = child.kill();
                    let _ = child.wait();
                    let _ = stderr_reader.join();
                    return Err(AttemptError::permanent(format!(
                        "waiting for {}: {e}",
                        self.cc
                    )));
                }
            }
        }
    }

    /// Probe whether a compile-only target is supported by the toolchain
    /// (e.g. `-m32` needs multilib). Returns Ok(true/false) rather than an
    /// error so the deploy matrix can report "toolchain gate".
    pub fn probe(&self, target: CcTarget) -> Result<bool> {
        let dir = std::env::temp_dir().join("nncg-cc-probe");
        std::fs::create_dir_all(&dir)?;
        let probe = dir.join("probe.c");
        std::fs::write(&probe, "int nncg_probe(int x) { return x + 1; }\n")?;
        Ok(self.compile(&probe, None, target).is_ok())
    }
}

impl CompileStats {
    pub fn bump(field: &AtomicU64) {
        field.fetch_add(1, Ordering::Relaxed);
    }

    pub fn get(field: &AtomicU64) -> u64 {
        field.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::faults::FaultSpec;

    fn workdir(tag: &str) -> std::path::PathBuf {
        let d = std::env::temp_dir().join(format!("nncg-cc-driver-{tag}"));
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn detects_a_compiler() {
        let cc = detect_compiler().unwrap();
        assert!(!cc.is_empty());
    }

    #[test]
    fn env_override_is_probed_first() {
        // A working explicit override wins even over the `cc` default.
        let detected = detect_compiler_from(None, None).unwrap();
        let chosen = detect_compiler_from(Some(&detected), None).unwrap();
        assert_eq!(chosen, detected);
        // A broken override falls through to the defaults.
        let fallback = detect_compiler_from(Some("/nonexistent/bin/fakecc"), None).unwrap();
        assert_eq!(fallback, detected);
        // NNCG_CC takes precedence over CC.
        let nncg_first =
            detect_compiler_from(Some(&detected), Some("/nonexistent/bin/other")).unwrap();
        assert_eq!(nncg_first, detected);
    }

    #[test]
    fn detection_error_lists_candidates_tried() {
        let err = probe_candidates(&["no-such-cc-1".into(), "no-such-cc-2".into()])
            .unwrap_err()
            .to_string();
        assert!(err.contains("no-such-cc-1") && err.contains("no-such-cc-2"), "{err}");
        assert!(err.contains("NNCG_CC"), "error should be actionable: {err}");
    }

    #[test]
    fn strict_ansi_accepts_ansi_and_rejects_c99() {
        let driver = CcDriver::detect().unwrap();
        let dir = std::env::temp_dir().join("nncg-cc-ansi");
        std::fs::create_dir_all(&dir).unwrap();

        let good = dir.join("good.c");
        std::fs::write(&good, "int f(int x) { int y; y = x + 1; return y; }\n").unwrap();
        assert!(driver.compile(&good, None, CcTarget::StrictAnsiCheck).is_ok());

        let bad = dir.join("bad.c");
        // C99 declaration-after-statement + // comment: must be rejected.
        std::fs::write(&bad, "int f(int x) { x += 1; int y = x; // c99\n return y; }\n").unwrap();
        assert!(driver.compile(&bad, None, CcTarget::StrictAnsiCheck).is_err());
    }

    #[test]
    fn compile_error_includes_stderr() {
        let driver = CcDriver::detect().unwrap();
        let dir = workdir("err");
        let bad = dir.join("syntax.c");
        std::fs::write(&bad, "this is not C\n").unwrap();
        let err = driver.compile(&bad, None, CcTarget::StrictAnsiCheck).unwrap_err().to_string();
        assert!(err.contains("error"), "{err}");
    }

    #[test]
    fn permanent_diagnostics_do_not_retry() {
        let driver = CcDriver::detect().unwrap();
        let dir = workdir("noretry");
        let bad = dir.join("bad.c");
        std::fs::write(&bad, "int broken(\n").unwrap();
        assert!(driver.compile(&bad, None, CcTarget::StrictAnsiCheck).is_err());
        assert_eq!(CompileStats::get(&driver.stats().attempts), 1, "syntax errors never retry");
        assert_eq!(CompileStats::get(&driver.stats().retries), 0);
        assert_eq!(CompileStats::get(&driver.stats().failures), 1);
    }

    #[test]
    fn injected_transient_failure_is_retried_to_success() {
        let plan = FaultPlan::builder(21).site(FaultSite::CompileFail, FaultSpec::First(1)).build();
        let driver = CcDriver::detect().unwrap().with_faults(plan);
        let dir = workdir("retry");
        let good = dir.join("ok.c");
        std::fs::write(&good, "int ok(int x) { return x; }\n").unwrap();
        driver.compile(&good, None, CcTarget::StrictAnsiCheck).unwrap();
        assert_eq!(CompileStats::get(&driver.stats().attempts), 2);
        assert_eq!(CompileStats::get(&driver.stats().retries), 1);
        assert_eq!(CompileStats::get(&driver.stats().failures), 0);
    }

    #[test]
    fn hung_compiler_is_killed_and_retried() {
        let plan = FaultPlan::builder(22)
            .site(FaultSite::CompileSlow, FaultSpec::First(1))
            .delay(Duration::from_secs(30))
            .build();
        let driver = CcDriver::detect().unwrap().with_faults(plan).with_limits(CompileLimits {
            timeout: Duration::from_millis(100),
            max_retries: 1,
            backoff_base: Duration::from_millis(1),
        });
        let dir = workdir("hang");
        let good = dir.join("ok.c");
        std::fs::write(&good, "int ok(int x) { return x; }\n").unwrap();
        let t0 = Instant::now();
        driver.compile(&good, None, CcTarget::StrictAnsiCheck).unwrap();
        assert!(t0.elapsed() < Duration::from_secs(5), "hung child must be killed, not waited");
        assert_eq!(CompileStats::get(&driver.stats().timeouts), 1);
        assert_eq!(CompileStats::get(&driver.stats().attempts), 2);
    }

    #[test]
    fn retries_exhaust_into_failure() {
        let plan = FaultPlan::builder(23).site(FaultSite::CompileFail, FaultSpec::Every(1)).build();
        let driver = CcDriver::detect().unwrap().with_faults(plan).with_limits(CompileLimits {
            timeout: Duration::from_secs(5),
            max_retries: 2,
            backoff_base: Duration::from_millis(1),
        });
        let dir = workdir("exhaust");
        let good = dir.join("ok.c");
        std::fs::write(&good, "int ok(int x) { return x; }\n").unwrap();
        let err = driver.compile(&good, None, CcTarget::StrictAnsiCheck).unwrap_err().to_string();
        assert!(err.contains("after 3 attempts"), "{err}");
        assert_eq!(CompileStats::get(&driver.stats().attempts), 3);
        assert_eq!(CompileStats::get(&driver.stats().failures), 1);
    }

    #[test]
    fn probe_reports_bool() {
        let driver = CcDriver::detect().unwrap();
        // Native syntax-only must always work.
        assert!(driver.probe(CcTarget::StrictAnsiCheck).unwrap());
        // m32 may or may not be available; must not error either way.
        let _ = driver.probe(CcTarget::M32Check).unwrap();
    }
}
