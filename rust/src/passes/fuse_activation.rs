//! Fuse standalone activation layers into the preceding Conv2D/Dense.
//!
//! The paper's generated C applies (leaky) ReLU directly on the accumulator
//! of the convolution that produced the value — one pass over memory instead
//! of two. Softmax also fuses (it runs once on the final 1×1×C map).
//! Activations that cannot fuse (e.g. ReLU after max-pool) are kept
//! standalone; the C emitter handles both forms.

use crate::graph::{Activation, Layer, Model};

/// Fuse activation layers into a directly preceding conv/dense that has no
/// activation yet. Anything else stays in place.
pub fn fuse_activations(model: &mut Model) {
    let mut out: Vec<Layer> = Vec::with_capacity(model.layers.len());
    for layer in model.layers.drain(..) {
        if let Layer::Activation(act) = layer {
            match out.last_mut() {
                Some(Layer::Conv2D { activation, .. })
                | Some(Layer::DepthwiseConv2D { activation, .. })
                | Some(Layer::Dense { activation, .. })
                    if *activation == Activation::None =>
                {
                    *activation = act;
                    continue;
                }
                _ => {}
            }
            out.push(Layer::Activation(act));
        } else {
            out.push(layer);
        }
    }
    model.layers = out;
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{zoo, Padding};
    use crate::interp;
    use crate::tensor::Tensor;
    use crate::util::XorShift64;

    #[test]
    fn fuses_relu_into_conv() {
        let mut m = zoo::ball_classifier().with_random_weights(2);
        let before = m.layers.len();
        fuse_activations(&mut m);
        assert!(m.layers.len() < before);
        match &m.layers[0] {
            Layer::Conv2D { activation, .. } => assert_eq!(*activation, Activation::Relu),
            other => panic!("expected conv, got {}", other.kind_name()),
        }
    }

    #[test]
    fn activation_after_pool_stays_standalone() {
        let mut m = Model::new("ap", &[4, 4, 2])
            .push(Layer::maxpool(2, 2))
            .push(Layer::relu());
        fuse_activations(&mut m);
        assert_eq!(m.layers.len(), 2);
        assert!(matches!(m.layers[1], Layer::Activation(Activation::Relu)));
    }

    #[test]
    fn does_not_overwrite_existing_fused_activation() {
        let mut m = Model::new("double", &[4, 4, 1])
            .push(Layer::conv2d(2, 1, 1, (1, 1), Padding::Valid, Activation::Relu))
            .push(Layer::softmax())
            .with_random_weights(4);
        fuse_activations(&mut m);
        // softmax cannot fuse into a conv that already has ReLU
        assert_eq!(m.layers.len(), 2);
    }

    #[test]
    fn fusion_preserves_semantics() {
        let m = zoo::pedestrian_classifier().with_random_weights(42);
        let mut fused = m.clone();
        fuse_activations(&mut fused);
        let mut rng = XorShift64::new(9);
        let x = Tensor::rand(m.input.dims(), 0.0, 1.0, &mut rng);
        let y0 = interp::run(&m, &x).unwrap();
        let y1 = interp::run(&fused, &x).unwrap();
        assert!(y0.max_abs_diff(&y1).unwrap() < 1e-5);
    }
}
