//! Fusion-group planning for the row-streaming emitter.
//!
//! Cross-layer row streaming (Boda-RTC's cross-layer tiling, arXiv
//! 1606.00094; "Deploying DNNs in the Embedded Space", arXiv 1806.08616)
//! keeps intermediates cache-resident: instead of each layer writing a
//! whole output plane before the next layer starts, a *fusion group* of
//! consecutive layers streams rows through ring line buffers of a few rows
//! each. This module decides **which layers may share a group** from layer
//! kinds alone; the codegen planner (`codegen::fusion_groups`) refines the
//! chains with shape- and cost-aware splits (depth cap, statement budget),
//! and `codegen/schedule.rs` derives the per-edge row schedule and ring
//! sizes.

use crate::graph::{Activation, Layer, Model};

/// A contiguous run of layers `[start, end)` emitted as one unit.
/// `len() == 1` means plain (unfused) emission.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FusionGroup {
    /// First layer index (inclusive).
    pub start: usize,
    /// One past the last layer index.
    pub end: usize,
}

impl FusionGroup {
    pub fn singleton(i: usize) -> FusionGroup {
        FusionGroup { start: i, end: i + 1 }
    }

    pub fn len(&self) -> usize {
        self.end - self.start
    }

    pub fn is_empty(&self) -> bool {
        self.end == self.start
    }
}

/// True when a layer can be a member of a row-streaming fusion group:
/// its output rows depend on a bounded, monotonically advancing window of
/// input rows. Softmax breaks groups (it normalizes over the whole output
/// map), as do Flatten/Dense (row structure disappears) and the layers the
/// pass pipeline removes before codegen (BatchNorm, Dropout).
pub fn fusable(layer: &Layer) -> bool {
    match layer {
        Layer::Conv2D { activation, .. } | Layer::DepthwiseConv2D { activation, .. } => {
            *activation != Activation::Softmax
        }
        Layer::MaxPool2D { .. } | Layer::AvgPool2D { .. } => true,
        Layer::Activation(a) => {
            matches!(a, Activation::None | Activation::Relu | Activation::LeakyRelu(_))
        }
        _ => false,
    }
}

/// Partition the layer list into maximal chains of fusable layers, each
/// chunked to at most `max_depth` members; non-fusable layers become
/// singleton groups. The result is a complete, ordered partition of
/// `0..model.layers.len()`.
pub fn plan_fusion_groups(model: &Model, max_depth: usize) -> Vec<FusionGroup> {
    let depth = max_depth.max(1);
    let n = model.layers.len();
    let mut groups = Vec::new();
    let mut i = 0;
    while i < n {
        if !fusable(&model.layers[i]) {
            groups.push(FusionGroup::singleton(i));
            i += 1;
            continue;
        }
        let mut j = i;
        while j < n && j - i < depth && fusable(&model.layers[j]) {
            j += 1;
        }
        groups.push(FusionGroup { start: i, end: j });
        i = j;
    }
    groups
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::zoo;
    use crate::passes::optimize;

    fn covers(groups: &[FusionGroup], n: usize) {
        let mut at = 0;
        for g in groups {
            assert_eq!(g.start, at, "groups must partition the layer list in order");
            assert!(g.len() >= 1);
            at = g.end;
        }
        assert_eq!(at, n);
    }

    #[test]
    fn ball_chain_groups_convs_and_pool_but_not_softmax() {
        // Post-optimize ball: conv8(+relu), maxpool, conv12(+relu),
        // conv2(+softmax) — the first three chain, the softmax-carrying
        // head conv stays alone (softmax normalizes over the whole map).
        let m = optimize(zoo::ball_classifier().with_random_weights(1)).unwrap();
        assert_eq!(m.layers.len(), 4);
        let groups = plan_fusion_groups(&m, 8);
        covers(&groups, m.layers.len());
        assert_eq!(groups[0], FusionGroup { start: 0, end: 3 });
        assert_eq!(groups[1], FusionGroup::singleton(3));
        assert!(!fusable(&m.layers[3]), "softmax head must not fuse");
    }

    #[test]
    fn depth_cap_chunks_long_chains() {
        let m = optimize(zoo::robot_detector().with_random_weights(2)).unwrap();
        // Robot post-optimize is a pure conv/pool chain (7 layers).
        let all = plan_fusion_groups(&m, 8);
        assert_eq!(all.len(), 1);
        assert_eq!(all[0].len(), m.layers.len());
        let capped = plan_fusion_groups(&m, 3);
        covers(&capped, m.layers.len());
        assert!(capped.iter().all(|g| g.len() <= 3));
        assert!(capped.iter().any(|g| g.len() == 3));
    }

    #[test]
    fn breakers_become_singletons() {
        use crate::graph::{Activation, Layer, Model, Padding};
        let m = Model::new("mix", &[8, 8, 2])
            .push(Layer::conv2d(4, 3, 3, (1, 1), Padding::Same, Activation::Relu))
            .push(Layer::maxpool(2, 2))
            .push(Layer::Flatten)
            .push(Layer::dense(4, Activation::None))
            .push(Layer::softmax())
            .with_random_weights(3);
        let groups = plan_fusion_groups(&m, 8);
        covers(&groups, m.layers.len());
        assert_eq!(groups[0], FusionGroup { start: 0, end: 2 });
        assert!(groups[1..].iter().all(|g| g.len() == 1));
        assert!(!fusable(&Layer::Flatten));
        assert!(!fusable(&m.layers[3]));
    }

    #[test]
    fn depth_one_means_all_singletons() {
        let m = optimize(zoo::pedestrian_classifier().with_random_weights(4)).unwrap();
        let groups = plan_fusion_groups(&m, 1);
        covers(&groups, m.layers.len());
        assert!(groups.iter().all(|g| g.len() == 1));
    }
}
