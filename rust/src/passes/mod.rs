//! Graph optimization passes run before code generation.
//!
//! The paper folds BatchNorm into the preceding convolution (§II-B.4, the
//! `bn(conv(x))` derivation) and fuses activations into the conv loop so the
//! generated C applies them on the accumulator. Dropout is an inference
//! no-op. The pass pipeline here reproduces that, with a validation pass
//! asserting semantic equivalence on random inputs (used by tests).

mod fold_bn;
mod fuse_activation;
mod fuse_groups;
mod quantize;

pub use fold_bn::fold_batchnorm;
pub use fuse_activation::fuse_activations;
pub use fuse_groups::{fusable, plan_fusion_groups, FusionGroup};
pub use quantize::{
    avg_mult, leaky_mult, qavg, qleaky, quantize_input, quantize_model, requant, LayerQuant,
    QuantArith, QuantPlan, ACT_SHIFT,
};

use crate::graph::{Layer, Model};
use anyhow::Result;

/// Remove inference no-ops (Dropout).
pub fn elide_dropout(model: &mut Model) {
    model.layers.retain(|l| !matches!(l, Layer::Dropout { .. }));
}

/// The standard NNCG pipeline: BN fold → dropout elision → activation
/// fusion. Returns the optimized model (input is consumed).
pub fn optimize(mut model: Model) -> Result<Model> {
    model.resolve_placeholders()?;
    model.validate()?;
    fold_batchnorm(&mut model)?;
    elide_dropout(&mut model);
    fuse_activations(&mut model);
    model.validate()?;
    Ok(model)
}

/// Count layers of each coarse kind — used by tests and the CLI `describe`.
pub fn layer_histogram(model: &Model) -> Vec<(&'static str, usize)> {
    let mut hist: Vec<(&'static str, usize)> = Vec::new();
    for l in &model.layers {
        let name = l.kind_name();
        if let Some(e) = hist.iter_mut().find(|(n, _)| *n == name) {
            e.1 += 1;
        } else {
            hist.push((name, 1));
        }
    }
    hist
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::zoo;
    use crate::interp;
    use crate::tensor::Tensor;
    use crate::util::XorShift64;

    /// The central invariant: optimization must not change the function.
    #[test]
    fn optimize_preserves_semantics_on_all_paper_models() {
        let mut rng = XorShift64::new(21);
        for name in zoo::PAPER_MODELS {
            let m = zoo::by_name(name).unwrap().with_random_weights(31);
            let opt = optimize(m.clone()).unwrap();
            for trial in 0..3 {
                let x = Tensor::rand(m.input.dims(), -1.0, 1.0, &mut rng);
                let y0 = interp::run(&m, &x).unwrap();
                let y1 = interp::run(&opt, &x).unwrap();
                let err = y0.max_abs_diff(&y1).unwrap();
                assert!(err < 1e-4, "{name} trial {trial}: err={err}");
            }
        }
    }

    #[test]
    fn optimize_removes_bn_dropout_and_standalone_activations() {
        let m = zoo::robot_detector().with_random_weights(5);
        let opt = optimize(m).unwrap();
        assert!(!opt.layers.iter().any(|l| matches!(l, Layer::BatchNorm { .. })));
        assert!(!opt.layers.iter().any(|l| matches!(l, Layer::Dropout { .. })));
        // all leaky-relus fused into convs
        assert!(!opt.layers.iter().any(|l| matches!(l, Layer::Activation(crate::graph::Activation::LeakyRelu(_)))));
    }

    #[test]
    fn histogram_counts() {
        let m = zoo::ball_classifier();
        let h = layer_histogram(&m);
        assert!(h.iter().any(|&(n, c)| n == "Conv" && c == 3));
        assert!(h.iter().any(|&(n, c)| n == "ReLU" && c == 2));
    }
}
