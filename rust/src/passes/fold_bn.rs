//! BatchNorm folding (paper §II-B.4).
//!
//! For `bn(conv(x))` with per-channel scale `s_k = gamma_k / sqrt(var_k+eps)`
//! and shift `t_k = beta_k - mean_k * s_k`:
//!
//! ```text
//! bn(conv(x))_k = s_k * (sum_i x_i w_ik + b_k) + t_k
//!               = sum_i x_i (s_k w_ik) + (s_k b_k + t_k)
//! ```
//!
//! i.e. scale every weight of output channel `k` by `s_k` and replace the
//! bias. This removes the BatchNorm layer entirely from the generated code —
//! the strongest form of the paper's "constants" principle.

use crate::graph::{Layer, Model};
use anyhow::{bail, Result};

/// Fold every BatchNorm that directly follows a Conv2D into that conv.
/// BatchNorm in any other position (e.g. model starts with one) is an error:
/// the paper's nets never do this, and the C emitter does not implement a
/// standalone BN (by design — it should always be folded).
pub fn fold_batchnorm(model: &mut Model) -> Result<()> {
    let mut out: Vec<Layer> = Vec::with_capacity(model.layers.len());
    for layer in model.layers.drain(..) {
        match layer {
            Layer::BatchNorm { gamma, beta, mean, variance, epsilon } => {
                let prev = out.last_mut();
                match prev {
                    Some(Layer::Conv2D { weights, bias, .. }) => {
                        let c_out = weights.dims()[3];
                        if gamma.numel() != c_out {
                            bail!("BN channels {} != conv c_out {}", gamma.numel(), c_out);
                        }
                        let scale: Vec<f32> = (0..c_out)
                            .map(|k| gamma.data()[k] / (variance.data()[k] + epsilon).sqrt())
                            .collect();
                        // w[n,m,o,k] *= s_k  — k is innermost in HWIO layout.
                        for (idx, w) in weights.data_mut().iter_mut().enumerate() {
                            *w *= scale[idx % c_out];
                        }
                        for k in 0..c_out {
                            let b = bias.data()[k];
                            bias.data_mut()[k] = scale[k] * b + (beta.data()[k] - mean.data()[k] * scale[k]);
                        }
                    }
                    Some(Layer::DepthwiseConv2D { weights, bias, .. }) => {
                        // depthwise weights [hk, wk, c]: c is minor, same
                        // scale-per-output-channel folding as dense conv.
                        let c = weights.dims()[2];
                        if gamma.numel() != c {
                            bail!("BN channels {} != depthwise c {}", gamma.numel(), c);
                        }
                        let scale: Vec<f32> = (0..c)
                            .map(|k| gamma.data()[k] / (variance.data()[k] + epsilon).sqrt())
                            .collect();
                        for (idx, w) in weights.data_mut().iter_mut().enumerate() {
                            *w *= scale[idx % c];
                        }
                        for k in 0..c {
                            let b = bias.data()[k];
                            bias.data_mut()[k] = scale[k] * b + (beta.data()[k] - mean.data()[k] * scale[k]);
                        }
                    }
                    _ => bail!("BatchNorm not preceded by a convolution — cannot fold"),
                }
            }
            other => out.push(other),
        }
    }
    model.layers = out;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{Activation, Padding};
    use crate::interp;
    use crate::tensor::Tensor;
    use crate::util::XorShift64;

    fn conv_bn_model() -> Model {
        Model::new("cb", &[6, 6, 2])
            .push(Layer::conv2d(4, 3, 3, (1, 1), Padding::Same, Activation::None))
            .push(Layer::batchnorm(4))
            .with_random_weights(77)
    }

    #[test]
    fn fold_matches_unfolded_numerics() {
        let m = conv_bn_model();
        let mut folded = m.clone();
        fold_batchnorm(&mut folded).unwrap();
        assert_eq!(folded.layers.len(), 1);

        let mut rng = XorShift64::new(3);
        for _ in 0..5 {
            let x = Tensor::rand(&[6, 6, 2], -2.0, 2.0, &mut rng);
            let y0 = interp::run(&m, &x).unwrap();
            let y1 = interp::run(&folded, &x).unwrap();
            assert!(y0.max_abs_diff(&y1).unwrap() < 1e-4);
        }
    }

    #[test]
    fn orphan_bn_is_an_error() {
        let mut m = Model::new("orphan", &[4, 4, 3]).push(Layer::batchnorm(3));
        assert!(fold_batchnorm(&mut m).is_err());
    }

    #[test]
    fn bn_after_pool_is_an_error() {
        let mut m = Model::new("bp", &[4, 4, 3])
            .push(Layer::maxpool(2, 2))
            .push(Layer::batchnorm(3));
        assert!(fold_batchnorm(&mut m).is_err());
    }

    #[test]
    fn channel_mismatch_is_an_error() {
        let mut m = Model::new("cm", &[6, 6, 2])
            .push(Layer::conv2d(4, 3, 3, (1, 1), Padding::Same, Activation::None))
            .push(Layer::batchnorm(5))
            .with_random_weights(7);
        assert!(fold_batchnorm(&mut m).is_err());
    }
}
