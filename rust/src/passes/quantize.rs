//! Post-training symmetric quantization (`--dtype int8`).
//!
//! [`quantize_model`] runs a deterministic calibration batch through the
//! f32 interpreter ops and derives a [`QuantPlan`]: one symmetric scale
//! per activation tensor (recorded **pre-activation** for MAC layers, so
//! leaky-negative ranges and pre-softmax logits are fully covered) and
//! per-channel symmetric scales for conv/depthwise weights (per-tensor
//! for dense). MAC layers additionally carry everything the integer
//! emitters need: quantized weights/bias and the int32 → int8
//! **multiply-shift requantization** parameters
//!
//! ```text
//! t = (acc + 2^(pre-1)) >> pre            (pre == 0: t = acc)
//! q = clamp((t * m[k] + 2^(post-1)) >> post, -127, 127)
//! ```
//!
//! chosen so `t` fits 16 bits and `t * m` fits int32 — proven against the
//! layer's worst-case accumulator (`127 * Σ|qw| + |qb|`), with a hard
//! error when even the pre-shift cannot make int32 accumulation safe, and
//! another when a channel's multiplier rounds to 0 (a per-channel scale
//! spread beyond ~2^16 would silently zero that channel's outputs).
//!
//! The same formula helpers ([`requant`], [`leaky_mult`], [`avg_mult`],
//! [`quantize_input`]) are used by the interpreter's int8 reference path
//! and by the C emitter, which is what makes the generated code
//! bit-exact against the oracle: both sides compute the identical
//! saturation-free integer arithmetic. (Arithmetic right shift of
//! negative values is implementation-defined in C89 but universal on
//! gcc/clang/MSVC targets; Rust's `>>` on `i32` matches it.)

use crate::graph::{Activation, Layer, Model};
use crate::interp;
use crate::tensor::Tensor;
use crate::util::XorShift64;
use anyhow::{bail, Result};

/// Calibration batch size (seeded, deterministic).
const CALIB_SAMPLES: usize = 8;

/// Guard floor for activation scales (all-zero calibration planes).
const SCALE_FLOOR: f32 = 1e-6;

/// Everything the integer emitters need for one MAC layer.
#[derive(Debug, Clone)]
pub struct QuantArith {
    /// Per-output-channel weight scales (dense: one scale replicated).
    pub w_scales: Vec<f32>,
    /// Quantized weights, original layout (HWIO / `[h,w,c]` / `[in,out]`).
    pub qw: Vec<i8>,
    /// Quantized bias in accumulator domain (`b / (s_in * s_w[k])`).
    pub qb: Vec<i32>,
    /// Per-channel requantization multipliers (`<= 32767`).
    pub m: Vec<i32>,
    /// Accumulator pre-shift (0 when the accumulator already fits 16 bits).
    pub pre: u32,
    /// Multiplier post-shift (`1..=30`).
    pub post: u32,
}

/// Per-layer quantization record, index-aligned with `model.layers`.
#[derive(Debug, Clone)]
pub enum LayerQuant {
    /// Conv2D / DepthwiseConv2D / Dense: quantized weights + requant.
    Mac { arith: QuantArith, out_scale: f32 },
    /// Pool / activation / flatten: int8 in, int8 out, scale unchanged.
    Passthrough { out_scale: f32 },
}

impl LayerQuant {
    /// Scale of this layer's int8 output plane.
    pub fn out_scale(&self) -> f32 {
        match self {
            LayerQuant::Mac { out_scale, .. } | LayerQuant::Passthrough { out_scale } => *out_scale,
        }
    }
}

/// The quantization plan carried alongside the fusion plan bundle.
#[derive(Debug, Clone)]
pub struct QuantPlan {
    /// Scale of the quantized input plane (`x_in[i] ≈ q[i] * input_scale`).
    pub input_scale: f32,
    /// True when the model ends in softmax: the integer chain treats it
    /// as `None` and a float softmax runs over `x_out` after dequantize.
    pub trailing_softmax: bool,
    /// One record per (optimized) model layer.
    pub layers: Vec<LayerQuant>,
}

/// Fixed-point shift for [`leaky_mult`] / [`avg_mult`] (Q15).
pub const ACT_SHIFT: u32 = 15;

/// Q15 multiplier for a leaky-ReLU slope (`alpha < 1` keeps results in
/// range without an extra clamp).
pub fn leaky_mult(alpha: f32) -> i32 {
    (alpha as f64 * (1i64 << ACT_SHIFT) as f64).round() as i32
}

/// Q15 multiplier for an average-pool window of `area` cells.
pub fn avg_mult(area: usize) -> i32 {
    ((1i64 << ACT_SHIFT) as f64 / area as f64).round() as i32
}

/// int32 → int8 multiply-shift requantization — the single definition
/// both the interpreter oracle and the emitted C formula follow.
pub fn requant(acc: i32, m: i32, pre: u32, post: u32) -> i8 {
    let t = if pre == 0 { acc } else { (acc + (1 << (pre - 1))) >> pre };
    let q = (t * m + (1 << (post - 1))) >> post;
    q.clamp(-127, 127) as i8
}

/// Quantized leaky ReLU on an int8 value (mirrors the emitted ternary).
pub fn qleaky(q: i32, mult: i32) -> i8 {
    if q > 0 {
        q as i8
    } else {
        ((q * mult + (1 << (ACT_SHIFT - 1))) >> ACT_SHIFT) as i8
    }
}

/// Quantized average of an int32 window sum (mirrors the emitted C).
pub fn qavg(sum: i32, mult: i32) -> i8 {
    let v = (sum * mult + (1 << (ACT_SHIFT - 1))) >> ACT_SHIFT;
    v.clamp(-127, 127) as i8
}

/// Entry quantization of one float input value: clamp-then-round-half-
/// away-from-zero, exactly what the generated entry loop computes
/// (`(int)(v + 0.5f)` / `(int)(v - 0.5f)` truncate toward zero, as does
/// Rust's `as i32`).
pub fn quantize_input(v: f32, inv_scale: f32) -> i8 {
    let x = (v * inv_scale).clamp(-127.0, 127.0);
    if x >= 0.0 {
        (x + 0.5) as i32 as i8
    } else {
        (x - 0.5) as i32 as i8
    }
}

/// Symmetric scale covering `maxabs` in 127 signed steps.
fn act_scale(maxabs: f32) -> f32 {
    maxabs.max(SCALE_FLOOR) / 127.0
}

fn quantize_weight(v: f32, scale: f32) -> i8 {
    ((v / scale).round() as i32).clamp(-127, 127) as i8
}

/// Bits needed to represent `v` (`v > 0`).
fn bits(v: i64) -> u32 {
    64 - v.leading_zeros()
}

/// Derive the requant arithmetic for one MAC layer.
///
/// * `taps_per_channel(k)` — iterator over channel `k`'s weight values.
/// * `s_in` / `s_out` — input/output activation scales.
fn derive_arith(
    layer_name: &str,
    n_ch: usize,
    w_scales: Vec<f32>,
    qw: Vec<i8>,
    qb: Vec<i32>,
    accmax: &[i64],
    s_in: f32,
    s_out: f32,
) -> Result<QuantArith> {
    let amax = accmax.iter().copied().max().unwrap_or(1).max(1);
    if amax * 2 > i32::MAX as i64 {
        bail!("int8 accumulation would overflow int32 in {layer_name}; layer too large for --dtype int8");
    }
    let pre: u32 = if amax > 32767 { bits(amax) - 15 } else { 0 };
    let r: Vec<f64> =
        (0..n_ch).map(|k| (s_in as f64) * (w_scales[k] as f64) / (s_out as f64)).collect();
    let max_r = r.iter().cloned().fold(f64::MIN_POSITIVE, f64::max);
    let total_shift = (32767.0 / max_r).log2().floor() as i64;
    let post = (total_shift - pre as i64).clamp(1, 30) as u32;
    let m: Vec<i32> = r
        .iter()
        .map(|&rk| {
            ((rk * (1u64 << (pre + post)) as f64).round() as i64).clamp(0, 32767) as i32
        })
        .collect();
    // A channel whose scale ratio is ~2^16 below the layer max rounds to
    // m == 0 and would silently zero that channel's outputs — bail instead.
    if m.iter().any(|&mk| mk == 0) {
        bail!(
            "per-channel weight-scale spread too wide in {layer_name}: a requant \
             multiplier rounded to 0; layer not representable under --dtype int8"
        );
    }
    Ok(QuantArith { w_scales, qw, qb, m, pre, post })
}

/// Worst-case |accumulator| per channel: full-scale activations on every
/// tap plus the bias.
fn channel_accmax(qw_by_channel: &[Vec<i8>], qb: &[i32]) -> Vec<i64> {
    qw_by_channel
        .iter()
        .zip(qb)
        .map(|(taps, &b)| 127 * taps.iter().map(|&q| q.unsigned_abs() as i64).sum::<i64>() + b.unsigned_abs() as i64)
        .collect()
}

/// Compute the quantization plan for an **optimized** model (BN folded,
/// dropout elided, activations fused — i.e. what `passes::optimize`
/// returns). Softmax is only admitted as the final activation; it runs
/// in float over `x_out` after the dequantize epilogue.
pub fn quantize_model(model: &Model) -> Result<QuantPlan> {
    let n = model.layers.len();
    if n == 0 {
        bail!("cannot quantize an empty model");
    }
    // Softmax placement check + trailing flag.
    let mut trailing_softmax = false;
    for (i, layer) in model.layers.iter().enumerate() {
        let is_softmax = matches!(
            layer,
            Layer::Activation(Activation::Softmax)
                | Layer::Conv2D { activation: Activation::Softmax, .. }
                | Layer::Dense { activation: Activation::Softmax, .. }
                | Layer::DepthwiseConv2D { activation: Activation::Softmax, .. }
        );
        if is_softmax {
            if i + 1 != n {
                bail!("--dtype int8 supports softmax only as the final activation (found at layer {i})");
            }
            trailing_softmax = true;
        }
        if matches!(layer, Layer::BatchNorm { .. } | Layer::Dropout { .. }) {
            bail!("quantize_model expects an optimized model (found {})", layer.kind_name());
        }
    }

    // Deterministic calibration batch in the interpreter's input domain.
    let mut rng = XorShift64::new(0xCA11_B8);
    let samples: Vec<Tensor> =
        (0..CALIB_SAMPLES).map(|_| Tensor::rand(model.input.dims(), -1.0, 1.0, &mut rng)).collect();
    let input_maxabs =
        samples.iter().flat_map(|t| t.data().iter()).fold(0f32, |a, &v| a.max(v.abs()));
    let input_scale = act_scale(input_maxabs);

    // Trace every sample, recording each MAC layer's PRE-activation
    // max-abs (post-activation ranges under-cover leaky negatives and
    // pre-softmax logits).
    let mut pre_maxabs = vec![0f32; n];
    for sample in &samples {
        let mut x = sample.clone();
        for (i, layer) in model.layers.iter().enumerate() {
            match layer {
                Layer::Conv2D { weights, bias, stride, padding, activation } => {
                    let y = interp::conv2d(&x, weights, bias, *stride, *padding)?;
                    record_maxabs(&mut pre_maxabs[i], &y);
                    x = apply_act(&y, *activation);
                }
                Layer::DepthwiseConv2D { weights, bias, stride, padding, activation } => {
                    let y = interp::depthwise_conv2d(&x, weights, bias, *stride, *padding)?;
                    record_maxabs(&mut pre_maxabs[i], &y);
                    x = apply_act(&y, *activation);
                }
                Layer::Dense { weights, bias, activation } => {
                    let y = interp::dense(&x, weights, bias)?;
                    record_maxabs(&mut pre_maxabs[i], &y);
                    x = apply_act(&y, *activation);
                }
                other => {
                    x = interp::run_layer(other, &x)?;
                }
            }
        }
    }

    // Per-layer quantization records.
    let mut layers = Vec::with_capacity(n);
    let mut s_in = input_scale;
    for (i, layer) in model.layers.iter().enumerate() {
        let lq = match layer {
            Layer::Conv2D { weights, bias, .. } => {
                let d = weights.dims();
                let (taps, c_out) = (d[0] * d[1] * d[2], d[3]);
                let s_out = act_scale(pre_maxabs[i]);
                let mut w_scales = vec![0f32; c_out];
                for k in 0..c_out {
                    let mx = (0..taps)
                        .map(|t| weights.data()[t * c_out + k].abs())
                        .fold(0f32, f32::max);
                    w_scales[k] = mx.max(1e-30) / 127.0;
                }
                mac_record(weights.data(), bias.data(), taps, c_out, true, w_scales, s_in, s_out, "Conv2D")?
            }
            Layer::DepthwiseConv2D { weights, bias, .. } => {
                let d = weights.dims();
                let (taps, c) = (d[0] * d[1], d[2]);
                let s_out = act_scale(pre_maxabs[i]);
                let mut w_scales = vec![0f32; c];
                for k in 0..c {
                    let mx =
                        (0..taps).map(|t| weights.data()[t * c + k].abs()).fold(0f32, f32::max);
                    w_scales[k] = mx.max(1e-30) / 127.0;
                }
                mac_record(weights.data(), bias.data(), taps, c, true, w_scales, s_in, s_out, "DepthwiseConv2D")?
            }
            Layer::Dense { weights, bias, .. } => {
                // Per-tensor weight scale (the issue's contract: per-channel
                // is a conv-weight refinement), replicated so the emitters
                // see one uniform per-channel format.
                let d = weights.dims();
                let (n_in, n_out) = (d[0], d[1]);
                let s_out = act_scale(pre_maxabs[i]);
                let mx = weights.data().iter().fold(0f32, |a, &v| a.max(v.abs()));
                let w_scales = vec![mx.max(1e-30) / 127.0; n_out];
                mac_record(weights.data(), bias.data(), n_in, n_out, true, w_scales, s_in, s_out, "Dense")?
            }
            _ => LayerQuant::Passthrough { out_scale: s_in },
        };
        s_in = lq.out_scale();
        layers.push(lq);
    }
    Ok(QuantPlan { input_scale, trailing_softmax, layers })
}

fn record_maxabs(slot: &mut f32, t: &Tensor) {
    for &v in t.data() {
        *slot = slot.max(v.abs());
    }
}

/// Activation as traced during calibration (softmax only ever trails, so
/// applying it cannot perturb any later scale).
fn apply_act(t: &Tensor, a: Activation) -> Tensor {
    match a {
        Activation::None => t.clone(),
        Activation::Relu => interp::relu(t),
        Activation::LeakyRelu(alpha) => interp::leaky_relu(t, alpha),
        Activation::Softmax => interp::softmax(t),
    }
}

/// Build one MAC layer's [`LayerQuant::Mac`] record. Weights are indexed
/// `tap * n_ch + k` when `channel_minor` (HWIO conv, `[h,w,c]` depthwise,
/// `[in,out]` dense — all three).
#[allow(clippy::too_many_arguments)]
fn mac_record(
    w: &[f32],
    b: &[f32],
    taps: usize,
    n_ch: usize,
    channel_minor: bool,
    w_scales: Vec<f32>,
    s_in: f32,
    s_out: f32,
    layer_name: &str,
) -> Result<LayerQuant> {
    debug_assert!(channel_minor, "all NNCG MAC layouts are channel-minor");
    debug_assert_eq!(w.len(), taps * n_ch);
    let qw: Vec<i8> = w
        .iter()
        .enumerate()
        .map(|(idx, &v)| quantize_weight(v, w_scales[idx % n_ch]))
        .collect();
    let qb: Vec<i32> = (0..n_ch)
        .map(|k| {
            let q = (b[k] as f64 / (s_in as f64 * w_scales[k] as f64)).round() as i64;
            q.clamp(-(1 << 30), 1 << 30) as i32
        })
        .collect();
    let by_channel: Vec<Vec<i8>> =
        (0..n_ch).map(|k| (0..taps).map(|t| qw[t * n_ch + k]).collect()).collect();
    let accmax = channel_accmax(&by_channel, &qb);
    let arith = derive_arith(layer_name, n_ch, w_scales, qw, qb, &accmax, s_in, s_out)?;
    Ok(LayerQuant::Mac { arith, out_scale: s_out })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::zoo;

    fn plan_for(name: &str) -> (Model, QuantPlan) {
        let m = zoo::by_name(name).unwrap().with_random_weights(13);
        let opt = crate::passes::optimize(m).unwrap();
        let qp = quantize_model(&opt).unwrap();
        (opt, qp)
    }

    #[test]
    fn plans_cover_all_paper_models() {
        for name in zoo::PAPER_MODELS {
            let (m, qp) = plan_for(name);
            assert_eq!(qp.layers.len(), m.layers.len(), "{name}");
            assert!(qp.input_scale > 0.0);
            for (i, (lq, layer)) in qp.layers.iter().zip(&m.layers).enumerate() {
                assert!(lq.out_scale() > 0.0, "{name} layer {i}");
                match layer {
                    Layer::Conv2D { weights, .. } => {
                        let arith = match lq {
                            LayerQuant::Mac { arith, .. } => arith,
                            _ => panic!("{name} layer {i}: conv must be Mac"),
                        };
                        let c_out = weights.dims()[3];
                        assert_eq!(arith.w_scales.len(), c_out);
                        assert_eq!(arith.m.len(), c_out);
                        assert_eq!(arith.qw.len(), weights.numel());
                        assert!((1..=30).contains(&arith.post));
                        assert!(arith.m.iter().all(|&m| (0..=32767).contains(&m)));
                    }
                    Layer::MaxPool2D { .. } | Layer::Flatten => {
                        assert!(matches!(lq, LayerQuant::Passthrough { .. }), "{name} layer {i}");
                    }
                    _ => {}
                }
            }
        }
    }

    #[test]
    fn trailing_softmax_detected_and_mid_model_rejected() {
        let (_, qp) = plan_for("ball");
        assert!(qp.trailing_softmax, "ball's classifier head ends in softmax");
        let (_, qp) = plan_for("robot");
        assert!(!qp.trailing_softmax, "robot's detector head is linear");
        // Mid-model softmax must be rejected.
        let m = Model {
            layers: vec![
                Layer::Activation(Activation::Softmax),
                Layer::Activation(Activation::Relu),
            ],
            ..zoo::ball_classifier().with_random_weights(1)
        };
        assert!(quantize_model(&m).is_err());
    }

    #[test]
    fn zero_multiplier_channel_is_rejected() {
        // Channel 1's scale ratio sits ~2^16 below channel 0's, so its
        // requant multiplier rounds to 0 — which would silently zero every
        // output of that channel (including a nonzero bias). Must bail.
        let r = derive_arith("conv0", 2, vec![1.0, 1e-9], vec![], vec![], &[100, 100], 1.0, 1.0);
        assert!(r.is_err());
        // A wide-but-representable spread still derives, with every m >= 1.
        let a = derive_arith("conv0", 2, vec![1.0, 1e-3], vec![], vec![], &[100, 100], 1.0, 1.0)
            .unwrap();
        assert!(a.m.iter().all(|&mk| mk >= 1), "m = {:?}", a.m);
    }

    #[test]
    fn requant_is_deterministic_and_clamped() {
        assert_eq!(requant(0, 16384, 0, 15), 0);
        assert_eq!(requant(1 << 15, 32767, 0, 15), 127); // saturates high
        assert_eq!(requant(-(1 << 15), 32767, 0, 15), -127); // saturates low
        // pre-shift rounds half up: (3 + 2) >> 2 == 1
        assert_eq!(requant(3, 1 << 14, 2, 14), 1);
        // negative inputs round through arithmetic shift, matching C:
        // (-3+2)>>2 = -1, (-16384 + 8192) >> 14 = floor(-0.5) = -1.
        assert_eq!(requant(-3, 1 << 14, 2, 14), -1);
    }

    #[test]
    fn fixed_point_activation_helpers_match_float() {
        let mult = leaky_mult(0.1);
        for q in -127i32..=127 {
            let got = if q > 0 { q } else { qleaky(q, mult) as i32 };
            let want = if q > 0 { q as f32 } else { q as f32 * 0.1 };
            assert!((got as f32 - want).abs() <= 0.51, "q={q} got={got} want={want}");
        }
        let am = avg_mult(4);
        assert_eq!(qavg(4 * 100, am), 100);
        assert_eq!(qavg(-4 * 100, am), -100);
    }

    #[test]
    fn input_quantization_round_trips_within_half_step() {
        let scale = 0.01f32;
        let inv = 1.0 / scale;
        for v in [-1.27f32, -0.5, -0.004, 0.0, 0.004, 0.5, 1.27, 99.0, -99.0] {
            let q = quantize_input(v, inv) as f32 * scale;
            let clamped = v.clamp(-1.27, 1.27);
            assert!((q - clamped).abs() <= scale * 0.5 + 1e-6, "v={v} q={q}");
        }
    }
}
