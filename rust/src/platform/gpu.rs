//! GTX 1050 latency/throughput model.
//!
//! Paper §III-C: running the ball classifier through TensorFlow XLA on a
//! GTX 1050 takes 5630µs for one image — ~2700× slower than NNCG on the
//! i7 — because dispatch, host↔device transfer and framework overhead
//! dominate; the latency "does not change significantly for under 100
//! images classified at once".
//!
//! Model: `t(batch) = overhead + batch * (transfer + compute)` where
//! overhead is the fixed dispatch cost and per-image terms come from PCIe
//! bandwidth and the device's MAC roofline. Calibrated against the paper's
//! ball (5630µs) and pedestrian (5762µs) single-image measurements:
//! their difference is ~132µs for a 1.28M-MAC increase, consistent with an
//! effective ~10 GMAC/s achieved rate at batch 1 (tiny kernels cannot fill
//! 640 CUDA cores), rising toward the roofline as batching improves
//! occupancy.

/// Simulated GPU executing via the TF-XLA path.
#[derive(Debug, Clone)]
pub struct GpuModel {
    pub name: &'static str,
    /// Fixed per-dispatch overhead in µs (framework + launch + sync).
    pub overhead_us: f64,
    /// Host↔device bandwidth in GB/s (PCIe 3.0 x16 effective).
    pub pcie_gbps: f64,
    /// Peak device throughput in GMAC/s (1.86 TFLOPs ≈ 930 GMAC/s).
    pub peak_gmacs: f64,
    /// Achieved fraction of peak at batch 1 (tiny-kernel occupancy).
    pub batch1_efficiency: f64,
    /// Batch size at which occupancy saturates.
    pub saturation_batch: f64,
}

impl GpuModel {
    /// GTX 1050 with TF-XLA, calibrated to the paper's measurements.
    pub fn gtx_1050() -> Self {
        GpuModel {
            name: "NVIDIA 1050",
            overhead_us: 5616.0,
            pcie_gbps: 12.0,
            peak_gmacs: 930.0,
            batch1_efficiency: 0.011,
            saturation_batch: 128.0,
        }
    }

    /// Achieved GMAC/s at a batch size: occupancy grows with batching and
    /// saturates at `saturation_batch`.
    fn achieved_gmacs(&self, batch: usize) -> f64 {
        let occ = (batch as f64 / self.saturation_batch).min(1.0);
        let eff = self.batch1_efficiency + (1.0 - self.batch1_efficiency) * occ;
        self.peak_gmacs * eff
    }

    /// Total latency in µs to classify `batch` images of `in_bytes` each,
    /// `macs` MACs per image.
    pub fn latency_us(&self, macs: u64, in_bytes: usize, batch: usize) -> f64 {
        let transfer = batch as f64 * in_bytes as f64 / (self.pcie_gbps * 1e9) * 1e6;
        let compute = batch as f64 * macs as f64 / (self.achieved_gmacs(batch) * 1e3);
        self.overhead_us + transfer + compute
    }

    /// Per-image latency at a batch size (the throughput view).
    pub fn per_image_us(&self, macs: u64, in_bytes: usize, batch: usize) -> f64 {
        self.latency_us(macs, in_bytes, batch) / batch as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::platform::BALL_MACS;

    const BALL_BYTES: usize = 16 * 16 * 4;

    #[test]
    fn single_image_matches_paper_ball() {
        let gpu = GpuModel::gtx_1050();
        let us = gpu.latency_us(BALL_MACS, BALL_BYTES, 1);
        assert!((us - 5630.0).abs() / 5630.0 < 0.02, "{us}");
    }

    #[test]
    fn single_image_matches_paper_pedestrian() {
        // pedestrian: 1.29M MACs, 18*36 f32 input; paper: 5762µs.
        let gpu = GpuModel::gtx_1050();
        let us = gpu.latency_us(1_294_432, 18 * 36 * 4, 1);
        assert!((us - 5762.0).abs() / 5762.0 < 0.05, "{us}");
    }

    #[test]
    fn latency_is_flat_below_100_images() {
        // The paper's qualitative claim.
        let gpu = GpuModel::gtx_1050();
        let t1 = gpu.latency_us(BALL_MACS, BALL_BYTES, 1);
        let t100 = gpu.latency_us(BALL_MACS, BALL_BYTES, 100);
        assert!(t100 / t1 < 1.15, "t1={t1} t100={t100}");
    }

    #[test]
    fn throughput_improves_with_large_batches() {
        let gpu = GpuModel::gtx_1050();
        let p1 = gpu.per_image_us(BALL_MACS, BALL_BYTES, 1);
        let p1k = gpu.per_image_us(BALL_MACS, BALL_BYTES, 1024);
        assert!(p1k < p1 / 100.0, "p1={p1} p1k={p1k}");
    }

    #[test]
    fn occupancy_monotone() {
        let gpu = GpuModel::gtx_1050();
        let mut last = 0.0;
        for b in [1, 2, 8, 64, 128, 512] {
            let g = gpu.achieved_gmacs(b);
            assert!(g >= last);
            last = g;
        }
        assert!(gpu.achieved_gmacs(4096) <= gpu.peak_gmacs);
    }
}
