//! Platform cost-model simulator.
//!
//! The paper measures four platforms we do not have (Intel i7-8650U, Atom
//! J1900, Atom Z530/Nao, NVIDIA GTX 1050). Per the substitution rule
//! (DESIGN.md §3) we *simulate* them: each CPU platform carries effective
//! per-engine MAC throughputs **calibrated on the paper's own Table IV
//! (ball classifier)**, and the other workloads (Tables V and VI) are then
//! *predicted* from their MAC counts — a calibrate-on-one, validate-on-rest
//! methodology whose prediction error is reported in EXPERIMENTS.md.
//!
//! The GPU model captures the paper's central GPU observation: a fixed
//! multi-millisecond dispatch+transfer overhead dominates small-CNN
//! latency, so latency is flat "for under 100 images" and only amortizes at
//! large batch sizes.

mod gpu;

pub use gpu::GpuModel;

use crate::graph::Model;
use crate::runtime::EngineKind;
use anyhow::Result;

/// MAC count of the ball classifier — the calibration workload.
pub const BALL_MACS: u64 = 16_352;

/// A simulated CPU platform with paper-calibrated effective throughputs.
#[derive(Debug, Clone)]
pub struct CpuPlatform {
    pub name: &'static str,
    /// Effective GMAC/s for NNCG-generated code (SSE, outer loops kept).
    pub nncg_gmacs: f64,
    /// Effective GMAC/s for the TF-XLA object-code path.
    pub xla_gmacs: Option<f64>,
    /// Effective GMAC/s for Glow (paper only measured it on the i7).
    pub glow_gmacs: Option<f64>,
    /// Clock in GHz (context for DESIGN.md; not used in the prediction).
    pub freq_ghz: f64,
}

impl CpuPlatform {
    /// Predicted single-image latency in µs for an engine on a workload of
    /// `macs` multiply-accumulates. `None` when the paper found the
    /// engine inapplicable on the platform (Glow's AVX objects on Atoms,
    /// XLA's Eigen dependency on the 32-bit Nao).
    pub fn predict_us(&self, engine: EngineKind, macs: u64) -> Option<f64> {
        let gmacs = match engine {
            EngineKind::Nncg => Some(self.nncg_gmacs),
            EngineKind::Xla => self.xla_gmacs,
            EngineKind::Interp => self.glow_gmacs,
        }?;
        Some(macs as f64 / gmacs / 1e3)
    }

    /// Predicted latency for a whole model.
    pub fn predict_model_us(&self, engine: EngineKind, model: &Model) -> Result<Option<f64>> {
        Ok(self.predict_us(engine, model.macs()?))
    }
}

/// Intel i7-8650U (Kaby Lake R, 1.9/4.2 GHz) — the paper's desktop row.
/// Rates derived from Table IV: NNCG 2.10µs, Glow 7.53µs, XLA 24.81µs on
/// the 16,352-MAC ball classifier.
pub fn i7_8650u() -> CpuPlatform {
    CpuPlatform {
        name: "Intel i7 (8650U)",
        nncg_gmacs: BALL_MACS as f64 / 2.10 / 1e3,  // 7.79
        xla_gmacs: Some(BALL_MACS as f64 / 24.81 / 1e3), // 0.659
        glow_gmacs: Some(BALL_MACS as f64 / 7.53 / 1e3), // 2.17
        freq_ghz: 4.2,
    }
}

/// Intel Atom J1900 (Silvermont, 2.42 GHz burst) — the efficient-platform
/// row. Table IV: NNCG 17.51µs, XLA 69.12µs; Glow N/A (its object file
/// contains host AVX instructions the Atom cannot execute).
pub fn atom_j1900() -> CpuPlatform {
    CpuPlatform {
        name: "Intel Atom (J1900)",
        nncg_gmacs: BALL_MACS as f64 / 17.51 / 1e3, // 0.934
        xla_gmacs: Some(BALL_MACS as f64 / 69.12 / 1e3), // 0.237
        glow_gmacs: None,
        freq_ghz: 2.42,
    }
}

/// Intel Atom Z530 (Bonnell in-order, 1.6 GHz) — the Nao robot, custom
/// 32-bit Linux. Table IV: NNCG 46.50µs; XLA N/A (Eigen does not build
/// for the 32-bit target), Glow N/A.
pub fn atom_z530() -> CpuPlatform {
    CpuPlatform {
        name: "Intel Atom (Z530)",
        nncg_gmacs: BALL_MACS as f64 / 46.50 / 1e3, // 0.352
        xla_gmacs: None,
        glow_gmacs: None,
        freq_ghz: 1.6,
    }
}

/// The paper's CPU platforms in table order.
pub fn paper_platforms() -> Vec<CpuPlatform> {
    vec![i7_8650u(), atom_j1900(), atom_z530()]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::zoo;

    #[test]
    fn ball_macs_constant_matches_zoo() {
        let m = zoo::ball_classifier().with_random_weights(1);
        assert_eq!(m.macs().unwrap(), BALL_MACS);
    }

    #[test]
    fn calibration_reproduces_table_iv_exactly() {
        // By construction: predicting the calibration workload must return
        // the paper's numbers.
        let cases = [
            (i7_8650u(), EngineKind::Nncg, Some(2.10)),
            (i7_8650u(), EngineKind::Interp, Some(7.53)),
            (i7_8650u(), EngineKind::Xla, Some(24.81)),
            (atom_j1900(), EngineKind::Nncg, Some(17.51)),
            (atom_j1900(), EngineKind::Xla, Some(69.12)),
            (atom_j1900(), EngineKind::Interp, None),
            (atom_z530(), EngineKind::Nncg, Some(46.50)),
            (atom_z530(), EngineKind::Xla, None),
        ];
        for (plat, eng, want) in cases {
            let got = plat.predict_us(eng, BALL_MACS);
            match (got, want) {
                (Some(g), Some(w)) => assert!((g - w).abs() < 0.01, "{} {eng:?}: {g} vs {w}", plat.name),
                (None, None) => {}
                other => panic!("{} {eng:?}: {other:?}", plat.name),
            }
        }
    }

    #[test]
    fn predictions_preserve_paper_ordering_on_other_tables() {
        // Validation workloads: NNCG must beat XLA everywhere, and
        // platform ordering i7 < J1900 < Z530 must hold.
        for name in ["pedestrian", "robot"] {
            let m = zoo::by_name(name).unwrap().with_random_weights(1);
            let macs = m.macs().unwrap();
            let i7 = i7_8650u();
            let j = atom_j1900();
            let z = atom_z530();
            let nncg_i7 = i7.predict_us(EngineKind::Nncg, macs).unwrap();
            let xla_i7 = i7.predict_us(EngineKind::Xla, macs).unwrap();
            assert!(nncg_i7 < xla_i7, "{name}");
            let nncg_j = j.predict_us(EngineKind::Nncg, macs).unwrap();
            let nncg_z = z.predict_us(EngineKind::Nncg, macs).unwrap();
            assert!(nncg_i7 < nncg_j && nncg_j < nncg_z, "{name}");
        }
    }

    #[test]
    fn pedestrian_prediction_within_50pct_of_paper() {
        // Calibrated on ball, predict pedestrian (paper: 135.7µs on i7).
        let m = zoo::pedestrian_classifier().with_random_weights(1);
        let us = i7_8650u().predict_model_us(EngineKind::Nncg, &m).unwrap().unwrap();
        let paper = 135.7;
        assert!((us - paper).abs() / paper < 0.5, "predicted {us}, paper {paper}");
    }
}
