//! Tiny flag parser: `--key value`, `--flag` (boolean), `-o value`.

use anyhow::{bail, Result};
use std::collections::HashMap;

/// Parsed flags.
#[derive(Debug, Default)]
pub struct Args {
    values: HashMap<String, String>,
    flags: Vec<String>,
    /// Positional arguments.
    pub positional: Vec<String>,
}

impl Args {
    pub fn parse(argv: &[String]) -> Result<Args> {
        let mut args = Args::default();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(key) = a.strip_prefix("--").or_else(|| a.strip_prefix('-').filter(|_| a.len() == 2)) {
                // Peek: value or boolean flag?
                if i + 1 < argv.len() && !argv[i + 1].starts_with('-') {
                    args.values.insert(key.to_string(), argv[i + 1].clone());
                    i += 2;
                } else {
                    args.flags.push(key.to_string());
                    i += 1;
                }
            } else {
                args.positional.push(a.clone());
                i += 1;
            }
        }
        Ok(args)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.values.get(key).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn has_flag(&self, key: &str) -> bool {
        self.flags.iter().any(|f| f == key)
    }

    pub fn get_usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => match v.parse() {
                Ok(n) => Ok(n),
                Err(_) => bail!("--{key} expects an integer, got {v:?}"),
            },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &[&str]) -> Args {
        Args::parse(&s.iter().map(|x| x.to_string()).collect::<Vec<_>>()).unwrap()
    }

    #[test]
    fn key_values_and_flags() {
        let a = parse(&["--model", "ball", "--quick", "--trials", "5", "pos1"]);
        assert_eq!(a.get("model"), Some("ball"));
        assert!(a.has_flag("quick"));
        assert_eq!(a.get_usize("trials", 1).unwrap(), 5);
        assert_eq!(a.positional, vec!["pos1"]);
    }

    #[test]
    fn short_flag_with_value() {
        let a = parse(&["-o", "out.c"]);
        assert_eq!(a.get("o"), Some("out.c"));
    }

    #[test]
    fn bad_integer_errors() {
        let a = parse(&["--trials", "many"]);
        assert!(a.get_usize("trials", 1).is_err());
    }

    #[test]
    fn defaults() {
        let a = parse(&[]);
        assert_eq!(a.get_or("model", "ball"), "ball");
        assert_eq!(a.get_usize("n", 7).unwrap(), 7);
    }
}
