//! Command-line interface (hand-rolled; clap is unavailable offline).
//!
//! ```text
//! nncg describe --model ball
//! nncg generate --model ball --isa sse3 --unroll full -o ball.c
//! nncg verify   --model ball [--trials 5]
//! nncg run      --model ball --engine nncg|interp|xla
//! nncg bench    --table 4|5|6|7|gpu
//! nncg serve    --model ball --frames 50 [--shards 4 --steal on|off --listen 127.0.0.1:0]
//! nncg platforms
//! nncg export-figures [fig1|fig2|fig3|all]
//! ```

mod args;
pub mod commands;

pub use args::Args;

use anyhow::Result;

/// Entry point used by `main.rs`. Returns the process exit code.
pub fn run(argv: &[String]) -> Result<i32> {
    if argv.is_empty() || argv[0] == "help" || argv[0] == "--help" || argv[0] == "-h" {
        print!("{}", usage());
        return Ok(0);
    }
    let cmd = argv[0].clone();
    let args = Args::parse(&argv[1..])?;
    match cmd.as_str() {
        "describe" => commands::describe(&args),
        "generate" => commands::generate(&args),
        "verify" => commands::verify(&args),
        "run" => commands::run_once(&args),
        "bench" => commands::bench(&args),
        "serve" => commands::serve(&args),
        "platforms" => commands::platforms(&args),
        "export-figures" => commands::export_figures(&args),
        other => {
            eprintln!("unknown command {other:?}\n{}", usage());
            Ok(2)
        }
    }
}

pub fn usage() -> String {
    "\
nncg — C code generator for fast CNN inference (paper reproduction)

USAGE: nncg <command> [flags]

COMMANDS:
  describe        print a model architecture table (--model ball|pedestrian|robot)
  generate        emit the C file for a model (--model,
                  --isa generic|sse3|avx2|neon|neon-vfpv3,
                  --unroll none|2|1|full, --pad-mode auto|copy|padless,
                  --tile auto|off|2..8|RxC (2-D register block, e.g. 2x4),
                  --align auto|off, --fuse auto|off|2..8 (row-streaming
                  fusion with ring line buffers; N = max group depth),
                  --harness, -o FILE)
  verify          compile generated C and compare against the interpreter
                  (--model, --isa, --unroll, --pad-mode, --tile, --align,
                  --fuse, --trials N; NEON is generate-only on x86 hosts)
  run             classify one synthetic input (--model, --engine nncg|interp|xla,
                  --artifacts DIR for xla)
  bench           reproduce a paper table (--table 4|5|6|7|gpu, --quick)
  serve           run the sharded serving coordinator over synthetic frames
                  (--model ball, --frames N, --engine ..., --shards N,
                  --steal on|off, --steal-policy half-length|one-length|
                  half-age|one-age (or NNCG_SERVE_STEAL_POLICY),
                  --workers N, --queue-cap N, --deadline-ms N,
                  --fallback, --faults SPEC, --listen ADDR to accept and
                  drive requests over the length-prefixed TCP protocol)
  platforms       print the simulated platform models and predictions
  export-figures  write Fig. 1-3 sample images (--out DIR)

Weights: models load trained weights from --weights-dir (default models/)
if present, else use seeded random weights (latency is weight-independent).

Alignment: with --align auto (default) scratch buffers and weight arrays get
a 32-byte NNCG_ALIGN attribute and provably-aligned vector accesses use the
aligned intrinsic forms (x_in/x_out always stay unaligned); --align off is
the paper-baseline unaligned emission. NEON ignores the distinction
(vld1q_f32 is alignment-agnostic) and always stores weights as arrays;
neon-vfpv3 targets pre-VFPv4 ARMv7 (non-fused vmlaq_f32).

Fusion: --fuse auto streams consecutive conv/depthwise/pool/activation
layers row-by-row through static ring line buffers of a few rows each,
shrinking peak scratch RAM from whole planes (O(H*W*C)) to kernel windows
(O(k_h*W*C)) per fused edge; outputs are bit-identical to --fuse off.
"
    .to_string()
}
