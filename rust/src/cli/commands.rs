//! CLI subcommand implementations.

use super::args::Args;
use crate::cc::{CcDriver, CcTarget, CompiledCnn};
use crate::codegen::{
    generate_c, AlignMode, ChanPad, CodegenOptions, DType, FuseMode, Isa, PadMode, RolledMode,
    TileMode, Unroll,
};
use crate::coordinator;
use crate::experiments::{self, build_engine, load_model};
use crate::platform::{paper_platforms, GpuModel};
use crate::runtime::EngineKind;
use crate::tensor::Tensor;
use crate::util::XorShift64;
use crate::vision::{ball, render};
use anyhow::{bail, Result};
use std::path::PathBuf;

fn opts_from_args(args: &Args) -> Result<CodegenOptions> {
    let isa_name = args.get_or("isa", "sse3");
    let isa = Isa::from_name(isa_name)
        .ok_or_else(|| anyhow::anyhow!("unknown --isa {isa_name:?} (generic|sse3|avx2|neon|neon-vfpv3|neon-dot)"))?;
    let unroll = Unroll::from_name(args.get_or("unroll", "keep-outer-2"))
        .ok_or_else(|| anyhow::anyhow!("unknown --unroll (none|2|1|full)"))?;
    let pad_mode = PadMode::from_name(args.get_or("pad-mode", "auto"))
        .ok_or_else(|| anyhow::anyhow!("unknown --pad-mode (auto|copy|padless)"))?;
    let tile = TileMode::from_name(args.get_or("tile", "auto"))
        .ok_or_else(|| anyhow::anyhow!("unknown --tile (auto|off|2..8|RxC e.g. 2x4)"))?;
    let align = AlignMode::from_name(args.get_or("align", "auto"))
        .ok_or_else(|| anyhow::anyhow!("unknown --align (auto|off)"))?;
    let fuse = FuseMode::from_name(args.get_or("fuse", "off"))
        .ok_or_else(|| anyhow::anyhow!("unknown --fuse (auto|off|2..8 = max group depth)"))?;
    let fuse_rolled = RolledMode::from_name(args.get_or("fuse-rolled", "auto")).ok_or_else(|| {
        anyhow::anyhow!(
            "unknown --fuse-rolled (auto = rotate, falling back to expand | \
             rotate = ring-pointer rotation, one pattern period per body | \
             expand = phase-expanded body (differential baseline) | \
             off = unrolled row schedule)"
        )
    })?;
    let dtype = DType::from_name(args.get_or("dtype", "f32"))
        .ok_or_else(|| anyhow::anyhow!("unknown --dtype (f32 | int8 = symmetric post-training quantization)"))?;
    let chan_pad = ChanPad::from_name(args.get_or("chan-pad", "auto"))
        .ok_or_else(|| anyhow::anyhow!("unknown --chan-pad (auto = round ring row strides to vector groups | off)"))?;
    Ok(CodegenOptions {
        isa,
        unroll,
        pad_mode,
        tile,
        align,
        fuse,
        fuse_rolled,
        dtype,
        chan_pad,
        test_harness: args.has_flag("harness"),
        ..Default::default()
    })
}

fn weights_dir(args: &Args) -> PathBuf {
    args.get("weights-dir").map(PathBuf::from).unwrap_or_else(experiments::default_weights_dir)
}

fn model_from_args(args: &Args) -> Result<crate::graph::Model> {
    load_model(args.get_or("model", "ball"), &weights_dir(args))
}

pub fn describe(args: &Args) -> Result<i32> {
    let model = model_from_args(args)?;
    print!("{}", model.describe());
    let hist = crate::passes::layer_histogram(&model);
    let parts: Vec<String> = hist.iter().map(|(k, c)| format!("{k}×{c}")).collect();
    println!("layers: {}", parts.join(", "));
    Ok(0)
}

pub fn generate(args: &Args) -> Result<i32> {
    let model = model_from_args(args)?;
    let opts = opts_from_args(args)?;
    let src = generate_c(&model, &opts)?;
    match args.get("o") {
        Some(path) => {
            std::fs::write(path, &src)?;
            eprintln!("wrote {} bytes ({} lines) to {path}", src.len(), src.lines().count());
        }
        None => print!("{src}"),
    }
    Ok(0)
}

pub fn verify(args: &Args) -> Result<i32> {
    let model = model_from_args(args)?;
    let opts = opts_from_args(args)?;
    if opts.isa.is_neon() && !cfg!(any(target_arch = "aarch64", target_arch = "arm")) {
        bail!(
            "--isa {} generates ARM intrinsics this host cannot execute; \
             use `nncg generate --isa {}` and cross-compile (CI syntax-checks it, \
             and runs it under qemu-user when available)",
            opts.isa.name(),
            opts.isa.name()
        );
    }
    let trials = args.get_usize("trials", 5)?;
    // f32 compares the compiled C against the float interpreter; int8
    // compares against the int8 reference path on the same quant plan
    // (bit-exact integers, so the tolerance only covers the float
    // softmax epilogue's libm term).
    let (err, tol, oracle) = if opts.dtype == DType::Int8 {
        let e = crate::cc::verify_int8_against_oracle(&model, &opts, experiments::default_work_dir(), trials, 42)?;
        (e, 1e-6, "int8-interp")
    } else {
        let e = crate::cc::verify_against_interp(&model, &opts, experiments::default_work_dir(), trials, 42)?;
        (e, 1e-4, "interp")
    };
    println!("model={} opts={} oracle={oracle} trials={trials} max_abs_err={err:.3e}", model.name, opts.tag());
    if err < tol {
        println!("VERIFY OK");
        Ok(0)
    } else {
        println!("VERIFY FAILED");
        Ok(1)
    }
}

pub fn run_once(args: &Args) -> Result<i32> {
    let model = model_from_args(args)?;
    let kind = EngineKind::from_name(args.get_or("engine", "nncg"))
        .ok_or_else(|| anyhow::anyhow!("unknown --engine (nncg|interp|xla)"))?;
    let artifacts = args.get("artifacts").map(PathBuf::from).unwrap_or_else(experiments::default_artifacts_dir);
    let engine = build_engine(kind, &model, &opts_from_args(args)?, &artifacts, &experiments::default_work_dir())?;
    let mut rng = XorShift64::new(args.get_usize("seed", 1)? as u64);
    let input = Tensor::rand(model.input.dims(), 0.0, 1.0, &mut rng);
    let t0 = std::time::Instant::now();
    let out = engine.infer(&input)?;
    let us = t0.elapsed().as_secs_f64() * 1e6;
    println!("engine={} model={} latency={:.2}us", engine.name(), model.name, us);
    let show = out.data().iter().take(8).map(|v| format!("{v:.5}")).collect::<Vec<_>>();
    println!("output[..{}] = [{}] argmax={}", show.len(), show.join(", "), out.argmax());
    Ok(0)
}

pub fn bench(args: &Args) -> Result<i32> {
    let quick = args.has_flag("quick");
    let which = args.get_or("table", "all");
    let run = |name: &str| -> Result<()> {
        let result = match name {
            "4" => experiments::run_table4(quick)?,
            "5" => experiments::run_table5(quick)?,
            "6" => experiments::run_table6(quick)?,
            "7" => experiments::run_table7(quick)?,
            "gpu" => experiments::run_gpu_throughput()?,
            other => bail!("unknown --table {other:?} (4|5|6|7|gpu|all)"),
        };
        println!("{}", result.rendered);
        Ok(())
    };
    if which == "all" {
        for t in ["4", "5", "6", "7", "gpu"] {
            run(t)?;
        }
    } else {
        run(which)?;
    }
    Ok(0)
}

/// Resolve `--batch-max N` / `--batch-adapt on|off` into a dequeue policy.
/// `--batch-max 1` (the default) keeps the latency-first immediate policy;
/// N ≥ 2 enables batched dequeue with a 2 ms fill wait, adaptive unless
/// `--batch-adapt off` pins the width.
fn batch_policy_from_args(args: &Args) -> Result<(coordinator::BatcherPolicy, bool)> {
    let max = args.get_usize("batch-max", 1)?.max(1);
    if max == 1 {
        return Ok((coordinator::BatcherPolicy::immediate(), false));
    }
    let policy = coordinator::BatcherPolicy::batched(max, std::time::Duration::from_millis(2));
    let adapt = !matches!(args.get_or("batch-adapt", "on"), "off" | "0" | "false");
    Ok((policy, adapt))
}

pub fn serve(args: &Args) -> Result<i32> {
    // End-to-end robot-soccer serving loop: synthetic frames → ball
    // candidates → classification via the coordinator, with the robustness
    // layer exposed: --shards N (per-model shard pools), --steal on|off
    // (work stealing between idle and backlogged shards), --steal-policy
    // half-length|one-length|half-age|one-age, --deadline-ms (shed stale
    // patches), --queue-cap, --fallback (circuit-breaker interp fallback),
    // --faults SPEC (or NNCG_FAULTS) for chaos drills, --listen ADDR to
    // serve and drive the frames over the length-prefixed TCP protocol.
    let model = load_model("ball", &weights_dir(args))?;
    let kind = EngineKind::from_name(args.get_or("engine", "nncg")).unwrap_or(EngineKind::Nncg);
    let artifacts = args.get("artifacts").map(PathBuf::from).unwrap_or_else(experiments::default_artifacts_dir);
    let mut engine = build_engine(kind, &model, &CodegenOptions::sse3(), &artifacts, &experiments::default_work_dir())?;

    let faults = match args.get("faults") {
        Some(spec) => Some(crate::faults::FaultPlan::parse(spec)?),
        None => crate::faults::FaultPlan::from_env()?,
    };
    if let Some(plan) = &faults {
        eprintln!("fault injection active: {}", plan.describe());
        engine = std::sync::Arc::new(crate::faults::FaultyEngine::new(engine, std::sync::Arc::clone(plan)));
    }

    let deadline = match args.get_usize("deadline-ms", 0)? {
        0 => None,
        ms => Some(std::time::Duration::from_millis(ms as u64)),
    };
    // --batch-max N caps the per-shard dequeue batch (N ≥ 2 enables the
    // batched engine entry path); --batch-adapt on|off (default on when
    // batching) adapts the effective width to queue depth, decaying back
    // to latency-first when the queue drains.
    let (batch, batch_adapt) = batch_policy_from_args(args)?;
    // --steal-policy wins over NNCG_SERVE_STEAL_POLICY; both fall back to
    // the half-length default.
    let steal_policy = match args.get("steal-policy") {
        Some(name) => coordinator::StealPolicy::parse(name).ok_or_else(|| {
            anyhow::anyhow!("unknown --steal-policy {name:?} (half-length|one-length|half-age|one-age)")
        })?,
        None => std::env::var("NNCG_SERVE_STEAL_POLICY")
            .ok()
            .and_then(|v| coordinator::StealPolicy::parse(v.trim()))
            .unwrap_or_default(),
    };
    let cfg = coordinator::ShardConfig {
        shards: args.get_usize("shards", 1)?.max(1),
        workers_per_shard: args.get_usize("workers", 1)?.max(1),
        queue_capacity: args.get_usize("queue-cap", 1024)?,
        default_deadline: deadline,
        steal: !matches!(args.get_or("steal", "on"), "off" | "0" | "false"),
        steal_policy,
        batch,
        batch_adapt,
        faults: faults.clone(),
        ..coordinator::ShardConfig::default()
    };
    // Start the coordinator over an empty router first so the fallback
    // wrapper can share the recorder's counters, then register.
    let router = std::sync::Arc::new(coordinator::Router::new());
    let handle = coordinator::serve_sharded(std::sync::Arc::clone(&router), cfg);
    if args.has_flag("fallback") {
        let interp: std::sync::Arc<dyn crate::runtime::InferenceEngine> =
            std::sync::Arc::new(crate::interp::InterpEngine::new(model.clone())?);
        let wrapped = coordinator::FallbackEngine::new(engine, interp, coordinator::BreakerConfig::default())
            .with_counters(std::sync::Arc::clone(handle.metrics.counters()));
        router.register("ball", std::sync::Arc::new(wrapped));
    } else {
        router.register("ball", engine);
    }

    // --listen ADDR puts the length-prefixed TCP front-end in front of the
    // pool and drives every patch through a loopback NetClient, so the
    // command exercises the full wire path (encode → TCP → decode → shard
    // queue → reply frame) instead of the in-process Submitter.
    let mut net_server = None;
    let mut net_client = None;
    if let Some(addr) = args.get("listen") {
        let net_cfg = coordinator::NetConfig { faults: faults.clone(), ..coordinator::NetConfig::default() };
        let server = coordinator::NetServer::start(handle.submitter(), addr, net_cfg)?;
        let bound = server.local_addr();
        eprintln!("listening on {bound} (NNCG/1 length-prefixed frames)");
        net_client = Some(coordinator::NetClient::connect(bound).map_err(|e| anyhow::anyhow!("connect {bound}: {e}"))?);
        net_server = Some(server);
    }

    let frames = args.get_usize("frames", 30)?;
    let mut rng = XorShift64::new(99);
    let mut total_candidates = 0usize;
    let mut total_balls = 0usize;
    let mut total_errors = 0usize;
    let t0 = std::time::Instant::now();
    for _ in 0..frames {
        let (img, _truth) = render::soccer_frame(60, 80, 1 + rng.below(2), rng.below(2), &mut rng);
        let cands = ball::extract_candidates(&img, &ball::BallExtractorConfig::default());
        total_candidates += cands.len();
        let patches: Vec<Tensor> = cands.iter().map(|c| ball::candidate_patch(&img, c)).collect();
        if let Some(client) = net_client.as_mut() {
            // Wire path: pipeline the frame's patches (send all, then read
            // all) so the per-connection window, not the round trip,
            // bounds throughput. Replies arrive in submission order.
            let mut sent = 0usize;
            for p in &patches {
                match client.send("ball", p) {
                    Ok(_) => sent += 1,
                    Err(_) => total_errors += 1,
                }
            }
            for _ in 0..sent {
                match client.read_reply() {
                    Ok((_, Ok(out))) => total_balls += (out.argmax() == 1) as usize,
                    Ok((_, Err(_))) => total_errors += 1,
                    Err(e) => return Err(anyhow::anyhow!("serving connection lost mid-frame: {e}")),
                }
            }
        } else {
            // Per-request submit (rather than infer_burst) so shed/failed
            // patches are counted without abandoning the rest of the frame.
            let receivers: Vec<_> = patches
                .into_iter()
                .filter_map(|p| match handle.submit("ball", p, None) {
                    Ok(rx) => Some(rx),
                    Err(_) => {
                        total_errors += 1;
                        None
                    }
                })
                .collect();
            for rx in receivers {
                match rx.recv().unwrap_or(Err(coordinator::ServeError::Stopped)) {
                    Ok(out) => total_balls += (out.argmax() == 1) as usize,
                    Err(_) => total_errors += 1,
                }
            }
        }
    }
    let total_s = t0.elapsed().as_secs_f64();
    // Close the wire before the pool: dropping the client ends its
    // connection cleanly, stop() joins the accept/conn threads, and only
    // then does the pool drain — so every accepted frame got its reply.
    drop(net_client);
    if let Some(server) = net_server.take() {
        server.stop();
    }
    let snap = handle.stop();
    println!(
        "frames={frames} candidates={total_candidates} classified-ball={total_balls} errors={total_errors} wall={:.3}s ({:.1} fps)",
        total_s,
        frames as f64 / total_s
    );
    for m in &snap.models {
        println!(
            "model={} n={} queue_mean={:.1}us infer_mean={:.1}us p50<{:.0}us p99<{:.0}us p999<{:.0}us",
            m.model, m.n, m.queue_mean_us, m.infer_mean_us, m.p50_us, m.p99_us, m.p999_us
        );
    }
    println!(
        "sheds: deadline={} queue-full={} | failures: engine={} panics={} degraded={} | fallback-served={} | breaker: open={} half-open={} closed={} | respawns={}",
        snap.deadline_sheds,
        snap.queue_full_sheds,
        snap.engine_failures,
        snap.engine_panics,
        snap.degraded,
        snap.fallback_served,
        snap.breaker_opens,
        snap.breaker_half_opens,
        snap.breaker_closes,
        snap.worker_respawns
    );
    println!(
        "shards: steals={} ejects={} probes={} readmits={} drains={} stopped={}",
        snap.steals,
        snap.shard_ejects,
        snap.shard_probes,
        snap.shard_readmits,
        snap.shard_drains,
        snap.stopped_replies
    );
    println!(
        "net: connections={} frames={} replies={} bad-frames={} dropped-conns={} unknown-rejects={} | steal-policy={}",
        snap.net_connections,
        snap.net_frames,
        snap.net_replies,
        snap.net_bad_frames,
        snap.net_dropped_conns,
        snap.net_unknown_rejects,
        steal_policy.name()
    );
    println!(
        "batching: batched-infers={} batched-requests={} batch-mean={:.2} batch-size-max={}",
        snap.batched_infers,
        snap.batched_requests,
        snap.batch_size_mean(),
        snap.batch_size_max
    );
    for s in &snap.shards {
        println!(
            "  shard {}: handled={} failed={} stolen-from={} stolen-by={} respawns={} ejects={} readmits={} drains={}",
            s.idx, s.handled, s.failed, s.stolen_from, s.stolen_by, s.respawns, s.ejects, s.readmits, s.drains
        );
    }
    if let Some(s) = snap.sickest_shard() {
        println!("  sickest shard: {} (sickness score {})", s.idx, s.sickness());
    }
    Ok(0)
}

pub fn platforms(_args: &Args) -> Result<i32> {
    println!("Simulated CPU platforms (rates calibrated on paper Table IV, ball = 16352 MACs):\n");
    for p in paper_platforms() {
        println!(
            "  {:<22} {:.2} GHz | NNCG {:.3} GMAC/s | XLA {} | Glow {}",
            p.name,
            p.freq_ghz,
            p.nncg_gmacs,
            p.xla_gmacs.map(|v| format!("{v:.3} GMAC/s")).unwrap_or_else(|| "N/A".into()),
            p.glow_gmacs.map(|v| format!("{v:.3} GMAC/s")).unwrap_or_else(|| "N/A".into()),
        );
    }
    let gpu = GpuModel::gtx_1050();
    println!(
        "\n  {:<22} overhead {:.0}us | PCIe {:.0} GB/s | peak {:.0} GMAC/s | batch-1 eff {:.1}%",
        gpu.name,
        gpu.overhead_us,
        gpu.pcie_gbps,
        gpu.peak_gmacs,
        gpu.batch1_efficiency * 100.0
    );
    println!("\nPer-model predictions (µs):");
    for name in crate::graph::zoo::PAPER_MODELS {
        let m = load_model(name, &experiments::default_weights_dir())?;
        let macs = m.macs()?;
        print!("  {name:<11} ({macs:>8} MACs)");
        for p in paper_platforms() {
            let v = p.predict_us(EngineKind::Nncg, macs).unwrap();
            print!("  {}={v:.1}", p.name.split_whitespace().last().unwrap_or("?"));
        }
        println!();
    }
    Ok(0)
}

pub fn export_figures(args: &Args) -> Result<i32> {
    let out = PathBuf::from(args.get_or("out", "figures"));
    let which = args.positional.first().map(|s| s.as_str()).unwrap_or("all");
    let mut rng = XorShift64::new(2020);

    if which == "fig1" || which == "all" {
        // Fig. 1: three positive + three negative ball patches.
        for i in 0..3 {
            render::write_pgm(&render::ball_patch(true, &mut rng), &out.join(format!("fig1_pos{i}.pgm")))?;
            render::write_pgm(&render::ball_patch(false, &mut rng), &out.join(format!("fig1_neg{i}.pgm")))?;
        }
        println!("fig1: wrote 6 ball patches to {}", out.display());
    }
    if which == "fig2" || which == "all" {
        for i in 0..3 {
            render::write_pgm(&render::pedestrian_patch(true, &mut rng), &out.join(format!("fig2_pos{i}.pgm")))?;
            render::write_pgm(&render::pedestrian_patch(false, &mut rng), &out.join(format!("fig2_neg{i}.pgm")))?;
        }
        println!("fig2: wrote 6 pedestrian patches to {}", out.display());
    }
    if which == "fig3" || which == "all" {
        // Fig. 3: a soccer scene with robots, plus the detector's boxes
        // burned in (white border) when the robot model is available.
        let (mut img, truth) = render::soccer_frame(60, 80, 1, 2, &mut rng);
        let model = load_model("robot", &weights_dir(args))?;
        let engine = build_engine(
            EngineKind::Nncg,
            &model,
            &CodegenOptions::sse3(),
            &experiments::default_artifacts_dir(),
            &experiments::default_work_dir(),
        )?;
        // model input is RGB [60,80,3]; tile grayscale to 3 channels
        let mut rgb = Tensor::zeros(&[60, 80, 3]);
        for i in 0..60 {
            for j in 0..80 {
                for k in 0..3 {
                    *rgb.at3_mut(i, j, k) = img.at3(i, j, 0);
                }
            }
        }
        let head = engine.infer(&rgb)?;
        let dets = crate::vision::yolo::decode(&head, &crate::vision::yolo::YoloConfig::default())?;
        for d in dets.iter().chain(truth.robots.iter()) {
            draw_box(&mut img, d);
        }
        render::write_pgm(&img, &out.join("fig3_robots.pgm"))?;
        println!("fig3: wrote annotated scene ({} detections, {} ground truth) to {}", dets.len(), truth.robots.len(), out.display());
    }
    Ok(0)
}

fn draw_box(img: &mut Tensor, d: &crate::vision::Detection) {
    let (h, w) = (img.dims()[0] as f32, img.dims()[1] as f32);
    let y0 = d.y.clamp(0.0, h - 1.0) as usize;
    let x0 = d.x.clamp(0.0, w - 1.0) as usize;
    let y1 = (d.y + d.h).clamp(0.0, h - 1.0) as usize;
    let x1 = (d.x + d.w).clamp(0.0, w - 1.0) as usize;
    for j in x0..=x1 {
        *img.at3_mut(y0, j, 0) = 1.0;
        *img.at3_mut(y1, j, 0) = 1.0;
    }
    for i in y0..=y1 {
        *img.at3_mut(i, x0, 0) = 1.0;
        *img.at3_mut(i, x1, 0) = 1.0;
    }
}

/// Deployment matrix check used by `examples/deploy_matrix.rs` and tests:
/// compile the generated C for each scenario the paper walks through.
/// (public so examples/deploy_matrix.rs and integration tests can reuse it)
pub fn deploy_matrix(model_name: &str) -> Result<Vec<(String, bool, String)>> {
    let model = load_model(model_name, &experiments::default_weights_dir())?;
    let driver = CcDriver::detect()?;
    let dir = experiments::default_work_dir().join("deploy");
    std::fs::create_dir_all(&dir)?;

    let mut results = Vec::new();
    let scenarios: Vec<(&str, CodegenOptions, CcTarget)> = vec![
        (
            "native -O3 (host, SSE)",
            CodegenOptions::sse3(),
            CcTarget::NativeShared,
        ),
        (
            "strict ANSI C89 (generic ISA)",
            CodegenOptions::general(),
            CcTarget::StrictAnsiCheck,
        ),
        (
            "32-bit target (-m32, Nao scenario)",
            CodegenOptions::general(),
            CcTarget::M32Check,
        ),
        (
            "retarget -march=x86-64 (J1900-style cross build)",
            CodegenOptions::general(),
            CcTarget::MarchCheck("x86-64"),
        ),
    ];
    for (label, opts, target) in scenarios {
        let src = generate_c(&model, &opts)?;
        let c_path = dir.join(format!("{}-{}.c", model.name, opts.tag()));
        std::fs::write(&c_path, &src)?;
        let out_so = dir.join(format!("{}-{}.so", model.name, opts.tag()));
        let result = match target {
            CcTarget::NativeShared => driver.compile(&c_path, Some(&out_so), target),
            _ => driver.compile(&c_path, None, target),
        };
        match result {
            Ok(()) => results.push((label.to_string(), true, String::new())),
            Err(e) => {
                let msg = e.to_string().lines().next().unwrap_or("").to_string();
                results.push((label.to_string(), false, msg));
            }
        }
    }
    // Sanity: native build must also load + run.
    let _ = CompiledCnn::build(&model, &CodegenOptions::sse3(), &dir)?;
    Ok(results)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &[&str]) -> Args {
        Args::parse(&s.iter().map(|x| x.to_string()).collect::<Vec<_>>()).unwrap()
    }

    #[test]
    fn opts_parsing() {
        let o = opts_from_args(&args(&["--isa", "generic", "--unroll", "full"])).unwrap();
        assert_eq!(o.isa, Isa::Generic);
        assert_eq!(o.unroll, Unroll::Full);
        assert_eq!(o.pad_mode, PadMode::Auto);
        assert_eq!(o.tile, TileMode::Auto);
        assert_eq!(o.dtype, DType::F32);
        assert_eq!(o.chan_pad, ChanPad::Auto);
        assert!(opts_from_args(&args(&["--isa", "avx512"])).is_err());
    }

    #[test]
    fn batch_knobs_parse() {
        // Default: latency-first, no adaptation.
        let (p, adapt) = batch_policy_from_args(&args(&[])).unwrap();
        assert_eq!(p.max_batch, 1);
        assert_eq!(p.max_wait, std::time::Duration::ZERO);
        assert!(!adapt);
        // --batch-max N enables batching, adaptive by default.
        let (p, adapt) = batch_policy_from_args(&args(&["--batch-max", "8"])).unwrap();
        assert_eq!(p.max_batch, 8);
        assert!(p.max_wait > std::time::Duration::ZERO);
        assert!(adapt);
        // --batch-adapt off pins the width.
        let (p, adapt) =
            batch_policy_from_args(&args(&["--batch-max", "4", "--batch-adapt", "off"])).unwrap();
        assert_eq!(p.max_batch, 4);
        assert!(!adapt);
        assert!(batch_policy_from_args(&args(&["--batch-max", "lots"])).is_err());
    }

    #[test]
    fn dtype_and_chan_pad_knobs_parse() {
        let o = opts_from_args(&args(&["--dtype", "int8"])).unwrap();
        assert_eq!(o.dtype, DType::Int8);
        assert!(o.tag().contains("dtint8"));
        let o = opts_from_args(&args(&["--chan-pad", "off"])).unwrap();
        assert_eq!(o.chan_pad, ChanPad::Off);
        assert!(o.tag().contains("cpoff"));
        // Defaults keep the pre-int8 byte-stable tags.
        let o = opts_from_args(&args(&[])).unwrap();
        assert!(!o.tag().contains("dtint8"));
        assert!(!o.tag().contains("cpoff"));
        assert!(opts_from_args(&args(&["--dtype", "int4"])).is_err());
        assert!(opts_from_args(&args(&["--chan-pad", "always"])).is_err());
        // neon-dot is reachable from the CLI (int8 SDOT row).
        let o = opts_from_args(&args(&["--isa", "neon-dot", "--dtype", "int8"])).unwrap();
        assert_eq!(o.isa, Isa::NeonDot);
        assert!(o.isa.is_neon());
    }

    #[test]
    fn pad_and_tile_knobs_parse() {
        let o = opts_from_args(&args(&["--pad-mode", "copy", "--tile", "off"])).unwrap();
        assert_eq!(o.pad_mode, PadMode::Copy);
        assert_eq!(o.tile, TileMode::Off);
        let o = opts_from_args(&args(&["--pad-mode", "padless", "--tile", "4"])).unwrap();
        assert_eq!(o.pad_mode, PadMode::Padless);
        assert_eq!(o.tile, TileMode::Fixed(4));
        assert!(opts_from_args(&args(&["--pad-mode", "mirror"])).is_err());
        assert!(opts_from_args(&args(&["--tile", "16"])).is_err());
    }

    #[test]
    fn neon_tile2d_and_align_knobs_parse() {
        let o = opts_from_args(&args(&["--isa", "neon", "--tile", "2x4", "--align", "off"])).unwrap();
        assert_eq!(o.isa, Isa::Neon);
        assert_eq!(o.tile, TileMode::Fixed2D(2, 4));
        assert_eq!(o.align, AlignMode::Off);
        assert!(!o.use_aligned());
        let o = opts_from_args(&args(&[])).unwrap();
        assert_eq!(o.align, AlignMode::Auto);
        assert!(opts_from_args(&args(&["--align", "force"])).is_err());
        assert!(opts_from_args(&args(&["--tile", "9x2"])).is_err());
        assert!(opts_from_args(&args(&["--tile", "2x12"])).is_err());
    }

    #[test]
    fn fuse_and_vfpv3_knobs_parse() {
        let o = opts_from_args(&args(&[])).unwrap();
        assert_eq!(o.fuse, FuseMode::Off);
        assert_eq!(o.fuse_rolled, RolledMode::Auto);
        let o = opts_from_args(&args(&["--fuse", "auto"])).unwrap();
        assert_eq!(o.fuse, FuseMode::Auto);
        assert_eq!(o.fuse_rolled, RolledMode::Auto);
        let o = opts_from_args(&args(&["--fuse", "auto", "--fuse-rolled", "off"])).unwrap();
        assert_eq!(o.fuse_rolled, RolledMode::Off);
        let o = opts_from_args(&args(&["--fuse", "auto", "--fuse-rolled", "rotate"])).unwrap();
        assert_eq!(o.fuse_rolled, RolledMode::Rotate);
        let o = opts_from_args(&args(&["--fuse", "auto", "--fuse-rolled", "expand"])).unwrap();
        assert_eq!(o.fuse_rolled, RolledMode::Expand);
        assert!(opts_from_args(&args(&["--fuse-rolled", "sometimes"])).is_err());
        let o = opts_from_args(&args(&["--fuse", "3"])).unwrap();
        assert_eq!(o.fuse, FuseMode::Depth(3));
        assert!(opts_from_args(&args(&["--fuse", "16"])).is_err());
        assert!(opts_from_args(&args(&["--fuse", "rings"])).is_err());
        let o = opts_from_args(&args(&["--isa", "neon-vfpv3"])).unwrap();
        assert_eq!(o.isa, Isa::NeonVfpv3);
        assert!(o.isa.is_neon());
    }

    #[test]
    fn verify_rejects_neon_on_foreign_hosts() {
        if cfg!(any(target_arch = "aarch64", target_arch = "arm")) {
            return; // NEON executes natively there
        }
        let err = verify(&args(&["--model", "tiny", "--isa", "neon"])).unwrap_err();
        assert!(format!("{err:#}").contains("neon"), "{err:#}");
        // The dotprod flavor is equally ARM-only.
        let err = verify(&args(&["--model", "tiny", "--isa", "neon-dot", "--dtype", "int8"])).unwrap_err();
        assert!(format!("{err:#}").contains("neon-dot"), "{err:#}");
    }

    #[test]
    fn describe_runs() {
        assert_eq!(describe(&args(&["--model", "ball"])).unwrap(), 0);
    }

    #[test]
    fn generate_to_file() {
        let out = std::env::temp_dir().join("nncg-cli-gen.c");
        let code = generate(&args(&["--model", "ball", "-o", out.to_str().unwrap()])).unwrap();
        assert_eq!(code, 0);
        let src = std::fs::read_to_string(&out).unwrap();
        assert!(src.contains("ball_inference"));
    }

    #[test]
    fn verify_ball_passes() {
        let code = verify(&args(&["--model", "tiny", "--trials", "2"])).unwrap();
        assert_eq!(code, 0);
    }

    #[test]
    fn deploy_matrix_native_and_ansi_succeed() {
        let results = deploy_matrix("ball").unwrap();
        let native = results.iter().find(|(l, _, _)| l.starts_with("native")).unwrap();
        assert!(native.1, "{:?}", native);
        let ansi = results.iter().find(|(l, _, _)| l.contains("ANSI")).unwrap();
        assert!(ansi.1, "generic output must be strict ANSI C89: {}", ansi.2);
    }
}
