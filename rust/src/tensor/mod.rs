//! Dense f32 tensors in HWC layout (batch size is always 1 — the paper's
//! whole point is single-image latency on embedded CPUs).
//!
//! Layout convention throughout the crate:
//! * activations: `[h, w, c]`, C innermost (channel-minor) — this is what the
//!   paper's SIMD-over-output-channels principle (§II-A.4) requires, and it
//!   matches Keras/JAX NHWC.
//! * conv weights: `[h_k, w_k, c_in, c_out]` (HWIO), `c_out` innermost.

mod shape;
pub use shape::Shape;

use crate::util::XorShift64;
use anyhow::{bail, Result};

/// A dense f32 tensor with up to 4 dimensions.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    shape: Shape,
    data: Vec<f32>,
}

impl Tensor {
    /// Zero-filled tensor.
    pub fn zeros(dims: &[usize]) -> Self {
        let shape = Shape::new(dims);
        let n = shape.numel();
        Tensor { shape, data: vec![0.0; n] }
    }

    /// Tensor from a flat vec; length must match the shape product.
    pub fn from_vec(dims: &[usize], data: Vec<f32>) -> Result<Self> {
        let shape = Shape::new(dims);
        if shape.numel() != data.len() {
            bail!("shape {:?} wants {} elements, got {}", dims, shape.numel(), data.len());
        }
        Ok(Tensor { shape, data })
    }

    /// Uniformly random tensor in [lo, hi), deterministic in the seed.
    pub fn rand(dims: &[usize], lo: f32, hi: f32, rng: &mut XorShift64) -> Self {
        let shape = Shape::new(dims);
        let data = (0..shape.numel()).map(|_| rng.uniform(lo, hi)).collect();
        Tensor { shape, data }
    }

    /// Glorot-uniform initialized tensor (fan_in/fan_out from first/last dims
    /// for dense, receptive-field-aware for 4-d conv weights).
    pub fn glorot(dims: &[usize], rng: &mut XorShift64) -> Self {
        let (fan_in, fan_out) = match dims.len() {
            4 => {
                let rf = dims[0] * dims[1];
                (rf * dims[2], rf * dims[3])
            }
            2 => (dims[0], dims[1]),
            _ => {
                let n = dims.iter().product::<usize>().max(1);
                (n, n)
            }
        };
        let limit = (6.0 / (fan_in + fan_out) as f32).sqrt();
        Self::rand(dims, -limit, limit, rng)
    }

    pub fn shape(&self) -> &Shape {
        &self.shape
    }

    pub fn dims(&self) -> &[usize] {
        self.shape.dims()
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn into_vec(self) -> Vec<f32> {
        self.data
    }

    /// Index into a 3-d `[h, w, c]` tensor.
    #[inline]
    pub fn at3(&self, i: usize, j: usize, k: usize) -> f32 {
        let d = self.shape.dims();
        debug_assert_eq!(d.len(), 3);
        self.data[(i * d[1] + j) * d[2] + k]
    }

    /// Mutable index into a 3-d `[h, w, c]` tensor.
    #[inline]
    pub fn at3_mut(&mut self, i: usize, j: usize, k: usize) -> &mut f32 {
        let d = self.shape.dims();
        debug_assert_eq!(d.len(), 3);
        let idx = (i * d[1] + j) * d[2] + k;
        &mut self.data[idx]
    }

    /// Index into a 4-d `[h_k, w_k, c_in, c_out]` weight tensor.
    #[inline]
    pub fn at4(&self, n: usize, m: usize, o: usize, k: usize) -> f32 {
        let d = self.shape.dims();
        debug_assert_eq!(d.len(), 4);
        self.data[((n * d[1] + m) * d[2] + o) * d[3] + k]
    }

    /// Reshape in place (same element count).
    pub fn reshape(&mut self, dims: &[usize]) -> Result<()> {
        let s = Shape::new(dims);
        if s.numel() != self.data.len() {
            bail!("cannot reshape {} elements to {:?}", self.data.len(), dims);
        }
        self.shape = s;
        Ok(())
    }

    /// Maximum absolute difference against another tensor of the same shape.
    pub fn max_abs_diff(&self, other: &Tensor) -> Result<f32> {
        if self.shape != other.shape {
            bail!("shape mismatch: {:?} vs {:?}", self.dims(), other.dims());
        }
        Ok(self
            .data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max))
    }

    /// Relative L2 error ‖a−b‖ / max(‖b‖, ε).
    pub fn rel_l2(&self, other: &Tensor) -> Result<f32> {
        if self.shape != other.shape {
            bail!("shape mismatch: {:?} vs {:?}", self.dims(), other.dims());
        }
        let num: f32 = self
            .data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b) * (a - b))
            .sum::<f32>()
            .sqrt();
        let den: f32 = other.data.iter().map(|b| b * b).sum::<f32>().sqrt().max(1e-12);
        Ok(num / den)
    }

    /// Argmax over the flat data (used on classifier logits/probs).
    pub fn argmax(&self) -> usize {
        self.data
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap_or(std::cmp::Ordering::Equal))
            .map(|(i, _)| i)
            .unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zeros_and_numel() {
        let t = Tensor::zeros(&[2, 3, 4]);
        assert_eq!(t.numel(), 24);
        assert!(t.data().iter().all(|&v| v == 0.0));
    }

    #[test]
    fn from_vec_rejects_bad_len() {
        assert!(Tensor::from_vec(&[2, 2], vec![1.0; 3]).is_err());
        assert!(Tensor::from_vec(&[2, 2], vec![1.0; 4]).is_ok());
    }

    #[test]
    fn indexing_is_channel_minor() {
        // [h=1, w=2, c=3]: data laid out (0,0,:) then (0,1,:).
        let t = Tensor::from_vec(&[1, 2, 3], vec![0., 1., 2., 10., 11., 12.]).unwrap();
        assert_eq!(t.at3(0, 0, 2), 2.0);
        assert_eq!(t.at3(0, 1, 0), 10.0);
    }

    #[test]
    fn at4_weight_layout() {
        // [1,1,2,2]: (o=0,k=0),(o=0,k=1),(o=1,k=0),(o=1,k=1)
        let t = Tensor::from_vec(&[1, 1, 2, 2], vec![1., 2., 3., 4.]).unwrap();
        assert_eq!(t.at4(0, 0, 0, 1), 2.0);
        assert_eq!(t.at4(0, 0, 1, 0), 3.0);
    }

    #[test]
    fn rand_deterministic_in_seed() {
        let mut r1 = XorShift64::new(1);
        let mut r2 = XorShift64::new(1);
        let a = Tensor::rand(&[4, 4], -1.0, 1.0, &mut r1);
        let b = Tensor::rand(&[4, 4], -1.0, 1.0, &mut r2);
        assert_eq!(a, b);
    }

    #[test]
    fn glorot_limit_respected() {
        let mut r = XorShift64::new(2);
        let t = Tensor::glorot(&[3, 3, 8, 16], &mut r);
        let limit = (6.0f32 / ((9 * 8 + 9 * 16) as f32)).sqrt();
        assert!(t.data().iter().all(|v| v.abs() <= limit));
    }

    #[test]
    fn diff_metrics() {
        let a = Tensor::from_vec(&[2], vec![1.0, 2.0]).unwrap();
        let b = Tensor::from_vec(&[2], vec![1.5, 2.0]).unwrap();
        assert!((a.max_abs_diff(&b).unwrap() - 0.5).abs() < 1e-6);
        assert!(a.rel_l2(&b).unwrap() > 0.0);
        let c = Tensor::zeros(&[3]);
        assert!(a.max_abs_diff(&c).is_err());
    }

    #[test]
    fn argmax_picks_largest() {
        let t = Tensor::from_vec(&[4], vec![0.1, 0.7, 0.15, 0.05]).unwrap();
        assert_eq!(t.argmax(), 1);
    }

    #[test]
    fn reshape_checks_numel() {
        let mut t = Tensor::zeros(&[2, 6]);
        assert!(t.reshape(&[3, 4]).is_ok());
        assert!(t.reshape(&[5]).is_err());
    }
}
