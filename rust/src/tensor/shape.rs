//! Shape type shared by tensors and the graph IR's shape inference.

use std::fmt;

/// A tensor shape (up to 4 dims in practice; stored as a small vec).
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct Shape {
    dims: Vec<usize>,
}

impl Shape {
    pub fn new(dims: &[usize]) -> Self {
        Shape { dims: dims.to_vec() }
    }

    pub fn dims(&self) -> &[usize] {
        &self.dims
    }

    pub fn rank(&self) -> usize {
        self.dims.len()
    }

    pub fn numel(&self) -> usize {
        self.dims.iter().product()
    }

    /// Height of an HWC shape.
    pub fn h(&self) -> usize {
        self.dims[0]
    }

    /// Width of an HWC shape.
    pub fn w(&self) -> usize {
        self.dims[1]
    }

    /// Channels of an HWC shape.
    pub fn c(&self) -> usize {
        self.dims[2]
    }
}

impl fmt::Display for Shape {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "[")?;
        for (i, d) in self.dims.iter().enumerate() {
            if i > 0 {
                write!(f, "x")?;
            }
            write!(f, "{d}")?;
        }
        write!(f, "]")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accessors() {
        let s = Shape::new(&[16, 16, 1]);
        assert_eq!((s.h(), s.w(), s.c()), (16, 16, 1));
        assert_eq!(s.numel(), 256);
        assert_eq!(s.rank(), 3);
    }

    #[test]
    fn display() {
        assert_eq!(Shape::new(&[3, 80, 60]).to_string(), "[3x80x60]");
    }
}
