//! NNCG code generation: trained CNN → single ANSI C file.
//!
//! This is the paper's contribution. The generated file contains one
//! function `void <name>_inference(const float *x_in, float *x_out)` with
//! **no dependencies** beyond `math.h` (softmax) and, in SSE mode, x86
//! intrinsics — so it cross-compiles to any ANSI C target.
//!
//! The four design principles (paper §II-A) map to:
//!
//! * **P1 loop unrolling** — [`Unroll`]: from keeping every loop
//!   (`Unroll::None`) to emitting one straight-line statement per MAC
//!   (`Unroll::Full`), with the paper's intermediate levels that keep the
//!   one/two outermost (spatial) loops.
//! * **P2 conditional moves** — (leaky) ReLU is emitted as a C ternary on
//!   the accumulator (scalar) or as `max(x, alpha*x)` (SSE `maxps`), never
//!   as an `if`.
//! * **P3 constants** — weights are printed into the expression text
//!   ([`ConstMode::Inline`]) or as `static const` arrays
//!   ([`ConstMode::Array`]); zero-padding is resolved at generation time.
//!   In the default **padless** mode ([`PadMode`]) the generator splits
//!   each Same-padded conv into a branch-free interior region that indexes
//!   the source directly plus peeled border rows/columns whose
//!   out-of-bounds taps are dropped outright (they would multiply zeros),
//!   deleting the extra read+write pass and the `nncg_pad` scratch buffer
//!   of the legacy copy mode ([`PadMode::Copy`], Eq. 1's x̂) entirely.
//! * **P4 SIMD** — [`Isa::Sse3`] vectorizes over the output-channel
//!   dimension (channel-minor layout, exactly the paper's scheme);
//!   [`Isa::Avx2`] and [`Isa::Neon`] implement the paper's stated future
//!   work through a table-driven intrinsic vocabulary (`simd::OpTable`) —
//!   every emitter speaks abstract ops, so an ISA is one table row.
//!   Channel counts that do not divide the lane width no longer fall back
//!   to scalar code: a *lane schedule* covers them with full-width vector
//!   groups, then narrower vectors (SSE under AVX2), then scalar
//!   remainder lanes.
//!
//! Beyond the paper, interior cells are **register-tiled** ([`TileMode`],
//! `--tile`): a 1-D column block or 2-D `RxC` row×column block of output
//! pixels shares one weight-stationary register per tap — each weight
//! vector is materialized once per tap and FMA'd into every pixel's
//! accumulators — cutting weight loads by the block size. Generator-owned
//! buffers carry a 32-byte alignment attribute ([`AlignMode`], `--align`)
//! and provably-aligned vector accesses use the aligned intrinsic forms.
//! `codegen/schedule.rs` picks the block shape, padding strategy, and
//! alignment proofs per layer from its geometry and [`CodegenOptions`].

mod activation;
mod conv;
mod cwriter;
mod dense;
mod depthwise;
mod harness;
mod pool;
mod qemit;
mod schedule;
mod simd;

pub use cwriter::{c_ident, fmt_f32, CWriter};

use crate::graph::{Activation, Layer, Model};
use crate::tensor::Shape;
use anyhow::{bail, Result};

/// Instruction-set target for generated code.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Isa {
    /// Pure ANSI C — compiles anywhere (the paper's "general architecture").
    Generic,
    /// x86 SSE/SSSE3 intrinsics, 4-wide f32 over output channels.
    Sse3,
    /// x86 AVX2+FMA, 8-wide f32 over output channels (the paper's stated
    /// future work: "an extension of NNCG to other instruction sets like
    /// AVX ... can be realized rapidly").
    Avx2,
    /// ARM NEON (`arm_neon.h`), 4-wide f32 over output channels with fused
    /// `vfmaq_f32` — the hardware the paper actually deploys on (Nao
    /// robots, ARM SoCs). NEON has no lane-literal constructor, so this
    /// ISA always places weights in `static const` arrays
    /// ([`ConstMode::Array`]); `vld1q_f32` loads have no alignment
    /// requirement, so the aligned/unaligned split collapses.
    Neon,
    /// ARM NEON for pre-VFPv4 ARMv7 cores (Cortex-A8/A9-era): identical
    /// vocabulary except the multiply-accumulate is the non-fused
    /// `vmlaq_f32` (`vfmaq_f32` needs VFPv4). Same Array-only constants
    /// and alignment-agnostic loads as [`Isa::Neon`].
    NeonVfpv3,
    /// ARMv8.2+dotprod NEON: identical f32 vocabulary to [`Isa::Neon`],
    /// but the int8 path (`--dtype int8`) uses the SDOT instruction
    /// (`vdotq_s32`, 4 int8×int8 products per int32 lane per step)
    /// instead of the widening `vmlal_s16` baseline.
    NeonDot,
}

impl Isa {
    pub fn name(&self) -> &'static str {
        match self {
            Isa::Generic => "generic",
            Isa::Sse3 => "sse3",
            Isa::Avx2 => "avx2",
            Isa::Neon => "neon",
            Isa::NeonVfpv3 => "neon-vfpv3",
            Isa::NeonDot => "neon-dot",
        }
    }

    pub fn from_name(s: &str) -> Option<Isa> {
        Some(match s {
            "generic" => Isa::Generic,
            "sse3" => Isa::Sse3,
            "avx2" => Isa::Avx2,
            "neon" => Isa::Neon,
            "neon-vfpv3" => Isa::NeonVfpv3,
            "neon-dot" => Isa::NeonDot,
            _ => return None,
        })
    }

    /// True for the ARM NEON family (any multiply-accumulate flavor).
    pub fn is_neon(&self) -> bool {
        matches!(self, Isa::Neon | Isa::NeonVfpv3 | Isa::NeonDot)
    }
}

/// Loop unrolling level (paper §II-A.1: "level 0 all loops are unrolled,
/// level 1 does not unroll the outermost loop and so forth").
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Unroll {
    /// Keep every loop; weights live in `static const` arrays.
    None,
    /// Keep the two outer (spatial) loops, unroll kernel/channel loops.
    KeepOuter2,
    /// Keep only the outermost (row) loop.
    KeepOuter1,
    /// Unroll everything into straight-line code.
    Full,
}

impl Unroll {
    /// True if the spatial column loop is kept.
    pub fn keeps_cols(&self) -> bool {
        matches!(self, Unroll::None | Unroll::KeepOuter2)
    }

    /// True if the spatial row loop is kept.
    pub fn keeps_rows(&self) -> bool {
        !matches!(self, Unroll::Full)
    }

    /// True if the inner (kernel/channel) loops are kept.
    pub fn keeps_inner(&self) -> bool {
        matches!(self, Unroll::None)
    }

    pub fn name(&self) -> &'static str {
        match self {
            Unroll::None => "none",
            Unroll::KeepOuter2 => "keep-outer-2",
            Unroll::KeepOuter1 => "keep-outer-1",
            Unroll::Full => "full",
        }
    }

    pub fn from_name(s: &str) -> Option<Unroll> {
        Some(match s {
            "none" => Unroll::None,
            "keep-outer-2" | "2" => Unroll::KeepOuter2,
            "keep-outer-1" | "1" => Unroll::KeepOuter1,
            "full" | "0" => Unroll::Full,
            _ => return None,
        })
    }
}

/// Where weight constants go (principle P3).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ConstMode {
    /// Printed directly into the expressions (needs unrolled inner loops).
    Inline,
    /// `static const float` arrays indexed in the loops.
    Array,
}

impl ConstMode {
    pub fn name(&self) -> &'static str {
        match self {
            ConstMode::Inline => "inline",
            ConstMode::Array => "array",
        }
    }

    pub fn from_name(s: &str) -> Option<ConstMode> {
        Some(match s {
            "inline" => ConstMode::Inline,
            "array" => ConstMode::Array,
            _ => return None,
        })
    }
}

/// Zero-padding strategy for Same-padded conv/depthwise layers
/// (`--pad-mode`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PadMode {
    /// Padless whenever the unroll level allows it (everything except
    /// `Unroll::None`, whose kernel loops stay symbolic).
    Auto,
    /// Always materialize the zero-padded input (Eq. 1) into `nncg_pad` —
    /// the paper's original scheme; one extra read+write pass per layer.
    Copy,
    /// Region-split padless emission (falls back to the copy only for
    /// `Unroll::None`).
    Padless,
}

impl PadMode {
    pub fn name(&self) -> &'static str {
        match self {
            PadMode::Auto => "auto",
            PadMode::Copy => "copy",
            PadMode::Padless => "padless",
        }
    }

    pub fn from_name(s: &str) -> Option<PadMode> {
        Some(match s {
            "auto" => PadMode::Auto,
            "copy" => PadMode::Copy,
            "padless" => PadMode::Padless,
            _ => return None,
        })
    }
}

/// Register-tiling knob (`--tile`): how many interior output pixels share
/// one weight-stationary register tile in conv-like layers. `RxC` syntax
/// grows a row dimension: a 2-D block of `R` interior rows × `C` interior
/// columns shares every materialized weight vector across all `R*C`
/// accumulator sets.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TileMode {
    /// Pick per layer from geometry (4 columns when the interior is wide
    /// enough, else 2, else untiled; always untiled without vector lanes).
    Auto,
    /// Never tile (one output pixel at a time — the paper's scheme).
    Off,
    /// Force a 1-D column-block width (clamped to 1..=8).
    Fixed(usize),
    /// Force a 2-D register block: (rows, cols). Rows clamp to 2..=4 and
    /// apply only when the unroll level keeps the spatial row loop.
    Fixed2D(usize, usize),
}

impl TileMode {
    pub fn name(&self) -> String {
        match self {
            TileMode::Auto => "auto".to_string(),
            TileMode::Off => "off".to_string(),
            TileMode::Fixed(n) => n.to_string(),
            TileMode::Fixed2D(r, c) => format!("{r}x{c}"),
        }
    }

    pub fn from_name(s: &str) -> Option<TileMode> {
        Some(match s {
            "auto" => TileMode::Auto,
            "off" | "1" => TileMode::Off,
            other => {
                if let Some((r, c)) = other.split_once('x') {
                    let r = r.parse::<usize>().ok().filter(|&r| (1..=4).contains(&r))?;
                    let c = c.parse::<usize>().ok().filter(|&c| (2..=8).contains(&c))?;
                    // `1xC` is just a 1-D block; normalize so
                    // `from_name(name()) == Some(self)` round-trips.
                    if r == 1 {
                        TileMode::Fixed(c)
                    } else {
                        TileMode::Fixed2D(r, c)
                    }
                } else {
                    TileMode::Fixed(other.parse::<usize>().ok().filter(|&n| (2..=8).contains(&n))?)
                }
            }
        })
    }
}

/// Buffer-alignment knob (`--align`): whether scratch buffers and weight
/// arrays carry a 32-byte alignment attribute (`NNCG_ALIGN`, degrading to
/// nothing under compilers without one) and vector loads/stores whose
/// address is provably aligned use the aligned intrinsic forms.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AlignMode {
    /// Align generator-owned buffers and use aligned ops where provable
    /// (caller pointers `x_in`/`x_out` always stay unaligned).
    Auto,
    /// Paper-baseline behavior: no alignment attributes, `loadu`/`storeu`
    /// everywhere.
    Off,
}

impl AlignMode {
    pub fn name(&self) -> &'static str {
        match self {
            AlignMode::Auto => "auto",
            AlignMode::Off => "off",
        }
    }

    pub fn from_name(s: &str) -> Option<AlignMode> {
        Some(match s {
            "auto" => AlignMode::Auto,
            "off" => AlignMode::Off,
            _ => return None,
        })
    }
}

/// Cross-layer row-streaming fusion (`--fuse`): whether consecutive
/// stride-compatible conv/depthwise/pool/activation layers share one
/// rolling row schedule with **ring line buffers** between them instead of
/// whole-plane ping-pong scratch. Inside a group each producer computes
/// only the rows its consumer needs next; an intermediate edge then costs
/// O(k_h·W·C) static floats instead of O(H·W·C), and every intermediate
/// row stays cache-resident. Ring slot indices (`row % rows`) are resolved
/// at generation time — the emitted C contains no runtime `%`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FuseMode {
    /// Fuse every eligible chain, depth-capped at 4 and split by the
    /// statement budget that keeps each group's unrolled row schedule
    /// compiler-friendly.
    Auto,
    /// Paper-baseline emission: every layer computes its whole plane.
    Off,
    /// Fuse with an explicit maximum group depth (2..=8).
    Depth(usize),
}

impl FuseMode {
    /// Maximum number of layers one fusion group may span.
    pub fn max_depth(&self) -> usize {
        match self {
            FuseMode::Auto => 4,
            FuseMode::Off => 1,
            FuseMode::Depth(n) => *n,
        }
    }

    pub fn name(&self) -> String {
        match self {
            FuseMode::Auto => "auto".to_string(),
            FuseMode::Off => "off".to_string(),
            FuseMode::Depth(n) => n.to_string(),
        }
    }

    pub fn from_name(s: &str) -> Option<FuseMode> {
        Some(match s {
            "auto" => FuseMode::Auto,
            // Depth 1 is "every group is a single layer" — plain emission.
            "off" | "1" => FuseMode::Off,
            other => {
                FuseMode::Depth(other.parse::<usize>().ok().filter(|n| (2..=8).contains(n))?)
            }
        })
    }
}

/// Steady-state rolled emission of fused row schedules (`--fuse-rolled`).
///
/// The row schedule of a fusion group is eventually periodic: after a
/// warm-up prologue, the per-row op pattern repeats with a fixed period.
/// The rolled forms emit prologue + a genuine C `for` loop over the
/// steady-state iterations + drain epilogue; they differ in how ring rows
/// are addressed inside the loop body:
///
/// * `Rotate` — **ring pointer rotation**: one `float *nncg_ring{i}_r{k}`
///   pointer per live ring row, the body addresses kernel rows through
///   those pointers, and the loop bottom rotates the pointer set with
///   straight-line assignments. The row→pointer mapping is
///   iteration-invariant for *any* period, so the body holds exactly one
///   op-pattern period — no ring-phase expansion — and warm-up/drain runs
///   whose ops form a constant-delta ramp roll into loops of their own
///   (`schedule::detect_ramps`). Still no runtime `%`.
/// * `Expand` — the ring-phase-expanded body (`schedule::detect_periodic`):
///   ring offsets are frozen at iteration 0, which forces the body to
///   carry one pattern copy per ring phase (up to 64×). Kept as the
///   rotated form's differential baseline.
/// * `Auto` (default) — rotation when it verifies, else phase expansion.
/// * `Off` — the fully unrolled row schedule of the same groups (one
///   statement block per output row) — the PR 3 emission form.
///
/// The fusion-group partition (and therefore every buffer) is identical
/// across all four modes, which is what keeps them bit-comparable.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RolledMode {
    /// Roll the steady state whenever a period is detected, preferring
    /// pointer rotation (default).
    Auto,
    /// Always unroll the row schedule (debug/ablation baseline; large
    /// models emit very large C files at full fusion depth).
    Off,
    /// Require ring pointer rotation (falls back to unrolled emission for
    /// groups whose schedule never settles).
    Rotate,
    /// Require the phase-expanded body (the PR 4 form; differential
    /// baseline for the rotated emission). Groups whose phase count
    /// exceeds the 64x expansion cap fall back to unrolled emission of
    /// the same group — the partition never depends on the knob.
    Expand,
}

impl RolledMode {
    pub fn name(&self) -> &'static str {
        match self {
            RolledMode::Auto => "auto",
            RolledMode::Off => "off",
            RolledMode::Rotate => "rotate",
            RolledMode::Expand => "expand",
        }
    }

    pub fn from_name(s: &str) -> Option<RolledMode> {
        Some(match s {
            "auto" => RolledMode::Auto,
            "off" => RolledMode::Off,
            "rotate" => RolledMode::Rotate,
            "expand" => RolledMode::Expand,
            _ => return None,
        })
    }
}

/// Numeric emission domain (`--dtype`).
///
/// `Int8` switches the whole generated artifact to post-training
/// symmetric quantization: a [`crate::passes::QuantPlan`] is computed
/// from a deterministic calibration batch run through the interpreter,
/// weights are emitted as quantized integer arrays, activations flow as
/// `signed char` planes/rings, accumulation is int32, and the int32 →
/// int8 **requantization (multiply-shift, no float)** happens only at
/// fusion-group boundaries — inside a group the data stays int8 end to
/// end through the ring/rolled machinery. Float appears exactly twice:
/// quantizing `x_in` on entry and dequantizing into `x_out` on exit.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    /// f32 emission (default; the paper's numeric domain).
    F32,
    /// int8 symmetric quantized emission.
    Int8,
}

impl DType {
    pub fn name(&self) -> &'static str {
        match self {
            DType::F32 => "f32",
            DType::Int8 => "int8",
        }
    }

    pub fn from_name(s: &str) -> Option<DType> {
        Some(match s {
            "f32" => DType::F32,
            "int8" => DType::Int8,
            _ => return None,
        })
    }
}

/// Channel-stride padding of ring line buffers (`--chan-pad`).
///
/// Under `Auto` (default) each ring row's element stride is rounded up
/// to a whole vector group (8 floats / 32 int8 lanes), so odd channel
/// counts keep 32-byte-aligned row starts — the alignment prover can
/// then use aligned loads on every ring row, not just those whose
/// natural `w*c` happens to divide the group. Only takes effect when
/// alignment is on ([`AlignMode::Auto`]); the pad tail is never read or
/// written. `Off` keeps exact `w*c` row strides.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ChanPad {
    /// Round ring row strides up to a vector group (default).
    Auto,
    /// Exact row strides (pre-PR-8 layout).
    Off,
}

impl ChanPad {
    pub fn name(&self) -> &'static str {
        match self {
            ChanPad::Auto => "auto",
            ChanPad::Off => "off",
        }
    }

    pub fn from_name(s: &str) -> Option<ChanPad> {
        Some(match s {
            "auto" => ChanPad::Auto,
            "off" => ChanPad::Off,
            _ => return None,
        })
    }
}

/// Code generation options.
#[derive(Debug, Clone)]
pub struct CodegenOptions {
    pub isa: Isa,
    pub unroll: Unroll,
    /// `None` picks the paper default: inline when inner loops are
    /// unrolled, array otherwise.
    pub const_mode: Option<ConstMode>,
    /// Skip multiply-adds whose weight is exactly 0.0 (only possible with
    /// inline constants; free sparsity from the generator's knowledge).
    pub skip_zero_weights: bool,
    /// Refuse to generate more than this many statements (a full unroll of
    /// a big net produces C files compilers choke on — the paper's
    /// MobileNetV2 anecdote).
    pub max_statements: usize,
    /// Append a self-contained `main()` benchmark/test harness.
    pub test_harness: bool,
    /// Zero-padding strategy for Same-padded layers.
    pub pad_mode: PadMode,
    /// Register-tiling of interior output columns (or rows × columns).
    pub tile: TileMode,
    /// Buffer alignment + aligned-load selection.
    pub align: AlignMode,
    /// Cross-layer row-streaming fusion with ring line buffers.
    pub fuse: FuseMode,
    /// Steady-state rolled emission of fused row schedules.
    pub fuse_rolled: RolledMode,
    /// Numeric emission domain (f32 or symmetric int8).
    pub dtype: DType,
    /// Ring row-stride padding to whole vector groups.
    pub chan_pad: ChanPad,
}

impl Default for CodegenOptions {
    fn default() -> Self {
        CodegenOptions {
            isa: Isa::Sse3,
            unroll: Unroll::KeepOuter2,
            const_mode: None,
            skip_zero_weights: true,
            max_statements: 2_000_000,
            test_harness: false,
            pad_mode: PadMode::Auto,
            tile: TileMode::Auto,
            align: AlignMode::Auto,
            fuse: FuseMode::Off,
            fuse_rolled: RolledMode::Auto,
            dtype: DType::F32,
            chan_pad: ChanPad::Auto,
        }
    }
}

impl CodegenOptions {
    /// Table VII column 1: generic ISA, outer loops kept.
    pub fn general() -> Self {
        CodegenOptions { isa: Isa::Generic, unroll: Unroll::KeepOuter2, ..Default::default() }
    }

    /// Table VII column 2: SSE, outer loops kept.
    pub fn sse3() -> Self {
        CodegenOptions { isa: Isa::Sse3, unroll: Unroll::KeepOuter2, ..Default::default() }
    }

    /// Table VII column 3: SSE + full unroll.
    pub fn sse3_full_unroll() -> Self {
        CodegenOptions { isa: Isa::Sse3, unroll: Unroll::Full, ..Default::default() }
    }

    /// AVX2+FMA, outer loops kept (the paper's future-work ISA).
    pub fn avx2() -> Self {
        CodegenOptions { isa: Isa::Avx2, unroll: Unroll::KeepOuter2, ..Default::default() }
    }

    /// The paper's original emission scheme: pad-copy buffers, no tiling,
    /// no alignment machinery. Used as the ablation baseline.
    pub fn paper_baseline(isa: Isa) -> Self {
        CodegenOptions {
            isa,
            pad_mode: PadMode::Copy,
            tile: TileMode::Off,
            align: AlignMode::Off,
            ..Default::default()
        }
    }

    /// Effective constant mode (resolves the paper default).
    ///
    /// NEON always resolves to [`ConstMode::Array`]: the ISA has no
    /// lane-literal constructor (`_mm_setr_ps` counterpart), so vector
    /// weights must be loadable from addressable arrays — which is also
    /// what an embedded icache wants.
    pub fn effective_const_mode(&self) -> ConstMode {
        if self.isa.is_neon() {
            return ConstMode::Array;
        }
        self.const_mode.unwrap_or(match self.unroll {
            Unroll::None => ConstMode::Array,
            _ => ConstMode::Inline,
        })
    }

    /// True when alignment attributes + aligned-op selection are on.
    pub fn use_aligned(&self) -> bool {
        self.align == AlignMode::Auto
    }

    /// Short tag used in cache keys and bench labels. The PR-8 knobs
    /// append suffixes only at their non-default settings, so every
    /// pre-existing configuration keeps a byte-stable tag.
    pub fn tag(&self) -> String {
        let mut tag = format!(
            "{}-{}-{}-pad{}-t{}-al{}-fu{}-fr{}",
            self.isa.name(),
            self.unroll.name(),
            self.effective_const_mode().name(),
            self.pad_mode.name(),
            self.tile.name(),
            self.align.name(),
            self.fuse.name(),
            self.fuse_rolled.name(),
        );
        if self.chan_pad == ChanPad::Off {
            tag.push_str("-cpoff");
        }
        if self.dtype == DType::Int8 {
            tag.push_str("-dtint8");
        }
        tag
    }
}

/// Per-layer emission context handed to the layer emitters.
pub(crate) struct LayerCtx<'a> {
    /// Layer index (names weight arrays `w{idx}` / `b{idx}`).
    pub idx: usize,
    /// Input shape of this layer.
    pub in_shape: &'a Shape,
    /// Output shape of this layer.
    pub out_shape: &'a Shape,
    /// C expression for the input buffer (e.g. `x_in`, `nncg_bufa`).
    pub src: &'a str,
    /// C expression for the output buffer.
    pub dst: &'a str,
    /// Name of the shared padding scratch buffer.
    pub padbuf: &'a str,
    pub opts: &'a CodegenOptions,
}

/// Generate the complete C source for a model.
///
/// Runs the standard pass pipeline (BN fold, dropout elision, activation
/// fusion) first, so callers can hand in the raw zoo/Keras-shaped model.
pub fn generate_c(model: &Model, opts: &CodegenOptions) -> Result<String> {
    let model = crate::passes::optimize(model.clone())?;
    let shapes = model.infer_shapes()?;

    // int8 emission is a parallel orchestration over the same fusion /
    // buffer machinery; it computes the QuantPlan and emits integer
    // bodies end to end.
    if opts.dtype == DType::Int8 {
        return qemit::generate_int8(&model, &shapes, opts);
    }

    // Derive-once fusion bundle: the group partition plus every group's
    // row plans, demand schedule and rolled emission plan. The cost guard,
    // the buffer planner and the emitters below all consume this single
    // instance — grouping and emission cannot disagree.
    let bundle = plan_fusion(&model, &shapes, opts)?;

    // Cost guard: estimate emitted statements before doing the work.
    let est = estimate_statements(&model, &shapes, opts, &bundle);
    if est > opts.max_statements {
        bail!(
            "unroll level {:?} would emit ~{est} statements for model {:?} (limit {}); \
             use a coarser unroll level",
            opts.unroll,
            model.name,
            opts.max_statements
        );
    }

    let ident = c_ident(&model.name);
    let mut w = CWriter::new();
    emit_prelude(&mut w, &model, &ident, opts, &shapes);

    // Buffer planning (liveness-aware): ping-pong scratch holds only
    // group-boundary planes; intermediates inside a fusion group live in
    // per-edge ring line buffers of a few rows each. Copy-mode padding
    // additionally needs a third buffer holding the zero-padded input
    // (Eq. 1's x̂); padless emission does not, shrinking the footprint.
    let plan = plan_buffers(&model, &shapes, opts, &bundle)?;
    let qual = if opts.use_aligned() { "NNCG_ALIGN(32) " } else { "" };
    w.line(&format!("static {qual}float nncg_bufa[{}];", plan.main_size.max(1)));
    w.line(&format!("static {qual}float nncg_bufb[{}];", plan.main_size.max(1)));
    if plan.pad_size > 0 {
        w.line(&format!("static {qual}float nncg_pad[{}];", plan.pad_size));
    }
    for r in &plan.rings {
        w.line(&format!(
            "static {qual}float nncg_ring{}[{}]; /* ring: {} rows of {} (layer {} -> {}) */",
            r.layer,
            r.floats.max(1),
            r.rows,
            r.row_elems,
            r.layer,
            r.layer + 1
        ));
    }
    w.blank();

    // Weight arrays (ConstMode::Array).
    if opts.effective_const_mode() == ConstMode::Array {
        for (i, layer) in model.layers.iter().enumerate() {
            emit_weight_arrays(&mut w, i, layer, qual);
        }
        w.blank();
    }

    w.line("/* Single-function CNN inference (paper's deployment model):");
    w.line(&format!(" * input:  float[{}] in HWC order {}", shapes[0].numel(), shapes[0]));
    w.line(&format!(" * output: float[{}] {}", shapes.last().unwrap().numel(), shapes.last().unwrap()));
    w.line(" */");
    w.open(&format!("void {ident}_inference(const float *x_in, float *x_out)"));
    if needs_loop_vars(opts) {
        w.line("int i, j, k, n, m, o;");
        w.line("(void)i; (void)j; (void)k; (void)n; (void)m; (void)o;");
    }

    let n_layers = model.layers.len();
    let mut cur_src: String = "x_in".to_string();
    let mut ping = true;
    for pg in &bundle.groups {
        let group = &pg.group;
        let is_last = group.end == n_layers;
        match &pg.fused {
            None => {
                let i = group.start;
                let layer = &model.layers[i];
                let dst = if is_last {
                    "x_out".to_string()
                } else if is_inplace(layer) && cur_src != "x_in" {
                    cur_src.clone()
                } else {
                    let d = if ping { "nncg_bufa" } else { "nncg_bufb" };
                    ping = !ping;
                    d.to_string()
                };
                let ctx = LayerCtx {
                    idx: i,
                    in_shape: &shapes[i],
                    out_shape: &shapes[i + 1],
                    src: &cur_src,
                    dst: &dst,
                    padbuf: "nncg_pad",
                    opts,
                };
                w.blank();
                w.line(&format!(
                    "/* layer {i}: {} {} -> {} */",
                    layer.kind_name(),
                    shapes[i],
                    shapes[i + 1]
                ));
                emit_layer(&mut w, layer, &ctx)?;
                cur_src = dst;
            }
            Some(fp) => {
                let dst = if is_last {
                    "x_out".to_string()
                } else {
                    let d = if ping { "nncg_bufa" } else { "nncg_bufb" };
                    ping = !ping;
                    d.to_string()
                };
                w.blank();
                w.line(&format!(
                    "/* fused group: layers {}..{} ({} -> {}) stream rows through ring line buffers */",
                    group.start,
                    group.end - 1,
                    shapes[group.start],
                    shapes[group.end]
                ));
                emit_fused_group(&mut w, &model, &shapes, group, fp, &cur_src, &dst, &plan, opts, None)?;
                cur_src = dst;
            }
        }
    }
    w.close();

    emit_batch_entry(&mut w, &ident);

    if opts.test_harness {
        harness::emit_test_harness(&mut w, &ident, shapes[0].numel(), shapes.last().unwrap().numel());
    }

    Ok(w.finish())
}

/// Emit the batched entry point `<ident>_inference_batch` (the paper-level
/// `nncg_cnn_batch` contract) right after the single-image function: a
/// plain C89 loop calling `<ident>_inference` per image, so the static
/// weight arrays stay hot in cache across images while every image's
/// output stays bit-identical to a single call. Shared by the f32 and
/// int8 emission paths.
pub(crate) fn emit_batch_entry(w: &mut CWriter, ident: &str) {
    let up = ident.to_uppercase();
    w.blank();
    w.line("/* Amortized multi-image entry point (the nncg_cnn_batch contract):");
    w.line(&format!(" * runs n images back-to-back through {ident}_inference, keeping the"));
    w.line(" * weight arrays cache-warm across images. Images are contiguous");
    w.line(&format!(" * {up}_INPUT_SIZE-float planes; results are contiguous"));
    w.line(&format!(" * {up}_OUTPUT_SIZE-float planes. Output is bit-identical to n"));
    w.line(" * single calls. */");
    w.open(&format!("void {ident}_inference_batch(const float *x_in, float *x_out, int n)"));
    w.line("int b;");
    w.open("for (b = 0; b < n; b++)");
    w.line(&format!(
        "{ident}_inference(x_in + {up}_INPUT_SIZE * b, x_out + {up}_OUTPUT_SIZE * b);"
    ));
    w.close();
    w.close();
}

/// True when the generated code needs the shared loop variables.
fn needs_loop_vars(opts: &CodegenOptions) -> bool {
    opts.unroll != Unroll::Full
}

/// Layers that may write over their own input buffer.
fn is_inplace(layer: &Layer) -> bool {
    matches!(layer, Layer::Activation(_) | Layer::Flatten)
}

fn emit_prelude(w: &mut CWriter, model: &Model, ident: &str, opts: &CodegenOptions, shapes: &[Shape]) {
    w.line("/*");
    w.line(&format!(" * {ident}.c — generated by NNCG (rust reimplementation)"));
    w.line(&format!(
        " * model: {} | isa: {:?} | unroll: {} | constants: {:?} | pad: {} | tile: {}",
        model.name,
        opts.isa,
        opts.unroll.name(),
        opts.effective_const_mode(),
        opts.pad_mode.name(),
        opts.tile.name(),
    ));
    w.line(&format!(" * params: {} | MACs/inference: {}", model.num_params(), model.macs().unwrap_or(0)));
    match opts.isa {
        Isa::Generic => w.line(" * Plain ANSI C — only depends on math.h."),
        Isa::Sse3 => w.line(" * ANSI C + x86 SSE intrinsics (needs an SSE-capable target)."),
        Isa::Avx2 => w.line(" * ANSI C + x86 AVX2/FMA intrinsics (needs an AVX2-capable target)."),
        Isa::Neon => w.line(" * ANSI C + ARM NEON intrinsics (AArch64 or ARMv7+VFPv4 for vfmaq_f32)."),
        Isa::NeonVfpv3 => w.line(" * ANSI C + ARM NEON intrinsics (ARMv7 pre-VFPv4: non-fused vmlaq_f32)."),
        Isa::NeonDot => w.line(" * ANSI C + ARM NEON intrinsics (ARMv8.2+dotprod: vdotq_s32 on the int8 path)."),
    }
    if opts.dtype == DType::Int8 {
        w.line(" * dtype: int8 — symmetric post-training quantization (per-channel");
        w.line(" *        conv weight scales); int32 accumulators with multiply-shift");
        w.line(" *        requantization at fusion-group boundaries; no float between");
        w.line(" *        the entry quantize and the exit dequantize planes.");
    }
    w.line(" */");
    let uses_softmax = model.layers.iter().any(|l| {
        matches!(l, Layer::Activation(Activation::Softmax))
            || matches!(l, Layer::Conv2D { activation: Activation::Softmax, .. })
            || matches!(l, Layer::Dense { activation: Activation::Softmax, .. })
    });
    if uses_softmax {
        w.line("#include <math.h>");
    }
    match opts.isa {
        Isa::Generic => {}
        Isa::Sse3 => w.line("#include <emmintrin.h>"),
        Isa::Avx2 => w.line("#include <immintrin.h>"),
        Isa::Neon | Isa::NeonVfpv3 | Isa::NeonDot => w.line("#include <arm_neon.h>"),
    }
    if opts.use_aligned() {
        w.blank();
        w.line("/* 32-byte alignment for generator-owned buffers. Degrades to");
        w.line(" * nothing under strict-ANSI compilers without an alignment");
        w.line(" * attribute — safe there because the generic ISA emits no");
        w.line(" * vector ops; vector ISAs imply __GNUC__ or _MSC_VER. */");
        w.line("#if defined(__GNUC__)");
        w.line("#define NNCG_ALIGN(n) __attribute__((aligned(n)))");
        w.line("#elif defined(_MSC_VER)");
        w.line("#define NNCG_ALIGN(n) __declspec(align(n))");
        w.line("#else");
        w.line("#define NNCG_ALIGN(n)");
        w.line("#endif");
    }
    w.blank();
    w.line(&format!("#define {}_INPUT_SIZE {}", ident.to_uppercase(), shapes[0].numel()));
    w.line(&format!("#define {}_OUTPUT_SIZE {}", ident.to_uppercase(), shapes.last().unwrap().numel()));
    w.blank();
}

/// Emit `static const float w{i}[] = {...}` / `b{i}` for Array mode.
/// `qual` carries the `NNCG_ALIGN(32)` qualifier when alignment is on.
fn emit_weight_arrays(w: &mut CWriter, idx: usize, layer: &Layer, qual: &str) {
    let mut emit = |name: String, data: &[f32]| {
        w.line(&format!("static {qual}const float {name}[{}] = {{", data.len()));
        for chunk in data.chunks(8) {
            let vals: Vec<String> = chunk.iter().map(|&v| fmt_f32(v)).collect();
            w.line(&format!("    {},", vals.join(", ")));
        }
        w.line("};");
    };
    match layer {
        Layer::Conv2D { weights, bias, .. }
        | Layer::Dense { weights, bias, .. }
        | Layer::DepthwiseConv2D { weights, bias, .. } => {
            emit(format!("w{idx}"), weights.data());
            emit(format!("b{idx}"), bias.data());
        }
        _ => {}
    }
}

fn emit_layer(w: &mut CWriter, layer: &Layer, ctx: &LayerCtx<'_>) -> Result<()> {
    match layer {
        Layer::Conv2D { weights, bias, stride, padding, activation } => {
            conv::emit_conv(w, ctx, weights, bias, *stride, *padding, *activation)
        }
        Layer::MaxPool2D { pool, stride } => pool::emit_maxpool(w, ctx, *pool, *stride),
        Layer::AvgPool2D { pool, stride } => depthwise::emit_avgpool(w, ctx, *pool, *stride),
        Layer::DepthwiseConv2D { weights, bias, stride, padding, activation } => {
            depthwise::emit_depthwise(w, ctx, weights, bias, *stride, *padding, *activation)
        }
        Layer::Activation(a) => activation::emit_activation(w, ctx, *a),
        Layer::Flatten => {
            // HWC is already flat; only copy if src/dst differ.
            if ctx.src != ctx.dst {
                activation::emit_copy(w, ctx);
            }
            Ok(())
        }
        Layer::Dense { weights, bias, activation } => dense::emit_dense(w, ctx, weights, bias, *activation),
        Layer::BatchNorm { .. } => bail!("BatchNorm must be folded before codegen (passes::optimize)"),
        Layer::Dropout { .. } => bail!("Dropout must be elided before codegen (passes::optimize)"),
    }
}

/// One ring line buffer: the output edge of fusion-group member `layer`
/// (global index), holding `rows` rows of `row_elems` floats each.
struct RingInfo {
    layer: usize,
    rows: usize,
    row_elems: usize,
    floats: usize,
}

struct BufferPlan {
    main_size: usize,
    pad_size: usize,
    rings: Vec<RingInfo>,
}

/// Round a float count up to a whole 32-byte (8-float) group so buffer
/// tails never share a vector-width line with unrelated data.
fn round_to_vec(n: usize) -> usize {
    crate::util::div_ceil(n, 8) * 8
}

/// Elements in one 32-byte vector group for the emission dtype (8 f32
/// lanes or 32 int8 lanes) — the `--chan-pad` rounding quantum.
fn dtype_quantum(dtype: DType) -> usize {
    match dtype {
        DType::F32 => 8,
        DType::Int8 => 32,
    }
}

/// Auto-fusion statement budget per group. Fused emission unrolls the row
/// schedule, so generated-code size (and C compile time) grows with
/// body×rows; chains are split so each group stays comfortably within
/// what a C compiler chews through in seconds at -O3.
const FUSE_GROUP_STMT_BUDGET: usize = 5_000;

/// Statement budget for one *rolled* group: prologue + loop bodies +
/// epilogue must stay compiler-friendly even though the plane heights no
/// longer matter. Configurations whose rolled emission still explodes
/// (scalar ISAs or unrolled columns over wide planes) fall back to the
/// classic per-group split.
const ROLLED_GROUP_STMT_BUDGET: usize = 50_000;

/// Per-group payload of the derive-once [`FusionPlanBundle`]: the row-axis
/// plans, the demand-driven row schedule with its ring heights, and the
/// mode-resolved rolled emission plan (`None` = fully unrolled schedule).
pub(crate) struct FusedGroupPlan {
    pub plans: Vec<schedule::AxisPlan>,
    pub layout: schedule::GroupLayout,
    pub rolled: Option<schedule::RolledPlan>,
}

/// One entry of the fusion partition: the group span plus, for multi-layer
/// groups, everything emission needs, derived exactly once.
pub(crate) struct PlannedGroup {
    pub group: crate::passes::FusionGroup,
    /// `Some` iff `group.len() > 1`.
    pub fused: Option<FusedGroupPlan>,
}

/// Derive-once fusion bundle (`groups` + per-group `plans`/`layout`/rolled
/// plan), built by [`plan_fusion`] and threaded through
/// [`estimate_statements`], [`plan_buffers`] and [`emit_fused_group`] —
/// the single source of truth that makes it impossible for grouping,
/// buffer sizing and emission to disagree.
pub(crate) struct FusionPlanBundle {
    pub groups: Vec<PlannedGroup>,
}

impl FusionPlanBundle {
    fn singletons(n: usize) -> FusionPlanBundle {
        FusionPlanBundle {
            groups: (0..n)
                .map(|i| PlannedGroup {
                    group: crate::passes::FusionGroup::singleton(i),
                    fused: None,
                })
                .collect(),
        }
    }
}

/// Resolve the fusion partition for these options and derive every
/// multi-layer group's plans/schedule/rolled-plan once: kind-based chains
/// from [`crate::passes::plan_fusion_groups`], refined with shape checks,
/// the depth cap, and the per-group statement budget. Returns
/// all-singletons when fusion is off or the emission mode cannot stream
/// rows: the loop form and full unroll keep their whole-plane walks, and
/// copy-mode padding materializes whole padded planes by definition.
///
/// Depth-capped groups whose *rolled* emission (under [`RolledMode::Auto`]
/// — the partition deliberately ignores the actual knob, so every mode
/// emits the same groups and stays bit-comparable) fits
/// [`ROLLED_GROUP_STMT_BUDGET`] skip the unrolled statement-budget split:
/// rolling makes their code size independent of plane height, so the
/// models the budget used to fragment (robot, pedestrian) fuse at full
/// depth.
pub(crate) fn plan_fusion(
    model: &Model,
    shapes: &[Shape],
    opts: &CodegenOptions,
) -> Result<FusionPlanBundle> {
    use crate::passes::FusionGroup;
    let n = model.layers.len();
    if opts.fuse.max_depth() < 2
        || !matches!(opts.unroll, Unroll::KeepOuter1 | Unroll::KeepOuter2)
        || schedule::pad_strategy(opts) != schedule::PadStrategy::Padless
    {
        return Ok(FusionPlanBundle::singletons(n));
    }
    // Derive one group's payload (plans + schedule + mode-resolved rolled
    // plan) — the only place these are ever computed.
    let derive = |group: FusionGroup| -> Result<PlannedGroup> {
        if group.len() < 2 {
            return Ok(PlannedGroup { group, fused: None });
        }
        let plans = group_row_plans(model, shapes, &group)?;
        let layout = schedule::plan_group_rows(&plans);
        let rolled = schedule::rolled_plan(&layout, &plans, opts.fuse_rolled);
        Ok(PlannedGroup { group, fused: Some(FusedGroupPlan { plans, layout, rolled }) })
    };
    let max_depth = opts.fuse.max_depth();
    let mut out: Vec<PlannedGroup> = Vec::new();
    for chain in crate::passes::plan_fusion_groups(model, usize::MAX) {
        // Row streaming needs image-shaped planes on both sides; split the
        // chain at any non-3D boundary. int8 additionally splits at layers
        // the integer row emitter does not fuse (depthwise/avgpool stay
        // whole-plane under int8).
        let mut runs: Vec<FusionGroup> = Vec::new();
        let mut start = chain.start;
        for i in chain.start..chain.end {
            if shapes[i].rank() != 3
                || shapes[i + 1].rank() != 3
                || (opts.dtype == DType::Int8 && !int8_fusable(&model.layers[i]))
            {
                if i > start {
                    runs.push(FusionGroup { start, end: i });
                }
                runs.push(FusionGroup::singleton(i));
                start = i + 1;
            }
        }
        if start < chain.end {
            runs.push(FusionGroup { start, end: chain.end });
        }
        for run in runs {
            let mut s = run.start;
            while s < run.end {
                let group = FusionGroup { start: s, end: (s + max_depth).min(run.end) };
                s = group.end;
                if group.len() > 1 {
                    let plans = group_row_plans(model, shapes, &group)?;
                    let layout = schedule::plan_group_rows(&plans);
                    // Knob-independent qualification: does the AUTO-mode
                    // rolled emission fit the rolled budget? A group that
                    // fails it but comes back from the statement-budget
                    // refinement unsplit reuses the payload computed here
                    // rather than re-deriving it.
                    let auto = schedule::rolled_plan(&layout, &plans, RolledMode::Auto);
                    let rolled_fits = auto.as_ref().map_or(false, |rp| {
                        rolled_plan_cost(model, shapes, opts, &group, &layout, rp)
                            <= ROLLED_GROUP_STMT_BUDGET
                    });
                    let pieces = if rolled_fits {
                        Vec::new()
                    } else {
                        split_by_budget(model, shapes, opts, group)
                    };
                    let fits = rolled_fits || pieces.len() == 1;
                    if fits {
                        // Reuse the auto plan instead of re-running
                        // detection: rotate-mode loops carry `rotate`,
                        // so the auto plan's provenance is recoverable.
                        // Only `Expand` while rotation succeeded needs
                        // the other detector.
                        //
                        // When the *requested* mode's detector fails on a
                        // group that qualified under Auto (Rotate where
                        // only expansion verifies, or Expand where the
                        // ring-phase count exceeds the 64x cap), the
                        // group deliberately degrades to the fully
                        // unrolled schedule of the SAME span — exactly
                        // like `--fuse-rolled off`. Splitting instead
                        // would change the partition per knob and break
                        // the bit-comparability of the four emission
                        // forms; the cost guard still bounds the result.
                        let auto_rotated =
                            auto.as_ref().map_or(false, |rp| rp.loops().any(|l| l.rotate));
                        let rolled = match opts.fuse_rolled {
                            RolledMode::Auto => auto,
                            RolledMode::Off => None,
                            RolledMode::Rotate => {
                                if auto_rotated {
                                    auto
                                } else {
                                    None
                                }
                            }
                            RolledMode::Expand => {
                                if auto_rotated {
                                    schedule::rolled_plan(&layout, &plans, RolledMode::Expand)
                                } else {
                                    // Auto already fell back to (or failed
                                    // at) phase expansion.
                                    auto
                                }
                            }
                        };
                        out.push(PlannedGroup {
                            group,
                            fused: Some(FusedGroupPlan { plans, layout, rolled }),
                        });
                        continue;
                    }
                    // Real split: derive each refined piece.
                    for piece in pieces {
                        out.push(derive(piece)?);
                    }
                    continue;
                }
                out.push(derive(group)?);
            }
        }
    }
    Ok(FusionPlanBundle { groups: out })
}

/// Layers [`qemit::emit_qrow`] can emit as fused int8 row ops. Conv must
/// carry an integer-expressible activation (softmax is a float epilogue,
/// never fused); depthwise and average pooling keep their whole-plane
/// int8 emitters.
fn int8_fusable(layer: &Layer) -> bool {
    matches!(
        layer,
        Layer::Conv2D {
            activation: Activation::None | Activation::Relu | Activation::LeakyRelu(_),
            ..
        } | Layer::MaxPool2D { .. }
            | Layer::Activation(Activation::None | Activation::Relu | Activation::LeakyRelu(_))
    )
}

/// Statement cost of a rolled plan: every unrolled op plus one pattern
/// copy per loop (mirrors what [`emit_fused_group`] actually writes).
fn rolled_plan_cost(
    model: &Model,
    shapes: &[Shape],
    opts: &CodegenOptions,
    group: &crate::passes::FusionGroup,
    layout: &schedule::GroupLayout,
    rp: &schedule::RolledPlan,
) -> usize {
    rp.segments
        .iter()
        .map(|seg| match seg {
            schedule::Segment::Unrolled(lo, hi) => {
                group_rows_cost(model, shapes, opts, group, &layout.ops[*lo..*hi])
            }
            schedule::Segment::Loop(l) => {
                group_rows_cost(model, shapes, opts, group, &layout.ops[l.pattern()])
            }
        })
        .sum()
}

/// Statement cost of a slice of a group's row ops (shared pricing for the
/// rolled-budget decision and the cost guard).
fn group_rows_cost(
    model: &Model,
    shapes: &[Shape],
    opts: &CodegenOptions,
    group: &crate::passes::FusionGroup,
    ops: &[schedule::RowOp],
) -> usize {
    ops.iter()
        .map(|op| {
            let gi = group.start + op.layer;
            fused_row_cost(&model.layers[gi], &shapes[gi + 1], opts)
        })
        .sum()
}

/// Statement-budget refinement for groups that must unroll their whole row
/// schedule: split so each piece's unrolled emission stays fast for a C
/// compiler to chew through.
fn split_by_budget(
    model: &Model,
    shapes: &[Shape],
    opts: &CodegenOptions,
    group: crate::passes::FusionGroup,
) -> Vec<crate::passes::FusionGroup> {
    use crate::passes::FusionGroup;
    let mut out = Vec::new();
    let mut start = group.start;
    let mut acc = 0usize;
    for i in group.start..group.end {
        let cost = fused_layer_cost(&model.layers[i], &shapes[i + 1], opts);
        if i > start && acc + cost > FUSE_GROUP_STMT_BUDGET {
            out.push(FusionGroup { start, end: i });
            start = i;
            acc = 0;
        }
        acc += cost;
    }
    if start < group.end {
        out.push(FusionGroup { start, end: group.end });
    }
    out
}

/// Row-axis [`schedule::AxisPlan`] of every member of a fusion group, in
/// member order; drives both the demand schedule and ring sizing.
fn group_row_plans(
    model: &Model,
    shapes: &[Shape],
    group: &crate::passes::FusionGroup,
) -> Result<Vec<schedule::AxisPlan>> {
    let mut plans = Vec::with_capacity(group.len());
    for i in group.start..group.end {
        let (h_in, h_out) = (shapes[i].h(), shapes[i + 1].h());
        let plan = match &model.layers[i] {
            Layer::Conv2D { weights, stride, padding, .. }
            | Layer::DepthwiseConv2D { weights, stride, padding, .. } => {
                let k = weights.dims()[0];
                let (_, pad) = padding.resolve(h_in, k, stride.0)?;
                schedule::AxisPlan::padless(h_out, stride.0, k, pad, h_in)
            }
            Layer::MaxPool2D { pool, stride } | Layer::AvgPool2D { pool, stride } => {
                schedule::AxisPlan::padless(h_out, stride.0, pool.0, 0, h_in)
            }
            Layer::Activation(_) => schedule::AxisPlan::padless(h_out, 1, 1, 0, h_in),
            other => bail!("layer {} cannot join a fusion group", other.kind_name()),
        };
        plans.push(plan);
    }
    Ok(plans)
}

/// Steady-state loop context of one emitted row op: per-member row
/// advance, per-edge ring advance (rotate-mode loops only), and the
/// generation-time rotation state `phi` of every edge's pointer set at
/// loop entry.
struct LoopCtx<'a> {
    row_delta: &'a [usize],
    /// `Some` for rotate-mode loops; `None` freezes every ring offset at
    /// iteration 0 (the phase-expanded body, whose advances are multiples
    /// of the ring heights by construction).
    edge_adv: Option<&'a [usize]>,
    phi: &'a [usize],
}

impl LoopCtx<'_> {
    /// True when ring edge `e` (height `rows`) is addressed through the
    /// rotating pointer set inside this loop.
    fn rotates(&self, e: usize, rows: usize) -> bool {
        self.edge_adv.map_or(false, |adv| adv[e] % rows.max(1) != 0)
    }
}

/// Emit one fusion group: replay the demand-driven row schedule, routing
/// every member's input/output rows through the group input plane, the
/// per-edge ring buffers, or the group output plane.
///
/// A group with a rolled plan emits each [`schedule::Segment`] in order:
/// unrolled runs one block per op, loops (the steady-state body plus any
/// warm-up/drain ramps) as genuine C `for` loops. Plane bases advance by a
/// constant element stride per iteration; ring rows are addressed either
/// at frozen slot offsets (when the loop's edge advance is a multiple of
/// the ring height) or through `float *nncg_ring{i}_r{k}` pointers that
/// the loop bottom rotates with straight-line assignments — either way the
/// emitted C contains no runtime `%`.
#[allow(clippy::too_many_arguments)]
fn emit_fused_group(
    w: &mut CWriter,
    model: &Model,
    shapes: &[Shape],
    group: &crate::passes::FusionGroup,
    fp: &FusedGroupPlan,
    group_src: &str,
    group_dst: &str,
    plan: &BufferPlan,
    opts: &CodegenOptions,
    qp: Option<&crate::passes::QuantPlan>,
) -> Result<()> {
    use schedule::Segment;
    // int8 groups carry signed-char rings; everything else about the
    // ring/rolled machinery (slots, rotation, phases) is dtype-blind.
    let ety = if qp.is_some() { "signed char" } else { "float" };
    let plans = &fp.plans;
    let layout = &fp.layout;
    let rp = match &fp.rolled {
        Some(rp) => rp,
        None => {
            for op in &layout.ops {
                emit_group_row_op(
                    w, model, shapes, group, group_src, group_dst, plan, opts, plans, layout, op,
                    None, qp,
                )?;
            }
            return Ok(());
        }
    };
    let edges = group.len() - 1;
    // Per-loop ring advances, resolved once; an edge some loop rotates
    // gets a pointer set declared at the top of the group block.
    let mut loop_adv: Vec<Option<Vec<usize>>> = Vec::new();
    let mut rotated = vec![false; edges];
    for seg in &rp.segments {
        if let Segment::Loop(l) = seg {
            if !l.rotate {
                loop_adv.push(None);
                continue;
            }
            let adv = schedule::edge_advances(&layout.ops[l.pattern()], &l.row_delta, plans)
                .ok_or_else(|| {
                    anyhow::anyhow!("rolled loop references a ring edge at two rates")
                })?;
            for e in 0..edges {
                if adv[e] % layout.ring_rows[e].max(1) != 0 {
                    rotated[e] = true;
                }
            }
            loop_adv.push(Some(adv));
        }
    }
    let scoped = rotated.iter().any(|&r| r);
    if scoped {
        // Group-scoped block so the pointer declarations stay ANSI-legal
        // after earlier statements.
        w.open("");
        for (e, _) in rotated.iter().enumerate().filter(|(_, &r)| r) {
            let ring = find_ring(plan, group.start + e)?;
            for k in 0..ring.rows {
                w.line(&format!(
                    "{ety} *nncg_ring{gl}_r{k} = nncg_ring{gl} + {};",
                    k * ring.row_elems,
                    gl = ring.layer
                ));
            }
        }
    }
    let mut phi = vec![0usize; edges];
    let mut loops_seen = 0usize;
    for seg in &rp.segments {
        match seg {
            Segment::Unrolled(lo, hi) => {
                for op in &layout.ops[*lo..*hi] {
                    emit_group_row_op(
                        w, model, shapes, group, group_src, group_dst, plan, opts, plans, layout,
                        op, None, qp,
                    )?;
                }
            }
            Segment::Loop(l) => {
                let adv = loop_adv[loops_seen].as_deref();
                loops_seen += 1;
                if l.ramp {
                    w.line(&format!(
                        "/* rolled ramp: {} iterations x {} row-ops */",
                        l.iters, l.ops_per_iter
                    ));
                } else {
                    w.line(&format!(
                        "/* steady state: {} iterations x {} row-ops per iteration ({}) */",
                        l.iters,
                        l.ops_per_iter,
                        if l.rotate {
                            "one op-pattern period; rotated ring pointers"
                        } else {
                            "ring phases included; frozen ring slots"
                        }
                    ));
                }
                w.open(&format!("for (i = 0; i < {}; i++)", l.iters));
                {
                    let ctx = LoopCtx { row_delta: &l.row_delta, edge_adv: adv, phi: &phi };
                    for op in &layout.ops[l.pattern()] {
                        emit_group_row_op(
                            w, model, shapes, group, group_src, group_dst, plan, opts, plans,
                            layout, op, Some(&ctx), qp,
                        )?;
                    }
                    emit_ring_rotations(w, group, layout, &ctx, ety)?;
                }
                w.close();
                if let Some(adv) = adv {
                    for e in 0..edges {
                        let r = layout.ring_rows[e].max(1);
                        phi[e] = (phi[e] + l.iters * (adv[e] % r)) % r;
                    }
                }
            }
        }
    }
    if scoped {
        w.close();
    }
    Ok(())
}

/// Straight-line pointer rotation at the bottom of a rotate-mode loop
/// body: for every edge the loop rotates, `ptr'[k] = ptr[(k + g) % R]`
/// with `g` the edge's per-iteration row advance mod its ring height —
/// `g` temporaries, then `R` reassignments, no runtime index arithmetic.
fn emit_ring_rotations(
    w: &mut CWriter,
    group: &crate::passes::FusionGroup,
    layout: &schedule::GroupLayout,
    ctx: &LoopCtx<'_>,
    ety: &str,
) -> Result<()> {
    let adv = match ctx.edge_adv {
        Some(adv) => adv,
        None => return Ok(()),
    };
    let rot: Vec<(usize, usize, usize)> = (0..layout.ring_rows.len())
        .filter_map(|e| {
            let r = layout.ring_rows[e].max(1);
            let g = adv[e] % r;
            (g != 0).then_some((e, r, g))
        })
        .collect();
    if rot.is_empty() {
        return Ok(());
    }
    w.line("/* rotate ring row pointers by this iteration's row advance */");
    w.open("");
    for &(e, _, g) in &rot {
        let gl = group.start + e;
        for t in 0..g {
            w.line(&format!("{ety} *nncg_rt{e}_{t} = nncg_ring{gl}_r{t};"));
        }
    }
    for &(e, r, g) in &rot {
        let gl = group.start + e;
        for k in 0..r - g {
            w.line(&format!("nncg_ring{gl}_r{k} = nncg_ring{gl}_r{};", k + g));
        }
        for t in 0..g {
            w.line(&format!("nncg_ring{gl}_r{} = nncg_rt{e}_{t};", r - g + t));
        }
    }
    w.close();
    Ok(())
}

/// Emit one row op of a fusion group. `loop_ctx` is `Some` inside a
/// rolled loop body: the op then computes row `op.row + i*delta` per
/// iteration `i`, with plane bases advancing by a constant element
/// stride and ring rows addressed either at frozen slot offsets or, when
/// the loop rotates the edge, through the rotating pointer set (indices
/// resolved at generation time against the loop-entry rotation state).
#[allow(clippy::too_many_arguments)]
fn emit_group_row_op(
    w: &mut CWriter,
    model: &Model,
    shapes: &[Shape],
    group: &crate::passes::FusionGroup,
    group_src: &str,
    group_dst: &str,
    plan: &BufferPlan,
    opts: &CodegenOptions,
    plans: &[schedule::AxisPlan],
    layout: &schedule::GroupLayout,
    op: &schedule::RowOp,
    loop_ctx: Option<&LoopCtx<'_>>,
    qp: Option<&crate::passes::QuantPlan>,
) -> Result<()> {
    use schedule::{FusedRowIo, RotPtrs, RowMap};
    let members = group.len();
    let i = group.start + op.layer;
    let in_s = &shapes[i];
    let out_s = &shapes[i + 1];
    // Rotating pointer name for ring row `q` of edge `e`: the body
    // addresses the pointer whose slot tracks `q` across iterations —
    // index `(q - phi) mod R` against the loop-entry rotation state.
    let rot_name = |e: usize, q: usize, ctx: &LoopCtx<'_>| {
        let r = layout.ring_rows[e].max(1);
        format!("nncg_ring{}_r{}", group.start + e, (q % r + r - ctx.phi[e] % r) % r)
    };
    let (src_name, src_map) = if op.layer == 0 {
        (group_src.to_string(), RowMap::Plane { row_elems: in_s.w() * in_s.c() })
    } else {
        let r = find_ring(plan, i - 1)?;
        (format!("nncg_ring{}", r.layer), RowMap::Ring { rows: r.rows, row_elems: r.row_elems })
    };
    let src_rot = match (loop_ctx, op.layer > 0) {
        (Some(ctx), true) if ctx.rotates(op.layer - 1, layout.ring_rows[op.layer - 1]) => {
            let e = op.layer - 1;
            let pl = &plans[op.layer];
            let (k0, k1) = pl.window(op.row);
            let p0 = pl.src_start(op.row);
            let ring = find_ring(plan, i - 1)?;
            Some(RotPtrs {
                names: (0..k1 - k0).map(|t| rot_name(e, p0 + t, ctx)).collect(),
                aligned: ring.row_elems % 8 == 0,
            })
        }
        _ => None,
    };
    let (dst_name, dst_map) = if op.layer == members - 1 {
        (group_dst.to_string(), RowMap::Plane { row_elems: out_s.w() * out_s.c() })
    } else {
        let r = find_ring(plan, i)?;
        (format!("nncg_ring{}", r.layer), RowMap::Ring { rows: r.rows, row_elems: r.row_elems })
    };
    let dst_rot = match (loop_ctx, op.layer < members - 1) {
        (Some(ctx), true) if ctx.rotates(op.layer, layout.ring_rows[op.layer]) => {
            let ring = find_ring(plan, i)?;
            Some(RotPtrs {
                names: vec![rot_name(op.layer, op.row, ctx)],
                aligned: ring.row_elems % 8 == 0,
            })
        }
        _ => None,
    };
    // A rotating destination pointer addresses the row start directly.
    let dst_row_off = if dst_rot.is_some() { 0 } else { dst_map.off(op.row) };
    // Per-iteration base strides inside the rolled loop: a plane source
    // advances `delta * stride` source rows, a plane destination `delta`
    // output rows; ring bases never move (frozen slots repeat exactly,
    // rotating pointers carry the advance themselves).
    let (src_iter_elems, dst_iter_elems) = match loop_ctx {
        None => (0, 0),
        Some(ctx) => {
            let si = if op.layer == 0 {
                ctx.row_delta[0] * plans[0].stride * in_s.w() * in_s.c()
            } else {
                0
            };
            let di = if op.layer == members - 1 {
                ctx.row_delta[op.layer] * out_s.w() * out_s.c()
            } else {
                0
            };
            (si, di)
        }
    };
    let io = FusedRowIo {
        out_row: op.row,
        src_map,
        dst_row_off,
        src_iter_elems,
        dst_iter_elems,
        src_rot,
        dst_rot,
    };
    let ctx = LayerCtx {
        idx: i,
        in_shape: in_s,
        out_shape: out_s,
        src: &src_name,
        dst: &dst_name,
        padbuf: "nncg_pad",
        opts,
    };
    match loop_ctx {
        None => w.line(&format!("/* L{i} {} row {} */", model.layers[i].kind_name(), op.row)),
        Some(lc) => w.line(&format!(
            "/* L{i} {} row {}+{}i */",
            model.layers[i].kind_name(),
            op.row,
            lc.row_delta[op.layer]
        )),
    }
    if let Some(qp) = qp {
        // int8 fused rows: one shared integer row emitter per layer kind
        // (qemit), addressing rows through the same FusedRowIo contract.
        return qemit::emit_qrow(w, &ctx, &model.layers[i], &qp.layers[i], &io);
    }
    match &model.layers[i] {
        Layer::Conv2D { weights, bias, stride, padding, activation } => {
            conv::emit_conv_row_fused(w, &ctx, weights, bias, *stride, *padding, *activation, &io)?
        }
        Layer::DepthwiseConv2D { weights, bias, stride, padding, activation } => {
            depthwise::emit_depthwise_row_fused(
                w, &ctx, weights, bias, *stride, *padding, *activation, &io,
            )?
        }
        Layer::MaxPool2D { pool, stride } => {
            pool::emit_maxpool_row_fused(w, &ctx, *pool, *stride, &io)?
        }
        Layer::AvgPool2D { pool, stride } => {
            depthwise::emit_avgpool_row_fused(w, &ctx, *pool, *stride, &io)?
        }
        Layer::Activation(a) => activation::emit_activation_row_fused(w, &ctx, *a, &io)?,
        other => bail!("layer {} cannot be emitted in a fusion group", other.kind_name()),
    }
    Ok(())
}

/// Ring buffer whose producer is global layer `layer`.
fn find_ring(plan: &BufferPlan, layer: usize) -> Result<&RingInfo> {
    plan.rings
        .iter()
        .find(|r| r.layer == layer)
        .ok_or_else(|| anyhow::anyhow!("missing ring buffer for layer {layer}"))
}

fn plan_buffers(
    model: &Model,
    shapes: &[Shape],
    opts: &CodegenOptions,
    bundle: &FusionPlanBundle,
) -> Result<BufferPlan> {
    let uses_pad_buffer = schedule::pad_strategy(opts) == schedule::PadStrategy::Copy;
    let n_layers = model.layers.len();
    let mut main_size = 0usize;
    let mut pad_size = 0usize;
    let mut rings = Vec::new();
    // Liveness-aware ping-pong sizing: scratch only ever holds a group
    // boundary plane (the final output goes straight to x_out, and fused
    // intermediates live in their ring buffers instead). Ring heights come
    // straight from the bundle's layouts — never re-derived.
    for pg in &bundle.groups {
        let group = &pg.group;
        if group.end != n_layers {
            main_size = main_size.max(shapes[group.end].numel());
        }
        if let Some(fp) = &pg.fused {
            for e in 0..group.len() - 1 {
                let out_s = &shapes[group.start + e + 1];
                let mut row_elems = out_s.w() * out_s.c();
                // Channel-stride padding: round each ring row's stride up
                // to a whole vector group, so every ring row starts
                // 32-byte aligned and odd channel counts keep aligned
                // interiors. The pad tail is never read or written.
                if opts.chan_pad == ChanPad::Auto && opts.use_aligned() {
                    let q = dtype_quantum(opts.dtype);
                    row_elems = crate::util::div_ceil(row_elems, q) * q;
                }
                let rows = fp.layout.ring_rows[e];
                let mut floats = rows * row_elems;
                if opts.use_aligned() {
                    floats = round_to_vec(floats);
                }
                rings.push(RingInfo { layer: group.start + e, rows, row_elems, floats });
            }
        }
    }
    if uses_pad_buffer {
        for (i, layer) in model.layers.iter().enumerate() {
            match layer {
                Layer::Conv2D { weights, stride, padding, .. } => {
                    let (ph, pw) = conv::padded_extent(&shapes[i], weights.dims(), *stride, *padding)?;
                    if (ph, pw) != (shapes[i].h(), shapes[i].w()) {
                        pad_size = pad_size.max(ph * pw * shapes[i].c());
                    }
                }
                Layer::DepthwiseConv2D { weights, stride, padding, .. } => {
                    let d = weights.dims();
                    let pseudo = [d[0], d[1], d[2], d[2]];
                    let (ph, pw) = conv::padded_extent(&shapes[i], &pseudo, *stride, *padding)?;
                    if (ph, pw) != (shapes[i].h(), shapes[i].w()) {
                        pad_size = pad_size.max(ph * pw * shapes[i].c());
                    }
                }
                _ => {}
            }
        }
    }
    if opts.use_aligned() {
        main_size = round_to_vec(main_size);
        pad_size = round_to_vec(pad_size);
    }
    Ok(BufferPlan { main_size, pad_size, rings })
}

/// Static scratch footprint of the generated C. The paper's
/// resource-constrained targets budget RAM as tightly as cycles; ring line
/// buffers shrink fused models' peak static scratch from O(H·W·C) per
/// intermediate to O(k_h·W·C) per fused edge.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ScratchReport {
    /// Elements per ping-pong scratch buffer (two are declared). Named
    /// for the f32 path; under `--dtype int8` the same count is in
    /// `signed char` elements.
    pub main_floats: usize,
    /// Elements in the pad-copy buffer (0 under padless emission and in
    /// the int8 path, which peels border rows instead of pad-copying).
    pub pad_floats: usize,
    /// Total elements across all ring line buffers.
    pub ring_floats: usize,
    /// Number of ring buffers (fused interior edges).
    pub ring_count: usize,
    /// Bytes per scratch element: 4 for f32, 1 for int8.
    pub elem_bytes: usize,
}

impl ScratchReport {
    /// Total static scratch bytes the generated file declares.
    pub fn total_bytes(&self) -> usize {
        (2 * self.main_floats.max(1) + self.pad_floats + self.ring_floats) * self.elem_bytes
    }
}

/// Compute the static-buffer plan for a model under `opts` without
/// generating code (the ablation bench's memory-footprint column).
pub fn scratch_report(model: &Model, opts: &CodegenOptions) -> Result<ScratchReport> {
    let model = crate::passes::optimize(model.clone())?;
    let shapes = model.infer_shapes()?;
    let bundle = plan_fusion(&model, &shapes, opts)?;
    let plan = plan_buffers(&model, &shapes, opts, &bundle)?;
    let int8 = opts.dtype == DType::Int8;
    let mut main = plan.main_size;
    if int8 {
        // The int8 rings also host the quantized entry and exit planes,
        // so they are at least input/output sized (mirrors qemit).
        main = main.max(model.input.numel()).max(model.output_shape()?.numel());
    }
    Ok(ScratchReport {
        main_floats: main,
        pad_floats: if int8 { 0 } else { plan.pad_size },
        ring_floats: plan.rings.iter().map(|r| r.floats).sum(),
        ring_count: plan.rings.len(),
        elem_bytes: if int8 { 1 } else { 4 },
    })
}

/// Per-cell statement cost of one layer's inner body (one statement per
/// vector group plus one per scalar lane and tap) — shared by the cost
/// guard and the fusion planner's statement budget.
fn layer_body_cost(layer: &Layer, out: &Shape, isa: Isa) -> usize {
    use simd::ChannelSchedule;
    match layer {
        Layer::Conv2D { weights, .. } => {
            let d = weights.dims();
            d[0] * d[1] * d[2] * ChannelSchedule::for_channels(isa, d[3]).cost_per_tap()
        }
        Layer::MaxPool2D { pool, .. } | Layer::AvgPool2D { pool, .. } => {
            pool.0 * pool.1 * ChannelSchedule::for_channels(isa, out.c()).cost_per_tap()
        }
        Layer::DepthwiseConv2D { weights, .. } => {
            let d = weights.dims();
            d[0] * d[1] * ChannelSchedule::for_channels(isa, d[2]).cost_per_tap()
        }
        Layer::Dense { weights, .. } => weights.numel(),
        _ => out.numel().max(1),
    }
}

/// Statements one emitted fused row of a layer costs: columns keep their
/// loop per the unroll level.
fn fused_row_cost(layer: &Layer, out: &Shape, opts: &CodegenOptions) -> usize {
    let body = layer_body_cost(layer, out, opts.isa);
    match layer {
        Layer::Conv2D { .. }
        | Layer::DepthwiseConv2D { .. }
        | Layer::MaxPool2D { .. }
        | Layer::AvgPool2D { .. } => {
            let cols = if opts.unroll.keeps_cols() { 1 } else { out.w() };
            body * cols
        }
        // Elementwise layers spread their total over the plane's rows.
        _ => crate::util::div_ceil(body, out.h().max(1)),
    }
}

/// Statements a layer contributes when its whole row schedule is emitted
/// unrolled (the statement-budget split's currency).
fn fused_layer_cost(layer: &Layer, out: &Shape, opts: &CodegenOptions) -> usize {
    match layer {
        Layer::Conv2D { .. }
        | Layer::DepthwiseConv2D { .. }
        | Layer::MaxPool2D { .. }
        | Layer::AvgPool2D { .. } => fused_row_cost(layer, out, opts) * out.h(),
        // Elementwise rows: fusing does not change the total work.
        _ => layer_body_cost(layer, out, opts.isa),
    }
}

/// Rough statement-count estimate for the cost guard, priced straight off
/// the bundle: fused groups pay per scheduled row op, and a group with a
/// rolled plan only pays for its unrolled runs plus one pattern copy per
/// loop — mirroring what `emit_fused_group` actually writes out.
fn estimate_statements(
    model: &Model,
    shapes: &[Shape],
    opts: &CodegenOptions,
    bundle: &FusionPlanBundle,
) -> usize {
    let mut total = 0usize;
    for pg in &bundle.groups {
        let group = &pg.group;
        if let Some(fp) = &pg.fused {
            total += match &fp.rolled {
                Some(rp) => rolled_plan_cost(model, shapes, opts, group, &fp.layout, rp),
                None => group_rows_cost(model, shapes, opts, group, &fp.layout.ops),
            };
            continue;
        }
        let i = group.start;
        let layer = &model.layers[i];
        let out = &shapes[i + 1];
        let body = layer_body_cost(layer, out, opts.isa);
        // Spatial extent only exists for image-shaped layers; dense/flat
        // layers behave as a single cell.
        let (rows, cols) = match layer {
            Layer::Conv2D { .. }
            | Layer::MaxPool2D { .. }
            | Layer::AvgPool2D { .. }
            | Layer::DepthwiseConv2D { .. } => (out.h(), out.w()),
            _ => (1, 1),
        };
        total += match opts.unroll {
            Unroll::None => 16, // constant-size loop nest
            Unroll::KeepOuter2 => body,
            Unroll::KeepOuter1 => body * cols.max(1),
            Unroll::Full => body * rows * cols,
        };
    }
    total
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::zoo;

    fn gen(model: &str, opts: &CodegenOptions) -> String {
        let m = zoo::by_name(model).unwrap().with_random_weights(13);
        generate_c(&m, opts).unwrap()
    }

    #[test]
    fn ball_generic_contains_expected_structure() {
        let src = gen("ball", &CodegenOptions::general());
        assert!(src.contains("void ball_inference(const float *x_in, float *x_out)"));
        assert!(src.contains("#define BALL_INPUT_SIZE 256"));
        assert!(src.contains("#define BALL_OUTPUT_SIZE 2"));
        assert!(src.contains("#include <math.h>")); // softmax
        assert!(!src.contains("emmintrin")); // generic must be ANSI only
        // P2: ternary conditional move present (ReLU)
        assert!(src.contains('?'), "expected ternary operator for cmov principle");
    }

    #[test]
    fn sse_mode_uses_intrinsics() {
        let src = gen("ball", &CodegenOptions::sse3());
        assert!(src.contains("#include <emmintrin.h>"));
        assert!(src.contains("_mm_add_ps"));
        assert!(src.contains("_mm_max_ps")); // relu via maxps
    }

    #[test]
    fn full_unroll_has_no_loops() {
        let src = gen("ball", &CodegenOptions::sse3_full_unroll());
        // The batch entry point is a deliberate loop over images; full
        // unroll only promises straight-line code *inside* one inference.
        let single = src.split("nncg_cnn_batch").next().unwrap();
        assert!(!single.contains("for ("), "full unroll must emit straight-line code");
    }

    #[test]
    fn no_unroll_uses_weight_arrays() {
        let opts = CodegenOptions { isa: Isa::Generic, unroll: Unroll::None, ..Default::default() };
        let src = gen("ball", &opts);
        assert!(src.contains("static const float w0["));
        assert!(src.contains("for ("));
    }

    #[test]
    fn statement_guard_rejects_absurd_unroll() {
        let m = zoo::pedestrian_classifier().with_random_weights(3);
        let opts = CodegenOptions { unroll: Unroll::Full, max_statements: 10_000, ..Default::default() };
        assert!(generate_c(&m, &opts).is_err());
    }

    #[test]
    fn all_paper_models_generate_under_default_options() {
        for name in zoo::PAPER_MODELS {
            let src = gen(name, &CodegenOptions::default());
            assert!(src.len() > 1000, "{name}");
            // Balanced braces is a decent smoke test for emitter bugs.
            let open = src.matches('{').count();
            let close = src.matches('}').count();
            assert_eq!(open, close, "{name}: unbalanced braces");
        }
    }

    #[test]
    fn options_tags_are_distinct() {
        let a = CodegenOptions::general().tag();
        let b = CodegenOptions::sse3().tag();
        let c = CodegenOptions::sse3_full_unroll().tag();
        assert_ne!(a, b);
        assert_ne!(b, c);
        // The new knobs must reach the tag (cache keys, bench labels).
        let d = CodegenOptions { pad_mode: PadMode::Copy, ..CodegenOptions::sse3() }.tag();
        let e = CodegenOptions { tile: TileMode::Off, ..CodegenOptions::sse3() }.tag();
        assert_ne!(b, d);
        assert_ne!(b, e);
        // PR-8 knobs: suffixes only at non-default settings, so every
        // pre-existing configuration keeps a byte-stable tag.
        assert!(!b.contains("-cpoff") && !b.contains("-dtint8"));
        let f = CodegenOptions { dtype: DType::Int8, ..CodegenOptions::sse3() }.tag();
        let g = CodegenOptions { chan_pad: ChanPad::Off, ..CodegenOptions::sse3() }.tag();
        assert!(f.ends_with("-dtint8"));
        assert!(g.ends_with("-cpoff"));
        assert_ne!(b, f);
        assert_ne!(b, g);
        assert_eq!(f.replace("-dtint8", ""), b);
    }

    #[test]
    fn robot_bn_is_folded_by_pipeline() {
        let src = gen("robot", &CodegenOptions::sse3());
        assert!(src.contains("robot_inference"));
        // The batch *entry point* is the one legitimate use of the word;
        // outside those lines "batch" means a BatchNorm leaked through the
        // fold. Same line-filter contract as the CI purity grep.
        for line in src.lines() {
            if line.contains("inference_batch") || line.contains("nncg_cnn_batch") {
                continue;
            }
            assert!(!line.to_lowercase().contains("batch"), "BN must be folded away: {line}");
        }
    }

    #[test]
    fn batch_entry_point_is_emitted_for_every_isa() {
        // nncg_cnn_batch contract: one extra symbol, same translation unit,
        // delegating to the single-image function per image.
        for opts in
            [CodegenOptions::general(), CodegenOptions::sse3(), CodegenOptions::sse3_full_unroll()]
        {
            let src = gen("ball", &opts);
            assert!(
                src.contains("void ball_inference_batch(const float *x_in, float *x_out, int n)"),
                "{}: missing batch entry",
                opts.tag()
            );
            assert!(
                src.contains("ball_inference(x_in + BALL_INPUT_SIZE * b, x_out + BALL_OUTPUT_SIZE * b);"),
                "{}: batch entry must delegate per image",
                opts.tag()
            );
            // Exactly one definition of each entry point.
            assert_eq!(src.matches("void ball_inference(const float").count(), 1, "{}", opts.tag());
            assert_eq!(src.matches("void ball_inference_batch(const float").count(), 1, "{}", opts.tag());
        }
    }

    #[test]
    fn avx2_mode_uses_wide_intrinsics() {
        let src = gen("ball", &CodegenOptions::avx2());
        assert!(src.contains("#include <immintrin.h>"));
        assert!(src.contains("_mm256_fmadd_ps"));
        // ball's first conv has c_out=8 -> one 8-wide group
        assert!(src.contains("__m256"));
    }

    #[test]
    fn unroll_from_name_round_trips() {
        for u in [Unroll::None, Unroll::KeepOuter2, Unroll::KeepOuter1, Unroll::Full] {
            assert_eq!(Unroll::from_name(u.name()), Some(u));
        }
        assert_eq!(Unroll::from_name("bogus"), None);
    }

    #[test]
    fn pad_and_tile_names_round_trip() {
        for p in [PadMode::Auto, PadMode::Copy, PadMode::Padless] {
            assert_eq!(PadMode::from_name(p.name()), Some(p));
        }
        assert_eq!(PadMode::from_name("zeropad"), None);
        assert_eq!(TileMode::from_name("auto"), Some(TileMode::Auto));
        assert_eq!(TileMode::from_name("off"), Some(TileMode::Off));
        assert_eq!(TileMode::from_name("4"), Some(TileMode::Fixed(4)));
        assert_eq!(TileMode::from_name("17"), None);
    }

    /// Property over every option enum: `from_name(name()) == Some(self)`
    /// for the full value space (cache keys, bench labels and CLI flags
    /// all round-trip through these names).
    #[test]
    fn option_enum_names_round_trip() {
        for isa in [Isa::Generic, Isa::Sse3, Isa::Avx2, Isa::Neon, Isa::NeonVfpv3, Isa::NeonDot] {
            assert_eq!(Isa::from_name(isa.name()), Some(isa));
        }
        for d in [DType::F32, DType::Int8] {
            assert_eq!(DType::from_name(d.name()), Some(d));
        }
        assert_eq!(DType::from_name("int16"), None);
        for c in [ChanPad::Auto, ChanPad::Off] {
            assert_eq!(ChanPad::from_name(c.name()), Some(c));
        }
        assert_eq!(ChanPad::from_name("on"), None);
        let mut fuses = vec![FuseMode::Auto, FuseMode::Off];
        for n in 2..=8 {
            fuses.push(FuseMode::Depth(n));
        }
        for f in fuses {
            assert_eq!(FuseMode::from_name(&f.name()), Some(f), "{}", f.name());
        }
        assert_eq!(FuseMode::from_name("1"), Some(FuseMode::Off));
        assert_eq!(FuseMode::from_name("0"), None);
        assert_eq!(FuseMode::from_name("9"), None);
        assert_eq!(FuseMode::from_name("rings"), None);
        for u in [Unroll::None, Unroll::KeepOuter2, Unroll::KeepOuter1, Unroll::Full] {
            assert_eq!(Unroll::from_name(u.name()), Some(u));
        }
        for c in [ConstMode::Inline, ConstMode::Array] {
            assert_eq!(ConstMode::from_name(c.name()), Some(c));
        }
        for p in [PadMode::Auto, PadMode::Copy, PadMode::Padless] {
            assert_eq!(PadMode::from_name(p.name()), Some(p));
        }
        for a in [AlignMode::Auto, AlignMode::Off] {
            assert_eq!(AlignMode::from_name(a.name()), Some(a));
        }
        for r in [RolledMode::Auto, RolledMode::Off, RolledMode::Rotate, RolledMode::Expand] {
            assert_eq!(RolledMode::from_name(r.name()), Some(r));
        }
        assert_eq!(RolledMode::from_name("rolled"), None);
        assert_eq!(RolledMode::from_name("phases"), None);
        let mut tiles = vec![TileMode::Auto, TileMode::Off];
        for n in 2..=8 {
            tiles.push(TileMode::Fixed(n));
        }
        for r in 2..=4 {
            for c in 2..=8 {
                tiles.push(TileMode::Fixed2D(r, c));
            }
        }
        for t in tiles {
            assert_eq!(TileMode::from_name(&t.name()), Some(t), "{}", t.name());
        }
        // 2-D syntax normalizes and rejects out-of-range shapes.
        assert_eq!(TileMode::from_name("1x4"), Some(TileMode::Fixed(4)));
        assert_eq!(TileMode::from_name("2x4"), Some(TileMode::Fixed2D(2, 4)));
        assert_eq!(TileMode::from_name("5x4"), None);
        assert_eq!(TileMode::from_name("2x12"), None);
        assert_eq!(TileMode::from_name("2x"), None);
        assert_eq!(Isa::from_name("avx512"), None);
        assert_eq!(AlignMode::from_name("force"), None);
        assert_eq!(ConstMode::from_name("rom"), None);
    }

    #[test]
    fn neon_vfpv3_uses_nonfused_multiply_accumulate() {
        let opts = CodegenOptions { isa: Isa::NeonVfpv3, ..Default::default() };
        // Same Array-only constant rule as mainline NEON.
        assert_eq!(opts.effective_const_mode(), ConstMode::Array);
        for name in zoo::PAPER_MODELS {
            let src = gen(name, &opts);
            assert!(src.contains("#include <arm_neon.h>"), "{name}");
            assert!(src.contains("float32x4_t"), "{name}");
            assert!(src.contains("vmlaq_f32"), "{name}: pre-VFPv4 targets need vmlaq");
            assert!(!src.contains("vfmaq_f32"), "{name}: vfmaq_f32 needs VFPv4");
            assert!(!src.contains("vaddvq_f32"), "{name}: vaddvq_f32 is AArch64-only");
            assert!(!src.contains("_mm"), "{name}: x86 intrinsics must not leak");
            assert_eq!(src.matches('{').count(), src.matches('}').count(), "{name}");
        }
    }

    #[test]
    fn fused_emission_declares_ring_buffers_and_no_runtime_modulo() {
        let opts = CodegenOptions { fuse: FuseMode::Auto, ..CodegenOptions::sse3() };
        let src = gen("ball", &opts);
        // Post-optimize ball: [conv8, pool, conv12] fuse; conv2+softmax
        // head stays whole-plane.
        assert!(src.contains("/* fused group: layers 0..2"), "missing fused group marker");
        assert!(src.contains("float nncg_ring0["), "missing ring buffer for layer 0");
        assert!(src.contains("float nncg_ring1["), "missing ring buffer for layer 1");
        assert!(!src.contains("nncg_pad"), "fusion requires padless emission");
        // Ring slot arithmetic is resolved at generation time (no runtime %).
        assert!(!src.contains('%'), "fused output must contain no runtime modulo");
        assert_eq!(src.matches('{').count(), src.matches('}').count());
        // The default stays unfused and structurally unchanged.
        let plain = gen("ball", &CodegenOptions::sse3());
        assert!(!plain.contains("nncg_ring"));
        assert!(!plain.contains("fused group"));
    }

    #[test]
    fn fuse_depth_caps_group_size() {
        let opts = CodegenOptions { fuse: FuseMode::Depth(2), ..CodegenOptions::sse3() };
        let src = gen("ball", &opts);
        assert!(src.contains("/* fused group: layers 0..1"), "depth 2 must cap the chain");
        assert!(src.contains("float nncg_ring0["));
        assert!(!src.contains("nncg_ring1"), "a depth-2 group has a single interior edge");
    }

    #[test]
    fn fused_generates_balanced_for_all_paper_models_isas_and_unrolls() {
        for name in zoo::PAPER_MODELS {
            for unroll in [Unroll::KeepOuter2, Unroll::KeepOuter1] {
                for isa in [Isa::Generic, Isa::Sse3, Isa::Avx2, Isa::Neon] {
                    let opts =
                        CodegenOptions { isa, unroll, fuse: FuseMode::Auto, ..Default::default() };
                    let src = gen(name, &opts);
                    let open = src.matches('{').count();
                    let close = src.matches('}').count();
                    assert_eq!(open, close, "{name} {}: unbalanced braces", opts.tag());
                }
            }
        }
        // Loop form and full unroll silently fall back to whole-plane
        // emission (no ring buffers, still correct structure).
        for unroll in [Unroll::None, Unroll::Full] {
            let opts = CodegenOptions { unroll, fuse: FuseMode::Auto, ..CodegenOptions::sse3() };
            let src = gen("ball", &opts);
            assert!(!src.contains("nncg_ring"), "{}: no streaming outside kept-row unrolls", opts.tag());
        }
    }

    #[test]
    fn rolled_emission_emits_steady_state_loop() {
        use crate::graph::{Activation, Layer, Model, Padding};
        // 24-row planes with a pool inside: the schedule settles into a
        // steady state (period 4 ops x 3 ring phases, see schedule tests).
        let m = Model::new("rollnet", &[24, 10, 3])
            .push(Layer::conv2d(6, 3, 3, (1, 1), Padding::Same, Activation::Relu))
            .push(Layer::maxpool(2, 2))
            .push(Layer::conv2d(8, 3, 3, (1, 1), Padding::Same, Activation::None))
            .push(Layer::softmax())
            .with_random_weights(21);
        let rolled_opts = CodegenOptions { fuse: FuseMode::Auto, ..CodegenOptions::sse3() };
        let rolled = generate_c(&m, &rolled_opts).unwrap();
        assert!(rolled.contains("/* steady state:"), "missing steady-state marker");
        assert!(rolled.contains("for (i = 0; i <"), "missing the rolled row loop");
        // Match a ring *access* (base-pointer binding), not the static
        // declaration plan_buffers always emits.
        assert!(rolled.contains("s = nncg_ring"), "rolled body must still read the rings");
        assert!(!rolled.contains('%'), "rolled emission must stay free of runtime modulo");
        assert_eq!(rolled.matches('{').count(), rolled.matches('}').count());
        // The unrolled baseline emits the same groups, one block per row.
        let unrolled_opts = CodegenOptions {
            fuse: FuseMode::Auto,
            fuse_rolled: RolledMode::Off,
            ..CodegenOptions::sse3()
        };
        let unrolled = generate_c(&m, &unrolled_opts).unwrap();
        assert!(!unrolled.contains("/* steady state:"));
        assert!(unrolled.len() > rolled.len(), "rolling must shrink the generated C");
        assert_ne!(rolled_opts.tag(), unrolled_opts.tag());
    }

    #[test]
    fn rolled_and_unrolled_share_groups_and_scratch() {
        // The partition (and therefore every buffer) must not depend on the
        // emission form — that is what makes all four forms bit-comparable.
        for name in zoo::PAPER_MODELS {
            let m = zoo::by_name(name).unwrap().with_random_weights(9);
            let auto = scratch_report(&m, &CodegenOptions { fuse: FuseMode::Auto, ..CodegenOptions::sse3() }).unwrap();
            for mode in [RolledMode::Off, RolledMode::Rotate, RolledMode::Expand] {
                let other = scratch_report(
                    &m,
                    &CodegenOptions {
                        fuse: FuseMode::Auto,
                        fuse_rolled: mode,
                        ..CodegenOptions::sse3()
                    },
                )
                .unwrap();
                assert_eq!(auto, other, "{name}: scratch plan must ignore the rolled knob ({})", mode.name());
            }
        }
    }

    #[test]
    fn rotated_emission_collapses_body_and_rotates_pointers() {
        // Robot group [0..4) has 3 ring phases: the expanded body carries
        // 15 row-ops, the rotated body the bare 5-op pattern plus the
        // pointer rotation block. Auto must pick rotation.
        let rotate = gen("robot", &CodegenOptions {
            fuse: FuseMode::Auto,
            fuse_rolled: RolledMode::Rotate,
            ..CodegenOptions::sse3()
        });
        assert!(rotate.contains("one op-pattern period; rotated ring pointers"));
        assert!(rotate.contains("float *nncg_ring0_r0 = nncg_ring0"), "missing ring pointer decls");
        assert!(rotate.contains("/* rotate ring row pointers"), "missing rotation block");
        assert!(rotate.contains("/* rolled ramp:"), "robot warm-up ramps must roll");
        assert!(!rotate.contains('%'), "rotation must stay free of runtime modulo");
        assert_eq!(rotate.matches('{').count(), rotate.matches('}').count());
        let auto = gen("robot", &CodegenOptions { fuse: FuseMode::Auto, ..CodegenOptions::sse3() });
        assert_eq!(auto, rotate, "auto must prefer rotation when it verifies");
        let expand = gen("robot", &CodegenOptions {
            fuse: FuseMode::Auto,
            fuse_rolled: RolledMode::Expand,
            ..CodegenOptions::sse3()
        });
        assert!(expand.contains("ring phases included; frozen ring slots"));
        assert!(!expand.contains("nncg_ring0_r0"), "expanded body must not rotate pointers");
        assert!(rotate.len() < expand.len(), "rotation must shrink the generated C");
        // All three tags are distinct (cache keys, bench labels).
        let tags: Vec<String> = [RolledMode::Auto, RolledMode::Rotate, RolledMode::Expand, RolledMode::Off]
            .iter()
            .map(|&m| CodegenOptions { fuse: FuseMode::Auto, fuse_rolled: m, ..CodegenOptions::sse3() }.tag())
            .collect();
        let mut uniq = tags.clone();
        uniq.sort();
        uniq.dedup();
        assert_eq!(uniq.len(), tags.len(), "rolled modes must tag distinctly: {tags:?}");
    }

    #[test]
    fn robot_and_pedestrian_fuse_full_depth_without_budget_splits() {
        // The statement budget used to fragment these models' chains
        // (robot: [0,2) [2,3) [3,4) [4,6) [6,7)); periodic-eligible groups
        // skip it, so both now fuse at the full depth cap.
        let opts = CodegenOptions { fuse: FuseMode::Auto, ..CodegenOptions::sse3() };
        let robot = gen("robot", &opts);
        assert!(robot.contains("/* fused group: layers 0..3"), "robot group [0,4) missing");
        assert!(robot.contains("/* fused group: layers 4..6"), "robot group [4,7) missing");
        assert_eq!(robot.matches("/* fused group:").count(), 2, "robot must form exactly two groups");
        assert!(robot.contains("/* steady state:"), "robot groups must roll");
        let ped = gen("pedestrian", &opts);
        assert!(ped.contains("/* fused group: layers 0..3"), "pedestrian group [0,4) missing");
        assert!(ped.contains("/* fused group: layers 4..5"), "pedestrian group [4,6) missing");
        assert_eq!(ped.matches("/* fused group:").count(), 2, "pedestrian must form exactly two groups");
        assert!(ped.contains("/* steady state:"), "pedestrian groups must roll");
    }

    #[test]
    fn scratch_report_shrinks_under_fusion() {
        for name in zoo::PAPER_MODELS {
            let m = zoo::by_name(name).unwrap().with_random_weights(5);
            let unfused = scratch_report(&m, &CodegenOptions::sse3()).unwrap();
            let fused = scratch_report(
                &m,
                &CodegenOptions { fuse: FuseMode::Auto, ..CodegenOptions::sse3() },
            )
            .unwrap();
            assert_eq!(unfused.ring_count, 0);
            assert!(fused.ring_count >= 1, "{name}: expected at least one fused group");
            assert!(
                fused.total_bytes() < unfused.total_bytes(),
                "{name}: fused {} must beat unfused {}",
                fused.total_bytes(),
                unfused.total_bytes()
            );
            // Every ring buffer together stays below one whole-plane
            // ping-pong buffer: O(k_h*W*C) vs O(H*W*C).
            assert!(fused.ring_floats < unfused.main_floats, "{name}");
        }
    }

    #[test]
    fn neon_emits_arm_intrinsics_with_weight_arrays() {
        let opts = CodegenOptions { isa: Isa::Neon, ..Default::default() };
        // NEON has no lane-literal constructor; const mode must resolve to
        // Array whatever the default says.
        assert_eq!(opts.effective_const_mode(), ConstMode::Array);
        for name in zoo::PAPER_MODELS {
            let src = gen(name, &opts);
            assert!(src.contains("#include <arm_neon.h>"), "{name}: missing NEON header");
            assert!(src.contains("float32x4_t"), "{name}");
            assert!(src.contains("vfmaq_f32"), "{name}: interior must use fused multiply-add");
            assert!(src.contains("vld1q_f32"), "{name}");
            assert!(src.contains("vst1q_f32"), "{name}");
            assert!(src.contains("static NNCG_ALIGN(32) const float w0["), "{name}: weights must be arrays");
            assert!(!src.contains("_mm"), "{name}: x86 intrinsics must not leak into NEON output");
            let open = src.matches('{').count();
            let close = src.matches('}').count();
            assert_eq!(open, close, "{name}: unbalanced braces");
        }
    }

    #[test]
    fn aligned_loads_for_static_buffers_loadu_for_caller_pointers() {
        use crate::graph::{Activation, Layer, Model, Padding};
        // Layer 0 (maxpool) vector-loads x_in — alignment unknown, must
        // stay loadu; layer 1 reads the aligned scratch buffer — interior
        // segments use aligned loads; the final store hits x_out — storeu.
        let m = Model::new("alignnet", &[8, 8, 8])
            .push(Layer::maxpool(2, 2))
            .push(Layer::conv2d(8, 3, 3, (1, 1), Padding::Same, Activation::Relu))
            .push(Layer::maxpool(2, 2))
            .with_random_weights(11);
        let opts = CodegenOptions { isa: Isa::Avx2, ..Default::default() };
        let src = generate_c(&m, &opts).unwrap();
        assert!(src.contains("NNCG_ALIGN(32)"), "buffers must carry the alignment attribute");
        assert!(src.contains("_mm256_loadu_ps("), "x_in loads must stay unaligned");
        assert!(src.contains("_mm256_load_ps("), "interior loads from static buffers must be aligned");
        assert!(src.contains("_mm256_store_ps("), "stores to static buffers must be aligned");
        assert!(src.contains("_mm256_storeu_ps("), "x_out stores must stay unaligned");

        // The ablation baseline: no attribute, no aligned ops anywhere.
        let off = CodegenOptions { align: AlignMode::Off, ..opts };
        let src = generate_c(&m, &off).unwrap();
        assert!(!src.contains("NNCG_ALIGN"));
        assert!(!src.contains("_mm256_load_ps("));
        assert!(!src.contains("_mm256_store_ps("));
    }

    #[test]
    fn odd_channels_keep_unaligned_loads_in_undivisible_segments() {
        use crate::graph::{Layer, Model};
        // c = 6 under SSE: spatial offsets step by 6, which 4 does not
        // divide — even static-buffer loads must stay loadu.
        let m = Model::new("oddalign", &[8, 8, 6])
            .push(Layer::maxpool(2, 2))
            .push(Layer::maxpool(2, 2))
            .with_random_weights(3);
        let src = generate_c(&m, &CodegenOptions::sse3()).unwrap();
        assert!(src.contains("_mm_loadu_ps("));
        assert!(!src.contains("_mm_load_ps("), "c=6 layers must not claim alignment");
    }

    #[test]
    fn tile_2d_emits_row_blocked_interior() {
        // ball conv1: 8x8 output, interior rows [1, 7) — a 2x4 block
        // covers the 6 interior rows in three row-pair steps with no
        // remainder loop.
        let opts = CodegenOptions { tile: TileMode::Fixed2D(2, 4), ..CodegenOptions::sse3() };
        let src = gen("ball", &opts);
        assert!(
            src.contains("for (i = 1; i + 2 <= 7; i += 2)"),
            "expected the 2-row interior block loop"
        );
        assert!(src.contains("wv = "), "2-D blocks are weight-stationary");
        // 2 rows x 4 cols = 8 accumulator sets share each weight vector.
        assert!(src.contains("a7_0"), "expected 8 live accumulator cells");
        assert_eq!(src.matches('{').count(), src.matches('}').count());
        // 1-D tiling keeps the single-row walk.
        let src_1d = gen("ball", &CodegenOptions { tile: TileMode::Fixed(4), ..CodegenOptions::sse3() });
        assert!(src_1d.contains("for (i = 1; i < 7; i++)"));
        assert!(!src_1d.contains("a4_0"));
    }

    #[test]
    fn tile_2d_row_remainder_gets_single_row_loop() {
        use crate::graph::{Activation, Layer, Model, Padding};
        // 9x9 stride-1 k3 Same: interior rows [1, 8) = 7 rows; 3x4 blocks
        // cover 6, leaving one remainder row walked singly.
        let m = Model::new("rowrem", &[9, 9, 4])
            .push(Layer::conv2d(4, 3, 3, (1, 1), Padding::Same, Activation::None))
            .with_random_weights(8);
        let opts = CodegenOptions { tile: TileMode::Fixed2D(3, 4), ..CodegenOptions::sse3() };
        let src = generate_c(&m, &opts).unwrap();
        assert!(src.contains("for (i = 1; i + 3 <= 8; i += 3)"), "main 3-row block loop");
        assert!(src.contains("for (i = 7; i < 8; i++)"), "remainder row loop");
        assert_eq!(src.matches('{').count(), src.matches('}').count());
    }

    #[test]
    fn tile_2d_generates_for_all_paper_models_and_unrolls() {
        // Full unroll is covered on the small net only (`ball`); the big
        // models would trip the statement-count guard there.
        for name in zoo::PAPER_MODELS {
            for unroll in [Unroll::None, Unroll::KeepOuter2, Unroll::KeepOuter1] {
                for isa in [Isa::Sse3, Isa::Avx2, Isa::Neon] {
                    let opts = CodegenOptions {
                        isa,
                        unroll,
                        tile: TileMode::Fixed2D(2, 4),
                        ..Default::default()
                    };
                    let src = gen(name, &opts);
                    let open = src.matches('{').count();
                    let close = src.matches('}').count();
                    assert_eq!(open, close, "{name} {}: unbalanced braces", opts.tag());
                }
            }
        }
        for isa in [Isa::Sse3, Isa::Avx2, Isa::Neon] {
            let opts = CodegenOptions {
                isa,
                unroll: Unroll::Full,
                tile: TileMode::Fixed2D(2, 4),
                ..Default::default()
            };
            let src = gen("ball", &opts);
            assert_eq!(src.matches('{').count(), src.matches('}').count(), "{}", opts.tag());
        }
    }

    #[test]
    fn padless_default_emits_no_pad_buffer() {
        // ball + robot both have Same-padded convs; under the default
        // (Auto → padless) the scratch pad must be gone entirely.
        for opts in [CodegenOptions::sse3(), CodegenOptions::general(), CodegenOptions::sse3_full_unroll()] {
            let src = gen("ball", &opts);
            assert!(!src.contains("nncg_pad"), "ball {}: padless mode must not reference nncg_pad", opts.tag());
        }
        let src = gen("robot", &CodegenOptions::sse3());
        assert!(!src.contains("nncg_pad"), "robot: padless mode must not reference nncg_pad");
    }

    #[test]
    fn pad_copy_mode_still_materializes() {
        let opts = CodegenOptions { pad_mode: PadMode::Copy, ..CodegenOptions::sse3() };
        let src = gen("ball", &opts);
        assert!(src.contains("static float nncg_pad["));
        assert!(src.contains("/* zero-pad"));
        // Loop form always takes the copy, whatever the knob says.
        let loops = CodegenOptions { unroll: Unroll::None, pad_mode: PadMode::Padless, ..CodegenOptions::sse3() };
        let src = gen("ball", &loops);
        assert!(src.contains("nncg_pad"));
    }

    #[test]
    fn odd_channels_keep_vector_body_under_sse_and_avx2() {
        // c_out = 6: one 4-wide SSE group + 2 scalar lanes. The paper's
        // original rule would have dropped the whole layer to scalar.
        use crate::graph::{Activation, Layer, Padding};
        let m = Model::new("oddc", &[8, 8, 3])
            .push(Layer::conv2d(6, 3, 3, (1, 1), Padding::Same, Activation::Relu))
            .push(Layer::conv2d(10, 3, 3, (2, 2), Padding::Same, Activation::None))
            .push(Layer::softmax())
            .with_random_weights(5);
        for isa in [Isa::Sse3, Isa::Avx2] {
            let opts = CodegenOptions { isa, ..Default::default() };
            let src = generate_c(&m, &opts).unwrap();
            let pfx = if isa == Isa::Avx2 { "_mm256_" } else { "_mm_" };
            assert!(src.contains(&format!("{pfx}loadu_ps")) || src.contains(&format!("{pfx}setr_ps")),
                "{isa:?}: expected vector intrinsics for odd channel counts");
            // Scalar remainder lanes exist too.
            assert!(src.contains("float a ="), "{isa:?}: expected scalar tail lanes");
        }
    }

    #[test]
    fn tiled_emission_shares_weight_registers() {
        // Interior columns of ball conv1 are wide enough for a 4-block;
        // the weight-stationary form materializes `wv` once per tap.
        let opts = CodegenOptions { tile: TileMode::Fixed(4), ..CodegenOptions::sse3() };
        let src = gen("ball", &opts);
        assert!(src.contains("wv = "), "expected weight-stationary register in tiled emission");
        let untiled = gen("ball", &CodegenOptions { tile: TileMode::Off, ..CodegenOptions::sse3() });
        assert!(!untiled.contains("wv = "));
        // Tiling must not change the statement estimator's verdict or brace balance.
        assert_eq!(src.matches('{').count(), src.matches('}').count());
    }

    #[test]
    fn pad_modes_and_tiles_generate_for_all_paper_models() {
        for name in zoo::PAPER_MODELS {
            for pad_mode in [PadMode::Auto, PadMode::Copy, PadMode::Padless] {
                for tile in [TileMode::Auto, TileMode::Off, TileMode::Fixed(2)] {
                    for unroll in [Unroll::None, Unroll::KeepOuter2, Unroll::KeepOuter1] {
                        let opts = CodegenOptions { pad_mode, tile, unroll, ..Default::default() };
                        let src = gen(name, &opts);
                        let open = src.matches('{').count();
                        let close = src.matches('}').count();
                        assert_eq!(open, close, "{name} {}: unbalanced braces", opts.tag());
                    }
                }
            }
        }
    }
}
