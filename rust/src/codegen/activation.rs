//! Standalone activation emitters (ReLU / leaky ReLU after non-conv layers,
//! softmax heads) and the buffer-copy helper.

use super::conv::scalar_act;
use super::cwriter::{fmt_f32, CWriter};
use super::schedule;
use super::simd::{emit_vec_activation, ChannelSchedule};
use super::{LayerCtx, Unroll};
use crate::graph::Activation;
use anyhow::Result;

pub(crate) fn emit_activation(w: &mut CWriter, ctx: &LayerCtx<'_>, act: Activation) -> Result<()> {
    let n = ctx.in_shape.numel();
    match act {
        Activation::None => {
            if ctx.src != ctx.dst {
                emit_copy(w, ctx);
            }
        }
        Activation::Softmax => {
            if ctx.src != ctx.dst {
                emit_copy(w, ctx);
            }
            emit_softmax_over(w, ctx, ctx.dst, n);
        }
        Activation::Relu | Activation::LeakyRelu(_) => {
            // Elementwise over the flat buffer, lane-scheduled: vector
            // groups over the divisible prefix, scalar remainder tail.
            // Flat offsets step by the width from a width-multiple start,
            // so a static buffer alone proves alignment.
            let sched = ChannelSchedule::for_channels(ctx.opts.isa, n);
            let s_al = ctx.opts.use_aligned() && schedule::static_buf(ctx.src);
            let d_al = ctx.opts.use_aligned() && schedule::static_buf(ctx.dst);
            if ctx.opts.unroll == Unroll::Full {
                for seg in &sched.segments {
                    if let Some(v) = seg.vec {
                        for i0 in (seg.start..seg.end()).step_by(v.width) {
                            w.open("");
                            w.line(&format!(
                                "{} a = {};",
                                v.ty,
                                v.load(&format!("{} + {i0}", ctx.src), s_al && i0 % v.width == 0)
                            ));
                            emit_vec_activation(w, v, act, "a");
                            w.line(&v.store(&format!("{} + {i0}", ctx.dst), "a", d_al && i0 % v.width == 0));
                            w.close();
                        }
                    } else {
                        for i in seg.start..seg.end() {
                            let val = format!("{}[{i}]", ctx.src);
                            w.line(&format!("{}[{i}] = {};", ctx.dst, scalar_act(&val, act)));
                        }
                    }
                }
            } else {
                for seg in &sched.segments {
                    if seg.len == 0 {
                        continue;
                    }
                    if let Some(v) = seg.vec {
                        let seg_al = seg.start % v.width == 0;
                        w.open(&format!("for (i = {}; i < {}; i += {})", seg.start, seg.end(), v.width));
                        w.line(&format!("{} a = {};", v.ty, v.load(&format!("{} + i", ctx.src), s_al && seg_al)));
                        emit_vec_activation(w, v, act, "a");
                        w.line(&v.store(&format!("{} + i", ctx.dst), "a", d_al && seg_al));
                        w.close();
                    } else {
                        w.open(&format!("for (i = {}; i < {}; i++)", seg.start, seg.end()));
                        let val = format!("{}[i]", ctx.src);
                        w.line(&format!("{}[i] = {};", ctx.dst, scalar_act(&val, act)));
                        w.close();
                    }
                }
            }
        }
    }
    Ok(())
}

/// One constant-coordinate row of a standalone elementwise activation
/// inside a row-streaming fusion group: `w*c` lane-scheduled elements read
/// from the source row (ring slot, plane row, or rotating ring pointer)
/// and written to the destination row, with plane bases additionally
/// advancing `io.*_iter_elems` floats per steady-state loop iteration `i`
/// (0 outside rolled loops). (Softmax never fuses — it normalizes over
/// the whole map.)
pub(crate) fn emit_activation_row_fused(
    w: &mut CWriter,
    ctx: &LayerCtx<'_>,
    act: Activation,
    io: &schedule::FusedRowIo,
) -> Result<()> {
    debug_assert!(act != Activation::Softmax, "softmax heads are never fused");
    let n = ctx.in_shape.w() * ctx.in_shape.c();
    let sched = ChannelSchedule::for_channels(ctx.opts.isa, n);
    // The single source row of a 1x1/stride-1 member is the output row.
    let src_row_off = match &io.src_rot {
        Some(_) => 0,
        None => io.src_map.off(io.out_row),
    };
    let dst_row_off = io.dst_row_off;
    // Rolled loop terms / rotating pointers keep the alignment proofs
    // only under the shared claim rule.
    let s_al = ctx.opts.use_aligned() && io.src_claims_aligned(ctx.src);
    let d_al = ctx.opts.use_aligned() && io.dst_claims_aligned(ctx.dst);
    let src_base = match &io.src_rot {
        Some(rot) => rot.names[0].clone(),
        None => schedule::fused_base(ctx.src, src_row_off, io.src_iter_elems),
    };
    let dst_base = match &io.dst_rot {
        Some(rot) => rot.names[0].clone(),
        None => schedule::fused_base(ctx.dst, dst_row_off, io.dst_iter_elems),
    };
    for seg in &sched.segments {
        if seg.len == 0 {
            continue;
        }
        if let Some(v) = seg.vec {
            let seg_al = seg.start % v.width == 0;
            let load_al = s_al && seg_al && src_row_off % v.width == 0;
            let store_al = d_al && seg_al && dst_row_off % v.width == 0;
            w.open(&format!("for (k = {}; k < {}; k += {})", seg.start, seg.end(), v.width));
            w.line(&format!("{} a = {};", v.ty, v.load(&format!("{src_base} + k"), load_al)));
            emit_vec_activation(w, v, act, "a");
            w.line(&v.store(&format!("{dst_base} + k"), "a", store_al));
            w.close();
        } else {
            w.open(&format!("for (k = {}; k < {}; k++)", seg.start, seg.end()));
            // `fused_base` parenthesizes compound forms, so indexing the
            // base expression directly is precedence-safe.
            let val = format!("{src_base}[k]");
            w.line(&format!("{dst_base}[k] = {};", scalar_act(&val, act)));
            w.close();
        }
    }
    Ok(())
}

/// Copy `numel` floats from src to dst.
pub(crate) fn emit_copy(w: &mut CWriter, ctx: &LayerCtx<'_>) {
    let n = ctx.in_shape.numel();
    if ctx.opts.unroll == Unroll::Full {
        for i in 0..n {
            w.line(&format!("{}[{i}] = {}[{i}];", ctx.dst, ctx.src));
        }
    } else {
        w.open(&format!("for (i = 0; i < {n}; i++)"));
        w.line(&format!("{}[i] = {}[i];", ctx.dst, ctx.src));
        w.close();
    }
}

/// Numerically-stable softmax computed in place over `buf[0..n]`.
///
/// Uses `exp` from math.h (ANSI C89 has no `expf`); the cast keeps single
/// precision. The head maps are tiny (1×1×2 for the paper's classifiers),
/// so this is never on the profile.
pub(crate) fn emit_softmax_over(w: &mut CWriter, ctx: &LayerCtx<'_>, buf: &str, n: usize) {
    w.line("/* softmax (numerically stable) */");
    if ctx.opts.unroll == Unroll::Full {
        w.open("");
        w.line(&format!("float mx = {buf}[0];"));
        w.line(&format!("float sum = {};", fmt_f32(0.0)));
        for i in 1..n {
            w.line(&format!("mx = {buf}[{i}] > mx ? {buf}[{i}] : mx;"));
        }
        for i in 0..n {
            w.line(&format!("{buf}[{i}] = (float)exp((double)({buf}[{i}] - mx));"));
            w.line(&format!("sum += {buf}[{i}];"));
        }
        for i in 0..n {
            w.line(&format!("{buf}[{i}] /= sum;"));
        }
        w.close();
    } else {
        w.open("");
        w.line(&format!("float mx = {buf}[0];"));
        w.line("float sum = 0.0f;");
        w.open(&format!("for (i = 1; i < {n}; i++)"));
        w.line(&format!("mx = {buf}[i] > mx ? {buf}[i] : mx;"));
        w.close();
        w.open(&format!("for (i = 0; i < {n}; i++)"));
        w.line(&format!("{buf}[i] = (float)exp((double)({buf}[i] - mx));"));
        w.line(&format!("sum += {buf}[i];"));
        w.close();
        w.open(&format!("for (i = 0; i < {n}; i++)"));
        w.line(&format!("{buf}[i] /= sum;"));
        w.close();
        w.close();
    }
}
