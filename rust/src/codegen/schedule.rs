//! Per-layer emission planning: padding strategy, register-tile width, and
//! the spatial region split that powers padless emission.
//!
//! Everything here is resolved at *generation* time (principle P3): the
//! planner looks at layer geometry plus [`CodegenOptions`] and hands the
//! emitters a fully-static plan — which columns are interior (full kernel
//! in bounds), which border rows/columns need edge-trimmed taps, how many
//! output pixels share one register tile, and how many vector channel
//! groups may be live per emitted chunk.

use super::simd::ChannelSchedule;
use super::{CodegenOptions, PadMode, RolledMode, TileMode, Unroll};

/// Resolved padding strategy for one Same-padded layer.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub(crate) enum PadStrategy {
    /// Materialize the zero-padded input (Eq. 1) into `nncg_pad`.
    Copy,
    /// Region-split emission: no scratch buffer, out-of-bounds taps are
    /// dropped by the generator (they multiply zeros anyway).
    Padless,
}

/// The padding strategy these options give every Same-padded layer.
///
/// `Unroll::None` keeps the kernel loops symbolic, so taps cannot be
/// dropped per-region without emitting branches; it always takes the copy.
/// Mirrored by `plan_buffers`, which sizes `nncg_pad` only when this
/// returns [`PadStrategy::Copy`].
pub(crate) fn pad_strategy(opts: &CodegenOptions) -> PadStrategy {
    match opts.pad_mode {
        PadMode::Copy => PadStrategy::Copy,
        PadMode::Auto | PadMode::Padless => {
            if opts.unroll == Unroll::None {
                PadStrategy::Copy
            } else {
                PadStrategy::Padless
            }
        }
    }
}

/// Register-block shape `(rows, cols)` for a conv-like layer: how many
/// interior output pixels share one weight-stationary register tile.
/// `(1, 1)` = untiled. Rows grow only under [`TileMode::Fixed2D`] and only
/// when the unroll level keeps the spatial row loop (`KeepOuter1/2`) —
/// border rows and full unroll always walk single rows.
pub(crate) fn tile_shape(
    opts: &CodegenOptions,
    sched: &ChannelSchedule,
    interior_rows: usize,
    interior_cols: usize,
) -> (usize, usize) {
    // Loop form keeps the kernel/channel loops symbolic — no layer type
    // can tile there, whatever the knob says.
    if opts.unroll == Unroll::None {
        return (1, 1);
    }
    let cols = match opts.tile {
        TileMode::Off => 1,
        TileMode::Fixed(n) | TileMode::Fixed2D(_, n) => n.clamp(1, 8).min(interior_cols.max(1)),
        TileMode::Auto => {
            if !sched.has_vector() {
                1
            } else if interior_cols >= 4 {
                4
            } else if interior_cols >= 2 {
                2
            } else {
                1
            }
        }
    };
    let rows = match opts.tile {
        TileMode::Fixed2D(r, _)
            if matches!(opts.unroll, Unroll::KeepOuter1 | Unroll::KeepOuter2) =>
        {
            r.clamp(1, 4).min(interior_rows.max(1))
        }
        _ => 1,
    };
    (rows, cols)
}

/// Backwards-compatible 1-D view of [`tile_shape`] (column width only).
#[cfg(test)]
pub(crate) fn tile_width(opts: &CodegenOptions, sched: &ChannelSchedule, interior_cols: usize) -> usize {
    tile_shape(opts, sched, 1, interior_cols).1
}

/// True when a C buffer expression names a generator-owned static buffer
/// (emitted with `NNCG_ALIGN(32)` when alignment is on) rather than a
/// caller pointer whose alignment is unknown.
pub(crate) fn static_buf(name: &str) -> bool {
    name != "x_in" && name != "x_out"
}

/// Max vector channel-groups per emitted chunk so one block's live
/// registers — `block` broadcast registers + 1 weight register +
/// `block·groups` accumulators — fit a 16-register file with a scratch
/// register to spare.
pub(crate) fn max_groups_per_chunk(block: usize) -> usize {
    if block <= 1 {
        // Input-stationary single-cell form: 1 broadcast + G accumulators.
        8
    } else {
        // Saturate: 2-D blocks can exceed the register file (block > 14);
        // they still emit correctly with one group per chunk, spilling.
        (14usize.saturating_sub(block) / block).clamp(1, 8)
    }
}

/// One spatial axis of a conv-like layer, split into edge regions (output
/// coordinates whose kernel window hangs past the source) and an interior.
///
/// For copy-mode emission the source is the padded buffer, every window is
/// in bounds, and the split degenerates to "all interior".
#[derive(Debug, Clone, Copy)]
pub(crate) struct AxisPlan {
    /// Output extent along this axis.
    pub out: usize,
    /// Stride along this axis.
    pub stride: usize,
    /// Kernel extent along this axis.
    pub kernel: usize,
    /// Leading zero-pad resolved away at generation time.
    pub pad: usize,
    /// Source extent along this axis.
    pub input: usize,
    /// Output coords [0, lo) are leading-edge border cells.
    pub lo: usize,
    /// Output coords [hi, out) are trailing-edge border cells.
    pub hi: usize,
}

impl AxisPlan {
    /// Padless split: interior coords see the full kernel window inside
    /// the unpadded source.
    pub fn padless(out: usize, stride: usize, kernel: usize, pad: usize, input: usize) -> AxisPlan {
        let lo = crate::util::div_ceil(pad, stride).min(out);
        let hi = if input + pad >= kernel {
            (((input + pad - kernel) / stride) + 1).clamp(lo, out)
        } else {
            lo
        };
        AxisPlan { out, stride, kernel, pad, input, lo, hi }
    }

    /// Copy-mode split over an already-padded source of extent `input`:
    /// no border regions at all.
    pub fn full(out: usize, stride: usize, kernel: usize, input: usize) -> AxisPlan {
        debug_assert!(out == 0 || (out - 1) * stride + kernel <= input);
        AxisPlan { out, stride, kernel, pad: 0, input, lo: 0, hi: out }
    }

    /// Valid kernel-tap range `[k0, k1)` for output coordinate `i`.
    pub fn window(&self, i: usize) -> (usize, usize) {
        let base = i * self.stride;
        let k0 = self.pad.saturating_sub(base);
        let k1 = self.kernel.min((self.input + self.pad).saturating_sub(base));
        (k0, k1.max(k0))
    }

    /// Source coordinate of the first valid tap of output coordinate `i`.
    pub fn src_start(&self, i: usize) -> usize {
        i * self.stride + self.window(i).0 - self.pad
    }

    /// Number of interior output coordinates.
    pub fn interior(&self) -> usize {
        self.hi - self.lo
    }
}

/// Generation-time mapping from a logical plane row index to an element
/// offset inside the buffer holding it: whole planes store rows linearly;
/// ring line buffers store row `r` in slot `r % rows`. All modular
/// arithmetic happens here, at generation time — the emitted C only ever
/// sees resolved integer offsets (no runtime `%`).
#[derive(Debug, Clone, Copy)]
pub(crate) enum RowMap {
    Plane { row_elems: usize },
    Ring { rows: usize, row_elems: usize },
}

impl RowMap {
    pub fn off(&self, row: usize) -> usize {
        match *self {
            RowMap::Plane { row_elems } => row * row_elems,
            RowMap::Ring { rows, row_elems } => (row % rows.max(1)) * row_elems,
        }
    }
}

/// One step of a fusion group's row schedule: compute output row `row` of
/// group member `layer` (index within the group).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub(crate) struct RowOp {
    pub layer: usize,
    pub row: usize,
}

/// A fusion group's resolved row schedule plus the ring-buffer height of
/// every interior edge (`ring_rows[e]` holds the output of group member
/// `e`, read by member `e + 1`).
#[derive(Debug, Clone)]
pub(crate) struct GroupLayout {
    pub ops: Vec<RowOp>,
    pub ring_rows: Vec<usize>,
}

/// Demand-driven row schedule for a fusion group described by one row-axis
/// [`AxisPlan`] per member (member 0 reads the group's input plane). Every
/// member's rows are produced in strictly increasing order, each exactly
/// once, and a row is produced only when the next consumer row needs it —
/// so the set of simultaneously-live producer rows stays bounded by the
/// consumer's kernel window.
pub(crate) fn schedule_group_rows(plans: &[AxisPlan]) -> Vec<RowOp> {
    fn produce(j: usize, r: usize, plans: &[AxisPlan], next: &mut [usize], ops: &mut Vec<RowOp>) {
        while next[j] <= r {
            let rr = next[j];
            if j > 0 {
                let (k0, k1) = plans[j].window(rr);
                if k1 > k0 {
                    let last_needed = plans[j].src_start(rr) + (k1 - k0) - 1;
                    produce(j - 1, last_needed, plans, next, ops);
                }
            }
            ops.push(RowOp { layer: j, row: rr });
            next[j] = rr + 1;
        }
    }
    let n = plans.len();
    let mut next = vec![0usize; n];
    let mut ops = Vec::new();
    for r in 0..plans[n - 1].out {
        produce(n - 1, r, plans, &mut next, &mut ops);
    }
    ops
}

/// Smallest ring height for edge `e` such that no row is overwritten
/// (slot `row % rows`) before its last read: for every produced row `q`,
/// the row `q + R` sharing its slot must be produced only after `q`'s
/// final read in the schedule.
fn ring_rows_for_edge(ops: &[RowOp], plans: &[AxisPlan], e: usize) -> usize {
    let produced = plans[e].out;
    let consumer = &plans[e + 1];
    let mut t_produce = vec![usize::MAX; produced];
    let mut t_last_read = vec![0usize; produced];
    for (t, op) in ops.iter().enumerate() {
        if op.layer == e {
            t_produce[op.row] = t;
        } else if op.layer == e + 1 {
            let (k0, k1) = consumer.window(op.row);
            let start = consumer.src_start(op.row);
            for q in start..start + (k1 - k0) {
                t_last_read[q] = t;
            }
        }
    }
    (1..=produced)
        .find(|&r| {
            (0..produced).all(|q| {
                q + r >= produced
                    || t_produce[q + r] == usize::MAX
                    || t_last_read[q] == 0
                    || t_produce[q + r] > t_last_read[q]
            })
        })
        .unwrap_or_else(|| produced.max(1))
}

/// Schedule a fusion group and size every interior ring buffer.
pub(crate) fn plan_group_rows(plans: &[AxisPlan]) -> GroupLayout {
    let ops = schedule_group_rows(plans);
    let ring_rows = (0..plans.len().saturating_sub(1))
        .map(|e| ring_rows_for_edge(&ops, plans, e))
        .collect();
    GroupLayout { ops, ring_rows }
}

/// Steady-state decomposition of a fusion group's row schedule:
/// `ops[..body_start]` is the warm-up prologue (emitted unrolled),
/// `ops[body_start .. body_start + ops_per_iter]` is the loop body pattern,
/// repeated `iters` times with member `j`'s rows advancing `row_delta[j]`
/// per iteration, and `ops[epilogue_start..]` drains the remaining (mostly
/// border) rows unrolled. Guaranteed by [`detect_periodic`]:
///
/// * replaying prologue + `iters` shifted copies of the body + epilogue
///   reproduces the schedule exactly (every row once, in order);
/// * every row covered by the loop keeps the full, untrimmed kernel
///   window, so one emitted body is valid for all iterations;
/// * ring-slot assignments are identical across iterations (`row_delta`
///   is a multiple of every ring height the op touches), so ring offsets
///   resolved at generation time stay correct — the body contains one
///   copy of the op pattern per ring phase, `ops_per_iter / pattern`
///   phases total, and the emitted C needs no runtime `%`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub(crate) struct PeriodicLayout {
    pub body_start: usize,
    pub ops_per_iter: usize,
    pub iters: usize,
    pub row_delta: Vec<usize>,
    pub epilogue_start: usize,
}

impl PeriodicLayout {
    /// Ops the rolled emission writes out (prologue + one body + epilogue)
    /// — the unrolled schedule writes `ops.len()` of them.
    pub fn emitted_ops(&self, total: usize) -> usize {
        self.body_start + self.ops_per_iter + (total - self.epilogue_start)
    }
}

/// Regular region `[r0, r1)` of a schedule: ops whose kernel window is
/// untrimmed. Rows ascend per member, so trimmed tops all precede `r0` and
/// the first trimmed bottom row caps `r1`.
fn regular_region(ops: &[RowOp], plans: &[AxisPlan]) -> (usize, usize) {
    let mut r0 = 0;
    for (t, op) in ops.iter().enumerate() {
        if op.row < plans[op.layer].lo {
            r0 = t + 1;
        }
    }
    let mut r1 = ops.len();
    for (t, op) in ops.iter().enumerate().skip(r0) {
        if op.row >= plans[op.layer].hi {
            r1 = t;
            break;
        }
    }
    (r0, r1)
}

/// Largest `a` in `[r0, r1 - p]` with `ops[t + p] == shift(ops[t])` for
/// all `t in [a, r1 - p)`, where the shift is a per-member constant
/// positive row delta.
fn pattern_suffix(ops: &[RowOp], r0: usize, r1: usize, p: usize, n: usize) -> usize {
    let mut delta: Vec<Option<usize>> = vec![None; n];
    let mut a = r1 - p;
    while a > r0 {
        let x = ops[a - 1];
        let y = ops[a - 1 + p];
        if x.layer != y.layer || y.row <= x.row {
            break;
        }
        let d = y.row - x.row;
        match delta[x.layer] {
            Some(prev) if prev != d => break,
            _ => delta[x.layer] = Some(d),
        }
        a -= 1;
    }
    a
}

/// Find the steady-state period of a fusion group's row schedule, or
/// `None` when no loop is worth emitting (tiny planes, degenerate
/// geometry, or a schedule whose tail never settles).
///
/// This is the **phase-expanded** form: the body holds one copy of the op
/// pattern per ring phase, so every ring offset can be frozen at its
/// iteration-0 value. The search walks candidate op-pattern periods
/// smallest-first; for each it grows the largest suffix of the
/// trimmed-window-free region in which `ops[t + p]` is `ops[t]` shifted by
/// a per-member constant row delta, then multiplies the period by the
/// smallest ring-phase count that returns every ring buffer to the same
/// slot assignment. Everything is re-verified by literal replay before
/// returning. ([`detect_rotating`] is the pointer-rotation alternative
/// whose body is a single pattern period.)
pub(crate) fn detect_periodic(layout: &GroupLayout, plans: &[AxisPlan]) -> Option<PeriodicLayout> {
    let ops = &layout.ops;
    let n = plans.len();
    if n < 2 || ops.len() < 8 {
        return None;
    }
    let (r0, r1) = regular_region(ops, plans);
    if r1 <= r0 + 3 {
        return None;
    }
    'period: for p in 1..=(r1 - r0) / 2 {
        let a = pattern_suffix(ops, r0, r1, p, n);
        if r1 - a < 2 * p {
            continue;
        }
        // Rows each member advances per pattern period (== its op count in
        // one period, since a member's rows step by one per op).
        let mut per_period = vec![0usize; n];
        for op in &ops[a..a + p] {
            per_period[op.layer] += 1;
        }
        // Ring-phase count: smallest iteration multiple after which every
        // ring buffer's row->slot assignment repeats.
        let mut phases = 1usize;
        for e in 0..n - 1 {
            if per_period[e] == 0 {
                continue 'period;
            }
            let r = layout.ring_rows[e].max(1);
            phases = crate::util::lcm(phases, r / crate::util::gcd(per_period[e], r));
            if phases == 0 || phases > 64 {
                continue 'period;
            }
        }
        let ops_per_iter = p * phases;
        if r1 - a < 2 * ops_per_iter {
            continue;
        }
        let row_delta: Vec<usize> = per_period.iter().map(|d| d * phases).collect();
        // Alignment shift: sliding the loop start by whole pattern periods
        // can move leftover ops from the epilogue into the prologue and
        // buy another iteration.
        let mut best: Option<PeriodicLayout> = None;
        for shift in 0..phases {
            let b = a + shift * p;
            if b + 2 * ops_per_iter > r1 {
                break;
            }
            let iters = (r1 - b) / ops_per_iter;
            let cand = PeriodicLayout {
                body_start: b,
                ops_per_iter,
                iters,
                row_delta: row_delta.clone(),
                epilogue_start: b + iters * ops_per_iter,
            };
            if best.as_ref().map_or(true, |l| cand.emitted_ops(ops.len()) < l.emitted_ops(ops.len())) {
                best = Some(cand);
            }
        }
        if let Some(cand) = best {
            if verify_periodic(layout, plans, &cand) {
                return Some(cand);
            }
        }
    }
    None
}

/// Authoritative re-check of a [`PeriodicLayout`] candidate: literal
/// replay equality plus the window- and ring-stability conditions the
/// rolled emission relies on.
fn verify_periodic(layout: &GroupLayout, plans: &[AxisPlan], cand: &PeriodicLayout) -> bool {
    let ops = &layout.ops;
    let n = plans.len();
    if cand.iters < 2
        || cand.ops_per_iter == 0
        || cand.epilogue_start != cand.body_start + cand.iters * cand.ops_per_iter
        || cand.epilogue_start > ops.len()
        || cand.row_delta.len() != n
        || !replay_matches(ops, cand)
    {
        return false;
    }
    // One emitted body must be valid for every iteration.
    for t in 0..cand.ops_per_iter {
        let op = ops[cand.body_start + t];
        let pl = &plans[op.layer];
        let last_row = op.row + (cand.iters - 1) * cand.row_delta[op.layer];
        // Full kernel window on every covered row (same emitted taps).
        if op.row < pl.lo || last_row >= pl.hi {
            return false;
        }
        // Ring writes land in the same slot each iteration.
        if op.layer + 1 < n && cand.row_delta[op.layer] % layout.ring_rows[op.layer].max(1) != 0 {
            return false;
        }
        // Ring reads see the same slots each iteration (the window start
        // advances `row_delta * stride` producer rows per iteration).
        if op.layer > 0 {
            let adv = cand.row_delta[op.layer] * pl.stride;
            if adv % layout.ring_rows[op.layer - 1].max(1) != 0 {
                return false;
            }
        }
    }
    true
}

/// `prologue + iters x pattern + epilogue` reproduces the schedule op for
/// op (shared replay check of both steady-state verifiers).
fn replay_matches(ops: &[RowOp], cand: &PeriodicLayout) -> bool {
    let mut idx = cand.body_start;
    for i in 0..cand.iters {
        for t in 0..cand.ops_per_iter {
            let pat = ops[cand.body_start + t];
            let expect = RowOp { layer: pat.layer, row: pat.row + i * cand.row_delta[pat.layer] };
            if ops[idx] != expect {
                return false;
            }
            idx += 1;
        }
    }
    true
}

/// Row advance per loop iteration of every interior ring edge a loop
/// pattern touches: a write to edge `e` advances by the producer's
/// `row_delta[e]`, a read by the consumer's `row_delta[e+1] * stride`.
/// Untouched edges report 0. `None` when the pattern references one edge
/// at two different rates — a single rotating pointer set (or frozen slot
/// table) cannot serve both, so such a loop is never emitted.
pub(crate) fn edge_advances(
    ops: &[RowOp],
    row_delta: &[usize],
    plans: &[AxisPlan],
) -> Option<Vec<usize>> {
    let n = plans.len();
    let mut adv: Vec<Option<usize>> = vec![None; n.saturating_sub(1)];
    for op in ops {
        if op.layer + 1 < n {
            let a = row_delta[op.layer];
            match adv[op.layer] {
                Some(prev) if prev != a => return None,
                _ => adv[op.layer] = Some(a),
            }
        }
        if op.layer > 0 {
            let a = row_delta[op.layer] * plans[op.layer].stride;
            match adv[op.layer - 1] {
                Some(prev) if prev != a => return None,
                _ => adv[op.layer - 1] = Some(a),
            }
        }
    }
    Some(adv.into_iter().map(|a| a.unwrap_or(0)).collect())
}

/// Find the steady-state layout for **ring pointer rotation**: the body is
/// a single op-pattern period (no ring-phase expansion), and ring rows are
/// addressed through a pointer set the loop bottom rotates by the edge's
/// per-iteration advance — so the row→pointer mapping, unlike the
/// row→slot mapping, is iteration-invariant for *any* period. Returns the
/// smallest verified period; `None` when the schedule never settles.
pub(crate) fn detect_rotating(layout: &GroupLayout, plans: &[AxisPlan]) -> Option<PeriodicLayout> {
    let ops = &layout.ops;
    let n = plans.len();
    if n < 2 || ops.len() < 8 {
        return None;
    }
    let (r0, r1) = regular_region(ops, plans);
    if r1 <= r0 + 3 {
        return None;
    }
    for p in 1..=(r1 - r0) / 2 {
        let a = pattern_suffix(ops, r0, r1, p, n);
        if r1 - a < 2 * p {
            continue;
        }
        let mut per_period = vec![0usize; n];
        for op in &ops[a..a + p] {
            per_period[op.layer] += 1;
        }
        let iters = (r1 - a) / p;
        let cand = PeriodicLayout {
            body_start: a,
            ops_per_iter: p,
            iters,
            row_delta: per_period,
            epilogue_start: a + iters * p,
        };
        if verify_rotating(layout, plans, &cand) {
            return Some(cand);
        }
    }
    None
}

/// Authoritative re-check of a rotating-layout candidate: literal replay
/// equality, a full kernel window on every covered row (one emitted body
/// serves all iterations), and a single per-iteration rate on every ring
/// edge the pattern touches (the rotation invariant). No modular ring
/// conditions — pointer rotation is what removes them.
fn verify_rotating(layout: &GroupLayout, plans: &[AxisPlan], cand: &PeriodicLayout) -> bool {
    let ops = &layout.ops;
    if cand.iters < 2
        || cand.ops_per_iter == 0
        || cand.epilogue_start != cand.body_start + cand.iters * cand.ops_per_iter
        || cand.epilogue_start > ops.len()
        || cand.row_delta.len() != plans.len()
        || !replay_matches(ops, cand)
    {
        return false;
    }
    let pat = &ops[cand.body_start..cand.body_start + cand.ops_per_iter];
    for op in pat {
        let pl = &plans[op.layer];
        let last_row = op.row + (cand.iters - 1) * cand.row_delta[op.layer];
        if op.row < pl.lo || last_row >= pl.hi {
            return false;
        }
    }
    edge_advances(pat, &cand.row_delta, plans).is_some()
}

/// One loop of a [`RolledPlan`]: `iters` shifted copies of the op pattern
/// `ops[start .. start + ops_per_iter)`, member `j` advancing
/// `row_delta[j]` rows per iteration.
#[derive(Debug, Clone, PartialEq, Eq)]
pub(crate) struct LoopSpec {
    pub start: usize,
    pub ops_per_iter: usize,
    pub iters: usize,
    pub row_delta: Vec<usize>,
    /// True when the loop may rotate ring pointers (rotate-mode loops);
    /// false for phase-expanded bodies, whose ring offsets are frozen at
    /// iteration 0 (every edge advance is a multiple of its ring height by
    /// [`verify_periodic`]).
    pub rotate: bool,
    /// True for warm-up/drain ramps, false for the steady-state body.
    pub ramp: bool,
}

impl LoopSpec {
    /// One past the last covered op index.
    pub fn end(&self) -> usize {
        self.start + self.ops_per_iter * self.iters
    }

    /// Index range of the emitted pattern.
    pub fn pattern(&self) -> std::ops::Range<usize> {
        self.start..self.start + self.ops_per_iter
    }
}

/// One entry of a [`RolledPlan`]: a run of schedule ops emitted one block
/// per op, or a generation-time loop.
#[derive(Debug, Clone, PartialEq, Eq)]
pub(crate) enum Segment {
    /// `ops[lo..hi]`, emitted unrolled.
    Unrolled(usize, usize),
    Loop(LoopSpec),
}

/// Mode-resolved rolled emission plan of one fusion group: an ordered
/// partition of the schedule into unrolled runs and loops (the
/// steady-state body plus any rolled warm-up/drain ramps). Produced once
/// by [`rolled_plan`] and consumed by both the statement cost model and
/// the emitter, so pricing and emission cannot disagree.
#[derive(Debug, Clone, PartialEq, Eq)]
pub(crate) struct RolledPlan {
    pub segments: Vec<Segment>,
}

impl RolledPlan {
    /// Op blocks the emission writes out (each loop pattern counts once).
    pub fn emitted_ops(&self) -> usize {
        self.segments
            .iter()
            .map(|s| match s {
                Segment::Unrolled(lo, hi) => hi - lo,
                Segment::Loop(l) => l.ops_per_iter,
            })
            .sum()
    }

    pub fn loops(&self) -> impl Iterator<Item = &LoopSpec> {
        self.segments.iter().filter_map(|s| match s {
            Segment::Loop(l) => Some(l),
            Segment::Unrolled(..) => None,
        })
    }
}

/// Longest ramp period the warm-up/drain scanner tries. Ramps are short,
/// so a small cap bounds the quadratic scan without losing real ramps.
const MAX_RAMP_PERIOD: usize = 8;

/// Find rolled **ramps** inside `ops[lo..hi)` — the warm-up prologue or
/// drain epilogue of a rotating steady-state layout. A ramp is a maximal
/// run of `iters >= 2` shifted copies of a short op pattern with constant
/// per-member row deltas, where every covered row keeps its full kernel
/// window (one emitted body serves all iterations) and every touched ring
/// edge is referenced at a single per-iteration rate (the pointer-rotation
/// invariant). Returned ramps are disjoint and in schedule order.
pub(crate) fn detect_ramps(
    layout: &GroupLayout,
    plans: &[AxisPlan],
    lo: usize,
    hi: usize,
) -> Vec<LoopSpec> {
    let ops = &layout.ops;
    let n = plans.len();
    let mut ramps = Vec::new();
    let mut t = lo;
    while t < hi {
        let mut best: Option<LoopSpec> = None;
        for p in 1..=MAX_RAMP_PERIOD.min((hi - t) / 2) {
            // Per-member delta between the first two pattern copies.
            let mut delta: Vec<Option<usize>> = vec![None; n];
            let mut ok = true;
            for j in 0..p {
                let x = ops[t + j];
                let y = ops[t + j + p];
                if x.layer != y.layer || y.row <= x.row {
                    ok = false;
                    break;
                }
                let d = y.row - x.row;
                match delta[x.layer] {
                    Some(prev) if prev != d => {
                        ok = false;
                        break;
                    }
                    _ => delta[x.layer] = Some(d),
                }
            }
            if !ok {
                continue;
            }
            let row_delta: Vec<usize> = delta.iter().map(|d| d.unwrap_or(0)).collect();
            // Grow iterations while further copies keep matching.
            let mut iters = 2usize;
            while t + (iters + 1) * p <= hi {
                let all = (0..p).all(|j| {
                    let x = ops[t + j];
                    ops[t + iters * p + j]
                        == RowOp { layer: x.layer, row: x.row + iters * row_delta[x.layer] }
                });
                if !all {
                    break;
                }
                iters += 1;
            }
            let pat = &ops[t..t + p];
            // Clamp to the legal full-window prefix rather than rejecting
            // the whole run: a drain run whose tail straddles the trim
            // line still rolls its regular head (pattern deltas are >= 1,
            // so the division is safe). A first copy already outside
            // [lo, hi) can't be saved by clamping.
            let mut legal = true;
            for op in pat {
                let pl = &plans[op.layer];
                if op.row < pl.lo || op.row >= pl.hi {
                    legal = false;
                    break;
                }
                iters = iters.min((pl.hi - 1 - op.row) / row_delta[op.layer] + 1);
            }
            if !legal || iters < 2 || edge_advances(pat, &row_delta, plans).is_none() {
                continue;
            }
            let covered = iters * p;
            if best.as_ref().map_or(true, |b| covered > b.ops_per_iter * b.iters) {
                best = Some(LoopSpec {
                    start: t,
                    ops_per_iter: p,
                    iters,
                    row_delta,
                    rotate: true,
                    ramp: true,
                });
            }
        }
        match best {
            Some(r) => {
                t = r.end();
                ramps.push(r);
            }
            None => t += 1,
        }
    }
    ramps
}

/// Assemble the mode-resolved rolled emission plan of a fusion group, or
/// `None` when the schedule should be emitted fully unrolled (mode `Off`,
/// or no detectable steady state).
///
/// * `Rotate` — single-period body via [`detect_rotating`] plus rolled
///   warm-up/drain ramps.
/// * `Expand` — the phase-expanded body via [`detect_periodic`] with an
///   unrolled prologue/epilogue (the PR 4 emission, kept as the
///   differential baseline).
/// * `Auto` — rotation when it verifies, else phase expansion.
pub(crate) fn rolled_plan(
    layout: &GroupLayout,
    plans: &[AxisPlan],
    mode: RolledMode,
) -> Option<RolledPlan> {
    fn push_unrolled(segs: &mut Vec<Segment>, lo: usize, hi: usize) {
        if lo < hi {
            segs.push(Segment::Unrolled(lo, hi));
        }
    }
    let rotate_plan = |layout: &GroupLayout| -> Option<RolledPlan> {
        let p = detect_rotating(layout, plans)?;
        let mut segs = Vec::new();
        let mut fill = |segs: &mut Vec<Segment>, lo: usize, hi: usize| {
            let mut pos = lo;
            for ramp in detect_ramps(layout, plans, lo, hi) {
                push_unrolled(segs, pos, ramp.start);
                pos = ramp.end();
                segs.push(Segment::Loop(ramp));
            }
            push_unrolled(segs, pos, hi);
        };
        fill(&mut segs, 0, p.body_start);
        segs.push(Segment::Loop(LoopSpec {
            start: p.body_start,
            ops_per_iter: p.ops_per_iter,
            iters: p.iters,
            row_delta: p.row_delta,
            rotate: true,
            ramp: false,
        }));
        fill(&mut segs, p.epilogue_start, layout.ops.len());
        Some(RolledPlan { segments: segs })
    };
    let expand_plan = |layout: &GroupLayout| -> Option<RolledPlan> {
        let p = detect_periodic(layout, plans)?;
        let mut segs = Vec::new();
        push_unrolled(&mut segs, 0, p.body_start);
        segs.push(Segment::Loop(LoopSpec {
            start: p.body_start,
            ops_per_iter: p.ops_per_iter,
            iters: p.iters,
            row_delta: p.row_delta,
            rotate: false,
            ramp: false,
        }));
        push_unrolled(&mut segs, p.epilogue_start, layout.ops.len());
        Some(RolledPlan { segments: segs })
    };
    match mode {
        RolledMode::Off => None,
        RolledMode::Expand => expand_plan(layout),
        RolledMode::Rotate => rotate_plan(layout),
        RolledMode::Auto => rotate_plan(layout).or_else(|| expand_plan(layout)),
    }
}

/// Rotating ring-pointer base set for one side of a fused-row emission:
/// `names[t]` is the pointer variable through which source window row `t`
/// (or, on the destination side, the single output row) is addressed.
/// `aligned` carries the alignment claim across rotation: it holds only
/// when every rotation target shares the same provable 32-byte class —
/// i.e. the slot stride is a whole number of 8-float groups.
#[derive(Debug, Clone)]
pub(crate) struct RotPtrs {
    pub names: Vec<String>,
    pub aligned: bool,
}

/// Row-level I/O of one fused-row emission, shared by the unrolled and
/// steady-state (rolled) paths. In the rolled loop body the row coordinate
/// is `out_row + i * row_delta` for loop variable `i`; plane bases then
/// advance `*_iter_elems` floats per iteration, while ring rows are
/// addressed either at fixed slot offsets (iteration-invariant slots) or
/// through a rotating pointer set (`src_rot`/`dst_rot`) the loop bottom
/// advances.
pub(crate) struct FusedRowIo {
    /// Output row at the first covered iteration (generation-time constant
    /// outside the loop).
    pub out_row: usize,
    /// Addressing of the source rows (producer ring or group input plane).
    pub src_map: RowMap,
    /// Element offset of the output row inside the destination buffer.
    pub dst_row_off: usize,
    /// Floats the source base advances per loop iteration (0 when the base
    /// is constant: ring sources and unrolled rows).
    pub src_iter_elems: usize,
    /// Floats the destination base advances per loop iteration.
    pub dst_iter_elems: usize,
    /// Rotate-mode source addressing: window row `t` reads through the
    /// rotating pointer `src_rot.names[t]`, superseding `src_map`.
    pub src_rot: Option<RotPtrs>,
    /// Rotate-mode destination addressing: the output row is written
    /// through `dst_rot.names[0]` (`dst_row_off` is then 0).
    pub dst_rot: Option<RotPtrs>,
}

impl FusedRowIo {
    /// True when a vector access through this side's base may still claim
    /// provable alignment: a loop-term of a whole number of 8-float groups
    /// (the widest vector) preserves every narrower width's proof.
    pub fn src_iter_aligned(&self) -> bool {
        self.src_iter_elems % 8 == 0
    }

    pub fn dst_iter_aligned(&self) -> bool {
        self.dst_iter_elems % 8 == 0
    }

    /// The single alignment-claim rule for a fused source base, shared by
    /// every emitter: a rotating pointer set carries its own claim (all
    /// rotation targets in one class), otherwise the base must be a
    /// generator-owned buffer whose loop term keeps whole 8-float groups.
    /// `src` is the base buffer expression the non-rotating form reads.
    pub fn src_claims_aligned(&self, src: &str) -> bool {
        match &self.src_rot {
            Some(rot) => rot.aligned,
            None => static_buf(src) && self.src_iter_aligned(),
        }
    }

    /// Destination-side counterpart of [`FusedRowIo::src_claims_aligned`].
    pub fn dst_claims_aligned(&self, dst: &str) -> bool {
        match &self.dst_rot {
            Some(rot) => rot.aligned,
            None => static_buf(dst) && self.dst_iter_aligned(),
        }
    }
}

/// C expression for a fused-row base pointer: constant offset plus an
/// optional steady-state loop term (`i` is the loop variable). Compound
/// forms are parenthesized so callers may both add offsets to the result
/// and index it with `[]` (indexing an unparenthesized `a + i*b` would
/// bind the subscript to `b`).
pub(crate) fn fused_base(buf: &str, off: usize, iter_elems: usize) -> String {
    match (off, iter_elems) {
        (0, 0) => buf.to_string(),
        (o, 0) => format!("({buf} + {o})"),
        (0, it) => format!("({buf} + i*{it})"),
        (o, it) => format!("({buf} + {o} + i*{it})"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codegen::{Isa, TileMode};

    #[test]
    fn pad_strategy_follows_mode_and_unroll() {
        let mut opts = CodegenOptions::default();
        assert_eq!(pad_strategy(&opts), PadStrategy::Padless); // Auto + KeepOuter2
        opts.pad_mode = PadMode::Copy;
        assert_eq!(pad_strategy(&opts), PadStrategy::Copy);
        opts.pad_mode = PadMode::Padless;
        opts.unroll = Unroll::None;
        assert_eq!(pad_strategy(&opts), PadStrategy::Copy); // loop form keeps the copy
        opts.unroll = Unroll::Full;
        assert_eq!(pad_strategy(&opts), PadStrategy::Padless);
    }

    #[test]
    fn axis_split_ball_conv1() {
        // 16 input, k5, s2, pad 1 → 8 outputs; row 0 and row 7 are borders.
        let a = AxisPlan::padless(8, 2, 5, 1, 16);
        assert_eq!((a.lo, a.hi), (1, 7));
        assert_eq!(a.window(0), (1, 5)); // top row drops one tap row
        assert_eq!(a.window(1), (0, 5)); // first interior row
        assert_eq!(a.window(7), (0, 3)); // bottom row drops two tap rows
        assert_eq!(a.src_start(0), 0);
        assert_eq!(a.src_start(1), 1);
        assert_eq!(a.src_start(7), 13);
        assert_eq!(a.interior(), 6);
    }

    #[test]
    fn axis_split_stride1_3x3() {
        // 8 input, k3, s1, pad 1 → 8 outputs; one border cell each side.
        let a = AxisPlan::padless(8, 1, 3, 1, 8);
        assert_eq!((a.lo, a.hi), (1, 7));
        assert_eq!(a.window(0), (1, 3));
        assert_eq!(a.window(7), (0, 2));
        for i in 1..7 {
            assert_eq!(a.window(i), (0, 3), "i={i}");
        }
    }

    #[test]
    fn axis_split_no_pad_is_all_interior() {
        // Same padding with k1 needs no pad at all.
        let a = AxisPlan::padless(9, 1, 1, 0, 9);
        assert_eq!((a.lo, a.hi), (0, 9));
        // Copy-mode over the padded extent: also all interior.
        let f = AxisPlan::full(8, 2, 5, 19);
        assert_eq!((f.lo, f.hi), (0, 8));
        assert_eq!(f.window(0), (0, 5));
        assert_eq!(f.src_start(3), 6);
    }

    #[test]
    fn tile_width_rules() {
        let vec4 = ChannelSchedule::for_channels(Isa::Sse3, 8);
        let scalar = ChannelSchedule::for_channels(Isa::Generic, 8);
        let opts = CodegenOptions::default(); // tile Auto
        assert_eq!(tile_width(&opts, &vec4, 8), 4);
        assert_eq!(tile_width(&opts, &vec4, 3), 2);
        assert_eq!(tile_width(&opts, &vec4, 1), 1);
        assert_eq!(tile_width(&opts, &scalar, 8), 1);
        let off = CodegenOptions { tile: TileMode::Off, ..Default::default() };
        assert_eq!(tile_width(&off, &vec4, 8), 1);
        let fixed = CodegenOptions { tile: TileMode::Fixed(2), ..Default::default() };
        assert_eq!(tile_width(&fixed, &vec4, 8), 2);
        let loops = CodegenOptions { unroll: Unroll::None, ..Default::default() };
        assert_eq!(tile_width(&loops, &vec4, 8), 1);
        // Fixed is also overridden by the loop form (consistent across
        // conv and depthwise emitters).
        let loops_fixed =
            CodegenOptions { unroll: Unroll::None, tile: TileMode::Fixed(4), ..Default::default() };
        assert_eq!(tile_width(&loops_fixed, &vec4, 8), 1);
    }

    #[test]
    fn tile_shape_2d_rules() {
        let vec4 = ChannelSchedule::for_channels(Isa::Sse3, 8);
        let t2x4 = CodegenOptions { tile: TileMode::Fixed2D(2, 4), ..Default::default() };
        assert_eq!(tile_shape(&t2x4, &vec4, 8, 8), (2, 4));
        // Rows clamp to the interior extent.
        assert_eq!(tile_shape(&t2x4, &vec4, 1, 8), (1, 4));
        // Full unroll walks rows one at a time.
        let full = CodegenOptions {
            unroll: Unroll::Full,
            tile: TileMode::Fixed2D(2, 4),
            ..Default::default()
        };
        assert_eq!(tile_shape(&full, &vec4, 8, 8).0, 1);
        // Loop form never tiles.
        let loops = CodegenOptions {
            unroll: Unroll::None,
            tile: TileMode::Fixed2D(2, 4),
            ..Default::default()
        };
        assert_eq!(tile_shape(&loops, &vec4, 8, 8), (1, 1));
        // 1-D modes keep a single row.
        assert_eq!(tile_shape(&CodegenOptions::default(), &vec4, 8, 8), (1, 4));
    }

    #[test]
    fn static_buf_distinguishes_caller_pointers() {
        assert!(static_buf("nncg_bufa"));
        assert!(static_buf("nncg_pad"));
        assert!(!static_buf("x_in"));
        assert!(!static_buf("x_out"));
    }

    #[test]
    fn row_schedule_conv_then_pool_interleaves() {
        // conv 3x3 s1 Same on 8 rows (out 8) feeding a 2x2 s2 pool (out 4).
        let conv = AxisPlan::padless(8, 1, 3, 1, 8);
        let pool = AxisPlan::padless(4, 2, 2, 0, 8);
        let layout = plan_group_rows(&[conv, pool]);
        // Pool row 0 needs conv rows 0..2, pool row 1 needs 2..4, ...
        let ops = &layout.ops;
        assert_eq!(&ops[..3], &[
            RowOp { layer: 0, row: 0 },
            RowOp { layer: 0, row: 1 },
            RowOp { layer: 1, row: 0 },
        ]);
        // Every conv row produced exactly once, in order.
        let conv_rows: Vec<usize> = ops.iter().filter(|o| o.layer == 0).map(|o| o.row).collect();
        assert_eq!(conv_rows, (0..8).collect::<Vec<_>>());
        let pool_rows: Vec<usize> = ops.iter().filter(|o| o.layer == 1).map(|o| o.row).collect();
        assert_eq!(pool_rows, (0..4).collect::<Vec<_>>());
        // Non-overlapping stride-2 windows: two live conv rows suffice.
        assert_eq!(layout.ring_rows, vec![2]);
    }

    #[test]
    fn ring_rows_match_kernel_overlap() {
        // stride-1 3x3 consumer: three conv rows live at once.
        let a = AxisPlan::padless(8, 1, 3, 1, 8);
        let b = AxisPlan::padless(8, 1, 3, 1, 8);
        let layout = plan_group_rows(&[a, b]);
        assert_eq!(layout.ring_rows, vec![3]);
        // A consumer whose kernel spans the whole input degenerates to a
        // full-plane ring (correct, no saving).
        let c = AxisPlan::padless(4, 1, 1, 0, 4);
        let head = AxisPlan::padless(1, 1, 4, 0, 4);
        let layout = plan_group_rows(&[c, head]);
        assert_eq!(layout.ring_rows, vec![4]);
    }

    #[test]
    fn row_map_resolves_modulo_at_generation_time() {
        let plane = RowMap::Plane { row_elems: 10 };
        assert_eq!(plane.off(7), 70);
        let ring = RowMap::Ring { rows: 3, row_elems: 10 };
        assert_eq!(ring.off(0), 0);
        assert_eq!(ring.off(2), 20);
        assert_eq!(ring.off(3), 0);
        assert_eq!(ring.off(7), 10);
    }

    /// Property (issue acceptance): across random stride/kernel/pad chains,
    /// replaying the schedule against per-edge ring buffers of the planned
    /// height never reads a slot that no longer holds the needed row, rows
    /// are produced in order exactly once, and the final plane completes.
    #[test]
    fn ring_buffer_rows_never_alias_live_rows() {
        let mut rng = crate::util::XorShift64::new(0xA11A5);
        let mut checked = 0usize;
        for trial in 0..400 {
            let mut h = 4 + rng.below(20);
            let depth = 2 + rng.below(4);
            let mut plans: Vec<AxisPlan> = Vec::new();
            for _ in 0..depth {
                let k = 1 + rng.below(4.min(h));
                let s = 1 + rng.below(3);
                let (out, pad) = if rng.below(2) == 0 {
                    // Same-style: out = ceil(h/s), centered pad.
                    let out = (h + s - 1) / s;
                    let total = ((out - 1) * s + k).saturating_sub(h);
                    (out, total / 2)
                } else {
                    // Valid-style geometry.
                    if h < k {
                        break;
                    }
                    ((h - k) / s + 1, 0)
                };
                if out == 0 {
                    break;
                }
                plans.push(AxisPlan::padless(out, s, k, pad, h));
                h = out;
                if h < 2 {
                    break;
                }
            }
            if plans.len() < 2 {
                continue;
            }
            checked += 1;
            let layout = plan_group_rows(&plans);
            let n = plans.len();
            let mut slots: Vec<Vec<Option<usize>>> =
                (0..n - 1).map(|e| vec![None; layout.ring_rows[e]]).collect();
            let mut produced = vec![0usize; n];
            for op in &layout.ops {
                if op.layer > 0 {
                    let e = op.layer - 1;
                    let r = layout.ring_rows[e];
                    let (k0, k1) = plans[op.layer].window(op.row);
                    let start = plans[op.layer].src_start(op.row);
                    for q in start..start + (k1 - k0) {
                        assert_eq!(
                            slots[e][q % r],
                            Some(q),
                            "trial {trial}: member {} row {} reads an aliased ring slot",
                            op.layer,
                            op.row
                        );
                    }
                }
                assert_eq!(
                    produced[op.layer], op.row,
                    "trial {trial}: rows must be produced in order exactly once"
                );
                produced[op.layer] = op.row + 1;
                if op.layer < n - 1 {
                    let r = layout.ring_rows[op.layer];
                    slots[op.layer][op.row % r] = Some(op.row);
                }
            }
            assert_eq!(produced[n - 1], plans[n - 1].out, "trial {trial}: final plane incomplete");
        }
        assert!(checked > 100, "property exercised only {checked} chains");
    }

    #[test]
    fn periodic_two_stride1_convs() {
        // Two 3x3 s1 Same convs on 16 rows: pattern [L0, L1] (period 2),
        // one ring of 3 rows rotating by 1 per pattern → 3 ring phases.
        let a = AxisPlan::padless(16, 1, 3, 1, 16);
        let b = AxisPlan::padless(16, 1, 3, 1, 16);
        let layout = plan_group_rows(&[a, b]);
        assert_eq!(layout.ops.len(), 32);
        assert_eq!(layout.ring_rows, vec![3]);
        let p = detect_periodic(&layout, &[a, b]).expect("chain must be periodic");
        assert_eq!(p.body_start, 3);
        assert_eq!(p.ops_per_iter, 6); // period 2 x 3 phases
        assert_eq!(p.iters, 4);
        assert_eq!(p.row_delta, vec![3, 3]);
        assert_eq!(p.epilogue_start, 27);
        assert_eq!(p.emitted_ops(layout.ops.len()), 3 + 6 + 5);
    }

    #[test]
    fn periodic_conv_into_pool_needs_single_phase() {
        // conv 3x3 s1 Same (24 rows) into 2x2 s2 pool: the ring holds 2
        // rows and the conv advances 2 rows per pattern — slots repeat
        // every iteration, no phase expansion.
        let conv = AxisPlan::padless(24, 1, 3, 1, 24);
        let pool = AxisPlan::padless(12, 2, 2, 0, 24);
        let layout = plan_group_rows(&[conv, pool]);
        assert_eq!(layout.ops.len(), 36);
        assert_eq!(layout.ring_rows, vec![2]);
        let p = detect_periodic(&layout, &[conv, pool]).unwrap();
        assert_eq!(p.body_start, 1);
        assert_eq!(p.ops_per_iter, 3); // period 3 x 1 phase
        assert_eq!(p.iters, 11);
        assert_eq!(p.row_delta, vec![2, 1]);
        assert_eq!(p.epilogue_start, 34);
    }

    #[test]
    fn periodic_robot_first_group_shape() {
        // Robot group [0..4): conv8 s1 (60 rows) -> pool s2 -> conv12 s1
        // -> conv8 s1. Period 5 ops, 3 ring phases, 8 steady iterations.
        let plans = [
            AxisPlan::padless(60, 1, 3, 1, 60),
            AxisPlan::padless(30, 2, 2, 0, 60),
            AxisPlan::padless(30, 1, 3, 1, 30),
            AxisPlan::padless(30, 1, 3, 1, 30),
        ];
        let layout = plan_group_rows(&plans);
        assert_eq!(layout.ops.len(), 150);
        assert_eq!(layout.ring_rows, vec![2, 3, 3]);
        let p = detect_periodic(&layout, &plans).unwrap();
        assert_eq!(p.body_start, 12);
        assert_eq!(p.ops_per_iter, 15);
        assert_eq!(p.iters, 8);
        assert_eq!(p.row_delta, vec![6, 3, 3, 3]);
        assert_eq!(p.epilogue_start, 132);
        // The rolled emission writes 45 of 150 ops — the >=3x robot
        // code-size claim comes straight from here.
        assert!(p.emitted_ops(150) * 3 <= 150);
    }

    #[test]
    fn short_planes_are_not_periodic() {
        // Ball's trunk: conv 5x5 s2 Same (16 rows) -> pool -> conv 3x3
        // Valid; the final plane has 2 rows — nothing to roll.
        let plans = [
            AxisPlan::padless(8, 2, 5, 1, 16),
            AxisPlan::padless(4, 2, 2, 0, 8),
            AxisPlan::padless(2, 1, 3, 0, 4),
        ];
        let layout = plan_group_rows(&plans);
        assert!(detect_periodic(&layout, &plans).is_none());
    }

    /// Property (issue acceptance): across random chains, whenever a
    /// periodic layout is detected, prologue + iters x body + epilogue
    /// covers every member's rows exactly once in order, and replaying the
    /// rolled schedule against ring buffers of the planned heights — with
    /// the body's ring slots frozen at iteration 0, exactly as the emitter
    /// resolves them — never reads an aliased slot.
    #[test]
    fn periodic_layout_covers_rows_and_preserves_ring_aliasing() {
        let mut rng = crate::util::XorShift64::new(0x9E10D1C);
        let mut checked = 0usize;
        let mut detected = 0usize;
        for trial in 0..400 {
            let mut h = 10 + rng.below(30);
            let depth = 2 + rng.below(3);
            let mut plans: Vec<AxisPlan> = Vec::new();
            for _ in 0..depth {
                let k = 1 + rng.below(3.min(h));
                let s = 1 + rng.below(2);
                let (out, pad) = if rng.below(2) == 0 {
                    let out = (h + s - 1) / s;
                    let total = ((out - 1) * s + k).saturating_sub(h);
                    (out, total / 2)
                } else {
                    if h < k {
                        break;
                    }
                    ((h - k) / s + 1, 0)
                };
                if out == 0 {
                    break;
                }
                plans.push(AxisPlan::padless(out, s, k, pad, h));
                h = out;
                if h < 2 {
                    break;
                }
            }
            if plans.len() < 2 {
                continue;
            }
            checked += 1;
            let layout = plan_group_rows(&plans);
            let p = match detect_periodic(&layout, &plans) {
                Some(p) => p,
                None => continue,
            };
            detected += 1;
            let n = plans.len();
            // Reconstruct the rolled emission's op stream.
            let mut rec: Vec<RowOp> = layout.ops[..p.body_start].to_vec();
            for i in 0..p.iters {
                for t in 0..p.ops_per_iter {
                    let pat = layout.ops[p.body_start + t];
                    rec.push(RowOp { layer: pat.layer, row: pat.row + i * p.row_delta[pat.layer] });
                }
            }
            rec.extend_from_slice(&layout.ops[p.epilogue_start..]);
            // Coverage: every member's rows exactly once, in order.
            let mut next = vec![0usize; n];
            for op in &rec {
                assert_eq!(op.row, next[op.layer], "trial {trial}: row skipped or repeated");
                next[op.layer] = op.row + 1;
            }
            for (j, plan) in plans.iter().enumerate() {
                assert_eq!(next[j], plan.out, "trial {trial}: member {j} incomplete");
            }
            // Ring aliasing on the reconstructed stream, with body reads
            // resolved at iteration 0 (what the emitted C hard-codes).
            let mut slots: Vec<Vec<Option<usize>>> =
                (0..n - 1).map(|e| vec![None; layout.ring_rows[e]]).collect();
            for (t, op) in rec.iter().enumerate() {
                if op.layer > 0 {
                    let e = op.layer - 1;
                    let r = layout.ring_rows[e];
                    let (k0, k1) = plans[op.layer].window(op.row);
                    let start = plans[op.layer].src_start(op.row);
                    // The emitter freezes slot indices at the body's first
                    // iteration; stability (verified by the detector) makes
                    // iteration-i slots identical.
                    for q in start..start + (k1 - k0) {
                        assert_eq!(
                            slots[e][q % r],
                            Some(q),
                            "trial {trial} op {t}: rolled body reads an aliased ring slot"
                        );
                    }
                }
                if op.layer < n - 1 {
                    let r = layout.ring_rows[op.layer];
                    slots[op.layer][op.row % r] = Some(op.row);
                }
            }
        }
        assert!(checked > 150, "property exercised only {checked} chains");
        assert!(detected > 60, "period detector fired on only {detected}/{checked} chains");
    }

    #[test]
    fn rotating_two_stride1_convs_needs_single_period() {
        // Same chain as `periodic_two_stride1_convs`: the phase-expanded
        // body needs 3 ring phases (6 ops); pointer rotation collapses it
        // to the bare 2-op pattern and rolls 13 iterations.
        let a = AxisPlan::padless(16, 1, 3, 1, 16);
        let b = AxisPlan::padless(16, 1, 3, 1, 16);
        let layout = plan_group_rows(&[a, b]);
        let p = detect_rotating(&layout, &[a, b]).expect("chain must rotate");
        assert_eq!(p.body_start, 3);
        assert_eq!(p.ops_per_iter, 2); // one pattern period, no phases
        assert_eq!(p.iters, 13);
        assert_eq!(p.row_delta, vec![1, 1]);
        assert_eq!(p.epilogue_start, 29);
        // The expanded body is exactly `phases x` bigger.
        let e = detect_periodic(&layout, &[a, b]).unwrap();
        assert_eq!(e.ops_per_iter, 3 * p.ops_per_iter);
    }

    #[test]
    fn rotating_robot_first_group_shape() {
        // Robot group [0..4): the expanded body carries 3 ring phases
        // (15 ops); rotation emits the 5-op pattern and 26 iterations,
        // and two warm-up ramps roll inside the 12-op prologue.
        let plans = [
            AxisPlan::padless(60, 1, 3, 1, 60),
            AxisPlan::padless(30, 2, 2, 0, 60),
            AxisPlan::padless(30, 1, 3, 1, 30),
            AxisPlan::padless(30, 1, 3, 1, 30),
        ];
        let layout = plan_group_rows(&plans);
        let p = detect_rotating(&layout, &plans).unwrap();
        assert_eq!(p.body_start, 12);
        assert_eq!(p.ops_per_iter, 5);
        assert_eq!(p.iters, 26);
        assert_eq!(p.row_delta, vec![2, 1, 1, 1]);
        assert_eq!(p.epilogue_start, 142);
        let rp = rolled_plan(&layout, &plans, crate::codegen::RolledMode::Rotate).unwrap();
        // 150 schedule ops emit as 23 op blocks (45 under phase expansion).
        assert_eq!(rp.emitted_ops(), 23);
        let ramps: Vec<&LoopSpec> = rp.loops().filter(|l| l.ramp).collect();
        assert_eq!(ramps.len(), 2, "two warm-up ramps expected");
        assert!(ramps.iter().all(|r| r.iters == 2 && r.ops_per_iter == 1));
        // Auto prefers rotation; Expand keeps the PR 4 plan; Off rolls
        // nothing.
        let auto = rolled_plan(&layout, &plans, crate::codegen::RolledMode::Auto).unwrap();
        assert_eq!(auto, rp);
        let exp = rolled_plan(&layout, &plans, crate::codegen::RolledMode::Expand).unwrap();
        assert_eq!(exp.emitted_ops(), 45);
        assert!(exp.loops().all(|l| !l.rotate && !l.ramp));
        assert!(rolled_plan(&layout, &plans, crate::codegen::RolledMode::Off).is_none());
    }

    #[test]
    fn rotating_handles_phase_counts_beyond_the_expansion_cap_regime() {
        // conv3 -> conv5 -> conv3 at stride 1: ring heights [5, 3] with a
        // per-period advance of 1 row need lcm(5, 3) = 15 ring phases —
        // a 45-op expanded body. Rotation emits the 3-op pattern.
        let plans = [
            AxisPlan::padless(100, 1, 3, 1, 100),
            AxisPlan::padless(100, 1, 5, 2, 100),
            AxisPlan::padless(100, 1, 3, 1, 100),
        ];
        let layout = plan_group_rows(&plans);
        assert_eq!(layout.ring_rows, vec![5, 3]);
        let rot = detect_rotating(&layout, &plans).unwrap();
        assert_eq!(rot.ops_per_iter, 3);
        assert_eq!(rot.row_delta, vec![1, 1, 1]);
        let exp = detect_periodic(&layout, &plans).unwrap();
        assert_eq!(exp.ops_per_iter, 45, "15 ring phases under expansion");
        let rp = rolled_plan(&layout, &plans, crate::codegen::RolledMode::Rotate).unwrap();
        assert!(rp.emitted_ops() * 4 <= rolled_plan(&layout, &plans, crate::codegen::RolledMode::Expand).unwrap().emitted_ops());
    }

    #[test]
    fn edge_advances_rejects_mixed_rates() {
        let plans = [AxisPlan::padless(16, 1, 3, 1, 16), AxisPlan::padless(16, 1, 3, 1, 16)];
        // Producer advances 2/iter but the consumer only 1 (reads advance
        // 1*stride = 1): no single rotation distance serves edge 0.
        let ops = [RowOp { layer: 0, row: 4 }, RowOp { layer: 0, row: 5 }, RowOp { layer: 1, row: 3 }];
        assert!(edge_advances(&ops, &[2, 1], &plans).is_none());
        // Consistent rates resolve: producer 1/iter, consumer 1/iter.
        let ops = [RowOp { layer: 0, row: 4 }, RowOp { layer: 1, row: 3 }];
        assert_eq!(edge_advances(&ops, &[1, 1], &plans), Some(vec![1]));
    }

    /// Expand a rolled plan back into its op stream while simulating ring
    /// slots and the rotating pointer sets exactly as the emitter resolves
    /// them (pattern rows frozen at iteration 0, pointer indices resolved
    /// against the generation-time rotation state, pointers rotated at
    /// each loop bottom). Asserts the stream equals the literal schedule
    /// and that no read ever sees a stale or mis-mapped slot.
    fn replay_rolled_plan(plans: &[AxisPlan], layout: &GroupLayout, rp: &RolledPlan, trial: usize) {
        let ops = &layout.ops;
        let n = plans.len();
        let ne = n - 1;
        let rings = &layout.ring_rows;
        let mut slots: Vec<Vec<Option<usize>>> = (0..ne).map(|e| vec![None; rings[e]]).collect();
        let mut ptrs: Vec<Vec<usize>> = (0..ne).map(|e| (0..rings[e]).collect()).collect();
        let mut phi = vec![0usize; ne];
        let mut stream: Vec<RowOp> = Vec::new();
        let read_rows = |l: usize, row: usize| -> std::ops::Range<usize> {
            let (k0, k1) = plans[l].window(row);
            let s = plans[l].src_start(row);
            s..s + (k1 - k0)
        };
        for seg in &rp.segments {
            match seg {
                Segment::Unrolled(lo, hi) => {
                    for op in &ops[*lo..*hi] {
                        if op.layer > 0 {
                            let e = op.layer - 1;
                            for q in read_rows(op.layer, op.row) {
                                assert_eq!(slots[e][q % rings[e]], Some(q), "trial {trial}: unrolled read stale");
                            }
                        }
                        if op.layer < ne {
                            let r = rings[op.layer];
                            slots[op.layer][op.row % r] = Some(op.row);
                        }
                        stream.push(*op);
                    }
                }
                Segment::Loop(l) => {
                    let pat: Vec<RowOp> = ops[l.pattern()].to_vec();
                    let adv = if l.rotate {
                        edge_advances(&pat, &l.row_delta, plans)
                            .unwrap_or_else(|| panic!("trial {trial}: loop with inconsistent edge rates"))
                    } else {
                        vec![0; ne] // expand loops never rotate pointers
                    };
                    // Emission-time resolution: pointer index (rotating
                    // edges) or frozen slot (everything else), from the
                    // iteration-0 row.
                    let uses_ptr =
                        |e: usize| l.rotate && adv[e] % rings[e].max(1) != 0;
                    let resolve = |e: usize, q0: usize| -> (bool, usize) {
                        let r = rings[e];
                        if uses_ptr(e) {
                            (true, (q0 % r + r - phi[e]) % r)
                        } else {
                            (false, q0 % r)
                        }
                    };
                    for i in 0..l.iters {
                        for op in &pat {
                            let row = op.row + i * l.row_delta[op.layer];
                            if op.layer > 0 {
                                let e = op.layer - 1;
                                for (q0, q) in read_rows(op.layer, op.row).zip(read_rows(op.layer, row)) {
                                    let (is_ptr, idx) = resolve(e, q0);
                                    let slot = if is_ptr { ptrs[e][idx] } else { idx };
                                    assert_eq!(slot, q % rings[e], "trial {trial}: loop read slot mismatch");
                                    assert_eq!(slots[e][slot], Some(q), "trial {trial}: loop read stale");
                                }
                            }
                            if op.layer < ne {
                                let (is_ptr, idx) = resolve(op.layer, op.row);
                                let slot = if is_ptr { ptrs[op.layer][idx] } else { idx };
                                assert_eq!(slot, row % rings[op.layer], "trial {trial}: loop write slot mismatch");
                                slots[op.layer][slot] = Some(row);
                            }
                            stream.push(RowOp { layer: op.layer, row });
                        }
                        for e in 0..ne {
                            if uses_ptr(e) {
                                let r = rings[e];
                                let g = adv[e] % r;
                                let turned: Vec<usize> =
                                    (0..r).map(|k| ptrs[e][(k + g) % r]).collect();
                                ptrs[e] = turned;
                            }
                        }
                    }
                    for e in 0..ne {
                        phi[e] = (phi[e] + l.iters * adv[e]) % rings[e].max(1);
                    }
                }
            }
        }
        assert_eq!(&stream, ops, "trial {trial}: rolled plan replay diverges from the schedule");
    }

    /// Property (issue acceptance): across random chains, the rotated
    /// rolled plan — warm-up ramps + single-period body + drain ramps —
    /// covers exactly the same row ops as the literal schedule, in order,
    /// and its pointer/slot addressing (resolved at generation time, as
    /// the emitter does) never reads an aliased or mis-mapped ring slot.
    /// The phase-expanded plan is replayed through the same harness.
    #[test]
    fn rolled_plans_cover_schedule_and_preserve_ring_addressing() {
        use crate::codegen::RolledMode;
        let mut rng = crate::util::XorShift64::new(0x0707A7E);
        let mut checked = 0usize;
        let mut rotated = 0usize;
        let mut with_ramps = 0usize;
        for trial in 0..400 {
            let mut h = 10 + rng.below(40);
            let depth = 2 + rng.below(3);
            let mut plans: Vec<AxisPlan> = Vec::new();
            for _ in 0..depth {
                let k = 1 + rng.below(3.min(h));
                let s = 1 + rng.below(2);
                let (out, pad) = if rng.below(2) == 0 {
                    let out = (h + s - 1) / s;
                    let total = ((out - 1) * s + k).saturating_sub(h);
                    (out, total / 2)
                } else {
                    if h < k {
                        break;
                    }
                    ((h - k) / s + 1, 0)
                };
                if out == 0 {
                    break;
                }
                plans.push(AxisPlan::padless(out, s, k, pad, h));
                h = out;
                if h < 2 {
                    break;
                }
            }
            if plans.len() < 2 {
                continue;
            }
            checked += 1;
            let layout = plan_group_rows(&plans);
            if let Some(rp) = rolled_plan(&layout, &plans, RolledMode::Rotate) {
                rotated += 1;
                if rp.loops().any(|l| l.ramp) {
                    with_ramps += 1;
                }
                // The rotated body must be a single pattern period: never
                // larger than the expanded body, and its loops must never
                // cover fewer ops than they replace.
                if let Some(exp) = rolled_plan(&layout, &plans, RolledMode::Expand) {
                    assert!(rp.emitted_ops() <= exp.emitted_ops(), "trial {trial}");
                    replay_rolled_plan(&plans, &layout, &exp, trial);
                }
                replay_rolled_plan(&plans, &layout, &rp, trial);
            } else if let Some(exp) = rolled_plan(&layout, &plans, RolledMode::Expand) {
                replay_rolled_plan(&plans, &layout, &exp, trial);
            }
        }
        assert!(checked > 150, "property exercised only {checked} chains");
        assert!(rotated > 100, "rotation detector fired on only {rotated}/{checked} chains");
        assert!(with_ramps > 30, "ramps rolled on only {with_ramps}/{checked} chains");
    }

    #[test]
    fn chunk_budget_shrinks_with_block_width() {
        assert_eq!(max_groups_per_chunk(1), 8);
        assert_eq!(max_groups_per_chunk(2), 6);
        assert_eq!(max_groups_per_chunk(3), 3);
        assert_eq!(max_groups_per_chunk(4), 2);
        assert!(max_groups_per_chunk(8) >= 1);
        // 2-D blocks can exceed the 14-register budget; must not underflow.
        assert_eq!(max_groups_per_chunk(16), 1);
        assert_eq!(max_groups_per_chunk(32), 1);
    }
}
