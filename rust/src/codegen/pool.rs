//! Max-pooling emitter (paper §II-B.2, Eq. 3).
//!
//! Same unroll/SIMD regime as the convolution: spatial loops optionally
//! kept, window loops unrolled, vector `maxps` over channel lane groups
//! with a scalar tail for channel counts that do not divide the width.
//! The scalar max uses the ternary operator (P2 — conditional moves).

use super::cwriter::CWriter;
use super::schedule;
use super::simd::ChannelSchedule;
use super::{LayerCtx, Unroll};
use anyhow::Result;

pub(crate) fn emit_maxpool(w: &mut CWriter, ctx: &LayerCtx<'_>, pool: (usize, usize), stride: (usize, usize)) -> Result<()> {
    let (h_out, w_out, c) = (ctx.out_shape.h(), ctx.out_shape.w(), ctx.out_shape.c());
    let w_in = ctx.in_shape.w();
    let sched = ChannelSchedule::for_channels(ctx.opts.isa, c);
    let geom = PoolGeom {
        src: ctx.src.to_string(),
        dst: ctx.dst.to_string(),
        pool,
        stride,
        w_in,
        w_out,
        c,
        // Every pool offset is a multiple of `c`, so channel-divisibility
        // plus a static base proves alignment (same rule as depthwise).
        src_aligned: ctx.opts.use_aligned() && schedule::static_buf(ctx.src),
        dst_aligned: ctx.opts.use_aligned() && schedule::static_buf(ctx.dst),
    };

    match ctx.opts.unroll {
        Unroll::None => {
            w.open(&format!("for (i = 0; i < {h_out}; i++)"));
            w.open(&format!("for (j = 0; j < {w_out}; j++)"));
            emit_bases(w, &geom);
            for seg in &sched.segments {
                if seg.len == 0 {
                    continue;
                }
                if let Some(v) = seg.vec {
                    let s_al = geom.src_aligned && c % v.width == 0 && seg.start % v.width == 0;
                    let d_al = geom.dst_aligned && c % v.width == 0 && seg.start % v.width == 0;
                    w.open(&format!("for (k = {}; k < {}; k += {})", seg.start, seg.end(), v.width));
                    w.line(&format!("{} v = {};", v.ty, v.load("s + k", s_al)));
                    w.open(&format!("for (n = 0; n < {}; n++)", pool.0));
                    w.open(&format!("for (m = 0; m < {}; m++)", pool.1));
                    w.line(&v.max("v", &v.load(&format!("s + (n*{} + m)*{c} + k", w_in), s_al)));
                    w.close();
                    w.close();
                    w.line(&v.store("d + k", "v", d_al));
                    w.close();
                } else {
                    w.open(&format!("for (k = {}; k < {}; k++)", seg.start, seg.end()));
                    w.line("float v = s[k];");
                    w.line("float t;");
                    w.open(&format!("for (n = 0; n < {}; n++)", pool.0));
                    w.open(&format!("for (m = 0; m < {}; m++)", pool.1));
                    w.line(&format!("t = s[(n*{} + m)*{c} + k];", w_in));
                    w.line("v = t > v ? t : v;");
                    w.close();
                    w.close();
                    w.line("d[k] = v;");
                    w.close();
                }
            }
            w.close();
            w.close();
        }
        Unroll::KeepOuter2 => {
            let rows = linear_rows(&geom, "s");
            w.open(&format!("for (i = 0; i < {h_out}; i++)"));
            w.open(&format!("for (j = 0; j < {w_out}; j++)"));
            emit_bases(w, &geom);
            emit_window(w, &geom, &sched, &rows, 0, "d", 0);
            w.close();
            w.close();
        }
        Unroll::KeepOuter1 => {
            let rows = linear_rows(&geom, "s");
            w.open(&format!("for (i = 0; i < {h_out}; i++)"));
            w.line(&format!("const float *s = {} + i*{};", geom.src, stride.0 * w_in * c));
            w.line(&format!("float *d = {} + i*{};", geom.dst, w_out * c));
            for j in 0..w_out {
                emit_window(w, &geom, &sched, &rows, j * stride.1 * c, "d", j * c);
            }
            w.close();
        }
        Unroll::Full => {
            let rows = linear_rows(&geom, &geom.src);
            for i in 0..h_out {
                for j in 0..w_out {
                    emit_window(
                        w,
                        &geom,
                        &sched,
                        &rows,
                        (i * stride.0 * w_in + j * stride.1) * c,
                        &geom.dst,
                        (i * w_out + j) * c,
                    );
                }
            }
        }
    }
    Ok(())
}

/// Window-row bases of a whole-plane walk (one shared base, rows at the
/// linear stride).
fn linear_rows(g: &PoolGeom, base: &str) -> Vec<(String, usize)> {
    (0..g.pool.0).map(|n| (base.to_string(), n * g.w_in * g.c)).collect()
}

/// Column bases for one fused pool-window row op inside the kept column
/// loop, shared by the max- and average-pool emitters: a rotating source
/// gets one alias per row pointer (each advanced by the column stride); a
/// non-rotating source keeps the single `s` of the unrolled form, with
/// the resolved row offsets staying inside the window. Emits the
/// declarations and returns the `(base, row offset)` pairs.
pub(crate) fn fused_col_row_bases(
    w: &mut CWriter,
    io: &schedule::FusedRowIo,
    plain_base: &str,
    col_stride: usize,
    base_rows: &[(String, usize)],
) -> Vec<(String, usize)> {
    match &io.src_rot {
        Some(rot) => rot
            .names
            .iter()
            .enumerate()
            .map(|(n, name)| {
                w.line(&format!("const float *s{n} = {name} + j*{col_stride};"));
                (format!("s{n}"), 0)
            })
            .collect(),
        None => {
            w.line(&format!("const float *s = {plain_base} + j*{col_stride};"));
            base_rows.iter().map(|(_, off)| ("s".to_string(), *off)).collect()
        }
    }
}

/// One constant-coordinate output row of a max pool inside a row-streaming
/// fusion group; window rows are fetched through `io.src_map` (the
/// producer's ring buffer or the group input plane) or the rotating
/// pointer set, and plane bases advance `io.*_iter_elems` floats per
/// steady-state loop iteration.
pub(crate) fn emit_maxpool_row_fused(
    w: &mut CWriter,
    ctx: &LayerCtx<'_>,
    pool: (usize, usize),
    stride: (usize, usize),
    io: &schedule::FusedRowIo,
) -> Result<()> {
    let (w_out, c) = (ctx.out_shape.w(), ctx.out_shape.c());
    let w_in = ctx.in_shape.w();
    let sched = ChannelSchedule::for_channels(ctx.opts.isa, c);
    let geom = PoolGeom {
        src: schedule::fused_base(ctx.src, 0, io.src_iter_elems),
        dst: match &io.dst_rot {
            Some(rot) => rot.names[0].clone(),
            None => schedule::fused_base(ctx.dst, 0, io.dst_iter_elems),
        },
        pool,
        stride,
        w_in,
        w_out,
        c,
        // Rolled loop terms / rotating pointers keep the alignment proofs
        // only under the shared claim rule.
        src_aligned: ctx.opts.use_aligned() && io.src_claims_aligned(ctx.src),
        dst_aligned: ctx.opts.use_aligned() && io.dst_claims_aligned(ctx.dst),
    };
    // Row bases at a zero column offset: rotating pointers, or the fused
    // base plus resolved (plane or ring-slot) row offsets.
    let base_rows: Vec<(String, usize)> = match &io.src_rot {
        Some(rot) => rot.names.iter().map(|n| (n.clone(), 0)).collect(),
        None => (0..pool.0)
            .map(|n| (geom.src.clone(), io.src_map.off(io.out_row * stride.0 + n)))
            .collect(),
    };
    if ctx.opts.unroll.keeps_cols() {
        w.open(&format!("for (j = 0; j < {w_out}; j++)"));
        let rows = fused_col_row_bases(w, io, &geom.src, stride.1 * c, &base_rows);
        w.line(&format!("float *d = {} + {} + j*{};", geom.dst, io.dst_row_off, c));
        emit_window(w, &geom, &sched, &rows, 0, "d", 0);
        w.close();
    } else {
        for j in 0..w_out {
            emit_window(
                w,
                &geom,
                &sched,
                &base_rows,
                j * stride.1 * c,
                &geom.dst.clone(),
                io.dst_row_off + j * c,
            );
        }
    }
    Ok(())
}

struct PoolGeom {
    src: String,
    dst: String,
    pool: (usize, usize),
    stride: (usize, usize),
    w_in: usize,
    w_out: usize,
    c: usize,
    /// Base-buffer alignability (knob on + generator-owned buffer).
    src_aligned: bool,
    dst_aligned: bool,
}

fn emit_bases(w: &mut CWriter, g: &PoolGeom) {
    w.line(&format!("const float *s = {} + i*{} + j*{};", g.src, g.stride.0 * g.w_in * g.c, g.stride.1 * g.c));
    w.line(&format!("float *d = {} + i*{} + j*{};", g.dst, g.w_out * g.c, g.c));
}

/// Fully unrolled window max for one output cell, per lane segment.
/// `rows[n]` is the `(base, element offset)` of window row `n` — a single
/// base with linear offsets for plane walks, resolved ring slots for
/// fused rows, or one rotating pointer per row in rotate-mode loop bodies.
#[allow(clippy::too_many_arguments)]
fn emit_window(
    w: &mut CWriter,
    g: &PoolGeom,
    sched: &ChannelSchedule,
    rows: &[(String, usize)],
    s_off: usize,
    d_name: &str,
    d_off: usize,
) {
    for seg in &sched.segments {
        if let Some(v) = seg.vec {
            let base_al = g.c % v.width == 0;
            for k0 in (seg.start..seg.end()).step_by(v.width) {
                let off0 = s_off + rows[0].1 + k0;
                let s_al = g.src_aligned && base_al && off0 % v.width == 0;
                let d_al = g.dst_aligned && base_al && (d_off + k0) % v.width == 0;
                w.open("");
                w.line(&format!("{} v = {};", v.ty, v.load(&format!("{} + {off0}", rows[0].0), s_al)));
                for n in 0..g.pool.0 {
                    for m in 0..g.pool.1 {
                        if n == 0 && m == 0 {
                            continue;
                        }
                        let off = s_off + rows[n].1 + m * g.c + k0;
                        w.line(&v.max("v", &v.load(&format!("{} + {off}", rows[n].0), s_al && off % v.width == 0)));
                    }
                }
                w.line(&v.store(&format!("{d_name} + {}", d_off + k0), "v", d_al));
                w.close();
            }
        } else {
            for k in seg.start..seg.end() {
                w.open("");
                w.line(&format!("float v = {}[{}];", rows[0].0, s_off + rows[0].1 + k));
                w.line("float t;");
                for n in 0..g.pool.0 {
                    for m in 0..g.pool.1 {
                        if n == 0 && m == 0 {
                            continue;
                        }
                        let off = s_off + rows[n].1 + m * g.c + k;
                        w.line(&format!("t = {}[{off}];", rows[n].0));
                        w.line("v = t > v ? t : v;");
                    }
                }
                w.line(&format!("{d_name}[{}] = v;", d_off + k));
                w.close();
            }
        }
    }
}
