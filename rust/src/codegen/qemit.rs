//! int8 code emission (`--dtype int8`).
//!
//! [`generate_int8`] mirrors the f32 orchestration in `codegen::mod` over
//! the same fusion/buffer machinery, but the hot path is pure integer
//! arithmetic: the input plane is quantized **once** on entry, every
//! layer consumes and produces `signed char` planes with int32
//! accumulators and multiply-shift requantization at fusion-group
//! boundaries, and the output is dequantized **once** on exit (plus a
//! float softmax epilogue when the model ends in one). No `float`
//! appears between the entry and exit planes — CI greps fused-group
//! bodies for exactly this invariant.
//!
//! Bit-exactness contract: every integer step emitted here is the same
//! arithmetic the interpreter oracle (`interp::run_quantized`) computes
//! through the shared `passes::{requant, qleaky, qavg, quantize_input}`
//! helpers, and the per-layer accumulators are proven saturation-free by
//! `passes::quantize_model` — so accumulation order cannot change the
//! result and fused/unfused, rolled/expanded emissions of the same model
//! agree bit-for-bit with the oracle and with each other.
//!
//! Portability notes baked into the emitted formulas:
//! * `>>` on negative `int` is an arithmetic shift on every gcc / clang /
//!   MSVC target (implementation-defined in C89, universal in practice;
//!   matches Rust's `i32 >>`).
//! * Activation words are composed from **sign-extended** fields through
//!   `unsigned` subwords, avoiding signed-shift UB; the final
//!   `unsigned → int` conversion above `INT_MAX` is implementation-
//!   defined in C89 but two's-complement everywhere we target.
//! * x86 deliberately avoids `_mm*_maddubs_epi16` (it saturates its int16
//!   pair sums); the exact `_mm*_madd_epi16` over sign-extended int16
//!   pairs is used instead — see `simd::QSSE`.
//!
//! Knob behavior under int8: `--tile` and `--const-mode` are ignored
//! (weights always live in static arrays; register tiling is a f32
//! concern), and `--pad` affects only the fusion partition — emission is
//! always padless region splitting, which is semantically identical to
//! zero-padding because the symmetric scheme has zero-point 0.

use super::schedule::{fused_base, AxisPlan, FusedRowIo};
use super::simd::{QChannelSchedule, QVecSpec};
use super::{
    c_ident, emit_prelude, estimate_statements, fmt_f32, harness, is_inplace, plan_buffers,
    plan_fusion, CWriter, CodegenOptions, Isa, LayerCtx, Unroll,
};
use crate::graph::{Activation, Layer, Model};
use crate::passes::{self, avg_mult, leaky_mult, LayerQuant, QuantArith, ACT_SHIFT};
use crate::tensor::Shape;
use crate::util::div_ceil;
use anyhow::{bail, Result};

/// Generate the complete int8 C source for an already-optimized model.
pub(super) fn generate_int8(
    model: &Model,
    shapes: &[Shape],
    opts: &CodegenOptions,
) -> Result<String> {
    if !matches!(opts.unroll, Unroll::KeepOuter1 | Unroll::KeepOuter2) {
        bail!(
            "--dtype int8 supports the keep-outer-1/keep-outer-2 unroll levels only (got {})",
            opts.unroll.name()
        );
    }
    let qp = passes::quantize_model(model)?;
    let bundle = plan_fusion(model, shapes, opts)?;
    let est = estimate_statements(model, shapes, opts, &bundle);
    if est > opts.max_statements {
        bail!(
            "unroll level {:?} would emit ~{est} statements for model {:?} (limit {}); \
             use a coarser unroll level",
            opts.unroll,
            model.name,
            opts.max_statements
        );
    }

    let ident = c_ident(&model.name);
    let mut w = CWriter::new();
    emit_prelude(&mut w, model, &ident, opts, shapes);

    // int8 scratch: the ping-pong buffers additionally hold the quantized
    // input plane (entry) and the int8 logits plane (exit — x_out is
    // float, so the last group cannot write it directly), hence the max
    // over the boundary planes and both endpoints. Padless emission means
    // no nncg_pad buffer ever exists on this path.
    let plan = plan_buffers(model, shapes, opts, &bundle)?;
    let qual = if opts.use_aligned() { "NNCG_ALIGN(32) " } else { "" };
    let in_n = shapes[0].numel();
    let out_n = shapes.last().unwrap().numel();
    let mut qmain = plan.main_size.max(in_n).max(out_n).max(1);
    if opts.use_aligned() {
        qmain = div_ceil(qmain, 32) * 32;
    }
    w.line(&format!("static {qual}signed char nncg_bufa[{qmain}];"));
    w.line(&format!("static {qual}signed char nncg_bufb[{qmain}];"));
    for r in &plan.rings {
        let mut elems = (r.rows * r.row_elems).max(1);
        if opts.use_aligned() {
            elems = div_ceil(elems, 32) * 32;
        }
        w.line(&format!(
            "static {qual}signed char nncg_ring{}[{elems}]; /* ring: {} rows of {} (layer {} -> {}) */",
            r.layer,
            r.rows,
            r.row_elems,
            r.layer,
            r.layer + 1
        ));
    }
    // Spill slot for vector accumulator groups: requantization is scalar
    // (per-channel multipliers), so groups round-trip through memory.
    let vec_used = model.layers.iter().any(|l| match l {
        Layer::Conv2D { weights, .. } => {
            QChannelSchedule::for_channels(opts.isa, weights.dims()[3])
                .segments
                .iter()
                .any(|s| s.vec.is_some())
        }
        _ => false,
    });
    if vec_used {
        w.line("static int nncg_qacc[8]; /* vector accumulator spill for requantization */");
    }
    w.blank();

    for (i, layer) in model.layers.iter().enumerate() {
        emit_qweight_arrays(&mut w, i, layer, &qp.layers[i], opts.isa, qual);
    }
    w.blank();

    w.line("/* Single-function CNN inference (paper's deployment model):");
    w.line(&format!(" * input:  float[{}] in HWC order {}", in_n, shapes[0]));
    w.line(&format!(" * output: float[{}] {}", out_n, shapes.last().unwrap()));
    w.line(" * int8 pipeline: quantize once on entry, integer layer chain with");
    w.line(" * multiply-shift requantization at fusion-group boundaries,");
    w.line(" * dequantize once on exit (float softmax epilogue when trailing).");
    w.line(" */");
    w.open(&format!("void {ident}_inference(const float *x_in, float *x_out)"));
    w.line("int i, j, k, n, m, o;");
    w.line("(void)i; (void)j; (void)k; (void)n; (void)m; (void)o;");

    w.blank();
    w.line(&format!("/* entry: quantize x_in (s_in = {}) */", fmt_f32(qp.input_scale)));
    w.open(&format!("for (i = 0; i < {in_n}; i++)"));
    w.line(&format!("float v = x_in[i] * {};", fmt_f32(1.0 / qp.input_scale)));
    w.line("v = v > 127.0f ? 127.0f : (v < -127.0f ? -127.0f : v);");
    w.line("nncg_bufa[i] = (signed char)(v >= 0.0f ? (int)(v + 0.5f) : (int)(v - 0.5f));");
    w.close();

    let mut cur_src: String = "nncg_bufa".to_string();
    let mut ping = false; // bufa holds the quantized input; next scratch is bufb
    for pg in &bundle.groups {
        let group = &pg.group;
        match &pg.fused {
            None => {
                let i = group.start;
                let layer = &model.layers[i];
                w.blank();
                if matches!(layer, Layer::Activation(Activation::Softmax)) {
                    // quantize_model guarantees softmax only appears as
                    // the final layer; integers pass through and the
                    // float epilogue below applies it after dequantize.
                    w.line(&format!("/* layer {i}: Soft-Max handled by the float epilogue */"));
                    continue;
                }
                let dst = if is_inplace(layer) {
                    cur_src.clone()
                } else {
                    let d = if ping { "nncg_bufa" } else { "nncg_bufb" };
                    ping = !ping;
                    d.to_string()
                };
                w.line(&format!(
                    "/* layer {i}: {} {} -> {} */",
                    layer.kind_name(),
                    shapes[i],
                    shapes[i + 1]
                ));
                emit_qlayer(
                    &mut w,
                    layer,
                    &qp.layers[i],
                    i,
                    &shapes[i],
                    &shapes[i + 1],
                    &cur_src,
                    &dst,
                    opts,
                )?;
                cur_src = dst;
            }
            Some(fp) => {
                let d = if ping { "nncg_bufa" } else { "nncg_bufb" };
                ping = !ping;
                let dst = d.to_string();
                w.blank();
                w.line(&format!(
                    "/* fused group: layers {}..{} ({} -> {}) stream rows through ring line buffers */",
                    group.start,
                    group.end - 1,
                    shapes[group.start],
                    shapes[group.end]
                ));
                super::emit_fused_group(
                    &mut w,
                    model,
                    shapes,
                    group,
                    fp,
                    &cur_src,
                    &dst,
                    &plan,
                    opts,
                    Some(&qp),
                )?;
                w.line("/* end fused group */");
                cur_src = dst;
            }
        }
    }

    let s_out = qp.layers.last().map(|l| l.out_scale()).unwrap_or(qp.input_scale);
    w.blank();
    w.line(&format!("/* exit: dequantize (s_out = {}) */", fmt_f32(s_out)));
    w.open(&format!("for (i = 0; i < {out_n}; i++)"));
    w.line(&format!("x_out[i] = (float){cur_src}[i] * {};", fmt_f32(s_out)));
    w.close();
    if qp.trailing_softmax {
        w.line("/* float softmax epilogue (the only float math besides entry/exit) */");
        w.open("");
        w.line("float mx = x_out[0];");
        w.line("float sum = 0.0f;");
        w.open(&format!("for (i = 1; i < {out_n}; i++)"));
        w.line("mx = x_out[i] > mx ? x_out[i] : mx;");
        w.close();
        w.open(&format!("for (i = 0; i < {out_n}; i++)"));
        w.line("x_out[i] = (float)exp((double)(x_out[i] - mx));");
        w.line("sum += x_out[i];");
        w.close();
        w.open(&format!("for (i = 0; i < {out_n}; i++)"));
        w.line("x_out[i] /= sum;");
        w.close();
        w.close();
    }
    w.close();

    super::emit_batch_entry(&mut w, &ident);

    if opts.test_harness {
        harness::emit_test_harness(&mut w, &ident, in_n, out_n);
    }
    Ok(w.finish())
}

// ---------------------------------------------------------------------
// Quantized weight / bias / multiplier arrays
// ---------------------------------------------------------------------

/// Emit one integer constant array, 16 values per row.
fn emit_int_array(w: &mut CWriter, qual: &str, cty: &str, name: &str, vals: &[i64]) {
    assert!(!vals.is_empty(), "empty quantized array {name}");
    w.line(&format!("static {qual}const {cty} {name}[{}] = {{", vals.len()));
    for chunk in vals.chunks(16) {
        let row: Vec<String> = chunk.iter().map(|v| v.to_string()).collect();
        w.line(&format!("    {},", row.join(", ")));
    }
    w.line("};");
}

/// Pre-packed weight array for one vector segment of a conv layer. The
/// layout matches the emission loops in [`emit_conv_cell`] exactly:
/// consecutive `load_w` addresses walk taps × channel-chunks × groups.
///
/// * chunk 2 (`qwp{i}_{start}`, int16): lane `t` holds the window-pair
///   `(w[2q], w[2q+1])` for output channel `k0+t`; an odd trailing input
///   channel zero-pads its high half (the activation word's high short is
///   also composed as zero there, so the pair product contributes 0).
/// * chunk 1 (`qws{i}_{start}`, int16): plain widened weights, 4 lanes.
/// * chunk 4 (`qwq{i}_{start}`, int8): lane `t` holds bytes for input
///   channels `4qd..4qd+3`; channels past `cin` stay zero (the matching
///   activation bytes are omitted from the composed word).
#[allow(clippy::too_many_arguments)]
fn emit_packed_segment(
    w: &mut CWriter,
    idx: usize,
    a: &QuantArith,
    v: QVecSpec,
    start: usize,
    len: usize,
    taps: usize,
    cin: usize,
    cout: usize,
    qual: &str,
) {
    let ngroups = len / v.lanes;
    let qw = |p: usize, o: usize, k: usize| a.qw[(p * cin + o) * cout + k] as i64;
    match v.chunk {
        2 => {
            let npairs = div_ceil(cin, 2);
            let mut vals = vec![0i64; taps * npairs * ngroups * 2 * v.lanes];
            for p in 0..taps {
                for q in 0..npairs {
                    for g in 0..ngroups {
                        let base = ((p * npairs + q) * ngroups + g) * 2 * v.lanes;
                        for t in 0..v.lanes {
                            let ch = start + g * v.lanes + t;
                            vals[base + 2 * t] = qw(p, 2 * q, ch);
                            if 2 * q + 1 < cin {
                                vals[base + 2 * t + 1] = qw(p, 2 * q + 1, ch);
                            }
                        }
                    }
                }
            }
            emit_int_array(w, qual, v.w_elem_ty, &format!("qwp{idx}_{start}"), &vals);
        }
        1 => {
            let mut vals = vec![0i64; taps * cin * ngroups * v.lanes];
            for p in 0..taps {
                for o in 0..cin {
                    for g in 0..ngroups {
                        for t in 0..v.lanes {
                            vals[((p * cin + o) * ngroups + g) * v.lanes + t] =
                                qw(p, o, start + g * v.lanes + t);
                        }
                    }
                }
            }
            emit_int_array(w, qual, v.w_elem_ty, &format!("qws{idx}_{start}"), &vals);
        }
        4 => {
            let nquads = div_ceil(cin, 4);
            let step = v.lanes * v.chunk; // 16 bytes per load
            let mut vals = vec![0i64; taps * nquads * ngroups * step];
            for p in 0..taps {
                for qd in 0..nquads {
                    for g in 0..ngroups {
                        let base = ((p * nquads + qd) * ngroups + g) * step;
                        for t in 0..v.lanes {
                            for b in 0..v.chunk {
                                let o = 4 * qd + b;
                                if o < cin {
                                    vals[base + t * 4 + b] = qw(p, o, start + g * v.lanes + t);
                                }
                            }
                        }
                    }
                }
            }
            emit_int_array(w, qual, v.w_elem_ty, &format!("qwq{idx}_{start}"), &vals);
        }
        c => unreachable!("unknown int8 chunk width {c}"),
    }
}

/// Emit the quantized constant arrays for one layer: packed per-segment
/// weights for vectorized convs, plain `qw{i}` for scalar lanes and for
/// depthwise/dense, and the `qb{i}` / `qm{i}` bias+multiplier tables.
fn emit_qweight_arrays(
    w: &mut CWriter,
    idx: usize,
    layer: &Layer,
    lq: &LayerQuant,
    isa: Isa,
    qual: &str,
) {
    let a = match lq {
        LayerQuant::Mac { arith, .. } => arith,
        LayerQuant::Passthrough { .. } => return,
    };
    let as_i64 = |s: &[i8]| s.iter().map(|&v| v as i64).collect::<Vec<_>>();
    match layer {
        Layer::Conv2D { weights, .. } => {
            let d = weights.dims();
            let (taps, cin, cout) = (d[0] * d[1], d[2], d[3]);
            let sched = QChannelSchedule::for_channels(isa, cout);
            let mut scalar = false;
            for seg in &sched.segments {
                match seg.vec {
                    Some(v) => {
                        emit_packed_segment(w, idx, a, v, seg.start, seg.len, taps, cin, cout, qual)
                    }
                    None => scalar = scalar || seg.len > 0,
                }
            }
            if scalar {
                emit_int_array(w, qual, "signed char", &format!("qw{idx}"), &as_i64(&a.qw));
            }
        }
        Layer::DepthwiseConv2D { .. } | Layer::Dense { .. } => {
            emit_int_array(w, qual, "signed char", &format!("qw{idx}"), &as_i64(&a.qw));
        }
        _ => return,
    }
    emit_int_array(
        w,
        qual,
        "int",
        &format!("qb{idx}"),
        &a.qb.iter().map(|&v| v as i64).collect::<Vec<_>>(),
    );
    emit_int_array(
        w,
        qual,
        "int",
        &format!("qm{idx}"),
        &a.m.iter().map(|&v| v as i64).collect::<Vec<_>>(),
    );
}

// ---------------------------------------------------------------------
// Shared emission vocabulary
// ---------------------------------------------------------------------

/// Column position of the cell being emitted: a peeled literal column or
/// the interior column loop variable `j`.
#[derive(Clone, Copy)]
enum Col {
    Lit(usize),
    Var,
}

/// `coeff*var + c` with generation-time constant folding (negative `c`
/// prints as a subtraction — C has no negative literals to index with).
fn lin(var: &str, coeff: usize, c: isize) -> String {
    match (coeff, c) {
        (1, 0) => var.to_string(),
        (1, c) if c > 0 => format!("{var} + {c}"),
        (1, c) => format!("{var} - {}", -c),
        (k, 0) => format!("{k}*{var}"),
        (k, c) if c > 0 => format!("{k}*{var} + {c}"),
        (k, c) => format!("{k}*{var} - {}", -c),
    }
}

/// Element index of channel `ch`, column-tap `m`, inside a source row
/// (the s-pointers point at row starts). Border columns resolve to plain
/// literals; the interior column loop emits `cin*stride*j + const`.
fn col_src_idx(colp: &AxisPlan, col: Col, m: usize, cin: usize, ch: usize) -> String {
    match col {
        Col::Lit(j) => {
            let s = j * colp.stride + m;
            debug_assert!(s >= colp.pad, "column tap outside its valid window");
            ((s - colp.pad) * cin + ch).to_string()
        }
        Col::Var => lin(
            "j",
            cin * colp.stride,
            (m as isize - colp.pad as isize) * cin as isize + ch as isize,
        ),
    }
}

/// Destination element index of output channel `k` at the cell's column.
fn dst_idx(col: Col, cout: usize, k: usize) -> String {
    match col {
        Col::Lit(j) => (j * cout + k).to_string(),
        Col::Var => lin("j", cout, k as isize),
    }
}

/// Destination index for vector lane `t` of a group starting at channel
/// `k0` (the requant spill loop's store address).
fn dst_idx_lane(col: Col, cout: usize, k0: usize) -> String {
    format!("{} + t", dst_idx(col, cout, k0))
}

/// Compose two sign-extended int8 values into one `int` word of int16
/// halves (the x86 madd activation broadcast). The fields pass through
/// `unsigned` subwords so no signed value is ever left-shifted; a missing
/// high element (odd `cin` tail) leaves the high short zero, matching the
/// zero-packed weight half.
fn pair_word(e0: &str, e1: Option<&str>) -> String {
    let lo = format!("(unsigned)(unsigned short)(short){e0}");
    match e1 {
        Some(e1) => {
            format!("(int)({lo} | (unsigned)(unsigned short)(short){e1} << 16)")
        }
        None => format!("(int)({lo})"),
    }
}

/// Compose up to four int8 values into one `int` word of bytes (the SDOT
/// activation broadcast); omitted bytes (cin remainder) stay zero and
/// pair with zero-padded weight bytes.
fn quad_word(exprs: &[String]) -> String {
    let terms: Vec<String> = exprs
        .iter()
        .enumerate()
        .map(|(b, e)| {
            let byte = format!("(unsigned)(unsigned char){e}");
            if b == 0 {
                byte
            } else {
                format!("{byte} << {}", 8 * b)
            }
        })
        .collect();
    format!("(int)({})", terms.join(" | "))
}

/// The int32 → int8 requantization statements on variable `v`, followed
/// by the integer activation — the C mirror of [`passes::requant`] (plus
/// `qleaky`). Softmax emits nothing: it is never integer.
fn emit_requant_lines(
    w: &mut CWriter,
    v: &str,
    m_expr: &str,
    pre: u32,
    post: u32,
    act: Activation,
) {
    if pre > 0 {
        w.line(&format!("{v} = ({v} + {}) >> {pre};", 1i64 << (pre - 1)));
    }
    w.line(&format!("{v} = ({v} * {m_expr} + {}) >> {post};", 1i64 << (post - 1)));
    w.line(&format!("{v} = {v} > 127 ? 127 : ({v} < -127 ? -127 : {v});"));
    emit_qact_lines(w, v, act);
}

/// Integer activation on an already-requantized value (P2: ternaries).
fn emit_qact_lines(w: &mut CWriter, v: &str, act: Activation) {
    match act {
        Activation::None | Activation::Softmax => {}
        Activation::Relu => w.line(&format!("{v} = {v} > 0 ? {v} : 0;")),
        Activation::LeakyRelu(alpha) => w.line(&format!(
            "{v} = {v} > 0 ? {v} : (({v} * {} + {}) >> {});",
            leaky_mult(alpha),
            1i64 << (ACT_SHIFT - 1),
            ACT_SHIFT
        )),
    }
}

fn mac_arith<'a>(lq: &'a LayerQuant, kind: &str) -> Result<&'a QuantArith> {
    match lq {
        LayerQuant::Mac { arith, .. } => Ok(arith),
        LayerQuant::Passthrough { .. } => bail!("{kind} layer is missing its Mac quant record"),
    }
}

// ---------------------------------------------------------------------
// Convolution rows (shared by the fused and whole-plane paths)
// ---------------------------------------------------------------------

/// Everything one conv cell/row emission needs (threading it as one
/// struct keeps the border/interior call sites identical).
struct ConvCellCtx<'a> {
    idx: usize,
    a: &'a QuantArith,
    sched: &'a QChannelSchedule,
    cin: usize,
    cout: usize,
    /// Kernel width (column taps).
    wk: usize,
    /// First valid row tap of this output row.
    kr0: usize,
    /// Number of valid row taps (== number of s-pointers).
    ntr: usize,
    colp: &'a AxisPlan,
    act: Activation,
}

/// One output cell: every channel-schedule segment's accumulator groups
/// and scalar lanes over the valid tap window `(m0, m1)`.
fn emit_conv_cell(w: &mut CWriter, cc: &ConvCellCtx<'_>, col: Col, win: (usize, usize)) {
    let (m0, m1) = win;
    let src =
        |tr: usize, m: usize, ch: usize| format!("s{tr}[{}]", col_src_idx(cc.colp, col, m, cc.cin, ch));
    for seg in &cc.sched.segments {
        match seg.vec {
            Some(v) => {
                let ngroups = seg.len / v.lanes;
                for g in 0..ngroups {
                    let k0 = seg.start + g * v.lanes;
                    w.open("");
                    w.line(&format!(
                        "{} qacc = {};",
                        v.acc_ty,
                        v.load_acc(&format!("qb{} + {k0}", cc.idx))
                    ));
                    w.line("int t, qv;");
                    for tr in 0..cc.ntr {
                        for m in m0..m1 {
                            let p = (cc.kr0 + tr) * cc.wk + m;
                            match v.chunk {
                                2 => {
                                    let npairs = div_ceil(cc.cin, 2);
                                    for q in 0..npairs {
                                        let e0 = src(tr, m, 2 * q);
                                        let e1 = (2 * q + 1 < cc.cin).then(|| src(tr, m, 2 * q + 1));
                                        let word = pair_word(&e0, e1.as_deref());
                                        let waddr = format!(
                                            "qwp{}_{} + {}",
                                            cc.idx,
                                            seg.start,
                                            ((p * npairs + q) * ngroups + g) * 2 * v.lanes
                                        );
                                        w.line(&v.madd(
                                            &v.broadcast(&word),
                                            &v.load_w(&waddr),
                                            "qacc",
                                        ));
                                    }
                                }
                                1 => {
                                    for o in 0..cc.cin {
                                        let word = format!("(short){}", src(tr, m, o));
                                        let waddr = format!(
                                            "qws{}_{} + {}",
                                            cc.idx,
                                            seg.start,
                                            ((p * cc.cin + o) * ngroups + g) * v.lanes
                                        );
                                        w.line(&v.madd(
                                            &v.broadcast(&word),
                                            &v.load_w(&waddr),
                                            "qacc",
                                        ));
                                    }
                                }
                                4 => {
                                    let nquads = div_ceil(cc.cin, 4);
                                    for qd in 0..nquads {
                                        let exprs: Vec<String> = (0..4)
                                            .filter(|&b| 4 * qd + b < cc.cin)
                                            .map(|b| src(tr, m, 4 * qd + b))
                                            .collect();
                                        let word = quad_word(&exprs);
                                        let waddr = format!(
                                            "qwq{}_{} + {}",
                                            cc.idx,
                                            seg.start,
                                            ((p * nquads + qd) * ngroups + g) * v.lanes * v.chunk
                                        );
                                        w.line(&v.madd(
                                            &v.broadcast(&word),
                                            &v.load_w(&waddr),
                                            "qacc",
                                        ));
                                    }
                                }
                                c => unreachable!("unknown int8 chunk width {c}"),
                            }
                        }
                    }
                    w.line(&v.store_acc("nncg_qacc", "qacc"));
                    w.open(&format!("for (t = 0; t < {}; t++)", v.lanes));
                    w.line("qv = nncg_qacc[t];");
                    emit_requant_lines(
                        w,
                        "qv",
                        &format!("qm{}[{k0} + t]", cc.idx),
                        cc.a.pre,
                        cc.a.post,
                        cc.act,
                    );
                    w.line(&format!("d[{}] = (signed char)qv;", dst_idx_lane(col, cc.cout, k0)));
                    w.close();
                    w.close();
                }
            }
            None => {
                for kc in seg.start..seg.start + seg.len {
                    w.open("");
                    w.line(&format!("int qv = qb{}[{kc}];", cc.idx));
                    for tr in 0..cc.ntr {
                        for m in m0..m1 {
                            let p = (cc.kr0 + tr) * cc.wk + m;
                            for o in 0..cc.cin {
                                w.line(&format!(
                                    "qv += (int){} * qw{}[{}];",
                                    src(tr, m, o),
                                    cc.idx,
                                    (p * cc.cin + o) * cc.cout + kc
                                ));
                            }
                        }
                    }
                    emit_requant_lines(
                        w,
                        "qv",
                        &format!("qm{}[{kc}]", cc.idx),
                        cc.a.pre,
                        cc.a.post,
                        cc.act,
                    );
                    w.line(&format!("d[{}] = (signed char)qv;", dst_idx(col, cc.cout, kc)));
                    w.close();
                }
            }
        }
    }
}

/// One full conv output row: s-pointer prologue, peeled border columns,
/// interior column loop (or literal unroll under keep-outer-1), trailing
/// border columns.
fn emit_conv_row_block(
    w: &mut CWriter,
    cc: &ConvCellCtx<'_>,
    src_exprs: &[String],
    dst_expr: &str,
    keeps_cols: bool,
) {
    w.open("");
    for (t, e) in src_exprs.iter().enumerate() {
        w.line(&format!("const signed char *s{t} = {e};"));
    }
    w.line(&format!("signed char *d = {dst_expr};"));
    let colp = cc.colp;
    for j in 0..colp.lo {
        emit_conv_cell(w, cc, Col::Lit(j), colp.window(j));
    }
    if colp.interior() > 0 {
        if keeps_cols {
            w.open(&format!("for (j = {}; j < {}; j++)", colp.lo, colp.hi));
            emit_conv_cell(w, cc, Col::Var, (0, cc.wk));
            w.close();
        } else {
            for j in colp.lo..colp.hi {
                emit_conv_cell(w, cc, Col::Lit(j), (0, cc.wk));
            }
        }
    }
    for j in colp.hi..colp.out {
        emit_conv_cell(w, cc, Col::Lit(j), colp.window(j));
    }
    w.close();
}

// ---------------------------------------------------------------------
// Fused row emission (called from `emit_fused_group` via `emit_qrow`)
// ---------------------------------------------------------------------

/// Emit one fused int8 row op, addressing rows through the same
/// [`FusedRowIo`] contract the f32 row emitters use (rotating ring
/// pointers, frozen slots, or steady-state plane bases).
pub(super) fn emit_qrow(
    w: &mut CWriter,
    ctx: &LayerCtx<'_>,
    layer: &Layer,
    lq: &LayerQuant,
    io: &FusedRowIo,
) -> Result<()> {
    let keeps_cols = ctx.opts.unroll.keeps_cols();
    let dst_expr = match &io.dst_rot {
        Some(rot) => rot.names[0].clone(),
        None => fused_base(ctx.dst, io.dst_row_off, io.dst_iter_elems),
    };
    match layer {
        Layer::Conv2D { weights, stride, padding, activation, .. } => {
            let a = mac_arith(lq, "Conv2D")?;
            let d = weights.dims();
            let (in_h, in_w, cin) = (ctx.in_shape.h(), ctx.in_shape.w(), ctx.in_shape.c());
            let (out_h, pad_h) = padding.resolve(in_h, d[0], stride.0)?;
            let (out_w, pad_w) = padding.resolve(in_w, d[1], stride.1)?;
            let rowp = AxisPlan::padless(out_h, stride.0, d[0], pad_h, in_h);
            let colp = AxisPlan::padless(out_w, stride.1, d[1], pad_w, in_w);
            let (k0r, k1r) = rowp.window(io.out_row);
            let p0 = rowp.src_start(io.out_row);
            let src_exprs: Vec<String> = (0..k1r - k0r)
                .map(|t| match &io.src_rot {
                    Some(rot) => rot.names[t].clone(),
                    None => fused_base(ctx.src, io.src_map.off(p0 + t), io.src_iter_elems),
                })
                .collect();
            let sched = QChannelSchedule::for_channels(ctx.opts.isa, d[3]);
            let cc = ConvCellCtx {
                idx: ctx.idx,
                a,
                sched: &sched,
                cin,
                cout: d[3],
                wk: d[1],
                kr0: k0r,
                ntr: k1r - k0r,
                colp: &colp,
                act: *activation,
            };
            emit_conv_row_block(w, &cc, &src_exprs, &dst_expr, keeps_cols);
            Ok(())
        }
        Layer::MaxPool2D { pool, stride } => {
            let (in_w, c) = (ctx.in_shape.w(), ctx.in_shape.c());
            let colp = AxisPlan::padless(ctx.out_shape.w(), stride.1, pool.1, 0, in_w);
            let p0 = io.out_row * stride.0;
            let src_exprs: Vec<String> = (0..pool.0)
                .map(|t| match &io.src_rot {
                    Some(rot) => rot.names[t].clone(),
                    None => fused_base(ctx.src, io.src_map.off(p0 + t), io.src_iter_elems),
                })
                .collect();
            emit_maxpool_row_block(w, &src_exprs, &dst_expr, &colp, pool.0, c, keeps_cols);
            Ok(())
        }
        Layer::Activation(act) => {
            let n = ctx.out_shape.w() * ctx.out_shape.c();
            let s0 = match &io.src_rot {
                Some(rot) => rot.names[0].clone(),
                None => fused_base(ctx.src, io.src_map.off(io.out_row), io.src_iter_elems),
            };
            w.open("");
            w.line(&format!("const signed char *s0 = {s0};"));
            w.line(&format!("signed char *d = {dst_expr};"));
            w.open(&format!("for (j = 0; j < {n}; j++)"));
            match act {
                Activation::None | Activation::Softmax => w.line("d[j] = s0[j];"),
                _ => {
                    w.line("int qv = s0[j];");
                    emit_qact_lines(w, "qv", *act);
                    w.line("d[j] = (signed char)qv;");
                }
            }
            w.close();
            w.close();
            Ok(())
        }
        other => bail!("layer {} cannot be fused on the int8 path", other.kind_name()),
    }
}

// ---------------------------------------------------------------------
// Pool rows (shared fused/unfused)
// ---------------------------------------------------------------------

fn emit_maxpool_cell(
    w: &mut CWriter,
    colp: &AxisPlan,
    ntr: usize,
    pool_w: usize,
    c: usize,
    col: Col,
) {
    for kc in 0..c {
        w.open("");
        w.line(&format!("int qv = s0[{}];", col_src_idx(colp, col, 0, c, kc)));
        if ntr * pool_w > 1 {
            w.line("int qt;");
        }
        for tr in 0..ntr {
            for m in 0..pool_w {
                if tr == 0 && m == 0 {
                    continue;
                }
                w.line(&format!(
                    "qt = s{tr}[{}]; qv = qt > qv ? qt : qv;",
                    col_src_idx(colp, col, m, c, kc)
                ));
            }
        }
        w.line(&format!("d[{}] = (signed char)qv;", dst_idx(col, c, kc)));
        w.close();
    }
}

fn emit_maxpool_row_block(
    w: &mut CWriter,
    src_exprs: &[String],
    dst_expr: &str,
    colp: &AxisPlan,
    pool_h: usize,
    c: usize,
    keeps_cols: bool,
) {
    w.open("");
    for (t, e) in src_exprs.iter().enumerate() {
        w.line(&format!("const signed char *s{t} = {e};"));
    }
    w.line(&format!("signed char *d = {dst_expr};"));
    // Pooling never pads: every column is interior with a full window.
    if keeps_cols {
        w.open(&format!("for (j = 0; j < {}; j++)", colp.out));
        emit_maxpool_cell(w, colp, pool_h, colp.kernel, c, Col::Var);
        w.close();
    } else {
        for j in 0..colp.out {
            emit_maxpool_cell(w, colp, pool_h, colp.kernel, c, Col::Lit(j));
        }
    }
    w.close();
}

fn emit_avgpool_cell(
    w: &mut CWriter,
    colp: &AxisPlan,
    ntr: usize,
    pool_w: usize,
    c: usize,
    col: Col,
) {
    let mult = avg_mult(ntr * pool_w);
    for kc in 0..c {
        w.open("");
        w.line(&format!("int qv = s0[{}];", col_src_idx(colp, col, 0, c, kc)));
        for tr in 0..ntr {
            for m in 0..pool_w {
                if tr == 0 && m == 0 {
                    continue;
                }
                w.line(&format!("qv += s{tr}[{}];", col_src_idx(colp, col, m, c, kc)));
            }
        }
        // Q15 window average, the C mirror of passes::qavg.
        w.line(&format!("qv = (qv * {mult} + {}) >> {};", 1i64 << (ACT_SHIFT - 1), ACT_SHIFT));
        w.line("qv = qv > 127 ? 127 : (qv < -127 ? -127 : qv);");
        w.line(&format!("d[{}] = (signed char)qv;", dst_idx(col, c, kc)));
        w.close();
    }
}

// ---------------------------------------------------------------------
// Whole-plane (unfused) layer emitters
// ---------------------------------------------------------------------

/// Emit one unfused int8 layer writing `dst` from `src` (both int8
/// planes).
#[allow(clippy::too_many_arguments)]
fn emit_qlayer(
    w: &mut CWriter,
    layer: &Layer,
    lq: &LayerQuant,
    idx: usize,
    in_s: &Shape,
    out_s: &Shape,
    src: &str,
    dst: &str,
    opts: &CodegenOptions,
) -> Result<()> {
    let keeps_cols = opts.unroll.keeps_cols();
    match layer {
        Layer::Conv2D { weights, stride, padding, activation, .. } => {
            let a = mac_arith(lq, "Conv2D")?;
            let d = weights.dims();
            let (in_h, in_w, cin) = (in_s.h(), in_s.w(), in_s.c());
            let (out_h, pad_h) = padding.resolve(in_h, d[0], stride.0)?;
            let (out_w, pad_w) = padding.resolve(in_w, d[1], stride.1)?;
            let rowp = AxisPlan::padless(out_h, stride.0, d[0], pad_h, in_h);
            let colp = AxisPlan::padless(out_w, stride.1, d[1], pad_w, in_w);
            let rin = in_w * cin;
            let rout = out_w * d[3];
            let sched = QChannelSchedule::for_channels(opts.isa, d[3]);
            let border = |w: &mut CWriter, r: usize| {
                let (k0, k1) = rowp.window(r);
                let p0 = rowp.src_start(r);
                let src_exprs: Vec<String> =
                    (0..k1 - k0).map(|t| fused_base(src, (p0 + t) * rin, 0)).collect();
                let dst_expr = fused_base(dst, r * rout, 0);
                let cc = ConvCellCtx {
                    idx,
                    a,
                    sched: &sched,
                    cin,
                    cout: d[3],
                    wk: d[1],
                    kr0: k0,
                    ntr: k1 - k0,
                    colp: &colp,
                    act: *activation,
                };
                emit_conv_row_block(w, &cc, &src_exprs, &dst_expr, keeps_cols);
            };
            for r in 0..rowp.lo {
                border(w, r);
            }
            if rowp.interior() > 0 {
                w.open(&format!("for (i = {}; i < {}; i++)", rowp.lo, rowp.hi));
                let src_exprs: Vec<String> = (0..d[0])
                    .map(|t| {
                        format!(
                            "({src} + {rin}*({}))",
                            lin("i", stride.0, t as isize - pad_h as isize)
                        )
                    })
                    .collect();
                let dst_expr = format!("({dst} + {rout}*i)");
                let cc = ConvCellCtx {
                    idx,
                    a,
                    sched: &sched,
                    cin,
                    cout: d[3],
                    wk: d[1],
                    kr0: 0,
                    ntr: d[0],
                    colp: &colp,
                    act: *activation,
                };
                emit_conv_row_block(w, &cc, &src_exprs, &dst_expr, keeps_cols);
                w.close();
            }
            for r in rowp.hi..rowp.out {
                border(w, r);
            }
            Ok(())
        }
        Layer::DepthwiseConv2D { weights, stride, padding, activation, .. } => {
            let a = mac_arith(lq, "DepthwiseConv2D")?;
            let d = weights.dims(); // [kh, kw, c]
            let c = d[2];
            let (in_h, in_w) = (in_s.h(), in_s.w());
            let (out_h, pad_h) = padding.resolve(in_h, d[0], stride.0)?;
            let (out_w, pad_w) = padding.resolve(in_w, d[1], stride.1)?;
            let rowp = AxisPlan::padless(out_h, stride.0, d[0], pad_h, in_h);
            let colp = AxisPlan::padless(out_w, stride.1, d[1], pad_w, in_w);
            let rin = in_w * c;
            let rout = out_w * c;
            let row = |w: &mut CWriter, src_exprs: &[String], dst_expr: &str, k0: usize, ntr: usize| {
                w.open("");
                for (t, e) in src_exprs.iter().enumerate() {
                    w.line(&format!("const signed char *s{t} = {e};"));
                }
                w.line(&format!("signed char *d = {dst_expr};"));
                let cell = |w: &mut CWriter, col: Col, win: (usize, usize)| {
                    for kc in 0..c {
                        w.open("");
                        w.line(&format!("int qv = qb{idx}[{kc}];"));
                        for tr in 0..ntr {
                            for m in win.0..win.1 {
                                let p = (k0 + tr) * d[1] + m;
                                w.line(&format!(
                                    "qv += (int)s{tr}[{}] * qw{idx}[{}];",
                                    col_src_idx(&colp, col, m, c, kc),
                                    p * c + kc
                                ));
                            }
                        }
                        emit_requant_lines(w, "qv", &format!("qm{idx}[{kc}]"), a.pre, a.post, *activation);
                        w.line(&format!("d[{}] = (signed char)qv;", dst_idx(col, c, kc)));
                        w.close();
                    }
                };
                for j in 0..colp.lo {
                    cell(w, Col::Lit(j), colp.window(j));
                }
                if colp.interior() > 0 {
                    if keeps_cols {
                        w.open(&format!("for (j = {}; j < {}; j++)", colp.lo, colp.hi));
                        cell(w, Col::Var, (0, d[1]));
                        w.close();
                    } else {
                        for j in colp.lo..colp.hi {
                            cell(w, Col::Lit(j), (0, d[1]));
                        }
                    }
                }
                for j in colp.hi..colp.out {
                    cell(w, Col::Lit(j), colp.window(j));
                }
                w.close();
            };
            for r in 0..rowp.lo {
                let (k0, k1) = rowp.window(r);
                let p0 = rowp.src_start(r);
                let src_exprs: Vec<String> =
                    (0..k1 - k0).map(|t| fused_base(src, (p0 + t) * rin, 0)).collect();
                row(w, &src_exprs, &fused_base(dst, r * rout, 0), k0, k1 - k0);
            }
            if rowp.interior() > 0 {
                w.open(&format!("for (i = {}; i < {}; i++)", rowp.lo, rowp.hi));
                let src_exprs: Vec<String> = (0..d[0])
                    .map(|t| {
                        format!(
                            "({src} + {rin}*({}))",
                            lin("i", stride.0, t as isize - pad_h as isize)
                        )
                    })
                    .collect();
                row(w, &src_exprs, &format!("({dst} + {rout}*i)"), 0, d[0]);
                w.close();
            }
            for r in rowp.hi..rowp.out {
                let (k0, k1) = rowp.window(r);
                let p0 = rowp.src_start(r);
                let src_exprs: Vec<String> =
                    (0..k1 - k0).map(|t| fused_base(src, (p0 + t) * rin, 0)).collect();
                row(w, &src_exprs, &fused_base(dst, r * rout, 0), k0, k1 - k0);
            }
            Ok(())
        }
        Layer::MaxPool2D { pool, stride } | Layer::AvgPool2D { pool, stride } => {
            let c = in_s.c();
            let in_w = in_s.w();
            let (out_h, out_w) = (out_s.h(), out_s.w());
            let colp = AxisPlan::padless(out_w, stride.1, pool.1, 0, in_w);
            let rin = in_w * c;
            let rout = out_w * c;
            let is_max = matches!(layer, Layer::MaxPool2D { .. });
            w.open(&format!("for (i = 0; i < {out_h}; i++)"));
            for t in 0..pool.0 {
                w.line(&format!(
                    "const signed char *s{t} = {src} + {rin}*({});",
                    lin("i", stride.0, t as isize)
                ));
            }
            w.line(&format!("signed char *d = {dst} + {rout}*i;"));
            let cols = |w: &mut CWriter, col: Col| {
                if is_max {
                    emit_maxpool_cell(w, &colp, pool.0, pool.1, c, col);
                } else {
                    emit_avgpool_cell(w, &colp, pool.0, pool.1, c, col);
                }
            };
            if keeps_cols {
                w.open(&format!("for (j = 0; j < {out_w}; j++)"));
                cols(w, Col::Var);
                w.close();
            } else {
                for j in 0..out_w {
                    cols(w, Col::Lit(j));
                }
            }
            w.close();
            Ok(())
        }
        Layer::Dense { weights, activation, .. } => {
            // Dense stays a loop nest on the int8 path: one statement per
            // MAC would explode generated-code size for fully-connected
            // heads, and the scalar int32 loop is already the exact
            // oracle arithmetic (documented deviation from the f32
            // emitter's unrolled dense).
            let a = mac_arith(lq, "Dense")?;
            let d = weights.dims(); // [n_in, n_out]
            w.open(&format!("for (j = 0; j < {}; j++)", d[1]));
            w.line(&format!("int qv = qb{idx}[j];"));
            w.open(&format!("for (k = 0; k < {}; k++)", d[0]));
            w.line(&format!("qv += (int){src}[k] * qw{idx}[{}*k + j];", d[1]));
            w.close();
            emit_requant_lines(w, "qv", &format!("qm{idx}[j]"), a.pre, a.post, *activation);
            w.line(&format!("{dst}[j] = (signed char)qv;"));
            w.close();
            Ok(())
        }
        Layer::Activation(act) => {
            let nel = in_s.numel();
            match act {
                Activation::None | Activation::Softmax => {
                    if src != dst {
                        w.open(&format!("for (i = 0; i < {nel}; i++)"));
                        w.line(&format!("{dst}[i] = {src}[i];"));
                        w.close();
                    }
                }
                _ => {
                    w.open(&format!("for (i = 0; i < {nel}; i++)"));
                    w.line(&format!("int qv = {src}[i];"));
                    emit_qact_lines(w, "qv", *act);
                    w.line(&format!("{dst}[i] = (signed char)qv;"));
                    w.close();
                }
            }
            Ok(())
        }
        Layer::Flatten => {
            // HWC is already flat; only copy if src/dst differ.
            if src != dst {
                let nel = in_s.numel();
                w.open(&format!("for (i = 0; i < {nel}; i++)"));
                w.line(&format!("{dst}[i] = {src}[i];"));
                w.close();
            }
            Ok(())
        }
        Layer::BatchNorm { .. } => bail!("BatchNorm must be folded before codegen (passes::optimize)"),
        Layer::Dropout { .. } => bail!("Dropout must be elided before codegen (passes::optimize)"),
    }
}

#[cfg(test)]
mod tests {
    use super::super::{generate_c, CodegenOptions, DType, FuseMode, Isa, Unroll};
    use crate::graph::zoo;

    fn int8_opts(isa: Isa) -> CodegenOptions {
        CodegenOptions { isa, dtype: DType::Int8, ..Default::default() }
    }

    fn gen(model: &str, opts: &CodegenOptions) -> String {
        let m = zoo::by_name(model).unwrap().with_random_weights(13);
        generate_c(&m, opts).unwrap()
    }

    #[test]
    fn int8_generates_for_all_models_and_isas() {
        for name in zoo::PAPER_MODELS {
            for isa in [Isa::Generic, Isa::Sse3, Isa::Avx2, Isa::Neon, Isa::NeonDot] {
                let src = gen(name, &int8_opts(isa));
                assert!(
                    src.contains("_inference(const float *x_in, float *x_out)"),
                    "{name}/{isa:?}: missing entry point"
                );
                assert!(
                    src.contains("_inference_batch(const float *x_in, float *x_out, int n)"),
                    "{name}/{isa:?}: missing batch entry point"
                );
                assert!(src.contains("signed char nncg_bufa"), "{name}/{isa:?}");
                // Saturating/wrapping intrinsics must never appear.
                assert!(!src.contains("maddubs"), "{name}/{isa:?}: saturating madd");
                assert!(!src.contains("vmlal_s8"), "{name}/{isa:?}: wrapping int16 acc");
            }
        }
    }

    #[test]
    fn int8_generic_is_ansi_only() {
        for name in zoo::PAPER_MODELS {
            let src = gen(name, &int8_opts(Isa::Generic));
            assert!(!src.contains("emmintrin"), "{name}");
            assert!(!src.contains("immintrin"), "{name}");
            assert!(!src.contains("arm_neon"), "{name}");
            assert!(!src.contains("nncg_qacc"), "{name}: no vector spill in scalar code");
        }
    }

    #[test]
    fn int8_fused_group_bodies_contain_no_float() {
        // The same invariant CI greps on generated files: between the
        // fused-group markers, the hot loop is pure integer code.
        for name in zoo::PAPER_MODELS {
            for isa in [Isa::Generic, Isa::Avx2, Isa::NeonDot] {
                let opts = CodegenOptions { fuse: FuseMode::Auto, ..int8_opts(isa) };
                let src = gen(name, &opts);
                let mut groups = 0usize;
                let mut inside = false;
                for line in src.lines() {
                    if line.contains("/* fused group:") {
                        inside = true;
                        groups += 1;
                        continue;
                    }
                    if line.contains("/* end fused group */") {
                        inside = false;
                        continue;
                    }
                    if inside {
                        assert!(
                            !line.contains("float"),
                            "{name}/{isa:?}: float inside fused group body: {line}"
                        );
                    }
                }
                assert!(!inside, "{name}/{isa:?}: unterminated fused group");
                assert!(groups > 0, "{name}/{isa:?}: expected at least one fused group");
            }
        }
    }

    #[test]
    fn neon_dot_emits_sdot_and_packed_quads() {
        let src = gen("robot", &int8_opts(Isa::NeonDot));
        assert!(src.contains("vdotq_s32"));
        assert!(src.contains("qwq"));
        assert!(src.contains("vreinterpretq_s8_s32"));
    }

    #[test]
    fn x86_int8_uses_exact_madd_pairs() {
        let src = gen("robot", &int8_opts(Isa::Avx2));
        assert!(src.contains("_mm256_madd_epi16"));
        assert!(src.contains("qwp"));
        let src = gen("robot", &int8_opts(Isa::Sse3));
        assert!(src.contains("_mm_madd_epi16"));
    }

    #[test]
    fn int8_rejects_unsupported_unroll_levels() {
        let m = zoo::ball_classifier().with_random_weights(13);
        for unroll in [Unroll::None, Unroll::Full] {
            let opts = CodegenOptions { unroll, ..int8_opts(Isa::Generic) };
            assert!(generate_c(&m, &opts).is_err(), "{unroll:?} must be rejected under int8");
        }
    }

    #[test]
    fn int8_generation_is_deterministic() {
        let a = gen("pedestrian", &int8_opts(Isa::Avx2));
        let b = gen("pedestrian", &int8_opts(Isa::Avx2));
        assert_eq!(a, b);
    }

    #[test]
    fn int8_entry_and_exit_planes_are_float() {
        let src = gen("ball", &int8_opts(Isa::Generic));
        assert!(src.contains("/* entry: quantize x_in"));
        assert!(src.contains("/* exit: dequantize"));
        // ball ends in softmax: the float epilogue must be present.
        assert!(src.contains("float softmax epilogue"));
        assert!(src.contains("#include <math.h>"));
    }
}
