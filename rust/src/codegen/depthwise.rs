//! Depthwise convolution and average-pooling emitters (paper future work:
//! "more layer types to support modern widely known CNN structures" —
//! together with 1×1 convs these are the MobileNet building blocks the
//! paper's size anecdote refers to).
//!
//! Depthwise conv is the best case for the paper's channel-minor SIMD
//! scheme (P4): each tap is a pure elementwise `y[k] += w[n,m,k] * x[k]`
//! across channels — a vector multiply with **no broadcast at all**.
//! It shares the conv emitter's spatial machinery: padless region-split
//! borders, lane-scheduled channels (vector groups + scalar tail), and
//! weight-stationary register tiles across interior columns.

use super::conv::{padded_extent, scalar_act, RowAddr, SpatialWalk, TapWindow};
use super::cwriter::{fmt_f32, CWriter};
use super::schedule::{self, AxisPlan, PadStrategy};
use super::simd::{emit_vec_activation, ChannelSchedule, VecSpec};
use super::{ConstMode, LayerCtx, Unroll};
use crate::graph::{Activation, Padding};
use crate::tensor::Tensor;
use anyhow::{bail, Result};

pub(crate) fn emit_depthwise(
    w: &mut CWriter,
    ctx: &LayerCtx<'_>,
    weights: &Tensor,
    bias: &Tensor,
    stride: (usize, usize),
    padding: Padding,
    activation: Activation,
) -> Result<()> {
    let wd = weights.dims();
    let (h_k, w_k, c) = (wd[0], wd[1], wd[2]);
    let (h_in, w_in) = (ctx.in_shape.h(), ctx.in_shape.w());
    let (h_out, w_out) = (ctx.out_shape.h(), ctx.out_shape.w());
    // Reuse the conv padding machinery via a pseudo-HWIO dims slice.
    let pseudo = [h_k, w_k, c, c];
    let (ph, pw) = padded_extent(ctx.in_shape, &pseudo, stride, padding)?;
    let pads = (ph, pw) != (h_in, w_in);
    let (pad_top, pad_left) = match padding {
        Padding::Same => {
            let (_, pt) = padding.resolve(h_in, h_k, stride.0)?;
            let (_, pl) = padding.resolve(w_in, w_k, stride.1)?;
            (pt, pl)
        }
        Padding::Valid => (0, 0),
    };

    let inline = ctx.opts.effective_const_mode() == ConstMode::Inline;
    if ctx.opts.unroll == Unroll::None && inline {
        bail!("Unroll::None requires ConstMode::Array");
    }

    let sched = ChannelSchedule::for_channels(ctx.opts.isa, c);
    let padless = pads && schedule::pad_strategy(ctx.opts) == PadStrategy::Padless;
    let src = if pads && !padless {
        super::conv::emit_pad_fill_public(w, ctx, h_in, w_in, c, ph, pw, pad_top, pad_left)?;
        ctx.padbuf.to_string()
    } else {
        ctx.src.to_string()
    };

    let (rows, cols) = if padless {
        (
            AxisPlan::padless(h_out, stride.0, h_k, pad_top, h_in),
            AxisPlan::padless(w_out, stride.1, w_k, pad_left, w_in),
        )
    } else {
        let (src_h, src_w) = if pads { (ph, pw) } else { (h_in, w_in) };
        (AxisPlan::full(h_out, stride.0, h_k, src_h), AxisPlan::full(w_out, stride.1, w_k, src_w))
    };
    let row_elems = cols.input * c;
    let (tile_rows, tile) = schedule::tile_shape(ctx.opts, &sched, rows.interior(), cols.interior());

    // The depthwise kernel loops are always unrolled (they are tiny), so
    // the loop-form level shares the kept-spatial-loop walk.
    let walk_unroll = if ctx.opts.unroll == Unroll::None { Unroll::KeepOuter2 } else { ctx.opts.unroll };
    let src_static = schedule::static_buf(&src);
    let dst_static = schedule::static_buf(ctx.dst);
    let walk = SpatialWalk {
        rows,
        cols,
        tile,
        tile_rows,
        unroll: walk_unroll,
        src,
        dst: ctx.dst.to_string(),
        row_elems,
        cmin: c,
        out_minor: c,
        src_rows: 0,
    };
    let cells = DwCells {
        ctx,
        weights,
        bias,
        activation,
        sched: &sched,
        row_addr: RowAddr::Linear(row_elems),
        w_k,
        c,
        src_static,
        dst_static,
    };
    walk.emit(w, |w, win, s, so, d, dofs| cells.emit_block(w, win, s, so, d, dofs));

    if activation == Activation::Softmax {
        super::activation::emit_softmax_over(w, ctx, ctx.dst, ctx.out_shape.numel());
    }
    Ok(())
}

/// One constant-coordinate output row of a depthwise convolution inside a
/// fusion group (see [`super::conv::emit_conv_row_fused`]; inside the
/// steady-state rolled loop the bases additionally advance
/// `io.*_iter_elems` floats per loop iteration `i`).
pub(crate) fn emit_depthwise_row_fused(
    w: &mut CWriter,
    ctx: &LayerCtx<'_>,
    weights: &Tensor,
    bias: &Tensor,
    stride: (usize, usize),
    padding: Padding,
    activation: Activation,
    io: &schedule::FusedRowIo,
) -> Result<()> {
    debug_assert!(activation != Activation::Softmax, "softmax heads are never fused");
    let wd = weights.dims();
    let (h_k, w_k, c) = (wd[0], wd[1], wd[2]);
    let (h_in, w_in) = (ctx.in_shape.h(), ctx.in_shape.w());
    let (h_out, w_out) = (ctx.out_shape.h(), ctx.out_shape.w());
    let (pad_top, pad_left) = match padding {
        Padding::Same => {
            let (_, pt) = padding.resolve(h_in, h_k, stride.0)?;
            let (_, pl) = padding.resolve(w_in, w_k, stride.1)?;
            (pt, pl)
        }
        Padding::Valid => (0, 0),
    };
    let sched = ChannelSchedule::for_channels(ctx.opts.isa, c);
    let rows = AxisPlan::padless(h_out, stride.0, h_k, pad_top, h_in);
    let cols = AxisPlan::padless(w_out, stride.1, w_k, pad_left, w_in);
    let (n0, n1) = rows.window(io.out_row);
    let p0 = rows.src_start(io.out_row);
    let (row_addr, src_rows) = match &io.src_rot {
        // Rotating ring source: one pointer alias per window row.
        Some(rot) => {
            debug_assert_eq!(rot.names.len(), n1 - n0, "rotating pointer set must cover the window");
            (RowAddr::Rotating(rot.names.len()), rot.names.len())
        }
        None => {
            let offs: Vec<usize> = (0..n1 - n0).map(|t| io.src_map.off(p0 + t)).collect();
            (RowAddr::Table(offs), 0)
        }
    };
    let (_, tile) = schedule::tile_shape(ctx.opts, &sched, 1, cols.interior());
    let walk = SpatialWalk {
        rows,
        cols,
        tile,
        tile_rows: 1,
        unroll: ctx.opts.unroll,
        src: ctx.src.to_string(),
        dst: ctx.dst.to_string(),
        row_elems: 0, // rows are addressed through the offset table
        cmin: c,
        out_minor: c,
        src_rows,
    };
    let cells = DwCells {
        ctx,
        weights,
        bias,
        activation,
        sched: &sched,
        row_addr,
        w_k,
        c,
        // Rolled loop terms / rotating pointers keep the alignment proofs
        // only under the shared claim rule.
        src_static: io.src_claims_aligned(ctx.src),
        dst_static: io.dst_claims_aligned(ctx.dst),
    };
    w.open("");
    match &io.src_rot {
        Some(rot) => {
            for (t, name) in rot.names.iter().enumerate() {
                w.line(&format!("const float *s{t} = {name};"));
            }
        }
        None => w.line(&format!(
            "const float *s = {};",
            schedule::fused_base(ctx.src, 0, io.src_iter_elems)
        )),
    }
    match &io.dst_rot {
        Some(rot) => w.line(&format!("float *d = {};", rot.names[0])),
        None => w.line(&format!(
            "float *d = {};",
            schedule::fused_base(ctx.dst, io.dst_row_off, io.dst_iter_elems)
        )),
    }
    walk.emit_cols(w, n0, n1, 1, &mut |w, win, s, so, d, dofs| {
        cells.emit_block(w, win, s, so, d, dofs)
    });
    w.close();
    Ok(())
}

/// Cell-block emitter for depthwise convolution.
struct DwCells<'a> {
    ctx: &'a LayerCtx<'a>,
    weights: &'a Tensor,
    bias: &'a Tensor,
    activation: Activation,
    sched: &'a ChannelSchedule,
    /// How the valid kernel rows of a cell map to source offsets.
    row_addr: RowAddr,
    w_k: usize,
    c: usize,
    /// Whether src/dst are generator-owned (alignable) buffers.
    src_static: bool,
    dst_static: bool,
}

impl DwCells<'_> {
    fn inline(&self) -> bool {
        self.ctx.opts.effective_const_mode() == ConstMode::Inline
    }

    /// `(base, element offset)` of the source vector/scalar at kernel tap
    /// `(n, m)` for the cell at column offset `s_off` from walker base
    /// `s_name`. Rotating row addressing swaps the base per window row.
    fn src_base_off(&self, s_name: &str, s_off: usize, win: &TapWindow, n: usize, m: usize) -> (String, usize) {
        let (base, row_off) = self.row_addr.base_off(s_name, n - win.n0);
        (base, s_off + row_off + (m - win.m0) * self.c)
    }

    /// Every spatial offset into src/dst is a multiple of the channel
    /// count `c` (channel-minor layout), so alignment of a channel-group
    /// access reduces to: static base, `c` divisible by the width, and a
    /// width-multiple group start.
    fn src_aligned(&self, v: &VecSpec, k0: usize) -> bool {
        self.ctx.opts.use_aligned()
            && self.src_static
            && self.c % v.width == 0
            && k0 % v.width == 0
    }

    fn dst_aligned(&self, v: &VecSpec, k0: usize) -> bool {
        self.ctx.opts.use_aligned()
            && self.dst_static
            && self.c % v.width == 0
            && k0 % v.width == 0
    }

    /// Weight/bias arrays are generator-owned; tap stride is `c`.
    fn warr_aligned(&self, v: &VecSpec, idx: usize) -> bool {
        self.ctx.opts.use_aligned() && idx % v.width == 0 && self.c % v.width == 0
    }

    fn bias_aligned(&self, v: &VecSpec, k0: usize) -> bool {
        self.ctx.opts.use_aligned() && k0 % v.width == 0
    }

    fn emit_block(
        &self,
        w: &mut CWriter,
        win: TapWindow,
        s_name: &str,
        s_offs: &[usize],
        d_name: &str,
        d_offs: &[usize],
    ) {
        for seg in &self.sched.segments {
            match seg.vec {
                Some(v) => {
                    let mut k0 = seg.start;
                    while k0 < seg.end() {
                        self.emit_vec_group(w, v, k0, &win, s_name, s_offs, d_name, d_offs);
                        k0 += v.width;
                    }
                }
                None => {
                    for k in seg.start..seg.end() {
                        for (&so, &dof) in s_offs.iter().zip(d_offs) {
                            self.emit_scalar_cell(w, k, &win, s_name, so, d_name, dof);
                        }
                    }
                }
            }
        }
    }

    /// One vector channel group over every cell of the block. Multi-cell
    /// blocks load each tap's weight vector once (weight-stationary).
    #[allow(clippy::too_many_arguments)]
    fn emit_vec_group(
        &self,
        w: &mut CWriter,
        v: VecSpec,
        k0: usize,
        win: &TapWindow,
        s_name: &str,
        s_offs: &[usize],
        d_name: &str,
        d_offs: &[usize],
    ) {
        let b = s_offs.len();
        let inline = self.inline();
        w.open("");
        for t in 0..b {
            let init = if inline {
                let bv: Vec<f32> = (0..v.width).map(|l| self.bias.data()[k0 + l]).collect();
                v.setr(&bv)
            } else {
                v.load(&format!("b{} + {k0}", self.ctx.idx), self.bias_aligned(&v, k0))
            };
            w.line(&format!("{} a{t} = {};", v.ty, init));
        }
        if b > 1 {
            w.line(&format!("{} wv;", v.ty));
        }
        for n in win.n0..win.n1 {
            for m in win.m0..win.m1 {
                let widx = (n * self.w_k + m) * self.c + k0;
                let ws: Vec<f32> = (0..v.width).map(|l| self.weights.data()[widx + l]).collect();
                if inline && self.ctx.opts.skip_zero_weights && ws.iter().all(|&x| x == 0.0) {
                    continue;
                }
                let wexpr = if inline {
                    v.setr(&ws)
                } else {
                    v.load(&format!("w{} + {widx}", self.ctx.idx), self.warr_aligned(&v, widx))
                };
                let s_al = self.src_aligned(&v, k0);
                if b == 1 {
                    let (base, off) = self.src_base_off(s_name, s_offs[0], win, n, m);
                    w.line(&v.mul_add("a0", &v.load(&format!("{base} + {}", off + k0), s_al), &wexpr));
                } else {
                    w.line(&format!("wv = {wexpr};"));
                    for (t, &so) in s_offs.iter().enumerate() {
                        let (base, off) = self.src_base_off(s_name, so, win, n, m);
                        w.line(&v.mul_add(&format!("a{t}"), &v.load(&format!("{base} + {}", off + k0), s_al), "wv"));
                    }
                }
            }
        }
        for t in 0..b {
            let reg = format!("a{t}");
            emit_vec_activation(w, v, self.activation, &reg);
            w.line(&v.store(&format!("{d_name} + {}", d_offs[t] + k0), &reg, self.dst_aligned(&v, k0)));
        }
        w.close();
    }

    #[allow(clippy::too_many_arguments)]
    fn emit_scalar_cell(
        &self,
        w: &mut CWriter,
        k: usize,
        win: &TapWindow,
        s_name: &str,
        s_off: usize,
        d_name: &str,
        d_off: usize,
    ) {
        let inline = self.inline();
        w.open("");
        if inline {
            w.line(&format!("float a = {};", fmt_f32(self.bias.data()[k])));
        } else {
            w.line(&format!("float a = b{}[{k}];", self.ctx.idx));
        }
        for n in win.n0..win.n1 {
            for m in win.m0..win.m1 {
                let widx = (n * self.w_k + m) * self.c + k;
                let (base, off) = self.src_base_off(s_name, s_off, win, n, m);
                let off = off + k;
                if inline {
                    let wv = self.weights.data()[widx];
                    if self.ctx.opts.skip_zero_weights && wv == 0.0 {
                        continue;
                    }
                    w.line(&format!("a += {base}[{off}] * {};", fmt_f32(wv)));
                } else {
                    w.line(&format!("a += {base}[{off}] * w{}[{widx}];", self.ctx.idx));
                }
            }
        }
        w.line(&format!("{d_name}[{}] = {};", d_off + k, scalar_act("a", self.activation)));
        w.close();
    }
}

/// Average pooling: like max-pool but accumulate + scale by 1/window.
/// Channels follow the lane schedule (vector groups + scalar tail).
pub(crate) fn emit_avgpool(w: &mut CWriter, ctx: &LayerCtx<'_>, pool: (usize, usize), stride: (usize, usize)) -> Result<()> {
    let (h_out, w_out, c) = (ctx.out_shape.h(), ctx.out_shape.w(), ctx.out_shape.c());
    let w_in = ctx.in_shape.w();
    let sched = ChannelSchedule::for_channels(ctx.opts.isa, c);
    let inv = fmt_f32(1.0 / (pool.0 * pool.1) as f32);
    // Pool offsets are all multiples of `c`; same alignment rule as the
    // depthwise input loads.
    let s_static_al = ctx.opts.use_aligned() && schedule::static_buf(ctx.src);
    let d_static_al = ctx.opts.use_aligned() && schedule::static_buf(ctx.dst);

    // Whole-plane walk: window rows sit at the linear row stride behind
    // one shared base (built once per base, not per emitted cell).
    let plane_rows = |base: &str| -> Vec<(String, usize)> {
        (0..pool.0).map(|n| (base.to_string(), n * w_in * c)).collect()
    };
    let window = |w: &mut CWriter, rows: &[(String, usize)], s_off: usize, d_name: &str, d_off: usize| {
        emit_avg_window(w, &sched, pool, c, &inv, s_static_al, d_static_al, rows, s_off, d_name, d_off);
    };

    match ctx.opts.unroll {
        Unroll::None | Unroll::KeepOuter2 => {
            let rows = plane_rows("s");
            w.open(&format!("for (i = 0; i < {h_out}; i++)"));
            w.open(&format!("for (j = 0; j < {w_out}; j++)"));
            w.line(&format!("const float *s = {} + i*{} + j*{};", ctx.src, stride.0 * w_in * c, stride.1 * c));
            w.line(&format!("float *d = {} + i*{} + j*{};", ctx.dst, w_out * c, c));
            window(w, &rows, 0, "d", 0);
            w.close();
            w.close();
        }
        Unroll::KeepOuter1 => {
            let rows = plane_rows("s");
            w.open(&format!("for (i = 0; i < {h_out}; i++)"));
            w.line(&format!("const float *s = {} + i*{};", ctx.src, stride.0 * w_in * c));
            w.line(&format!("float *d = {} + i*{};", ctx.dst, w_out * c));
            for j in 0..w_out {
                window(w, &rows, j * stride.1 * c, "d", j * c);
            }
            w.close();
        }
        Unroll::Full => {
            let rows = plane_rows(ctx.src);
            for i in 0..h_out {
                for j in 0..w_out {
                    window(
                        w,
                        &rows,
                        (i * stride.0 * w_in + j * stride.1) * c,
                        ctx.dst,
                        (i * w_out + j) * c,
                    );
                }
            }
        }
    }
    Ok(())
}

/// One fully-unrolled average-pool window per lane segment. `rows[n]` is
/// the `(base, element offset)` of window row `n` — a single base with
/// linear offsets for plane walks, resolved ring-slot offsets for fused
/// rows, or one rotating pointer per row in rotate-mode loop bodies.
#[allow(clippy::too_many_arguments)]
fn emit_avg_window(
    w: &mut CWriter,
    sched: &ChannelSchedule,
    pool: (usize, usize),
    c: usize,
    inv: &str,
    s_static_al: bool,
    d_static_al: bool,
    rows: &[(String, usize)],
    s_off: usize,
    d_name: &str,
    d_off: usize,
) {
    for seg in &sched.segments {
        if let Some(v) = seg.vec {
            let s_al = s_static_al && c % v.width == 0;
            let d_al = d_static_al && c % v.width == 0;
            for k0 in (seg.start..seg.end()).step_by(v.width) {
                w.open("");
                let off0 = s_off + rows[0].1 + k0;
                w.line(&format!(
                    "{} a = {};",
                    v.ty,
                    v.load(&format!("{} + {off0}", rows[0].0), s_al && off0 % v.width == 0)
                ));
                for n in 0..pool.0 {
                    for m in 0..pool.1 {
                        if n == 0 && m == 0 {
                            continue;
                        }
                        let off = s_off + rows[n].1 + m * c + k0;
                        w.line(&format!(
                            "a = {};",
                            v.add_expr("a", &v.load(&format!("{} + {off}", rows[n].0), s_al && off % v.width == 0))
                        ));
                    }
                }
                w.line(&format!("a = {};", v.mul_expr("a", &v.set1(inv))));
                w.line(&v.store(
                    &format!("{d_name} + {}", d_off + k0),
                    "a",
                    d_al && (d_off + k0) % v.width == 0,
                ));
                w.close();
            }
        } else {
            for k in seg.start..seg.end() {
                w.open("");
                w.line(&format!("float a = {}[{}];", rows[0].0, s_off + rows[0].1 + k));
                for n in 0..pool.0 {
                    for m in 0..pool.1 {
                        if n == 0 && m == 0 {
                            continue;
                        }
                        w.line(&format!("a += {}[{}];", rows[n].0, s_off + rows[n].1 + m * c + k));
                    }
                }
                w.line(&format!("{d_name}[{}] = a * {inv};", d_off + k));
                w.close();
            }
        }
    }
}

/// One constant-coordinate output row of an average pool inside a fusion
/// group; window rows are fetched through `io.src_map` (ring or plane) or
/// the rotating pointer set, and plane bases advance `io.*_iter_elems` per
/// steady-state loop iteration.
pub(crate) fn emit_avgpool_row_fused(
    w: &mut CWriter,
    ctx: &LayerCtx<'_>,
    pool: (usize, usize),
    stride: (usize, usize),
    io: &schedule::FusedRowIo,
) -> Result<()> {
    let (w_out, c) = (ctx.out_shape.w(), ctx.out_shape.c());
    let sched = ChannelSchedule::for_channels(ctx.opts.isa, c);
    let inv = fmt_f32(1.0 / (pool.0 * pool.1) as f32);
    let s_static_al = ctx.opts.use_aligned() && io.src_claims_aligned(ctx.src);
    let d_static_al = ctx.opts.use_aligned() && io.dst_claims_aligned(ctx.dst);
    // Row bases at a zero column offset: rotating pointers, or the fused
    // plane/ring base plus resolved row offsets.
    let base_rows: Vec<(String, usize)> = match &io.src_rot {
        Some(rot) => rot.names.iter().map(|n| (n.clone(), 0)).collect(),
        None => {
            let src_base = schedule::fused_base(ctx.src, 0, io.src_iter_elems);
            (0..pool.0)
                .map(|n| (src_base.clone(), io.src_map.off(io.out_row * stride.0 + n)))
                .collect()
        }
    };
    let dst_base = match &io.dst_rot {
        Some(rot) => rot.names[0].clone(),
        None => schedule::fused_base(ctx.dst, 0, io.dst_iter_elems),
    };
    if ctx.opts.unroll.keeps_cols() {
        w.open(&format!("for (j = 0; j < {w_out}; j++)"));
        let src_base = schedule::fused_base(ctx.src, 0, io.src_iter_elems);
        let rows = super::pool::fused_col_row_bases(w, io, &src_base, stride.1 * c, &base_rows);
        w.line(&format!("float *d = {} + {} + j*{};", dst_base, io.dst_row_off, c));
        emit_avg_window(w, &sched, pool, c, &inv, s_static_al, d_static_al, &rows, 0, "d", 0);
        w.close();
    } else {
        for j in 0..w_out {
            emit_avg_window(
                w,
                &sched,
                pool,
                c,
                &inv,
                s_static_al,
                d_static_al,
                &base_rows,
                j * stride.1 * c,
                &dst_base,
                io.dst_row_off + j * c,
            );
        }
    }
    Ok(())
}
