//! Depthwise convolution and average-pooling emitters (paper future work:
//! "more layer types to support modern widely known CNN structures" —
//! together with 1×1 convs these are the MobileNet building blocks the
//! paper's size anecdote refers to).
//!
//! Depthwise conv is the best case for the paper's channel-minor SIMD
//! scheme (P4): each tap is a pure elementwise `y[k] += w[n,m,k] * x[k]`
//! across channels — a vector multiply with **no broadcast at all**.

use super::conv::{padded_extent, scalar_act};
use super::cwriter::{fmt_f32, CWriter};
use super::simd::{emit_vec_activation, VecSpec};
use super::{ConstMode, LayerCtx, Unroll};
use crate::graph::{Activation, Padding};
use crate::tensor::Tensor;
use anyhow::{bail, Result};

pub(crate) fn emit_depthwise(
    w: &mut CWriter,
    ctx: &LayerCtx<'_>,
    weights: &Tensor,
    bias: &Tensor,
    stride: (usize, usize),
    padding: Padding,
    activation: Activation,
) -> Result<()> {
    let wd = weights.dims();
    let (h_k, w_k, c) = (wd[0], wd[1], wd[2]);
    let (h_in, w_in) = (ctx.in_shape.h(), ctx.in_shape.w());
    let (h_out, w_out) = (ctx.out_shape.h(), ctx.out_shape.w());
    // Reuse the conv padding machinery via a pseudo-HWIO dims slice.
    let pseudo = [h_k, w_k, c, c];
    let (ph, pw) = padded_extent(ctx.in_shape, &pseudo, stride, padding)?;
    let pads = (ph, pw) != (h_in, w_in);
    let (pad_top, pad_left) = match padding {
        Padding::Same => {
            let (_, pt) = padding.resolve(h_in, h_k, stride.0)?;
            let (_, pl) = padding.resolve(w_in, w_k, stride.1)?;
            (pt, pl)
        }
        Padding::Valid => (0, 0),
    };
    let src = if pads {
        super::conv::emit_pad_fill_public(w, ctx, h_in, w_in, c, ph, pw, pad_top, pad_left)?;
        ctx.padbuf.to_string()
    } else {
        ctx.src.to_string()
    };

    let vec = VecSpec::for_channels(ctx.opts.isa, c);
    let inline = ctx.opts.effective_const_mode() == ConstMode::Inline;
    let pw_elems = pw * c;

    // Array-mode weights are emitted by mod.rs as w{idx}/b{idx} with layout
    // [(n*w_k + m)*c + k].
    let cell = |w: &mut CWriter, s_name: &str, s_off: usize, d_name: &str, d_off: usize| {
        if let Some(v) = vec {
            for k0 in (0..c).step_by(v.width) {
                w.open("");
                if inline {
                    let b: Vec<f32> = (0..v.width).map(|l| bias.data()[k0 + l]).collect();
                    w.line(&format!("{} a = {};", v.ty, v.setr(&b)));
                } else {
                    w.line(&format!("{} a = {};", v.ty, v.loadu(&format!("b{} + {k0}", ctx.idx))));
                }
                for n in 0..h_k {
                    for m in 0..w_k {
                        let off = s_off + n * pw_elems + m * c + k0;
                        if inline {
                            let ws: Vec<f32> =
                                (0..v.width).map(|l| weights.data()[(n * w_k + m) * c + k0 + l]).collect();
                            if ctx.opts.skip_zero_weights && ws.iter().all(|&x| x == 0.0) {
                                continue;
                            }
                            w.line(&v.mul_add("a", &v.loadu(&format!("{s_name} + {off}")), &v.setr(&ws)));
                        } else {
                            let widx = (n * w_k + m) * c + k0;
                            w.line(&v.mul_add(
                                "a",
                                &v.loadu(&format!("{s_name} + {off}")),
                                &v.loadu(&format!("w{} + {widx}", ctx.idx)),
                            ));
                        }
                    }
                }
                emit_vec_activation(w, v, activation, "a");
                w.line(&v.storeu(&format!("{d_name} + {}", d_off + k0), "a"));
                w.close();
            }
        } else {
            for k in 0..c {
                w.open("");
                if inline {
                    w.line(&format!("float a = {};", fmt_f32(bias.data()[k])));
                } else {
                    w.line(&format!("float a = b{}[{k}];", ctx.idx));
                }
                for n in 0..h_k {
                    for m in 0..w_k {
                        let off = s_off + n * pw_elems + m * c + k;
                        if inline {
                            let wv = weights.data()[(n * w_k + m) * c + k];
                            if ctx.opts.skip_zero_weights && wv == 0.0 {
                                continue;
                            }
                            w.line(&format!("a += {s_name}[{off}] * {};", fmt_f32(wv)));
                        } else {
                            w.line(&format!("a += {s_name}[{off}] * w{}[{}];", ctx.idx, (n * w_k + m) * c + k));
                        }
                    }
                }
                w.line(&format!("{d_name}[{}] = {};", d_off + k, scalar_act("a", activation)));
                w.close();
            }
        }
    };

    match ctx.opts.unroll {
        Unroll::None | Unroll::KeepOuter2 => {
            if ctx.opts.unroll == Unroll::None && inline {
                bail!("Unroll::None requires ConstMode::Array");
            }
            w.open(&format!("for (i = 0; i < {h_out}; i++)"));
            w.open(&format!("for (j = 0; j < {w_out}; j++)"));
            w.line(&format!("const float *s = {src} + i*{} + j*{};", stride.0 * pw_elems, stride.1 * c));
            w.line(&format!("float *d = {} + i*{} + j*{};", ctx.dst, w_out * c, c));
            cell(w, "s", 0, "d", 0);
            w.close();
            w.close();
        }
        Unroll::KeepOuter1 => {
            w.open(&format!("for (i = 0; i < {h_out}; i++)"));
            w.line(&format!("const float *s = {src} + i*{};", stride.0 * pw_elems));
            w.line(&format!("float *d = {} + i*{};", ctx.dst, w_out * c));
            for j in 0..w_out {
                cell(w, "s", j * stride.1 * c, "d", j * c);
            }
            w.close();
        }
        Unroll::Full => {
            for i in 0..h_out {
                for j in 0..w_out {
                    cell(
                        w,
                        &src,
                        i * stride.0 * pw_elems + j * stride.1 * c,
                        ctx.dst,
                        (i * w_out + j) * c,
                    );
                }
            }
        }
    }

    if activation == Activation::Softmax {
        super::activation::emit_softmax_over(w, ctx, ctx.dst, ctx.out_shape.numel());
    }
    Ok(())
}

/// Average pooling: like max-pool but accumulate + scale by 1/window.
pub(crate) fn emit_avgpool(w: &mut CWriter, ctx: &LayerCtx<'_>, pool: (usize, usize), stride: (usize, usize)) -> Result<()> {
    let (h_out, w_out, c) = (ctx.out_shape.h(), ctx.out_shape.w(), ctx.out_shape.c());
    let w_in = ctx.in_shape.w();
    let vec = VecSpec::for_channels(ctx.opts.isa, c);
    let inv = fmt_f32(1.0 / (pool.0 * pool.1) as f32);

    let window = |w: &mut CWriter, s_name: &str, s_off: usize, d_name: &str, d_off: usize| {
        if let Some(v) = vec {
            for k0 in (0..c).step_by(v.width) {
                w.open("");
                w.line(&format!("{} a = {};", v.ty, v.loadu(&format!("{s_name} + {}", s_off + k0))));
                for n in 0..pool.0 {
                    for m in 0..pool.1 {
                        if n == 0 && m == 0 {
                            continue;
                        }
                        let off = s_off + (n * w_in + m) * c + k0;
                        w.line(&format!(
                            "a = {}_add_ps(a, {});",
                            v.pfx,
                            v.loadu(&format!("{s_name} + {off}"))
                        ));
                    }
                }
                w.line(&format!("a = {}_mul_ps(a, {});", v.pfx, v.set1(&inv)));
                w.line(&v.storeu(&format!("{d_name} + {}", d_off + k0), "a"));
                w.close();
            }
        } else {
            for k in 0..c {
                w.open("");
                w.line(&format!("float a = {s_name}[{}];", s_off + k));
                for n in 0..pool.0 {
                    for m in 0..pool.1 {
                        if n == 0 && m == 0 {
                            continue;
                        }
                        w.line(&format!("a += {s_name}[{}];", s_off + (n * w_in + m) * c + k));
                    }
                }
                w.line(&format!("{d_name}[{}] = a * {inv};", d_off + k));
                w.close();
            }
        }
    };

    match ctx.opts.unroll {
        Unroll::None | Unroll::KeepOuter2 => {
            w.open(&format!("for (i = 0; i < {h_out}; i++)"));
            w.open(&format!("for (j = 0; j < {w_out}; j++)"));
            w.line(&format!("const float *s = {} + i*{} + j*{};", ctx.src, stride.0 * w_in * c, stride.1 * c));
            w.line(&format!("float *d = {} + i*{} + j*{};", ctx.dst, w_out * c, c));
            window(w, "s", 0, "d", 0);
            w.close();
            w.close();
        }
        Unroll::KeepOuter1 => {
            w.open(&format!("for (i = 0; i < {h_out}; i++)"));
            w.line(&format!("const float *s = {} + i*{};", ctx.src, stride.0 * w_in * c));
            w.line(&format!("float *d = {} + i*{};", ctx.dst, w_out * c));
            for j in 0..w_out {
                window(w, "s", j * stride.1 * c, "d", j * c);
            }
            w.close();
        }
        Unroll::Full => {
            for i in 0..h_out {
                for j in 0..w_out {
                    window(
                        w,
                        ctx.src,
                        (i * stride.0 * w_in + j * stride.1) * c,
                        ctx.dst,
                        (i * w_out + j) * c,
                    );
                }
            }
        }
    }
    Ok(())
}
