//! Optional self-contained `main()` appended to the generated C file.
//!
//! Turns the generated file into a standalone benchmark/verification
//! executable — the form used for cross-compile deployment checks: it needs
//! nothing but a C compiler on the target (paper §III-B).
//!
//! ```text
//! ./ball 100000            # bench: 100000 inferences on a seeded input
//! ./ball 1 input.raw       # classify raw f32 HWC input from a file
//! ```

use super::cwriter::CWriter;

pub(crate) fn emit_test_harness(w: &mut CWriter, ident: &str, input_size: usize, output_size: usize) {
    w.blank();
    w.line("/* ---- standalone test & benchmark harness (not part of the library) ---- */");
    w.line("#include <stdio.h>");
    w.line("#include <stdlib.h>");
    w.line("#include <time.h>");
    w.blank();
    w.open("int main(int argc, char **argv)");
    w.line(&format!("static float in[{input_size}];"));
    w.line(&format!("static float out[{output_size}];"));
    w.line("int iters = argc > 1 ? atoi(argv[1]) : 1000;");
    w.line("int i;");
    w.line("unsigned long s = 88172645463325252UL;");
    w.line("/* deterministic pseudo-random input (same on every platform) */");
    w.open(&format!("for (i = 0; i < {input_size}; i++)"));
    w.line("s ^= s << 13; s ^= s >> 7; s ^= s << 17;");
    w.line("in[i] = (float)((s >> 24) & 1023u) / 1023.0f;");
    w.close();
    w.open("if (argc > 2)");
    w.line("FILE *f = fopen(argv[2], \"rb\");");
    w.line(&format!(
        "if (!f || fread(in, sizeof(float), {input_size}, f) != {input_size}) {{ fprintf(stderr, \"bad input file\\n\"); return 2; }}"
    ));
    w.line("fclose(f);");
    w.close();
    w.open("");
    w.line("struct timespec t0, t1;");
    w.line("double el;");
    w.line(&format!("{ident}_inference(in, out); /* warmup */"));
    w.line("clock_gettime(CLOCK_MONOTONIC, &t0);");
    w.line(&format!("for (i = 0; i < iters; i++) {ident}_inference(in, out);"));
    w.line("clock_gettime(CLOCK_MONOTONIC, &t1);");
    w.line("el = (double)(t1.tv_sec - t0.tv_sec) * 1e6 + (double)(t1.tv_nsec - t0.tv_nsec) / 1e3;");
    w.line("printf(\"iters=%d total_us=%.1f per_inference_us=%.4f\\n\", iters, el, el / iters);");
    w.close();
    w.open(&format!("for (i = 0; i < {output_size}; i++)"));
    w.line("printf(\"out[%d]=%.9g\\n\", i, (double)out[i]);");
    w.close();
    w.line("return 0;");
    w.close();
}
