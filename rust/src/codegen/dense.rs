//! Dense (fully connected) layer emitter.
//!
//! Weights are `[in, out]` with `out` minor, so the vector path runs over
//! output neurons in lane groups — the same channel-minor scheme as the
//! convolution (P4). Output counts that do not divide the lane width keep
//! a vectorized main body plus a scalar tail ([`ChannelSchedule`]).

use super::conv::scalar_act;
use super::cwriter::{fmt_f32, CWriter};
use super::schedule;
use super::simd::{emit_vec_activation, ChannelSchedule};
use super::{ConstMode, LayerCtx};
use crate::graph::Activation;
use crate::tensor::Tensor;
use anyhow::Result;

pub(crate) fn emit_dense(
    w: &mut CWriter,
    ctx: &LayerCtx<'_>,
    weights: &Tensor,
    bias: &Tensor,
    activation: Activation,
) -> Result<()> {
    let n_in = weights.dims()[0];
    let n_out = weights.dims()[1];
    let sched = ChannelSchedule::for_channels(ctx.opts.isa, n_out);
    let inline = ctx.opts.effective_const_mode() == ConstMode::Inline;
    let align_on = ctx.opts.use_aligned();
    let dst_static = schedule::static_buf(ctx.dst);

    if ctx.opts.unroll.keeps_inner() {
        // Loop form with weight arrays: one neuron loop per lane segment.
        for seg in &sched.segments {
            if seg.len == 0 {
                continue;
            }
            if let Some(v) = seg.vec {
                // Neuron-row stride is n_out, so symbolic weight loads are
                // aligned only when n_out divides the width.
                let b_al = align_on && seg.start % v.width == 0;
                let w_al = b_al && n_out % v.width == 0;
                let d_al = b_al && dst_static;
                w.open(&format!("for (k = {}; k < {}; k += {})", seg.start, seg.end(), v.width));
                w.line(&format!("{} a = {};", v.ty, v.load(&format!("b{} + k", ctx.idx), b_al)));
                w.open(&format!("for (i = 0; i < {n_in}; i++)"));
                w.line(&v.mul_add(
                    "a",
                    &v.set1(&format!("{}[i]", ctx.src)),
                    &v.load(&format!("w{} + i*{n_out} + k", ctx.idx), w_al),
                ));
                w.close();
                emit_vec_activation(w, v, activation, "a");
                w.line(&v.store(&format!("{} + k", ctx.dst), "a", d_al));
                w.close();
            } else {
                w.open(&format!("for (k = {}; k < {}; k++)", seg.start, seg.end()));
                w.line(&format!("float a = b{}[k];", ctx.idx));
                w.open(&format!("for (i = 0; i < {n_in}; i++)"));
                w.line(&format!("a += {}[i] * w{}[i*{n_out} + k];", ctx.src, ctx.idx));
                w.close();
                w.line(&format!("{}[k] = {};", ctx.dst, scalar_act("a", activation)));
                w.close();
            }
        }
    } else {
        for seg in &sched.segments {
            if let Some(v) = seg.vec {
                for k0 in (seg.start..seg.end()).step_by(v.width) {
                    let al = align_on && k0 % v.width == 0;
                    w.open("");
                    if inline {
                        let b = bias.data();
                        w.line(&format!("{} a = {};", v.ty, v.setr(&b[k0..k0 + v.width])));
                    } else {
                        w.line(&format!("{} a = {};", v.ty, v.load(&format!("b{} + {k0}", ctx.idx), al)));
                    }
                    for i in 0..n_in {
                        if inline {
                            let ws: Vec<f32> = (0..v.width).map(|l| weights.data()[i * n_out + k0 + l]).collect();
                            if ctx.opts.skip_zero_weights && ws.iter().all(|&x| x == 0.0) {
                                continue;
                            }
                            w.line(&v.mul_add("a", &v.set1(&format!("{}[{i}]", ctx.src)), &v.setr(&ws)));
                        } else {
                            let idx = i * n_out + k0;
                            w.line(&v.mul_add(
                                "a",
                                &v.set1(&format!("{}[{i}]", ctx.src)),
                                &v.load(&format!("w{} + {idx}", ctx.idx), align_on && idx % v.width == 0),
                            ));
                        }
                    }
                    emit_vec_activation(w, v, activation, "a");
                    w.line(&v.store(&format!("{} + {k0}", ctx.dst), "a", al && dst_static));
                    w.close();
                }
            } else {
                for k in seg.start..seg.end() {
                    w.open("");
                    if inline {
                        w.line(&format!("float a = {};", fmt_f32(bias.data()[k])));
                        for i in 0..n_in {
                            let wv = weights.data()[i * n_out + k];
                            if ctx.opts.skip_zero_weights && wv == 0.0 {
                                continue;
                            }
                            w.line(&format!("a += {}[{i}] * {};", ctx.src, fmt_f32(wv)));
                        }
                    } else {
                        w.line(&format!("float a = b{}[{k}];", ctx.idx));
                        for i in 0..n_in {
                            w.line(&format!("a += {}[{i}] * w{}[{}];", ctx.src, ctx.idx, i * n_out + k));
                        }
                    }
                    w.line(&format!("{}[{k}] = {};", ctx.dst, scalar_act("a", activation)));
                    w.close();
                }
            }
        }
    }

    if activation == Activation::Softmax {
        super::activation::emit_softmax_over(w, ctx, ctx.dst, n_out);
    }
    Ok(())
}
