//! Convolution emitter — the heart of NNCG (paper §II-B.1).
//!
//! Strategy per the paper, adapted as described in `codegen`:
//!
//! 1. If the layer pads, materialize x̂ (Eq. 1) into the shared scratch
//!    buffer `nncg_pad` so the compute loops are branch-free (P3: the pad
//!    geometry is constant-folded at *generation* time).
//! 2. Emit the 6-deep loop nest of Eq. 2 at the configured unroll level:
//!    spatial loops (`i`, `j`) optionally kept, kernel/channel loops
//!    (`n`, `m`, `o`, `k`) unrolled with inline weight constants, or kept
//!    with `static const` weight arrays.
//! 3. SSE mode vectorizes over `k` (output channels) in groups of 4 — the
//!    paper's P4 choice, possible because C is the minor-most axis.

use super::cwriter::{fmt_f32, CWriter};
use super::simd::{emit_vec_activation, VecSpec};
use super::{ConstMode, LayerCtx, Unroll};
use crate::graph::{Activation, Padding};
use crate::tensor::{Shape, Tensor};
use anyhow::{bail, Result};

/// Padded input extent `(h, w)` for a conv layer (equals the input extent
/// when the layer does not pad).
pub(crate) fn padded_extent(input: &Shape, wdims: &[usize], stride: (usize, usize), padding: Padding) -> Result<(usize, usize)> {
    let (oh, _) = padding.resolve(input.h(), wdims[0], stride.0)?;
    let (ow, _) = padding.resolve(input.w(), wdims[1], stride.1)?;
    let th = match padding {
        Padding::Same => ((oh - 1) * stride.0 + wdims[0]).saturating_sub(input.h()),
        Padding::Valid => 0,
    };
    let tw = match padding {
        Padding::Same => ((ow - 1) * stride.1 + wdims[1]).saturating_sub(input.w()),
        Padding::Valid => 0,
    };
    Ok((input.h() + th, input.w() + tw))
}

pub(crate) fn emit_conv(
    w: &mut CWriter,
    ctx: &LayerCtx<'_>,
    weights: &Tensor,
    bias: &Tensor,
    stride: (usize, usize),
    padding: Padding,
    activation: Activation,
) -> Result<()> {
    let wd = weights.dims();
    let (h_k, w_k, c_in, c_out) = (wd[0], wd[1], wd[2], wd[3]);
    let (h_in, w_in) = (ctx.in_shape.h(), ctx.in_shape.w());
    let (h_out, w_out) = (ctx.out_shape.h(), ctx.out_shape.w());
    let (ph, pw) = padded_extent(ctx.in_shape, wd, stride, padding)?;
    let pads = (ph, pw) != (h_in, w_in);
    let (pad_top, pad_left) = match padding {
        Padding::Same => {
            let (_, pt) = padding.resolve(h_in, h_k, stride.0)?;
            let (_, pl) = padding.resolve(w_in, w_k, stride.1)?;
            (pt, pl)
        }
        Padding::Valid => (0, 0),
    };

    // --- Step 1: padded input (Eq. 1) -------------------------------------
    let src: String = if pads {
        emit_pad_fill_public(w, ctx, h_in, w_in, ctx.in_shape.c(), ph, pw, pad_top, pad_left)?;
        ctx.padbuf.to_string()
    } else {
        ctx.src.to_string()
    };

    // --- Step 2/3: compute loops ------------------------------------------
    let vec = VecSpec::for_channels(ctx.opts.isa, c_out);
    let geom = ConvGeom {
        src,
        dst: ctx.dst.to_string(),
        h_k,
        w_k,
        c_in,
        c_out,
        pw_elems: pw * c_in,
        stride,
        h_out,
        w_out,
        idx: ctx.idx,
    };

    match ctx.opts.unroll {
        Unroll::None => emit_conv_loops(w, ctx, &geom, weights, bias, activation, vec)?,
        Unroll::KeepOuter2 => {
            w.open(&format!("for (i = 0; i < {h_out}; i++)"));
            w.open(&format!("for (j = 0; j < {w_out}; j++)"));
            w.line(&format!(
                "const float *s = {} + i*{} + j*{};",
                geom.src,
                stride.0 * geom.pw_elems,
                stride.1 * c_in
            ));
            w.line(&format!("float *d = {} + i*{} + j*{};", geom.dst, w_out * c_out, c_out));
            emit_cell(w, ctx, &geom, weights, bias, activation, vec, "s", 0, "d", 0);
            w.close();
            w.close();
        }
        Unroll::KeepOuter1 => {
            w.open(&format!("for (i = 0; i < {h_out}; i++)"));
            w.line(&format!("const float *s = {} + i*{};", geom.src, stride.0 * geom.pw_elems));
            w.line(&format!("float *d = {} + i*{};", geom.dst, w_out * c_out));
            for j in 0..w_out {
                emit_cell(w, ctx, &geom, weights, bias, activation, vec, "s", j * stride.1 * c_in, "d", j * c_out);
            }
            w.close();
        }
        Unroll::Full => {
            for i in 0..h_out {
                for j in 0..w_out {
                    emit_cell(
                        w,
                        ctx,
                        &geom,
                        weights,
                        bias,
                        activation,
                        vec,
                        &geom.src.clone(),
                        i * stride.0 * geom.pw_elems + j * stride.1 * c_in,
                        &geom.dst.clone(),
                        (i * w_out + j) * c_out,
                    );
                }
            }
        }
    }

    // Fused softmax runs once over the final map.
    if activation == Activation::Softmax {
        super::activation::emit_softmax_over(w, ctx, &geom.dst, ctx.out_shape.numel());
    }
    Ok(())
}

/// Geometry shared by the cell emitters.
struct ConvGeom {
    src: String,
    dst: String,
    h_k: usize,
    w_k: usize,
    c_in: usize,
    c_out: usize,
    /// Elements per padded input row (`pw * c_in`).
    pw_elems: usize,
    stride: (usize, usize),
    h_out: usize,
    w_out: usize,
    idx: usize,
}

/// Emit the zero-pad + copy of the input into `nncg_pad` (shared with the
/// depthwise emitter).
#[allow(clippy::too_many_arguments)]
pub(crate) fn emit_pad_fill_public(
    w: &mut CWriter,
    ctx: &LayerCtx<'_>,
    h_in: usize,
    w_in: usize,
    c: usize,
    ph: usize,
    pw: usize,
    pad_top: usize,
    pad_left: usize,
) -> Result<()> {
    w.line(&format!("/* zero-pad {}x{}x{c} -> {ph}x{pw}x{c} (Eq. 1) */", h_in, w_in));
    if ctx.opts.unroll == Unroll::Full {
        // Straight-line: one store per padded cell.
        for r in 0..ph {
            for q in 0..pw {
                let inside = r >= pad_top && r < pad_top + h_in && q >= pad_left && q < pad_left + w_in;
                for o in 0..c {
                    let pidx = (r * pw + q) * c + o;
                    if inside {
                        let sidx = ((r - pad_top) * w_in + (q - pad_left)) * c + o;
                        w.line(&format!("{}[{}] = {}[{}];", ctx.padbuf, pidx, ctx.src, sidx));
                    } else {
                        w.line(&format!("{}[{}] = 0.0f;", ctx.padbuf, pidx));
                    }
                }
            }
        }
    } else {
        w.open(&format!("for (i = 0; i < {}; i++)", ph * pw * c));
        w.line(&format!("{}[i] = 0.0f;", ctx.padbuf));
        w.close();
        w.open(&format!("for (i = 0; i < {h_in}; i++)"));
        w.open(&format!("for (j = 0; j < {}; j++)", w_in * c));
        w.line(&format!(
            "{}[(i + {pad_top})*{} + {} + j] = {}[i*{} + j];",
            ctx.padbuf,
            pw * c,
            pad_left * c,
            ctx.src,
            w_in * c
        ));
        w.close();
        w.close();
    }
    Ok(())
}

/// Emit one output cell (all `c_out` channels at `(i, j)`), with the source
/// base expressed as `s_name[s_off + tap]` and dest as `d_name[d_off + k]`.
#[allow(clippy::too_many_arguments)]
fn emit_cell(
    w: &mut CWriter,
    ctx: &LayerCtx<'_>,
    geom: &ConvGeom,
    weights: &Tensor,
    bias: &Tensor,
    activation: Activation,
    vec: Option<VecSpec>,
    s_name: &str,
    s_off: usize,
    d_name: &str,
    d_off: usize,
) {
    let inline = ctx.opts.effective_const_mode() == ConstMode::Inline;
    if let Some(v) = vec {
        // Multi-accumulator emission (§Perf optimization 1, EXPERIMENTS.md):
        // one broadcast input feeds ALL channel groups of a chunk, instead
        // of reloading the input scalar per group. Chunked to at most 8
        // live accumulators to stay within the register file.
        const CHUNK_GROUPS: usize = 8;
        let mut k0 = 0;
        while k0 < geom.c_out {
            let groups = ((geom.c_out - k0) / v.width).min(CHUNK_GROUPS);
            emit_vec_chunk(w, ctx, geom, weights, bias, activation, v, k0, groups, s_name, s_off, d_name, d_off, inline);
            k0 += groups * v.width;
        }
    } else {
        for k in 0..geom.c_out {
            emit_scalar_block(w, ctx, geom, weights, bias, activation, k, s_name, s_off, d_name, d_off, inline);
        }
    }
}

/// Index of tap `(n, m, o)` relative to the cell's source base.
fn tap_off(geom: &ConvGeom, n: usize, m: usize, o: usize) -> usize {
    n * geom.pw_elems + m * geom.c_in + o
}

/// Scalar accumulator block for one output channel `k`.
#[allow(clippy::too_many_arguments)]
fn emit_scalar_block(
    w: &mut CWriter,
    ctx: &LayerCtx<'_>,
    geom: &ConvGeom,
    weights: &Tensor,
    bias: &Tensor,
    activation: Activation,
    k: usize,
    s_name: &str,
    s_off: usize,
    d_name: &str,
    d_off: usize,
    inline: bool,
) {
    w.open("");
    if inline {
        w.line(&format!("float a = {};", fmt_f32(bias.data()[k])));
        for n in 0..geom.h_k {
            for m in 0..geom.w_k {
                for o in 0..geom.c_in {
                    let wv = weights.at4(n, m, o, k);
                    if ctx.opts.skip_zero_weights && wv == 0.0 {
                        continue;
                    }
                    let off = s_off + tap_off(geom, n, m, o);
                    w.line(&format!("a += {s_name}[{off}] * {};", fmt_f32(wv)));
                }
            }
        }
    } else {
        w.line(&format!("float a = b{}[{k}];", geom.idx));
        for n in 0..geom.h_k {
            for m in 0..geom.w_k {
                for o in 0..geom.c_in {
                    let widx = ((n * geom.w_k + m) * geom.c_in + o) * geom.c_out + k;
                    let off = s_off + tap_off(geom, n, m, o);
                    w.line(&format!("a += {s_name}[{off}] * w{}[{widx}];", geom.idx));
                }
            }
        }
    }
    w.line(&format!("{d_name}[{}] = {};", d_off + k, scalar_act("a", activation)));
    w.close();
}

/// Vector chunk covering output channels `k0 .. k0 + groups*width` with
/// one accumulator register per lane group: each input scalar is broadcast
/// once and multiplied into every group, cutting input loads by a factor
/// of `groups` compared with per-group emission.
#[allow(clippy::too_many_arguments)]
fn emit_vec_chunk(
    w: &mut CWriter,
    ctx: &LayerCtx<'_>,
    geom: &ConvGeom,
    weights: &Tensor,
    bias: &Tensor,
    activation: Activation,
    v: VecSpec,
    k0: usize,
    groups: usize,
    s_name: &str,
    s_off: usize,
    d_name: &str,
    d_off: usize,
    inline: bool,
) {
    w.open("");
    let b = bias.data();
    for g in 0..groups {
        let k = k0 + g * v.width;
        if inline {
            w.line(&format!("{} a{g} = {};", v.ty, v.setr(&b[k..k + v.width])));
        } else {
            w.line(&format!("{} a{g} = {};", v.ty, v.loadu(&format!("b{} + {k}", geom.idx))));
        }
    }
    w.line(&format!("{} t;", v.ty));
    for n in 0..geom.h_k {
        for m in 0..geom.w_k {
            for o in 0..geom.c_in {
                // group weights for this tap; skip the whole tap if all zero
                let tap_w: Vec<Vec<f32>> = (0..groups)
                    .map(|g| (0..v.width).map(|l| weights.at4(n, m, o, k0 + g * v.width + l)).collect())
                    .collect();
                let live: Vec<usize> = (0..groups)
                    .filter(|&g| !(ctx.opts.skip_zero_weights && inline && tap_w[g].iter().all(|&x| x == 0.0)))
                    .collect();
                if live.is_empty() {
                    continue;
                }
                let off = s_off + tap_off(geom, n, m, o);
                w.line(&format!("t = {};", v.set1(&format!("{s_name}[{off}]"))));
                for &g in &live {
                    if inline {
                        w.line(&v.mul_add(&format!("a{g}"), "t", &v.setr(&tap_w[g])));
                    } else {
                        let widx = ((n * geom.w_k + m) * geom.c_in + o) * geom.c_out + k0 + g * v.width;
                        w.line(&v.mul_add(&format!("a{g}"), "t", &v.loadu(&format!("w{} + {widx}", geom.idx))));
                    }
                }
            }
        }
    }
    for g in 0..groups {
        emit_vec_activation(w, v, activation, &format!("a{g}"));
        w.line(&v.storeu(&format!("{d_name} + {}", d_off + k0 + g * v.width), &format!("a{g}")));
    }
    w.close();
}

/// The paper's loop-form emission (`Unroll::None`): all six loops kept,
/// weights in `static const` arrays.
fn emit_conv_loops(
    w: &mut CWriter,
    ctx: &LayerCtx<'_>,
    geom: &ConvGeom,
    _weights: &Tensor,
    _bias: &Tensor,
    activation: Activation,
    vec: Option<VecSpec>,
) -> Result<()> {
    if ctx.opts.effective_const_mode() != ConstMode::Array {
        bail!("Unroll::None requires ConstMode::Array (inline constants need unrolled loops)");
    }
    let (sh, sw) = geom.stride;
    w.open(&format!("for (i = 0; i < {}; i++)", geom.h_out));
    w.open(&format!("for (j = 0; j < {}; j++)", geom.w_out));
    w.line(&format!("const float *s = {} + i*{} + j*{};", geom.src, sh * geom.pw_elems, sw * geom.c_in));
    w.line(&format!("float *d = {} + i*{} + j*{};", geom.dst, geom.w_out * geom.c_out, geom.c_out));
    if let Some(v) = vec {
        w.open(&format!("for (k = 0; k < {}; k += {})", geom.c_out, v.width));
        w.line(&format!("{} a = {};", v.ty, v.loadu(&format!("b{} + k", geom.idx))));
        w.open(&format!("for (n = 0; n < {}; n++)", geom.h_k));
        w.open(&format!("for (m = 0; m < {}; m++)", geom.w_k));
        w.open(&format!("for (o = 0; o < {}; o++)", geom.c_in));
        w.line(&v.mul_add(
            "a",
            &v.set1(&format!("s[n*{} + m*{} + o]", geom.pw_elems, geom.c_in)),
            &v.loadu(&format!(
                "w{} + ((n*{} + m)*{} + o)*{} + k",
                geom.idx, geom.w_k, geom.c_in, geom.c_out
            )),
        ));
        w.close();
        w.close();
        w.close();
        emit_vec_activation(w, v, activation, "a");
        w.line(&v.storeu("d + k", "a"));
        w.close();
    } else {
        w.open(&format!("for (k = 0; k < {}; k++)", geom.c_out));
        w.line(&format!("float a = b{}[k];", geom.idx));
        w.open(&format!("for (n = 0; n < {}; n++)", geom.h_k));
        w.open(&format!("for (m = 0; m < {}; m++)", geom.w_k));
        w.open(&format!("for (o = 0; o < {}; o++)", geom.c_in));
        w.line(&format!(
            "a += s[n*{} + m*{} + o] * w{}[((n*{} + m)*{} + o)*{} + k];",
            geom.pw_elems, geom.c_in, geom.idx, geom.w_k, geom.c_in, geom.c_out
        ));
        w.close();
        w.close();
        w.close();
        w.line(&format!("d[k] = {};", scalar_act("a", activation)));
        w.close();
    }
    w.close();
    w.close();
    Ok(())
}

/// Scalar activation expression over accumulator `a` (P2: ternary form).
pub(crate) fn scalar_act(a: &str, activation: Activation) -> String {
    match activation {
        Activation::None | Activation::Softmax => a.to_string(),
        Activation::Relu => format!("{a} > 0.0f ? {a} : 0.0f"),
        Activation::LeakyRelu(alpha) => format!("{a} > 0.0f ? {a} : {} * {a}", fmt_f32(alpha)),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn padded_extent_same() {
        // 16x16, k5, s2: out 8, total pad = 7*2+5-16 = 3 → padded 19
        let s = Shape::new(&[16, 16, 1]);
        let (ph, pw) = padded_extent(&s, &[5, 5, 1, 8], (2, 2), Padding::Same).unwrap();
        assert_eq!((ph, pw), (19, 19));
    }

    #[test]
    fn padded_extent_valid_is_input() {
        let s = Shape::new(&[10, 12, 3]);
        let (ph, pw) = padded_extent(&s, &[3, 3, 3, 4], (1, 1), Padding::Valid).unwrap();
        assert_eq!((ph, pw), (10, 12));
    }

    #[test]
    fn scalar_act_ternaries() {
        assert_eq!(scalar_act("a", Activation::Relu), "a > 0.0f ? a : 0.0f");
        assert!(scalar_act("a", Activation::LeakyRelu(0.1)).contains("0.1f * a"));
        assert_eq!(scalar_act("a", Activation::None), "a");
    }
}
