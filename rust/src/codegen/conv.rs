//! Convolution emitter — the heart of NNCG (paper §II-B.1).
//!
//! Strategy per the paper, extended as described in `codegen`:
//!
//! 1. Padding is resolved at *generation* time (P3). In the default
//!    **padless** mode the generator splits the output plane into an
//!    interior region (full kernel window in bounds — a branch-free loop
//!    that indexes the source directly) plus peeled border rows/columns
//!    whose out-of-bounds taps are simply *dropped* (zero-padding means
//!    those MACs contribute nothing). The legacy **copy** mode
//!    materializes x̂ (Eq. 1) into the shared `nncg_pad` scratch buffer.
//! 2. The channel dimension follows a [`ChannelSchedule`]: full vector
//!    groups, then narrower vectors, then scalar remainder lanes — so
//!    `c_out % width != 0` layers keep a vectorized main body.
//! 3. Interior columns are register-tiled: a block of `tile` output
//!    pixels shares one weight-stationary register per tap (the weight
//!    vector is materialized once and FMA'd into every pixel's
//!    accumulators), cutting weight loads/materializations by the block
//!    width.

use super::cwriter::{fmt_f32, CWriter};
use super::schedule::{self, AxisPlan, PadStrategy};
use super::simd::{emit_vec_activation, ChannelSchedule, VecSpec};
use super::{ConstMode, LayerCtx, Unroll};
use crate::graph::{Activation, Padding};
use crate::tensor::{Shape, Tensor};
use anyhow::{bail, Result};

/// Generation-time source-row addressing for a cell block: whole-plane
/// walks see kernel rows at a fixed linear stride; fused ring-buffer rows
/// wrap around, so each valid kernel row gets an explicit offset resolved
/// while generating; rotate-mode rolled loops address each window row
/// through its own rotating pointer alias (no runtime index arithmetic
/// beyond constant folds either way).
#[derive(Debug, Clone)]
pub(crate) enum RowAddr {
    /// Row `n` of the window lives `n * row_elems` after the base.
    Linear(usize),
    /// Row `n` of the window lives at `offsets[n]` (ring slots).
    Table(Vec<usize>),
    /// Row `n` of the window lives behind the per-row base alias
    /// `{base}{n}` (rotating ring pointers); the payload is the window
    /// height. Cell emitters must resolve rows through [`RowAddr::base_off`].
    Rotating(usize),
}

impl RowAddr {
    /// Resolve relative window row `n_rel` against the walker-provided
    /// base name: the `(base, extra element offset)` the access goes
    /// through. Rotating rows live behind per-row aliases `{base}{n_rel}`
    /// declared by the fused emitters — there is deliberately no
    /// offset-only accessor, so a rotating row can never be silently
    /// collapsed onto the shared base.
    pub(crate) fn base_off(&self, base: &str, n_rel: usize) -> (String, usize) {
        match self {
            RowAddr::Linear(row_elems) => (base.to_string(), n_rel * row_elems),
            RowAddr::Table(offs) => (base.to_string(), offs[n_rel]),
            RowAddr::Rotating(_) => (format!("{base}{n_rel}"), 0),
        }
    }
}

/// Padded input extent `(h, w)` for a conv layer (equals the input extent
/// when the layer does not pad).
pub(crate) fn padded_extent(input: &Shape, wdims: &[usize], stride: (usize, usize), padding: Padding) -> Result<(usize, usize)> {
    let (oh, _) = padding.resolve(input.h(), wdims[0], stride.0)?;
    let (ow, _) = padding.resolve(input.w(), wdims[1], stride.1)?;
    let th = match padding {
        Padding::Same => ((oh - 1) * stride.0 + wdims[0]).saturating_sub(input.h()),
        Padding::Valid => 0,
    };
    let tw = match padding {
        Padding::Same => ((ow - 1) * stride.1 + wdims[1]).saturating_sub(input.w()),
        Padding::Valid => 0,
    };
    Ok((input.h() + th, input.w() + tw))
}

/// Valid kernel-tap ranges for one emitted cell block (constant at
/// generation time; border cells get trimmed windows).
#[derive(Debug, Clone, Copy)]
pub(crate) struct TapWindow {
    pub n0: usize,
    pub n1: usize,
    pub m0: usize,
    pub m1: usize,
}

/// Spatial region walker shared by the conv and depthwise emitters.
///
/// Walks output rows/columns per the two [`AxisPlan`]s and the unroll
/// level, peeling border cells and blocking interior cells into register
/// tiles — `tile` columns wide and, when the row loop is kept,
/// `tile_rows` rows tall (a 2-D register block: every cell of the
/// `tile_rows × tile` block shares each materialized weight vector) —
/// then hands each block to the layer-specific cell emitter:
/// `block(w, window, s_name, s_offs, d_name, d_offs)` where `s_offs[t]`
/// addresses cell `t`'s first valid tap relative to `s_name` and
/// `d_offs[t]` its output cell.
pub(crate) struct SpatialWalk {
    pub rows: AxisPlan,
    pub cols: AxisPlan,
    /// Interior column-block width (1 = untiled).
    pub tile: usize,
    /// Interior row-block height (1 = single-row walk).
    pub tile_rows: usize,
    pub unroll: Unroll,
    pub src: String,
    pub dst: String,
    /// Source elements per row.
    pub row_elems: usize,
    /// Source elements per column step (the channel-minor extent).
    pub cmin: usize,
    /// Output elements per cell.
    pub out_minor: usize,
    /// Number of per-window-row source base aliases (`s0`, `s1`, …) the
    /// caller declared — rotate-mode fused rows; 0 = single base `s`.
    /// Kept interior column loops then derive one `sj{t}` per row.
    pub src_rows: usize,
}

/// `i*stride - pad` as a C int expression (non-negative where emitted).
fn lin(var: &str, stride: usize, pad: usize) -> String {
    if pad == 0 {
        format!("{var}*{stride}")
    } else {
        format!("{var}*{stride} - {pad}")
    }
}

impl SpatialWalk {
    pub fn emit<F>(&self, w: &mut CWriter, mut block: F)
    where
        F: FnMut(&mut CWriter, TapWindow, &str, &[usize], &str, &[usize]),
    {
        match self.unroll {
            Unroll::None => unreachable!("loop-form layers are emitted separately"),
            Unroll::Full => {
                for i in 0..self.rows.out {
                    self.emit_row_fixed(w, i, &mut block);
                }
            }
            Unroll::KeepOuter1 | Unroll::KeepOuter2 => {
                for i in 0..self.rows.lo {
                    self.emit_row_fixed(w, i, &mut block);
                }
                if self.rows.lo < self.rows.hi {
                    let rb = self.tile_rows.min(self.rows.interior()).max(1);
                    if rb > 1 {
                        // 2-D register block: rb interior rows advance
                        // together; every cell's taps stay in bounds
                        // because i + rb <= hi keeps the whole block
                        // interior.
                        w.open(&format!(
                            "for (i = {}; i + {} <= {}; i += {})",
                            self.rows.lo, rb, self.rows.hi, rb
                        ));
                        self.emit_interior_row_body(w, rb, &mut block);
                        w.close();
                        let rest = self.rows.lo + (self.rows.interior() / rb) * rb;
                        if rest < self.rows.hi {
                            w.open(&format!("for (i = {}; i < {}; i++)", rest, self.rows.hi));
                            self.emit_interior_row_body(w, 1, &mut block);
                            w.close();
                        }
                    } else {
                        w.open(&format!("for (i = {}; i < {}; i++)", self.rows.lo, self.rows.hi));
                        self.emit_interior_row_body(w, 1, &mut block);
                        w.close();
                    }
                }
                for i in self.rows.hi..self.rows.out {
                    self.emit_row_fixed(w, i, &mut block);
                }
            }
        }
    }

    /// Body of the kept interior-row loop (`i` symbolic): bases for the
    /// row block, then the column walk over `rb` rows at once.
    fn emit_interior_row_body<F>(&self, w: &mut CWriter, rb: usize, block: &mut F)
    where
        F: FnMut(&mut CWriter, TapWindow, &str, &[usize], &str, &[usize]),
    {
        w.line(&format!(
            "const float *s = {} + ({})*{};",
            self.src,
            lin("i", self.rows.stride, self.rows.pad),
            self.row_elems
        ));
        w.line(&format!("float *d = {} + i*{};", self.dst, self.cols.out * self.out_minor));
        self.emit_cols(w, 0, self.rows.kernel, rb, block);
    }

    /// A row at a generation-time-constant coordinate (border rows, and
    /// every row under full unroll).
    fn emit_row_fixed<F>(&self, w: &mut CWriter, i: usize, block: &mut F)
    where
        F: FnMut(&mut CWriter, TapWindow, &str, &[usize], &str, &[usize]),
    {
        let (n0, n1) = self.rows.window(i);
        w.open("");
        w.line(&format!("const float *s = {} + {};", self.src, self.rows.src_start(i) * self.row_elems));
        w.line(&format!("float *d = {} + {};", self.dst, i * self.cols.out * self.out_minor));
        self.emit_cols(w, n0, n1, 1, block);
        w.close();
    }

    /// Per-cell source offset within a row block (`rr` rows below the
    /// block's first row, relative tap column offset `c_off`).
    fn row_s_off(&self, rr: usize, c_off: usize) -> usize {
        rr * self.rows.stride * self.row_elems + c_off
    }

    /// Per-cell destination offset within a row block.
    fn row_d_off(&self, rr: usize, c_off: usize) -> usize {
        rr * self.cols.out * self.out_minor + c_off
    }

    pub(crate) fn emit_cols<F>(&self, w: &mut CWriter, n0: usize, n1: usize, rb: usize, block: &mut F)
    where
        F: FnMut(&mut CWriter, TapWindow, &str, &[usize], &str, &[usize]),
    {
        for j in 0..self.cols.lo {
            self.emit_col_fixed(w, n0, n1, j, rb, block);
        }
        if self.cols.lo < self.cols.hi {
            let interior = self.cols.hi - self.cols.lo;
            if self.unroll.keeps_cols() {
                let tb = self.tile.min(interior).max(1);
                if tb > 1 {
                    w.open(&format!(
                        "for (j = {}; j + {} <= {}; j += {})",
                        self.cols.lo, tb, self.cols.hi, tb
                    ));
                    self.emit_interior_body(w, n0, n1, rb, tb, block);
                    w.close();
                    let rest = self.cols.lo + (interior / tb) * tb;
                    if rest < self.cols.hi {
                        w.open(&format!("for (j = {}; j < {}; j++)", rest, self.cols.hi));
                        self.emit_interior_body(w, n0, n1, rb, 1, block);
                        w.close();
                    }
                } else {
                    w.open(&format!("for (j = {}; j < {}; j++)", self.cols.lo, self.cols.hi));
                    self.emit_interior_body(w, n0, n1, rb, 1, block);
                    w.close();
                }
            } else {
                // Columns unrolled: block consecutive interior cells.
                let mut j = self.cols.lo;
                while j < self.cols.hi {
                    let b = self.tile.min(self.cols.hi - j).max(1);
                    let mut s_offs = Vec::with_capacity(rb * b);
                    let mut d_offs = Vec::with_capacity(rb * b);
                    for rr in 0..rb {
                        for t in 0..b {
                            let c = ((j + t) * self.cols.stride - self.cols.pad) * self.cmin;
                            s_offs.push(self.row_s_off(rr, c));
                            d_offs.push(self.row_d_off(rr, (j + t) * self.out_minor));
                        }
                    }
                    let win = TapWindow { n0, n1, m0: 0, m1: self.cols.kernel };
                    block(w, win, "s", &s_offs, "d", &d_offs);
                    j += b;
                }
            }
        }
        for j in self.cols.hi..self.cols.out {
            self.emit_col_fixed(w, n0, n1, j, rb, block);
        }
    }

    /// Body of the kept interior-column loop (`j` symbolic) for a block of
    /// `rb` rows × `cb` columns.
    fn emit_interior_body<F>(&self, w: &mut CWriter, n0: usize, n1: usize, rb: usize, cb: usize, block: &mut F)
    where
        F: FnMut(&mut CWriter, TapWindow, &str, &[usize], &str, &[usize]),
    {
        let col_term = format!("({})*{}", lin("j", self.cols.stride, self.cols.pad), self.cmin);
        if self.src_rows == 0 {
            w.line(&format!("const float *sj = s + {col_term};"));
        } else {
            // One column base per rotating source-row alias.
            for t in 0..self.src_rows {
                w.line(&format!("const float *sj{t} = s{t} + {col_term};"));
            }
        }
        w.line(&format!("float *dj = d + j*{};", self.out_minor));
        let mut s_offs = Vec::with_capacity(rb * cb);
        let mut d_offs = Vec::with_capacity(rb * cb);
        for rr in 0..rb {
            for t in 0..cb {
                s_offs.push(self.row_s_off(rr, t * self.cols.stride * self.cmin));
                d_offs.push(self.row_d_off(rr, t * self.out_minor));
            }
        }
        let win = TapWindow { n0, n1, m0: 0, m1: self.cols.kernel };
        block(w, win, "sj", &s_offs, "dj", &d_offs);
    }

    /// A border column at a constant coordinate (still spans the row
    /// block: the trimmed column window applies to every row of it).
    fn emit_col_fixed<F>(&self, w: &mut CWriter, n0: usize, n1: usize, j: usize, rb: usize, block: &mut F)
    where
        F: FnMut(&mut CWriter, TapWindow, &str, &[usize], &str, &[usize]),
    {
        let (m0, m1) = self.cols.window(j);
        let win = TapWindow { n0, n1, m0, m1 };
        let c = self.cols.src_start(j) * self.cmin;
        let s_offs: Vec<usize> = (0..rb).map(|rr| self.row_s_off(rr, c)).collect();
        let d_offs: Vec<usize> = (0..rb).map(|rr| self.row_d_off(rr, j * self.out_minor)).collect();
        block(w, win, "s", &s_offs, "d", &d_offs);
    }
}

pub(crate) fn emit_conv(
    w: &mut CWriter,
    ctx: &LayerCtx<'_>,
    weights: &Tensor,
    bias: &Tensor,
    stride: (usize, usize),
    padding: Padding,
    activation: Activation,
) -> Result<()> {
    let wd = weights.dims();
    let (h_k, w_k, c_in, c_out) = (wd[0], wd[1], wd[2], wd[3]);
    let (h_in, w_in) = (ctx.in_shape.h(), ctx.in_shape.w());
    let (h_out, w_out) = (ctx.out_shape.h(), ctx.out_shape.w());
    let (ph, pw) = padded_extent(ctx.in_shape, wd, stride, padding)?;
    let pads = (ph, pw) != (h_in, w_in);
    let (pad_top, pad_left) = match padding {
        Padding::Same => {
            let (_, pt) = padding.resolve(h_in, h_k, stride.0)?;
            let (_, pl) = padding.resolve(w_in, w_k, stride.1)?;
            (pt, pl)
        }
        Padding::Valid => (0, 0),
    };

    let sched = ChannelSchedule::for_channels(ctx.opts.isa, c_out);
    let padless = pads && schedule::pad_strategy(ctx.opts) == PadStrategy::Padless;

    // --- Step 1: padding strategy -----------------------------------------
    let src: String = if pads && !padless {
        emit_pad_fill_public(w, ctx, h_in, w_in, c_in, ph, pw, pad_top, pad_left)?;
        ctx.padbuf.to_string()
    } else {
        ctx.src.to_string()
    };

    // --- Step 2/3: compute loops ------------------------------------------
    if ctx.opts.unroll == Unroll::None {
        return emit_conv_loops(w, ctx, &src, h_k, w_k, c_in, c_out, pw * c_in, stride, h_out, w_out, activation, &sched);
    }

    let (rows, cols) = if padless {
        (
            AxisPlan::padless(h_out, stride.0, h_k, pad_top, h_in),
            AxisPlan::padless(w_out, stride.1, w_k, pad_left, w_in),
        )
    } else {
        let (src_h, src_w) = if pads { (ph, pw) } else { (h_in, w_in) };
        (AxisPlan::full(h_out, stride.0, h_k, src_h), AxisPlan::full(w_out, stride.1, w_k, src_w))
    };
    let row_elems = cols.input * c_in;
    let (tile_rows, tile) = schedule::tile_shape(ctx.opts, &sched, rows.interior(), cols.interior());

    let dst_static = schedule::static_buf(ctx.dst);
    let walk = SpatialWalk {
        rows,
        cols,
        tile,
        tile_rows,
        unroll: ctx.opts.unroll,
        src,
        dst: ctx.dst.to_string(),
        row_elems,
        cmin: c_in,
        out_minor: c_out,
        src_rows: 0,
    };
    let cells = ConvCells {
        ctx,
        weights,
        bias,
        activation,
        sched: &sched,
        row_addr: RowAddr::Linear(row_elems),
        w_k,
        c_in,
        c_out,
        dst_static,
    };
    walk.emit(w, |w, win, s, so, d, dofs| cells.emit_block(w, win, s, so, d, dofs));

    // Fused softmax runs once over the final map.
    if activation == Activation::Softmax {
        super::activation::emit_softmax_over(w, ctx, ctx.dst, ctx.out_shape.numel());
    }
    Ok(())
}

/// Emit one output row of a convolution inside a row-streaming fusion
/// group: the row coordinate is a generation-time constant (plus, inside
/// the steady-state rolled loop, `io.*_iter_elems` floats per loop
/// iteration `i`), the source rows come from `io.src_map` (the producer's
/// ring buffer or the group's input plane, base expression `ctx.src`), and
/// the output row lands `io.dst_row_off` elements into `ctx.dst`. Columns
/// keep the usual padless split: peeled border columns plus a
/// (register-tiled) interior loop.
pub(crate) fn emit_conv_row_fused(
    w: &mut CWriter,
    ctx: &LayerCtx<'_>,
    weights: &Tensor,
    bias: &Tensor,
    stride: (usize, usize),
    padding: Padding,
    activation: Activation,
    io: &schedule::FusedRowIo,
) -> Result<()> {
    debug_assert!(activation != Activation::Softmax, "softmax heads are never fused");
    let wd = weights.dims();
    let (h_k, w_k, c_in, c_out) = (wd[0], wd[1], wd[2], wd[3]);
    let (h_in, w_in) = (ctx.in_shape.h(), ctx.in_shape.w());
    let (h_out, w_out) = (ctx.out_shape.h(), ctx.out_shape.w());
    let (pad_top, pad_left) = match padding {
        Padding::Same => {
            let (_, pt) = padding.resolve(h_in, h_k, stride.0)?;
            let (_, pl) = padding.resolve(w_in, w_k, stride.1)?;
            (pt, pl)
        }
        Padding::Valid => (0, 0),
    };
    let sched = ChannelSchedule::for_channels(ctx.opts.isa, c_out);
    let rows = AxisPlan::padless(h_out, stride.0, h_k, pad_top, h_in);
    let cols = AxisPlan::padless(w_out, stride.1, w_k, pad_left, w_in);
    let (n0, n1) = rows.window(io.out_row);
    let p0 = rows.src_start(io.out_row);
    let (row_addr, src_rows) = match &io.src_rot {
        // Rotating ring source: one pointer alias per window row.
        Some(rot) => {
            debug_assert_eq!(rot.names.len(), n1 - n0, "rotating pointer set must cover the window");
            (RowAddr::Rotating(rot.names.len()), rot.names.len())
        }
        None => {
            let offs: Vec<usize> = (0..n1 - n0).map(|t| io.src_map.off(p0 + t)).collect();
            (RowAddr::Table(offs), 0)
        }
    };
    let (_, tile) = schedule::tile_shape(ctx.opts, &sched, 1, cols.interior());
    let walk = SpatialWalk {
        rows,
        cols,
        tile,
        tile_rows: 1,
        unroll: ctx.opts.unroll,
        src: ctx.src.to_string(),
        dst: ctx.dst.to_string(),
        row_elems: 0, // rows are addressed through the offset table
        cmin: c_in,
        out_minor: c_out,
        src_rows,
    };
    let cells = ConvCells {
        ctx,
        weights,
        bias,
        activation,
        sched: &sched,
        row_addr,
        w_k,
        c_in,
        c_out,
        // Rolled loop terms / rotating pointers keep the store-alignment
        // proof only under the shared claim rule.
        dst_static: io.dst_claims_aligned(ctx.dst),
    };
    w.open("");
    match &io.src_rot {
        Some(rot) => {
            for (t, name) in rot.names.iter().enumerate() {
                w.line(&format!("const float *s{t} = {name};"));
            }
        }
        None => w.line(&format!(
            "const float *s = {};",
            schedule::fused_base(ctx.src, 0, io.src_iter_elems)
        )),
    }
    match &io.dst_rot {
        Some(rot) => w.line(&format!("float *d = {};", rot.names[0])),
        None => w.line(&format!(
            "float *d = {};",
            schedule::fused_base(ctx.dst, io.dst_row_off, io.dst_iter_elems)
        )),
    }
    walk.emit_cols(w, n0, n1, 1, &mut |w, win, s, so, d, dofs| {
        cells.emit_block(w, win, s, so, d, dofs)
    });
    w.close();
    Ok(())
}

/// Cell-block emitter for the standard convolution.
struct ConvCells<'a> {
    ctx: &'a LayerCtx<'a>,
    weights: &'a Tensor,
    bias: &'a Tensor,
    activation: Activation,
    sched: &'a ChannelSchedule,
    /// How the valid kernel rows of a cell map to source offsets.
    row_addr: RowAddr,
    w_k: usize,
    c_in: usize,
    c_out: usize,
    /// Whether `dst` is a generator-owned (alignable) buffer.
    dst_static: bool,
}

impl ConvCells<'_> {
    fn inline(&self) -> bool {
        self.ctx.opts.effective_const_mode() == ConstMode::Inline
    }

    /// Weight/bias arrays are always generator-owned; a load of channel
    /// group `k0` is aligned when alignment is on and the flat index is a
    /// whole number of vectors (stride terms are multiples of `c_out`, so
    /// `c_out % width == 0` keeps every tap aligned).
    fn warr_aligned(&self, v: &VecSpec, idx: usize) -> bool {
        self.ctx.opts.use_aligned() && idx % v.width == 0 && self.c_out % v.width == 0
    }

    fn bias_aligned(&self, v: &VecSpec, k0: usize) -> bool {
        self.ctx.opts.use_aligned() && k0 % v.width == 0
    }

    /// Output stores: the symbolic cell base advances in multiples of
    /// `c_out`, so provable alignment needs a static dst, a divisible
    /// channel count, and a vector-aligned constant offset.
    fn store_aligned(&self, v: &VecSpec, d_off: usize) -> bool {
        self.ctx.opts.use_aligned()
            && self.dst_static
            && self.c_out % v.width == 0
            && d_off % v.width == 0
    }

    /// Flat index into the HWIO weight array.
    fn widx(&self, n: usize, m: usize, o: usize, k: usize) -> usize {
        ((n * self.w_k + m) * self.c_in + o) * self.c_out + k
    }

    /// C expression reading the source element at kernel tap `(n, m)`,
    /// input channel `o`, of the cell whose column offset from the walker
    /// base `s_name` is `s_off`. Rotating row addressing swaps the base
    /// per window row; the other forms fold the row term into the offset.
    fn src_ref(&self, s_name: &str, s_off: usize, win: &TapWindow, n: usize, m: usize, o: usize) -> String {
        let (base, row_off) = self.row_addr.base_off(s_name, n - win.n0);
        format!("{base}[{}]", s_off + row_off + (m - win.m0) * self.c_in + o)
    }

    /// Emit all channels of a block of cells sharing one tap window.
    fn emit_block(
        &self,
        w: &mut CWriter,
        win: TapWindow,
        s_name: &str,
        s_offs: &[usize],
        d_name: &str,
        d_offs: &[usize],
    ) {
        for seg in &self.sched.segments {
            match seg.vec {
                Some(v) => {
                    let total_groups = seg.len / v.width;
                    let max_g = schedule::max_groups_per_chunk(s_offs.len());
                    let mut g0 = 0usize;
                    while g0 < total_groups {
                        let gc = (total_groups - g0).min(max_g);
                        self.emit_vec_chunk(w, v, seg.start + g0 * v.width, gc, &win, s_name, s_offs, d_name, d_offs);
                        g0 += gc;
                    }
                }
                None => {
                    for k in seg.start..seg.end() {
                        for (&so, &dof) in s_offs.iter().zip(d_offs) {
                            self.emit_scalar_cell(w, k, &win, s_name, so, d_name, dof);
                        }
                    }
                }
            }
        }
    }

    /// Vector chunk covering channels `k0 .. k0 + gc*width` for every cell
    /// of the block. Single-cell blocks are input-stationary (one
    /// broadcast feeds all channel groups); multi-cell blocks are
    /// weight-stationary (one weight register per tap feeds all cells).
    #[allow(clippy::too_many_arguments)]
    fn emit_vec_chunk(
        &self,
        w: &mut CWriter,
        v: VecSpec,
        k0: usize,
        gc: usize,
        win: &TapWindow,
        s_name: &str,
        s_offs: &[usize],
        d_name: &str,
        d_offs: &[usize],
    ) {
        let b = s_offs.len();
        let inline = self.inline();
        let bias = self.bias.data();
        w.open("");
        for t in 0..b {
            for g in 0..gc {
                let k = k0 + g * v.width;
                let init = if inline {
                    v.setr(&bias[k..k + v.width])
                } else {
                    v.load(&format!("b{} + {k}", self.ctx.idx), self.bias_aligned(&v, k))
                };
                w.line(&format!("{} a{t}_{g} = {};", v.ty, init));
            }
        }
        if b == 1 {
            w.line(&format!("{} t0;", v.ty));
        } else {
            w.line(&format!("{} wv;", v.ty));
            for t in 0..b {
                w.line(&format!("{} t{t};", v.ty));
            }
        }
        for n in win.n0..win.n1 {
            for m in win.m0..win.m1 {
                for o in 0..self.c_in {
                    let tap_w: Vec<Vec<f32>> = (0..gc)
                        .map(|g| (0..v.width).map(|l| self.weights.at4(n, m, o, k0 + g * v.width + l)).collect())
                        .collect();
                    let live: Vec<usize> = (0..gc)
                        .filter(|&g| {
                            !(self.ctx.opts.skip_zero_weights
                                && inline
                                && tap_w[g].iter().all(|&x| x == 0.0))
                        })
                        .collect();
                    if live.is_empty() {
                        continue;
                    }
                    let wexpr = |g: usize| {
                        if inline {
                            v.setr(&tap_w[g])
                        } else {
                            let idx = self.widx(n, m, o, k0 + g * v.width);
                            v.load(&format!("w{} + {idx}", self.ctx.idx), self.warr_aligned(&v, idx))
                        }
                    };
                    if b == 1 {
                        w.line(&format!("t0 = {};", v.set1(&self.src_ref(s_name, s_offs[0], win, n, m, o))));
                        for &g in &live {
                            w.line(&v.mul_add(&format!("a0_{g}"), "t0", &wexpr(g)));
                        }
                    } else {
                        for (t, &so) in s_offs.iter().enumerate() {
                            w.line(&format!("t{t} = {};", v.set1(&self.src_ref(s_name, so, win, n, m, o))));
                        }
                        for &g in &live {
                            w.line(&format!("wv = {};", wexpr(g)));
                            for t in 0..b {
                                w.line(&v.mul_add(&format!("a{t}_{g}"), &format!("t{t}"), "wv"));
                            }
                        }
                    }
                }
            }
        }
        for t in 0..b {
            for g in 0..gc {
                let reg = format!("a{t}_{g}");
                emit_vec_activation(w, v, self.activation, &reg);
                let off = d_offs[t] + k0 + g * v.width;
                w.line(&v.store(&format!("{d_name} + {off}"), &reg, self.store_aligned(&v, off)));
            }
        }
        w.close();
    }

    /// Scalar accumulator for one output channel of one cell.
    #[allow(clippy::too_many_arguments)]
    fn emit_scalar_cell(
        &self,
        w: &mut CWriter,
        k: usize,
        win: &TapWindow,
        s_name: &str,
        s_off: usize,
        d_name: &str,
        d_off: usize,
    ) {
        let inline = self.inline();
        w.open("");
        if inline {
            w.line(&format!("float a = {};", fmt_f32(self.bias.data()[k])));
        } else {
            w.line(&format!("float a = b{}[{k}];", self.ctx.idx));
        }
        for n in win.n0..win.n1 {
            for m in win.m0..win.m1 {
                for o in 0..self.c_in {
                    let sref = self.src_ref(s_name, s_off, win, n, m, o);
                    if inline {
                        let wv = self.weights.at4(n, m, o, k);
                        if self.ctx.opts.skip_zero_weights && wv == 0.0 {
                            continue;
                        }
                        w.line(&format!("a += {sref} * {};", fmt_f32(wv)));
                    } else {
                        w.line(&format!("a += {sref} * w{}[{}];", self.ctx.idx, self.widx(n, m, o, k)));
                    }
                }
            }
        }
        w.line(&format!("{d_name}[{}] = {};", d_off + k, scalar_act("a", self.activation)));
        w.close();
    }
}

/// Emit the zero-pad + copy of the input into `nncg_pad` (shared with the
/// depthwise emitter; used by the copy pad strategy).
#[allow(clippy::too_many_arguments)]
pub(crate) fn emit_pad_fill_public(
    w: &mut CWriter,
    ctx: &LayerCtx<'_>,
    h_in: usize,
    w_in: usize,
    c: usize,
    ph: usize,
    pw: usize,
    pad_top: usize,
    pad_left: usize,
) -> Result<()> {
    w.line(&format!("/* zero-pad {}x{}x{c} -> {ph}x{pw}x{c} (Eq. 1) */", h_in, w_in));
    if ctx.opts.unroll == Unroll::Full {
        // Straight-line: one store per padded cell.
        for r in 0..ph {
            for q in 0..pw {
                let inside = r >= pad_top && r < pad_top + h_in && q >= pad_left && q < pad_left + w_in;
                for o in 0..c {
                    let pidx = (r * pw + q) * c + o;
                    if inside {
                        let sidx = ((r - pad_top) * w_in + (q - pad_left)) * c + o;
                        w.line(&format!("{}[{}] = {}[{}];", ctx.padbuf, pidx, ctx.src, sidx));
                    } else {
                        w.line(&format!("{}[{}] = 0.0f;", ctx.padbuf, pidx));
                    }
                }
            }
        }
    } else {
        w.open(&format!("for (i = 0; i < {}; i++)", ph * pw * c));
        w.line(&format!("{}[i] = 0.0f;", ctx.padbuf));
        w.close();
        w.open(&format!("for (i = 0; i < {h_in}; i++)"));
        w.open(&format!("for (j = 0; j < {}; j++)", w_in * c));
        w.line(&format!(
            "{}[(i + {pad_top})*{} + {} + j] = {}[i*{} + j];",
            ctx.padbuf,
            pw * c,
            pad_left * c,
            ctx.src,
            w_in * c
        ));
        w.close();
        w.close();
    }
    Ok(())
}

/// The paper's loop-form emission (`Unroll::None`): all six loops kept,
/// weights in `static const` arrays. The channel loop is emitted once per
/// lane segment, so odd channel counts get a vector main loop plus a
/// scalar tail loop instead of falling back to all-scalar code.
#[allow(clippy::too_many_arguments)]
fn emit_conv_loops(
    w: &mut CWriter,
    ctx: &LayerCtx<'_>,
    src: &str,
    h_k: usize,
    w_k: usize,
    c_in: usize,
    c_out: usize,
    row_elems: usize,
    stride: (usize, usize),
    h_out: usize,
    w_out: usize,
    activation: Activation,
    sched: &ChannelSchedule,
) -> Result<()> {
    if ctx.opts.effective_const_mode() != ConstMode::Array {
        bail!("Unroll::None requires ConstMode::Array (inline constants need unrolled loops)");
    }
    let (sh, sw) = stride;
    let idx = ctx.idx;
    let align_on = ctx.opts.use_aligned();
    let dst_static = schedule::static_buf(ctx.dst);
    w.open(&format!("for (i = 0; i < {h_out}; i++)"));
    w.open(&format!("for (j = 0; j < {w_out}; j++)"));
    w.line(&format!("const float *s = {src} + i*{} + j*{};", sh * row_elems, sw * c_in));
    w.line(&format!("float *d = {} + i*{} + j*{};", ctx.dst, w_out * c_out, c_out));
    for seg in &sched.segments {
        if seg.len == 0 {
            continue;
        }
        if let Some(v) = seg.vec {
            // `k` is symbolic but steps by the width from a width-multiple
            // start, so bias/weight alignment follows the same divisibility
            // rules as the unrolled path.
            let b_al = align_on && seg.start % v.width == 0;
            let w_al = b_al && c_out % v.width == 0;
            let d_al = w_al && dst_static;
            w.open(&format!("for (k = {}; k < {}; k += {})", seg.start, seg.end(), v.width));
            w.line(&format!("{} a = {};", v.ty, v.load(&format!("b{idx} + k"), b_al)));
            w.open(&format!("for (n = 0; n < {h_k}; n++)"));
            w.open(&format!("for (m = 0; m < {w_k}; m++)"));
            w.open(&format!("for (o = 0; o < {c_in}; o++)"));
            w.line(&v.mul_add(
                "a",
                &v.set1(&format!("s[n*{row_elems} + m*{c_in} + o]")),
                &v.load(&format!("w{idx} + ((n*{w_k} + m)*{c_in} + o)*{c_out} + k"), w_al),
            ));
            w.close();
            w.close();
            w.close();
            emit_vec_activation(w, v, activation, "a");
            w.line(&v.store("d + k", "a", d_al));
            w.close();
        } else {
            w.open(&format!("for (k = {}; k < {}; k++)", seg.start, seg.end()));
            w.line(&format!("float a = b{idx}[k];"));
            w.open(&format!("for (n = 0; n < {h_k}; n++)"));
            w.open(&format!("for (m = 0; m < {w_k}; m++)"));
            w.open(&format!("for (o = 0; o < {c_in}; o++)"));
            w.line(&format!(
                "a += s[n*{row_elems} + m*{c_in} + o] * w{idx}[((n*{w_k} + m)*{c_in} + o)*{c_out} + k];"
            ));
            w.close();
            w.close();
            w.close();
            w.line(&format!("d[k] = {};", scalar_act("a", activation)));
            w.close();
        }
    }
    w.close();
    w.close();
    // Fused softmax runs once over the final map.
    if activation == Activation::Softmax {
        super::activation::emit_softmax_over(w, ctx, ctx.dst, ctx.out_shape.numel());
    }
    Ok(())
}

/// Scalar activation expression over accumulator `a` (P2: ternary form).
pub(crate) fn scalar_act(a: &str, activation: Activation) -> String {
    match activation {
        Activation::None | Activation::Softmax => a.to_string(),
        Activation::Relu => format!("{a} > 0.0f ? {a} : 0.0f"),
        Activation::LeakyRelu(alpha) => format!("{a} > 0.0f ? {a} : {} * {a}", fmt_f32(alpha)),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn padded_extent_same() {
        // 16x16, k5, s2: out 8, total pad = 7*2+5-16 = 3 → padded 19
        let s = Shape::new(&[16, 16, 1]);
        let (ph, pw) = padded_extent(&s, &[5, 5, 1, 8], (2, 2), Padding::Same).unwrap();
        assert_eq!((ph, pw), (19, 19));
    }

    #[test]
    fn padded_extent_valid_is_input() {
        let s = Shape::new(&[10, 12, 3]);
        let (ph, pw) = padded_extent(&s, &[3, 3, 3, 4], (1, 1), Padding::Valid).unwrap();
        assert_eq!((ph, pw), (10, 12));
    }

    #[test]
    fn scalar_act_ternaries() {
        assert_eq!(scalar_act("a", Activation::Relu), "a > 0.0f ? a : 0.0f");
        assert!(scalar_act("a", Activation::LeakyRelu(0.1)).contains("0.1f * a"));
        assert_eq!(scalar_act("a", Activation::None), "a");
    }
}
