//! SIMD emission helpers shared by the conv/pool/dense/activation
//! emitters.
//!
//! The paper ships SSSE3 (4-wide f32) and names AVX/NEON as immediate
//! future work; [`Isa::Avx2`] implements the AVX path (8-wide f32 + FMA).
//! Everything is parameterized over a [`VecSpec`] so adding an ISA means
//! adding a table entry, exactly the "can be realized rapidly" claim.

use super::cwriter::fmt_f32;
use super::Isa;

/// One vector flavor: register type + intrinsic naming.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub(crate) struct VecSpec {
    /// f32 lanes per register.
    pub width: usize,
    /// C register type (`__m128` / `__m256`).
    pub ty: &'static str,
    /// Intrinsic prefix (`_mm` / `_mm256`).
    pub pfx: &'static str,
    /// Whether fused multiply-add is available (`_mm256_fmadd_ps`).
    pub fma: bool,
}

pub(crate) const SSE: VecSpec = VecSpec { width: 4, ty: "__m128", pfx: "_mm", fma: false };
pub(crate) const AVX2: VecSpec = VecSpec { width: 8, ty: "__m256", pfx: "_mm256", fma: true };

impl VecSpec {
    /// Pick the widest vector flavor usable for a channel count under an
    /// ISA; `None` = scalar fallback (the paper's rule: the channel count
    /// must divide the lane width).
    pub fn for_channels(isa: Isa, channels: usize) -> Option<VecSpec> {
        match isa {
            Isa::Generic => None,
            Isa::Sse3 => (channels % 4 == 0).then_some(SSE),
            Isa::Avx2 => {
                if channels % 8 == 0 {
                    Some(AVX2)
                } else if channels % 4 == 0 {
                    Some(SSE) // AVX2 hosts run SSE fine; keep partial layers vectorized
                } else {
                    None
                }
            }
        }
    }

    /// `_mm*_set1_ps(expr)`.
    pub fn set1(&self, expr: &str) -> String {
        format!("{}_set1_ps({expr})", self.pfx)
    }

    /// `_mm*_setr_ps(c0, ..., cw)` from weight constants.
    pub fn setr(&self, vals: &[f32]) -> String {
        debug_assert_eq!(vals.len(), self.width);
        let parts: Vec<String> = vals.iter().map(|&v| fmt_f32(v)).collect();
        format!("{}_setr_ps({})", self.pfx, parts.join(", "))
    }

    /// `_mm*_loadu_ps(addr)`.
    pub fn loadu(&self, addr: &str) -> String {
        format!("{}_loadu_ps({addr})", self.pfx)
    }

    /// `reg = _mm*_storeu_ps(addr, reg)` statement.
    pub fn storeu(&self, addr: &str, reg: &str) -> String {
        format!("{}_storeu_ps({addr}, {reg});", self.pfx)
    }

    /// `acc = acc + t * w` — FMA when the ISA has it.
    pub fn mul_add(&self, acc: &str, t: &str, w: &str) -> String {
        if self.fma {
            format!("{acc} = {}_fmadd_ps({t}, {w}, {acc});", self.pfx)
        } else {
            format!("{acc} = {}_add_ps({acc}, {}_mul_ps({t}, {w}));", self.pfx, self.pfx)
        }
    }

    /// `a = max(a, b)` statement.
    pub fn max(&self, a: &str, b: &str) -> String {
        format!("{a} = {}_max_ps({a}, {b});", self.pfx)
    }

    /// Zero register expression.
    pub fn zero(&self) -> String {
        format!("{}_setzero_ps()", self.pfx)
    }

    /// Header needed for this flavor.
    #[allow(dead_code)]
    pub fn header(&self) -> &'static str {
        if self.width == 8 {
            "immintrin.h"
        } else {
            "emmintrin.h"
        }
    }
}

/// Activation applied to a named vector register (P2 as predicated max).
pub(crate) fn emit_vec_activation(
    w: &mut super::cwriter::CWriter,
    v: VecSpec,
    activation: crate::graph::Activation,
    reg: &str,
) {
    use crate::graph::Activation;
    match activation {
        Activation::None | Activation::Softmax => {}
        Activation::Relu => w.line(&v.max(reg, &v.zero())),
        // 0 <= alpha < 1 ⇒ max(x, alpha x) == leaky_relu(x)
        Activation::LeakyRelu(alpha) => {
            w.line(&format!(
                "{reg} = {}_max_ps({reg}, {}_mul_ps({reg}, {}));",
                v.pfx,
                v.pfx,
                v.set1(&fmt_f32(alpha))
            ));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn for_channels_picks_widest() {
        assert_eq!(VecSpec::for_channels(Isa::Generic, 8), None);
        assert_eq!(VecSpec::for_channels(Isa::Sse3, 8).unwrap().width, 4);
        assert_eq!(VecSpec::for_channels(Isa::Avx2, 8).unwrap().width, 8);
        assert_eq!(VecSpec::for_channels(Isa::Avx2, 12).unwrap().width, 4);
        assert_eq!(VecSpec::for_channels(Isa::Avx2, 6), None);
        assert_eq!(VecSpec::for_channels(Isa::Sse3, 6), None);
    }

    #[test]
    fn intrinsic_text() {
        assert_eq!(SSE.set1("x[0]"), "_mm_set1_ps(x[0])");
        assert!(AVX2.mul_add("a0", "t", "w").contains("_mm256_fmadd_ps"));
        assert!(SSE.mul_add("a0", "t", "w").contains("_mm_add_ps"));
        assert_eq!(AVX2.header(), "immintrin.h");
        assert_eq!(SSE.setr(&[1.0, 2.0, 3.0, 4.0]), "_mm_setr_ps(1.0f, 2.0f, 3.0f, 4.0f)");
    }
}
