//! SIMD emission helpers shared by the conv/pool/dense/activation
//! emitters.
//!
//! The paper ships SSSE3 (4-wide f32) and names AVX/NEON as immediate
//! future work; [`Isa::Avx2`] implements the AVX path (8-wide f32 + FMA)
//! and [`Isa::Neon`] the ARM path (`float32x4_t`, `vfmaq_f32`).
//!
//! Everything an emitter says in vector registers goes through a
//! **table-driven intrinsic vocabulary** ([`OpTable`]): one entry per
//! vector flavor mapping each abstract op (load / loadu / store / set1 /
//! setr / fmadd / max / reduce-add / ...) to a C template with `$a`/`$b`/
//! `$c` operand slots. Adding an ISA is adding a table row — exactly the
//! paper's "can be realized rapidly" claim, and the same move Boda-RTC
//! makes with its per-target vector vocabularies. The templates absorb
//! cross-ISA differences like operand order (`_mm256_fmadd_ps(a, b, c)` is
//! `a*b + c`; `vfmaq_f32(a, b, c)` is `a + b*c`) so the emitters never
//! special-case an ISA.
//!
//! [`ChannelSchedule`] generalizes the paper's divisibility rule ("the
//! number of filters should be a multiple of 4") into a *lane schedule*:
//! a channel count that does not divide the vector width is covered by as
//! many full-width vector groups as fit, then narrower vector groups
//! (AVX2 hosts run SSE fine), then scalar remainder lanes — so odd channel
//! counts keep their main body vectorized instead of falling off a cliff
//! to fully scalar code.

use super::cwriter::fmt_f32;
use super::Isa;

/// C templates for one vector flavor's intrinsic vocabulary. `$a`, `$b`,
/// `$c` are operand slots; `$*` (setr only) is the comma-joined lane list.
/// Load/store templates come in aligned/unaligned pairs; on ISAs without
/// the distinction (NEON) both entries share one intrinsic.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub(crate) struct OpTable {
    /// Aligned load expression — address must be `width*4`-byte aligned.
    pub load: &'static str,
    /// Unaligned load expression.
    pub loadu: &'static str,
    /// Aligned store statement (`$a` address, `$b` register).
    pub store: &'static str,
    /// Unaligned store statement.
    pub storeu: &'static str,
    /// Broadcast a scalar expression to all lanes.
    pub set1: &'static str,
    /// Lane-literal constructor from constants; `None` = the ISA has no
    /// immediate-lane constructor (NEON) and weights must live in
    /// addressable arrays ([`ConstMode::Array`][super::ConstMode]).
    pub setr: Option<&'static str>,
    /// Elementwise add expression.
    pub add: &'static str,
    /// Elementwise multiply expression.
    pub mul: &'static str,
    /// Elementwise max expression.
    pub max: &'static str,
    /// All-zero register expression.
    pub zero: &'static str,
    /// Fused `$c += $a * $b` statement; `None` = compose add + mul.
    pub fmadd: Option<&'static str>,
    /// Horizontal-sum-to-scalar expression (vocabulary completeness; no
    /// channel-minor emitter needs a reduction yet).
    pub reduce_add: &'static str,
}

/// Substitute the operand slots of a template.
fn subst(tpl: &str, a: &str, b: &str, c: &str) -> String {
    tpl.replace("$a", a).replace("$b", b).replace("$c", c)
}

const SSE_OPS: OpTable = OpTable {
    load: "_mm_load_ps($a)",
    loadu: "_mm_loadu_ps($a)",
    store: "_mm_store_ps($a, $b);",
    storeu: "_mm_storeu_ps($a, $b);",
    set1: "_mm_set1_ps($a)",
    setr: Some("_mm_setr_ps($*)"),
    add: "_mm_add_ps($a, $b)",
    mul: "_mm_mul_ps($a, $b)",
    max: "_mm_max_ps($a, $b)",
    zero: "_mm_setzero_ps()",
    fmadd: None,
    reduce_add: "_mm_cvtss_f32(_mm_add_ss(_mm_add_ps($a, _mm_movehl_ps($a, $a)), \
                 _mm_shuffle_ps(_mm_add_ps($a, _mm_movehl_ps($a, $a)), \
                 _mm_add_ps($a, _mm_movehl_ps($a, $a)), 1)))",
};

const AVX2_OPS: OpTable = OpTable {
    load: "_mm256_load_ps($a)",
    loadu: "_mm256_loadu_ps($a)",
    store: "_mm256_store_ps($a, $b);",
    storeu: "_mm256_storeu_ps($a, $b);",
    set1: "_mm256_set1_ps($a)",
    setr: Some("_mm256_setr_ps($*)"),
    add: "_mm256_add_ps($a, $b)",
    mul: "_mm256_mul_ps($a, $b)",
    max: "_mm256_max_ps($a, $b)",
    zero: "_mm256_setzero_ps()",
    fmadd: Some("$c = _mm256_fmadd_ps($a, $b, $c);"),
    // Fold 256 -> 128 (low + high lane), then the SSE shuffle reduction.
    reduce_add: "_mm_cvtss_f32(_mm_add_ss(_mm_add_ps(_mm_add_ps(_mm256_castps256_ps128($a), \
                 _mm256_extractf128_ps($a, 1)), _mm_movehl_ps(_mm_add_ps(_mm256_castps256_ps128($a), \
                 _mm256_extractf128_ps($a, 1)), _mm_add_ps(_mm256_castps256_ps128($a), \
                 _mm256_extractf128_ps($a, 1)))), _mm_shuffle_ps(_mm_add_ps(_mm_add_ps(\
_mm256_castps256_ps128($a), _mm256_extractf128_ps($a, 1)), _mm_movehl_ps(_mm_add_ps(\
_mm256_castps256_ps128($a), _mm256_extractf128_ps($a, 1)), _mm_add_ps(_mm256_castps256_ps128($a), \
                 _mm256_extractf128_ps($a, 1)))), _mm_add_ps(_mm_add_ps(_mm256_castps256_ps128($a), \
                 _mm256_extractf128_ps($a, 1)), _mm_movehl_ps(_mm_add_ps(_mm256_castps256_ps128($a), \
                 _mm256_extractf128_ps($a, 1)), _mm_add_ps(_mm256_castps256_ps128($a), \
                 _mm256_extractf128_ps($a, 1)))), 1)))",
};

const NEON_OPS: OpTable = OpTable {
    // NEON element loads have no alignment requirement: one intrinsic
    // serves both slots (the aligned path simply costs nothing extra).
    load: "vld1q_f32($a)",
    loadu: "vld1q_f32($a)",
    store: "vst1q_f32($a, $b);",
    storeu: "vst1q_f32($a, $b);",
    set1: "vdupq_n_f32($a)",
    setr: None,
    add: "vaddq_f32($a, $b)",
    mul: "vmulq_f32($a, $b)",
    max: "vmaxq_f32($a, $b)",
    zero: "vdupq_n_f32(0.0f)",
    fmadd: Some("$c = vfmaq_f32($c, $a, $b);"),
    reduce_add: "vaddvq_f32($a)",
};

/// Pre-VFPv4 ARMv7 row: `vfmaq_f32` does not exist there, so the
/// multiply-accumulate is the classic non-fused `vmlaq_f32` (same
/// `$c += $a * $b` contract, two roundings instead of one — bit-compatible
/// with the SSE compose-add-mul scheme). `vaddvq_f32` is AArch64-only, so
/// the reduction folds pairwise through `vpadd_f32` instead.
const NEON_VFPV3_OPS: OpTable = OpTable {
    load: "vld1q_f32($a)",
    loadu: "vld1q_f32($a)",
    store: "vst1q_f32($a, $b);",
    storeu: "vst1q_f32($a, $b);",
    set1: "vdupq_n_f32($a)",
    setr: None,
    add: "vaddq_f32($a, $b)",
    mul: "vmulq_f32($a, $b)",
    max: "vmaxq_f32($a, $b)",
    zero: "vdupq_n_f32(0.0f)",
    fmadd: Some("$c = vmlaq_f32($c, $a, $b);"),
    reduce_add: "vget_lane_f32(vpadd_f32(vpadd_f32(vget_low_f32($a), vget_high_f32($a)), \
                 vpadd_f32(vget_low_f32($a), vget_high_f32($a))), 0)",
};

/// One vector flavor: register type + its intrinsic vocabulary.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub(crate) struct VecSpec {
    /// f32 lanes per register.
    pub width: usize,
    /// C register type (`__m128` / `__m256` / `float32x4_t`).
    pub ty: &'static str,
    /// Header providing the type + intrinsics.
    pub header_name: &'static str,
    /// Intrinsic vocabulary table.
    pub ops: OpTable,
}

pub(crate) const SSE: VecSpec =
    VecSpec { width: 4, ty: "__m128", header_name: "emmintrin.h", ops: SSE_OPS };
pub(crate) const AVX2: VecSpec =
    VecSpec { width: 8, ty: "__m256", header_name: "immintrin.h", ops: AVX2_OPS };
pub(crate) const NEON: VecSpec =
    VecSpec { width: 4, ty: "float32x4_t", header_name: "arm_neon.h", ops: NEON_OPS };
pub(crate) const NEON_VFPV3: VecSpec =
    VecSpec { width: 4, ty: "float32x4_t", header_name: "arm_neon.h", ops: NEON_VFPV3_OPS };

impl VecSpec {
    /// Pick the widest vector flavor usable for a channel count under an
    /// ISA; `None` = scalar fallback (the paper's original all-or-nothing
    /// rule: the channel count must divide the lane width). Documents the
    /// paper's rule; emitters now use [`ChannelSchedule`] instead.
    #[allow(dead_code)]
    pub fn for_channels(isa: Isa, channels: usize) -> Option<VecSpec> {
        match isa {
            Isa::Generic => None,
            Isa::Sse3 => (channels % 4 == 0).then_some(SSE),
            Isa::Neon | Isa::NeonDot => (channels % 4 == 0).then_some(NEON),
            Isa::NeonVfpv3 => (channels % 4 == 0).then_some(NEON_VFPV3),
            Isa::Avx2 => {
                if channels % 8 == 0 {
                    Some(AVX2)
                } else if channels % 4 == 0 {
                    Some(SSE) // AVX2 hosts run SSE fine; keep partial layers vectorized
                } else {
                    None
                }
            }
        }
    }

    /// Vector flavors available under an ISA, widest first.
    pub fn flavors(isa: Isa) -> &'static [VecSpec] {
        match isa {
            Isa::Generic => &[],
            Isa::Sse3 => &[SSE],
            Isa::Avx2 => &[AVX2, SSE],
            Isa::Neon => &[NEON],
            Isa::NeonVfpv3 => &[NEON_VFPV3],
            // f32 under neon-dot is plain NEON: SDOT only changes the
            // int8 vocabulary below.
            Isa::NeonDot => &[NEON],
        }
    }

    /// Broadcast expression from a scalar C expression.
    pub fn set1(&self, expr: &str) -> String {
        subst(self.ops.set1, expr, "", "")
    }

    /// Lane-literal constructor from weight constants.
    ///
    /// # Panics
    /// On ISAs without one (NEON); those force
    /// [`ConstMode::Array`][super::ConstMode] so this is never reached.
    pub fn setr(&self, vals: &[f32]) -> String {
        debug_assert_eq!(vals.len(), self.width);
        let tpl = self.ops.setr.unwrap_or_else(|| {
            panic!("ISA vocabulary for {} has no lane-literal constructor (use ConstMode::Array)", self.ty)
        });
        let parts: Vec<String> = vals.iter().map(|&v| fmt_f32(v)).collect();
        tpl.replace("$*", &parts.join(", "))
    }

    /// Load expression; `aligned` picks the aligned-load template (the
    /// caller must have proven `addr` is `width*4`-byte aligned).
    pub fn load(&self, addr: &str, aligned: bool) -> String {
        subst(if aligned { self.ops.load } else { self.ops.loadu }, addr, "", "")
    }

    /// Unaligned load expression.
    pub fn loadu(&self, addr: &str) -> String {
        self.load(addr, false)
    }

    /// Store statement; `aligned` as in [`VecSpec::load`].
    pub fn store(&self, addr: &str, reg: &str, aligned: bool) -> String {
        subst(if aligned { self.ops.store } else { self.ops.storeu }, addr, reg, "")
    }

    /// Unaligned store statement.
    pub fn storeu(&self, addr: &str, reg: &str) -> String {
        self.store(addr, reg, false)
    }

    /// `acc = acc + t * w` statement — fused when the ISA has FMA.
    pub fn mul_add(&self, acc: &str, t: &str, w: &str) -> String {
        match self.ops.fmadd {
            Some(tpl) => subst(tpl, t, w, acc),
            None => format!("{acc} = {};", self.add_expr(acc, &self.mul_expr(t, w))),
        }
    }

    /// Elementwise add expression.
    pub fn add_expr(&self, a: &str, b: &str) -> String {
        subst(self.ops.add, a, b, "")
    }

    /// Elementwise multiply expression.
    pub fn mul_expr(&self, a: &str, b: &str) -> String {
        subst(self.ops.mul, a, b, "")
    }

    /// Elementwise max expression.
    pub fn max_expr(&self, a: &str, b: &str) -> String {
        subst(self.ops.max, a, b, "")
    }

    /// `a = max(a, b)` statement.
    pub fn max(&self, a: &str, b: &str) -> String {
        format!("{a} = {};", self.max_expr(a, b))
    }

    /// Zero register expression.
    pub fn zero(&self) -> String {
        self.ops.zero.to_string()
    }

    /// Horizontal-sum-to-scalar expression. `reg` must be a plain register
    /// identifier: the x86 templates repeat the operand while folding
    /// lanes, so a compound expression would be re-evaluated per mention.
    /// (NEON's `vaddvq_f32` entry is AArch64-only; an ARMv7 vocabulary
    /// would need the `vpadd_f32` pairwise fold instead.)
    #[allow(dead_code)]
    pub fn reduce_add(&self, reg: &str) -> String {
        debug_assert!(
            reg.chars().all(|c| c.is_ascii_alphanumeric() || c == '_'),
            "reduce_add needs a plain register name, got {reg:?}"
        );
        subst(self.ops.reduce_add, reg, "", "")
    }

    /// Header needed for this flavor.
    #[allow(dead_code)]
    pub fn header(&self) -> &'static str {
        self.header_name
    }
}

/// A contiguous run of channels emitted with one strategy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub(crate) struct LaneSegment {
    /// First channel covered.
    pub start: usize,
    /// Number of channels covered (a multiple of the vector width for
    /// vector segments).
    pub len: usize,
    /// Vector flavor, or `None` for scalar lanes.
    pub vec: Option<VecSpec>,
}

impl LaneSegment {
    /// One past the last channel covered.
    pub fn end(&self) -> usize {
        self.start + self.len
    }
}

/// How a channel (or neuron, or flat-element) range is carved into vector
/// groups plus a scalar tail.
#[derive(Debug, Clone, PartialEq, Eq)]
pub(crate) struct ChannelSchedule {
    pub segments: Vec<LaneSegment>,
}

impl ChannelSchedule {
    /// Greedy widest-first schedule for `channels` lanes under `isa`.
    pub fn for_channels(isa: Isa, channels: usize) -> ChannelSchedule {
        let mut segments = Vec::new();
        let mut at = 0usize;
        for &v in VecSpec::flavors(isa) {
            let n = (channels - at) / v.width * v.width;
            if n > 0 {
                segments.push(LaneSegment { start: at, len: n, vec: Some(v) });
                at += n;
            }
        }
        if at < channels || channels == 0 {
            segments.push(LaneSegment { start: at, len: channels - at, vec: None });
        }
        ChannelSchedule { segments }
    }

    /// True if any segment is vectorized.
    pub fn has_vector(&self) -> bool {
        self.segments.iter().any(|s| s.vec.is_some())
    }

    /// Emitted statements per tap: one per vector group plus one per
    /// scalar lane (the cost-guard estimate).
    pub fn cost_per_tap(&self) -> usize {
        self.segments
            .iter()
            .map(|s| match s.vec {
                Some(v) => s.len / v.width,
                None => s.len,
            })
            .sum()
    }
}

// ---------------------------------------------------------------------
// int8 vocabulary (`--dtype int8`)
// ---------------------------------------------------------------------

/// C templates for one int8 dot-product flavor. The unit of work is one
/// **accumulator group**: `lanes` int32 accumulators covering `lanes`
/// output channels, fed `chunk` input channels per multiply-accumulate
/// step from a pre-packed weight vector.
///
/// x86 note: `_mm*_maddubs_epi16` (the obvious int8 pairing) multiplies
/// unsigned × signed and **saturates** the int16 pair sums, which would
/// break the bit-exact oracle contract for adversarial weights. The x86
/// rows therefore sign-extend activation pairs to int16 at generation
/// time (composed into one broadcast word) and use `_mm*_madd_epi16`,
/// whose int32 pair sums are exact — same throughput class, no
/// saturation hazard.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub(crate) struct QVecSpec {
    /// int32 accumulator lanes per group (= output channels per group).
    pub lanes: usize,
    /// Input channels consumed per multiply-accumulate step.
    pub chunk: usize,
    /// Accumulator register C type.
    pub acc_ty: &'static str,
    /// Broadcast-activation register C type.
    pub act_ty: &'static str,
    /// Packed-weight element C type in the static arrays.
    pub w_elem_ty: &'static str,
    /// Load `lanes` int32 values ($a = `const int *` address).
    pub load_acc: &'static str,
    /// Store the accumulator group ($a = `int *` address, $b = register).
    pub store_acc: &'static str,
    /// Load one packed weight vector ($a = element address).
    pub load_w: &'static str,
    /// Broadcast a composed activation word ($a = scalar C expression;
    /// an `int` word for the x86/SDOT rows, a single `short` for NEON's
    /// widening row).
    pub broadcast: &'static str,
    /// `$c += $a . $b` multiply-accumulate statement ($a activations,
    /// $b weights, $c accumulator).
    pub madd: &'static str,
}

/// SSE2 int8 row: activations sign-extended to int16 pairs, exact
/// `_mm_madd_epi16` pair-dot into 4 int32 accumulators.
pub(crate) const QSSE: QVecSpec = QVecSpec {
    lanes: 4,
    chunk: 2,
    acc_ty: "__m128i",
    act_ty: "__m128i",
    w_elem_ty: "short",
    load_acc: "_mm_loadu_si128((const __m128i *)($a))",
    store_acc: "_mm_storeu_si128((__m128i *)($a), $b);",
    load_w: "_mm_loadu_si128((const __m128i *)($a))",
    broadcast: "_mm_set1_epi32($a)",
    madd: "$c = _mm_add_epi32($c, _mm_madd_epi16($a, $b));",
};

/// AVX2 int8 row: the same exact madd scheme, 8 accumulator lanes.
pub(crate) const QAVX2: QVecSpec = QVecSpec {
    lanes: 8,
    chunk: 2,
    acc_ty: "__m256i",
    act_ty: "__m256i",
    w_elem_ty: "short",
    load_acc: "_mm256_loadu_si256((const __m256i *)($a))",
    store_acc: "_mm256_storeu_si256((__m256i *)($a), $b);",
    load_w: "_mm256_loadu_si256((const __m256i *)($a))",
    broadcast: "_mm256_set1_epi32($a)",
    madd: "$c = _mm256_add_epi32($c, _mm256_madd_epi16($a, $b));",
};

/// NEON int8 row (ARMv7+/AArch64 baseline): `vmlal_s16` widening
/// multiply-accumulate — int16 × int16 + int32, exact. (`vmlal_s8`
/// accumulates into int16 lanes, which wrap for real accumulations, so
/// the widening int16 form is the correct baseline row.)
pub(crate) const QNEON: QVecSpec = QVecSpec {
    lanes: 4,
    chunk: 1,
    acc_ty: "int32x4_t",
    act_ty: "int16x4_t",
    w_elem_ty: "short",
    load_acc: "vld1q_s32($a)",
    store_acc: "vst1q_s32($a, $b);",
    load_w: "vld1_s16($a)",
    broadcast: "vdup_n_s16($a)",
    madd: "$c = vmlal_s16($c, $a, $b);",
};

/// ARMv8.2+dotprod row ([`Isa::NeonDot`]): `vdotq_s32` — four signed
/// int8×int8 products per lane summed into each int32 accumulator, so
/// one step consumes 4 input channels for 4 output channels.
pub(crate) const QNEON_DOT: QVecSpec = QVecSpec {
    lanes: 4,
    chunk: 4,
    acc_ty: "int32x4_t",
    act_ty: "int8x16_t",
    w_elem_ty: "signed char",
    load_acc: "vld1q_s32($a)",
    store_acc: "vst1q_s32($a, $b);",
    load_w: "vld1q_s8($a)",
    broadcast: "vreinterpretq_s8_s32(vdupq_n_s32($a))",
    madd: "$c = vdotq_s32($c, $a, $b);",
};

impl QVecSpec {
    /// int8 flavors available under an ISA, widest first. AVX2 hosts
    /// also get the SSE row for 4-lane remainder groups.
    pub fn flavors(isa: Isa) -> &'static [QVecSpec] {
        match isa {
            Isa::Generic => &[],
            Isa::Sse3 => &[QSSE],
            Isa::Avx2 => &[QAVX2, QSSE],
            Isa::Neon | Isa::NeonVfpv3 => &[QNEON],
            Isa::NeonDot => &[QNEON_DOT],
        }
    }

    /// Accumulator-group load expression.
    pub fn load_acc(&self, addr: &str) -> String {
        subst(self.load_acc, addr, "", "")
    }

    /// Accumulator-group store statement.
    pub fn store_acc(&self, addr: &str, reg: &str) -> String {
        subst(self.store_acc, addr, reg, "")
    }

    /// Packed-weight vector load expression.
    pub fn load_w(&self, addr: &str) -> String {
        subst(self.load_w, addr, "", "")
    }

    /// Broadcast expression from a composed activation word.
    pub fn broadcast(&self, expr: &str) -> String {
        subst(self.broadcast, expr, "", "")
    }

    /// `acc += act . w` statement.
    pub fn madd(&self, act: &str, wv: &str, acc: &str) -> String {
        subst(self.madd, act, wv, acc)
    }
}

/// int8 counterpart of [`LaneSegment`]: a run of output channels
/// emitted as accumulator groups of one flavor, or scalar lanes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub(crate) struct QLaneSegment {
    /// First output channel covered.
    pub start: usize,
    /// Number of channels covered (multiple of `lanes` for vector
    /// segments).
    pub len: usize,
    /// int8 flavor, or `None` for scalar lanes.
    pub vec: Option<QVecSpec>,
}

/// int8 counterpart of [`ChannelSchedule`]: vector-group width is
/// per-dtype (the int32 accumulator lanes of the ISA's dot row), greedy
/// widest first, scalar remainder lanes.
#[derive(Debug, Clone, PartialEq, Eq)]
pub(crate) struct QChannelSchedule {
    pub segments: Vec<QLaneSegment>,
}

impl QChannelSchedule {
    /// Greedy widest-first schedule for `channels` output lanes.
    pub fn for_channels(isa: Isa, channels: usize) -> QChannelSchedule {
        let mut segments = Vec::new();
        let mut at = 0usize;
        for &v in QVecSpec::flavors(isa) {
            let n = (channels - at) / v.lanes * v.lanes;
            if n > 0 {
                segments.push(QLaneSegment { start: at, len: n, vec: Some(v) });
                at += n;
            }
        }
        if at < channels || channels == 0 {
            segments.push(QLaneSegment { start: at, len: channels - at, vec: None });
        }
        QChannelSchedule { segments }
    }

    /// Statement-count estimate per tap (one per accumulator group plus
    /// one per scalar lane), mirroring [`ChannelSchedule::cost_per_tap`].
    pub fn cost_per_tap(&self) -> usize {
        self.segments
            .iter()
            .map(|s| match s.vec {
                Some(v) => s.len / v.lanes,
                None => s.len,
            })
            .sum()
    }
}

/// Activation applied to a named vector register (P2 as predicated max).
pub(crate) fn emit_vec_activation(
    w: &mut super::cwriter::CWriter,
    v: VecSpec,
    activation: crate::graph::Activation,
    reg: &str,
) {
    use crate::graph::Activation;
    match activation {
        Activation::None | Activation::Softmax => {}
        Activation::Relu => w.line(&v.max(reg, &v.zero())),
        // 0 <= alpha < 1 ⇒ max(x, alpha x) == leaky_relu(x)
        Activation::LeakyRelu(alpha) => {
            let scaled = v.mul_expr(reg, &v.set1(&fmt_f32(alpha)));
            w.line(&v.max(reg, &scaled));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn for_channels_picks_widest() {
        assert_eq!(VecSpec::for_channels(Isa::Generic, 8), None);
        assert_eq!(VecSpec::for_channels(Isa::Sse3, 8).unwrap().width, 4);
        assert_eq!(VecSpec::for_channels(Isa::Avx2, 8).unwrap().width, 8);
        assert_eq!(VecSpec::for_channels(Isa::Avx2, 12).unwrap().width, 4);
        assert_eq!(VecSpec::for_channels(Isa::Avx2, 6), None);
        assert_eq!(VecSpec::for_channels(Isa::Sse3, 6), None);
        assert_eq!(VecSpec::for_channels(Isa::Neon, 8).unwrap().ty, "float32x4_t");
        assert_eq!(VecSpec::for_channels(Isa::Neon, 6), None);
    }

    #[test]
    fn intrinsic_text() {
        assert_eq!(SSE.set1("x[0]"), "_mm_set1_ps(x[0])");
        assert!(AVX2.mul_add("a0", "t", "w").contains("_mm256_fmadd_ps"));
        assert!(SSE.mul_add("a0", "t", "w").contains("_mm_add_ps"));
        assert_eq!(AVX2.header(), "immintrin.h");
        assert_eq!(SSE.setr(&[1.0, 2.0, 3.0, 4.0]), "_mm_setr_ps(1.0f, 2.0f, 3.0f, 4.0f)");
    }

    #[test]
    fn neon_vocabulary() {
        assert_eq!(NEON.header(), "arm_neon.h");
        assert_eq!(NEON.ty, "float32x4_t");
        assert_eq!(NEON.set1("x[0]"), "vdupq_n_f32(x[0])");
        assert_eq!(NEON.loadu("s + 4"), "vld1q_f32(s + 4)");
        // NEON loads are alignment-agnostic: both templates are vld1q.
        assert_eq!(NEON.load("s + 4", true), "vld1q_f32(s + 4)");
        assert_eq!(NEON.storeu("d + 4", "a0"), "vst1q_f32(d + 4, a0);");
        // vfmaq_f32(acc, a, b) = acc + a*b — operand order differs from x86
        // FMA; the template absorbs it.
        assert_eq!(NEON.mul_add("acc", "t", "wv"), "acc = vfmaq_f32(acc, t, wv);");
        assert_eq!(NEON.max("a", "b"), "a = vmaxq_f32(a, b);");
        assert_eq!(NEON.zero(), "vdupq_n_f32(0.0f)");
        assert_eq!(NEON.reduce_add("a"), "vaddvq_f32(a)");
        assert!(NEON.ops.setr.is_none());
    }

    #[test]
    #[should_panic]
    fn neon_setr_is_unreachable_by_contract() {
        let _ = NEON.setr(&[1.0, 2.0, 3.0, 4.0]);
    }

    #[test]
    fn neon_vfpv3_vocabulary_uses_nonfused_mla() {
        assert_eq!(NEON_VFPV3.ty, "float32x4_t");
        assert_eq!(NEON_VFPV3.header(), "arm_neon.h");
        // vmlaq_f32(acc, a, b) = acc + a*b, two roundings (no VFPv4 fuse).
        assert_eq!(NEON_VFPV3.mul_add("acc", "t", "wv"), "acc = vmlaq_f32(acc, t, wv);");
        assert!(!NEON_VFPV3.mul_add("acc", "t", "wv").contains("vfmaq"));
        // Loads/stores/max share the alignment-agnostic NEON forms.
        assert_eq!(NEON_VFPV3.load("s + 4", true), "vld1q_f32(s + 4)");
        assert_eq!(NEON_VFPV3.storeu("d", "a0"), "vst1q_f32(d, a0);");
        assert_eq!(NEON_VFPV3.max("a", "b"), "a = vmaxq_f32(a, b);");
        assert!(NEON_VFPV3.ops.setr.is_none());
        // ARMv7 has no vaddvq_f32: the reduction folds through vpadd_f32.
        let red = NEON_VFPV3.reduce_add("v");
        assert!(red.contains("vpadd_f32"));
        assert!(red.contains("vget_low_f32(v)"));
        assert!(!red.contains("vaddvq"));
        // Schedules mirror the NEON shape (4-wide groups + scalar tail).
        let s = ChannelSchedule::for_channels(Isa::NeonVfpv3, 6);
        assert_eq!(s.segments.len(), 2);
        assert_eq!(s.segments[0].vec.unwrap().width, 4);
        assert!(s.segments[1].vec.is_none());
        assert_eq!(VecSpec::for_channels(Isa::NeonVfpv3, 8).unwrap().ty, "float32x4_t");
        assert_eq!(VecSpec::for_channels(Isa::NeonVfpv3, 6), None);
    }

    #[test]
    fn aligned_and_unaligned_templates_differ_on_x86() {
        assert_eq!(SSE.load("p", true), "_mm_load_ps(p)");
        assert_eq!(SSE.load("p", false), "_mm_loadu_ps(p)");
        assert_eq!(AVX2.load("p", true), "_mm256_load_ps(p)");
        assert_eq!(AVX2.store("p", "r", true), "_mm256_store_ps(p, r);");
        assert_eq!(AVX2.store("p", "r", false), "_mm256_storeu_ps(p, r);");
    }

    #[test]
    fn reduce_add_templates_reference_every_lane_fold() {
        assert!(SSE.reduce_add("v").starts_with("_mm_cvtss_f32("));
        assert!(SSE.reduce_add("v").contains("_mm_movehl_ps(v, v)"));
        let avx = AVX2.reduce_add("v");
        assert!(avx.contains("_mm256_extractf128_ps(v, 1)"));
        assert!(avx.contains("_mm256_castps256_ps128(v)"));
    }

    #[test]
    fn schedule_covers_odd_channels_with_vectors_plus_tail() {
        let s = ChannelSchedule::for_channels(Isa::Sse3, 6);
        assert_eq!(s.segments.len(), 2);
        assert_eq!((s.segments[0].start, s.segments[0].len), (0, 4));
        assert_eq!(s.segments[0].vec.unwrap().width, 4);
        assert_eq!((s.segments[1].start, s.segments[1].len), (4, 2));
        assert!(s.segments[1].vec.is_none());
        assert!(s.has_vector());
        assert_eq!(s.cost_per_tap(), 3); // one SSE group + two scalar lanes
    }

    #[test]
    fn schedule_avx2_mixes_flavors() {
        // 13 = one 8-wide group + one 4-wide group + one scalar lane
        let s = ChannelSchedule::for_channels(Isa::Avx2, 13);
        let widths: Vec<Option<usize>> = s.segments.iter().map(|g| g.vec.map(|v| v.width)).collect();
        assert_eq!(widths, vec![Some(8), Some(4), None]);
        assert_eq!(s.segments[2].len, 1);
        assert_eq!(s.cost_per_tap(), 3);
        assert_eq!(s.segments[1].end(), 12);
    }

    #[test]
    fn schedule_neon_matches_sse_shape() {
        let s = ChannelSchedule::for_channels(Isa::Neon, 6);
        assert_eq!(s.segments.len(), 2);
        assert_eq!(s.segments[0].vec.unwrap().ty, "float32x4_t");
        assert_eq!((s.segments[1].start, s.segments[1].len), (4, 2));
        assert!(s.segments[1].vec.is_none());
    }

    #[test]
    fn schedule_generic_is_all_scalar() {
        let s = ChannelSchedule::for_channels(Isa::Generic, 5);
        assert_eq!(s.segments.len(), 1);
        assert!(s.segments[0].vec.is_none());
        assert!(!s.has_vector());
        assert_eq!(s.cost_per_tap(), 5);
    }

    #[test]
    fn schedule_exact_multiple_has_no_tail() {
        let s = ChannelSchedule::for_channels(Isa::Sse3, 8);
        assert_eq!(s.segments.len(), 1);
        assert_eq!(s.segments[0].len, 8);
        assert_eq!(s.cost_per_tap(), 2);
    }

    #[test]
    fn int8_vocabulary_is_saturation_free() {
        // No row may use the saturating unsigned pairing or the
        // int16-accumulating vmlal_s8 — both break the bit-exact oracle.
        for isa in [Isa::Sse3, Isa::Avx2, Isa::Neon, Isa::NeonVfpv3, Isa::NeonDot] {
            for v in QVecSpec::flavors(isa) {
                assert!(!v.madd.contains("maddubs"), "{isa:?} uses saturating maddubs");
                assert!(!v.madd.contains("vmlal_s8"), "{isa:?} uses int16-wrapping vmlal_s8");
            }
        }
        assert_eq!(QSSE.madd("qa", "qw", "qc"), "qc = _mm_add_epi32(qc, _mm_madd_epi16(qa, qw));");
        assert!(QAVX2.madd("a", "w", "c").contains("_mm256_madd_epi16"));
        assert_eq!(QNEON.madd("qa", "qw", "qc"), "qc = vmlal_s16(qc, qa, qw);");
        assert_eq!(QNEON_DOT.madd("qa", "qw", "qc"), "qc = vdotq_s32(qc, qa, qw);");
    }

    #[test]
    fn int8_rows_consume_expected_channel_chunks() {
        assert_eq!((QSSE.lanes, QSSE.chunk), (4, 2));
        assert_eq!((QAVX2.lanes, QAVX2.chunk), (8, 2));
        assert_eq!((QNEON.lanes, QNEON.chunk), (4, 1));
        assert_eq!((QNEON_DOT.lanes, QNEON_DOT.chunk), (4, 4));
        assert_eq!(QNEON_DOT.load_w("qwq0 + 16"), "vld1q_s8(qwq0 + 16)");
        assert_eq!(QNEON.broadcast("(short)s0[3]"), "vdup_n_s16((short)s0[3])");
        assert_eq!(QSSE.load_acc("qb0 + 4"), "_mm_loadu_si128((const __m128i *)(qb0 + 4))");
        assert_eq!(QAVX2.store_acc("nncg_qacc", "qc"), "_mm256_storeu_si256((__m256i *)(nncg_qacc), qc);");
    }

    #[test]
    fn int8_schedule_width_is_per_dtype() {
        // 13 outputs under AVX2: one 8-lane group, one 4-lane SSE
        // remainder group, one scalar lane.
        let s = QChannelSchedule::for_channels(Isa::Avx2, 13);
        let lanes: Vec<Option<usize>> = s.segments.iter().map(|g| g.vec.map(|v| v.lanes)).collect();
        assert_eq!(lanes, vec![Some(8), Some(4), None]);
        assert_eq!(s.cost_per_tap(), 3);
        // neon-dot and plain neon share the 4-lane group shape; generic
        // is all scalar.
        let d = QChannelSchedule::for_channels(Isa::NeonDot, 6);
        assert_eq!(d.segments[0].vec.unwrap().chunk, 4);
        assert_eq!((d.segments[1].start, d.segments[1].len), (4, 2));
        assert!(QChannelSchedule::for_channels(Isa::Generic, 5).segments[0].vec.is_none());
    }

    #[test]
    fn f32_flavors_under_neon_dot_are_plain_neon() {
        assert_eq!(VecSpec::flavors(Isa::NeonDot), &[NEON]);
        assert_eq!(VecSpec::for_channels(Isa::NeonDot, 8).unwrap().ty, "float32x4_t");
    }
}
