//! SIMD emission helpers shared by the conv/pool/dense/activation
//! emitters.
//!
//! The paper ships SSSE3 (4-wide f32) and names AVX/NEON as immediate
//! future work; [`Isa::Avx2`] implements the AVX path (8-wide f32 + FMA).
//! Everything is parameterized over a [`VecSpec`] so adding an ISA means
//! adding a table entry, exactly the "can be realized rapidly" claim.
//!
//! [`ChannelSchedule`] generalizes the paper's divisibility rule ("the
//! number of filters should be a multiple of 4") into a *lane schedule*:
//! a channel count that does not divide the vector width is covered by as
//! many full-width vector groups as fit, then narrower vector groups
//! (AVX2 hosts run SSE fine), then scalar remainder lanes — so odd channel
//! counts keep their main body vectorized instead of falling off a cliff
//! to fully scalar code.

use super::cwriter::fmt_f32;
use super::Isa;

/// One vector flavor: register type + intrinsic naming.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub(crate) struct VecSpec {
    /// f32 lanes per register.
    pub width: usize,
    /// C register type (`__m128` / `__m256`).
    pub ty: &'static str,
    /// Intrinsic prefix (`_mm` / `_mm256`).
    pub pfx: &'static str,
    /// Whether fused multiply-add is available (`_mm256_fmadd_ps`).
    pub fma: bool,
}

pub(crate) const SSE: VecSpec = VecSpec { width: 4, ty: "__m128", pfx: "_mm", fma: false };
pub(crate) const AVX2: VecSpec = VecSpec { width: 8, ty: "__m256", pfx: "_mm256", fma: true };

impl VecSpec {
    /// Pick the widest vector flavor usable for a channel count under an
    /// ISA; `None` = scalar fallback (the paper's original all-or-nothing
    /// rule: the channel count must divide the lane width). Documents the
    /// paper's rule; emitters now use [`ChannelSchedule`] instead.
    #[allow(dead_code)]
    pub fn for_channels(isa: Isa, channels: usize) -> Option<VecSpec> {
        match isa {
            Isa::Generic => None,
            Isa::Sse3 => (channels % 4 == 0).then_some(SSE),
            Isa::Avx2 => {
                if channels % 8 == 0 {
                    Some(AVX2)
                } else if channels % 4 == 0 {
                    Some(SSE) // AVX2 hosts run SSE fine; keep partial layers vectorized
                } else {
                    None
                }
            }
        }
    }

    /// Vector flavors available under an ISA, widest first.
    pub fn flavors(isa: Isa) -> &'static [VecSpec] {
        match isa {
            Isa::Generic => &[],
            Isa::Sse3 => &[SSE],
            Isa::Avx2 => &[AVX2, SSE],
        }
    }

    /// `_mm*_set1_ps(expr)`.
    pub fn set1(&self, expr: &str) -> String {
        format!("{}_set1_ps({expr})", self.pfx)
    }

    /// `_mm*_setr_ps(c0, ..., cw)` from weight constants.
    pub fn setr(&self, vals: &[f32]) -> String {
        debug_assert_eq!(vals.len(), self.width);
        let parts: Vec<String> = vals.iter().map(|&v| fmt_f32(v)).collect();
        format!("{}_setr_ps({})", self.pfx, parts.join(", "))
    }

    /// `_mm*_loadu_ps(addr)`.
    pub fn loadu(&self, addr: &str) -> String {
        format!("{}_loadu_ps({addr})", self.pfx)
    }

    /// `reg = _mm*_storeu_ps(addr, reg)` statement.
    pub fn storeu(&self, addr: &str, reg: &str) -> String {
        format!("{}_storeu_ps({addr}, {reg});", self.pfx)
    }

    /// `acc = acc + t * w` — FMA when the ISA has it.
    pub fn mul_add(&self, acc: &str, t: &str, w: &str) -> String {
        if self.fma {
            format!("{acc} = {}_fmadd_ps({t}, {w}, {acc});", self.pfx)
        } else {
            format!("{acc} = {}_add_ps({acc}, {}_mul_ps({t}, {w}));", self.pfx, self.pfx)
        }
    }

    /// `a = max(a, b)` statement.
    pub fn max(&self, a: &str, b: &str) -> String {
        format!("{a} = {}_max_ps({a}, {b});", self.pfx)
    }

    /// Zero register expression.
    pub fn zero(&self) -> String {
        format!("{}_setzero_ps()", self.pfx)
    }

    /// Header needed for this flavor.
    #[allow(dead_code)]
    pub fn header(&self) -> &'static str {
        if self.width == 8 {
            "immintrin.h"
        } else {
            "emmintrin.h"
        }
    }
}

/// A contiguous run of channels emitted with one strategy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub(crate) struct LaneSegment {
    /// First channel covered.
    pub start: usize,
    /// Number of channels covered (a multiple of the vector width for
    /// vector segments).
    pub len: usize,
    /// Vector flavor, or `None` for scalar lanes.
    pub vec: Option<VecSpec>,
}

impl LaneSegment {
    /// One past the last channel covered.
    pub fn end(&self) -> usize {
        self.start + self.len
    }
}

/// How a channel (or neuron, or flat-element) range is carved into vector
/// groups plus a scalar tail.
#[derive(Debug, Clone, PartialEq, Eq)]
pub(crate) struct ChannelSchedule {
    pub segments: Vec<LaneSegment>,
}

impl ChannelSchedule {
    /// Greedy widest-first schedule for `channels` lanes under `isa`.
    pub fn for_channels(isa: Isa, channels: usize) -> ChannelSchedule {
        let mut segments = Vec::new();
        let mut at = 0usize;
        for &v in VecSpec::flavors(isa) {
            let n = (channels - at) / v.width * v.width;
            if n > 0 {
                segments.push(LaneSegment { start: at, len: n, vec: Some(v) });
                at += n;
            }
        }
        if at < channels || channels == 0 {
            segments.push(LaneSegment { start: at, len: channels - at, vec: None });
        }
        ChannelSchedule { segments }
    }

    /// True if any segment is vectorized.
    pub fn has_vector(&self) -> bool {
        self.segments.iter().any(|s| s.vec.is_some())
    }

    /// Emitted statements per tap: one per vector group plus one per
    /// scalar lane (the cost-guard estimate).
    pub fn cost_per_tap(&self) -> usize {
        self.segments
            .iter()
            .map(|s| match s.vec {
                Some(v) => s.len / v.width,
                None => s.len,
            })
            .sum()
    }
}

/// Activation applied to a named vector register (P2 as predicated max).
pub(crate) fn emit_vec_activation(
    w: &mut super::cwriter::CWriter,
    v: VecSpec,
    activation: crate::graph::Activation,
    reg: &str,
) {
    use crate::graph::Activation;
    match activation {
        Activation::None | Activation::Softmax => {}
        Activation::Relu => w.line(&v.max(reg, &v.zero())),
        // 0 <= alpha < 1 ⇒ max(x, alpha x) == leaky_relu(x)
        Activation::LeakyRelu(alpha) => {
            w.line(&format!(
                "{reg} = {}_max_ps({reg}, {}_mul_ps({reg}, {}));",
                v.pfx,
                v.pfx,
                v.set1(&fmt_f32(alpha))
            ));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn for_channels_picks_widest() {
        assert_eq!(VecSpec::for_channels(Isa::Generic, 8), None);
        assert_eq!(VecSpec::for_channels(Isa::Sse3, 8).unwrap().width, 4);
        assert_eq!(VecSpec::for_channels(Isa::Avx2, 8).unwrap().width, 8);
        assert_eq!(VecSpec::for_channels(Isa::Avx2, 12).unwrap().width, 4);
        assert_eq!(VecSpec::for_channels(Isa::Avx2, 6), None);
        assert_eq!(VecSpec::for_channels(Isa::Sse3, 6), None);
    }

    #[test]
    fn intrinsic_text() {
        assert_eq!(SSE.set1("x[0]"), "_mm_set1_ps(x[0])");
        assert!(AVX2.mul_add("a0", "t", "w").contains("_mm256_fmadd_ps"));
        assert!(SSE.mul_add("a0", "t", "w").contains("_mm_add_ps"));
        assert_eq!(AVX2.header(), "immintrin.h");
        assert_eq!(SSE.setr(&[1.0, 2.0, 3.0, 4.0]), "_mm_setr_ps(1.0f, 2.0f, 3.0f, 4.0f)");
    }

    #[test]
    fn schedule_covers_odd_channels_with_vectors_plus_tail() {
        let s = ChannelSchedule::for_channels(Isa::Sse3, 6);
        assert_eq!(s.segments.len(), 2);
        assert_eq!((s.segments[0].start, s.segments[0].len), (0, 4));
        assert_eq!(s.segments[0].vec.unwrap().width, 4);
        assert_eq!((s.segments[1].start, s.segments[1].len), (4, 2));
        assert!(s.segments[1].vec.is_none());
        assert!(s.has_vector());
        assert_eq!(s.cost_per_tap(), 3); // one SSE group + two scalar lanes
    }

    #[test]
    fn schedule_avx2_mixes_flavors() {
        // 13 = one 8-wide group + one 4-wide group + one scalar lane
        let s = ChannelSchedule::for_channels(Isa::Avx2, 13);
        let widths: Vec<Option<usize>> = s.segments.iter().map(|g| g.vec.map(|v| v.width)).collect();
        assert_eq!(widths, vec![Some(8), Some(4), None]);
        assert_eq!(s.segments[2].len, 1);
        assert_eq!(s.cost_per_tap(), 3);
        assert_eq!(s.segments[1].end(), 12);
    }

    #[test]
    fn schedule_generic_is_all_scalar() {
        let s = ChannelSchedule::for_channels(Isa::Generic, 5);
        assert_eq!(s.segments.len(), 1);
        assert!(s.segments[0].vec.is_none());
        assert!(!s.has_vector());
        assert_eq!(s.cost_per_tap(), 5);
    }

    #[test]
    fn schedule_exact_multiple_has_no_tail() {
        let s = ChannelSchedule::for_channels(Isa::Sse3, 8);
        assert_eq!(s.segments.len(), 1);
        assert_eq!(s.segments[0].len, 8);
        assert_eq!(s.cost_per_tap(), 2);
    }
}
