//! Low-level C source emission: indentation, float literals, identifiers.

/// Accumulates C source text with indentation management.
#[derive(Debug, Default)]
pub struct CWriter {
    buf: String,
    indent: usize,
}

impl CWriter {
    pub fn new() -> Self {
        Self::default()
    }

    /// Emit one line at the current indent.
    pub fn line(&mut self, s: &str) {
        for _ in 0..self.indent {
            self.buf.push_str("    ");
        }
        self.buf.push_str(s);
        self.buf.push('\n');
    }

    /// Emit a blank line.
    pub fn blank(&mut self) {
        self.buf.push('\n');
    }

    /// Emit raw text without indent handling (multi-line blocks).
    pub fn raw(&mut self, s: &str) {
        self.buf.push_str(s);
    }

    /// Open a block: `line` + `{`, increasing indent.
    pub fn open(&mut self, s: &str) {
        self.line(&format!("{s} {{"));
        self.indent += 1;
    }

    /// Close a block: `}`.
    pub fn close(&mut self) {
        assert!(self.indent > 0, "unbalanced close()");
        self.indent -= 1;
        self.line("}");
    }

    pub fn finish(self) -> String {
        assert_eq!(self.indent, 0, "unbalanced blocks at finish()");
        self.buf
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }
}

/// Format an f32 as a C literal that round-trips exactly.
///
/// Rust's `{:?}` prints the shortest decimal that parses back to the same
/// f32; appending `f` makes it a C float literal evaluated in single
/// precision (principle P3 — weights become compile-time constants with
/// zero precision loss).
pub fn fmt_f32(v: f32) -> String {
    assert!(v.is_finite(), "non-finite weight {v} cannot be emitted");
    let s = format!("{v:?}");
    if s.contains('.') || s.contains('e') || s.contains('E') {
        format!("{s}f")
    } else {
        format!("{s}.0f")
    }
}

/// Sanitize a model name into a C identifier prefix.
pub fn c_ident(name: &str) -> String {
    let mut s: String = name
        .chars()
        .map(|c| if c.is_ascii_alphanumeric() || c == '_' { c } else { '_' })
        .collect();
    if s.is_empty() || s.chars().next().unwrap().is_ascii_digit() {
        s.insert(0, 'n');
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn indentation_and_blocks() {
        let mut w = CWriter::new();
        w.open("void f(void)");
        w.line("int x = 0;");
        w.open("for (;;)");
        w.line("x++;");
        w.close();
        w.close();
        let s = w.finish();
        assert_eq!(s, "void f(void) {\n    int x = 0;\n    for (;;) {\n        x++;\n    }\n}\n");
    }

    #[test]
    #[should_panic]
    fn unbalanced_finish_panics() {
        let mut w = CWriter::new();
        w.open("if (1)");
        let _ = w.finish();
    }

    #[test]
    fn float_literals_round_trip() {
        for v in [0.0f32, -0.0, 1.0, -1.5, 0.1, 1.0 / 3.0, 1e-30, 3.4e38, -2.75e-12] {
            let lit = fmt_f32(v);
            assert!(lit.ends_with('f'), "{lit}");
            let parsed: f32 = lit[..lit.len() - 1].parse().unwrap();
            assert_eq!(parsed.to_bits(), v.to_bits(), "{v} -> {lit}");
        }
    }

    #[test]
    fn integral_floats_get_a_decimal_point() {
        assert_eq!(fmt_f32(2.0), "2.0f");
        assert_eq!(fmt_f32(-3.0), "-3.0f");
    }

    #[test]
    #[should_panic]
    fn nan_rejected() {
        fmt_f32(f32::NAN);
    }

    #[test]
    fn ident_sanitization() {
        assert_eq!(c_ident("ball"), "ball");
        assert_eq!(c_ident("my-model.v2"), "my_model_v2");
        assert_eq!(c_ident("3net"), "n3net");
        assert_eq!(c_ident(""), "n");
    }
}
