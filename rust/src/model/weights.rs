//! `.nncgw` — the weights interchange format between the Python trainer and
//! the Rust side.
//!
//! Layout (all little-endian):
//!
//! ```text
//! magic   8 bytes  b"NNCGW1\0\0"
//! count   u32      number of records
//! per record:
//!   name_len u32, name bytes (utf-8)
//!   rank     u32, dims u32 × rank
//!   data     f32 × prod(dims)
//! ```

use anyhow::{bail, Context, Result};
use std::io::{Read, Write};
use std::path::Path;

const MAGIC: &[u8; 8] = b"NNCGW1\0\0";

/// One named tensor.
#[derive(Debug, Clone, PartialEq)]
pub struct WeightRecord {
    pub name: String,
    pub dims: Vec<usize>,
    pub data: Vec<f32>,
}

/// Write records to a `.nncgw` file.
pub fn write_weights(path: &Path, records: &[WeightRecord]) -> Result<()> {
    let mut buf: Vec<u8> = Vec::new();
    buf.extend_from_slice(MAGIC);
    buf.extend_from_slice(&(records.len() as u32).to_le_bytes());
    for r in records {
        let numel: usize = r.dims.iter().product();
        if numel != r.data.len() {
            bail!("record {:?}: dims {:?} want {numel} values, have {}", r.name, r.dims, r.data.len());
        }
        buf.extend_from_slice(&(r.name.len() as u32).to_le_bytes());
        buf.extend_from_slice(r.name.as_bytes());
        buf.extend_from_slice(&(r.dims.len() as u32).to_le_bytes());
        for &d in &r.dims {
            buf.extend_from_slice(&(d as u32).to_le_bytes());
        }
        for &v in &r.data {
            buf.extend_from_slice(&v.to_le_bytes());
        }
    }
    let mut f = std::fs::File::create(path).with_context(|| format!("creating {}", path.display()))?;
    f.write_all(&buf)?;
    Ok(())
}

/// Read records from a `.nncgw` file.
pub fn read_weights(path: &Path) -> Result<Vec<WeightRecord>> {
    let mut bytes = Vec::new();
    std::fs::File::open(path)
        .with_context(|| format!("opening {}", path.display()))?
        .read_to_end(&mut bytes)?;
    parse_weights(&bytes)
}

/// Parse the binary format from a byte slice.
pub fn parse_weights(bytes: &[u8]) -> Result<Vec<WeightRecord>> {
    let mut pos = 0usize;
    let take = |pos: &mut usize, n: usize| -> Result<&[u8]> {
        if *pos + n > bytes.len() {
            bail!("truncated nncgw file at byte {}", *pos);
        }
        let s = &bytes[*pos..*pos + n];
        *pos += n;
        Ok(s)
    };
    let take_u32 = |pos: &mut usize| -> Result<u32> {
        Ok(u32::from_le_bytes(take(pos, 4)?.try_into().unwrap()))
    };

    if take(&mut pos, 8)? != MAGIC {
        bail!("bad magic — not a .nncgw file");
    }
    let count = take_u32(&mut pos)? as usize;
    if count > 10_000 {
        bail!("implausible record count {count}");
    }
    let mut records = Vec::with_capacity(count);
    for _ in 0..count {
        let name_len = take_u32(&mut pos)? as usize;
        if name_len > 4096 {
            bail!("implausible name length {name_len}");
        }
        let name = std::str::from_utf8(take(&mut pos, name_len)?)
            .context("weight name is not utf-8")?
            .to_string();
        let rank = take_u32(&mut pos)? as usize;
        if rank > 8 {
            bail!("implausible rank {rank}");
        }
        let mut dims = Vec::with_capacity(rank);
        for _ in 0..rank {
            dims.push(take_u32(&mut pos)? as usize);
        }
        let numel: usize = dims.iter().product();
        if numel > 100_000_000 {
            bail!("implausible tensor size {numel}");
        }
        let raw = take(&mut pos, numel * 4)?;
        let data = raw
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect();
        records.push(WeightRecord { name, dims, data });
    }
    if pos != bytes.len() {
        bail!("{} trailing bytes after last record", bytes.len() - pos);
    }
    Ok(records)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Vec<WeightRecord> {
        vec![
            WeightRecord { name: "layer0.weights".into(), dims: vec![2, 2, 1, 2], data: (0..8).map(|v| v as f32 * 0.5).collect() },
            WeightRecord { name: "layer0.bias".into(), dims: vec![2], data: vec![1.0, -1.0] },
        ]
    }

    #[test]
    fn round_trip() {
        let path = std::env::temp_dir().join("nncg-test-weights.nncgw");
        write_weights(&path, &sample()).unwrap();
        let back = read_weights(&path).unwrap();
        assert_eq!(back, sample());
    }

    #[test]
    fn rejects_bad_magic() {
        assert!(parse_weights(b"NOTMAGIC\x00\x00\x00\x00").is_err());
    }

    #[test]
    fn rejects_truncation_at_every_boundary() {
        let path = std::env::temp_dir().join("nncg-test-trunc.nncgw");
        write_weights(&path, &sample()).unwrap();
        let full = std::fs::read(&path).unwrap();
        // Any strict prefix must fail (either truncated or trailing-byte check).
        for cut in [7, 11, 13, 20, full.len() - 1] {
            assert!(parse_weights(&full[..cut]).is_err(), "cut={cut}");
        }
    }

    #[test]
    fn rejects_dims_data_mismatch_on_write() {
        let bad = vec![WeightRecord { name: "x".into(), dims: vec![3], data: vec![1.0] }];
        let path = std::env::temp_dir().join("nncg-test-bad.nncgw");
        assert!(write_weights(&path, &bad).is_err());
    }

    #[test]
    fn empty_file_of_records_is_valid() {
        let path = std::env::temp_dir().join("nncg-test-empty.nncgw");
        write_weights(&path, &[]).unwrap();
        assert_eq!(read_weights(&path).unwrap(), vec![]);
    }
}
