//! Model (de)serialization: architecture JSON + `.nncgw` binary weights.
//!
//! The Python trainer (`python/compile/export.py`) writes both files; the
//! Rust side loads them into a [`Model`]. Both directions are implemented in
//! Rust too so tests can round-trip without Python.

pub mod json;
mod weights;

pub use weights::{read_weights, write_weights, WeightRecord};

use crate::graph::{Activation, Layer, Model, Padding};
use crate::tensor::Tensor;
use anyhow::{bail, Context, Result};
use json::Value;
use std::path::Path;

/// Load a model from `<stem>.json` (architecture) + `<stem>.nncgw` (weights).
pub fn load(stem: &Path) -> Result<Model> {
    let arch_path = stem.with_extension("json");
    let weights_path = stem.with_extension("nncgw");
    let arch = std::fs::read_to_string(&arch_path)
        .with_context(|| format!("reading {}", arch_path.display()))?;
    let mut model = model_from_json(&arch)?;
    let records = read_weights(&weights_path)?;
    install_weights(&mut model, &records)?;
    model.validate()?;
    Ok(model)
}

/// Save a model as `<stem>.json` + `<stem>.nncgw`.
pub fn save(model: &Model, stem: &Path) -> Result<()> {
    model.validate()?;
    if let Some(dir) = stem.parent() {
        std::fs::create_dir_all(dir)?;
    }
    std::fs::write(stem.with_extension("json"), model_to_json(model))?;
    write_weights(&stem.with_extension("nncgw"), &collect_weights(model))?;
    Ok(())
}

/// Parse an architecture JSON document into a model with placeholder weights.
pub fn model_from_json(text: &str) -> Result<Model> {
    let v = json::parse(text)?;
    let name = v.get("name")?.as_str()?.to_string();
    let input = v.get("input")?.as_usize_vec()?;
    if input.len() != 3 {
        bail!("input must be [h, w, c], got {input:?}");
    }
    let mut model = Model::new(&name, &input);
    for (idx, lv) in v.get("layers")?.as_array()?.iter().enumerate() {
        let layer = layer_from_json(lv).with_context(|| format!("layer {idx}"))?;
        model.layers.push(layer);
    }
    model.resolve_placeholders()?;
    Ok(model)
}

fn activation_from_json(v: &Value) -> Result<Activation> {
    Ok(match v {
        Value::Str(s) => match s.as_str() {
            "none" => Activation::None,
            "relu" => Activation::Relu,
            "softmax" => Activation::Softmax,
            other => bail!("unknown activation {other:?}"),
        },
        Value::Object(_) => {
            let alpha = v.get("leaky_relu")?.as_f64()? as f32;
            Activation::LeakyRelu(alpha)
        }
        _ => bail!("bad activation {v:?}"),
    })
}

fn activation_to_json(a: &Activation) -> Value {
    match a {
        Activation::None => Value::Str("none".into()),
        Activation::Relu => Value::Str("relu".into()),
        Activation::Softmax => Value::Str("softmax".into()),
        Activation::LeakyRelu(alpha) => {
            Value::Object(vec![("leaky_relu".into(), Value::Num(*alpha as f64))])
        }
    }
}

fn layer_from_json(v: &Value) -> Result<Layer> {
    let kind = v.get("kind")?.as_str()?;
    Ok(match kind {
        "conv2d" => {
            let c_out = v.get("c_out")?.as_usize()?;
            let k = v.get("kernel")?.as_usize_vec()?;
            if k.len() != 2 {
                bail!("kernel must be [h_k, w_k]");
            }
            let stride = match v.get_opt("stride") {
                Some(s) => {
                    let s = s.as_usize_vec()?;
                    (s[0], s[1])
                }
                None => (1, 1),
            };
            let padding = match v.get("padding")?.as_str()? {
                "same" => Padding::Same,
                "valid" => Padding::Valid,
                p => bail!("unknown padding {p:?}"),
            };
            let activation = match v.get_opt("activation") {
                Some(a) => activation_from_json(a)?,
                None => Activation::None,
            };
            Layer::conv2d(c_out, k[0], k[1], stride, padding, activation)
        }
        "avgpool" => {
            let pl = v.get("pool")?.as_usize_vec()?;
            let stride = match v.get_opt("stride") {
                Some(s) => {
                    let s = s.as_usize_vec()?;
                    (s[0], s[1])
                }
                None => (pl[0], pl[1]),
            };
            Layer::AvgPool2D { pool: (pl[0], pl[1]), stride }
        }
        "depthwise" => {
            let k = v.get("kernel")?.as_usize_vec()?;
            let stride = match v.get_opt("stride") {
                Some(s) => {
                    let s = s.as_usize_vec()?;
                    (s[0], s[1])
                }
                None => (1, 1),
            };
            let padding = match v.get("padding")?.as_str()? {
                "same" => Padding::Same,
                "valid" => Padding::Valid,
                p => bail!("unknown padding {p:?}"),
            };
            let activation = match v.get_opt("activation") {
                Some(a) => activation_from_json(a)?,
                None => Activation::None,
            };
            Layer::depthwise(k[0], k[1], stride, padding, activation)
        }
        "maxpool" => {
            let p = v.get("pool")?.as_usize_vec()?;
            let stride = match v.get_opt("stride") {
                Some(s) => {
                    let s = s.as_usize_vec()?;
                    (s[0], s[1])
                }
                None => (p[0], p[1]),
            };
            Layer::MaxPool2D { pool: (p[0], p[1]), stride }
        }
        "relu" => Layer::relu(),
        "leaky_relu" => Layer::leaky_relu(v.get("alpha")?.as_f64()? as f32),
        "softmax" => Layer::softmax(),
        "batchnorm" => {
            let mut l = Layer::batchnorm(v.get("channels")?.as_usize()?);
            if let Some(eps) = v.get_opt("epsilon") {
                if let Layer::BatchNorm { epsilon, .. } = &mut l {
                    *epsilon = eps.as_f64()? as f32;
                }
            }
            l
        }
        "dropout" => Layer::Dropout { rate: v.get("rate")?.as_f64()? as f32 },
        "flatten" => Layer::Flatten,
        "dense" => {
            let out = v.get("out")?.as_usize()?;
            let activation = match v.get_opt("activation") {
                Some(a) => activation_from_json(a)?,
                None => Activation::None,
            };
            Layer::dense(out, activation)
        }
        other => bail!("unknown layer kind {other:?}"),
    })
}

/// Serialize a model's architecture (no weights) to JSON text.
pub fn model_to_json(model: &Model) -> String {
    let layers: Vec<Value> = model.layers.iter().map(layer_to_json).collect();
    Value::Object(vec![
        ("name".into(), Value::Str(model.name.clone())),
        (
            "input".into(),
            Value::Array(model.input.dims().iter().map(|&d| Value::Num(d as f64)).collect()),
        ),
        ("layers".into(), Value::Array(layers)),
    ])
    .to_json()
}

fn usize_pair(a: usize, b: usize) -> Value {
    Value::Array(vec![Value::Num(a as f64), Value::Num(b as f64)])
}

fn layer_to_json(l: &Layer) -> Value {
    match l {
        Layer::Conv2D { weights, stride, padding, activation, .. } => {
            let d = weights.dims();
            Value::Object(vec![
                ("kind".into(), Value::Str("conv2d".into())),
                ("c_out".into(), Value::Num(d[3] as f64)),
                ("kernel".into(), usize_pair(d[0], d[1])),
                ("stride".into(), usize_pair(stride.0, stride.1)),
                ("padding".into(), Value::Str(padding.name().into())),
                ("activation".into(), activation_to_json(activation)),
            ])
        }
        Layer::MaxPool2D { pool, stride } => Value::Object(vec![
            ("kind".into(), Value::Str("maxpool".into())),
            ("pool".into(), usize_pair(pool.0, pool.1)),
            ("stride".into(), usize_pair(stride.0, stride.1)),
        ]),
        Layer::AvgPool2D { pool, stride } => Value::Object(vec![
            ("kind".into(), Value::Str("avgpool".into())),
            ("pool".into(), usize_pair(pool.0, pool.1)),
            ("stride".into(), usize_pair(stride.0, stride.1)),
        ]),
        Layer::DepthwiseConv2D { weights, stride, padding, activation, .. } => {
            let d = weights.dims();
            Value::Object(vec![
                ("kind".into(), Value::Str("depthwise".into())),
                ("kernel".into(), usize_pair(d[0], d[1])),
                ("stride".into(), usize_pair(stride.0, stride.1)),
                ("padding".into(), Value::Str(padding.name().into())),
                ("activation".into(), activation_to_json(activation)),
            ])
        }
        Layer::Activation(Activation::Relu) => {
            Value::Object(vec![("kind".into(), Value::Str("relu".into()))])
        }
        Layer::Activation(Activation::LeakyRelu(a)) => Value::Object(vec![
            ("kind".into(), Value::Str("leaky_relu".into())),
            ("alpha".into(), Value::Num(*a as f64)),
        ]),
        Layer::Activation(Activation::Softmax) => {
            Value::Object(vec![("kind".into(), Value::Str("softmax".into()))])
        }
        Layer::Activation(Activation::None) => {
            Value::Object(vec![("kind".into(), Value::Str("relu".into()))]) // unreachable in practice
        }
        Layer::BatchNorm { gamma, epsilon, .. } => Value::Object(vec![
            ("kind".into(), Value::Str("batchnorm".into())),
            ("channels".into(), Value::Num(gamma.numel() as f64)),
            ("epsilon".into(), Value::Num(*epsilon as f64)),
        ]),
        Layer::Dropout { rate } => Value::Object(vec![
            ("kind".into(), Value::Str("dropout".into())),
            ("rate".into(), Value::Num(*rate as f64)),
        ]),
        Layer::Flatten => Value::Object(vec![("kind".into(), Value::Str("flatten".into()))]),
        Layer::Dense { weights, activation, .. } => Value::Object(vec![
            ("kind".into(), Value::Str("dense".into())),
            ("out".into(), Value::Num(weights.dims()[1] as f64)),
            ("activation".into(), activation_to_json(activation)),
        ]),
    }
}

/// Collect all weight tensors as named records (`layer{i}.{field}`).
pub fn collect_weights(model: &Model) -> Vec<WeightRecord> {
    let mut records = Vec::new();
    for (i, l) in model.layers.iter().enumerate() {
        let mut push = |field: &str, t: &Tensor| {
            records.push(WeightRecord {
                name: format!("layer{i}.{field}"),
                dims: t.dims().to_vec(),
                data: t.data().to_vec(),
            });
        };
        match l {
            Layer::Conv2D { weights, bias, .. } | Layer::DepthwiseConv2D { weights, bias, .. } => {
                push("weights", weights);
                push("bias", bias);
            }
            Layer::BatchNorm { gamma, beta, mean, variance, .. } => {
                push("gamma", gamma);
                push("beta", beta);
                push("mean", mean);
                push("variance", variance);
            }
            Layer::Dense { weights, bias, .. } => {
                push("weights", weights);
                push("bias", bias);
            }
            _ => {}
        }
    }
    records
}

/// Install named weight records into a model (shapes must match).
pub fn install_weights(model: &mut Model, records: &[WeightRecord]) -> Result<()> {
    model.resolve_placeholders()?;
    let find = |name: &str| -> Result<&WeightRecord> {
        records
            .iter()
            .find(|r| r.name == name)
            .ok_or_else(|| anyhow::anyhow!("missing weight record {name:?}"))
    };
    for (i, l) in model.layers.iter_mut().enumerate() {
        let set = |field: &str, t: &mut Tensor| -> Result<()> {
            let r = find(&format!("layer{i}.{field}"))?;
            if r.dims != t.dims() {
                bail!("layer{i}.{field}: shape {:?} != expected {:?}", r.dims, t.dims());
            }
            *t = Tensor::from_vec(&r.dims, r.data.clone())?;
            Ok(())
        };
        match l {
            Layer::Conv2D { weights, bias, .. } | Layer::DepthwiseConv2D { weights, bias, .. } => {
                set("weights", weights)?;
                set("bias", bias)?;
            }
            Layer::BatchNorm { gamma, beta, mean, variance, .. } => {
                set("gamma", gamma)?;
                set("beta", beta)?;
                set("mean", mean)?;
                set("variance", variance)?;
            }
            Layer::Dense { weights, bias, .. } => {
                set("weights", weights)?;
                set("bias", bias)?;
            }
            _ => {}
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::zoo;
    use crate::interp;
    use crate::util::XorShift64;

    #[test]
    fn json_round_trip_all_paper_models() {
        for name in zoo::PAPER_MODELS {
            let m = zoo::by_name(name).unwrap().with_random_weights(1);
            let text = model_to_json(&m);
            let m2 = model_from_json(&text).unwrap().with_random_weights(1);
            assert_eq!(m2.name, m.name);
            assert_eq!(m2.layers.len(), m.layers.len(), "{name}");
            assert_eq!(m2.output_shape().unwrap(), m.output_shape().unwrap(), "{name}");
        }
    }

    #[test]
    fn save_load_round_trip_preserves_numerics() {
        let dir = std::env::temp_dir().join("nncg-test-model-rt");
        let m = zoo::ball_classifier().with_random_weights(99);
        save(&m, &dir.join("ball")).unwrap();
        let m2 = load(&dir.join("ball")).unwrap();

        let mut rng = XorShift64::new(5);
        let x = crate::tensor::Tensor::rand(&[16, 16, 1], 0.0, 1.0, &mut rng);
        let y0 = interp::run(&m, &x).unwrap();
        let y1 = interp::run(&m2, &x).unwrap();
        assert_eq!(y0, y1);
    }

    #[test]
    fn install_rejects_shape_mismatch() {
        let mut m = zoo::tiny_test_net();
        let mut records = collect_weights(&zoo::tiny_test_net().with_random_weights(3));
        records[0].dims = vec![1, 1, 1, 4];
        records[0].data = vec![0.0; 4];
        assert!(install_weights(&mut m, &records).is_err());
    }

    #[test]
    fn install_rejects_missing_record() {
        let mut m = zoo::tiny_test_net();
        let records = vec![];
        assert!(install_weights(&mut m, &records).is_err());
    }

    #[test]
    fn arch_json_errors_are_descriptive() {
        assert!(model_from_json("{}").is_err());
        assert!(model_from_json(r#"{"name":"x","input":[1,2],"layers":[]}"#).is_err());
        let bad_layer = r#"{"name":"x","input":[4,4,1],"layers":[{"kind":"warp"}]}"#;
        let err = model_from_json(bad_layer).unwrap_err().to_string();
        assert!(err.contains("layer 0"), "{err}");
    }
}
