//! Minimal JSON parser + serializer (serde is unavailable offline).
//!
//! Supports the full JSON grammar except `\u` surrogate pairs beyond the
//! BMP. Numbers parse as f64. Object key order is preserved (useful for
//! stable round-trips in tests).

use anyhow::{anyhow, bail, Result};
use std::fmt::Write as _;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Array(Vec<Value>),
    Object(Vec<(String, Value)>),
}

impl Value {
    pub fn as_str(&self) -> Result<&str> {
        match self {
            Value::Str(s) => Ok(s),
            _ => bail!("expected string, got {self:?}"),
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Value::Num(n) => Ok(*n),
            _ => bail!("expected number, got {self:?}"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        let n = self.as_f64()?;
        if n < 0.0 || n.fract() != 0.0 {
            bail!("expected non-negative integer, got {n}");
        }
        Ok(n as usize)
    }

    pub fn as_array(&self) -> Result<&[Value]> {
        match self {
            Value::Array(a) => Ok(a),
            _ => bail!("expected array, got {self:?}"),
        }
    }

    pub fn as_object(&self) -> Result<&[(String, Value)]> {
        match self {
            Value::Object(o) => Ok(o),
            _ => bail!("expected object, got {self:?}"),
        }
    }

    /// Object field lookup.
    pub fn get(&self, key: &str) -> Result<&Value> {
        self.as_object()?
            .iter()
            .find(|(k, _)| k == key)
            .map(|(_, v)| v)
            .ok_or_else(|| anyhow!("missing key {key:?}"))
    }

    /// Optional field lookup.
    pub fn get_opt(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Object(o) => o.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// Array of usize convenience (shapes, strides).
    pub fn as_usize_vec(&self) -> Result<Vec<usize>> {
        self.as_array()?.iter().map(|v| v.as_usize()).collect()
    }

    /// Serialize to a compact JSON string.
    pub fn to_json(&self) -> String {
        let mut s = String::new();
        self.write_json(&mut s);
        s
    }

    fn write_json(&self, out: &mut String) {
        match self {
            Value::Null => out.push_str("null"),
            Value::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Value::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Value::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Value::Array(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write_json(out);
                }
                out.push(']');
            }
            Value::Object(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Value::Str(k.clone()).write_json(out);
                    out.push(':');
                    v.write_json(out);
                }
                out.push('}');
            }
        }
    }
}

/// Parse a JSON document. Trailing garbage is an error.
pub fn parse(input: &str) -> Result<Value> {
    let mut p = Parser { bytes: input.as_bytes(), pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        bail!("trailing characters at byte {}", p.pos);
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Result<u8> {
        let b = self.peek().ok_or_else(|| anyhow!("unexpected end of input"))?;
        self.pos += 1;
        Ok(b)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        let got = self.bump()?;
        if got != b {
            bail!("expected {:?} at byte {}, got {:?}", b as char, self.pos - 1, got as char);
        }
        Ok(())
    }

    fn literal(&mut self, word: &str, v: Value) -> Result<Value> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            bail!("invalid literal at byte {}", self.pos);
        }
    }

    fn value(&mut self) -> Result<Value> {
        self.skip_ws();
        match self.peek().ok_or_else(|| anyhow!("unexpected end of input"))? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Value::Str(self.string()?)),
            b't' => self.literal("true", Value::Bool(true)),
            b'f' => self.literal("false", Value::Bool(false)),
            b'n' => self.literal("null", Value::Null),
            b'-' | b'0'..=b'9' => self.number(),
            c => bail!("unexpected character {:?} at byte {}", c as char, self.pos),
        }
    }

    fn object(&mut self) -> Result<Value> {
        self.expect(b'{')?;
        let mut fields = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Object(fields));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            fields.push((key, val));
            self.skip_ws();
            match self.bump()? {
                b',' => continue,
                b'}' => break,
                c => bail!("expected ',' or '}}', got {:?}", c as char),
            }
        }
        Ok(Value::Object(fields))
    }

    fn array(&mut self) -> Result<Value> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Array(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.bump()? {
                b',' => continue,
                b']' => break,
                c => bail!("expected ',' or ']', got {:?}", c as char),
            }
        }
        Ok(Value::Array(items))
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            let b = self.bump()?;
            match b {
                b'"' => return Ok(s),
                b'\\' => match self.bump()? {
                    b'"' => s.push('"'),
                    b'\\' => s.push('\\'),
                    b'/' => s.push('/'),
                    b'n' => s.push('\n'),
                    b't' => s.push('\t'),
                    b'r' => s.push('\r'),
                    b'b' => s.push('\u{8}'),
                    b'f' => s.push('\u{c}'),
                    b'u' => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let h = self.bump()?;
                            code = code * 16
                                + match h {
                                    b'0'..=b'9' => (h - b'0') as u32,
                                    b'a'..=b'f' => (h - b'a' + 10) as u32,
                                    b'A'..=b'F' => (h - b'A' + 10) as u32,
                                    _ => bail!("bad \\u escape"),
                                };
                        }
                        s.push(char::from_u32(code).ok_or_else(|| anyhow!("bad codepoint"))?);
                    }
                    c => bail!("bad escape \\{}", c as char),
                },
                _ => {
                    // Re-decode UTF-8: back up and take the full char.
                    self.pos -= 1;
                    let rest = std::str::from_utf8(&self.bytes[self.pos..])
                        .map_err(|_| anyhow!("invalid utf-8 in string"))?;
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Value> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-')) {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>().map(Value::Num).map_err(|_| anyhow!("bad number {text:?}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(parse("42").unwrap(), Value::Num(42.0));
        assert_eq!(parse("-1.5e2").unwrap(), Value::Num(-150.0));
        assert_eq!(parse("true").unwrap(), Value::Bool(true));
        assert_eq!(parse("null").unwrap(), Value::Null);
        assert_eq!(parse("\"hi\"").unwrap(), Value::Str("hi".into()));
    }

    #[test]
    fn parses_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_array().unwrap().len(), 3);
        assert_eq!(v.get("a").unwrap().as_array().unwrap()[2].get("b").unwrap().as_str().unwrap(), "c");
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("1 2").is_err());
        assert!(parse("{'a': 1}").is_err());
        assert!(parse("").is_err());
    }

    #[test]
    fn escapes_round_trip() {
        let v = parse(r#""a\"b\\c\ndA""#).unwrap();
        assert_eq!(v, Value::Str("a\"b\\c\nA".replace('A', "d\u{41}")));
    }

    #[test]
    fn serializer_round_trips() {
        let src = r#"{"name":"ball","input":[16,16,1],"alpha":0.1,"ok":true,"x":null}"#;
        let v = parse(src).unwrap();
        let re = parse(&v.to_json()).unwrap();
        assert_eq!(v, re);
    }

    #[test]
    fn usize_vec() {
        let v = parse("[5, 5]").unwrap();
        assert_eq!(v.as_usize_vec().unwrap(), vec![5, 5]);
        assert!(parse("[1.5]").unwrap().as_usize_vec().is_err());
        assert!(parse("[-1]").unwrap().as_usize_vec().is_err());
    }

    #[test]
    fn unicode_passthrough() {
        let v = parse("\"µs — ünïcode\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "µs — ünïcode");
    }
}
