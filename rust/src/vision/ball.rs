//! Ball-candidate extraction (paper §III-A, citing Schwarz et al. 2016):
//! scanline traversal + segmentation, edge points on bright segments,
//! circle fit, candidate patch extraction for CNN verification.
//!
//! This reproduces the *pipeline structure* (an average of ~20 candidates
//! per frame feed the 16×16 CNN); the segmentation itself is a simplified
//! brightness-based variant adequate for the synthetic renderer.

use super::render::extract_patch;
use super::{Detection, Image};

/// A fitted circle candidate.
#[derive(Debug, Clone)]
pub struct BallCandidate {
    pub cy: f32,
    pub cx: f32,
    pub r: f32,
}

/// Parameters of the extractor.
#[derive(Debug, Clone)]
pub struct BallExtractorConfig {
    /// Scanline spacing in rows.
    pub scanline_step: usize,
    /// Brightness threshold separating ball-bright pixels from field.
    pub bright_thresh: f32,
    /// Minimum / maximum plausible radius in pixels.
    pub min_r: f32,
    pub max_r: f32,
}

impl Default for BallExtractorConfig {
    fn default() -> Self {
        BallExtractorConfig { scanline_step: 2, bright_thresh: 0.62, min_r: 2.0, max_r: 12.0 }
    }
}

/// A bright segment on one scanline.
#[derive(Debug, Clone, Copy)]
struct Segment {
    row: usize,
    start: usize,
    end: usize, // inclusive
}

/// Extract ball candidates from a grayscale frame.
pub fn extract_candidates(img: &Image, cfg: &BallExtractorConfig) -> Vec<BallCandidate> {
    let segments = scan_segments(img, cfg);
    let groups = group_segments(&segments);
    let mut candidates = Vec::new();
    for group in groups {
        if let Some(c) = fit_circle(&group) {
            if c.r >= cfg.min_r && c.r <= cfg.max_r {
                candidates.push(c);
            }
        }
    }
    candidates
}

/// Scanline segmentation: bright runs on every `scanline_step`-th row.
fn scan_segments(img: &Image, cfg: &BallExtractorConfig) -> Vec<Segment> {
    let (h, w) = (img.dims()[0], img.dims()[1]);
    let mut segments = Vec::new();
    let mut row = 0;
    while row < h {
        let mut j = 0;
        while j < w {
            if img.at3(row, j, 0) > cfg.bright_thresh {
                let start = j;
                while j < w && img.at3(row, j, 0) > cfg.bright_thresh {
                    j += 1;
                }
                let end = j - 1;
                // discard very long runs (field lines / robots)
                if end - start + 1 <= (2.0 * cfg.max_r) as usize {
                    segments.push(Segment { row, start, end });
                }
            } else {
                j += 1;
            }
        }
        row += cfg.scanline_step;
    }
    segments
}

/// Group vertically-adjacent, horizontally-overlapping segments.
fn group_segments(segments: &[Segment]) -> Vec<Vec<Segment>> {
    let mut groups: Vec<Vec<Segment>> = Vec::new();
    for &seg in segments {
        let mut placed = false;
        for group in groups.iter_mut() {
            let last = *group.last().unwrap();
            let near_rows = seg.row > last.row && seg.row - last.row <= 4;
            let overlaps = seg.start <= last.end + 2 && last.start <= seg.end + 2;
            if near_rows && overlaps {
                group.push(seg);
                placed = true;
                break;
            }
        }
        if !placed {
            groups.push(vec![seg]);
        }
    }
    groups.retain(|g| g.len() >= 2);
    groups
}

/// Fit a circle to a segment group's edge points (left/right run ends):
/// centroid + mean-distance radius — the cheap fit the paper's pipeline
/// uses before CNN verification.
fn fit_circle(group: &[Segment]) -> Option<BallCandidate> {
    let mut pts: Vec<(f32, f32)> = Vec::with_capacity(group.len() * 2);
    for s in group {
        pts.push((s.row as f32, s.start as f32));
        pts.push((s.row as f32, s.end as f32));
    }
    if pts.len() < 4 {
        return None;
    }
    let n = pts.len() as f32;
    let cy = pts.iter().map(|p| p.0).sum::<f32>() / n;
    let cx = pts.iter().map(|p| p.1).sum::<f32>() / n;
    let r = pts.iter().map(|p| ((p.0 - cy).powi(2) + (p.1 - cx).powi(2)).sqrt()).sum::<f32>() / n;
    Some(BallCandidate { cy, cx, r })
}

/// Cut the CNN input patch (16×16, 2× candidate diameter context) for a
/// candidate.
pub fn candidate_patch(img: &Image, cand: &BallCandidate) -> Image {
    let d = (cand.r * 2.0 * 1.6).max(8.0);
    extract_patch(img, cand.cy, cand.cx, d, d, 16, 16)
}

/// Convert an accepted candidate to a detection box.
pub fn to_detection(cand: &BallCandidate, score: f32) -> Detection {
    Detection {
        y: cand.cy - cand.r,
        x: cand.cx - cand.r,
        h: 2.0 * cand.r,
        w: 2.0 * cand.r,
        score,
        class: 0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::XorShift64;
    use crate::vision::render::soccer_frame;

    #[test]
    fn finds_a_planted_ball() {
        let mut rng = XorShift64::new(5);
        let (img, truth) = soccer_frame(60, 80, 1, 0, &mut rng);
        let cands = extract_candidates(&img, &BallExtractorConfig::default());
        assert!(!cands.is_empty(), "no candidates found");
        let gt = &truth.balls[0];
        let (gy, gx) = (gt.y + gt.h / 2.0, gt.x + gt.w / 2.0);
        let hit = cands.iter().any(|c| (c.cy - gy).abs() < 6.0 && (c.cx - gx).abs() < 6.0);
        assert!(hit, "no candidate near ground truth ({gy},{gx}): {cands:?}");
    }

    #[test]
    fn empty_field_yields_few_candidates() {
        let mut rng = XorShift64::new(6);
        let (img, _) = soccer_frame(60, 80, 0, 0, &mut rng);
        let cands = extract_candidates(&img, &BallExtractorConfig::default());
        assert!(cands.len() <= 3, "{} candidates on an empty field", cands.len());
    }

    #[test]
    fn candidate_patch_is_16x16() {
        let mut rng = XorShift64::new(7);
        let (img, _) = soccer_frame(60, 80, 1, 0, &mut rng);
        let cands = extract_candidates(&img, &BallExtractorConfig::default());
        if let Some(c) = cands.first() {
            assert_eq!(candidate_patch(&img, c).dims(), &[16, 16, 1]);
        }
    }

    #[test]
    fn long_runs_are_rejected_as_lines() {
        // a pure horizontal line across the image is not a ball segment
        let mut img = crate::tensor::Tensor::zeros(&[20, 60, 1]);
        for j in 0..60 {
            *img.at3_mut(10, j, 0) = 0.9;
        }
        let cands = extract_candidates(&img, &BallExtractorConfig::default());
        assert!(cands.is_empty(), "{cands:?}");
    }
}
