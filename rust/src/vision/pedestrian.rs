//! Sliding-window pedestrian scan feeding the 18×36 classifier
//! (paper §III-A, Daimler benchmark scenario).

use super::render::extract_patch;
use super::{Detection, Image};

/// Scan configuration.
#[derive(Debug, Clone)]
pub struct ScanConfig {
    /// Window stride in pixels.
    pub stride: usize,
    /// Scales applied to the base 18×36 window.
    pub scales: Vec<f32>,
    /// Classifier probability threshold for a detection.
    pub threshold: f32,
}

impl Default for ScanConfig {
    fn default() -> Self {
        ScanConfig { stride: 6, scales: vec![1.0, 1.5], threshold: 0.5 }
    }
}

/// All candidate windows over a frame (the classifier then scores each).
pub fn windows(img: &Image, cfg: &ScanConfig) -> Vec<(f32, f32, f32, f32)> {
    let (h, w) = (img.dims()[0] as f32, img.dims()[1] as f32);
    let mut out = Vec::new();
    for &scale in &cfg.scales {
        let wh = 36.0 * scale;
        let ww = 18.0 * scale;
        if wh > h || ww > w {
            continue;
        }
        let mut y = 0.0;
        while y + wh <= h {
            let mut x = 0.0;
            while x + ww <= w {
                out.push((y + wh / 2.0, x + ww / 2.0, wh, ww));
                x += cfg.stride as f32 * scale;
            }
            y += cfg.stride as f32 * scale;
        }
    }
    out
}

/// Cut the CNN input patch ([36, 18, 1]) for a window.
pub fn window_patch(img: &Image, win: (f32, f32, f32, f32)) -> Image {
    extract_patch(img, win.0, win.1, win.2, win.3, 36, 18)
}

/// Assemble detections from per-window pedestrian probabilities.
pub fn detections_from_scores(wins: &[(f32, f32, f32, f32)], scores: &[f32], cfg: &ScanConfig) -> Vec<Detection> {
    wins.iter()
        .zip(scores)
        .filter(|(_, &s)| s >= cfg.threshold)
        .map(|(&(cy, cx, wh, ww), &s)| Detection {
            y: cy - wh / 2.0,
            x: cx - ww / 2.0,
            h: wh,
            w: ww,
            score: s,
            class: 0,
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Tensor;

    #[test]
    fn windows_cover_frame() {
        let img = Tensor::zeros(&[72, 90, 1]);
        let wins = windows(&img, &ScanConfig::default());
        assert!(!wins.is_empty());
        // all inside bounds
        for (cy, cx, wh, ww) in &wins {
            assert!(cy - wh / 2.0 >= -0.01 && cy + wh / 2.0 <= 72.01);
            assert!(cx - ww / 2.0 >= -0.01 && cx + ww / 2.0 <= 90.01);
        }
    }

    #[test]
    fn too_small_frame_has_no_windows() {
        let img = Tensor::zeros(&[20, 10, 1]);
        assert!(windows(&img, &ScanConfig::default()).is_empty());
    }

    #[test]
    fn patch_shape_matches_model_input() {
        let img = Tensor::zeros(&[72, 90, 1]);
        let wins = windows(&img, &ScanConfig::default());
        let p = window_patch(&img, wins[0]);
        assert_eq!(p.dims(), &[36, 18, 1]);
    }

    #[test]
    fn score_threshold_filters() {
        let wins = vec![(18.0, 9.0, 36.0, 18.0), (18.0, 30.0, 36.0, 18.0)];
        let dets = detections_from_scores(&wins, &[0.9, 0.2], &ScanConfig::default());
        assert_eq!(dets.len(), 1);
        assert_eq!(dets[0].score, 0.9);
    }
}
