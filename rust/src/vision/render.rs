//! Synthetic scene renderer — deterministic stand-in for the paper's
//! datasets (RoboCup camera logs, Daimler pedestrian corpus).
//!
//! Scenes carry ground truth so the pipelines and the end-to-end examples
//! can report detection quality, and the figure exporter (Figs. 1–3) dumps
//! sample grids from the same generators the Python trainer uses
//! (structurally equivalent implementations; both are seeded).

use super::{Detection, Image};
use crate::tensor::Tensor;
use crate::util::XorShift64;

/// Ground-truth annotation for a rendered scene.
#[derive(Debug, Clone)]
pub struct SceneTruth {
    pub balls: Vec<Detection>,
    pub robots: Vec<Detection>,
}

/// Render a grayscale soccer-field frame of `h`×`w` with `n_balls` balls
/// (bright circles with dark spots) and `n_robots` robot-ish blobs.
pub fn soccer_frame(h: usize, w: usize, n_balls: usize, n_robots: usize, rng: &mut XorShift64) -> (Image, SceneTruth) {
    let mut img = Tensor::zeros(&[h, w, 1]);
    // field: mid-gray with mild vertical gradient + noise
    for i in 0..h {
        for j in 0..w {
            let g = 0.35 + 0.1 * (i as f32 / h as f32) + 0.03 * (rng.next_f32() - 0.5);
            *img.at3_mut(i, j, 0) = g;
        }
    }
    // field lines
    for j in 0..w {
        let line_row = h / 2;
        if line_row < h {
            *img.at3_mut(line_row, j, 0) = 0.8;
        }
    }

    let mut truth = SceneTruth { balls: Vec::new(), robots: Vec::new() };

    for _ in 0..n_robots {
        let rh = (h / 3).max(8);
        let rw = (w / 8).max(4);
        let top = rng.below(h.saturating_sub(rh).max(1));
        let left = rng.below(w.saturating_sub(rw).max(1));
        draw_robot(&mut img, top, left, rh, rw, rng);
        truth.robots.push(Detection { y: top as f32, x: left as f32, h: rh as f32, w: rw as f32, score: 1.0, class: 0 });
    }

    for _ in 0..n_balls {
        let r = 3 + rng.below(((h.min(w)) / 10).max(2));
        let cy = r + rng.below(h.saturating_sub(2 * r).max(1));
        let cx = r + rng.below(w.saturating_sub(2 * r).max(1));
        draw_ball(&mut img, cy, cx, r, rng);
        truth.balls.push(Detection {
            y: (cy - r) as f32,
            x: (cx - r) as f32,
            h: (2 * r) as f32,
            w: (2 * r) as f32,
            score: 1.0,
            class: 0,
        });
    }
    (img, truth)
}

/// Draw a RoboCup-style ball: bright disc with dark pentagon-ish spots.
pub fn draw_ball(img: &mut Image, cy: usize, cx: usize, r: usize, rng: &mut XorShift64) {
    let (h, w) = (img.dims()[0], img.dims()[1]);
    let rf = r as f32;
    // a few dark spot centers on the disc
    let spots: Vec<(f32, f32)> = (0..3)
        .map(|_| {
            let a = rng.next_f32() * std::f32::consts::TAU;
            let d = rng.next_f32() * 0.6 * rf;
            (a.cos() * d, a.sin() * d)
        })
        .collect();
    for i in cy.saturating_sub(r)..(cy + r + 1).min(h) {
        for j in cx.saturating_sub(r)..(cx + r + 1).min(w) {
            let dy = i as f32 - cy as f32;
            let dx = j as f32 - cx as f32;
            let d = (dy * dy + dx * dx).sqrt();
            if d <= rf {
                let mut v = 0.95 - 0.1 * (d / rf);
                for (sy, sx) in &spots {
                    let sd = ((dy - sy).powi(2) + (dx - sx).powi(2)).sqrt();
                    if sd < 0.3 * rf {
                        v = 0.15;
                    }
                }
                *img.at3_mut(i, j, 0) = v;
            }
        }
    }
}

/// Draw a Nao-robot-ish white vertical blob with darker joints.
fn draw_robot(img: &mut Image, top: usize, left: usize, rh: usize, rw: usize, rng: &mut XorShift64) {
    let (h, w) = (img.dims()[0], img.dims()[1]);
    for i in top..(top + rh).min(h) {
        for j in left..(left + rw).min(w) {
            let frac = (i - top) as f32 / rh as f32;
            let body = 0.85 - 0.15 * (frac * 6.0).sin().abs();
            *img.at3_mut(i, j, 0) = body + 0.02 * (rng.next_f32() - 0.5);
        }
    }
}

/// Extract a patch `[ph, pw, c]` centered at (cy, cx), zero-padded at
/// borders, optionally rescaled from a source box of `sh`×`sw` via nearest
/// neighbor (candidates come at many scales; the CNN wants a fixed size).
pub fn extract_patch(img: &Image, cy: f32, cx: f32, sh: f32, sw: f32, ph: usize, pw: usize) -> Image {
    let (h, w, c) = (img.dims()[0], img.dims()[1], img.dims()[2]);
    let mut patch = Tensor::zeros(&[ph, pw, c]);
    for i in 0..ph {
        for j in 0..pw {
            // map patch pixel to source coordinates
            let sy = cy - sh / 2.0 + (i as f32 + 0.5) * sh / ph as f32;
            let sx = cx - sw / 2.0 + (j as f32 + 0.5) * sw / pw as f32;
            if sy >= 0.0 && sx >= 0.0 && (sy as usize) < h && (sx as usize) < w {
                for k in 0..c {
                    *patch.at3_mut(i, j, k) = img.at3(sy as usize, sx as usize, k);
                }
            }
        }
    }
    patch
}

/// A 16×16 ball-candidate patch like the paper's Fig. 1: positive =
/// centered ball; negative = field/line/robot clutter.
pub fn ball_patch(positive: bool, rng: &mut XorShift64) -> Image {
    let mut img = Tensor::zeros(&[16, 16, 1]);
    for v in img.data_mut() {
        *v = 0.3 + 0.15 * rng.next_f32();
    }
    if positive {
        let r = 4 + rng.below(3);
        let cy = 8 + rng.below(3) as isize - 1;
        let cx = 8 + rng.below(3) as isize - 1;
        draw_ball(&mut img, cy as usize, cx as usize, r, rng);
    } else {
        // clutter: random bright streak or blob that is not ball-like
        match rng.below(3) {
            0 => {
                let row = rng.below(16);
                for j in 0..16 {
                    *img.at3_mut(row, j, 0) = 0.8;
                }
            }
            1 => {
                let top = rng.below(8);
                let left = rng.below(8);
                for i in top..(top + 8).min(16) {
                    for j in left..(left + 4).min(16) {
                        *img.at3_mut(i, j, 0) = 0.85;
                    }
                }
            }
            _ => {}
        }
    }
    img
}

/// An 18-wide × 36-tall pedestrian patch like Fig. 2 (HWC [36, 18, 1]).
pub fn pedestrian_patch(positive: bool, rng: &mut XorShift64) -> Image {
    let mut img = Tensor::zeros(&[36, 18, 1]);
    for v in img.data_mut() {
        *v = 0.4 + 0.2 * rng.next_f32();
    }
    if positive {
        // head + torso + legs silhouette, darker than background
        let cx = 9 + rng.below(3) as isize - 1;
        for i in 2..8 {
            for j in -2i32..3 {
                let jj = cx as i32 + j;
                if (0..18).contains(&jj) {
                    *img.at3_mut(i, jj as usize, 0) = 0.12 + 0.05 * rng.next_f32();
                }
            }
        }
        for i in 8..22 {
            for j in -3i32..4 {
                let jj = cx as i32 + j;
                if (0..18).contains(&jj) {
                    *img.at3_mut(i, jj as usize, 0) = 0.15 + 0.05 * rng.next_f32();
                }
            }
        }
        for (leg, span) in [(-2i32, 0i32), (1, 3)] {
            for i in 22..34 {
                for j in leg..span {
                    let jj = cx as i32 + j;
                    if (0..18).contains(&jj) {
                        *img.at3_mut(i, jj as usize, 0) = 0.18 + 0.05 * rng.next_f32();
                    }
                }
            }
        }
    } else if rng.below(2) == 0 {
        // vertical pole distractor
        let col = rng.below(18);
        for i in 0..36 {
            *img.at3_mut(i, col, 0) = 0.2;
        }
    }
    img
}

/// Write a tensor as a PGM (grayscale) image file — figure export format.
pub fn write_pgm(img: &Image, path: &std::path::Path) -> anyhow::Result<()> {
    let (h, w) = (img.dims()[0], img.dims()[1]);
    let mut data = format!("P2\n{w} {h}\n255\n");
    for i in 0..h {
        let row: Vec<String> = (0..w)
            .map(|j| format!("{}", (img.at3(i, j, 0).clamp(0.0, 1.0) * 255.0) as u8))
            .collect();
        data.push_str(&row.join(" "));
        data.push('\n');
    }
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    std::fs::write(path, data)?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn soccer_frame_has_truth() {
        let mut rng = XorShift64::new(1);
        let (img, truth) = soccer_frame(60, 80, 2, 1, &mut rng);
        assert_eq!(img.dims(), &[60, 80, 1]);
        assert_eq!(truth.balls.len(), 2);
        assert_eq!(truth.robots.len(), 1);
        assert!(img.data().iter().all(|v| (0.0..=1.1).contains(v)));
    }

    #[test]
    fn ball_patch_positive_is_brighter_in_center() {
        let mut rng = XorShift64::new(2);
        let pos = ball_patch(true, &mut rng);
        // center pixel should be ball-bright or spot-dark, not background
        let c = pos.at3(8, 8, 0);
        assert!(c > 0.6 || c < 0.25, "center={c}");
    }

    #[test]
    fn patches_are_deterministic_in_seed() {
        let a = ball_patch(true, &mut XorShift64::new(7));
        let b = ball_patch(true, &mut XorShift64::new(7));
        assert_eq!(a, b);
    }

    #[test]
    fn extract_patch_handles_borders() {
        let mut rng = XorShift64::new(3);
        let (img, _) = soccer_frame(30, 40, 0, 0, &mut rng);
        let p = extract_patch(&img, 0.0, 0.0, 16.0, 16.0, 16, 16);
        assert_eq!(p.dims(), &[16, 16, 1]);
        // top-left corner patch has zero-padded area
        assert_eq!(p.at3(0, 0, 0), 0.0);
    }

    #[test]
    fn pgm_write_produces_valid_header() {
        let mut rng = XorShift64::new(4);
        let img = ball_patch(true, &mut rng);
        let path = std::env::temp_dir().join("nncg-test-fig/ball.pgm");
        write_pgm(&img, &path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.starts_with("P2\n16 16\n255\n"));
    }
}
