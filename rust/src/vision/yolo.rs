//! YOLO-v2-style decoding of the robot detector head (paper Table III:
//! final 15×20×20 grid over an 80×60 input; pipeline per Redmon et al.).
//!
//! Channel layout per grid cell (20 channels = 4 anchors × 5 values):
//! `[tx, ty, tw, th, to] × 4` — box offsets, log-scales and objectness.

use super::{nms, Detection};
use crate::tensor::Tensor;
use anyhow::{bail, Result};

/// Decoder configuration.
#[derive(Debug, Clone)]
pub struct YoloConfig {
    /// Input image extent the grid maps back to.
    pub img_h: f32,
    pub img_w: f32,
    /// Anchor box sizes in grid-cell units (w, h).
    pub anchors: Vec<(f32, f32)>,
    pub obj_threshold: f32,
    pub nms_iou: f32,
}

impl Default for YoloConfig {
    fn default() -> Self {
        YoloConfig {
            img_h: 60.0,
            img_w: 80.0,
            // Nao robots are tall boxes; anchors in cell units.
            anchors: vec![(0.8, 2.0), (1.2, 3.0), (1.8, 4.0), (2.5, 5.0)],
            obj_threshold: 0.9,
            nms_iou: 0.45,
        }
    }
}

#[inline]
fn sigmoid(x: f32) -> f32 {
    1.0 / (1.0 + (-x).exp())
}

/// Decode a `[gh, gw, anchors*5]` head tensor into detections.
pub fn decode(head: &Tensor, cfg: &YoloConfig) -> Result<Vec<Detection>> {
    let dims = head.dims();
    if dims.len() != 3 {
        bail!("yolo head must be 3-d, got {:?}", dims);
    }
    let (gh, gw, c) = (dims[0], dims[1], dims[2]);
    let na = cfg.anchors.len();
    if c != na * 5 {
        bail!("head channels {c} != anchors*5 = {}", na * 5);
    }
    let cell_h = cfg.img_h / gh as f32;
    let cell_w = cfg.img_w / gw as f32;

    let mut dets = Vec::new();
    for gy in 0..gh {
        for gx in 0..gw {
            for a in 0..na {
                let base = a * 5;
                let tx = head.at3(gy, gx, base);
                let ty = head.at3(gy, gx, base + 1);
                let tw = head.at3(gy, gx, base + 2);
                let th = head.at3(gy, gx, base + 3);
                let to = head.at3(gy, gx, base + 4);
                let score = sigmoid(to);
                if score < cfg.obj_threshold {
                    continue;
                }
                let (aw, ah) = cfg.anchors[a];
                let cx = (gx as f32 + sigmoid(tx)) * cell_w;
                let cy = (gy as f32 + sigmoid(ty)) * cell_h;
                let bw = aw * tw.clamp(-4.0, 4.0).exp() * cell_w;
                let bh = ah * th.clamp(-4.0, 4.0).exp() * cell_h;
                dets.push(Detection {
                    y: cy - bh / 2.0,
                    x: cx - bw / 2.0,
                    h: bh,
                    w: bw,
                    score,
                    class: 0,
                });
            }
        }
    }
    Ok(nms(dets, cfg.nms_iou))
}

/// Inverse of [`decode`] for one target box — used by tests and by the
/// synthetic trainer's target construction (Python mirrors this).
pub fn encode_target(det: &Detection, cfg: &YoloConfig, gh: usize, gw: usize) -> Result<(usize, usize, usize, [f32; 5])> {
    let cell_h = cfg.img_h / gh as f32;
    let cell_w = cfg.img_w / gw as f32;
    let cy = det.y + det.h / 2.0;
    let cx = det.x + det.w / 2.0;
    let gy = (cy / cell_h) as usize;
    let gx = (cx / cell_w) as usize;
    if gy >= gh || gx >= gw {
        bail!("box center outside grid");
    }
    // best anchor by IoU of (w, h) only
    let (mut best_a, mut best_iou) = (0usize, -1.0f32);
    for (a, &(aw, ah)) in cfg.anchors.iter().enumerate() {
        let (aw, ah) = (aw * cell_w, ah * cell_h);
        let inter = det.w.min(aw) * det.h.min(ah);
        let union = det.w * det.h + aw * ah - inter;
        let iou = inter / union;
        if iou > best_iou {
            best_iou = iou;
            best_a = a;
        }
    }
    let (aw, ah) = cfg.anchors[best_a];
    let fx = cx / cell_w - gx as f32;
    let fy = cy / cell_h - gy as f32;
    let logit = |p: f32| (p.clamp(1e-4, 1.0 - 1e-4) / (1.0 - p.clamp(1e-4, 1.0 - 1e-4))).ln();
    let vals = [
        logit(fx),
        logit(fy),
        (det.w / (aw * cell_w)).ln(),
        (det.h / (ah * cell_h)).ln(),
        logit(0.95), // objectness target
    ];
    Ok((gy, gx, best_a, vals))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_head_decodes_to_nothing() {
        // all-zero logits → sigmoid(0)=0.5 objectness; threshold 0.6 rejects
        let head = Tensor::zeros(&[15, 20, 20]);
        let cfg = YoloConfig { obj_threshold: 0.6, ..Default::default() };
        assert!(decode(&head, &cfg).unwrap().is_empty());
    }

    #[test]
    fn encode_decode_round_trip() {
        let cfg = YoloConfig::default();
        let gt = Detection { y: 10.0, x: 30.0, h: 24.0, w: 10.0, score: 1.0, class: 0 };
        let (gy, gx, a, vals) = encode_target(&gt, &cfg, 15, 20).unwrap();
        let mut head = Tensor::zeros(&[15, 20, 20]);
        // strongly negative objectness everywhere else
        for cell in head.data_mut().iter_mut() {
            *cell = 0.0;
        }
        for gyy in 0..15 {
            for gxx in 0..20 {
                for aa in 0..4 {
                    *head.at3_mut(gyy, gxx, aa * 5 + 4) = -10.0;
                }
            }
        }
        for (i, v) in vals.iter().enumerate() {
            *head.at3_mut(gy, gx, a * 5 + i) = *v;
        }
        let dets = decode(&head, &cfg).unwrap();
        assert_eq!(dets.len(), 1);
        let d = &dets[0];
        assert!((d.x - gt.x).abs() < 1.5, "{d:?}");
        assert!((d.y - gt.y).abs() < 1.5, "{d:?}");
        assert!((d.w - gt.w).abs() / gt.w < 0.15);
        assert!((d.h - gt.h).abs() / gt.h < 0.15);
    }

    #[test]
    fn rejects_wrong_channel_count() {
        let head = Tensor::zeros(&[15, 20, 19]);
        assert!(decode(&head, &YoloConfig::default()).is_err());
    }

    #[test]
    fn nms_is_applied() {
        let cfg = YoloConfig { obj_threshold: 0.4, ..Default::default() };
        // objectness 0.5 everywhere → a flood of 15*20*4 = 1200 boxes; the
        // four same-cell anchors overlap heavily, so NMS must thin the set
        // substantially below the raw count.
        let head = Tensor::zeros(&[15, 20, 20]);
        let dets = decode(&head, &cfg).unwrap();
        assert!(!dets.is_empty());
        assert!(dets.len() < 15 * 20 * 4 * 3 / 4, "nms did not thin: {}", dets.len());
    }
}
