//! Robotics vision pipelines from the paper's evaluation scenarios
//! (§III-A) plus the synthetic scene renderer that stands in for the
//! paper's datasets (no RoboCup logs or Daimler corpus offline).
//!
//! * [`ball`] — the R-CNN-style candidate pipeline: scanline segmentation
//!   over a camera frame, edge-point extraction, circle fitting; each
//!   candidate patch (16×16) goes to the ball classifier CNN. The paper
//!   reports ~20 candidates/frame.
//! * [`pedestrian`] — sliding-window scan feeding 18×36 patches to the
//!   pedestrian classifier.
//! * [`yolo`] — decoding of the robot detector's 15×20×20 output grid into
//!   boxes (YOLO-v2-style objectness + box regression).
//! * [`render`] — deterministic synthetic soccer-field / street scenes with
//!   ground-truth annotations.

pub mod ball;
pub mod pedestrian;
pub mod render;
pub mod yolo;

/// A grayscale or RGB image in HWC f32, values in [0, 1].
pub type Image = crate::tensor::Tensor;

/// An axis-aligned detection with a confidence score.
#[derive(Debug, Clone, PartialEq)]
pub struct Detection {
    /// Top-left row.
    pub y: f32,
    /// Top-left column.
    pub x: f32,
    pub h: f32,
    pub w: f32,
    pub score: f32,
    /// Class id (pipeline-specific).
    pub class: usize,
}

impl Detection {
    /// Intersection-over-union with another box.
    pub fn iou(&self, other: &Detection) -> f32 {
        let x1 = self.x.max(other.x);
        let y1 = self.y.max(other.y);
        let x2 = (self.x + self.w).min(other.x + other.w);
        let y2 = (self.y + self.h).min(other.y + other.h);
        let inter = (x2 - x1).max(0.0) * (y2 - y1).max(0.0);
        let union = self.w * self.h + other.w * other.h - inter;
        if union <= 0.0 {
            0.0
        } else {
            inter / union
        }
    }
}

/// Greedy non-maximum suppression.
pub fn nms(mut dets: Vec<Detection>, iou_thresh: f32) -> Vec<Detection> {
    dets.sort_by(|a, b| b.score.partial_cmp(&a.score).unwrap_or(std::cmp::Ordering::Equal));
    let mut keep: Vec<Detection> = Vec::new();
    for d in dets {
        if keep.iter().all(|k| k.iou(&d) < iou_thresh) {
            keep.push(d);
        }
    }
    keep
}

#[cfg(test)]
mod tests {
    use super::*;

    fn det(x: f32, y: f32, s: f32) -> Detection {
        Detection { x, y, w: 10.0, h: 10.0, score: s, class: 0 }
    }

    #[test]
    fn iou_identical_is_one() {
        let a = det(0.0, 0.0, 1.0);
        assert!((a.iou(&a) - 1.0).abs() < 1e-6);
    }

    #[test]
    fn iou_disjoint_is_zero() {
        assert_eq!(det(0.0, 0.0, 1.0).iou(&det(100.0, 100.0, 1.0)), 0.0);
    }

    #[test]
    fn nms_suppresses_overlaps_keeps_best() {
        let dets = vec![det(0.0, 0.0, 0.5), det(1.0, 1.0, 0.9), det(50.0, 50.0, 0.3)];
        let kept = nms(dets, 0.3);
        assert_eq!(kept.len(), 2);
        assert_eq!(kept[0].score, 0.9);
        assert!(kept.iter().any(|d| d.x == 50.0));
    }
}
