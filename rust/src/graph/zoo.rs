//! The paper's three evaluation CNNs (Tables I–III), plus small variants
//! used by tests.
//!
//! Architectures are transcribed literally from the paper:
//!
//! * **Table I — ball classifier**: 16×16×1 → Conv(8,5×5,s2,same) → ReLU →
//!   MaxPool(2×2,s2) → Conv(12,3×3,valid) → ReLU → Conv(2,2×2,valid) →
//!   Soft-Max. Binary ball/no-ball on RoboCup candidate patches.
//! * **Table II — pedestrian classifier**: 18×36×1, three conv blocks with
//!   max-pooling and leaky ReLU (α=0.1), Dropout(0.3), final valid
//!   Conv(2,4×2) + Soft-Max. (Daimler pedestrian benchmark in the paper.)
//! * **Table III — robot detector**: 80×60×3 YOLO-style backbone, five conv
//!   blocks with BatchNorm + leaky ReLU and two max-pools; output is a
//!   20-channel detection grid (YOLO v2-ish head: 4 box + 1 objectness
//!   per anchor, decoded by `vision::yolo`).
//!
//! The paper writes inputs as `# × WxH` (e.g. `1 | 16x16`, `3 | 80x60`); our
//! shapes are `[h, w, c]`.

use super::{Activation, Layer, Model, Padding};

/// Table I: ball classifier (16×16 grayscale patch → {ball, no-ball}).
pub fn ball_classifier() -> Model {
    Model::new("ball", &[16, 16, 1])
        .push(Layer::conv2d(8, 5, 5, (2, 2), Padding::Same, Activation::None))
        .push(Layer::relu())
        .push(Layer::maxpool(2, 2))
        .push(Layer::conv2d(12, 3, 3, (1, 1), Padding::Valid, Activation::None))
        .push(Layer::relu())
        .push(Layer::conv2d(2, 2, 2, (1, 1), Padding::Valid, Activation::None))
        .push(Layer::softmax())
}

/// Table II: pedestrian classifier (18×36 grayscale → {pedestrian, none}).
///
/// Paper's input row reads `1 | 18x36` (w×h); our HWC shape is [36, 18, 1].
pub fn pedestrian_classifier() -> Model {
    Model::new("pedestrian", &[36, 18, 1])
        .push(Layer::conv2d(12, 3, 3, (1, 1), Padding::Same, Activation::None))
        .push(Layer::relu())
        .push(Layer::maxpool(2, 2))
        .push(Layer::conv2d(32, 3, 3, (1, 1), Padding::Same, Activation::None))
        .push(Layer::leaky_relu(0.1))
        .push(Layer::maxpool(2, 2))
        .push(Layer::conv2d(64, 3, 3, (1, 1), Padding::Same, Activation::None))
        .push(Layer::leaky_relu(0.1))
        .push(Layer::maxpool(2, 2))
        .push(Layer::Dropout { rate: 0.3 })
        .push(Layer::conv2d(2, 4, 2, (1, 1), Padding::Valid, Activation::None))
        .push(Layer::softmax())
}

/// Table III: robot detector backbone (80×60 RGB → 20×15×20 YOLO grid).
///
/// Paper's input row reads `3 | 80x60` (w×h); our HWC shape is [60, 80, 3].
pub fn robot_detector() -> Model {
    Model::new("robot", &[60, 80, 3])
        .push(Layer::conv2d(8, 3, 3, (1, 1), Padding::Same, Activation::None))
        .push(Layer::batchnorm(8))
        .push(Layer::leaky_relu(0.1))
        .push(Layer::maxpool(2, 2))
        .push(Layer::conv2d(12, 3, 3, (1, 1), Padding::Same, Activation::None))
        .push(Layer::batchnorm(12))
        .push(Layer::leaky_relu(0.1))
        .push(Layer::conv2d(8, 3, 3, (1, 1), Padding::Same, Activation::None))
        .push(Layer::batchnorm(8))
        .push(Layer::leaky_relu(0.1))
        .push(Layer::maxpool(2, 2))
        .push(Layer::conv2d(16, 3, 3, (1, 1), Padding::Same, Activation::None))
        .push(Layer::batchnorm(16))
        .push(Layer::leaky_relu(0.1))
        .push(Layer::conv2d(20, 3, 3, (1, 1), Padding::Same, Activation::None))
        .push(Layer::batchnorm(20))
        .push(Layer::leaky_relu(0.1))
}

/// A MobileNet-style block stack (not in the paper's evaluation; exercises
/// the future-work layer types: depthwise separable convs + avg-pool head).
/// Shaped like a scaled-down MobileNetV2 stem for the paper's size
/// anecdote ("a MobileNet V2 leads to an 78 MB C code file").
pub fn mobilenet_mini() -> Model {
    let mut m = Model::new("mobilenet_mini", &[32, 32, 3])
        // stem
        .push(Layer::conv2d(8, 3, 3, (2, 2), Padding::Same, Activation::None))
        .push(Layer::batchnorm(8))
        .push(Layer::relu());
    // three depthwise-separable blocks
    let mut cur_c = 8usize;
    for c_out in [16usize, 24, 32] {
        m = m
            .push(Layer::depthwise(3, 3, (1, 1), Padding::Same, Activation::None))
            .push(Layer::batchnorm(cur_c))
            .push(Layer::relu())
            .push(Layer::conv2d(c_out, 1, 1, (1, 1), Padding::Valid, Activation::None))
            .push(Layer::batchnorm(c_out))
            .push(Layer::relu())
            .push(Layer::maxpool(2, 2));
        cur_c = c_out;
    }
    // head: global average pool + 1x1 classifier
    let s = m.output_shape().unwrap();
    m.push(Layer::avgpool(s.h(), 1))
        .push(Layer::conv2d(4, 1, 1, (1, 1), Padding::Valid, Activation::None))
        .push(Layer::softmax())
}

/// Look a model up by name (CLI surface).
pub fn by_name(name: &str) -> Option<Model> {
    match name {
        "ball" => Some(ball_classifier()),
        "pedestrian" => Some(pedestrian_classifier()),
        "robot" => Some(robot_detector()),
        "tiny" => Some(tiny_test_net()),
        "mobilenet_mini" => Some(mobilenet_mini()),
        _ => None,
    }
}

/// Names of the paper's three models, in table order.
pub const PAPER_MODELS: [&str; 3] = ["ball", "pedestrian", "robot"];

/// A minimal net used by fast unit tests (not in the paper).
pub fn tiny_test_net() -> Model {
    Model::new("tiny", &[8, 8, 1])
        .push(Layer::conv2d(4, 3, 3, (1, 1), Padding::Same, Activation::None))
        .push(Layer::relu())
        .push(Layer::maxpool(2, 2))
        .push(Layer::conv2d(2, 3, 3, (1, 1), Padding::Valid, Activation::None))
        .push(Layer::softmax())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ball_shapes_match_paper() {
        let m = ball_classifier().with_random_weights(1);
        let shapes = m.infer_shapes().unwrap();
        // conv 5x5 s2 same on 16x16 → 8x8x8; pool → 4x4x8;
        // conv 3x3 valid → 2x2x12; conv 2x2 valid → 1x1x2.
        assert_eq!(shapes.last().unwrap().dims(), &[1, 1, 2]);
        assert_eq!(shapes[1].dims(), &[8, 8, 8]);
        assert_eq!(shapes[3].dims(), &[4, 4, 8]);
        assert_eq!(shapes[5].dims(), &[2, 2, 12]);
    }

    #[test]
    fn pedestrian_shapes_match_paper() {
        let m = pedestrian_classifier().with_random_weights(2);
        let shapes = m.infer_shapes().unwrap();
        // 36x18 → pool 18x9 → pool 9x4 → pool 4x2 → conv 4x2 valid → 1x1x2
        assert_eq!(shapes.last().unwrap().dims(), &[1, 1, 2]);
        m.validate().unwrap();
    }

    #[test]
    fn robot_shapes_match_paper() {
        let m = robot_detector().with_random_weights(3);
        let shapes = m.infer_shapes().unwrap();
        // two 2x2 pools: 60x80 → 30x40 → 15x20; final conv 20 channels
        assert_eq!(shapes.last().unwrap().dims(), &[15, 20, 20]);
        m.validate().unwrap();
    }

    #[test]
    fn zoo_lookup() {
        for name in PAPER_MODELS {
            assert!(by_name(name).is_some(), "{name}");
        }
        assert!(by_name("mobilenetv2").is_none());
    }

    #[test]
    fn paper_models_are_simd_friendly_in_the_main_trunk() {
        // Paper §II-B.1: "the number of filters in convolutional layers
        // should be a multiple of 4" — holds for all trunk convs (the final
        // 2-class head is handled by the generic path).
        let m = robot_detector().with_random_weights(4);
        assert!(m.simd_friendly(4));
    }

    #[test]
    fn param_counts_are_paper_scale() {
        // Sanity: these are "small CNNs" — between 1e2 and 1e5 params.
        for name in PAPER_MODELS {
            let m = by_name(name).unwrap().with_random_weights(5);
            let p = m.num_params();
            assert!(p > 100 && p < 100_000, "{name}: {p}");
        }
    }
}
