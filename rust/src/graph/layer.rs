//! Layer definitions and per-layer shape/weight logic.

use crate::tensor::{Shape, Tensor};
use crate::util::XorShift64;
use anyhow::{bail, Result};

/// Padding mode, Keras semantics (the paper generates from Keras models).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Padding {
    /// Output spatial size = ceil(in / stride); zero-pad as needed (Eq. 1).
    Same,
    /// No padding: out = floor((in - k) / stride) + 1.
    Valid,
}

impl Padding {
    pub fn name(&self) -> &'static str {
        match self {
            Padding::Same => "same",
            Padding::Valid => "valid",
        }
    }

    /// (out_size, pad_begin) for one spatial dim.
    pub fn resolve(&self, input: usize, kernel: usize, stride: usize) -> Result<(usize, usize)> {
        match self {
            Padding::Same => {
                let out = (input + stride - 1) / stride;
                let total = ((out - 1) * stride + kernel).saturating_sub(input);
                Ok((out, total / 2))
            }
            Padding::Valid => {
                if kernel > input {
                    bail!("kernel {kernel} larger than input {input} with valid padding");
                }
                Ok(((input - kernel) / stride + 1, 0))
            }
        }
    }
}

/// Activation function, either fused into a conv or standalone.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Activation {
    None,
    /// max(x, 0) — paper Eq. 4.
    Relu,
    /// x if x > 0 else alpha * x — paper Eq. 5.
    LeakyRelu(f32),
    /// Channel-wise softmax over the flattened output.
    Softmax,
}

impl Activation {
    pub fn name(&self) -> &'static str {
        match self {
            Activation::None => "none",
            Activation::Relu => "ReLU",
            Activation::LeakyRelu(_) => "Leaky-ReLU",
            Activation::Softmax => "Soft-Max",
        }
    }

    /// Apply to a scalar (softmax is handled at the tensor level).
    #[inline]
    pub fn apply(&self, x: f32) -> f32 {
        match self {
            Activation::None => x,
            Activation::Relu => x.max(0.0),
            Activation::LeakyRelu(a) => {
                if x > 0.0 {
                    x
                } else {
                    a * x
                }
            }
            Activation::Softmax => x, // normalized later over the channel dim
        }
    }
}

/// One layer of the sequential CNN IR.
#[derive(Debug, Clone)]
pub enum Layer {
    /// 2-d convolution, HWIO weights `[h_k, w_k, c_in, c_out]` + bias
    /// `[c_out]`, with an optionally fused activation (paper fuses BN and
    /// activation into the conv loop; the fusion pass produces this form).
    Conv2D {
        weights: Tensor,
        bias: Tensor,
        stride: (usize, usize),
        padding: Padding,
        activation: Activation,
    },
    /// Max-pooling over `pool` windows with `stride` (paper Eq. 3).
    MaxPool2D { pool: (usize, usize), stride: (usize, usize) },
    /// Average pooling (paper future work: "more layer types to support
    /// modern widely known CNN structures" — MobileNet heads use it).
    AvgPool2D { pool: (usize, usize), stride: (usize, usize) },
    /// Depthwise convolution (multiplier 1): weights `[h_k, w_k, c]`,
    /// bias `[c]`. The MobileNet building block the paper discusses.
    DepthwiseConv2D {
        weights: Tensor,
        bias: Tensor,
        stride: (usize, usize),
        padding: Padding,
        activation: Activation,
    },
    /// Standalone activation layer (the zoo mirrors the paper's table rows;
    /// the fusion pass folds these into the preceding conv).
    Activation(Activation),
    /// Batch normalization with per-channel learned affine + running stats
    /// (paper Eq. 6); folded into the preceding conv by `passes::fold_bn`.
    BatchNorm {
        gamma: Tensor,
        beta: Tensor,
        mean: Tensor,
        variance: Tensor,
        epsilon: f32,
    },
    /// Inference no-op (paper Table II lists Dropout 0.3); elided by passes.
    Dropout { rate: f32 },
    /// Reshape HWC → flat vector.
    Flatten,
    /// Fully connected: weights `[in, out]`, bias `[out]`.
    Dense { weights: Tensor, bias: Tensor, activation: Activation },
}

impl Layer {
    /// Conv constructor with placeholder (empty) weights — call
    /// `Model::with_random_weights` or load real weights before use.
    pub fn conv2d(c_out: usize, h_k: usize, w_k: usize, stride: (usize, usize), padding: Padding, activation: Activation) -> Layer {
        Layer::Conv2D {
            // c_in unknown until shape inference; encode the intent in dims
            // [h_k, w_k, 0, c_out] and fix up in randomize/load.
            weights: Tensor::zeros(&[h_k, w_k, 0, c_out]),
            bias: Tensor::zeros(&[c_out]),
            stride,
            padding,
            activation,
        }
    }

    pub fn maxpool(size: usize, stride: usize) -> Layer {
        Layer::MaxPool2D { pool: (size, size), stride: (stride, stride) }
    }

    pub fn avgpool(size: usize, stride: usize) -> Layer {
        Layer::AvgPool2D { pool: (size, size), stride: (stride, stride) }
    }

    /// Depthwise conv constructor with placeholder weights (channel count
    /// resolved against the input shape like `conv2d`).
    pub fn depthwise(h_k: usize, w_k: usize, stride: (usize, usize), padding: Padding, activation: Activation) -> Layer {
        Layer::DepthwiseConv2D {
            weights: Tensor::zeros(&[h_k, w_k, 0]),
            bias: Tensor::zeros(&[0]),
            stride,
            padding,
            activation,
        }
    }

    pub fn relu() -> Layer {
        Layer::Activation(Activation::Relu)
    }

    pub fn leaky_relu(alpha: f32) -> Layer {
        Layer::Activation(Activation::LeakyRelu(alpha))
    }

    pub fn softmax() -> Layer {
        Layer::Activation(Activation::Softmax)
    }

    pub fn batchnorm(channels: usize) -> Layer {
        Layer::BatchNorm {
            gamma: Tensor::from_vec(&[channels], vec![1.0; channels]).unwrap(),
            beta: Tensor::zeros(&[channels]),
            mean: Tensor::zeros(&[channels]),
            variance: Tensor::from_vec(&[channels], vec![1.0; channels]).unwrap(),
            epsilon: 1e-3,
        }
    }

    pub fn dense(out: usize, activation: Activation) -> Layer {
        Layer::Dense { weights: Tensor::zeros(&[0, out]), bias: Tensor::zeros(&[out]), activation }
    }

    pub fn kind_name(&self) -> &'static str {
        match self {
            Layer::Conv2D { .. } => "Conv",
            Layer::MaxPool2D { .. } => "Max-Pool",
            Layer::AvgPool2D { .. } => "Avg-Pool",
            Layer::DepthwiseConv2D { .. } => "DW-Conv",
            Layer::Activation(a) => a.name(),
            Layer::BatchNorm { .. } => "Batch Norm.",
            Layer::Dropout { .. } => "Dropout",
            Layer::Flatten => "Flatten",
            Layer::Dense { .. } => "Dense",
        }
    }

    /// Output shape given the input shape.
    pub fn output_shape(&self, input: &Shape) -> Result<Shape> {
        match self {
            Layer::Conv2D { weights, stride, padding, .. } => {
                let d = weights.dims();
                let (h_k, w_k, c_out) = (d[0], d[1], d[3]);
                if input.rank() != 3 {
                    bail!("conv input must be HWC, got {input}");
                }
                let (oh, _) = padding.resolve(input.h(), h_k, stride.0)?;
                let (ow, _) = padding.resolve(input.w(), w_k, stride.1)?;
                if oh == 0 || ow == 0 {
                    bail!("conv produces empty output from {input}");
                }
                Ok(Shape::new(&[oh, ow, c_out]))
            }
            Layer::MaxPool2D { pool, stride } | Layer::AvgPool2D { pool, stride } => {
                if input.rank() != 3 {
                    bail!("pool input must be HWC, got {input}");
                }
                if pool.0 > input.h() || pool.1 > input.w() {
                    bail!("pool window {pool:?} larger than input {input}");
                }
                let oh = (input.h() - pool.0) / stride.0 + 1;
                let ow = (input.w() - pool.1) / stride.1 + 1;
                Ok(Shape::new(&[oh, ow, input.c()]))
            }
            Layer::DepthwiseConv2D { weights, stride, padding, .. } => {
                let d = weights.dims();
                if input.rank() != 3 {
                    bail!("depthwise input must be HWC, got {input}");
                }
                let (oh, _) = padding.resolve(input.h(), d[0], stride.0)?;
                let (ow, _) = padding.resolve(input.w(), d[1], stride.1)?;
                Ok(Shape::new(&[oh, ow, input.c()]))
            }
            Layer::Activation(_) | Layer::BatchNorm { .. } | Layer::Dropout { .. } => Ok(input.clone()),
            Layer::Flatten => Ok(Shape::new(&[input.numel()])),
            Layer::Dense { weights, .. } => {
                let out = weights.dims()[1];
                Ok(Shape::new(&[out]))
            }
        }
    }

    /// Check weight tensors are consistent with the incoming shape.
    pub fn validate_weights(&self, input: &Shape) -> Result<()> {
        match self {
            Layer::Conv2D { weights, bias, .. } => {
                let d = weights.dims();
                if d.len() != 4 {
                    bail!("conv weights must be 4-d HWIO, got {:?}", d);
                }
                if d[2] != input.c() {
                    bail!("conv expects c_in={}, weights have {}", input.c(), d[2]);
                }
                if bias.dims() != [d[3]] {
                    bail!("conv bias shape {:?} != [c_out={}]", bias.dims(), d[3]);
                }
                if weights.numel() == 0 {
                    bail!("conv weights are empty (placeholder not initialized)");
                }
                Ok(())
            }
            Layer::BatchNorm { gamma, beta, mean, variance, .. } => {
                let c = input.c();
                for (name, t) in [("gamma", gamma), ("beta", beta), ("mean", mean), ("variance", variance)] {
                    if t.dims() != [c] {
                        bail!("batchnorm {name} shape {:?} != [{c}]", t.dims());
                    }
                }
                Ok(())
            }
            Layer::DepthwiseConv2D { weights, bias, .. } => {
                let d = weights.dims();
                if d.len() != 3 {
                    bail!("depthwise weights must be 3-d [hk, wk, c], got {:?}", d);
                }
                if d[2] != input.c() {
                    bail!("depthwise expects c={}, weights have {}", input.c(), d[2]);
                }
                if bias.dims() != [d[2]] {
                    bail!("depthwise bias shape {:?} != [{}]", bias.dims(), d[2]);
                }
                if weights.numel() == 0 {
                    bail!("depthwise weights are empty");
                }
                Ok(())
            }
            Layer::Dense { weights, bias, .. } => {
                let d = weights.dims();
                if d.len() != 2 {
                    bail!("dense weights must be 2-d, got {:?}", d);
                }
                if d[0] != input.numel() {
                    bail!("dense expects in={}, weights have {}", input.numel(), d[0]);
                }
                if bias.dims() != [d[1]] {
                    bail!("dense bias shape {:?} != [{}]", bias.dims(), d[1]);
                }
                if weights.numel() == 0 {
                    bail!("dense weights are empty");
                }
                Ok(())
            }
            _ => Ok(()),
        }
    }

    pub fn num_params(&self) -> usize {
        match self {
            Layer::Conv2D { weights, bias, .. } => weights.numel() + bias.numel(),
            Layer::DepthwiseConv2D { weights, bias, .. } => weights.numel() + bias.numel(),
            Layer::BatchNorm { gamma, beta, mean, variance, .. } => {
                gamma.numel() + beta.numel() + mean.numel() + variance.numel()
            }
            Layer::Dense { weights, bias, .. } => weights.numel() + bias.numel(),
            _ => 0,
        }
    }

    /// MAC count for this layer given its input shape.
    pub fn macs(&self, input: &Shape) -> Result<u64> {
        Ok(match self {
            Layer::Conv2D { weights, .. } => {
                let out = self.output_shape(input)?;
                let d = weights.dims();
                (out.h() * out.w() * d[3] * d[0] * d[1] * d[2]) as u64
            }
            Layer::DepthwiseConv2D { weights, .. } => {
                let out = self.output_shape(input)?;
                let d = weights.dims();
                (out.h() * out.w() * d[2] * d[0] * d[1]) as u64
            }
            Layer::Dense { weights, .. } => weights.numel() as u64,
            _ => 0,
        })
    }

    /// Fill placeholder weights with Glorot noise; resolves the deferred
    /// `c_in` of conv/dense placeholders. Requires being called in model
    /// order (the `Model::with_random_weights` driver does this).
    pub fn randomize_weights(&mut self, rng: &mut XorShift64) {
        // c_in resolution happens via a shape-inference pass in Model; here
        // we only know local dims, so Model passes shapes through the
        // `resolve_placeholder` call below. For convenience, this method is
        // only invoked through Model::with_random_weights which first calls
        // resolve. (Kept separate so loading real weights shares the code.)
        match self {
            Layer::Conv2D { weights, bias, .. } => {
                let d = weights.dims().to_vec();
                *weights = Tensor::glorot(&d, rng);
                let b = bias.numel();
                *bias = Tensor::rand(&[b], -0.05, 0.05, rng);
            }
            Layer::BatchNorm { gamma, beta, mean, variance, .. } => {
                let c = gamma.numel();
                *gamma = Tensor::rand(&[c], 0.5, 1.5, rng);
                *beta = Tensor::rand(&[c], -0.2, 0.2, rng);
                *mean = Tensor::rand(&[c], -0.5, 0.5, rng);
                *variance = Tensor::rand(&[c], 0.25, 1.0, rng);
            }
            Layer::DepthwiseConv2D { weights, bias, .. } => {
                let d = weights.dims().to_vec();
                *weights = Tensor::rand(&d, -0.5, 0.5, rng);
                let b = bias.numel();
                *bias = Tensor::rand(&[b], -0.05, 0.05, rng);
            }
            Layer::Dense { weights, bias, .. } => {
                let d = weights.dims().to_vec();
                *weights = Tensor::glorot(&d, rng);
                let b = bias.numel();
                *bias = Tensor::rand(&[b], -0.05, 0.05, rng);
            }
            _ => {}
        }
    }

    /// Resolve a deferred `c_in`/`in` placeholder dimension now that the
    /// input shape is known.
    pub fn resolve_placeholder(&mut self, input: &Shape) {
        match self {
            Layer::Conv2D { weights, .. } => {
                let d = weights.dims().to_vec();
                if d[2] == 0 {
                    *weights = Tensor::zeros(&[d[0], d[1], input.c(), d[3]]);
                }
            }
            Layer::DepthwiseConv2D { weights, bias, .. } => {
                let d = weights.dims().to_vec();
                if d[2] == 0 {
                    *weights = Tensor::zeros(&[d[0], d[1], input.c()]);
                    *bias = Tensor::zeros(&[input.c()]);
                }
            }
            Layer::Dense { weights, .. } => {
                let d = weights.dims().to_vec();
                if d[0] == 0 {
                    *weights = Tensor::zeros(&[input.numel(), d[1]]);
                }
            }
            _ => {}
        }
    }

    /// One row of the paper-style architecture table.
    pub fn describe_row(&self, out: &Shape) -> String {
        match self {
            Layer::Conv2D { weights, stride, padding, activation, .. } => {
                let d = weights.dims();
                let mut row = format!(
                    "{:<14} {:>5} {:>9} {:>8} {:>8}   {}",
                    "Conv",
                    d[3],
                    format!("{}x{}", d[0], d[1]),
                    format!("{}x{}", stride.0, stride.1),
                    padding.name(),
                    out
                );
                if *activation != Activation::None {
                    row.push_str(&format!("  (+{})", activation.name()));
                }
                row
            }
            Layer::DepthwiseConv2D { weights, stride, padding, activation, .. } => {
                let d = weights.dims();
                let mut row = format!(
                    "{:<14} {:>5} {:>9} {:>8} {:>8}   {}",
                    "DW-Conv",
                    d[2],
                    format!("{}x{}", d[0], d[1]),
                    format!("{}x{}", stride.0, stride.1),
                    padding.name(),
                    out
                );
                if *activation != Activation::None {
                    row.push_str(&format!("  (+{})", activation.name()));
                }
                row
            }
            Layer::AvgPool2D { pool, stride } => format!(
                "{:<14} {:>5} {:>9} {:>8} {:>8}   {}",
                "Avg-Pool",
                "",
                format!("{}x{}", pool.0, pool.1),
                format!("{}x{}", stride.0, stride.1),
                "",
                out
            ),
            Layer::MaxPool2D { pool, stride } => format!(
                "{:<14} {:>5} {:>9} {:>8} {:>8}   {}",
                "Max-Pool",
                "",
                format!("{}x{}", pool.0, pool.1),
                format!("{}x{}", stride.0, stride.1),
                "",
                out
            ),
            Layer::Activation(a) => match a {
                Activation::LeakyRelu(alpha) => {
                    format!("{:<14} {:>5} {:>9} {:>8} {:>8}   {}", a.name(), "", format!("a={alpha}"), "", "", out)
                }
                _ => format!("{:<14} {:>5} {:>9} {:>8} {:>8}   {}", a.name(), "", "", "", "", out),
            },
            Layer::BatchNorm { .. } => format!("{:<14} {:>5} {:>9} {:>8} {:>8}   {}", "Batch Norm.", "", "", "", "", out),
            Layer::Dropout { rate } => {
                format!("{:<14} {:>5} {:>9} {:>8} {:>8}   {}", "Dropout", "", format!("{rate}"), "", "", out)
            }
            Layer::Flatten => format!("{:<14} {:>5} {:>9} {:>8} {:>8}   {}", "Flatten", "", "", "", "", out),
            Layer::Dense { weights, activation, .. } => {
                let mut row = format!(
                    "{:<14} {:>5} {:>9} {:>8} {:>8}   {}",
                    "Dense",
                    weights.dims()[1],
                    "",
                    "",
                    "",
                    out
                );
                if *activation != Activation::None {
                    row.push_str(&format!("  (+{})", activation.name()));
                }
                row
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn same_padding_keras_semantics() {
        // 16x16 input, 5x5 kernel, stride 2, same → ceil(16/2)=8, pad=(7*2+5-16)/2=1
        let (out, pad) = Padding::Same.resolve(16, 5, 2).unwrap();
        assert_eq!((out, pad), (8, 1));
        // stride 1 same keeps size, pad=(k-1)/2
        let (out, pad) = Padding::Same.resolve(18, 3, 1).unwrap();
        assert_eq!((out, pad), (18, 1));
    }

    #[test]
    fn valid_padding() {
        let (out, pad) = Padding::Valid.resolve(6, 3, 1).unwrap();
        assert_eq!((out, pad), (4, 0));
        let (out, _) = Padding::Valid.resolve(7, 2, 2).unwrap();
        assert_eq!(out, 3);
        assert!(Padding::Valid.resolve(2, 3, 1).is_err());
    }

    #[test]
    fn activation_scalars() {
        assert_eq!(Activation::Relu.apply(-1.0), 0.0);
        assert_eq!(Activation::Relu.apply(2.0), 2.0);
        assert_eq!(Activation::LeakyRelu(0.1).apply(-2.0), -0.2);
        assert_eq!(Activation::LeakyRelu(0.1).apply(3.0), 3.0);
        assert_eq!(Activation::None.apply(-5.0), -5.0);
    }

    #[test]
    fn maxpool_shape() {
        let l = Layer::maxpool(2, 2);
        let s = l.output_shape(&Shape::new(&[9, 18, 12])).unwrap();
        // Keras valid pooling: floor((9-2)/2)+1 = 4
        assert_eq!(s.dims(), &[4, 9, 12]);
    }

    #[test]
    fn conv_macs() {
        let mut l = Layer::conv2d(8, 5, 5, (2, 2), Padding::Same, Activation::None);
        l.resolve_placeholder(&Shape::new(&[16, 16, 1]));
        // out 8x8x8, per-output 5*5*1 macs
        assert_eq!(l.macs(&Shape::new(&[16, 16, 1])).unwrap(), 8 * 8 * 8 * 25);
    }

    #[test]
    fn batchnorm_validation() {
        let l = Layer::batchnorm(8);
        assert!(l.validate_weights(&Shape::new(&[4, 4, 8])).is_ok());
        assert!(l.validate_weights(&Shape::new(&[4, 4, 7])).is_err());
    }

    #[test]
    fn dense_shapes() {
        let mut l = Layer::dense(10, Activation::None);
        l.resolve_placeholder(&Shape::new(&[4, 4, 2]));
        assert_eq!(l.output_shape(&Shape::new(&[4, 4, 2])).unwrap().dims(), &[10]);
        if let Layer::Dense { weights, .. } = &l {
            assert_eq!(weights.dims(), &[32, 10]);
        } else {
            unreachable!()
        }
    }
}
