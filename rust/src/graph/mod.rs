//! CNN graph IR: the layer sequence NNCG compiles.
//!
//! The paper targets small, *sequential* CNNs (Tables I–III): the IR is a
//! straight-line list of layers with static shapes, which is exactly what
//! makes whole-model specialization (unrolling, constant baking) tractable.

mod layer;
pub mod zoo;

pub use layer::{Activation, Layer, Padding};

use crate::tensor::{Shape, Tensor};
use crate::util::XorShift64;
use anyhow::{bail, Context, Result};

/// A trained (or to-be-trained) CNN: architecture + weights.
#[derive(Debug, Clone)]
pub struct Model {
    /// Human-readable name; also used for artifact file stems.
    pub name: String,
    /// HWC input shape.
    pub input: Shape,
    /// Straight-line layer sequence.
    pub layers: Vec<Layer>,
}

impl Model {
    pub fn new(name: &str, input: &[usize]) -> Self {
        Model { name: name.to_string(), input: Shape::new(input), layers: Vec::new() }
    }

    /// Append a layer (builder style).
    pub fn push(mut self, layer: Layer) -> Self {
        self.layers.push(layer);
        self
    }

    /// Run shape inference over the whole model, returning every
    /// intermediate shape: `shapes[0]` is the input, `shapes[i+1]` the output
    /// of `layers[i]`. Fails on any inconsistency (kernel larger than input,
    /// channel mismatch, non-positive output dims).
    pub fn infer_shapes(&self) -> Result<Vec<Shape>> {
        let mut shapes = vec![self.input.clone()];
        for (idx, layer) in self.layers.iter().enumerate() {
            let next = layer
                .output_shape(shapes.last().unwrap())
                .with_context(|| format!("layer {} ({})", idx, layer.kind_name()))?;
            shapes.push(next);
        }
        Ok(shapes)
    }

    /// Output shape of the full model.
    pub fn output_shape(&self) -> Result<Shape> {
        Ok(self.infer_shapes()?.pop().unwrap())
    }

    /// Validate architecture + weight tensor shapes together.
    pub fn validate(&self) -> Result<()> {
        let shapes = self.infer_shapes()?;
        for (idx, layer) in self.layers.iter().enumerate() {
            layer
                .validate_weights(&shapes[idx])
                .with_context(|| format!("layer {} ({})", idx, layer.kind_name()))?;
        }
        Ok(())
    }

    /// Number of scalar weights in the model.
    pub fn num_params(&self) -> usize {
        self.layers.iter().map(|l| l.num_params()).sum()
    }

    /// Multiply–accumulate count for a single inference (conv + dense only),
    /// used by the platform cost model.
    pub fn macs(&self) -> Result<u64> {
        let shapes = self.infer_shapes()?;
        let mut macs: u64 = 0;
        for (idx, layer) in self.layers.iter().enumerate() {
            macs += layer.macs(&shapes[idx])?;
        }
        Ok(macs)
    }

    /// Replace all weights with Glorot-uniform random values (deterministic
    /// in the seed). Used by tests and benches that don't need trained
    /// weights — the paper's latency numbers do not depend on weight values.
    pub fn with_random_weights(mut self, seed: u64) -> Self {
        let mut rng = XorShift64::new(seed);
        let mut shape = self.input.clone();
        for layer in &mut self.layers {
            layer.resolve_placeholder(&shape);
            layer.randomize_weights(&mut rng);
            shape = layer.output_shape(&shape).expect("shape inference while randomizing weights");
        }
        self
    }

    /// Resolve deferred `c_in`/`in` placeholder dims (builder constructors
    /// defer them until the input shape is known). Used by the weight
    /// loader before installing trained tensors.
    pub fn resolve_placeholders(&mut self) -> Result<()> {
        let mut shape = self.input.clone();
        for layer in &mut self.layers {
            layer.resolve_placeholder(&shape);
            shape = layer.output_shape(&shape)?;
        }
        Ok(())
    }

    /// Pretty-print the architecture as the paper's Tables I–III do.
    pub fn describe(&self) -> String {
        let shapes = match self.infer_shapes() {
            Ok(s) => s,
            Err(e) => return format!("<invalid model: {e}>"),
        };
        let mut out = String::new();
        out.push_str(&format!("Model: {}  ({} params, {} MACs)\n", self.name, self.num_params(), self.macs().unwrap_or(0)));
        out.push_str(&format!("{:<14} {:>5} {:>9} {:>8} {:>8}   {}\n", "Layer", "#", "Size", "Stride", "Padding", "Output"));
        out.push_str(&format!("{:<14} {:>5} {:>9} {:>8} {:>8}   {}\n", "Input", self.input.c(), format!("{}x{}", self.input.w(), self.input.h()), "", "", shapes[0]));
        for (i, l) in self.layers.iter().enumerate() {
            out.push_str(&l.describe_row(&shapes[i + 1]));
            out.push('\n');
        }
        out
    }

    /// Run the model on an input with the naive interpreter (convenience
    /// re-export used widely in tests).
    pub fn run_interp(&self, input: &Tensor) -> Result<Tensor> {
        crate::interp::run(self, input)
    }

    /// True if every conv layer's output channel count is a multiple of
    /// `lanes` — the paper's prerequisite for SIMD over output channels.
    pub fn simd_friendly(&self, lanes: usize) -> bool {
        self.layers.iter().all(|l| match l {
            Layer::Conv2D { weights, .. } => weights.dims()[3] % lanes == 0,
            _ => true,
        })
    }
}

/// Check an input tensor matches the model's declared input shape.
pub fn check_input(model: &Model, input: &Tensor) -> Result<()> {
    if input.dims() != model.input.dims() {
        bail!("input shape {:?} does not match model input {:?}", input.dims(), model.input.dims());
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> Model {
        Model::new("tiny", &[8, 8, 1])
            .push(Layer::conv2d(4, 3, 3, (1, 1), Padding::Same, Activation::Relu))
            .push(Layer::maxpool(2, 2))
            .push(Layer::conv2d(2, 3, 3, (1, 1), Padding::Valid, Activation::None))
            .push(Layer::softmax())
            .with_random_weights(1)
    }

    #[test]
    fn shape_inference_tiny() {
        let m = tiny();
        let shapes = m.infer_shapes().unwrap();
        assert_eq!(shapes[1].dims(), &[8, 8, 4]); // same pad conv
        assert_eq!(shapes[2].dims(), &[4, 4, 4]); // pool /2
        assert_eq!(shapes[3].dims(), &[2, 2, 2]); // valid conv 3x3
        assert_eq!(shapes[4].dims(), &[2, 2, 2]); // softmax preserves
    }

    #[test]
    fn validate_catches_missing_weights() {
        let m = Model::new("bad", &[8, 8, 1]).push(Layer::conv2d(4, 3, 3, (1, 1), Padding::Same, Activation::None));
        // conv2d() creates zero-sized weights until randomized/loaded
        assert!(m.validate().is_err());
        assert!(m.with_random_weights(3).validate().is_ok());
    }

    #[test]
    fn kernel_too_large_fails() {
        let m = Model::new("bad", &[4, 4, 1])
            .push(Layer::conv2d(2, 7, 7, (1, 1), Padding::Valid, Activation::None));
        assert!(m.infer_shapes().is_err());
    }

    #[test]
    fn num_params_counts_weights_and_bias() {
        let m = Model::new("p", &[8, 8, 2])
            .push(Layer::conv2d(4, 3, 3, (1, 1), Padding::Same, Activation::None))
            .with_random_weights(1);
        assert_eq!(m.num_params(), 3 * 3 * 2 * 4 + 4);
    }

    #[test]
    fn macs_positive() {
        assert!(tiny().macs().unwrap() > 0);
    }

    #[test]
    fn describe_contains_rows() {
        let d = tiny().describe();
        assert!(d.contains("Conv"), "{d}");
        assert!(d.contains("Max-Pool"), "{d}");
        assert!(d.contains("Soft-Max"), "{d}");
    }

    #[test]
    fn simd_friendly_checks_cout() {
        assert!(tiny().simd_friendly(2));
        assert!(!tiny().simd_friendly(8)); // last conv has c_out=2
    }
}
