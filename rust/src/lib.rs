//! # nncg — a C code generator for fast CNN inference on resource-constrained systems
//!
//! Reproduction of Urbann et al., *"A C Code Generator for Fast Inference and
//! Simple Deployment of Convolutional Neural Networks on Resource Constrained
//! Systems"* (2020), as a three-layer Rust + JAX + Pallas stack:
//!
//! * **Layer 3 (this crate)** — the NNCG compiler itself ([`codegen`]), the
//!   cc/dlopen execution engine ([`cc`]), a naive runtime interpreter used as
//!   the framework-overhead baseline ([`interp`]), the XLA/PJRT runtime that
//!   executes the JAX-lowered artifacts ([`runtime`]), the platform cost-model
//!   simulator for the paper's Atom/Nao/GPU rows ([`platform`]), and the
//!   serving coordinator ([`coordinator`]) with the paper's robotics vision
//!   pipelines ([`vision`]).
//! * **Layer 2 (`python/compile/model.py`)** — the paper's CNNs in JAX, lowered
//!   once to HLO text (`artifacts/*.hlo.txt`), never on the request path.
//! * **Layer 1 (`python/compile/kernels/`)** — Pallas kernels for the compute
//!   hot-spots, verified against a pure-jnp oracle.

pub mod bench_harness;
pub mod cc;
pub mod cli;
pub mod codegen;
pub mod coordinator;
pub mod experiments;
pub mod faults;
pub mod graph;
pub mod interp;
pub mod model;
pub mod passes;
pub mod platform;
pub mod runtime;
pub mod tensor;
pub mod util;
pub mod vision;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;
