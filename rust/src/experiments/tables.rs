//! Table IV–VII and GPU-throughput reproduction logic.

use super::{build_engine, default_artifacts_dir, default_weights_dir, default_work_dir, load_model};
use crate::bench_harness::{bench, BenchConfig, Stats, Table};
use crate::codegen::{AlignMode, CodegenOptions, DType, FuseMode, Isa, PadMode, TileMode};
use crate::platform::{paper_platforms, GpuModel};
use crate::runtime::EngineKind;
use crate::tensor::Tensor;
use crate::util::{fmt_us, XorShift64};
use anyhow::Result;
use std::path::Path;

/// One engine's result on one platform row.
#[derive(Debug, Clone)]
pub struct ExecTimeRow {
    pub platform: String,
    /// (engine label, measured-or-simulated µs, paper µs if reported)
    pub cells: Vec<(String, Option<f64>, Option<f64>)>,
    pub simulated: bool,
}

/// A rendered table plus its raw rows (benches print the table; tests and
/// EXPERIMENTS.md tooling read the rows).
#[derive(Debug)]
pub struct TableResult {
    pub title: String,
    pub rows: Vec<ExecTimeRow>,
    pub rendered: String,
    /// Measured host speed-up of NNCG over the XLA path.
    pub host_speedup_vs_xla: Option<f64>,
}

/// Paper values for Tables IV–VI, in µs.
/// (platform, nncg, glow, xla) — None = N/A in the paper.
type PaperRow = (&'static str, Option<f64>, Option<f64>, Option<f64>);

const PAPER_TABLE4: [PaperRow; 4] = [
    ("Intel i7 (8650U)", Some(2.10), Some(7.53), Some(24.81)),
    ("Intel Atom (J1900)", Some(17.51), None, Some(69.12)),
    ("Intel Atom (Z530)", Some(46.50), None, None),
    ("NVIDIA 1050", None, None, Some(5630.0)),
];

const PAPER_TABLE5: [PaperRow; 4] = [
    ("Intel i7 (8650U)", Some(135.7), None, Some(191.8)),
    ("Intel Atom (J1900)", Some(1020.3), None, Some(1757.2)),
    ("Intel Atom (Z530)", Some(2938.6), None, None),
    ("NVIDIA 1050", None, None, Some(5762.0)),
];

const PAPER_TABLE6: [PaperRow; 2] = [
    ("Intel i7 (8650U)", Some(474.0), None, Some(2457.0)),
    ("Intel Atom (J1900)", Some(1109.0), None, Some(6797.0)),
];

/// Measure one engine's single-image latency on the host.
fn measure_engine(kind: EngineKind, model_name: &str, cfg: &BenchConfig) -> Result<Stats> {
    let model = load_model(model_name, &default_weights_dir())?;
    let engine = build_engine(kind, &model, &CodegenOptions::sse3(), &default_artifacts_dir(), &default_work_dir())?;
    let mut rng = XorShift64::new(7);
    let input = Tensor::rand(model.input.dims(), 0.0, 1.0, &mut rng);
    // warm any lazy state
    engine.infer(&input)?;
    Ok(bench(cfg, || {
        let _ = engine.infer(&input).unwrap();
    }))
}

/// Shared driver for Tables IV/V/VI.
fn run_exec_time_table(
    table_no: usize,
    model_name: &str,
    paper: &[PaperRow],
    include_gpu: bool,
    cfg: &BenchConfig,
) -> Result<TableResult> {
    let model = load_model(model_name, &default_weights_dir())?;
    let macs = model.macs()?;
    let in_bytes = model.input.numel() * 4;

    // --- measured host row ---
    let nncg = measure_engine(EngineKind::Nncg, model_name, cfg)?;
    let interp = measure_engine(EngineKind::Interp, model_name, cfg)?;
    let xla = measure_engine(EngineKind::Xla, model_name, cfg).ok(); // needs artifacts

    let mut rows = Vec::new();
    rows.push(ExecTimeRow {
        platform: "This host (measured)".into(),
        cells: vec![
            ("NNCG".into(), Some(nncg.median_us), None),
            ("Glow*".into(), Some(interp.median_us), None),
            ("TF XLA".into(), xla.as_ref().map(|s| s.median_us), None),
        ],
        simulated: false,
    });

    // --- simulated paper platforms ---
    for (plat, paper_row) in paper_platforms().iter().zip(paper.iter()) {
        rows.push(ExecTimeRow {
            platform: format!("{} (sim)", plat.name),
            cells: vec![
                ("NNCG".into(), plat.predict_us(EngineKind::Nncg, macs), paper_row.1),
                ("Glow*".into(), plat.predict_us(EngineKind::Interp, macs), paper_row.2),
                ("TF XLA".into(), plat.predict_us(EngineKind::Xla, macs), paper_row.3),
            ],
            simulated: true,
        });
    }
    if include_gpu {
        let gpu = GpuModel::gtx_1050();
        let paper_gpu = paper.last().unwrap();
        rows.push(ExecTimeRow {
            platform: format!("{} (sim)", gpu.name),
            cells: vec![
                ("NNCG".into(), None, None),
                ("Glow*".into(), None, None),
                ("TF XLA".into(), Some(gpu.latency_us(macs, in_bytes, 1)), paper_gpu.3),
            ],
            simulated: true,
        });
    }

    let title = format!(
        "TABLE {}: EXECUTION TIME OF {} ({} MACs; *Glow column = naive-interpreter stand-in)",
        ["IV", "V", "VI"][table_no - 4],
        model_name.to_uppercase(),
        macs
    );
    let mut t = Table::new(&title, &["Platform", "NNCG", "Glow*", "TF XLA", "paper NNCG", "paper XLA"]);
    for row in &rows {
        let cell = |v: &Option<f64>| v.map(fmt_us).unwrap_or_else(|| "N/A".into());
        t.row(vec![
            row.platform.clone(),
            cell(&row.cells[0].1),
            cell(&row.cells[1].1),
            cell(&row.cells[2].1),
            cell(&row.cells[0].2),
            cell(&row.cells[2].2),
        ]);
    }
    let host_speedup = xla.as_ref().map(|x| x.median_us / nncg.median_us);
    let mut rendered = t.render();
    if let Some(s) = host_speedup {
        rendered.push_str(&format!(
            "host speed-up NNCG vs TF XLA: {s:.2}x | vs interp: {:.2}x\n",
            interp.median_us / nncg.median_us
        ));
    }
    Ok(TableResult { title, rows, rendered, host_speedup_vs_xla: host_speedup })
}

/// Table IV: ball classifier.
pub fn run_table4(quick: bool) -> Result<TableResult> {
    let cfg = if quick { BenchConfig::quick() } else { BenchConfig::small() };
    run_exec_time_table(4, "ball", &PAPER_TABLE4, true, &cfg)
}

/// Table V: pedestrian classifier.
pub fn run_table5(quick: bool) -> Result<TableResult> {
    let cfg = if quick { BenchConfig::quick() } else { BenchConfig { iters: 2_000, ..BenchConfig::small() } };
    run_exec_time_table(5, "pedestrian", &PAPER_TABLE5, true, &cfg)
}

/// Table VI: robot detector.
pub fn run_table6(quick: bool) -> Result<TableResult> {
    let cfg = if quick { BenchConfig::quick() } else { BenchConfig::large() };
    run_exec_time_table(6, "robot", &PAPER_TABLE6, false, &cfg)
}

/// Table VII: feature ablation on the ball classifier (host-measured, the
/// paper also measures this on one machine). The paper's three columns —
/// general ISA / SSSE3 / SSSE3 + full unroll (12.94µs / 2.64µs / 2.10µs)
/// — run with the paper's original emission scheme (pad-copy, untiled);
/// two extra rows ablate this repo's padless + register-tiled emission.
pub fn run_table7(quick: bool) -> Result<TableResult> {
    let cfg = if quick { BenchConfig::quick() } else { BenchConfig::small() };
    let model = load_model("ball", &default_weights_dir())?;
    let mut rng = XorShift64::new(7);
    let input = Tensor::rand(model.input.dims(), 0.0, 1.0, &mut rng);

    let configs: Vec<(&str, CodegenOptions, Option<f64>)> = vec![
        ("General", CodegenOptions::paper_baseline(Isa::Generic), Some(12.94)),
        ("SSSE3", CodegenOptions::paper_baseline(Isa::Sse3), Some(2.64)),
        (
            "SSSE3 + Full Unroll",
            CodegenOptions {
                unroll: crate::codegen::Unroll::Full,
                ..CodegenOptions::paper_baseline(Isa::Sse3)
            },
            Some(2.10),
        ),
        (
            "SSSE3 + padless",
            CodegenOptions { pad_mode: PadMode::Padless, tile: TileMode::Off, ..CodegenOptions::sse3() },
            None,
        ),
        (
            "SSSE3 + padless + tiled",
            CodegenOptions { pad_mode: PadMode::Padless, tile: TileMode::Auto, ..CodegenOptions::sse3() },
            None,
        ),
    ];
    let mut cells = Vec::new();
    for (label, opts, paper) in &configs {
        let cnn = crate::cc::CompiledCnn::build(&model, opts, default_work_dir())?;
        let mut out = vec![0.0f32; model.output_shape()?.numel()];
        let stats = bench(&cfg, || cnn.infer_into(input.data(), &mut out));
        cells.push((label.to_string(), Some(stats.median_us), *paper));
    }

    let title = "TABLE VII: SPEED COMPARISON OF DIFFERENT FEATURES (ball classifier)".to_string();
    let mut t = Table::new(&title, &["Feature set", "measured", "paper (i7)"]);
    for (label, v, p) in &cells {
        t.row(vec![
            label.clone(),
            v.map(fmt_us).unwrap_or_default(),
            p.map(fmt_us).unwrap_or_default(),
        ]);
    }
    let mut rendered = t.render();
    if let (Some(g), Some(s), Some(f)) = (cells[0].1, cells[1].1, cells[2].1) {
        rendered.push_str(&format!(
            "SIMD speed-up: {:.2}x (paper 4.9x) | full-unroll extra: {:.0}% (paper 26%)\n",
            g / s,
            (s / f - 1.0) * 100.0
        ));
    }
    Ok(TableResult {
        title,
        rows: vec![ExecTimeRow { platform: "host".into(), cells, simulated: false }],
        rendered,
        host_speedup_vs_xla: None,
    })
}

/// GPU throughput sweep (§III-C): per-image latency vs batch size on the
/// simulated GTX 1050, demonstrating the flat-under-100-images claim.
pub fn run_gpu_throughput() -> Result<TableResult> {
    let model = load_model("ball", &default_weights_dir())?;
    let macs = model.macs()?;
    let in_bytes = model.input.numel() * 4;
    let gpu = GpuModel::gtx_1050();

    let title = "GPU THROUGHPUT (simulated GTX 1050, TF XLA path, ball classifier)".to_string();
    let mut t = Table::new(&title, &["batch", "total latency", "per image", "vs host NNCG"]);
    // quick host reference
    let host = measure_engine(EngineKind::Nncg, "ball", &BenchConfig::quick())?;
    let mut rows = Vec::new();
    for batch in [1usize, 2, 4, 8, 16, 32, 64, 100, 128, 256, 512, 1024, 4096] {
        let total = gpu.latency_us(macs, in_bytes, batch);
        let per = total / batch as f64;
        t.row(vec![
            batch.to_string(),
            fmt_us(total),
            fmt_us(per),
            format!("{:.1}x", per / host.median_us),
        ]);
        rows.push(ExecTimeRow {
            platform: format!("batch {batch}"),
            cells: vec![("gpu-per-image".into(), Some(per), None)],
            simulated: true,
        });
    }
    Ok(TableResult { title, rows, rendered: t.render(), host_speedup_vs_xla: None })
}

/// One (model × emission-variant) measurement of the pad/tile ablation.
#[derive(Debug, Clone)]
pub struct AblationRow {
    pub model: String,
    pub variant: String,
    pub mean_us: f64,
    pub median_us: f64,
    pub p95_us: f64,
    /// Size of the generated C source, bytes.
    pub c_bytes: usize,
    /// Peak static scratch RAM the generated file declares (ping-pong
    /// planes + pad buffer + ring line buffers), bytes.
    pub static_bytes: usize,
}

/// The emission variants the ablation sweeps (all SSE, outer loops kept):
/// pad-copy vs padless × untiled vs tiled, an aligned-vs-unaligned axis, a
/// 1-D-vs-2-D register-tile axis, and a fused-vs-unfused axis (row-
/// streaming fusion with ring line buffers) on the fast configuration.
/// Since PR 4 the fused variant emits the steady-state **rolled** row
/// loops (`--fuse-rolled auto`, the default): periodic-eligible chains
/// fuse at full depth with prologue + `for` loop + epilogue emission, so
/// its `c_bytes` column now tracks the rolled code size and its
/// `static_bytes` the deeper groups' smaller footprint. Since PR 8 two
/// `--dtype int8` rows extend the sweep: the quantized emission keeps
/// all intermediates in `signed char` rings (4x smaller static RAM) and
/// replaces the float MACs with widening integer multiply-adds; the
/// register-tile knob is a no-op there, so the int8 rows pin tiling off.
pub const ABLATION_VARIANTS: [(&str, PadMode, TileMode, AlignMode, FuseMode, DType); 9] = [
    ("pad-copy+untiled", PadMode::Copy, TileMode::Off, AlignMode::Auto, FuseMode::Off, DType::F32),
    ("padless+untiled", PadMode::Padless, TileMode::Off, AlignMode::Auto, FuseMode::Off, DType::F32),
    ("pad-copy+tiled", PadMode::Copy, TileMode::Auto, AlignMode::Auto, FuseMode::Off, DType::F32),
    ("padless+tiled", PadMode::Padless, TileMode::Auto, AlignMode::Auto, FuseMode::Off, DType::F32),
    ("padless+tiled+unaligned", PadMode::Padless, TileMode::Auto, AlignMode::Off, FuseMode::Off, DType::F32),
    ("padless+tiled-2d", PadMode::Padless, TileMode::Fixed2D(2, 4), AlignMode::Auto, FuseMode::Off, DType::F32),
    ("padless+tiled+fused", PadMode::Padless, TileMode::Auto, AlignMode::Auto, FuseMode::Auto, DType::F32),
    ("int8", PadMode::Auto, TileMode::Off, AlignMode::Auto, FuseMode::Off, DType::Int8),
    ("int8+fused", PadMode::Auto, TileMode::Off, AlignMode::Auto, FuseMode::Auto, DType::Int8),
];

/// Measure every paper model under every pad/tile/fuse variant.
pub fn run_pad_tile_ablation(quick: bool) -> Result<Vec<AblationRow>> {
    let mut rows = Vec::new();
    for name in crate::graph::zoo::PAPER_MODELS {
        let model = load_model(name, &default_weights_dir())?;
        let cfg = if quick {
            BenchConfig::quick()
        } else if name == "robot" {
            BenchConfig::large()
        } else {
            BenchConfig::small()
        };
        let mut rng = XorShift64::new(7);
        let input = Tensor::rand(model.input.dims(), 0.0, 1.0, &mut rng);
        let mut out = vec![0.0f32; model.output_shape()?.numel()];
        for (variant, pad_mode, tile, align, fuse, dtype) in ABLATION_VARIANTS {
            let opts =
                CodegenOptions { pad_mode, tile, align, fuse, dtype, ..CodegenOptions::sse3() };
            let src = crate::codegen::generate_c(&model, &opts)?;
            let scratch = crate::codegen::scratch_report(&model, &opts)?;
            let cnn = crate::cc::CompiledCnn::from_source(&model, &opts, &src, default_work_dir())?;
            let stats = bench(&cfg, || cnn.infer_into(input.data(), &mut out));
            rows.push(AblationRow {
                model: name.to_string(),
                variant: variant.to_string(),
                mean_us: stats.mean_us,
                median_us: stats.median_us,
                p95_us: stats.p95_us,
                c_bytes: src.len(),
                static_bytes: scratch.total_bytes(),
            });
        }
    }
    Ok(rows)
}

/// Render the ablation rows as the extended Table VII columns.
pub fn render_ablation(rows: &[AblationRow]) -> String {
    let mut t = Table::new(
        "PAD/TILE/FUSE ABLATION: pad-copy vs padless × untiled vs tiled × fused (SSE, outer loops kept)",
        &["model", "variant", "mean", "median", "p95", "C size", "static RAM"],
    );
    for r in rows {
        t.row(vec![
            r.model.clone(),
            r.variant.clone(),
            fmt_us(r.mean_us),
            fmt_us(r.median_us),
            fmt_us(r.p95_us),
            format!("{}K", r.c_bytes / 1024),
            format!("{:.1}K", r.static_bytes as f64 / 1024.0),
        ]);
    }
    let mut out = t.render();
    for name in crate::graph::zoo::PAPER_MODELS {
        let find = |variant: &str| {
            rows.iter().find(|r| r.model == name && r.variant == variant).map(|r| r.median_us)
        };
        let find_ram = |variant: &str| {
            rows.iter().find(|r| r.model == name && r.variant == variant).map(|r| r.static_bytes)
        };
        if let (Some(base), Some(best)) = (find("pad-copy+untiled"), find("padless+tiled")) {
            out.push_str(&format!("{name}: padless+tiled vs pad-copy+untiled = {:.2}x\n", base / best));
        }
        if let (Some(al), Some(unal)) = (find("padless+tiled"), find("padless+tiled+unaligned")) {
            out.push_str(&format!("{name}: aligned vs unaligned = {:.3}x\n", unal / al));
        }
        if let (Some(d1), Some(d2)) = (find("padless+tiled"), find("padless+tiled-2d")) {
            out.push_str(&format!("{name}: 2-D (2x4) vs 1-D tile = {:.3}x\n", d1 / d2));
        }
        if let (Some(un), Some(fu)) = (find_ram("padless+tiled"), find_ram("padless+tiled+fused")) {
            out.push_str(&format!(
                "{name}: fused static RAM = {:.1}K vs {:.1}K unfused ({:.2}x smaller)\n",
                fu as f64 / 1024.0,
                un as f64 / 1024.0,
                un as f64 / fu.max(1) as f64
            ));
        }
        let find_bytes = |variant: &str| {
            rows.iter().find(|r| r.model == name && r.variant == variant).map(|r| r.c_bytes)
        };
        if let (Some(plain), Some(fu)) = (find_bytes("padless+tiled"), find_bytes("padless+tiled+fused")) {
            out.push_str(&format!(
                "{name}: rolled-fused C size = {:.0}K vs {:.0}K layer-at-a-time\n",
                fu as f64 / 1024.0,
                plain as f64 / 1024.0
            ));
        }
        if let (Some(f32_t), Some(q_t)) = (find("padless+tiled"), find("int8")) {
            out.push_str(&format!("{name}: int8 vs padless+tiled f32 = {:.2}x\n", f32_t / q_t));
        }
        if let (Some(f32_ram), Some(q_ram)) = (find_ram("padless+tiled"), find_ram("int8")) {
            out.push_str(&format!(
                "{name}: int8 static RAM = {:.1}K vs {:.1}K f32 ({:.2}x smaller)\n",
                q_ram as f64 / 1024.0,
                f32_ram as f64 / 1024.0,
                f32_ram as f64 / q_ram.max(1) as f64
            ));
        }
    }
    out
}

/// Write the ablation rows as `BENCH_table7.json` so future sessions can
/// track the perf trajectory. `source` records how the numbers were
/// obtained (`"measured"` from the bench, `"cost-model"` for projections).
pub fn write_bench_json(path: &Path, rows: &[AblationRow], source: &str) -> Result<()> {
    use crate::model::json::Value;
    let rows_json: Vec<Value> = rows
        .iter()
        .map(|r| {
            Value::Object(vec![
                ("model".to_string(), Value::Str(r.model.clone())),
                ("variant".to_string(), Value::Str(r.variant.clone())),
                ("mean_us".to_string(), Value::Num(round3(r.mean_us))),
                ("median_us".to_string(), Value::Num(round3(r.median_us))),
                ("p95_us".to_string(), Value::Num(round3(r.p95_us))),
                ("c_bytes".to_string(), Value::Num(r.c_bytes as f64)),
                ("static_bytes".to_string(), Value::Num(r.static_bytes as f64)),
            ])
        })
        .collect();
    let doc = Value::Object(vec![
        ("bench".to_string(), Value::Str("table7_pad_tile_ablation".to_string())),
        ("source".to_string(), Value::Str(source.to_string())),
        ("variants".to_string(), Value::Array(
            ABLATION_VARIANTS.iter().map(|(n, _, _, _, _, _)| Value::Str(n.to_string())).collect(),
        )),
        ("rows".to_string(), Value::Array(rows_json)),
    ]);
    std::fs::write(path, doc.to_json() + "\n")?;
    Ok(())
}

fn round3(v: f64) -> f64 {
    (v * 1000.0).round() / 1000.0
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table7_shape_holds() {
        // Full iteration count: the quick config is too noisy to order
        // configurations reliably on a shared single-core machine.
        let r = run_table7(false).unwrap();
        let cells = &r.rows[0].cells;
        let general = cells[0].1.unwrap();
        let sse = cells[1].1.unwrap();
        // The paper's core ablation claim: explicit SIMD wins. (Paper: 4.9x
        // with clang 6; modern gcc auto-vectorizes the generic code far
        // better, narrowing the factor — see EXPERIMENTS.md — so we assert
        // the ordering with a modest margin rather than the 2018 factor.)
        assert!(general > sse * 1.1, "general={general} sse={sse}");
    }

    #[test]
    fn table4_quick_runs_without_artifacts() {
        // XLA column may be N/A if artifacts are not built yet; the table
        // must still render with measured NNCG/interp host cells.
        let r = run_table4(true).unwrap();
        assert!(r.rendered.contains("NNCG"));
        let host = &r.rows[0];
        assert!(host.cells[0].1.unwrap() > 0.0);
        assert!(host.cells[1].1.unwrap() > host.cells[0].1.unwrap(), "interp must be slower than generated C");
    }

    #[test]
    fn pad_tile_ablation_quick_runs_and_serializes() {
        let rows = run_pad_tile_ablation(true).unwrap();
        assert_eq!(rows.len(), ABLATION_VARIANTS.len() * crate::graph::zoo::PAPER_MODELS.len());
        let path = std::env::temp_dir().join("nncg-bench-table7-test.json");
        write_bench_json(&path, &rows, "measured").unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let doc = crate::model::json::parse(&text).unwrap();
        assert_eq!(doc.get("source").unwrap().as_str().unwrap(), "measured");
        assert_eq!(doc.get("rows").unwrap().as_array().unwrap().len(), rows.len());
        assert!(text.contains("padless+tiled"));
        assert!(text.contains("padless+tiled+fused"));
        assert!(text.contains("static_bytes"));
        // The new footprint column must be real (c_bytes was 0 in the old
        // projections) and rings must shrink the multi-conv models' RAM.
        for r in &rows {
            assert!(r.c_bytes > 0, "{} {}: c_bytes must be measured", r.model, r.variant);
            assert!(r.static_bytes > 0, "{} {}: static_bytes must be measured", r.model, r.variant);
        }
        for name in ["pedestrian", "robot"] {
            let fused = rows.iter().find(|r| r.model == name && r.variant == "padless+tiled+fused").unwrap();
            let unfused = rows.iter().find(|r| r.model == name && r.variant == "padless+tiled").unwrap();
            assert!(
                fused.static_bytes < unfused.static_bytes,
                "{name}: ring buffers must shrink static RAM ({} vs {})",
                fused.static_bytes,
                unfused.static_bytes
            );
        }
        // The int8 rows must run on every paper model and realize the
        // signed-char footprint win over the f32 ping-pong planes.
        for name in crate::graph::zoo::PAPER_MODELS {
            let q = rows.iter().find(|r| r.model == name && r.variant == "int8").unwrap();
            let f = rows.iter().find(|r| r.model == name && r.variant == "padless+tiled").unwrap();
            assert!(
                q.static_bytes < f.static_bytes,
                "{name}: int8 scratch {} must undercut f32 {}",
                q.static_bytes,
                f.static_bytes
            );
        }
    }

    #[test]
    fn gpu_throughput_flat_then_amortized() {
        let r = run_gpu_throughput().unwrap();
        let per = |i: usize| r.rows[i].cells[0].1.unwrap();
        let total1 = per(0);
        // batch 100 total ≈ batch 1 total (flat latency claim): index 7 is batch 100
        let total100 = per(7) * 100.0;
        assert!(total100 < total1 * 1.2 * 100.0);
        // large batches amortize: per-image at 4096 far below at 1
        assert!(per(12) < per(0) / 100.0);
    }
}
