//! Reproduction drivers for every table and figure in the paper's
//! evaluation (§III). Shared by the CLI (`nncg bench`) and the cargo bench
//! targets (`rust/benches/*.rs`).
//!
//! Output convention for Tables IV–VI: host rows are **measured** on this
//! machine; the paper's platform rows are **simulated** via the calibrated
//! cost models in [`crate::platform`] and marked `(sim)`. Paper values are
//! printed alongside for comparison.

mod tables;

pub use tables::{
    render_ablation, run_gpu_throughput, run_pad_tile_ablation, run_table4, run_table5, run_table6,
    run_table7, write_bench_json, AblationRow, ExecTimeRow, TableResult, ABLATION_VARIANTS,
};

use crate::cc::CompiledCnn;
use crate::codegen::CodegenOptions;
use crate::graph::Model;
use crate::interp::InterpEngine;
use crate::runtime::{EngineKind, InferenceEngine, XlaEngine};
use anyhow::{Context, Result};
use std::path::{Path, PathBuf};
use std::sync::Arc;

/// Where compiled C objects are cached during benches/CLI runs.
pub fn default_work_dir() -> PathBuf {
    std::env::temp_dir().join("nncg-work")
}

/// Load a model with trained weights from `weights_dir` if present
/// (written by `make train`), falling back to seeded random weights —
/// latency does not depend on weight values, so benches work either way.
pub fn load_model(name: &str, weights_dir: &Path) -> Result<Model> {
    let stem = weights_dir.join(name);
    if stem.with_extension("json").exists() && stem.with_extension("nncgw").exists() {
        crate::model::load(&stem).with_context(|| format!("loading trained model {name}"))
    } else {
        crate::graph::zoo::by_name(name)
            .ok_or_else(|| anyhow::anyhow!("unknown model {name:?}"))
            .map(|m| m.with_random_weights(0xC0FFEE))
    }
}

/// Construct an engine of the requested kind for a model.
pub fn build_engine(
    kind: EngineKind,
    model: &Model,
    opts: &CodegenOptions,
    artifacts_dir: &Path,
    work_dir: &Path,
) -> Result<Arc<dyn InferenceEngine>> {
    Ok(match kind {
        EngineKind::Nncg => Arc::new(CompiledCnn::build(model, opts, work_dir)?),
        EngineKind::Interp => Arc::new(InterpEngine::new(model.clone())?),
        EngineKind::Xla => {
            let hlo = XlaEngine::artifact_path(artifacts_dir, &model.name);
            Arc::new(XlaEngine::load(
                &hlo,
                &model.name,
                model.input.dims(),
                model.output_shape()?.dims(),
            )?)
        }
    })
}

/// Default artifacts directory (repo-level `artifacts/`), overridable with
/// `NNCG_ARTIFACTS`.
pub fn default_artifacts_dir() -> PathBuf {
    std::env::var("NNCG_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}

/// Default trained-weights directory (`models/`), overridable with
/// `NNCG_MODELS`.
pub fn default_weights_dir() -> PathBuf {
    std::env::var("NNCG_MODELS").map(PathBuf::from).unwrap_or_else(|_| PathBuf::from("models"))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codegen::CodegenOptions;

    #[test]
    fn load_model_falls_back_to_random() {
        let m = load_model("ball", Path::new("/nonexistent")).unwrap();
        assert_eq!(m.name, "ball");
        m.validate().unwrap();
    }

    #[test]
    fn load_model_unknown_errors() {
        assert!(load_model("mobilenet", Path::new("/nonexistent")).is_err());
    }

    #[test]
    fn build_engine_nncg_and_interp() {
        let m = load_model("ball", Path::new("/nonexistent")).unwrap();
        let wd = default_work_dir();
        let e = build_engine(EngineKind::Nncg, &m, &CodegenOptions::sse3(), Path::new("artifacts"), &wd).unwrap();
        assert_eq!(e.name(), "ball");
        let e2 = build_engine(EngineKind::Interp, &m, &CodegenOptions::sse3(), Path::new("artifacts"), &wd).unwrap();
        assert_eq!(e2.name(), "interp");
    }
}
