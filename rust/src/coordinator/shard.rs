//! Sharded serving core: per-model shard pools with bounded per-model
//! queues, work stealing, shard-level health breakers, and graceful
//! drain/restart under live traffic.
//!
//! Topology: N shards, each with its own FIFO queue (bounded *per model*),
//! its own worker threads under a panic-isolation supervisor, and its own
//! [`CircuitBreaker`] tracking shard health. Requests route to their
//! model's **home shard** (`hash(model) % shards`), so each model gets a
//! stable shard pool and its requests stay FIFO; routing fails over to the
//! next healthy shard only when the home shard is draining or ejected by
//! its breaker.
//!
//! Work stealing: an idle worker whose own queue is empty takes work from
//! a backlogged peer queue — victim and amount per [`StealPolicy`]
//! (default: the oldest half of the longest queue). Steals pop from the queue
//! *front*, exactly like the owner, so a queue is always consumed in
//! submission order no matter who pops — stealing rebalances load without
//! reordering any submitter's dequeue sequence. (Replies can still
//! *complete* out of order across concurrent workers, as with any
//! multi-worker pool; the invariant stealing preserves is dequeue order
//! and exactly-one-reply.)
//!
//! Shard lifecycle: `closed` (in routing) → `ejected` (breaker open after
//! repeated worker unwinds / engine failures; routed around) → `probing`
//! (after the cooldown one request is admitted back) → `readmitted`
//! (probe succeeded, breaker closes). Independently,
//! [`ShardPool::recycle_shard`] drains a shard (admission routes around
//! it, its backlog is served to zero) and restarts its workers with a
//! fresh generation — zero accepted requests are dropped.

use super::batcher::{AdaptiveBatcher, BatcherPolicy};
use super::error::ServeError;
use super::fallback::{BreakerConfig, BreakerEvent, CircuitBreaker};
use super::metrics::{LatencyRecorder, MetricsSnapshot, ServeCounters, ShardStats};
use super::router::Router;
use super::{ExecOutcome, Request, ServeResult};
use crate::faults::{FaultPlan, FaultSite};
use crate::runtime::InferenceEngine;
use crate::tensor::Tensor;
use crate::util::{fxhash, panic_message};
use std::collections::{HashMap, VecDeque};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// Sharded-coordinator configuration (the explicit form;
/// [`super::ServeConfig`] maps onto this for the single-queue-era API).
#[derive(Debug, Clone)]
pub struct ShardConfig {
    /// Number of shards (min 1).
    pub shards: usize,
    /// Worker threads per shard (min 1).
    pub workers_per_shard: usize,
    /// Bounded queue capacity *per shard, per model*; submissions beyond
    /// it shed with [`ServeError::QueueFull`].
    pub queue_capacity: usize,
    /// Deadline applied to requests submitted without an explicit one.
    pub default_deadline: Option<Duration>,
    /// Enable work stealing between idle and backlogged shards.
    pub steal: bool,
    /// How a thief picks its victim and how much it takes per steal
    /// (`NNCG_SERVE_STEAL_POLICY` selects this in the env-driven paths).
    pub steal_policy: StealPolicy,
    /// Per-shard dequeue batching policy: `max_batch` requests are popped
    /// per dequeue and same-model runs execute through one
    /// `engine.infer_batch` call; `max_wait` is how long a dequeue lingers
    /// for the batch to fill (`immediate()` pops one at a time, never
    /// waiting). With [`ShardConfig::batch_adapt`] set, this is the upper
    /// *cap* of the adaptive range instead of a fixed policy.
    pub batch: BatcherPolicy,
    /// Adapt the effective batch width per shard between latency-first
    /// (width 1) and the `batch` cap, widening from observed queue depth
    /// and decaying when the queue drains (see
    /// [`super::AdaptiveBatcher`]). Off by default: a fixed policy keeps
    /// the single-queue-era semantics bit-compatible.
    pub batch_adapt: bool,
    /// Shard-level breaker tuning: consecutive request failures or worker
    /// unwinds on one shard eject it from routing until a probe succeeds.
    pub breaker: BreakerConfig,
    /// Deterministic fault plan consulted at the shard seams
    /// ([`FaultSite::ShardKill`], [`FaultSite::StealRace`]).
    pub faults: Option<Arc<FaultPlan>>,
}

impl Default for ShardConfig {
    fn default() -> Self {
        ShardConfig {
            shards: 1,
            workers_per_shard: 1,
            queue_capacity: 1024,
            default_deadline: None,
            steal: true,
            steal_policy: StealPolicy::default(),
            batch: BatcherPolicy::immediate(),
            batch_adapt: false,
            // Shard ejection wants more evidence than an engine-level
            // breaker: one flaky request shouldn't empty a shard pool.
            breaker: BreakerConfig { failure_threshold: 8, cooldown: Duration::from_millis(100) },
            faults: None,
        }
    }
}

/// A model's home shard: stable affinity so each model keeps a dedicated
/// shard pool and per-model FIFO order.
pub fn home_shard(model: &str, shards: usize) -> usize {
    (fxhash::hash_str(model) % shards.max(1) as u64) as usize
}

/// Work-stealing policy: victim selection × steal amount (ROADMAP 4(c)).
///
/// Every variant preserves the ordering contract — steals take from the
/// *front* of the victim's FIFO, so per-submitter dequeue order is
/// unchanged regardless of policy (pinned by the shard property test).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum StealPolicy {
    /// Victim = longest queue; take the older half of its backlog. The
    /// historical behavior and the default.
    #[default]
    HalfLength,
    /// Victim = longest queue; take one request. Minimal disruption,
    /// more steal round-trips under sustained imbalance.
    OneLength,
    /// Victim = queue whose front request was admitted earliest (oldest
    /// head-of-line); take the older half.
    HalfAge,
    /// Victim = oldest head-of-line; take one request. Closest to a pure
    /// "finish the longest-waiting work first" policy.
    OneAge,
}

impl StealPolicy {
    /// All policies, in stable order (A/B sweeps iterate this).
    pub const ALL: [StealPolicy; 4] = [
        StealPolicy::HalfLength,
        StealPolicy::OneLength,
        StealPolicy::HalfAge,
        StealPolicy::OneAge,
    ];

    /// Stable name (the `NNCG_SERVE_STEAL_POLICY` vocabulary).
    pub fn name(self) -> &'static str {
        match self {
            StealPolicy::HalfLength => "half-length",
            StealPolicy::OneLength => "one-length",
            StealPolicy::HalfAge => "half-age",
            StealPolicy::OneAge => "one-age",
        }
    }

    /// Parse a policy name; `None` for unknown input (callers fall back
    /// to the default rather than failing startup on a typo'd env var).
    pub fn parse(s: &str) -> Option<StealPolicy> {
        StealPolicy::ALL.iter().copied().find(|p| p.name() == s)
    }

    /// Whether the victim is chosen by front-request age rather than by
    /// queue length.
    pub fn by_age(self) -> bool {
        matches!(self, StealPolicy::HalfAge | StealPolicy::OneAge)
    }

    /// How many requests to steal from a victim with `backlog` queued.
    pub fn take_count(self, backlog: usize) -> usize {
        match self {
            StealPolicy::HalfLength | StealPolicy::HalfAge => (backlog + 1) / 2,
            StealPolicy::OneLength | StealPolicy::OneAge => backlog.min(1),
        }
    }
}

/// Pick a steal victim among `candidates = (shard idx, queue len, front
/// admission seq)` snapshots: by length (longest queue wins) or by age
/// (smallest front sequence number — the oldest head-of-line — wins).
/// Empty queues are never victims; ties keep the first candidate. Pure so
/// the unit tests can pin the choice without building a pool.
fn choose_victim(
    policy: StealPolicy,
    candidates: &[(usize, usize, Option<u64>)],
) -> Option<usize> {
    let mut best: Option<(usize, usize, u64)> = None; // (idx, len, front_seq)
    for &(idx, len, front) in candidates {
        if len == 0 {
            continue;
        }
        // A non-empty snapshot without a front seq lost a race to a
        // concurrent pop; treat it as newest so it never wins by age.
        let front = front.unwrap_or(u64::MAX);
        let wins = match (policy.by_age(), best) {
            (_, None) => true,
            (false, Some((_, bl, _))) => len > bl,
            (true, Some((_, _, bf))) => front < bf,
        };
        if wins {
            best = Some((idx, len, front));
        }
    }
    best.map(|(idx, _, _)| idx)
}

/// A queued request stamped with its global admission sequence number
/// (assigned under the admission path, monotone per submitter).
struct SeqReq {
    seq: u64,
    req: Request,
}

struct QueueInner {
    deque: VecDeque<SeqReq>,
    /// Queued-request count per model (the per-model bound).
    per_model: HashMap<String, usize>,
}

/// Bounded FIFO queue for one shard. Owner pops and steals both take from
/// the *front*, so consumption order equals submission order regardless of
/// which shard's worker does the popping.
///
/// The queue also owns the shard's **in-flight accounting**: `take_front`
/// increments `in_flight` *under the queue lock*, so there is no window in
/// which a dequeued-but-not-yet-counted batch lets a drain observe
/// "queue empty + nothing in flight" while work is in hand. Stolen work
/// stays charged to the queue it was taken from — draining a shard
/// therefore waits for its stolen backlog too.
struct ShardQueue {
    inner: Mutex<QueueInner>,
    /// Signaled on push (wakes a dequeue waiting for work).
    cond: Condvar,
    /// Signaled when the queue becomes empty or `in_flight` reaches zero
    /// (wakes drain/shutdown quiescence waiters).
    idle: Condvar,
    /// Per-model capacity.
    capacity: usize,
    /// Dequeued-but-not-yet-replied requests charged to this queue.
    in_flight: AtomicU64,
    stats: Arc<ShardStats>,
}

impl ShardQueue {
    fn new(capacity: usize, stats: Arc<ShardStats>) -> Self {
        ShardQueue {
            inner: Mutex::new(QueueInner { deque: VecDeque::new(), per_model: HashMap::new() }),
            cond: Condvar::new(),
            idle: Condvar::new(),
            capacity: capacity.max(1),
            in_flight: AtomicU64::new(0),
            stats,
        }
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, QueueInner> {
        self.inner.lock().unwrap_or_else(|e| e.into_inner())
    }

    fn push(&self, sr: SeqReq) -> Result<(), SeqReq> {
        let mut q = self.lock();
        let count = q.per_model.entry(sr.req.model.clone()).or_insert(0);
        if *count >= self.capacity {
            return Err(sr);
        }
        *count += 1;
        q.deque.push_back(sr);
        self.stats.queue_len.store(q.deque.len() as u64, Ordering::Relaxed);
        self.cond.notify_one();
        Ok(())
    }

    fn take_front(&self, q: &mut QueueInner, max_n: usize) -> Vec<SeqReq> {
        let n = max_n.min(q.deque.len());
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            let sr = q.deque.pop_front().expect("len checked");
            if let Some(c) = q.per_model.get_mut(&sr.req.model) {
                *c = c.saturating_sub(1);
            }
            out.push(sr);
        }
        // Count the batch in flight before the lock drops (see struct doc).
        self.in_flight.fetch_add(n as u64, Ordering::SeqCst);
        self.stats.queue_len.store(q.deque.len() as u64, Ordering::Relaxed);
        if q.deque.is_empty() && n > 0 {
            self.idle.notify_all();
        }
        out
    }

    /// Release `n` in-flight slots (requests replied or abandoned), waking
    /// quiescence waiters when the count reaches zero. The empty lock
    /// acquisition orders the notify against a concurrent
    /// [`ShardQueue::wait_quiesced`] check so the wakeup cannot be missed.
    fn in_flight_sub(&self, n: u64) {
        if n == 0 {
            return;
        }
        let prev = self.in_flight.fetch_sub(n, Ordering::SeqCst);
        debug_assert!(prev >= n, "in_flight underflow");
        if prev <= n {
            let _q = self.lock();
            self.idle.notify_all();
        }
    }

    /// Pop up to `max_n` from the front. `max_wait` is the configured
    /// [`BatcherPolicy::max_wait`]: zero means *never sleep* (the
    /// latency-first contract); otherwise the dequeue lingers until the
    /// batch can fill to `max_n` or the wait budget runs out, returning
    /// whatever is queued by then. Waiting happens with the work still in
    /// the queue, so lingering requests remain visible to thieves.
    fn pop_batch(&self, max_n: usize, max_wait: Duration) -> Vec<SeqReq> {
        let mut q = self.lock();
        if max_wait.is_zero() {
            return self.take_front(&mut q, max_n);
        }
        let deadline = Instant::now() + max_wait;
        while q.deque.len() < max_n {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            let (guard, _) = self
                .cond
                .wait_timeout(q, deadline - now)
                .unwrap_or_else(|e| e.into_inner());
            q = guard;
        }
        self.take_front(&mut q, max_n)
    }

    /// Steal up to `max_n` from the front without waiting.
    fn steal_batch(&self, max_n: usize) -> Vec<SeqReq> {
        let mut q = self.lock();
        self.take_front(&mut q, max_n)
    }

    fn len(&self) -> usize {
        self.lock().deque.len()
    }

    /// `(queue length, admission seq of the front request)` under one
    /// lock — a coherent snapshot for age-based victim selection.
    fn len_and_front_seq(&self) -> (usize, Option<u64>) {
        let q = self.lock();
        (q.deque.len(), q.deque.front().map(|sr| sr.seq))
    }

    /// Park until a push arrives or `timeout` elapses (idle workers park
    /// here instead of spinning when their policy says not to wait in
    /// `pop_batch`).
    fn wait_nonempty(&self, timeout: Duration) {
        let q = self.lock();
        if q.deque.is_empty() {
            let _ = self.cond.wait_timeout(q, timeout);
        }
    }

    /// Wait up to `timeout` for "queue empty and nothing in flight";
    /// returns whether that state held when the wait ended. Callers loop:
    /// a `true` can be stale the instant the lock drops, but drain callers
    /// have already unrouted the shard so no new pushes arrive.
    fn wait_quiesced(&self, timeout: Duration) -> bool {
        let q = self.lock();
        if q.deque.is_empty() && self.in_flight.load(Ordering::SeqCst) == 0 {
            return true;
        }
        let (q, _) = self
            .idle
            .wait_timeout(q, timeout)
            .unwrap_or_else(|e| e.into_inner());
        q.deque.is_empty() && self.in_flight.load(Ordering::SeqCst) == 0
    }

    /// Remove everything still queued (shutdown-deadline purge). The
    /// caller replies synchronously, so the in-flight charge `take_front`
    /// added is released before returning.
    fn drain_all(&self) -> Vec<SeqReq> {
        let out = {
            let mut q = self.lock();
            let n = q.deque.len();
            self.take_front(&mut q, n)
        };
        self.in_flight_sub(out.len() as u64);
        out
    }
}

/// Unwind-safe release of a batch's in-flight slots: `done_one` pays down
/// the charge as replies go out, and `Drop` releases whatever is left if a
/// panic escapes mid-batch — without it, an unwinding worker strands
/// `in_flight > 0` and `recycle_shard`/shutdown wait forever (the
/// [`super::ReplyGuard`] pattern, applied to accounting).
struct InFlightGuard<'a> {
    queue: &'a ShardQueue,
    remaining: u64,
}

impl InFlightGuard<'_> {
    fn done_one(&mut self) {
        debug_assert!(self.remaining > 0);
        if self.remaining > 0 {
            self.remaining -= 1;
            self.queue.in_flight_sub(1);
        }
    }
}

impl Drop for InFlightGuard<'_> {
    fn drop(&mut self) {
        self.queue.in_flight_sub(self.remaining);
        self.remaining = 0;
    }
}

/// One shard: queue + health breaker + drain/generation state.
struct Shard {
    idx: usize,
    queue: ShardQueue,
    breaker: CircuitBreaker,
    /// Admission routes around a draining shard; its workers keep serving
    /// the backlog down to zero.
    draining: AtomicBool,
    /// Bumped by [`ShardPool::recycle_shard`]; workers of an older
    /// generation exit at the next loop iteration.
    generation: AtomicU64,
    /// Effective dequeue policy, shared by this shard's workers; adapts to
    /// observed queue depth when [`ShardConfig::batch_adapt`] is on.
    batcher: AdaptiveBatcher,
    stats: Arc<ShardStats>,
}

impl Shard {
    fn new(idx: usize, cfg: &ShardConfig, counters: &Arc<ServeCounters>) -> Arc<Shard> {
        let stats = Arc::new(ShardStats::default());
        let mut breaker = CircuitBreaker::new(cfg.breaker.clone());
        let c = Arc::clone(counters);
        let st = Arc::clone(&stats);
        breaker.set_observer(Box::new(move |ev| match ev {
            BreakerEvent::Opened => {
                ServeCounters::bump(&c.shard_ejects);
                ServeCounters::bump(&st.ejects);
            }
            BreakerEvent::HalfOpened => ServeCounters::bump(&c.shard_probes),
            BreakerEvent::Closed => {
                ServeCounters::bump(&c.shard_readmits);
                ServeCounters::bump(&st.readmits);
            }
        }));
        let batcher = if cfg.batch_adapt {
            AdaptiveBatcher::adaptive(BatcherPolicy::immediate(), cfg.batch)
        } else {
            AdaptiveBatcher::fixed(cfg.batch)
        };
        Arc::new(Shard {
            idx,
            queue: ShardQueue::new(cfg.queue_capacity, Arc::clone(&stats)),
            breaker,
            draining: AtomicBool::new(false),
            generation: AtomicU64::new(0),
            batcher,
            stats,
        })
    }

    /// Dequeued-but-unreplied requests charged to this shard's queue
    /// (including work stolen from it that is still executing elsewhere).
    fn in_flight(&self) -> u64 {
        self.queue.in_flight.load(Ordering::SeqCst)
    }

    /// Report a request outcome executed by this shard's worker to the
    /// shard's health breaker and stats. Sheds (deadline, unknown model)
    /// say nothing about shard health.
    fn on_outcome(&self, outcome: ExecOutcome) {
        ServeCounters::bump(&self.stats.handled);
        match outcome {
            ExecOutcome::Served => self.breaker.on_success(),
            ExecOutcome::Failed => {
                ServeCounters::bump(&self.stats.failed);
                self.breaker.on_failure();
            }
            ExecOutcome::Shed => {}
        }
    }
}

/// The sharded coordinator. Usually driven through
/// [`super::ServerHandle`] / [`super::Submitter`]; exposed for tests and
/// the load benchmark.
pub struct ShardPool {
    cfg: ShardConfig,
    router: Arc<Router>,
    shards: Vec<Arc<Shard>>,
    metrics: Arc<LatencyRecorder>,
    stop: AtomicBool,
    seq: AtomicU64,
    workers: Mutex<Vec<Vec<std::thread::JoinHandle<()>>>>,
}

impl ShardPool {
    /// Spawn the pool: `cfg.shards` shards × `cfg.workers_per_shard`
    /// supervised workers over a shared router.
    pub fn start(router: Arc<Router>, cfg: ShardConfig) -> Arc<ShardPool> {
        let cfg = ShardConfig {
            shards: cfg.shards.max(1),
            workers_per_shard: cfg.workers_per_shard.max(1),
            queue_capacity: cfg.queue_capacity.max(1),
            ..cfg
        };
        let metrics = Arc::new(LatencyRecorder::new());
        let counters = Arc::clone(metrics.counters());
        let shards: Vec<Arc<Shard>> = (0..cfg.shards).map(|i| Shard::new(i, &cfg, &counters)).collect();
        metrics.attach_shard_stats(shards.iter().map(|s| Arc::clone(&s.stats)).collect());
        let pool = Arc::new(ShardPool {
            cfg,
            router,
            shards,
            metrics,
            stop: AtomicBool::new(false),
            seq: AtomicU64::new(0),
            workers: Mutex::new(Vec::new()),
        });
        let all: Vec<Vec<std::thread::JoinHandle<()>>> = pool
            .shards
            .iter()
            .map(|s| {
                (0..pool.cfg.workers_per_shard)
                    .map(|_| spawn_shard_worker(Arc::clone(&pool), Arc::clone(s), 0))
                    .collect()
            })
            .collect();
        *pool.workers.lock().unwrap_or_else(|e| e.into_inner()) = all;
        pool
    }

    pub fn metrics(&self) -> &Arc<LatencyRecorder> {
        &self.metrics
    }

    /// The model registry this pool routes through (pre-admission checks,
    /// e.g. the net front-end's unknown-model gate).
    pub(crate) fn router(&self) -> &Arc<Router> {
        &self.router
    }

    pub fn shards(&self) -> usize {
        self.shards.len()
    }

    pub fn config(&self) -> &ShardConfig {
        &self.cfg
    }

    /// Admission: stamp, route, and enqueue one request. Typed sheds:
    /// `Stopped` after shutdown began, `QueueFull` when the routed shard's
    /// per-model bound is hit.
    pub fn submit(
        &self,
        model: &str,
        input: Tensor,
        deadline: Option<Instant>,
    ) -> Result<mpsc::Receiver<ServeResult>, ServeError> {
        if self.stop.load(Ordering::SeqCst) {
            return Err(ServeError::Stopped);
        }
        let (reply_tx, reply_rx) = mpsc::channel();
        let req = Request {
            model: model.to_string(),
            input,
            reply: reply_tx,
            enqueued: Instant::now(),
            deadline,
        };
        let seq = self.seq.fetch_add(1, Ordering::SeqCst) + 1;
        let shard = self.route(model);
        match shard.queue.push(SeqReq { seq, req }) {
            Ok(()) => Ok(reply_rx),
            Err(_) => {
                ServeCounters::bump(&self.metrics.counters().queue_full_sheds);
                Err(ServeError::QueueFull { capacity: self.cfg.queue_capacity })
            }
        }
    }

    /// Health-aware routing: the home shard unless it is draining or its
    /// breaker rejects (ejected / probe already in flight); then the next
    /// healthy shard. Admission is never refused for health alone — if
    /// every shard is unhealthy the home shard still accepts (last
    /// resort), so health routing can only move load, not lose it.
    fn route(&self, model: &str) -> &Arc<Shard> {
        let n = self.shards.len();
        let home = home_shard(model, n);
        if n == 1 {
            return &self.shards[0];
        }
        for i in 0..n {
            let s = &self.shards[(home + i) % n];
            if s.draining.load(Ordering::SeqCst) {
                continue;
            }
            // `allow` admits the half-open probe itself when the cooldown
            // of an ejected shard has elapsed.
            if s.breaker.allow() {
                return s;
            }
        }
        for i in 0..n {
            let s = &self.shards[(home + i) % n];
            if !s.draining.load(Ordering::SeqCst) {
                return s;
            }
        }
        &self.shards[home]
    }

    /// Work stealing: called by a worker whose own queue is empty. Picks a
    /// victim per [`ShardConfig::steal_policy`] (longest queue or oldest
    /// head-of-line), takes the policy's share from the *front* of its
    /// FIFO, and executes it — attributing *outcomes* to the thief shard
    /// (its breaker did the work) while the in-flight charge stays on the
    /// victim's queue (it is the victim's backlog being finished). Returns
    /// whether anything was actually stolen and executed.
    fn try_steal(self: &Arc<Self>, thief: &Arc<Shard>) -> bool {
        let policy = self.cfg.steal_policy;
        let candidates: Vec<(usize, usize, Option<u64>)> = self
            .shards
            .iter()
            .enumerate()
            .filter(|(i, _)| *i != thief.idx)
            .map(|(i, s)| {
                let (len, front) = s.queue.len_and_front_seq();
                (i, len, front)
            })
            .collect();
        let Some(vidx) = choose_victim(policy, &candidates) else { return false };
        if let Some(plan) = &self.cfg.faults {
            // Widen the thief-vs-thief / thief-vs-owner race window.
            if let Some(d) = plan.maybe_delay_at(FaultSite::StealRace, thief.idx) {
                std::thread::sleep(d);
            }
        }
        let victim = &self.shards[vidx];
        // Re-read the length at take time: the snapshot may be stale.
        let batch = victim.queue.steal_batch(policy.take_count(victim.queue.len()).max(1));
        if batch.is_empty() {
            return false; // lost the race to the owner or another thief
        }
        let c = self.metrics.counters();
        for _ in 0..batch.len() {
            ServeCounters::bump(&c.steals);
            ServeCounters::bump(&victim.stats.stolen_from);
            ServeCounters::bump(&thief.stats.stolen_by);
        }
        self.run_batch(thief, victim, batch);
        true
    }

    /// Execute a popped batch on `executor`'s account, with its in-flight
    /// charge on `source`'s queue (the queue `take_front` counted it on —
    /// the thief passes the victim). Shard queues have model affinity, so
    /// a dequeued batch is usually one model: consecutive same-model runs
    /// with a resolvable engine dispatch through **one**
    /// `engine.infer_batch` call ([`super::execute_batch_with`]); runs of
    /// one, and runs whose model fails to resolve, go through the
    /// per-request [`super::execute_with`] path so the `ModelUnknown`
    /// reply semantics are preserved. The in-flight decrement is held by
    /// an [`InFlightGuard`], so a panic escaping mid-batch releases the
    /// remainder instead of stranding the drain/shutdown waiters.
    fn run_batch(&self, executor: &Arc<Shard>, source: &Arc<Shard>, batch: Vec<SeqReq>) {
        let mut guard = InFlightGuard { queue: &source.queue, remaining: batch.len() as u64 };
        let mut memo: Option<(String, Option<Arc<dyn InferenceEngine>>)> = None;
        let mut it = batch.into_iter().map(|sr| sr.req).peekable();
        while let Some(first) = it.next() {
            let mut run = vec![first];
            while it.peek().map_or(false, |r| r.model == run[0].model) {
                run.push(it.next().expect("peeked"));
            }
            let resolved = match &memo {
                Some((m, e)) if *m == run[0].model => e.clone(),
                _ => {
                    let e = self.router.engine(&run[0].model).ok();
                    memo = Some((run[0].model.clone(), e.clone()));
                    e
                }
            };
            match resolved {
                Some(engine) if run.len() >= 2 => {
                    for outcome in super::execute_batch_with(run, engine, &self.metrics) {
                        executor.on_outcome(outcome);
                        guard.done_one();
                    }
                }
                resolved => {
                    for req in run {
                        let outcome =
                            super::execute_with(req, resolved.clone(), &self.router, &self.metrics);
                        executor.on_outcome(outcome);
                        guard.done_one();
                    }
                }
            }
        }
    }

    /// Graceful shard drain/restart under live traffic: admission routes
    /// around the shard, its backlog is served to zero (own workers plus
    /// thieves), the old workers are retired via a generation bump, and a
    /// fresh set is spawned. Zero accepted requests are dropped. Returns
    /// `false` for an unknown index or a shard already draining.
    pub fn recycle_shard(self: &Arc<Self>, idx: usize) -> bool {
        let Some(shard) = self.shards.get(idx) else { return false };
        if shard.draining.swap(true, Ordering::SeqCst) {
            return false;
        }
        // Condvar-parked drain: woken on queue-empty and on in-flight-zero
        // transitions instead of burning a core polling at 1 ms. The
        // timeout is only a re-check cadence for the stop flag.
        while !shard.queue.wait_quiesced(Duration::from_millis(20)) {
            if self.stop.load(Ordering::SeqCst) {
                break; // shutdown takes over; its drain/purge owns the backlog
            }
        }
        let new_gen = shard.generation.fetch_add(1, Ordering::SeqCst) + 1;
        let old = {
            let mut all = self.workers.lock().unwrap_or_else(|e| e.into_inner());
            std::mem::take(&mut all[idx])
        };
        for h in old {
            let _ = h.join();
        }
        let fresh: Vec<_> = (0..self.cfg.workers_per_shard)
            .map(|_| spawn_shard_worker(Arc::clone(self), Arc::clone(shard), new_gen))
            .collect();
        self.workers.lock().unwrap_or_else(|e| e.into_inner())[idx] = fresh;
        shard.breaker.reset();
        shard.draining.store(false, Ordering::SeqCst);
        ServeCounters::bump(&self.metrics.counters().shard_drains);
        ServeCounters::bump(&shard.stats.drains);
        true
    }

    /// Close admission without blocking (used by `ServerHandle::drop` so
    /// an un-stopped handle never strands worker threads in a live loop).
    pub fn begin_stop(&self) {
        self.stop.store(true, Ordering::SeqCst);
    }

    /// Drain-then-join shutdown. With `timeout = None` this waits for the
    /// full backlog to be served (the PR6 `stop()` contract). With a
    /// deadline, whatever is still *queued* when it fires is answered with
    /// a typed [`ServeError::Stopped`] reply (never silently dropped), and
    /// a worker wedged inside a request is detached instead of hanging
    /// shutdown forever.
    pub fn shutdown_blocking(&self, timeout: Option<Duration>) -> MetricsSnapshot {
        self.stop.store(true, Ordering::SeqCst);
        let deadline = timeout.map(|d| Instant::now() + d);
        loop {
            let busy = self
                .shards
                .iter()
                .find(|s| s.queue.len() > 0 || s.in_flight() > 0);
            let Some(busy) = busy else { break };
            if let Some(dl) = deadline {
                if Instant::now() >= dl {
                    let c = self.metrics.counters();
                    for s in &self.shards {
                        for sr in s.queue.drain_all() {
                            let _ = sr.req.reply.send(Err(ServeError::Stopped));
                            ServeCounters::bump(&c.stopped_replies);
                        }
                    }
                    break;
                }
            }
            // Park on the busy shard's quiescence condvar (woken by its
            // workers' progress) instead of polling; cap the park so the
            // deadline and the other shards get re-checked.
            let cap = match deadline {
                Some(dl) => dl
                    .saturating_duration_since(Instant::now())
                    .min(Duration::from_millis(20))
                    .max(Duration::from_millis(1)),
                None => Duration::from_millis(20),
            };
            let _ = busy.queue.wait_quiesced(cap);
        }
        let all = {
            let mut w = self.workers.lock().unwrap_or_else(|e| e.into_inner());
            std::mem::take(&mut *w)
        };
        for handles in all {
            for h in handles {
                match deadline {
                    None => {
                        let _ = h.join();
                    }
                    Some(dl) => {
                        // Grace beyond the deadline so an in-flight request
                        // can finish its reply; then detach rather than hang.
                        let limit = dl.max(Instant::now() + Duration::from_millis(250));
                        while !h.is_finished() && Instant::now() < limit {
                            std::thread::sleep(Duration::from_millis(1));
                        }
                        if h.is_finished() {
                            let _ = h.join();
                        } else {
                            eprintln!(
                                "[nncg] detaching wedged shard worker at shutdown deadline"
                            );
                        }
                    }
                }
            }
        }
        self.metrics.snapshot()
    }
}

/// Supervisor thread for one shard worker: respawns the loop in-thread on
/// an unexpected unwind (e.g. an injected [`FaultSite::ShardKill`]). Each
/// unwind counts against the shard's breaker, so a repeatedly dying shard
/// gets ejected from routing; the short backoff before respawn leaves a
/// window for peers to steal the dead shard's backlog.
fn spawn_shard_worker(
    pool: Arc<ShardPool>,
    shard: Arc<Shard>,
    my_gen: u64,
) -> std::thread::JoinHandle<()> {
    std::thread::spawn(move || loop {
        let result = catch_unwind(AssertUnwindSafe(|| worker_loop(&pool, &shard, my_gen)));
        match result {
            Ok(()) => return, // clean exit (stop, or retired generation)
            Err(payload) => {
                ServeCounters::bump(&pool.metrics.counters().worker_respawns);
                ServeCounters::bump(&shard.stats.respawns);
                shard.breaker.on_failure();
                eprintln!(
                    "[nncg] shard {} worker unwound ({}); respawning",
                    shard.idx,
                    panic_message(&*payload)
                );
                std::thread::sleep(Duration::from_millis(2));
            }
        }
    })
}

fn worker_loop(pool: &Arc<ShardPool>, shard: &Arc<Shard>, my_gen: u64) {
    loop {
        if shard.generation.load(Ordering::SeqCst) != my_gen {
            return; // retired by a recycle
        }
        let stopping = pool.stop.load(Ordering::SeqCst);
        if let Some(plan) = &pool.cfg.faults {
            // Injected between requests: the queue survives the kill and
            // can be stolen while the supervisor respawns this worker.
            if plan.should_fire_at(FaultSite::ShardKill, shard.idx) {
                panic!("injected shard kill (shard {})", shard.idx);
            }
        }
        // Dequeue under the shard's *effective* policy: the configured (or
        // adaptively widened) max_batch and — the shard.rs:584 fix — the
        // policy's own max_wait, not a hardcoded constant. While stopping,
        // never linger: drain what's there immediately.
        let eff = shard.batcher.effective();
        let max_wait = if stopping { Duration::ZERO } else { eff.max_wait };
        let batch = shard.queue.pop_batch(eff.max_batch.max(1), max_wait);
        if batch.is_empty() {
            if stopping {
                if shard.queue.len() == 0 {
                    return;
                }
                continue;
            }
            let stole = pool.cfg.steal && pool.try_steal(shard);
            if !stole {
                // Nothing anywhere: park until a push lands (or a short
                // timeout to re-check stop/generation/steal targets)
                // rather than spinning on a zero-wait policy.
                shard.queue.wait_nonempty(Duration::from_millis(5));
            }
            continue;
        }
        // Depth the dequeue observed: what we took plus what is still
        // queued behind it — the adaptive policy's widen/decay signal.
        shard.batcher.observe_depth(batch.len() + shard.queue.len());
        pool.run_batch(shard, shard, batch);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk_req(model: &str) -> (Request, mpsc::Receiver<ServeResult>) {
        let (tx, rx) = mpsc::channel();
        (
            Request {
                model: model.to_string(),
                input: Tensor::zeros(&[1]),
                reply: tx,
                enqueued: Instant::now(),
                deadline: None,
            },
            rx,
        )
    }

    fn mk_queue(capacity: usize) -> ShardQueue {
        ShardQueue::new(capacity, Arc::new(ShardStats::default()))
    }

    #[test]
    fn home_shard_is_stable_and_in_range() {
        for shards in 1..8 {
            for model in ["ball", "pedestrian", "robot", "tiny"] {
                let h = home_shard(model, shards);
                assert!(h < shards);
                assert_eq!(h, home_shard(model, shards), "stable");
            }
        }
        assert_eq!(home_shard("anything", 1), 0);
        assert_eq!(home_shard("anything", 0), 0, "degenerate shard count clamps");
    }

    #[test]
    fn queue_bounds_per_model_not_globally() {
        let q = mk_queue(2);
        let mut keep = Vec::new();
        for i in 0..2 {
            let (req, rx) = mk_req("a");
            assert!(q.push(SeqReq { seq: i, req }).is_ok());
            keep.push(rx);
        }
        // Model "a" is at capacity; model "b" still has its own budget.
        let (req, _rx) = mk_req("a");
        assert!(q.push(SeqReq { seq: 10, req }).is_err(), "per-model bound hit");
        let (req, rx_b) = mk_req("b");
        assert!(q.push(SeqReq { seq: 11, req }).is_ok(), "other model unaffected");
        keep.push(rx_b);
        assert_eq!(q.len(), 3);
        // Popping frees the model's budget again.
        let popped = q.pop_batch(1, Duration::ZERO);
        assert_eq!(popped.len(), 1);
        assert_eq!(popped[0].req.model, "a");
        let (req, rx) = mk_req("a");
        assert!(q.push(SeqReq { seq: 12, req }).is_ok());
        keep.push(rx);
    }

    /// Batched dequeue resolves the engine once per distinct model in the
    /// batch, but every request must keep its own reply — successes for
    /// the registered model and `ModelUnknown` errors for the ghost
    /// model, interleaved through the same memoized batch.
    #[test]
    fn batched_dequeue_preserves_per_request_replies() {
        use crate::graph::zoo;
        use crate::interp::InterpEngine;
        let router = Arc::new(Router::new());
        let engine: Arc<dyn InferenceEngine> =
            Arc::new(InterpEngine::new(zoo::tiny_test_net().with_random_weights(3)).unwrap());
        router.register("tiny", engine);
        let cfg = ShardConfig {
            shards: 1,
            workers_per_shard: 1,
            batch: BatcherPolicy::batched(4, Duration::from_millis(1)),
            ..ShardConfig::default()
        };
        let handle = super::super::serve_sharded(router, cfg);
        let mut rxs = Vec::new();
        for i in 0..9 {
            let model = if i % 3 == 2 { "ghost" } else { "tiny" };
            let rx = handle.submit(model, Tensor::zeros(&[8, 8, 1]), None).unwrap();
            rxs.push((model, rx));
        }
        for (model, rx) in rxs {
            let res = rx.recv().unwrap_or(Err(ServeError::Stopped));
            match (model, res) {
                ("tiny", Ok(_)) => {}
                ("ghost", Err(ServeError::ModelUnknown { registered, .. })) => {
                    assert_eq!(registered, vec!["tiny".to_string()]);
                }
                (m, other) => panic!("{m}: unexpected reply {other:?}"),
            }
        }
        let snap = handle.stop();
        assert_eq!(snap.total_requests, 9);
    }

    /// The steal-order property, pinned **under every steal policy**:
    /// interleaving owner pops and policy-sized steals in any pattern
    /// consumes the queue exactly in submission (seq) order — a steal
    /// takes the *oldest* work whatever the policy's amount, so a single
    /// submitter's requests are never dequeued out of order, and none are
    /// lost or duplicated.
    #[test]
    fn property_steals_never_reorder_dequeue_for_a_single_submitter() {
        use crate::util::XorShift64;
        for policy in StealPolicy::ALL {
            let mut rng = XorShift64::new(7);
            for _round in 0..20 {
                let q = mk_queue(4096);
                let total = 64 + rng.below(64) as u64;
                let mut _rxs = Vec::new();
                for seq in 1..=total {
                    let (req, rx) = mk_req("tiny");
                    q.push(SeqReq { seq, req }).unwrap();
                    _rxs.push(rx);
                }
                let mut consumed: Vec<u64> = Vec::new();
                while consumed.len() < total as usize {
                    // Randomly interleave owner pops of random sizes with
                    // steals sized by the policy under test.
                    let batch = if rng.below(2) == 0 {
                        q.pop_batch(1 + rng.below(5), Duration::ZERO)
                    } else {
                        q.steal_batch(policy.take_count(q.len()).max(1))
                    };
                    consumed.extend(batch.iter().map(|sr| sr.seq));
                }
                let expected: Vec<u64> = (1..=total).collect();
                assert_eq!(
                    consumed, expected,
                    "dequeue order must equal submission order under {}",
                    policy.name()
                );
                assert_eq!(q.len(), 0);
            }
        }
    }

    #[test]
    fn steal_policy_names_round_trip_and_default_is_half_length() {
        assert_eq!(StealPolicy::default(), StealPolicy::HalfLength);
        for p in StealPolicy::ALL {
            assert_eq!(StealPolicy::parse(p.name()), Some(p), "{}", p.name());
        }
        assert_eq!(StealPolicy::parse("steal-everything"), None);
    }

    #[test]
    fn steal_policy_take_counts() {
        for backlog in [0usize, 1, 2, 5, 100] {
            assert_eq!(StealPolicy::HalfLength.take_count(backlog), (backlog + 1) / 2);
            assert_eq!(StealPolicy::HalfAge.take_count(backlog), (backlog + 1) / 2);
            assert_eq!(StealPolicy::OneLength.take_count(backlog), backlog.min(1));
            assert_eq!(StealPolicy::OneAge.take_count(backlog), backlog.min(1));
        }
    }

    #[test]
    fn choose_victim_by_length_and_by_age() {
        // (shard idx, queue len, front admission seq)
        let candidates = [
            (0, 3, Some(40u64)),
            (1, 7, Some(90)), // longest
            (2, 2, Some(10)), // oldest head-of-line
            (3, 0, None),     // empty: never a victim
        ];
        assert_eq!(choose_victim(StealPolicy::HalfLength, &candidates), Some(1));
        assert_eq!(choose_victim(StealPolicy::OneLength, &candidates), Some(1));
        assert_eq!(choose_victim(StealPolicy::HalfAge, &candidates), Some(2));
        assert_eq!(choose_victim(StealPolicy::OneAge, &candidates), Some(2));
        // All-empty: no victim under any policy.
        let empty = [(0, 0, None), (1, 0, None)];
        for p in StealPolicy::ALL {
            assert_eq!(choose_victim(p, &empty), None, "{}", p.name());
        }
        // A non-empty snapshot that lost its front to a racing pop is
        // treated as newest: by age it loses to any real front.
        let racy = [(0, 1, None), (1, 1, Some(5))];
        assert_eq!(choose_victim(StealPolicy::OneAge, &racy), Some(1));
        // Length ties keep the first candidate (stable choice).
        let tied = [(0, 4, Some(2)), (1, 4, Some(1))];
        assert_eq!(choose_victim(StealPolicy::HalfLength, &tied), Some(0));
    }

    #[test]
    fn drain_all_empties_and_resets_bounds() {
        let q = mk_queue(2);
        let mut _rxs = Vec::new();
        for seq in 0..2 {
            let (req, rx) = mk_req("m");
            q.push(SeqReq { seq, req }).unwrap();
            _rxs.push(rx);
        }
        let drained = q.drain_all();
        assert_eq!(drained.len(), 2);
        assert_eq!(q.len(), 0);
        let (req, _rx) = mk_req("m");
        assert!(q.push(SeqReq { seq: 9, req }).is_ok(), "budget freed by drain");
    }

    #[test]
    fn shard_config_default_is_sane() {
        let cfg = ShardConfig::default();
        assert_eq!(cfg.shards, 1);
        assert!(cfg.steal);
        assert!(cfg.breaker.failure_threshold > 3, "shard ejection needs more evidence");
        assert_eq!(cfg.batch.max_batch, 1);
        assert!(!cfg.batch_adapt, "adaptive batching is opt-in");
    }

    /// Regression for the shard.rs:584 bug: the dequeue must honor the
    /// configured `max_wait`, not a hardcoded constant. A zero-wait
    /// (immediate) policy never sleeps — empty or not — and a 50 ms
    /// policy lingers for the batch to fill before returning short.
    #[test]
    fn pop_batch_honors_configured_max_wait() {
        // Zero wait, empty queue: returns empty immediately.
        let q = mk_queue(16);
        let t0 = Instant::now();
        assert!(q.pop_batch(4, Duration::ZERO).is_empty());
        assert!(t0.elapsed() < Duration::from_millis(20), "zero-wait dequeue slept");

        // Zero wait, one queued item: returns it immediately, no lingering
        // for the batch to fill.
        let (req, _rx) = mk_req("m");
        q.push(SeqReq { seq: 1, req }).unwrap();
        let t0 = Instant::now();
        assert_eq!(q.pop_batch(4, Duration::ZERO).len(), 1);
        assert!(t0.elapsed() < Duration::from_millis(20), "zero-wait dequeue slept");

        // 50 ms wait, one queued item, room for 4: lingers for the batch
        // to fill, then returns the short batch at the deadline.
        let (req, _rx2) = mk_req("m");
        q.push(SeqReq { seq: 2, req }).unwrap();
        let t0 = Instant::now();
        let got = q.pop_batch(4, Duration::from_millis(50));
        assert_eq!(got.len(), 1);
        assert!(t0.elapsed() >= Duration::from_millis(40), "waited {:?}", t0.elapsed());

        // 500 ms wait with the batch already full: returns immediately.
        let mut _rxs = Vec::new();
        for seq in 3..7 {
            let (req, rx) = mk_req("m");
            q.push(SeqReq { seq, req }).unwrap();
            _rxs.push(rx);
        }
        let t0 = Instant::now();
        assert_eq!(q.pop_batch(4, Duration::from_millis(500)).len(), 4);
        assert!(t0.elapsed() < Duration::from_millis(100), "full batch still lingered");
    }

    /// In-flight accounting is unwind-safe and observable: `take_front`
    /// charges under the queue lock, `InFlightGuard::drop` releases what a
    /// mid-batch panic left unpaid, and `wait_quiesced` wakes on the
    /// zero transition.
    #[test]
    fn in_flight_guard_releases_on_drop_and_quiesce_wakes() {
        let q = Arc::new(mk_queue(16));
        let mut _rxs = Vec::new();
        for seq in 0..3 {
            let (req, rx) = mk_req("m");
            q.push(SeqReq { seq, req }).unwrap();
            _rxs.push(rx);
        }
        let batch = q.pop_batch(3, Duration::ZERO);
        assert_eq!(batch.len(), 3);
        assert_eq!(q.in_flight.load(Ordering::SeqCst), 3);
        assert!(!q.wait_quiesced(Duration::from_millis(1)), "work in flight");

        let mut guard = InFlightGuard { queue: &*q, remaining: 3 };
        guard.done_one();
        assert_eq!(q.in_flight.load(Ordering::SeqCst), 2);

        // A waiter parked on quiescence is woken by the drop-release of
        // the remaining two slots.
        let q2 = Arc::clone(&q);
        let waiter = std::thread::spawn(move || {
            let t0 = Instant::now();
            while !q2.wait_quiesced(Duration::from_millis(200)) {
                assert!(t0.elapsed() < Duration::from_secs(5), "quiesce never woke");
            }
        });
        std::thread::sleep(Duration::from_millis(10));
        drop(guard); // panic-path stand-in: releases the remaining 2
        waiter.join().unwrap();
        assert_eq!(q.in_flight.load(Ordering::SeqCst), 0);
    }

    /// End-to-end batched dispatch: with an adaptive policy capped at 4, a
    /// burst through one shard produces multi-request `infer_batch`
    /// dispatches, every request still gets its own correct reply, and the
    /// realized batch width never exceeds the cap.
    #[test]
    fn adaptive_batched_dispatch_serves_burst_within_cap() {
        use crate::graph::zoo;
        use crate::interp::InterpEngine;
        let router = Arc::new(Router::new());
        let engine: Arc<dyn InferenceEngine> =
            Arc::new(InterpEngine::new(zoo::tiny_test_net().with_random_weights(3)).unwrap());
        router.register("tiny", engine);
        let cfg = ShardConfig {
            shards: 1,
            workers_per_shard: 1,
            batch: BatcherPolicy::batched(4, Duration::from_millis(5)),
            batch_adapt: true,
            ..ShardConfig::default()
        };
        let handle = super::super::serve_sharded(router, cfg);
        let mut rxs = Vec::new();
        for _ in 0..32 {
            rxs.push(handle.submit("tiny", Tensor::zeros(&[8, 8, 1]), None).unwrap());
        }
        for rx in rxs {
            let res = rx.recv().unwrap_or(Err(ServeError::Stopped));
            let y = res.expect("burst request should be served");
            assert_eq!(y.dims(), &[2, 2, 2]);
        }
        let snap = handle.stop();
        assert_eq!(snap.total_requests, 32);
        assert_eq!(snap.errors, 0);
        assert!(snap.batch_size_max <= 4, "adaptive width exceeded cap: {}", snap.batch_size_max);
        if snap.batched_infers > 0 {
            assert!(snap.batched_requests >= 2 * snap.batched_infers);
            assert!(snap.batch_size_mean() >= 2.0);
        }
    }
}
