//! Serving coordinator — the L3 runtime that owns the event loop.
//!
//! The paper's deployment story is an embedded vision loop: frames arrive,
//! candidate patches are extracted, and a batch of small CNN inferences
//! must complete with minimal *latency* (not throughput — §I-A motivates
//! why). The coordinator provides:
//!
//! * [`Router`] — model registry mapping names to [`InferenceEngine`]s
//!   (generated-C, interpreter, or XLA/PJRT backends are interchangeable).
//! * [`Batcher`] — size/deadline micro-batching policy, used to quantify
//!   the latency-vs-throughput trade-off the paper discusses for GPUs.
//! * [`serve`] — a worker-thread request loop (std mpsc; tokio is not in
//!   the offline crate set) with per-request latency metrics.

mod batcher;
mod metrics;
mod router;

pub use batcher::{Batcher, BatcherPolicy};
pub use metrics::{LatencyRecorder, MetricsSnapshot};
pub use router::Router;

use crate::runtime::InferenceEngine;
use crate::tensor::Tensor;
use anyhow::Result;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc;
use std::sync::Arc;
use std::time::Instant;

/// One inference request flowing through the coordinator.
pub struct Request {
    pub model: String,
    pub input: Tensor,
    /// Reply channel; the worker sends the result exactly once.
    pub reply: mpsc::Sender<Result<Tensor>>,
    /// Enqueue timestamp for latency accounting.
    pub enqueued: Instant,
}

/// Handle to a running coordinator.
pub struct ServerHandle {
    tx: mpsc::Sender<Request>,
    stop: Arc<AtomicBool>,
    workers: Vec<std::thread::JoinHandle<()>>,
    pub metrics: Arc<LatencyRecorder>,
}

impl ServerHandle {
    /// Submit a request and wait for the reply (client-side latency).
    pub fn infer(&self, model: &str, input: Tensor) -> Result<Tensor> {
        let (reply_tx, reply_rx) = mpsc::channel();
        self.tx
            .send(Request { model: model.to_string(), input, reply: reply_tx, enqueued: Instant::now() })
            .map_err(|_| anyhow::anyhow!("coordinator stopped"))?;
        reply_rx.recv().map_err(|_| anyhow::anyhow!("worker dropped reply"))?
    }

    /// Fire-and-collect a burst of requests (per-frame candidate batch).
    pub fn infer_burst(&self, model: &str, inputs: Vec<Tensor>) -> Result<Vec<Tensor>> {
        let mut receivers = Vec::with_capacity(inputs.len());
        for input in inputs {
            let (reply_tx, reply_rx) = mpsc::channel();
            self.tx
                .send(Request { model: model.to_string(), input, reply: reply_tx, enqueued: Instant::now() })
                .map_err(|_| anyhow::anyhow!("coordinator stopped"))?;
            receivers.push(reply_rx);
        }
        receivers
            .into_iter()
            .map(|rx| rx.recv().map_err(|_| anyhow::anyhow!("worker dropped reply"))?)
            .collect()
    }

    /// Stop workers and join them.
    pub fn shutdown(mut self) {
        self.stop.store(true, Ordering::SeqCst);
        drop(self.tx);
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

/// Start the coordinator with `n_workers` threads over a router.
pub fn serve(router: Arc<Router>, n_workers: usize) -> ServerHandle {
    let (tx, rx) = mpsc::channel::<Request>();
    let rx = Arc::new(std::sync::Mutex::new(rx));
    let stop = Arc::new(AtomicBool::new(false));
    let metrics = Arc::new(LatencyRecorder::new());
    let mut workers = Vec::new();
    for _ in 0..n_workers.max(1) {
        let rx = Arc::clone(&rx);
        let router = Arc::clone(&router);
        let stop = Arc::clone(&stop);
        let metrics = Arc::clone(&metrics);
        workers.push(std::thread::spawn(move || {
            loop {
                let req = {
                    let guard = rx.lock().unwrap();
                    match guard.recv_timeout(std::time::Duration::from_millis(50)) {
                        Ok(r) => r,
                        Err(mpsc::RecvTimeoutError::Timeout) => {
                            if stop.load(Ordering::SeqCst) {
                                return;
                            }
                            continue;
                        }
                        Err(mpsc::RecvTimeoutError::Disconnected) => return,
                    }
                };
                let queue_us = req.enqueued.elapsed().as_secs_f64() * 1e6;
                let t0 = Instant::now();
                let result = router.infer(&req.model, &req.input);
                let infer_us = t0.elapsed().as_secs_f64() * 1e6;
                metrics.record(&req.model, queue_us, infer_us, result.is_ok());
                let _ = req.reply.send(result);
            }
        }));
    }
    ServerHandle { tx, stop, workers, metrics }
}

/// Convenience: a coordinator over a single engine registered as `model`.
pub fn serve_single(model: &str, engine: Arc<dyn InferenceEngine>, n_workers: usize) -> ServerHandle {
    let mut router = Router::new();
    router.register(model, engine);
    serve(Arc::new(router), n_workers)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::zoo;
    use crate::interp::InterpEngine;
    use crate::util::XorShift64;

    fn tiny_engine() -> Arc<dyn InferenceEngine> {
        Arc::new(InterpEngine::new(zoo::tiny_test_net().with_random_weights(3)).unwrap())
    }

    #[test]
    fn serve_round_trip() {
        let h = serve_single("tiny", tiny_engine(), 2);
        let mut rng = XorShift64::new(1);
        let x = Tensor::rand(&[8, 8, 1], 0.0, 1.0, &mut rng);
        let y = h.infer("tiny", x).unwrap();
        assert_eq!(y.dims(), &[2, 2, 2]);
        let snap = h.metrics.snapshot();
        assert_eq!(snap.total_requests, 1);
        assert_eq!(snap.errors, 0);
        h.shutdown();
    }

    #[test]
    fn unknown_model_is_an_error_reply() {
        let h = serve_single("tiny", tiny_engine(), 1);
        let res = h.infer("nonexistent", Tensor::zeros(&[8, 8, 1]));
        assert!(res.is_err());
        assert_eq!(h.metrics.snapshot().errors, 1);
        h.shutdown();
    }

    #[test]
    fn burst_of_candidates() {
        let h = serve_single("tiny", tiny_engine(), 2);
        let mut rng = XorShift64::new(2);
        let inputs: Vec<Tensor> = (0..20).map(|_| Tensor::rand(&[8, 8, 1], 0.0, 1.0, &mut rng)).collect();
        let outs = h.infer_burst("tiny", inputs).unwrap();
        assert_eq!(outs.len(), 20);
        assert_eq!(h.metrics.snapshot().total_requests, 20);
        h.shutdown();
    }

    #[test]
    fn shutdown_joins_cleanly() {
        let h = serve_single("tiny", tiny_engine(), 3);
        h.shutdown(); // must not hang
    }
}
