//! Serving coordinator — the L3 runtime that owns the event loop.
//!
//! The paper's deployment story is an embedded vision loop: frames arrive,
//! candidate patches are extracted, and a batch of small CNN inferences
//! must complete with minimal *latency* (not throughput — §I-A motivates
//! why). The coordinator provides:
//!
//! * [`Router`] — model registry mapping names to [`InferenceEngine`]s
//!   (generated-C, interpreter, or XLA/PJRT backends are interchangeable),
//!   interior-mutable for hot-swap while serving.
//! * [`Batcher`] — size/deadline micro-batching policy, used to quantify
//!   the latency-vs-throughput trade-off the paper discusses for GPUs.
//! * [`serve`]/[`serve_with`]/[`serve_sharded`] — a sharded worker pool
//!   (std threads; tokio is not in the offline crate set): N shards with
//!   model-affinity routing, bounded per-model queues, optional work
//!   stealing, per-shard health breakers, panic isolation with worker
//!   respawn, graceful drain/restart, and typed [`ServeError`] replies.
//!   `serve`/`serve_with` keep the single-queue-era API on top of a
//!   one-shard pool (shard count overridable via `NNCG_SERVE_SHARDS`).
//! * [`HealPipeline`] — per-model background rebuild slots that hot-swap
//!   a freshly compiled engine via [`Router::register`] without blocking
//!   the request path.
//!
//! The contract is **exactly one reply per accepted request**: either a
//! tensor or a `ServeError`. A panicking engine, a shed request, a stolen
//! queue entry, and a shutdown all produce a reply — `infer_burst` can
//! never hang on a dead worker.

mod batcher;
mod error;
mod fallback;
mod metrics;
mod net;
pub mod proto;
mod router;
mod shard;

pub use batcher::{AdaptiveBatcher, Batcher, BatcherPolicy};
pub use error::ServeError;
pub use fallback::{
    BreakerConfig, BreakerEvent, BreakerState, CircuitBreaker, FallbackEngine, HealPipeline,
};
pub use metrics::{
    LatencyHisto, LatencyRecorder, MetricsSnapshot, ModelStats, ServeCounters, ShardSnapshot,
    ShardStats,
};
pub use net::{NetClient, NetConfig, NetError, NetServer, RemoteError};
pub use router::Router;
pub use shard::{home_shard, ShardConfig, ShardPool, StealPolicy};

use crate::runtime::InferenceEngine;
use crate::tensor::Tensor;
use crate::util::panic_message;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::Ordering;
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

/// Reply type for every request: a tensor or a typed serving error. The
/// vendored `anyhow` shim has no downcast, so the typed error is returned
/// directly; `?` in anyhow-returning callers still works via `From`.
pub type ServeResult = Result<Tensor, ServeError>;

/// One inference request flowing through the coordinator.
pub struct Request {
    pub model: String,
    pub input: Tensor,
    /// Reply channel; the coordinator sends the result exactly once.
    pub reply: mpsc::Sender<ServeResult>,
    /// Enqueue timestamp for latency accounting.
    pub enqueued: Instant,
    /// If set and already past when a worker dequeues the request, the
    /// request is shed with [`ServeError::DeadlineExceeded`] instead of
    /// computing a stale frame.
    pub deadline: Option<Instant>,
}

/// Serving configuration (single-queue-era shape, kept stable; maps onto
/// [`ShardConfig`] with one shard unless `NNCG_SERVE_SHARDS` overrides).
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Worker threads (min 1). Under sharding this is workers *per shard*.
    pub workers: usize,
    /// Bounded queue capacity; submissions beyond it are shed with
    /// [`ServeError::QueueFull`] instead of growing an unbounded backlog
    /// (min 1). Under sharding the bound is per shard, per model.
    pub queue_capacity: usize,
    /// Deadline applied to requests submitted without an explicit one.
    pub default_deadline: Option<Duration>,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig { workers: 1, queue_capacity: 1024, default_deadline: None }
    }
}

/// Handle to a running coordinator (a [`ShardPool`] plus submission
/// defaults). Single-owner control surface; clone [`Submitter`]s for
/// multi-threaded clients.
pub struct ServerHandle {
    pool: Arc<ShardPool>,
    pub metrics: Arc<LatencyRecorder>,
    default_deadline: Option<Duration>,
}

impl ServerHandle {
    /// Submit a request; returns the reply receiver, or sheds immediately
    /// if the routed shard's queue is full / the coordinator has stopped.
    pub fn submit(
        &self,
        model: &str,
        input: Tensor,
        deadline: Option<Duration>,
    ) -> Result<mpsc::Receiver<ServeResult>, ServeError> {
        let deadline = deadline.or(self.default_deadline).map(|d| Instant::now() + d);
        self.pool.submit(model, input, deadline)
    }

    /// Submit a request and wait for the reply (client-side latency).
    pub fn infer(&self, model: &str, input: Tensor) -> ServeResult {
        self.infer_with_deadline(model, input, None)
    }

    /// Submit with an explicit deadline and wait for the reply.
    pub fn infer_with_deadline(
        &self,
        model: &str,
        input: Tensor,
        deadline: Option<Duration>,
    ) -> ServeResult {
        let rx = self.submit(model, input, deadline)?;
        rx.recv().unwrap_or(Err(ServeError::Stopped))
    }

    /// Fire-and-collect a burst of requests (per-frame candidate batch).
    /// Every accepted request gets a reply; the first error wins but all
    /// receivers are drained first so no reply is abandoned mid-flight.
    pub fn infer_burst(&self, model: &str, inputs: Vec<Tensor>) -> Result<Vec<Tensor>, ServeError> {
        let mut receivers = Vec::with_capacity(inputs.len());
        for input in inputs {
            receivers.push(self.submit(model, input, None)?);
        }
        let mut outs = Vec::with_capacity(receivers.len());
        let mut first_err: Option<ServeError> = None;
        for rx in receivers {
            match rx.recv().unwrap_or(Err(ServeError::Stopped)) {
                Ok(y) => outs.push(y),
                Err(e) => first_err = first_err.or(Some(e)),
            }
        }
        match first_err {
            None => Ok(outs),
            Some(e) => Err(e),
        }
    }

    /// A cloneable submission endpoint sharing this coordinator's pool —
    /// hand one to each client thread (the load benchmark, the CLI's
    /// frame loop).
    pub fn submitter(&self) -> Submitter {
        Submitter { pool: Arc::clone(&self.pool), default_deadline: self.default_deadline }
    }

    /// Number of shards in the pool.
    pub fn shards(&self) -> usize {
        self.pool.shards()
    }

    /// The shard a model's requests route to when healthy.
    pub fn home_shard(&self, model: &str) -> usize {
        home_shard(model, self.pool.shards())
    }

    /// Drain and restart one shard under live traffic (see
    /// [`ShardPool::recycle_shard`]).
    pub fn recycle_shard(&self, idx: usize) -> bool {
        self.pool.recycle_shard(idx)
    }

    /// Drain the queues, join the workers, and return the final metrics —
    /// every accepted request is answered (served or shed) before the
    /// workers exit: drain-then-join, not drop-on-the-floor.
    pub fn stop(self) -> MetricsSnapshot {
        self.pool.shutdown_blocking(None)
    }

    /// [`Self::stop`] with a deadline: drains until `timeout` fires, then
    /// answers anything still queued with a typed [`ServeError::Stopped`]
    /// reply and detaches any wedged worker instead of hanging shutdown.
    pub fn stop_with_timeout(self, timeout: Duration) -> MetricsSnapshot {
        self.pool.shutdown_blocking(Some(timeout))
    }

    /// Stop workers and join them (compat wrapper over [`Self::stop`]).
    pub fn shutdown(self) {
        let _ = self.stop();
    }
}

impl Drop for ServerHandle {
    fn drop(&mut self) {
        // A handle dropped without `stop()` must not strand worker threads
        // in a live loop; closing admission lets them drain and exit.
        // Idempotent after a normal `stop()`.
        self.pool.begin_stop();
    }
}

/// Cloneable submission endpoint over a shared [`ShardPool`]. Does not
/// own shutdown — submissions after the owning handle stopped return
/// [`ServeError::Stopped`].
#[derive(Clone)]
pub struct Submitter {
    pool: Arc<ShardPool>,
    default_deadline: Option<Duration>,
}

impl Submitter {
    /// See [`ServerHandle::submit`].
    pub fn submit(
        &self,
        model: &str,
        input: Tensor,
        deadline: Option<Duration>,
    ) -> Result<mpsc::Receiver<ServeResult>, ServeError> {
        let deadline = deadline.or(self.default_deadline).map(|d| Instant::now() + d);
        self.pool.submit(model, input, deadline)
    }

    /// Submit and wait for the reply.
    pub fn infer(&self, model: &str, input: Tensor) -> ServeResult {
        match self.submit(model, input, None) {
            Ok(rx) => rx.recv().unwrap_or(Err(ServeError::Stopped)),
            Err(e) => Err(e),
        }
    }

    /// Whether an engine is registered under `model`. The net front-end
    /// checks this *before* submitting so unknown-model frames never
    /// consume a shard-queue slot.
    pub fn has_model(&self, model: &str) -> bool {
        self.pool.router().contains(model)
    }

    /// Registered model names, sorted (for `ModelUnknown` replies).
    pub fn registered_models(&self) -> Vec<String> {
        self.pool.router().models()
    }

    /// The pool's shared serving counters (net front-end instrumentation).
    pub fn counters(&self) -> Arc<ServeCounters> {
        Arc::clone(self.pool.metrics().counters())
    }
}

/// Replies `EngineFailed` on drop unless defused — the exactly-once
/// backstop for a worker that unwinds mid-request.
struct ReplyGuard {
    reply: Option<mpsc::Sender<ServeResult>>,
    model: String,
}

impl ReplyGuard {
    fn new(reply: mpsc::Sender<ServeResult>, model: &str) -> Self {
        ReplyGuard { reply: Some(reply), model: model.to_string() }
    }

    fn send(mut self, result: ServeResult) {
        if let Some(tx) = self.reply.take() {
            let _ = tx.send(result);
        }
    }
}

impl Drop for ReplyGuard {
    fn drop(&mut self) {
        if let Some(tx) = self.reply.take() {
            let _ = tx.send(Err(ServeError::EngineFailed {
                model: self.model.clone(),
                reason: "worker crashed mid-request".into(),
            }));
        }
    }
}

/// How one request's execution went, as seen by the executing shard's
/// health breaker: sheds (deadline, unknown model) are client-side events
/// and say nothing about shard health.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub(crate) enum ExecOutcome {
    Served,
    Failed,
    Shed,
}

/// Start the coordinator with explicit robustness configuration
/// (single-queue-era API). The pool defaults to one shard with stealing
/// off — bit-compatible with the PR 6 coordinator — and honors
/// `NNCG_SERVE_SHARDS=<n>` / `NNCG_SERVE_STEAL=on` so existing callers
/// (and the chaos suite, unchanged) can be re-run against a sharded pool.
pub fn serve_with(router: Arc<Router>, cfg: ServeConfig) -> ServerHandle {
    let shards = std::env::var("NNCG_SERVE_SHARDS")
        .ok()
        .and_then(|v| v.trim().parse::<usize>().ok())
        .unwrap_or(1)
        .max(1);
    let steal = matches!(
        std::env::var("NNCG_SERVE_STEAL").as_deref().map(str::trim),
        Ok("on") | Ok("1") | Ok("true")
    );
    let steal_policy = std::env::var("NNCG_SERVE_STEAL_POLICY")
        .ok()
        .and_then(|v| StealPolicy::parse(v.trim()))
        .unwrap_or_default();
    serve_sharded(
        router,
        ShardConfig {
            shards,
            workers_per_shard: cfg.workers.max(1),
            queue_capacity: cfg.queue_capacity.max(1),
            default_deadline: cfg.default_deadline,
            steal,
            steal_policy,
            faults: crate::faults::FaultPlan::from_env().ok().flatten(),
            ..ShardConfig::default()
        },
    )
}

/// Start a sharded coordinator with explicit shard configuration.
pub fn serve_sharded(router: Arc<Router>, cfg: ShardConfig) -> ServerHandle {
    let default_deadline = cfg.default_deadline;
    let pool = ShardPool::start(router, cfg);
    let metrics = Arc::clone(pool.metrics());
    ServerHandle { pool, metrics, default_deadline }
}

/// Start the coordinator with `n_workers` threads over a router
/// (default queue bound, no default deadline).
pub fn serve(router: Arc<Router>, n_workers: usize) -> ServerHandle {
    serve_with(router, ServeConfig { workers: n_workers, ..ServeConfig::default() })
}

/// Convenience: a coordinator over a single engine registered as `model`.
pub fn serve_single(model: &str, engine: Arc<dyn InferenceEngine>, n_workers: usize) -> ServerHandle {
    let router = Router::new();
    router.register(model, engine);
    serve(Arc::new(router), n_workers)
}

/// Execute one dequeued request: shed if stale, route, run the engine
/// under panic isolation, record metrics, and reply exactly once.
pub(crate) fn execute(req: Request, router: &Router, metrics: &LatencyRecorder) -> ExecOutcome {
    execute_with(req, None, router, metrics)
}

/// [`execute`] with an optionally pre-resolved engine. Batch executors
/// pass `Some` so a dequeued batch shares one router lookup per distinct
/// model instead of taking the registry read-lock per request; `None`
/// resolves here. `resolved` must be the engine registered for
/// `req.model` (a stale pre-resolution after a hot-swap simply serves the
/// batch on the engine it was admitted under).
pub(crate) fn execute_with(
    req: Request,
    resolved: Option<Arc<dyn InferenceEngine>>,
    router: &Router,
    metrics: &LatencyRecorder,
) -> ExecOutcome {
    let Request { model, input, reply, enqueued, deadline } = req;
    let guard = ReplyGuard::new(reply, &model);
    let now = Instant::now();

    // Shed stale frames before spending compute on them.
    if let Some(dl) = deadline {
        if now >= dl {
            ServeCounters::bump(&metrics.counters().deadline_sheds);
            let late_by_us = now.duration_since(dl).as_micros() as u64;
            guard.send(Err(ServeError::DeadlineExceeded { model, late_by_us }));
            return ExecOutcome::Shed;
        }
    }

    let queue_us = now.duration_since(enqueued).as_secs_f64() * 1e6;
    let engine = match resolved.map(Ok).unwrap_or_else(|| router.engine(&model)) {
        Ok(e) => e,
        Err(_) => {
            metrics.record(&model, queue_us, 0.0, false);
            let registered = router.models();
            guard.send(Err(ServeError::ModelUnknown { model, registered }));
            return ExecOutcome::Shed;
        }
    };

    let t0 = Instant::now();
    let outcome = catch_unwind(AssertUnwindSafe(|| engine.infer(&input)));
    let infer_us = t0.elapsed().as_secs_f64() * 1e6;
    match outcome {
        Ok(Ok(y)) => {
            metrics.record(&model, queue_us, infer_us, true);
            guard.send(Ok(y));
            ExecOutcome::Served
        }
        Ok(Err(e)) => {
            ServeCounters::bump(&metrics.counters().engine_failures);
            metrics.record(&model, queue_us, infer_us, false);
            guard.send(Err(ServeError::EngineFailed { model, reason: format!("{e:#}") }));
            ExecOutcome::Failed
        }
        Err(payload) => {
            ServeCounters::bump(&metrics.counters().engine_panics);
            metrics.record(&model, queue_us, infer_us, false);
            let reason = format!("engine panicked: {}", panic_message(&*payload));
            guard.send(Err(ServeError::EngineFailed { model, reason }));
            ExecOutcome::Failed
        }
    }
}

/// Execute a same-model run of ≥ 2 dequeued requests through **one**
/// `engine.infer_batch` call — the real amortization the batched entry
/// point exists for. Per-request semantics match [`execute_with`] exactly:
/// stale requests shed individually before compute, every request gets
/// exactly one reply (Drop-backstopped), and an engine error or panic
/// fails only this batch's live requests. Returns one outcome per request,
/// in order.
pub(crate) fn execute_batch_with(
    reqs: Vec<Request>,
    engine: Arc<dyn InferenceEngine>,
    metrics: &LatencyRecorder,
) -> Vec<ExecOutcome> {
    let n_total = reqs.len();
    let mut outcomes = vec![ExecOutcome::Shed; n_total];
    let now = Instant::now();

    // Unpack, arm a reply guard per request, and shed stale frames first so
    // the engine call covers only live work.
    let mut live: Vec<(usize, String, Tensor, ReplyGuard, f64)> = Vec::with_capacity(n_total);
    for (i, req) in reqs.into_iter().enumerate() {
        let Request { model, input, reply, enqueued, deadline } = req;
        let guard = ReplyGuard::new(reply, &model);
        if let Some(dl) = deadline {
            if now >= dl {
                ServeCounters::bump(&metrics.counters().deadline_sheds);
                let late_by_us = now.duration_since(dl).as_micros() as u64;
                guard.send(Err(ServeError::DeadlineExceeded { model, late_by_us }));
                continue; // outcomes[i] stays Shed
            }
        }
        let queue_us = now.duration_since(enqueued).as_secs_f64() * 1e6;
        live.push((i, model, input, guard, queue_us));
    }
    if live.is_empty() {
        return outcomes;
    }

    let inputs: Vec<Tensor> = live.iter().map(|(_, _, input, _, _)| input.clone()).collect();
    let c = metrics.counters();
    ServeCounters::bump(&c.batched_infers);
    c.batched_requests.fetch_add(live.len() as u64, Ordering::Relaxed);
    c.batch_size_max.fetch_max(live.len() as u64, Ordering::Relaxed);

    let t0 = Instant::now();
    let result = catch_unwind(AssertUnwindSafe(|| engine.infer_batch(&inputs)));
    // Per-request cost is the amortized share of the one engine call — the
    // latency a request actually paid, and the number that makes batched
    // vs single throughput comparable in per-model means.
    let infer_us = t0.elapsed().as_secs_f64() * 1e6 / live.len() as f64;

    match result {
        Ok(Ok(outs)) if outs.len() == live.len() => {
            for ((i, model, _, guard, queue_us), y) in live.into_iter().zip(outs) {
                metrics.record(&model, queue_us, infer_us, true);
                guard.send(Ok(y));
                outcomes[i] = ExecOutcome::Served;
            }
        }
        Ok(Ok(outs)) => {
            // A length mismatch is an engine contract bug: no way to know
            // which output belongs to which request, so fail them all.
            let reason =
                format!("batch returned {} outputs for {} inputs", outs.len(), live.len());
            ServeCounters::bump(&c.engine_failures);
            for (i, model, _, guard, queue_us) in live {
                metrics.record(&model, queue_us, infer_us, false);
                guard.send(Err(ServeError::EngineFailed { model, reason: reason.clone() }));
                outcomes[i] = ExecOutcome::Failed;
            }
        }
        Ok(Err(e)) => {
            let reason = format!("{e:#}");
            ServeCounters::bump(&c.engine_failures);
            for (i, model, _, guard, queue_us) in live {
                metrics.record(&model, queue_us, infer_us, false);
                guard.send(Err(ServeError::EngineFailed { model, reason: reason.clone() }));
                outcomes[i] = ExecOutcome::Failed;
            }
        }
        Err(payload) => {
            let reason = format!("engine panicked: {}", panic_message(&*payload));
            ServeCounters::bump(&c.engine_panics);
            for (i, model, _, guard, queue_us) in live {
                metrics.record(&model, queue_us, infer_us, false);
                guard.send(Err(ServeError::EngineFailed { model, reason: reason.clone() }));
                outcomes[i] = ExecOutcome::Failed;
            }
        }
    }
    outcomes
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::faults::{FaultPlan, FaultSite, FaultSpec, FaultyEngine};
    use crate::graph::zoo;
    use crate::interp::InterpEngine;
    use crate::util::XorShift64;

    fn tiny_engine() -> Arc<dyn InferenceEngine> {
        Arc::new(InterpEngine::new(zoo::tiny_test_net().with_random_weights(3)).unwrap())
    }

    #[test]
    fn serve_round_trip() {
        let h = serve_single("tiny", tiny_engine(), 2);
        let mut rng = XorShift64::new(1);
        let x = Tensor::rand(&[8, 8, 1], 0.0, 1.0, &mut rng);
        let y = h.infer("tiny", x).unwrap();
        assert_eq!(y.dims(), &[2, 2, 2]);
        let snap = h.metrics.snapshot();
        assert_eq!(snap.total_requests, 1);
        assert_eq!(snap.errors, 0);
        h.shutdown();
    }

    #[test]
    fn unknown_model_is_an_error_reply() {
        let h = serve_single("tiny", tiny_engine(), 1);
        let res = h.infer("nonexistent", Tensor::zeros(&[8, 8, 1]));
        match res {
            Err(ServeError::ModelUnknown { registered, .. }) => {
                assert_eq!(registered, vec!["tiny".to_string()]);
            }
            other => panic!("expected ModelUnknown, got {other:?}"),
        }
        assert_eq!(h.metrics.snapshot().errors, 1);
        h.shutdown();
    }

    #[test]
    fn burst_of_candidates() {
        let h = serve_single("tiny", tiny_engine(), 2);
        let mut rng = XorShift64::new(2);
        let inputs: Vec<Tensor> = (0..20).map(|_| Tensor::rand(&[8, 8, 1], 0.0, 1.0, &mut rng)).collect();
        let outs = h.infer_burst("tiny", inputs).unwrap();
        assert_eq!(outs.len(), 20);
        assert_eq!(h.metrics.snapshot().total_requests, 20);
        h.shutdown();
    }

    #[test]
    fn shutdown_joins_cleanly() {
        let h = serve_single("tiny", tiny_engine(), 3);
        h.shutdown(); // must not hang
    }

    #[test]
    fn expired_deadline_is_shed_with_typed_error() {
        let h = serve_single("tiny", tiny_engine(), 1);
        // Zero deadline: already expired by the time a worker dequeues it.
        let res = h.infer_with_deadline("tiny", Tensor::zeros(&[8, 8, 1]), Some(Duration::ZERO));
        match res {
            Err(ServeError::DeadlineExceeded { model, .. }) => assert_eq!(model, "tiny"),
            other => panic!("expected DeadlineExceeded, got {other:?}"),
        }
        let snap = h.stop();
        assert_eq!(snap.deadline_sheds, 1);
        assert_eq!(snap.total_requests, 0, "shed requests don't pollute latency stats");
    }

    #[test]
    fn engine_panic_yields_reply_and_worker_survives() {
        let plan = FaultPlan::builder(11).site(FaultSite::EnginePanic, FaultSpec::First(1)).build();
        let engine: Arc<dyn InferenceEngine> = Arc::new(FaultyEngine::new(tiny_engine(), plan));
        let h = serve_single("tiny", engine, 1);
        let res = h.infer("tiny", Tensor::zeros(&[8, 8, 1]));
        match res {
            Err(ServeError::EngineFailed { reason, .. }) => {
                assert!(reason.contains("panicked"), "{reason}");
            }
            other => panic!("expected EngineFailed, got {other:?}"),
        }
        // Same worker keeps serving.
        assert!(h.infer("tiny", Tensor::zeros(&[8, 8, 1])).is_ok());
        let snap = h.stop();
        assert_eq!(snap.engine_panics, 1);
    }

    #[test]
    fn queue_full_sheds_at_submission() {
        // No workers draining: park the single worker on a slow request
        // first, then overfill the 2-slot queue.
        let plan = FaultPlan::builder(12)
            .site(FaultSite::LatencySpike, FaultSpec::Every(1))
            .delay(Duration::from_millis(200))
            .build();
        let engine: Arc<dyn InferenceEngine> = Arc::new(FaultyEngine::new(tiny_engine(), plan));
        let router = Router::new();
        router.register("tiny", engine);
        let h = serve_with(
            Arc::new(router),
            ServeConfig { workers: 1, queue_capacity: 2, default_deadline: None },
        );
        let mut receivers = vec![h.submit("tiny", Tensor::zeros(&[8, 8, 1]), None).unwrap()];
        // Give the worker time to pull the first request off the queue.
        std::thread::sleep(Duration::from_millis(50));
        let mut shed = 0;
        for _ in 0..4 {
            match h.submit("tiny", Tensor::zeros(&[8, 8, 1]), None) {
                Ok(rx) => receivers.push(rx),
                Err(ServeError::QueueFull { capacity }) => {
                    assert_eq!(capacity, 2);
                    shed += 1;
                }
                Err(other) => panic!("unexpected {other:?}"),
            }
        }
        assert!(shed >= 2, "at least 2 of 4 extra submissions must shed, got {shed}");
        for rx in receivers {
            assert!(rx.recv().unwrap().is_ok(), "accepted requests are all served");
        }
        let snap = h.stop();
        assert_eq!(snap.queue_full_sheds, shed);
    }

    #[test]
    fn stop_drains_queued_requests() {
        let plan = FaultPlan::builder(13)
            .site(FaultSite::LatencySpike, FaultSpec::Every(1))
            .delay(Duration::from_millis(20))
            .build();
        let engine: Arc<dyn InferenceEngine> = Arc::new(FaultyEngine::new(tiny_engine(), plan));
        let h = serve_single("tiny", engine, 1);
        let receivers: Vec<_> =
            (0..10).map(|_| h.submit("tiny", Tensor::zeros(&[8, 8, 1]), None).unwrap()).collect();
        let snap = h.stop(); // drain-then-join
        assert_eq!(snap.total_requests, 10, "stop() serves the backlog before joining");
        for rx in receivers {
            assert!(rx.recv().unwrap().is_ok(), "queued request answered after stop()");
        }
    }

    #[test]
    fn submit_after_stop_is_typed_stopped() {
        let h = serve_single("tiny", tiny_engine(), 1);
        let s = h.submitter();
        h.shutdown();
        assert!(matches!(
            s.submit("tiny", Tensor::zeros(&[8, 8, 1]), None),
            Err(ServeError::Stopped)
        ));
        assert!(matches!(s.infer("tiny", Tensor::zeros(&[8, 8, 1])), Err(ServeError::Stopped)));
    }

    #[test]
    fn default_deadline_applies_to_plain_infer() {
        let plan = FaultPlan::builder(14)
            .site(FaultSite::LatencySpike, FaultSpec::Every(1))
            .delay(Duration::from_millis(60))
            .build();
        let engine: Arc<dyn InferenceEngine> = Arc::new(FaultyEngine::new(tiny_engine(), plan));
        let router = Router::new();
        router.register("tiny", engine);
        let h = serve_with(
            Arc::new(router),
            ServeConfig { workers: 1, queue_capacity: 8, default_deadline: Some(Duration::from_millis(25)) },
        );
        // First request occupies the worker for ~60ms; the second's 25ms
        // default deadline expires while it waits in the queue.
        let rx1 = h.submit("tiny", Tensor::zeros(&[8, 8, 1]), None).unwrap();
        let res2 = h.infer("tiny", Tensor::zeros(&[8, 8, 1]));
        assert!(matches!(res2, Err(ServeError::DeadlineExceeded { .. })), "{res2:?}");
        assert!(rx1.recv().unwrap().is_ok());
        let snap = h.stop();
        assert_eq!(snap.deadline_sheds, 1);
    }

    #[test]
    fn stop_with_timeout_answers_still_queued_with_stopped() {
        // One worker wedged ~100ms per request; queue 5, stop with a
        // deadline shorter than the backlog needs: the in-flight request
        // (and possibly a successor) completes, the rest get a typed
        // `Stopped` reply — never a hang, never a dropped reply.
        let plan = FaultPlan::builder(15)
            .site(FaultSite::LatencySpike, FaultSpec::Every(1))
            .delay(Duration::from_millis(100))
            .build();
        let engine: Arc<dyn InferenceEngine> = Arc::new(FaultyEngine::new(tiny_engine(), plan));
        let h = serve_single("tiny", engine, 1);
        let receivers: Vec<_> =
            (0..5).map(|_| h.submit("tiny", Tensor::zeros(&[8, 8, 1]), None).unwrap()).collect();
        std::thread::sleep(Duration::from_millis(20)); // let the worker pick one up
        let t0 = Instant::now();
        let snap = h.stop_with_timeout(Duration::from_millis(150));
        assert!(t0.elapsed() < Duration::from_secs(2), "deadline stop must not hang");
        let mut served = 0;
        let mut stopped = 0;
        for rx in receivers {
            match rx.recv().unwrap_or(Err(ServeError::Stopped)) {
                Ok(_) => served += 1,
                Err(ServeError::Stopped) => stopped += 1,
                Err(other) => panic!("unexpected {other:?}"),
            }
        }
        assert_eq!(served + stopped, 5, "exactly one reply per accepted request");
        assert!(served >= 1, "the in-flight request finishes");
        assert!(stopped >= 2, "deep backlog is answered with Stopped, got {stopped}");
        assert_eq!(snap.stopped_replies, stopped as u64);
    }
}
