//! Request latency metrics: lock-free-ish counters + log-bucketed
//! histograms (no external metrics crates offline), plus the robustness
//! counters (sheds, panics, fallback, breaker transitions) added for the
//! fault-tolerant serving layer and the per-shard breakdown added for the
//! sharded coordinator.

use crate::cc::CompileStats;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Histogram with logarithmic µs buckets: [<1, <2, <4, ..., <2^19, inf).
const BUCKETS: usize = 21;

#[derive(Default)]
struct Histo {
    counts: [u64; BUCKETS],
    sum_us: f64,
    n: u64,
}

impl Histo {
    fn record(&mut self, us: f64) {
        let mut idx = 0usize;
        let mut bound = 1.0f64;
        while us >= bound && idx < BUCKETS - 1 {
            bound *= 2.0;
            idx += 1;
        }
        self.counts[idx] += 1;
        self.sum_us += us;
        self.n += 1;
    }

    fn quantile(&self, q: f64) -> f64 {
        if self.n == 0 {
            return 0.0;
        }
        let target = (q * self.n as f64).ceil() as u64;
        let mut seen = 0u64;
        for (idx, &c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= target {
                return 2f64.powi(idx as i32); // bucket upper bound
            }
        }
        2f64.powi(BUCKETS as i32)
    }

    fn mean(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.sum_us / self.n as f64
        }
    }

    fn merge(&mut self, other: &Histo) {
        for (a, b) in self.counts.iter_mut().zip(other.counts.iter()) {
            *a += b;
        }
        self.sum_us += other.sum_us;
        self.n += other.n;
    }
}

/// Public log-bucketed latency histogram for client-side measurement (the
/// load benchmark records end-to-end latency per submitter thread and
/// merges). Same buckets and quantile semantics (upper bound of the
/// containing power-of-two bucket) as the coordinator's internal histograms.
#[derive(Default)]
pub struct LatencyHisto {
    inner: Histo,
}

impl LatencyHisto {
    pub fn new() -> Self {
        LatencyHisto::default()
    }

    pub fn record_us(&mut self, us: f64) {
        self.inner.record(us);
    }

    /// Quantile in µs (bucket upper bound); `q` in (0, 1].
    pub fn quantile_us(&self, q: f64) -> f64 {
        self.inner.quantile(q)
    }

    pub fn mean_us(&self) -> f64 {
        self.inner.mean()
    }

    pub fn count(&self) -> u64 {
        self.inner.n
    }

    pub fn merge(&mut self, other: &LatencyHisto) {
        self.inner.merge(&other.inner);
    }
}

/// Robustness counters shared by the worker loop, the circuit-breaker
/// fallback wrapper, and anything else on the serving path. All fields are
/// public atomics so layers can bump them without going through the
/// recorder's model map lock.
#[derive(Debug, Default)]
pub struct ServeCounters {
    /// Requests shed because their deadline passed while queued.
    pub deadline_sheds: AtomicU64,
    /// Requests shed at admission because the bounded queue was full.
    pub queue_full_sheds: AtomicU64,
    /// Engine calls that returned an error.
    pub engine_failures: AtomicU64,
    /// Engine calls that panicked (isolated via `catch_unwind`).
    pub engine_panics: AtomicU64,
    /// Worker threads respawned after an unexpected unwind.
    pub worker_respawns: AtomicU64,
    /// Requests served by the fallback engine instead of the primary.
    pub fallback_served: AtomicU64,
    /// Requests where primary *and* fallback failed.
    pub degraded: AtomicU64,
    /// Circuit-breaker closed→open (and half-open→open) transitions
    /// (engine-level breakers, i.e. [`super::FallbackEngine`]).
    pub breaker_opens: AtomicU64,
    /// Circuit-breaker open→half-open probe admissions (engine-level).
    pub breaker_half_opens: AtomicU64,
    /// Circuit-breaker half-open→closed recoveries (engine-level).
    pub breaker_closes: AtomicU64,
    /// Requests stolen from a backlogged shard's queue by an idle peer.
    pub steals: AtomicU64,
    /// Shard-level breaker opens: a sick shard ejected from routing.
    pub shard_ejects: AtomicU64,
    /// Shard-level breaker half-open probe admissions.
    pub shard_probes: AtomicU64,
    /// Shard-level breaker closes: a probed shard re-admitted to routing.
    pub shard_readmits: AtomicU64,
    /// Graceful shard drain/restart cycles completed.
    pub shard_drains: AtomicU64,
    /// Requests still queued when a shutdown deadline fired, answered with
    /// `ServeError::Stopped` instead of being dropped.
    pub stopped_replies: AtomicU64,
    /// Background heal rebuilds started / succeeded / failed.
    pub heals_started: AtomicU64,
    pub heals_succeeded: AtomicU64,
    pub heals_failed: AtomicU64,
    /// Multi-request engine invocations (`infer_batch` with ≥ 2 requests):
    /// how often dispatch actually amortized work across images.
    pub batched_infers: AtomicU64,
    /// Requests served through those multi-request invocations; divide by
    /// `batched_infers` for the mean realized batch size.
    pub batched_requests: AtomicU64,
    /// Largest single `infer_batch` width dispatched so far.
    pub batch_size_max: AtomicU64,
    /// TCP connections accepted by the net front-end.
    pub net_connections: AtomicU64,
    /// Request frames fully decoded (accepted) off the wire. Every one of
    /// these gets exactly one response frame attempt.
    pub net_frames: AtomicU64,
    /// Response frames successfully written back to clients.
    pub net_replies: AtomicU64,
    /// Frames rejected as protocol violations (bad magic, version skew,
    /// oversize length, ...); the connection is closed, no reply is owed.
    pub net_bad_frames: AtomicU64,
    /// Connections that died mid-stream: client disconnect, slow-loris
    /// read deadline, injected drop, or a failed response write.
    pub net_dropped_conns: AtomicU64,
    /// Frames for unregistered models rejected *before* pool submission
    /// (they consume no shard-queue slot and no in-flight budget).
    pub net_unknown_rejects: AtomicU64,
}

impl ServeCounters {
    pub fn bump(field: &AtomicU64) {
        field.fetch_add(1, Ordering::Relaxed);
    }
}

/// Per-shard health/throughput stats, owned by the shard pool and attached
/// to the recorder so snapshots can report a per-shard breakdown.
#[derive(Debug, Default)]
pub struct ShardStats {
    /// Requests this shard's workers completed (served or error reply).
    pub handled: AtomicU64,
    /// Of those, requests whose engine failed or panicked.
    pub failed: AtomicU64,
    /// Requests stolen *from* this shard's queue by other shards.
    pub stolen_from: AtomicU64,
    /// Requests this shard's workers stole from other shards.
    pub stolen_by: AtomicU64,
    /// Worker respawns on this shard (supervisor caught an unwind).
    pub respawns: AtomicU64,
    /// Shard breaker ejections / re-admissions.
    pub ejects: AtomicU64,
    pub readmits: AtomicU64,
    /// Drain/restart cycles.
    pub drains: AtomicU64,
    /// Current queue depth (maintained by the shard's queue).
    pub queue_len: AtomicU64,
}

/// Immutable per-shard view inside a [`MetricsSnapshot`].
#[derive(Debug, Clone)]
pub struct ShardSnapshot {
    pub idx: usize,
    pub handled: u64,
    pub failed: u64,
    pub stolen_from: u64,
    pub stolen_by: u64,
    pub respawns: u64,
    pub ejects: u64,
    pub readmits: u64,
    pub drains: u64,
    pub queue_len: u64,
}

impl ShardSnapshot {
    /// Sickness score used to pick the "sickest shard" in reports: failures
    /// and respawns dominate, unresolved ejections break ties.
    pub fn sickness(&self) -> u64 {
        self.failed + self.respawns * 4 + self.ejects.saturating_sub(self.readmits) * 16
    }
}

/// Per-model latency statistics inside a [`MetricsSnapshot`].
#[derive(Debug, Clone)]
pub struct ModelStats {
    pub model: String,
    pub queue_mean_us: f64,
    pub infer_mean_us: f64,
    pub p50_us: f64,
    pub p99_us: f64,
    pub p999_us: f64,
    pub n: u64,
}

/// Concurrent latency recorder shared by workers.
pub struct LatencyRecorder {
    total: AtomicU64,
    errors: AtomicU64,
    counters: Arc<ServeCounters>,
    per_model: Mutex<HashMap<String, (Histo, Histo)>>, // (queue, infer)
    compile_stats: Mutex<Option<Arc<CompileStats>>>,
    shard_stats: Mutex<Vec<Arc<ShardStats>>>,
}

/// Immutable snapshot for reporting.
#[derive(Debug, Clone)]
pub struct MetricsSnapshot {
    pub total_requests: u64,
    pub errors: u64,
    /// Per-model latency breakdown, sorted by model name.
    pub models: Vec<ModelStats>,
    /// Per-shard breakdown (empty when no shard stats were attached,
    /// e.g. for a recorder used outside a shard pool).
    pub shards: Vec<ShardSnapshot>,
    // Robustness counters (see [`ServeCounters`] for semantics).
    pub deadline_sheds: u64,
    pub queue_full_sheds: u64,
    pub engine_failures: u64,
    pub engine_panics: u64,
    pub worker_respawns: u64,
    pub fallback_served: u64,
    pub degraded: u64,
    pub breaker_opens: u64,
    pub breaker_half_opens: u64,
    pub breaker_closes: u64,
    pub steals: u64,
    pub shard_ejects: u64,
    pub shard_probes: u64,
    pub shard_readmits: u64,
    pub shard_drains: u64,
    pub stopped_replies: u64,
    pub heals_started: u64,
    pub heals_succeeded: u64,
    pub heals_failed: u64,
    pub batched_infers: u64,
    pub batched_requests: u64,
    pub batch_size_max: u64,
    // Net front-end counters (see [`ServeCounters`] for semantics).
    pub net_connections: u64,
    pub net_frames: u64,
    pub net_replies: u64,
    pub net_bad_frames: u64,
    pub net_dropped_conns: u64,
    pub net_unknown_rejects: u64,
    /// Compile-pipeline retry/timeout counts, if a [`CompileStats`] was
    /// attached (e.g. by a healing recompile path).
    pub compile_retries: u64,
    pub compile_timeouts: u64,
}

impl MetricsSnapshot {
    /// Mean realized batch width across multi-request dispatches, or 0.0
    /// when batching never engaged.
    pub fn batch_size_mean(&self) -> f64 {
        if self.batched_infers == 0 {
            0.0
        } else {
            self.batched_requests as f64 / self.batched_infers as f64
        }
    }

    /// The shard with the worst sickness score, if any shard has one > 0.
    pub fn sickest_shard(&self) -> Option<&ShardSnapshot> {
        self.shards
            .iter()
            .max_by_key(|s| s.sickness())
            .filter(|s| s.sickness() > 0)
    }
}

impl LatencyRecorder {
    pub fn new() -> Self {
        LatencyRecorder {
            total: AtomicU64::new(0),
            errors: AtomicU64::new(0),
            counters: Arc::new(ServeCounters::default()),
            per_model: Mutex::new(HashMap::new()),
            compile_stats: Mutex::new(None),
            shard_stats: Mutex::new(Vec::new()),
        }
    }

    /// The shared robustness counters (clone the `Arc` to hand to a
    /// [`super::FallbackEngine`] or other serving-path component).
    pub fn counters(&self) -> &Arc<ServeCounters> {
        &self.counters
    }

    /// Surface a compile pipeline's retry/timeout stats in snapshots.
    pub fn attach_compile_stats(&self, stats: Arc<CompileStats>) {
        *self.compile_stats.lock().unwrap_or_else(|e| e.into_inner()) = Some(stats);
    }

    /// Surface per-shard stats (one entry per shard, in shard order) in
    /// snapshots. Called once by the shard pool at startup.
    pub fn attach_shard_stats(&self, stats: Vec<Arc<ShardStats>>) {
        *self.shard_stats.lock().unwrap_or_else(|e| e.into_inner()) = stats;
    }

    pub fn record(&self, model: &str, queue_us: f64, infer_us: f64, ok: bool) {
        self.total.fetch_add(1, Ordering::Relaxed);
        if !ok {
            self.errors.fetch_add(1, Ordering::Relaxed);
        }
        let mut map = self.per_model.lock().unwrap_or_else(|e| e.into_inner());
        let entry = map.entry(model.to_string()).or_default();
        entry.0.record(queue_us);
        entry.1.record(infer_us);
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        let map = self.per_model.lock().unwrap_or_else(|e| e.into_inner());
        let mut models: Vec<ModelStats> = map
            .iter()
            .map(|(name, (q, i))| ModelStats {
                model: name.clone(),
                queue_mean_us: q.mean(),
                infer_mean_us: i.mean(),
                p50_us: i.quantile(0.5),
                p99_us: i.quantile(0.99),
                p999_us: i.quantile(0.999),
                n: i.n,
            })
            .collect();
        models.sort_by(|a, b| a.model.cmp(&b.model));
        let shards: Vec<ShardSnapshot> = self
            .shard_stats
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .iter()
            .enumerate()
            .map(|(idx, s)| ShardSnapshot {
                idx,
                handled: s.handled.load(Ordering::Relaxed),
                failed: s.failed.load(Ordering::Relaxed),
                stolen_from: s.stolen_from.load(Ordering::Relaxed),
                stolen_by: s.stolen_by.load(Ordering::Relaxed),
                respawns: s.respawns.load(Ordering::Relaxed),
                ejects: s.ejects.load(Ordering::Relaxed),
                readmits: s.readmits.load(Ordering::Relaxed),
                drains: s.drains.load(Ordering::Relaxed),
                queue_len: s.queue_len.load(Ordering::Relaxed),
            })
            .collect();
        let c = &self.counters;
        let (compile_retries, compile_timeouts) = match &*self.compile_stats.lock().unwrap_or_else(|e| e.into_inner()) {
            Some(s) => (s.retries.load(Ordering::Relaxed), s.timeouts.load(Ordering::Relaxed)),
            None => (0, 0),
        };
        MetricsSnapshot {
            total_requests: self.total.load(Ordering::Relaxed),
            errors: self.errors.load(Ordering::Relaxed),
            models,
            shards,
            deadline_sheds: c.deadline_sheds.load(Ordering::Relaxed),
            queue_full_sheds: c.queue_full_sheds.load(Ordering::Relaxed),
            engine_failures: c.engine_failures.load(Ordering::Relaxed),
            engine_panics: c.engine_panics.load(Ordering::Relaxed),
            worker_respawns: c.worker_respawns.load(Ordering::Relaxed),
            fallback_served: c.fallback_served.load(Ordering::Relaxed),
            degraded: c.degraded.load(Ordering::Relaxed),
            breaker_opens: c.breaker_opens.load(Ordering::Relaxed),
            breaker_half_opens: c.breaker_half_opens.load(Ordering::Relaxed),
            breaker_closes: c.breaker_closes.load(Ordering::Relaxed),
            steals: c.steals.load(Ordering::Relaxed),
            shard_ejects: c.shard_ejects.load(Ordering::Relaxed),
            shard_probes: c.shard_probes.load(Ordering::Relaxed),
            shard_readmits: c.shard_readmits.load(Ordering::Relaxed),
            shard_drains: c.shard_drains.load(Ordering::Relaxed),
            stopped_replies: c.stopped_replies.load(Ordering::Relaxed),
            heals_started: c.heals_started.load(Ordering::Relaxed),
            heals_succeeded: c.heals_succeeded.load(Ordering::Relaxed),
            heals_failed: c.heals_failed.load(Ordering::Relaxed),
            batched_infers: c.batched_infers.load(Ordering::Relaxed),
            batched_requests: c.batched_requests.load(Ordering::Relaxed),
            batch_size_max: c.batch_size_max.load(Ordering::Relaxed),
            net_connections: c.net_connections.load(Ordering::Relaxed),
            net_frames: c.net_frames.load(Ordering::Relaxed),
            net_replies: c.net_replies.load(Ordering::Relaxed),
            net_bad_frames: c.net_bad_frames.load(Ordering::Relaxed),
            net_dropped_conns: c.net_dropped_conns.load(Ordering::Relaxed),
            net_unknown_rejects: c.net_unknown_rejects.load(Ordering::Relaxed),
            compile_retries,
            compile_timeouts,
        }
    }
}

impl Default for LatencyRecorder {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_snapshots() {
        let r = LatencyRecorder::new();
        r.record("ball", 1.0, 10.0, true);
        r.record("ball", 2.0, 20.0, true);
        r.record("ball", 3.0, 30.0, false);
        let s = r.snapshot();
        assert_eq!(s.total_requests, 3);
        assert_eq!(s.errors, 1);
        let m = &s.models[0];
        assert_eq!(m.model, "ball");
        assert_eq!(m.n, 3);
        assert!((m.queue_mean_us - 2.0).abs() < 1e-9);
        assert!((m.infer_mean_us - 20.0).abs() < 1e-9);
        assert!(m.p50_us <= m.p99_us && m.p99_us <= m.p999_us);
    }

    #[test]
    fn quantiles_are_monotone_upper_bounds() {
        let mut h = Histo::default();
        for us in [1.0, 3.0, 5.0, 100.0, 1000.0] {
            h.record(us);
        }
        let p50 = h.quantile(0.5);
        let p99 = h.quantile(0.99);
        assert!(p50 <= p99);
        assert!(p50 >= 3.0, "p50={p50}");
        assert!(p99 >= 1000.0, "p99={p99}");
    }

    #[test]
    fn empty_histogram_quantile_zero() {
        let h = Histo::default();
        assert_eq!(h.quantile(0.99), 0.0);
        assert_eq!(h.mean(), 0.0);
    }

    #[test]
    fn client_histo_merges() {
        let mut a = LatencyHisto::new();
        let mut b = LatencyHisto::new();
        for us in [1.0, 10.0, 100.0] {
            a.record_us(us);
        }
        b.record_us(1000.0);
        a.merge(&b);
        assert_eq!(a.count(), 4);
        assert!(a.quantile_us(0.999) >= 1000.0);
        assert!(a.quantile_us(0.5) <= a.quantile_us(0.99));
        assert!(a.mean_us() > 0.0);
    }

    #[test]
    fn robustness_counters_flow_into_snapshot() {
        let r = LatencyRecorder::new();
        let c = r.counters().clone();
        ServeCounters::bump(&c.deadline_sheds);
        ServeCounters::bump(&c.queue_full_sheds);
        ServeCounters::bump(&c.queue_full_sheds);
        ServeCounters::bump(&c.engine_panics);
        ServeCounters::bump(&c.fallback_served);
        ServeCounters::bump(&c.breaker_opens);
        ServeCounters::bump(&c.steals);
        ServeCounters::bump(&c.shard_ejects);
        ServeCounters::bump(&c.stopped_replies);
        let s = r.snapshot();
        assert_eq!(s.deadline_sheds, 1);
        assert_eq!(s.queue_full_sheds, 2);
        assert_eq!(s.engine_panics, 1);
        assert_eq!(s.fallback_served, 1);
        assert_eq!(s.breaker_opens, 1);
        assert_eq!(s.worker_respawns, 0);
        assert_eq!(s.steals, 1);
        assert_eq!(s.shard_ejects, 1);
        assert_eq!(s.stopped_replies, 1);
        assert_eq!(s.shard_readmits, 0);
    }

    #[test]
    fn batch_counters_flow_into_snapshot() {
        let r = LatencyRecorder::new();
        let c = r.counters().clone();
        assert_eq!(r.snapshot().batch_size_mean(), 0.0);
        // Two batched dispatches of widths 4 and 2.
        c.batched_infers.fetch_add(2, Ordering::Relaxed);
        c.batched_requests.fetch_add(6, Ordering::Relaxed);
        c.batch_size_max.fetch_max(4, Ordering::Relaxed);
        c.batch_size_max.fetch_max(2, Ordering::Relaxed);
        let s = r.snapshot();
        assert_eq!(s.batched_infers, 2);
        assert_eq!(s.batched_requests, 6);
        assert_eq!(s.batch_size_max, 4);
        assert!((s.batch_size_mean() - 3.0).abs() < 1e-9);
    }

    #[test]
    fn net_counters_flow_into_snapshot() {
        let r = LatencyRecorder::new();
        let c = r.counters().clone();
        ServeCounters::bump(&c.net_connections);
        ServeCounters::bump(&c.net_frames);
        ServeCounters::bump(&c.net_frames);
        ServeCounters::bump(&c.net_replies);
        ServeCounters::bump(&c.net_bad_frames);
        ServeCounters::bump(&c.net_dropped_conns);
        ServeCounters::bump(&c.net_unknown_rejects);
        let s = r.snapshot();
        assert_eq!(s.net_connections, 1);
        assert_eq!(s.net_frames, 2);
        assert_eq!(s.net_replies, 1);
        assert_eq!(s.net_bad_frames, 1);
        assert_eq!(s.net_dropped_conns, 1);
        assert_eq!(s.net_unknown_rejects, 1);
    }

    #[test]
    fn shard_stats_flow_into_snapshot_and_sickest_is_found() {
        let r = LatencyRecorder::new();
        assert!(r.snapshot().shards.is_empty());
        assert!(r.snapshot().sickest_shard().is_none());

        let stats: Vec<Arc<ShardStats>> =
            (0..3).map(|_| Arc::new(ShardStats::default())).collect();
        stats[0].handled.fetch_add(10, Ordering::Relaxed);
        stats[1].handled.fetch_add(10, Ordering::Relaxed);
        stats[1].failed.fetch_add(2, Ordering::Relaxed);
        stats[1].respawns.fetch_add(1, Ordering::Relaxed);
        stats[2].stolen_from.fetch_add(4, Ordering::Relaxed);
        r.attach_shard_stats(stats);

        let s = r.snapshot();
        assert_eq!(s.shards.len(), 3);
        assert_eq!(s.shards[1].failed, 2);
        assert_eq!(s.shards[2].stolen_from, 4);
        let sick = s.sickest_shard().expect("shard 1 is sick");
        assert_eq!(sick.idx, 1);
        assert_eq!(sick.sickness(), 2 + 4);
    }

    #[test]
    fn healthy_pool_has_no_sickest_shard() {
        let r = LatencyRecorder::new();
        let stats: Vec<Arc<ShardStats>> =
            (0..2).map(|_| Arc::new(ShardStats::default())).collect();
        stats[0].handled.fetch_add(100, Ordering::Relaxed);
        r.attach_shard_stats(stats);
        assert!(r.snapshot().sickest_shard().is_none(), "healthy shards are not 'sick'");
    }

    #[test]
    fn compile_stats_attach_is_reflected() {
        let r = LatencyRecorder::new();
        assert_eq!(r.snapshot().compile_retries, 0);
        let stats = Arc::new(CompileStats::default());
        stats.retries.fetch_add(2, Ordering::Relaxed);
        stats.timeouts.fetch_add(1, Ordering::Relaxed);
        r.attach_compile_stats(stats);
        let s = r.snapshot();
        assert_eq!(s.compile_retries, 2);
        assert_eq!(s.compile_timeouts, 1);
    }
}
