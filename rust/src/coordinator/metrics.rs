//! Request latency metrics: lock-free-ish counters + log-bucketed
//! histograms (no external metrics crates offline).

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// Histogram with logarithmic µs buckets: [<1, <2, <4, ..., <2^19, inf).
const BUCKETS: usize = 21;

#[derive(Default)]
struct Histo {
    counts: [u64; BUCKETS],
    sum_us: f64,
    n: u64,
}

impl Histo {
    fn record(&mut self, us: f64) {
        let mut idx = 0usize;
        let mut bound = 1.0f64;
        while us >= bound && idx < BUCKETS - 1 {
            bound *= 2.0;
            idx += 1;
        }
        self.counts[idx] += 1;
        self.sum_us += us;
        self.n += 1;
    }

    fn quantile(&self, q: f64) -> f64 {
        if self.n == 0 {
            return 0.0;
        }
        let target = (q * self.n as f64).ceil() as u64;
        let mut seen = 0u64;
        for (idx, &c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= target {
                return 2f64.powi(idx as i32); // bucket upper bound
            }
        }
        2f64.powi(BUCKETS as i32)
    }

    fn mean(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.sum_us / self.n as f64
        }
    }
}

/// Concurrent latency recorder shared by workers.
pub struct LatencyRecorder {
    total: AtomicU64,
    errors: AtomicU64,
    per_model: Mutex<HashMap<String, (Histo, Histo)>>, // (queue, infer)
}

/// Immutable snapshot for reporting.
#[derive(Debug, Clone)]
pub struct MetricsSnapshot {
    pub total_requests: u64,
    pub errors: u64,
    /// model → (mean queue µs, mean infer µs, p50 infer µs, p99 infer µs, n)
    pub models: Vec<(String, f64, f64, f64, f64, u64)>,
}

impl LatencyRecorder {
    pub fn new() -> Self {
        LatencyRecorder { total: AtomicU64::new(0), errors: AtomicU64::new(0), per_model: Mutex::new(HashMap::new()) }
    }

    pub fn record(&self, model: &str, queue_us: f64, infer_us: f64, ok: bool) {
        self.total.fetch_add(1, Ordering::Relaxed);
        if !ok {
            self.errors.fetch_add(1, Ordering::Relaxed);
        }
        let mut map = self.per_model.lock().unwrap();
        let entry = map.entry(model.to_string()).or_default();
        entry.0.record(queue_us);
        entry.1.record(infer_us);
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        let map = self.per_model.lock().unwrap();
        let mut models: Vec<_> = map
            .iter()
            .map(|(name, (q, i))| (name.clone(), q.mean(), i.mean(), i.quantile(0.5), i.quantile(0.99), i.n))
            .collect();
        models.sort_by(|a, b| a.0.cmp(&b.0));
        MetricsSnapshot {
            total_requests: self.total.load(Ordering::Relaxed),
            errors: self.errors.load(Ordering::Relaxed),
            models,
        }
    }
}

impl Default for LatencyRecorder {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_snapshots() {
        let r = LatencyRecorder::new();
        r.record("ball", 1.0, 10.0, true);
        r.record("ball", 2.0, 20.0, true);
        r.record("ball", 3.0, 30.0, false);
        let s = r.snapshot();
        assert_eq!(s.total_requests, 3);
        assert_eq!(s.errors, 1);
        let (name, q_mean, i_mean, _, _, n) = &s.models[0];
        assert_eq!(name, "ball");
        assert_eq!(*n, 3);
        assert!((q_mean - 2.0).abs() < 1e-9);
        assert!((i_mean - 20.0).abs() < 1e-9);
    }

    #[test]
    fn quantiles_are_monotone_upper_bounds() {
        let mut h = Histo::default();
        for us in [1.0, 3.0, 5.0, 100.0, 1000.0] {
            h.record(us);
        }
        let p50 = h.quantile(0.5);
        let p99 = h.quantile(0.99);
        assert!(p50 <= p99);
        assert!(p50 >= 3.0, "p50={p50}");
        assert!(p99 >= 1000.0, "p99={p99}");
    }

    #[test]
    fn empty_histogram_quantile_zero() {
        let h = Histo::default();
        assert_eq!(h.quantile(0.99), 0.0);
        assert_eq!(h.mean(), 0.0);
    }
}
