//! Request latency metrics: lock-free-ish counters + log-bucketed
//! histograms (no external metrics crates offline), plus the robustness
//! counters (sheds, panics, fallback, breaker transitions) added for the
//! fault-tolerant serving layer.

use crate::cc::CompileStats;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Histogram with logarithmic µs buckets: [<1, <2, <4, ..., <2^19, inf).
const BUCKETS: usize = 21;

#[derive(Default)]
struct Histo {
    counts: [u64; BUCKETS],
    sum_us: f64,
    n: u64,
}

impl Histo {
    fn record(&mut self, us: f64) {
        let mut idx = 0usize;
        let mut bound = 1.0f64;
        while us >= bound && idx < BUCKETS - 1 {
            bound *= 2.0;
            idx += 1;
        }
        self.counts[idx] += 1;
        self.sum_us += us;
        self.n += 1;
    }

    fn quantile(&self, q: f64) -> f64 {
        if self.n == 0 {
            return 0.0;
        }
        let target = (q * self.n as f64).ceil() as u64;
        let mut seen = 0u64;
        for (idx, &c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= target {
                return 2f64.powi(idx as i32); // bucket upper bound
            }
        }
        2f64.powi(BUCKETS as i32)
    }

    fn mean(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.sum_us / self.n as f64
        }
    }
}

/// Robustness counters shared by the worker loop, the circuit-breaker
/// fallback wrapper, and anything else on the serving path. All fields are
/// public atomics so layers can bump them without going through the
/// recorder's model map lock.
#[derive(Debug, Default)]
pub struct ServeCounters {
    /// Requests shed because their deadline passed while queued.
    pub deadline_sheds: AtomicU64,
    /// Requests shed at admission because the bounded queue was full.
    pub queue_full_sheds: AtomicU64,
    /// Engine calls that returned an error.
    pub engine_failures: AtomicU64,
    /// Engine calls that panicked (isolated via `catch_unwind`).
    pub engine_panics: AtomicU64,
    /// Worker threads respawned after an unexpected unwind.
    pub worker_respawns: AtomicU64,
    /// Requests served by the fallback engine instead of the primary.
    pub fallback_served: AtomicU64,
    /// Requests where primary *and* fallback failed.
    pub degraded: AtomicU64,
    /// Circuit-breaker closed→open (and half-open→open) transitions.
    pub breaker_opens: AtomicU64,
    /// Circuit-breaker open→half-open probe admissions.
    pub breaker_half_opens: AtomicU64,
    /// Circuit-breaker half-open→closed recoveries.
    pub breaker_closes: AtomicU64,
}

impl ServeCounters {
    pub fn bump(field: &AtomicU64) {
        field.fetch_add(1, Ordering::Relaxed);
    }
}

/// Concurrent latency recorder shared by workers.
pub struct LatencyRecorder {
    total: AtomicU64,
    errors: AtomicU64,
    counters: Arc<ServeCounters>,
    per_model: Mutex<HashMap<String, (Histo, Histo)>>, // (queue, infer)
    compile_stats: Mutex<Option<Arc<CompileStats>>>,
}

/// Immutable snapshot for reporting.
#[derive(Debug, Clone)]
pub struct MetricsSnapshot {
    pub total_requests: u64,
    pub errors: u64,
    /// model → (mean queue µs, mean infer µs, p50 infer µs, p99 infer µs, n)
    pub models: Vec<(String, f64, f64, f64, f64, u64)>,
    // Robustness counters (see [`ServeCounters`] for semantics).
    pub deadline_sheds: u64,
    pub queue_full_sheds: u64,
    pub engine_failures: u64,
    pub engine_panics: u64,
    pub worker_respawns: u64,
    pub fallback_served: u64,
    pub degraded: u64,
    pub breaker_opens: u64,
    pub breaker_half_opens: u64,
    pub breaker_closes: u64,
    /// Compile-pipeline retry/timeout counts, if a [`CompileStats`] was
    /// attached (e.g. by a healing recompile path).
    pub compile_retries: u64,
    pub compile_timeouts: u64,
}

impl LatencyRecorder {
    pub fn new() -> Self {
        LatencyRecorder {
            total: AtomicU64::new(0),
            errors: AtomicU64::new(0),
            counters: Arc::new(ServeCounters::default()),
            per_model: Mutex::new(HashMap::new()),
            compile_stats: Mutex::new(None),
        }
    }

    /// The shared robustness counters (clone the `Arc` to hand to a
    /// [`super::FallbackEngine`] or other serving-path component).
    pub fn counters(&self) -> &Arc<ServeCounters> {
        &self.counters
    }

    /// Surface a compile pipeline's retry/timeout stats in snapshots.
    pub fn attach_compile_stats(&self, stats: Arc<CompileStats>) {
        *self.compile_stats.lock().unwrap_or_else(|e| e.into_inner()) = Some(stats);
    }

    pub fn record(&self, model: &str, queue_us: f64, infer_us: f64, ok: bool) {
        self.total.fetch_add(1, Ordering::Relaxed);
        if !ok {
            self.errors.fetch_add(1, Ordering::Relaxed);
        }
        let mut map = self.per_model.lock().unwrap_or_else(|e| e.into_inner());
        let entry = map.entry(model.to_string()).or_default();
        entry.0.record(queue_us);
        entry.1.record(infer_us);
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        let map = self.per_model.lock().unwrap_or_else(|e| e.into_inner());
        let mut models: Vec<_> = map
            .iter()
            .map(|(name, (q, i))| (name.clone(), q.mean(), i.mean(), i.quantile(0.5), i.quantile(0.99), i.n))
            .collect();
        models.sort_by(|a, b| a.0.cmp(&b.0));
        let c = &self.counters;
        let (compile_retries, compile_timeouts) = match &*self.compile_stats.lock().unwrap_or_else(|e| e.into_inner()) {
            Some(s) => (s.retries.load(Ordering::Relaxed), s.timeouts.load(Ordering::Relaxed)),
            None => (0, 0),
        };
        MetricsSnapshot {
            total_requests: self.total.load(Ordering::Relaxed),
            errors: self.errors.load(Ordering::Relaxed),
            models,
            deadline_sheds: c.deadline_sheds.load(Ordering::Relaxed),
            queue_full_sheds: c.queue_full_sheds.load(Ordering::Relaxed),
            engine_failures: c.engine_failures.load(Ordering::Relaxed),
            engine_panics: c.engine_panics.load(Ordering::Relaxed),
            worker_respawns: c.worker_respawns.load(Ordering::Relaxed),
            fallback_served: c.fallback_served.load(Ordering::Relaxed),
            degraded: c.degraded.load(Ordering::Relaxed),
            breaker_opens: c.breaker_opens.load(Ordering::Relaxed),
            breaker_half_opens: c.breaker_half_opens.load(Ordering::Relaxed),
            breaker_closes: c.breaker_closes.load(Ordering::Relaxed),
            compile_retries,
            compile_timeouts,
        }
    }
}

impl Default for LatencyRecorder {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_snapshots() {
        let r = LatencyRecorder::new();
        r.record("ball", 1.0, 10.0, true);
        r.record("ball", 2.0, 20.0, true);
        r.record("ball", 3.0, 30.0, false);
        let s = r.snapshot();
        assert_eq!(s.total_requests, 3);
        assert_eq!(s.errors, 1);
        let (name, q_mean, i_mean, _, _, n) = &s.models[0];
        assert_eq!(name, "ball");
        assert_eq!(*n, 3);
        assert!((q_mean - 2.0).abs() < 1e-9);
        assert!((i_mean - 20.0).abs() < 1e-9);
    }

    #[test]
    fn quantiles_are_monotone_upper_bounds() {
        let mut h = Histo::default();
        for us in [1.0, 3.0, 5.0, 100.0, 1000.0] {
            h.record(us);
        }
        let p50 = h.quantile(0.5);
        let p99 = h.quantile(0.99);
        assert!(p50 <= p99);
        assert!(p50 >= 3.0, "p50={p50}");
        assert!(p99 >= 1000.0, "p99={p99}");
    }

    #[test]
    fn empty_histogram_quantile_zero() {
        let h = Histo::default();
        assert_eq!(h.quantile(0.99), 0.0);
        assert_eq!(h.mean(), 0.0);
    }

    #[test]
    fn robustness_counters_flow_into_snapshot() {
        let r = LatencyRecorder::new();
        let c = r.counters().clone();
        ServeCounters::bump(&c.deadline_sheds);
        ServeCounters::bump(&c.queue_full_sheds);
        ServeCounters::bump(&c.queue_full_sheds);
        ServeCounters::bump(&c.engine_panics);
        ServeCounters::bump(&c.fallback_served);
        ServeCounters::bump(&c.breaker_opens);
        let s = r.snapshot();
        assert_eq!(s.deadline_sheds, 1);
        assert_eq!(s.queue_full_sheds, 2);
        assert_eq!(s.engine_panics, 1);
        assert_eq!(s.fallback_served, 1);
        assert_eq!(s.breaker_opens, 1);
        assert_eq!(s.worker_respawns, 0);
    }

    #[test]
    fn compile_stats_attach_is_reflected() {
        let r = LatencyRecorder::new();
        assert_eq!(r.snapshot().compile_retries, 0);
        let stats = Arc::new(CompileStats::default());
        stats.retries.fetch_add(2, Ordering::Relaxed);
        stats.timeouts.fetch_add(1, Ordering::Relaxed);
        r.attach_compile_stats(stats);
        let s = r.snapshot();
        assert_eq!(s.compile_retries, 2);
        assert_eq!(s.compile_timeouts, 1);
    }
}
