//! Typed failure taxonomy for the serving layer.
//!
//! Every accepted request gets exactly one reply: either a tensor or one of
//! these errors. Clients can match on the variant (the vendored `anyhow`
//! shim has no downcast, so the coordinator returns `ServeError` directly;
//! `?` still converts into `anyhow::Error` via `std::error::Error`).

use std::fmt;

/// Why a request was not served with a tensor.
#[derive(Debug, Clone, PartialEq)]
pub enum ServeError {
    /// The request's deadline had already passed when a worker dequeued it
    /// (load shedding: stale frames are dropped, not computed).
    DeadlineExceeded { model: String, late_by_us: u64 },
    /// The bounded request queue was full at submission time.
    QueueFull { capacity: usize },
    /// The engine returned an error or panicked (reason includes which).
    EngineFailed { model: String, reason: String },
    /// No engine is registered under this name.
    ModelUnknown { model: String, registered: Vec<String> },
    /// The primary engine was down *and* the fallback failed too.
    Degraded { model: String, primary_error: String, fallback_error: String },
    /// The coordinator is shut down (or shutting down) and accepts no work.
    Stopped,
}

impl ServeError {
    /// Stable short name for metrics/logs.
    pub fn kind(&self) -> &'static str {
        match self {
            ServeError::DeadlineExceeded { .. } => "deadline-exceeded",
            ServeError::QueueFull { .. } => "queue-full",
            ServeError::EngineFailed { .. } => "engine-failed",
            ServeError::ModelUnknown { .. } => "model-unknown",
            ServeError::Degraded { .. } => "degraded",
            ServeError::Stopped => "stopped",
        }
    }
}

impl fmt::Display for ServeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ServeError::DeadlineExceeded { model, late_by_us } => {
                write!(f, "deadline exceeded for model {model:?} (late by {late_by_us}\u{b5}s; request shed)")
            }
            ServeError::QueueFull { capacity } => {
                write!(f, "serving queue full (capacity {capacity}); request shed")
            }
            ServeError::EngineFailed { model, reason } => {
                write!(f, "engine failed for model {model:?}: {reason}")
            }
            ServeError::ModelUnknown { model, registered } => {
                if registered.is_empty() {
                    write!(f, "no engine registered for model {model:?} (registry is empty)")
                } else {
                    write!(
                        f,
                        "no engine registered for model {model:?} (registered: {})",
                        registered.join(", ")
                    )
                }
            }
            ServeError::Degraded { model, primary_error, fallback_error } => {
                write!(
                    f,
                    "degraded: model {model:?} primary failed ({primary_error}) and fallback failed ({fallback_error})"
                )
            }
            ServeError::Stopped => write!(f, "coordinator stopped"),
        }
    }
}

impl std::error::Error for ServeError {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_names_registered_models() {
        let e = ServeError::ModelUnknown {
            model: "yolo".into(),
            registered: vec!["ball".into(), "pedestrian".into()],
        };
        let msg = e.to_string();
        assert!(msg.contains("ball") && msg.contains("pedestrian"), "{msg}");
        assert_eq!(e.kind(), "model-unknown");

        let empty = ServeError::ModelUnknown { model: "x".into(), registered: vec![] };
        assert!(empty.to_string().contains("empty"));
    }

    #[test]
    fn kinds_are_distinct() {
        let errs = [
            ServeError::DeadlineExceeded { model: "m".into(), late_by_us: 3 },
            ServeError::QueueFull { capacity: 4 },
            ServeError::EngineFailed { model: "m".into(), reason: "r".into() },
            ServeError::ModelUnknown { model: "m".into(), registered: vec![] },
            ServeError::Degraded { model: "m".into(), primary_error: "p".into(), fallback_error: "f".into() },
            ServeError::Stopped,
        ];
        let mut kinds: Vec<_> = errs.iter().map(|e| e.kind()).collect();
        kinds.sort();
        kinds.dedup();
        assert_eq!(kinds.len(), errs.len());
    }

    #[test]
    fn converts_into_anyhow() {
        fn f() -> anyhow::Result<()> {
            Err(ServeError::Stopped)?;
            Ok(())
        }
        assert!(f().unwrap_err().to_string().contains("stopped"));
    }
}
