//! Wire protocol for the TCP serving front-end: a minimal length-prefixed
//! binary framing, all multi-byte fields **little-endian**.
//!
//! Request frame:
//!
//! ```text
//! offset size      field
//! 0      4         magic "NNCG"
//! 4      1         version (= 1)
//! 5      8         request id (u64 LE, client-chosen, echoed in the reply)
//! 13     2         model-name length M (u16 LE, <= MAX_MODEL_LEN)
//! 15     M         model name (UTF-8)
//! ..     1         ndims D (1 ..= MAX_DIMS)
//! ..     4*D       dims (u32 LE each)
//! ..     4         payload length N in f32 elements (u32 LE, == prod(dims),
//!                  <= MAX_ELEMS)
//! ..     4*N       f32 payload (LE)
//! ```
//!
//! Response frame:
//!
//! ```text
//! offset size      field
//! 0      4         magic "NNCG"
//! 4      1         version (= 1)
//! 5      8         request id (echo)
//! 13     1         status byte (0 = ok, else ServeError kind; see status_of)
//! -- status == 0 --
//! 14     1         ndims D
//! ..     4*D       dims (u32 LE each)
//! ..     4         payload length N in f32 elements (u32 LE)
//! ..     4*N       f32 payload (LE)
//! -- status != 0 --
//! 14     4         message length (u32 LE, <= MAX_MSG_LEN)
//! ..     ..        message (UTF-8, the error's Display text)
//! ```
//!
//! Decoding works from any [`std::io::Read`] and tolerates arbitrary
//! segmentation (1-byte reads, split length prefixes, coalesced frames):
//! `read_exact` reassembles. Every malformed input maps to a typed
//! [`FrameError`]; decode never panics and all lengths are bounded before
//! allocation, so an adversarial length prefix cannot OOM the server.

use super::error::ServeError;
use crate::tensor::Tensor;
use std::fmt;
use std::io::{self, Read};

/// Frame magic: ASCII "NNCG".
pub const MAGIC: [u8; 4] = *b"NNCG";
/// Protocol version; a skew is rejected with [`FrameError::BadVersion`].
pub const VERSION: u8 = 1;
/// Longest accepted model name, in bytes.
pub const MAX_MODEL_LEN: usize = 256;
/// Most accepted tensor dimensions.
pub const MAX_DIMS: usize = 8;
/// Largest accepted tensor payload, in f32 elements (64 MiB of data).
pub const MAX_ELEMS: u64 = 1 << 24;
/// Longest accepted error-message body, in bytes.
pub const MAX_MSG_LEN: usize = 1 << 16;

/// Status byte for a successful reply; error statuses are 1..=6, one per
/// [`ServeError::kind`] (see [`status_of`] / [`status_name`]).
pub const STATUS_OK: u8 = 0;

/// The status byte a [`ServeError`] maps to on the wire.
pub fn status_of(e: &ServeError) -> u8 {
    match e {
        ServeError::DeadlineExceeded { .. } => 1,
        ServeError::QueueFull { .. } => 2,
        ServeError::EngineFailed { .. } => 3,
        ServeError::ModelUnknown { .. } => 4,
        ServeError::Degraded { .. } => 5,
        ServeError::Stopped => 6,
    }
}

/// Stable name for a status byte ("ok" plus the `ServeError::kind` strings);
/// `None` for a byte no release has ever emitted.
pub fn status_name(status: u8) -> Option<&'static str> {
    match status {
        STATUS_OK => Some("ok"),
        1 => Some("deadline-exceeded"),
        2 => Some("queue-full"),
        3 => Some("engine-failed"),
        4 => Some("model-unknown"),
        5 => Some("degraded"),
        6 => Some("stopped"),
        _ => None,
    }
}

/// Why a byte stream failed to decode as a frame (or a frame failed to
/// encode: the same limits apply on both sides).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum FrameError {
    /// The first four bytes were not [`MAGIC`].
    BadMagic([u8; 4]),
    /// The version byte differs from [`VERSION`].
    BadVersion { got: u8 },
    /// Model-name length exceeds [`MAX_MODEL_LEN`].
    ModelTooLong { len: usize },
    /// ndims is zero or exceeds [`MAX_DIMS`].
    BadDims { ndims: usize },
    /// Declared payload length exceeds [`MAX_ELEMS`].
    Oversize { elems: u64 },
    /// Declared payload length disagrees with the product of the dims.
    CountMismatch { count: u64, product: u64 },
    /// Error-message length exceeds [`MAX_MSG_LEN`].
    MessageTooLong { len: usize },
    /// Unknown response status byte.
    BadStatus { got: u8 },
    /// A name or message field was not valid UTF-8.
    BadUtf8,
    /// The stream ended mid-frame.
    Truncated,
    /// The transport's read deadline fired mid-frame (slow-loris).
    TimedOut,
    /// Any other transport error, by `io::ErrorKind` name.
    Io(String),
}

impl FrameError {
    /// Stable short name for metrics/logs.
    pub fn kind(&self) -> &'static str {
        match self {
            FrameError::BadMagic(_) => "bad-magic",
            FrameError::BadVersion { .. } => "bad-version",
            FrameError::ModelTooLong { .. } => "model-too-long",
            FrameError::BadDims { .. } => "bad-dims",
            FrameError::Oversize { .. } => "oversize",
            FrameError::CountMismatch { .. } => "count-mismatch",
            FrameError::MessageTooLong { .. } => "message-too-long",
            FrameError::BadStatus { .. } => "bad-status",
            FrameError::BadUtf8 => "bad-utf8",
            FrameError::Truncated => "truncated",
            FrameError::TimedOut => "timed-out",
            FrameError::Io(_) => "io",
        }
    }

    fn from_io(e: io::Error) -> FrameError {
        match e.kind() {
            io::ErrorKind::UnexpectedEof => FrameError::Truncated,
            io::ErrorKind::TimedOut | io::ErrorKind::WouldBlock => FrameError::TimedOut,
            kind => FrameError::Io(format!("{kind:?}")),
        }
    }
}

impl fmt::Display for FrameError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            FrameError::BadMagic(m) => write!(f, "bad frame magic {m:?}"),
            FrameError::BadVersion { got } => {
                write!(f, "protocol version skew: got {got}, want {VERSION}")
            }
            FrameError::ModelTooLong { len } => {
                write!(f, "model name length {len} exceeds {MAX_MODEL_LEN}")
            }
            FrameError::BadDims { ndims } => {
                write!(f, "tensor rank {ndims} outside 1..={MAX_DIMS}")
            }
            FrameError::Oversize { elems } => {
                write!(f, "payload length {elems} exceeds {MAX_ELEMS} elements")
            }
            FrameError::CountMismatch { count, product } => {
                write!(f, "payload length {count} != dims product {product}")
            }
            FrameError::MessageTooLong { len } => {
                write!(f, "message length {len} exceeds {MAX_MSG_LEN}")
            }
            FrameError::BadStatus { got } => write!(f, "unknown response status {got}"),
            FrameError::BadUtf8 => write!(f, "field is not valid UTF-8"),
            FrameError::Truncated => write!(f, "stream ended mid-frame"),
            FrameError::TimedOut => write!(f, "read deadline fired mid-frame"),
            FrameError::Io(kind) => write!(f, "transport error ({kind})"),
        }
    }
}

impl std::error::Error for FrameError {}

/// A decoded request frame.
#[derive(Debug, Clone, PartialEq)]
pub struct RequestFrame {
    pub id: u64,
    pub model: String,
    pub dims: Vec<usize>,
    pub data: Vec<f32>,
}

impl RequestFrame {
    /// Rebuild the payload tensor (the decode already validated that the
    /// data length equals the dims product).
    pub fn into_tensor(self) -> anyhow::Result<Tensor> {
        Tensor::from_vec(&self.dims, self.data)
    }
}

/// A decoded response frame.
#[derive(Debug, Clone, PartialEq)]
pub struct ResponseFrame {
    pub id: u64,
    pub status: u8,
    pub body: ResponseBody,
}

/// Body of a response: a tensor for `STATUS_OK`, a message otherwise.
#[derive(Debug, Clone, PartialEq)]
pub enum ResponseBody {
    Tensor { dims: Vec<usize>, data: Vec<f32> },
    Message(String),
}

fn check_shape(dims: &[usize], count: u64) -> Result<(), FrameError> {
    if dims.is_empty() || dims.len() > MAX_DIMS {
        return Err(FrameError::BadDims { ndims: dims.len() });
    }
    let mut product: u64 = 1;
    for &d in dims {
        product = product.saturating_mul(d as u64);
    }
    if product > MAX_ELEMS || count > MAX_ELEMS {
        return Err(FrameError::Oversize { elems: product.max(count) });
    }
    if count != product {
        return Err(FrameError::CountMismatch { count, product });
    }
    Ok(())
}

fn put_shape_and_data(buf: &mut Vec<u8>, dims: &[usize], data: &[f32]) {
    buf.push(dims.len() as u8);
    for &d in dims {
        buf.extend_from_slice(&(d as u32).to_le_bytes());
    }
    buf.extend_from_slice(&(data.len() as u32).to_le_bytes());
    for v in data {
        buf.extend_from_slice(&v.to_le_bytes());
    }
}

/// Encode a request frame. Fails (typed) when a field exceeds the protocol
/// limits — the same bounds the decoder enforces.
pub fn encode_request(
    id: u64,
    model: &str,
    dims: &[usize],
    data: &[f32],
) -> Result<Vec<u8>, FrameError> {
    if model.len() > MAX_MODEL_LEN {
        return Err(FrameError::ModelTooLong { len: model.len() });
    }
    check_shape(dims, data.len() as u64)?;
    let mut buf = Vec::with_capacity(15 + model.len() + 5 + 4 * dims.len() + 4 * data.len());
    buf.extend_from_slice(&MAGIC);
    buf.push(VERSION);
    buf.extend_from_slice(&id.to_le_bytes());
    buf.extend_from_slice(&(model.len() as u16).to_le_bytes());
    buf.extend_from_slice(model.as_bytes());
    put_shape_and_data(&mut buf, dims, data);
    Ok(buf)
}

/// Encode a success response carrying the output tensor.
pub fn encode_ok(id: u64, output: &Tensor) -> Result<Vec<u8>, FrameError> {
    check_shape(output.dims(), output.data().len() as u64)?;
    let mut buf = Vec::with_capacity(14 + 5 + 4 * output.dims().len() + 4 * output.data().len());
    buf.extend_from_slice(&MAGIC);
    buf.push(VERSION);
    buf.extend_from_slice(&id.to_le_bytes());
    buf.push(STATUS_OK);
    put_shape_and_data(&mut buf, output.dims(), output.data());
    Ok(buf)
}

/// Encode a typed-error response. Infallible: the status byte comes from
/// [`status_of`] and an over-long Display text is truncated to the limit
/// rather than failing the reply.
pub fn encode_err(id: u64, err: &ServeError) -> Vec<u8> {
    let mut msg = err.to_string();
    if msg.len() > MAX_MSG_LEN {
        // Truncate on a char boundary so the message stays valid UTF-8.
        let mut cut = MAX_MSG_LEN;
        while !msg.is_char_boundary(cut) {
            cut -= 1;
        }
        msg.truncate(cut);
    }
    let mut buf = Vec::with_capacity(18 + msg.len());
    buf.extend_from_slice(&MAGIC);
    buf.push(VERSION);
    buf.extend_from_slice(&id.to_le_bytes());
    buf.push(status_of(err));
    buf.extend_from_slice(&(msg.len() as u32).to_le_bytes());
    buf.extend_from_slice(msg.as_bytes());
    buf
}

fn read_bytes(r: &mut impl Read, buf: &mut [u8]) -> Result<(), FrameError> {
    r.read_exact(buf).map_err(FrameError::from_io)
}

fn read_u16(r: &mut impl Read) -> Result<u16, FrameError> {
    let mut b = [0u8; 2];
    read_bytes(r, &mut b)?;
    Ok(u16::from_le_bytes(b))
}

fn read_u32(r: &mut impl Read) -> Result<u32, FrameError> {
    let mut b = [0u8; 4];
    read_bytes(r, &mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn read_u64(r: &mut impl Read) -> Result<u64, FrameError> {
    let mut b = [0u8; 8];
    read_bytes(r, &mut b)?;
    Ok(u64::from_le_bytes(b))
}

fn read_u8(r: &mut impl Read) -> Result<u8, FrameError> {
    let mut b = [0u8; 1];
    read_bytes(r, &mut b)?;
    Ok(b[0])
}

/// Read the first byte of a frame, distinguishing a clean close (`None`)
/// from mid-stream errors. Retries `Interrupted`.
fn read_first_byte(r: &mut impl Read) -> Result<Option<u8>, FrameError> {
    let mut b = [0u8; 1];
    loop {
        match r.read(&mut b) {
            Ok(0) => return Ok(None), // EOF at a frame boundary
            Ok(_) => return Ok(Some(b[0])),
            Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(FrameError::from_io(e)),
        }
    }
}

fn read_magic_version(first: u8, r: &mut impl Read) -> Result<(), FrameError> {
    let mut magic = [first, 0, 0, 0];
    read_bytes(r, &mut magic[1..])?;
    if magic != MAGIC {
        return Err(FrameError::BadMagic(magic));
    }
    let version = read_u8(r)?;
    if version != VERSION {
        return Err(FrameError::BadVersion { got: version });
    }
    Ok(())
}

/// Read `(dims, data)` — the shared tail of requests and ok-responses —
/// validating every length before allocating.
fn read_shape_and_data(r: &mut impl Read) -> Result<(Vec<usize>, Vec<f32>), FrameError> {
    let ndims = read_u8(r)? as usize;
    if ndims == 0 || ndims > MAX_DIMS {
        return Err(FrameError::BadDims { ndims });
    }
    let mut dims = Vec::with_capacity(ndims);
    for _ in 0..ndims {
        dims.push(read_u32(r)? as usize);
    }
    let count = read_u32(r)? as u64;
    check_shape(&dims, count)?;
    let mut data = Vec::with_capacity(count as usize);
    let mut b = [0u8; 4];
    for _ in 0..count {
        read_bytes(r, &mut b)?;
        data.push(f32::from_le_bytes(b));
    }
    Ok((dims, data))
}

/// Decode one request frame from a reader; `Ok(None)` on a clean EOF at a
/// frame boundary. Any short read mid-frame is [`FrameError::Truncated`].
pub fn read_request(r: &mut impl Read) -> Result<Option<RequestFrame>, FrameError> {
    let Some(first) = read_first_byte(r)? else { return Ok(None) };
    read_request_resuming(first, r).map(Some)
}

/// Decode a request whose first byte was already consumed — the server
/// peels one byte off the stream so idle waiting (no frame started, stop
/// flag polled) is separate from the framed read deadline.
pub fn read_request_resuming(first: u8, r: &mut impl Read) -> Result<RequestFrame, FrameError> {
    read_magic_version(first, r)?;
    let id = read_u64(r)?;
    let model_len = read_u16(r)? as usize;
    if model_len > MAX_MODEL_LEN {
        return Err(FrameError::ModelTooLong { len: model_len });
    }
    let mut name = vec![0u8; model_len];
    read_bytes(r, &mut name)?;
    let model = String::from_utf8(name).map_err(|_| FrameError::BadUtf8)?;
    let (dims, data) = read_shape_and_data(r)?;
    Ok(RequestFrame { id, model, dims, data })
}

/// Decode one response frame; `Ok(None)` on a clean EOF at a frame
/// boundary.
pub fn read_response(r: &mut impl Read) -> Result<Option<ResponseFrame>, FrameError> {
    let Some(first) = read_first_byte(r)? else { return Ok(None) };
    read_magic_version(first, r)?;
    let id = read_u64(r)?;
    let status = read_u8(r)?;
    if status_name(status).is_none() {
        return Err(FrameError::BadStatus { got: status });
    }
    let body = if status == STATUS_OK {
        let (dims, data) = read_shape_and_data(r)?;
        ResponseBody::Tensor { dims, data }
    } else {
        let len = read_u32(r)? as usize;
        if len > MAX_MSG_LEN {
            return Err(FrameError::MessageTooLong { len });
        }
        let mut msg = vec![0u8; len];
        read_bytes(r, &mut msg)?;
        ResponseBody::Message(String::from_utf8(msg).map_err(|_| FrameError::BadUtf8)?)
    };
    Ok(Some(ResponseFrame { id, status, body }))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    #[test]
    fn request_round_trips() {
        let buf = encode_request(7, "ball", &[2, 2], &[1.0, -2.5, 0.0, 3.25]).unwrap();
        let f = read_request(&mut Cursor::new(&buf)).unwrap().unwrap();
        assert_eq!(f.id, 7);
        assert_eq!(f.model, "ball");
        assert_eq!(f.dims, vec![2, 2]);
        assert_eq!(f.data, vec![1.0, -2.5, 0.0, 3.25]);
        // Clean EOF after the frame.
        let mut c = Cursor::new(&buf);
        read_request(&mut c).unwrap();
        assert!(read_request(&mut c).unwrap().is_none());
    }

    #[test]
    fn responses_round_trip_ok_and_err() {
        let t = Tensor::from_vec(&[1, 2], vec![4.0, 5.0]).unwrap();
        let buf = encode_ok(9, &t).unwrap();
        let f = read_response(&mut Cursor::new(&buf)).unwrap().unwrap();
        assert_eq!(f.id, 9);
        assert_eq!(f.status, STATUS_OK);
        assert_eq!(f.body, ResponseBody::Tensor { dims: vec![1, 2], data: vec![4.0, 5.0] });

        let e = ServeError::QueueFull { capacity: 3 };
        let buf = encode_err(11, &e);
        let f = read_response(&mut Cursor::new(&buf)).unwrap().unwrap();
        assert_eq!(f.status, status_of(&e));
        match f.body {
            ResponseBody::Message(m) => assert!(m.contains("capacity 3"), "{m}"),
            other => panic!("expected message body, got {other:?}"),
        }
    }

    #[test]
    fn status_bytes_match_serve_error_kinds() {
        let errs = [
            ServeError::DeadlineExceeded { model: "m".into(), late_by_us: 1 },
            ServeError::QueueFull { capacity: 1 },
            ServeError::EngineFailed { model: "m".into(), reason: "r".into() },
            ServeError::ModelUnknown { model: "m".into(), registered: vec![] },
            ServeError::Degraded {
                model: "m".into(),
                primary_error: "p".into(),
                fallback_error: "f".into(),
            },
            ServeError::Stopped,
        ];
        for e in &errs {
            let s = status_of(e);
            assert_ne!(s, STATUS_OK);
            assert_eq!(status_name(s), Some(e.kind()), "status byte names the kind");
        }
        assert_eq!(status_name(STATUS_OK), Some("ok"));
        assert_eq!(status_name(200), None);
    }

    #[test]
    fn encode_enforces_the_same_limits_as_decode() {
        let long = "m".repeat(MAX_MODEL_LEN + 1);
        assert_eq!(
            encode_request(1, &long, &[1], &[0.0]),
            Err(FrameError::ModelTooLong { len: MAX_MODEL_LEN + 1 })
        );
        assert!(matches!(
            encode_request(1, "m", &[], &[]),
            Err(FrameError::BadDims { ndims: 0 })
        ));
        assert!(matches!(
            encode_request(1, "m", &[1, 2], &[0.0; 3]),
            Err(FrameError::CountMismatch { .. })
        ));
    }

    #[test]
    fn oversize_message_is_truncated_not_dropped() {
        let e = ServeError::EngineFailed { model: "m".into(), reason: "x".repeat(MAX_MSG_LEN * 2) };
        let buf = encode_err(1, &e);
        let f = read_response(&mut Cursor::new(&buf)).unwrap().unwrap();
        match f.body {
            ResponseBody::Message(m) => assert_eq!(m.len(), MAX_MSG_LEN),
            other => panic!("expected message body, got {other:?}"),
        }
    }
}
