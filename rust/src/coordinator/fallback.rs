//! Graceful degradation: a circuit-breaker fallback wrapper around a
//! primary engine.
//!
//! The paper's deployment target is a generated-C engine produced by a
//! compile-at-runtime pipeline (cc + dlopen). When that engine is unhealthy
//! — compiler missing, object corrupted, inference panicking — the serving
//! loop must keep answering frames. [`FallbackEngine`] routes around the
//! sick primary to a reference engine (typically the interpreter, whose
//! output the generated C is verified against), while a [`CircuitBreaker`]
//! stops hammering the primary and periodically probes it for recovery. A
//! healed engine (e.g. a background recompile) is hot-swapped back in with
//! [`FallbackEngine::swap_primary`].

use super::metrics::ServeCounters;
use crate::runtime::InferenceEngine;
use crate::tensor::Tensor;
use crate::util::panic_message;
use anyhow::Result;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::AtomicU64;
use std::sync::{Arc, Mutex, RwLock};
use std::time::{Duration, Instant};

/// Circuit breaker tuning.
#[derive(Debug, Clone)]
pub struct BreakerConfig {
    /// Consecutive primary failures that open the breaker.
    pub failure_threshold: u32,
    /// How long the breaker stays open before admitting a half-open probe.
    /// `Duration::ZERO` makes the very next call a probe (used by the
    /// deterministic chaos tests).
    pub cooldown: Duration,
}

impl Default for BreakerConfig {
    fn default() -> Self {
        BreakerConfig { failure_threshold: 3, cooldown: Duration::from_millis(250) }
    }
}

/// Observable breaker state.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BreakerState {
    /// Primary healthy; all traffic goes to it.
    Closed,
    /// Primary presumed down; traffic goes to the fallback.
    Open,
    /// One probe request is trying the primary.
    HalfOpen,
}

/// State transitions a breaker reports to its observer. Emitted inside the
/// breaker's own lock, so observers see exact transition counts even under
/// concurrency.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BreakerEvent {
    /// Closed→open or half-open→open.
    Opened,
    /// Open→half-open: a probe was admitted.
    HalfOpened,
    /// Half-open→closed: the probe succeeded.
    Closed,
}

type BreakerObserver = Box<dyn Fn(BreakerEvent) + Send + Sync>;

enum St {
    Closed { fails: u32 },
    Open { since: Instant },
    HalfOpen { probe_started: Instant },
}

/// Closed → (K consecutive failures) → Open → (cooldown) → HalfOpen →
/// success → Closed / failure → Open.
pub struct CircuitBreaker {
    cfg: BreakerConfig,
    st: Mutex<St>,
    observer: Option<BreakerObserver>,
}

impl CircuitBreaker {
    pub fn new(cfg: BreakerConfig) -> Self {
        CircuitBreaker { cfg, st: Mutex::new(St::Closed { fails: 0 }), observer: None }
    }

    /// Report transitions to an arbitrary observer. Engine-level breakers
    /// map events to the `breaker_*` counters (see
    /// [`CircuitBreaker::set_counters`]); shard-level breakers map them to
    /// `shard_ejects`/`shard_probes`/`shard_readmits` instead, so the two
    /// layers stay separately observable.
    pub fn set_observer(&mut self, observer: BreakerObserver) {
        self.observer = Some(observer);
    }

    /// Engine-level counter wiring: transitions bump
    /// `breaker_opens`/`breaker_half_opens`/`breaker_closes`.
    pub fn set_counters(&mut self, counters: Arc<ServeCounters>) {
        self.set_observer(Box::new(move |ev| {
            ServeCounters::bump(match ev {
                BreakerEvent::Opened => &counters.breaker_opens,
                BreakerEvent::HalfOpened => &counters.breaker_half_opens,
                BreakerEvent::Closed => &counters.breaker_closes,
            });
        }));
    }

    fn emit(&self, ev: BreakerEvent) {
        if let Some(obs) = &self.observer {
            obs(ev);
        }
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, St> {
        self.st.lock().unwrap_or_else(|e| e.into_inner())
    }

    pub fn state(&self) -> BreakerState {
        match *self.lock() {
            St::Closed { .. } => BreakerState::Closed,
            St::Open { .. } => BreakerState::Open,
            St::HalfOpen { .. } => BreakerState::HalfOpen,
        }
    }

    /// May this call try the primary? Open→HalfOpen transitions happen here
    /// (the admitted caller *is* the probe). While a probe is in flight,
    /// other callers are routed to the fallback; a probe that never resolves
    /// (crashed worker) is replaced after another cooldown.
    pub fn allow(&self) -> bool {
        let mut st = self.lock();
        match *st {
            St::Closed { .. } => true,
            St::Open { since } => {
                if since.elapsed() >= self.cfg.cooldown {
                    *st = St::HalfOpen { probe_started: Instant::now() };
                    self.emit(BreakerEvent::HalfOpened);
                    true
                } else {
                    false
                }
            }
            St::HalfOpen { probe_started } => {
                if probe_started.elapsed() >= self.cfg.cooldown.max(Duration::from_millis(1)) {
                    // The previous probe is presumed lost; admit another.
                    *st = St::HalfOpen { probe_started: Instant::now() };
                    true
                } else {
                    false
                }
            }
        }
    }

    /// Report the result of an *admitted* primary attempt.
    pub fn on_success(&self) {
        let mut st = self.lock();
        match *st {
            St::Closed { .. } => *st = St::Closed { fails: 0 },
            St::HalfOpen { .. } => {
                *st = St::Closed { fails: 0 };
                self.emit(BreakerEvent::Closed);
            }
            // A call admitted while closed can resolve after the breaker
            // opened; ignore the stale result so Open stays observable.
            St::Open { .. } => {}
        }
    }

    /// Report a failed *admitted* primary attempt.
    pub fn on_failure(&self) {
        let mut st = self.lock();
        match *st {
            St::Closed { fails } => {
                let fails = fails + 1;
                if fails >= self.cfg.failure_threshold {
                    *st = St::Open { since: Instant::now() };
                    self.emit(BreakerEvent::Opened);
                } else {
                    *st = St::Closed { fails };
                }
            }
            St::HalfOpen { .. } => {
                *st = St::Open { since: Instant::now() };
                self.emit(BreakerEvent::Opened);
            }
            St::Open { .. } => {}
        }
    }

    /// Force-open (ops/testing).
    pub fn trip(&self) {
        *self.lock() = St::Open { since: Instant::now() };
        self.emit(BreakerEvent::Opened);
    }

    /// Reset to closed (called after a heal swap).
    pub fn reset(&self) {
        *self.lock() = St::Closed { fails: 0 };
    }
}

/// An [`InferenceEngine`] that serves from a primary engine while healthy
/// and degrades to a fallback (interpreter) when the breaker is open.
/// Primary panics are isolated here too, so a crashing generated-C engine
/// becomes a breaker failure instead of a worker death.
pub struct FallbackEngine {
    label: String,
    primary: RwLock<Arc<dyn InferenceEngine>>,
    fallback: Arc<dyn InferenceEngine>,
    breaker: CircuitBreaker,
    counters: Option<Arc<ServeCounters>>,
}

impl FallbackEngine {
    pub fn new(
        primary: Arc<dyn InferenceEngine>,
        fallback: Arc<dyn InferenceEngine>,
        cfg: BreakerConfig,
    ) -> Self {
        let label = format!("fallback({}->{})", primary.name(), fallback.name());
        FallbackEngine {
            label,
            primary: RwLock::new(primary),
            fallback,
            breaker: CircuitBreaker::new(cfg),
            counters: None,
        }
    }

    /// Wire shared serving counters (fallback/degraded/breaker telemetry).
    pub fn with_counters(mut self, counters: Arc<ServeCounters>) -> Self {
        self.breaker.set_counters(Arc::clone(&counters));
        self.counters = Some(counters);
        self
    }

    pub fn breaker(&self) -> &CircuitBreaker {
        &self.breaker
    }

    fn primary_engine(&self) -> Arc<dyn InferenceEngine> {
        Arc::clone(&self.primary.read().unwrap_or_else(|e| e.into_inner()))
    }

    /// Name of the engine currently installed as primary.
    pub fn primary_name(&self) -> String {
        self.primary_engine().name().to_string()
    }

    /// Hot-swap a healed primary in and close the breaker.
    pub fn swap_primary(&self, engine: Arc<dyn InferenceEngine>) {
        *self.primary.write().unwrap_or_else(|e| e.into_inner()) = engine;
        self.breaker.reset();
    }

    /// Spawn a background heal: `build` produces a fresh primary (e.g. a
    /// recompile of the generated C); on success it is swapped in and the
    /// breaker closes. Returns the join handle (true = healed).
    pub fn heal_in_background<F>(self: &Arc<Self>, build: F) -> std::thread::JoinHandle<bool>
    where
        F: FnOnce() -> Result<Arc<dyn InferenceEngine>> + Send + 'static,
    {
        let me = Arc::clone(self);
        std::thread::spawn(move || match build() {
            Ok(engine) => {
                me.swap_primary(engine);
                true
            }
            Err(e) => {
                eprintln!("[nncg] heal recompile failed: {e:#}");
                false
            }
        })
    }

    fn bump(&self, pick: impl Fn(&ServeCounters) -> &AtomicU64) {
        if let Some(c) = &self.counters {
            ServeCounters::bump(pick(c));
        }
    }
}

impl InferenceEngine for FallbackEngine {
    fn name(&self) -> &str {
        &self.label
    }

    fn infer(&self, input: &Tensor) -> Result<Tensor> {
        let mut primary_error: Option<String> = None;
        if self.breaker.allow() {
            let engine = self.primary_engine();
            match catch_unwind(AssertUnwindSafe(|| engine.infer(input))) {
                Ok(Ok(y)) => {
                    self.breaker.on_success();
                    return Ok(y);
                }
                Ok(Err(e)) => {
                    self.breaker.on_failure();
                    primary_error = Some(format!("{e:#}"));
                }
                Err(payload) => {
                    self.breaker.on_failure();
                    self.bump(|c| &c.engine_panics);
                    primary_error = Some(format!("panicked: {}", panic_message(&*payload)));
                }
            }
        }
        // Degraded path: primary failed just now or the breaker is open.
        self.bump(|c| &c.fallback_served);
        match self.fallback.infer(input) {
            Ok(y) => Ok(y),
            Err(fe) => {
                self.bump(|c| &c.degraded);
                Err(super::ServeError::Degraded {
                    model: self.label.clone(),
                    primary_error: primary_error.unwrap_or_else(|| "circuit open".into()),
                    fallback_error: format!("{fe:#}"),
                }
                .into())
            }
        }
    }

    /// Batched mirror of [`FallbackEngine::infer`]: the whole batch goes to
    /// the primary's `infer_batch` (one breaker consult, one outcome — a
    /// batch is one unit of primary work), and on failure the whole batch
    /// degrades to the fallback with `fallback_served` counted per request.
    fn infer_batch(&self, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        let mut primary_error: Option<String> = None;
        if self.breaker.allow() {
            let engine = self.primary_engine();
            match catch_unwind(AssertUnwindSafe(|| engine.infer_batch(inputs))) {
                Ok(Ok(ys)) if ys.len() == inputs.len() => {
                    self.breaker.on_success();
                    return Ok(ys);
                }
                Ok(Ok(ys)) => {
                    self.breaker.on_failure();
                    primary_error =
                        Some(format!("batch returned {} outputs for {} inputs", ys.len(), inputs.len()));
                }
                Ok(Err(e)) => {
                    self.breaker.on_failure();
                    primary_error = Some(format!("{e:#}"));
                }
                Err(payload) => {
                    self.breaker.on_failure();
                    self.bump(|c| &c.engine_panics);
                    primary_error = Some(format!("panicked: {}", panic_message(&*payload)));
                }
            }
        }
        for _ in inputs {
            self.bump(|c| &c.fallback_served);
        }
        match self.fallback.infer_batch(inputs) {
            Ok(ys) => Ok(ys),
            Err(fe) => {
                self.bump(|c| &c.degraded);
                Err(super::ServeError::Degraded {
                    model: self.label.clone(),
                    primary_error: primary_error.unwrap_or_else(|| "circuit open".into()),
                    fallback_error: format!("{fe:#}"),
                }
                .into())
            }
        }
    }
}

/// Per-model background compilation pipeline: each model gets at most one
/// async rebuild slot. A rebuild runs a caller-supplied build closure
/// (typically `CcDriver::compile` under `CompileLimits`, wrapped in
/// `CompiledCnn::build_with`) off the request path and hot-swaps the result
/// into the shared [`super::Router`] via `register` on success — the
/// serving workers pick the healed engine up on their next lookup without
/// ever blocking on the compile.
pub struct HealPipeline {
    router: Arc<super::Router>,
    slots: Mutex<std::collections::HashMap<String, std::thread::JoinHandle<bool>>>,
    counters: Option<Arc<ServeCounters>>,
}

impl HealPipeline {
    pub fn new(router: Arc<super::Router>) -> Self {
        HealPipeline { router, slots: Mutex::new(std::collections::HashMap::new()), counters: None }
    }

    /// Wire shared serving counters (`heals_started/succeeded/failed`).
    pub fn with_counters(mut self, counters: Arc<ServeCounters>) -> Self {
        self.counters = Some(counters);
        self
    }

    fn bump(&self, pick: impl Fn(&ServeCounters) -> &AtomicU64) {
        if let Some(c) = &self.counters {
            ServeCounters::bump(pick(c));
        }
    }

    fn lock_slots(
        &self,
    ) -> std::sync::MutexGuard<'_, std::collections::HashMap<String, std::thread::JoinHandle<bool>>>
    {
        self.slots.lock().unwrap_or_else(|e| e.into_inner())
    }

    /// Request an async rebuild of `model`. Returns `false` without
    /// spawning when a rebuild for this model is already in flight (the
    /// single rebuild slot); `true` when a rebuild was started. On build
    /// success the fresh engine replaces the model's entry in the router.
    pub fn request_rebuild<F>(&self, model: &str, build: F) -> bool
    where
        F: FnOnce() -> Result<Arc<dyn InferenceEngine>> + Send + 'static,
    {
        let mut slots = self.lock_slots();
        if let Some(h) = slots.get(model) {
            if !h.is_finished() {
                return false;
            }
            let _ = slots.remove(model).map(|h| h.join());
        }
        self.bump(|c| &c.heals_started);
        let router = Arc::clone(&self.router);
        let counters = self.counters.clone();
        let name = model.to_string();
        let handle = std::thread::spawn(move || match build() {
            Ok(engine) => {
                router.register(&name, engine);
                if let Some(c) = &counters {
                    ServeCounters::bump(&c.heals_succeeded);
                }
                true
            }
            Err(e) => {
                eprintln!("[nncg] heal rebuild for model {name:?} failed: {e:#}");
                if let Some(c) = &counters {
                    ServeCounters::bump(&c.heals_failed);
                }
                false
            }
        });
        slots.insert(model.to_string(), handle);
        true
    }

    /// Number of rebuilds currently in flight.
    pub fn in_flight(&self) -> usize {
        self.lock_slots().values().filter(|h| !h.is_finished()).count()
    }

    /// Join every outstanding rebuild; returns how many succeeded.
    pub fn wait_idle(&self) -> usize {
        let handles: Vec<_> = {
            let mut slots = self.lock_slots();
            slots.drain().map(|(_, h)| h).collect()
        };
        handles.into_iter().map(|h| h.join().unwrap_or(false)).filter(|&ok| ok).count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::faults::{FaultPlan, FaultSite, FaultSpec, FaultyEngine};
    use crate::graph::zoo;
    use crate::interp::InterpEngine;

    fn interp(seed: u64) -> Arc<dyn InferenceEngine> {
        Arc::new(InterpEngine::new(zoo::tiny_test_net().with_random_weights(seed)).unwrap())
    }

    fn zero_cooldown(threshold: u32) -> BreakerConfig {
        BreakerConfig { failure_threshold: threshold, cooldown: Duration::ZERO }
    }

    #[test]
    fn breaker_walks_closed_open_halfopen_closed() {
        let b = CircuitBreaker::new(BreakerConfig {
            failure_threshold: 2,
            cooldown: Duration::from_millis(40),
        });
        assert_eq!(b.state(), BreakerState::Closed);
        assert!(b.allow());
        b.on_failure();
        assert_eq!(b.state(), BreakerState::Closed, "one failure below threshold");
        assert!(b.allow());
        b.on_failure();
        assert_eq!(b.state(), BreakerState::Open);
        assert!(!b.allow(), "open: calls rejected before cooldown");
        std::thread::sleep(Duration::from_millis(55));
        assert!(b.allow(), "cooldown elapsed: probe admitted");
        assert_eq!(b.state(), BreakerState::HalfOpen);
        assert!(!b.allow(), "only one probe at a time");
        b.on_success();
        assert_eq!(b.state(), BreakerState::Closed);
    }

    #[test]
    fn failed_probe_reopens() {
        let b = CircuitBreaker::new(zero_cooldown(1));
        b.on_failure();
        assert_eq!(b.state(), BreakerState::Open);
        assert!(b.allow());
        b.on_failure();
        assert_eq!(b.state(), BreakerState::Open, "failed probe re-opens");
    }

    #[test]
    fn success_resets_consecutive_failures() {
        let b = CircuitBreaker::new(zero_cooldown(2));
        b.on_failure();
        b.on_success();
        b.on_failure();
        assert_eq!(b.state(), BreakerState::Closed, "non-consecutive failures don't open");
    }

    #[test]
    fn fallback_serves_when_primary_fails_and_heals_on_swap() {
        let plan = FaultPlan::builder(5).site(FaultSite::EngineFail, FaultSpec::Every(1)).build();
        let primary: Arc<dyn InferenceEngine> = Arc::new(FaultyEngine::new(interp(1), plan));
        let fb = interp(2);
        let counters = Arc::new(ServeCounters::default());
        let fe = Arc::new(
            FallbackEngine::new(primary, Arc::clone(&fb), zero_cooldown(2))
                .with_counters(Arc::clone(&counters)),
        );

        let x = Tensor::zeros(&[8, 8, 1]);
        let reference = fb.infer(&x).unwrap();
        for _ in 0..4 {
            let y = fe.infer(&x).unwrap();
            assert_eq!(y, reference, "degraded replies come bit-identical from the fallback");
        }
        assert_eq!(fe.breaker().state(), BreakerState::Open);
        assert!(counters.fallback_served.load(std::sync::atomic::Ordering::Relaxed) >= 4);

        // Heal: swap a healthy primary in; traffic returns to it.
        let healthy = interp(9);
        let healed_reference = healthy.infer(&x).unwrap();
        fe.swap_primary(healthy);
        assert_eq!(fe.breaker().state(), BreakerState::Closed);
        let before = counters.fallback_served.load(std::sync::atomic::Ordering::Relaxed);
        assert_eq!(fe.infer(&x).unwrap(), healed_reference, "healed primary serves again");
        assert_eq!(counters.fallback_served.load(std::sync::atomic::Ordering::Relaxed), before);
    }

    #[test]
    fn primary_panic_is_contained_and_counted() {
        let plan = FaultPlan::builder(6).site(FaultSite::EnginePanic, FaultSpec::First(1)).build();
        let primary: Arc<dyn InferenceEngine> = Arc::new(FaultyEngine::new(interp(1), plan));
        let counters = Arc::new(ServeCounters::default());
        let fe = FallbackEngine::new(primary, interp(2), zero_cooldown(3))
            .with_counters(Arc::clone(&counters));
        let x = Tensor::zeros(&[8, 8, 1]);
        assert!(fe.infer(&x).is_ok(), "panic routed to fallback, not unwound");
        assert_eq!(counters.engine_panics.load(std::sync::atomic::Ordering::Relaxed), 1);
    }

    #[test]
    fn degraded_error_when_both_engines_fail() {
        let plan = FaultPlan::builder(7).site(FaultSite::EngineFail, FaultSpec::Every(1)).build();
        let bad_primary: Arc<dyn InferenceEngine> = Arc::new(FaultyEngine::new(interp(1), plan));
        let plan2 = FaultPlan::builder(8).site(FaultSite::EngineFail, FaultSpec::Every(1)).build();
        let bad_fallback: Arc<dyn InferenceEngine> = Arc::new(FaultyEngine::new(interp(2), plan2));
        let counters = Arc::new(ServeCounters::default());
        let fe = FallbackEngine::new(bad_primary, bad_fallback, zero_cooldown(5))
            .with_counters(Arc::clone(&counters));
        let err = fe.infer(&Tensor::zeros(&[8, 8, 1])).unwrap_err();
        assert!(format!("{err:#}").contains("degraded"), "{err:#}");
        assert_eq!(counters.degraded.load(std::sync::atomic::Ordering::Relaxed), 1);
    }

    #[test]
    fn breaker_observer_sees_exact_transitions() {
        use std::sync::atomic::{AtomicU64, Ordering};
        let opened = Arc::new(AtomicU64::new(0));
        let mut b = CircuitBreaker::new(zero_cooldown(1));
        let o = Arc::clone(&opened);
        b.set_observer(Box::new(move |ev| {
            if ev == BreakerEvent::Opened {
                o.fetch_add(1, Ordering::Relaxed);
            }
        }));
        b.on_failure();
        assert_eq!(opened.load(Ordering::Relaxed), 1);
        assert!(b.allow(), "zero cooldown admits a probe");
        b.on_failure();
        assert_eq!(opened.load(Ordering::Relaxed), 2, "failed probe re-opens");
    }

    #[test]
    fn heal_pipeline_single_slot_and_hot_swap() {
        use std::sync::atomic::Ordering;
        let router = Arc::new(crate::coordinator::Router::new());
        router.register("tiny", interp(1));
        let counters = Arc::new(ServeCounters::default());
        let heal = HealPipeline::new(Arc::clone(&router)).with_counters(Arc::clone(&counters));

        // A slow rebuild occupies the model's single slot.
        let started = heal.request_rebuild("tiny", || {
            std::thread::sleep(Duration::from_millis(40));
            Ok(interp(2))
        });
        assert!(started);
        assert!(
            !heal.request_rebuild("tiny", || Ok(interp(3))),
            "second rebuild for the same model must be rejected while one is in flight"
        );
        // A different model gets its own slot.
        router.register("other", interp(4));
        assert!(heal.request_rebuild("other", || Ok(interp(5))));
        assert_eq!(heal.wait_idle(), 2);
        assert_eq!(counters.heals_started.load(Ordering::Relaxed), 2);
        assert_eq!(counters.heals_succeeded.load(Ordering::Relaxed), 2);

        // The slot is free again after completion, and the router now
        // serves the rebuilt engine.
        let x = Tensor::zeros(&[8, 8, 1]);
        let rebuilt_ref = interp(2).infer(&x).unwrap();
        assert_eq!(router.infer("tiny", &x).unwrap(), rebuilt_ref, "hot-swap took effect");
        assert!(heal.request_rebuild("tiny", || Ok(interp(6))));
        heal.wait_idle();
    }

    #[test]
    fn heal_pipeline_counts_failures() {
        use std::sync::atomic::Ordering;
        let router = Arc::new(crate::coordinator::Router::new());
        router.register("tiny", interp(1));
        let counters = Arc::new(ServeCounters::default());
        let heal = HealPipeline::new(Arc::clone(&router)).with_counters(Arc::clone(&counters));
        let x = Tensor::zeros(&[8, 8, 1]);
        let before = router.infer("tiny", &x).unwrap();
        assert!(heal.request_rebuild("tiny", || anyhow::bail!("compiler exploded")));
        assert_eq!(heal.wait_idle(), 0);
        assert_eq!(counters.heals_failed.load(Ordering::Relaxed), 1);
        assert_eq!(router.infer("tiny", &x).unwrap(), before, "failed heal leaves the engine alone");
    }

    #[test]
    fn heal_in_background_swaps_primary() {
        let plan = FaultPlan::builder(9).site(FaultSite::EngineFail, FaultSpec::Every(1)).build();
        let primary: Arc<dyn InferenceEngine> = Arc::new(FaultyEngine::new(interp(1), plan));
        let fe = Arc::new(FallbackEngine::new(primary, interp(2), zero_cooldown(1)));
        let handle = fe.heal_in_background(|| {
            Ok(Arc::new(InterpEngine::new(zoo::tiny_test_net().with_random_weights(3)).unwrap())
                as Arc<dyn InferenceEngine>)
        });
        assert!(handle.join().unwrap());
        assert_eq!(fe.breaker().state(), BreakerState::Closed);
        assert!(fe.primary_name().contains("interp"));
    }
}
