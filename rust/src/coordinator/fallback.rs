//! Graceful degradation: a circuit-breaker fallback wrapper around a
//! primary engine.
//!
//! The paper's deployment target is a generated-C engine produced by a
//! compile-at-runtime pipeline (cc + dlopen). When that engine is unhealthy
//! — compiler missing, object corrupted, inference panicking — the serving
//! loop must keep answering frames. [`FallbackEngine`] routes around the
//! sick primary to a reference engine (typically the interpreter, whose
//! output the generated C is verified against), while a [`CircuitBreaker`]
//! stops hammering the primary and periodically probes it for recovery. A
//! healed engine (e.g. a background recompile) is hot-swapped back in with
//! [`FallbackEngine::swap_primary`].

use super::metrics::ServeCounters;
use crate::runtime::InferenceEngine;
use crate::tensor::Tensor;
use crate::util::panic_message;
use anyhow::Result;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::AtomicU64;
use std::sync::{Arc, Mutex, RwLock};
use std::time::{Duration, Instant};

/// Circuit breaker tuning.
#[derive(Debug, Clone)]
pub struct BreakerConfig {
    /// Consecutive primary failures that open the breaker.
    pub failure_threshold: u32,
    /// How long the breaker stays open before admitting a half-open probe.
    /// `Duration::ZERO` makes the very next call a probe (used by the
    /// deterministic chaos tests).
    pub cooldown: Duration,
}

impl Default for BreakerConfig {
    fn default() -> Self {
        BreakerConfig { failure_threshold: 3, cooldown: Duration::from_millis(250) }
    }
}

/// Observable breaker state.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BreakerState {
    /// Primary healthy; all traffic goes to it.
    Closed,
    /// Primary presumed down; traffic goes to the fallback.
    Open,
    /// One probe request is trying the primary.
    HalfOpen,
}

enum St {
    Closed { fails: u32 },
    Open { since: Instant },
    HalfOpen { probe_started: Instant },
}

/// Closed → (K consecutive failures) → Open → (cooldown) → HalfOpen →
/// success → Closed / failure → Open.
pub struct CircuitBreaker {
    cfg: BreakerConfig,
    st: Mutex<St>,
    counters: Option<Arc<ServeCounters>>,
}

impl CircuitBreaker {
    pub fn new(cfg: BreakerConfig) -> Self {
        CircuitBreaker { cfg, st: Mutex::new(St::Closed { fails: 0 }), counters: None }
    }

    pub fn set_counters(&mut self, counters: Arc<ServeCounters>) {
        self.counters = Some(counters);
    }

    fn bump(&self, pick: impl Fn(&ServeCounters) -> &AtomicU64) {
        if let Some(c) = &self.counters {
            ServeCounters::bump(pick(c));
        }
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, St> {
        self.st.lock().unwrap_or_else(|e| e.into_inner())
    }

    pub fn state(&self) -> BreakerState {
        match *self.lock() {
            St::Closed { .. } => BreakerState::Closed,
            St::Open { .. } => BreakerState::Open,
            St::HalfOpen { .. } => BreakerState::HalfOpen,
        }
    }

    /// May this call try the primary? Open→HalfOpen transitions happen here
    /// (the admitted caller *is* the probe). While a probe is in flight,
    /// other callers are routed to the fallback; a probe that never resolves
    /// (crashed worker) is replaced after another cooldown.
    pub fn allow(&self) -> bool {
        let mut st = self.lock();
        match *st {
            St::Closed { .. } => true,
            St::Open { since } => {
                if since.elapsed() >= self.cfg.cooldown {
                    *st = St::HalfOpen { probe_started: Instant::now() };
                    self.bump(|c| &c.breaker_half_opens);
                    true
                } else {
                    false
                }
            }
            St::HalfOpen { probe_started } => {
                if probe_started.elapsed() >= self.cfg.cooldown.max(Duration::from_millis(1)) {
                    // The previous probe is presumed lost; admit another.
                    *st = St::HalfOpen { probe_started: Instant::now() };
                    true
                } else {
                    false
                }
            }
        }
    }

    /// Report the result of an *admitted* primary attempt.
    pub fn on_success(&self) {
        let mut st = self.lock();
        match *st {
            St::Closed { .. } => *st = St::Closed { fails: 0 },
            St::HalfOpen { .. } => {
                *st = St::Closed { fails: 0 };
                self.bump(|c| &c.breaker_closes);
            }
            // A call admitted while closed can resolve after the breaker
            // opened; ignore the stale result so Open stays observable.
            St::Open { .. } => {}
        }
    }

    /// Report a failed *admitted* primary attempt.
    pub fn on_failure(&self) {
        let mut st = self.lock();
        match *st {
            St::Closed { fails } => {
                let fails = fails + 1;
                if fails >= self.cfg.failure_threshold {
                    *st = St::Open { since: Instant::now() };
                    self.bump(|c| &c.breaker_opens);
                } else {
                    *st = St::Closed { fails };
                }
            }
            St::HalfOpen { .. } => {
                *st = St::Open { since: Instant::now() };
                self.bump(|c| &c.breaker_opens);
            }
            St::Open { .. } => {}
        }
    }

    /// Force-open (ops/testing).
    pub fn trip(&self) {
        *self.lock() = St::Open { since: Instant::now() };
        self.bump(|c| &c.breaker_opens);
    }

    /// Reset to closed (called after a heal swap).
    pub fn reset(&self) {
        *self.lock() = St::Closed { fails: 0 };
    }
}

/// An [`InferenceEngine`] that serves from a primary engine while healthy
/// and degrades to a fallback (interpreter) when the breaker is open.
/// Primary panics are isolated here too, so a crashing generated-C engine
/// becomes a breaker failure instead of a worker death.
pub struct FallbackEngine {
    label: String,
    primary: RwLock<Arc<dyn InferenceEngine>>,
    fallback: Arc<dyn InferenceEngine>,
    breaker: CircuitBreaker,
    counters: Option<Arc<ServeCounters>>,
}

impl FallbackEngine {
    pub fn new(
        primary: Arc<dyn InferenceEngine>,
        fallback: Arc<dyn InferenceEngine>,
        cfg: BreakerConfig,
    ) -> Self {
        let label = format!("fallback({}->{})", primary.name(), fallback.name());
        FallbackEngine {
            label,
            primary: RwLock::new(primary),
            fallback,
            breaker: CircuitBreaker::new(cfg),
            counters: None,
        }
    }

    /// Wire shared serving counters (fallback/degraded/breaker telemetry).
    pub fn with_counters(mut self, counters: Arc<ServeCounters>) -> Self {
        self.breaker.set_counters(Arc::clone(&counters));
        self.counters = Some(counters);
        self
    }

    pub fn breaker(&self) -> &CircuitBreaker {
        &self.breaker
    }

    fn primary_engine(&self) -> Arc<dyn InferenceEngine> {
        Arc::clone(&self.primary.read().unwrap_or_else(|e| e.into_inner()))
    }

    /// Name of the engine currently installed as primary.
    pub fn primary_name(&self) -> String {
        self.primary_engine().name().to_string()
    }

    /// Hot-swap a healed primary in and close the breaker.
    pub fn swap_primary(&self, engine: Arc<dyn InferenceEngine>) {
        *self.primary.write().unwrap_or_else(|e| e.into_inner()) = engine;
        self.breaker.reset();
    }

    /// Spawn a background heal: `build` produces a fresh primary (e.g. a
    /// recompile of the generated C); on success it is swapped in and the
    /// breaker closes. Returns the join handle (true = healed).
    pub fn heal_in_background<F>(self: &Arc<Self>, build: F) -> std::thread::JoinHandle<bool>
    where
        F: FnOnce() -> Result<Arc<dyn InferenceEngine>> + Send + 'static,
    {
        let me = Arc::clone(self);
        std::thread::spawn(move || match build() {
            Ok(engine) => {
                me.swap_primary(engine);
                true
            }
            Err(e) => {
                eprintln!("[nncg] heal recompile failed: {e:#}");
                false
            }
        })
    }

    fn bump(&self, pick: impl Fn(&ServeCounters) -> &AtomicU64) {
        if let Some(c) = &self.counters {
            ServeCounters::bump(pick(c));
        }
    }
}

impl InferenceEngine for FallbackEngine {
    fn name(&self) -> &str {
        &self.label
    }

    fn infer(&self, input: &Tensor) -> Result<Tensor> {
        let mut primary_error: Option<String> = None;
        if self.breaker.allow() {
            let engine = self.primary_engine();
            match catch_unwind(AssertUnwindSafe(|| engine.infer(input))) {
                Ok(Ok(y)) => {
                    self.breaker.on_success();
                    return Ok(y);
                }
                Ok(Err(e)) => {
                    self.breaker.on_failure();
                    primary_error = Some(format!("{e:#}"));
                }
                Err(payload) => {
                    self.breaker.on_failure();
                    self.bump(|c| &c.engine_panics);
                    primary_error = Some(format!("panicked: {}", panic_message(&*payload)));
                }
            }
        }
        // Degraded path: primary failed just now or the breaker is open.
        self.bump(|c| &c.fallback_served);
        match self.fallback.infer(input) {
            Ok(y) => Ok(y),
            Err(fe) => {
                self.bump(|c| &c.degraded);
                Err(super::ServeError::Degraded {
                    model: self.label.clone(),
                    primary_error: primary_error.unwrap_or_else(|| "circuit open".into()),
                    fallback_error: format!("{fe:#}"),
                }
                .into())
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::faults::{FaultPlan, FaultSite, FaultSpec, FaultyEngine};
    use crate::graph::zoo;
    use crate::interp::InterpEngine;

    fn interp(seed: u64) -> Arc<dyn InferenceEngine> {
        Arc::new(InterpEngine::new(zoo::tiny_test_net().with_random_weights(seed)).unwrap())
    }

    fn zero_cooldown(threshold: u32) -> BreakerConfig {
        BreakerConfig { failure_threshold: threshold, cooldown: Duration::ZERO }
    }

    #[test]
    fn breaker_walks_closed_open_halfopen_closed() {
        let b = CircuitBreaker::new(BreakerConfig {
            failure_threshold: 2,
            cooldown: Duration::from_millis(40),
        });
        assert_eq!(b.state(), BreakerState::Closed);
        assert!(b.allow());
        b.on_failure();
        assert_eq!(b.state(), BreakerState::Closed, "one failure below threshold");
        assert!(b.allow());
        b.on_failure();
        assert_eq!(b.state(), BreakerState::Open);
        assert!(!b.allow(), "open: calls rejected before cooldown");
        std::thread::sleep(Duration::from_millis(55));
        assert!(b.allow(), "cooldown elapsed: probe admitted");
        assert_eq!(b.state(), BreakerState::HalfOpen);
        assert!(!b.allow(), "only one probe at a time");
        b.on_success();
        assert_eq!(b.state(), BreakerState::Closed);
    }

    #[test]
    fn failed_probe_reopens() {
        let b = CircuitBreaker::new(zero_cooldown(1));
        b.on_failure();
        assert_eq!(b.state(), BreakerState::Open);
        assert!(b.allow());
        b.on_failure();
        assert_eq!(b.state(), BreakerState::Open, "failed probe re-opens");
    }

    #[test]
    fn success_resets_consecutive_failures() {
        let b = CircuitBreaker::new(zero_cooldown(2));
        b.on_failure();
        b.on_success();
        b.on_failure();
        assert_eq!(b.state(), BreakerState::Closed, "non-consecutive failures don't open");
    }

    #[test]
    fn fallback_serves_when_primary_fails_and_heals_on_swap() {
        let plan = FaultPlan::builder(5).site(FaultSite::EngineFail, FaultSpec::Every(1)).build();
        let primary: Arc<dyn InferenceEngine> = Arc::new(FaultyEngine::new(interp(1), plan));
        let fb = interp(2);
        let counters = Arc::new(ServeCounters::default());
        let fe = Arc::new(
            FallbackEngine::new(primary, Arc::clone(&fb), zero_cooldown(2))
                .with_counters(Arc::clone(&counters)),
        );

        let x = Tensor::zeros(&[8, 8, 1]);
        let reference = fb.infer(&x).unwrap();
        for _ in 0..4 {
            let y = fe.infer(&x).unwrap();
            assert_eq!(y, reference, "degraded replies come bit-identical from the fallback");
        }
        assert_eq!(fe.breaker().state(), BreakerState::Open);
        assert!(counters.fallback_served.load(std::sync::atomic::Ordering::Relaxed) >= 4);

        // Heal: swap a healthy primary in; traffic returns to it.
        let healthy = interp(9);
        let healed_reference = healthy.infer(&x).unwrap();
        fe.swap_primary(healthy);
        assert_eq!(fe.breaker().state(), BreakerState::Closed);
        let before = counters.fallback_served.load(std::sync::atomic::Ordering::Relaxed);
        assert_eq!(fe.infer(&x).unwrap(), healed_reference, "healed primary serves again");
        assert_eq!(counters.fallback_served.load(std::sync::atomic::Ordering::Relaxed), before);
    }

    #[test]
    fn primary_panic_is_contained_and_counted() {
        let plan = FaultPlan::builder(6).site(FaultSite::EnginePanic, FaultSpec::First(1)).build();
        let primary: Arc<dyn InferenceEngine> = Arc::new(FaultyEngine::new(interp(1), plan));
        let counters = Arc::new(ServeCounters::default());
        let fe = FallbackEngine::new(primary, interp(2), zero_cooldown(3))
            .with_counters(Arc::clone(&counters));
        let x = Tensor::zeros(&[8, 8, 1]);
        assert!(fe.infer(&x).is_ok(), "panic routed to fallback, not unwound");
        assert_eq!(counters.engine_panics.load(std::sync::atomic::Ordering::Relaxed), 1);
    }

    #[test]
    fn degraded_error_when_both_engines_fail() {
        let plan = FaultPlan::builder(7).site(FaultSite::EngineFail, FaultSpec::Every(1)).build();
        let bad_primary: Arc<dyn InferenceEngine> = Arc::new(FaultyEngine::new(interp(1), plan));
        let plan2 = FaultPlan::builder(8).site(FaultSite::EngineFail, FaultSpec::Every(1)).build();
        let bad_fallback: Arc<dyn InferenceEngine> = Arc::new(FaultyEngine::new(interp(2), plan2));
        let counters = Arc::new(ServeCounters::default());
        let fe = FallbackEngine::new(bad_primary, bad_fallback, zero_cooldown(5))
            .with_counters(Arc::clone(&counters));
        let err = fe.infer(&Tensor::zeros(&[8, 8, 1])).unwrap_err();
        assert!(format!("{err:#}").contains("degraded"), "{err:#}");
        assert_eq!(counters.degraded.load(std::sync::atomic::Ordering::Relaxed), 1);
    }

    #[test]
    fn heal_in_background_swaps_primary() {
        let plan = FaultPlan::builder(9).site(FaultSite::EngineFail, FaultSpec::Every(1)).build();
        let primary: Arc<dyn InferenceEngine> = Arc::new(FaultyEngine::new(interp(1), plan));
        let fe = Arc::new(FallbackEngine::new(primary, interp(2), zero_cooldown(1)));
        let handle = fe.heal_in_background(|| {
            Ok(Arc::new(InterpEngine::new(zoo::tiny_test_net().with_random_weights(3)).unwrap())
                as Arc<dyn InferenceEngine>)
        });
        assert!(handle.join().unwrap());
        assert_eq!(fe.breaker().state(), BreakerState::Closed);
        assert!(fe.primary_name().contains("interp"));
    }
}
