//! Micro-batching policy.
//!
//! The paper's latency argument (§I-A, §III-C): for small CNNs, waiting to
//! accumulate a batch only pays off on throughput-oriented hardware (GPU);
//! on the embedded CPU path the batcher should flush immediately. The
//! policy object makes that trade-off explicit and testable, and the GPU
//! throughput bench sweeps it.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::time::{Duration, Instant};

/// When to flush a pending batch.
#[derive(Debug, Clone, Copy)]
pub struct BatcherPolicy {
    /// Flush as soon as this many items are pending.
    pub max_batch: usize,
    /// Flush when the oldest item has waited this long.
    pub max_wait: Duration,
}

impl BatcherPolicy {
    /// Latency-first: every item is its own batch (the embedded CPU path).
    pub fn immediate() -> Self {
        BatcherPolicy { max_batch: 1, max_wait: Duration::ZERO }
    }

    /// Throughput-oriented batching (the GPU path).
    pub fn batched(max_batch: usize, max_wait: Duration) -> Self {
        BatcherPolicy { max_batch: max_batch.max(1), max_wait }
    }
}

/// Load-adaptive batching for a shard's dequeue loop.
///
/// The fixed [`BatcherPolicy`] trade-off (latency vs throughput) is wrong at
/// both ends under varying load: a wide policy adds wait latency when the
/// queue is empty, a narrow one forfeits the batched engine entry's
/// amortization when the queue is deep. This widens the *effective* batch
/// width from observed queue depth and decays it back when the queue
/// drains, always bounded by the configured cap:
///
/// - **widen** (depth ≥ 2× current width → width doubles, up to
///   `cap.max_batch`): the queue is outpacing us; amortize harder.
/// - **decay** (depth ≤ half the current width → width halves, down to
///   `base.max_batch`): the backlog cleared; return toward latency-first.
/// - the effective `max_wait` scales linearly with the effective width
///   (`cap.max_wait × width / cap.max_batch`, floored at `base.max_wait`):
///   a wide batch is only worth waiting for when we expect it to fill.
///
/// All state is a single atomic, shared by the shard's workers; observations
/// from any worker adjust the width every dequeue, so adaptation reacts
/// within one batch either way.
pub struct AdaptiveBatcher {
    base: BatcherPolicy,
    cap: BatcherPolicy,
    adapt: bool,
    cur_batch: AtomicUsize,
}

impl AdaptiveBatcher {
    /// Non-adaptive: always dequeue with exactly `policy`.
    pub fn fixed(policy: BatcherPolicy) -> Self {
        AdaptiveBatcher { base: policy, cap: policy, adapt: false, cur_batch: AtomicUsize::new(policy.max_batch.max(1)) }
    }

    /// Adapt between latency-first `base` and throughput cap `cap`,
    /// starting at `base` (latency-first until load proves otherwise).
    pub fn adaptive(base: BatcherPolicy, cap: BatcherPolicy) -> Self {
        let base = BatcherPolicy { max_batch: base.max_batch.max(1), ..base };
        let cap = BatcherPolicy {
            max_batch: cap.max_batch.max(base.max_batch),
            max_wait: cap.max_wait.max(base.max_wait),
        };
        AdaptiveBatcher { base, cap, adapt: true, cur_batch: AtomicUsize::new(base.max_batch) }
    }

    /// The policy the next dequeue should use.
    pub fn effective(&self) -> BatcherPolicy {
        let cur = self.cur_batch.load(Ordering::Relaxed);
        if !self.adapt {
            return self.cap;
        }
        let wait = if cur >= self.cap.max_batch {
            self.cap.max_wait
        } else {
            self.cap
                .max_wait
                .mul_f64(cur as f64 / self.cap.max_batch.max(1) as f64)
                .max(self.base.max_wait)
        };
        BatcherPolicy { max_batch: cur, max_wait: wait }
    }

    /// Feed back the queue depth observed at a dequeue (items taken plus
    /// items still queued). No-op for fixed policies.
    pub fn observe_depth(&self, depth: usize) {
        if !self.adapt {
            return;
        }
        let cur = self.cur_batch.load(Ordering::Relaxed);
        if depth >= cur.saturating_mul(2) && cur < self.cap.max_batch {
            let next = (cur * 2).min(self.cap.max_batch);
            self.cur_batch.store(next, Ordering::Relaxed);
        } else if depth <= cur / 2 && cur > self.base.max_batch {
            let next = (cur / 2).max(self.base.max_batch);
            self.cur_batch.store(next, Ordering::Relaxed);
        }
    }

    /// The hard upper bound on effective batch width.
    pub fn cap(&self) -> BatcherPolicy {
        self.cap
    }
}

/// Accumulates items and reports when a flush is due.
pub struct Batcher<T> {
    policy: BatcherPolicy,
    pending: Vec<T>,
    oldest: Option<Instant>,
}

impl<T> Batcher<T> {
    pub fn new(policy: BatcherPolicy) -> Self {
        Batcher { policy, pending: Vec::new(), oldest: None }
    }

    /// Add an item; returns a full batch if the size trigger fired.
    pub fn push(&mut self, item: T) -> Option<Vec<T>> {
        if self.pending.is_empty() {
            self.oldest = Some(Instant::now());
        }
        self.pending.push(item);
        if self.pending.len() >= self.policy.max_batch {
            return Some(self.flush());
        }
        None
    }

    /// True if the deadline trigger has fired.
    pub fn deadline_due(&self) -> bool {
        match self.oldest {
            Some(t) => !self.pending.is_empty() && t.elapsed() >= self.policy.max_wait,
            None => false,
        }
    }

    /// Take the pending batch (possibly empty).
    pub fn flush(&mut self) -> Vec<T> {
        self.oldest = None;
        std::mem::take(&mut self.pending)
    }

    pub fn pending_len(&self) -> usize {
        self.pending.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn immediate_policy_flushes_every_item() {
        let mut b = Batcher::new(BatcherPolicy::immediate());
        assert_eq!(b.push(1), Some(vec![1]));
        assert_eq!(b.push(2), Some(vec![2]));
        assert_eq!(b.pending_len(), 0);
    }

    #[test]
    fn size_trigger() {
        let mut b = Batcher::new(BatcherPolicy::batched(3, Duration::from_secs(10)));
        assert_eq!(b.push(1), None);
        assert_eq!(b.push(2), None);
        assert_eq!(b.push(3), Some(vec![1, 2, 3]));
    }

    #[test]
    fn deadline_trigger() {
        let mut b = Batcher::new(BatcherPolicy::batched(100, Duration::from_millis(5)));
        assert_eq!(b.push(7), None);
        assert!(!b.deadline_due() || b.pending_len() == 1);
        std::thread::sleep(Duration::from_millis(8));
        assert!(b.deadline_due());
        assert_eq!(b.flush(), vec![7]);
        assert!(!b.deadline_due());
    }

    #[test]
    fn adaptive_widens_under_depth_and_decays_when_drained() {
        let b = AdaptiveBatcher::adaptive(
            BatcherPolicy::immediate(),
            BatcherPolicy::batched(8, Duration::from_millis(8)),
        );
        // Starts latency-first.
        assert_eq!(b.effective().max_batch, 1);
        // Deep queue: widen 1 -> 2 -> 4 -> 8, never past the cap.
        for expect in [2, 4, 8, 8] {
            b.observe_depth(100);
            assert_eq!(b.effective().max_batch, expect);
        }
        // At the cap the full wait applies.
        assert_eq!(b.effective().max_wait, Duration::from_millis(8));
        // Drained queue: decay 8 -> 4 -> 2 -> 1, never below base.
        for expect in [4, 2, 1, 1] {
            b.observe_depth(0);
            assert_eq!(b.effective().max_batch, expect);
        }
        // Back at base the wait is latency-first again (base max_wait 0,
        // scaled wait 8ms * 1/8 = 1ms).
        assert_eq!(b.effective().max_wait, Duration::from_millis(1));
        // Moderate depth holds steady: 1 -> 2, then depth 2 < 2*2 keeps 2.
        b.observe_depth(2);
        assert_eq!(b.effective().max_batch, 2);
        b.observe_depth(2);
        assert_eq!(b.effective().max_batch, 2);
    }

    #[test]
    fn fixed_batcher_never_adapts() {
        let p = BatcherPolicy::batched(4, Duration::from_millis(2));
        let b = AdaptiveBatcher::fixed(p);
        b.observe_depth(10_000);
        assert_eq!(b.effective().max_batch, 4);
        assert_eq!(b.effective().max_wait, Duration::from_millis(2));
        b.observe_depth(0);
        assert_eq!(b.effective().max_batch, 4);
    }

    #[test]
    fn adaptive_wait_scales_with_width() {
        let b = AdaptiveBatcher::adaptive(
            BatcherPolicy::batched(1, Duration::from_millis(1)),
            BatcherPolicy::batched(16, Duration::from_millis(16)),
        );
        b.observe_depth(100); // 1 -> 2
        b.observe_depth(100); // 2 -> 4
        let eff = b.effective();
        assert_eq!(eff.max_batch, 4);
        // 16ms * 4/16 = 4ms, above the 1ms base floor.
        assert_eq!(eff.max_wait, Duration::from_millis(4));
    }

    #[test]
    fn flush_empties() {
        let mut b = Batcher::new(BatcherPolicy::batched(10, Duration::from_secs(1)));
        b.push(1);
        b.push(2);
        assert_eq!(b.flush(), vec![1, 2]);
        assert_eq!(b.pending_len(), 0);
        assert_eq!(b.flush(), Vec::<i32>::new());
    }
}
