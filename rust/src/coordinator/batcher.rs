//! Micro-batching policy.
//!
//! The paper's latency argument (§I-A, §III-C): for small CNNs, waiting to
//! accumulate a batch only pays off on throughput-oriented hardware (GPU);
//! on the embedded CPU path the batcher should flush immediately. The
//! policy object makes that trade-off explicit and testable, and the GPU
//! throughput bench sweeps it.

use std::time::{Duration, Instant};

/// When to flush a pending batch.
#[derive(Debug, Clone, Copy)]
pub struct BatcherPolicy {
    /// Flush as soon as this many items are pending.
    pub max_batch: usize,
    /// Flush when the oldest item has waited this long.
    pub max_wait: Duration,
}

impl BatcherPolicy {
    /// Latency-first: every item is its own batch (the embedded CPU path).
    pub fn immediate() -> Self {
        BatcherPolicy { max_batch: 1, max_wait: Duration::ZERO }
    }

    /// Throughput-oriented batching (the GPU path).
    pub fn batched(max_batch: usize, max_wait: Duration) -> Self {
        BatcherPolicy { max_batch: max_batch.max(1), max_wait }
    }
}

/// Accumulates items and reports when a flush is due.
pub struct Batcher<T> {
    policy: BatcherPolicy,
    pending: Vec<T>,
    oldest: Option<Instant>,
}

impl<T> Batcher<T> {
    pub fn new(policy: BatcherPolicy) -> Self {
        Batcher { policy, pending: Vec::new(), oldest: None }
    }

    /// Add an item; returns a full batch if the size trigger fired.
    pub fn push(&mut self, item: T) -> Option<Vec<T>> {
        if self.pending.is_empty() {
            self.oldest = Some(Instant::now());
        }
        self.pending.push(item);
        if self.pending.len() >= self.policy.max_batch {
            return Some(self.flush());
        }
        None
    }

    /// True if the deadline trigger has fired.
    pub fn deadline_due(&self) -> bool {
        match self.oldest {
            Some(t) => !self.pending.is_empty() && t.elapsed() >= self.policy.max_wait,
            None => false,
        }
    }

    /// Take the pending batch (possibly empty).
    pub fn flush(&mut self) -> Vec<T> {
        self.oldest = None;
        std::mem::take(&mut self.pending)
    }

    pub fn pending_len(&self) -> usize {
        self.pending.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn immediate_policy_flushes_every_item() {
        let mut b = Batcher::new(BatcherPolicy::immediate());
        assert_eq!(b.push(1), Some(vec![1]));
        assert_eq!(b.push(2), Some(vec![2]));
        assert_eq!(b.pending_len(), 0);
    }

    #[test]
    fn size_trigger() {
        let mut b = Batcher::new(BatcherPolicy::batched(3, Duration::from_secs(10)));
        assert_eq!(b.push(1), None);
        assert_eq!(b.push(2), None);
        assert_eq!(b.push(3), Some(vec![1, 2, 3]));
    }

    #[test]
    fn deadline_trigger() {
        let mut b = Batcher::new(BatcherPolicy::batched(100, Duration::from_millis(5)));
        assert_eq!(b.push(7), None);
        assert!(!b.deadline_due() || b.pending_len() == 1);
        std::thread::sleep(Duration::from_millis(8));
        assert!(b.deadline_due());
        assert_eq!(b.flush(), vec![7]);
        assert!(!b.deadline_due());
    }

    #[test]
    fn flush_empties() {
        let mut b = Batcher::new(BatcherPolicy::batched(10, Duration::from_secs(1)));
        b.push(1);
        b.push(2);
        assert_eq!(b.flush(), vec![1, 2]);
        assert_eq!(b.pending_len(), 0);
        assert_eq!(b.flush(), Vec::<i32>::new());
    }
}
