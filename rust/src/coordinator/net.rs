//! TCP front-end over the shard pool: `std::net` only, no frameworks.
//!
//! [`NetServer`] accepts connections and runs a **reader thread + writer
//! thread pair per connection**, bridged by a bounded `sync_channel` whose
//! capacity is the per-connection in-flight window: when the window fills,
//! the reader blocks on the channel and stops pulling bytes off the socket,
//! so backpressure propagates to the client via TCP flow control — the
//! server never buffers an unbounded number of requests per connection.
//!
//! The exactly-one-reply contract extends to the wire: every frame the
//! reader *accepts* (decodes fully) is paired with exactly one channel
//! entry, and the writer turns every entry into exactly one response frame
//! — a tensor, a typed error, or `Stopped` at shutdown (the pool's
//! `ReplyGuard` guarantees the inner receiver always yields). A frame that
//! fails to decode is never accepted: the connection is closed without a
//! reply, and previously accepted frames on that connection still drain
//! through the writer.
//!
//! Replies on one connection are written in submission order (the channel
//! is FIFO and the writer resolves entries in order), so a pipelining
//! client may match replies positionally as well as by request id.

use super::error::ServeError;
use super::metrics::ServeCounters;
use super::proto::{self, FrameError, ResponseBody};
use super::Submitter;
use crate::faults::{FaultPlan, FaultSite};
use crate::tensor::Tensor;
use std::fmt;
use std::io::{self, Read, Write};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc};
use std::thread;
use std::time::{Duration, Instant};

/// Poll interval for the stoppable accept loop and the idle first-byte
/// wait: small enough that `stop()` latency is invisible, large enough
/// that an idle server burns no measurable CPU.
const TICK: Duration = Duration::from_millis(20);

/// Tuning knobs for a [`NetServer`].
#[derive(Clone)]
pub struct NetConfig {
    /// Deadline for completing one frame once its first byte arrived — a
    /// slow-loris client that trickles a frame slower than this is
    /// disconnected. Idle time *between* frames is not limited.
    pub read_timeout: Duration,
    /// Socket write timeout for response frames.
    pub write_timeout: Duration,
    /// Per-connection in-flight window (accepted-but-unanswered frames).
    pub window: usize,
    /// Optional fault plan consulted at the net fault sites
    /// ([`FaultSite::NetDropConn`], [`FaultSite::NetPartialWrite`],
    /// [`FaultSite::NetSlowRead`]).
    pub faults: Option<Arc<FaultPlan>>,
}

impl Default for NetConfig {
    fn default() -> Self {
        NetConfig {
            read_timeout: Duration::from_secs(5),
            write_timeout: Duration::from_secs(5),
            window: 64,
            faults: None,
        }
    }
}

/// Client-side failure taxonomy for [`NetClient`].
#[derive(Debug)]
pub enum NetError {
    /// The byte stream violated the protocol.
    Frame(FrameError),
    /// A transport-level error outside framing.
    Io(io::Error),
    /// The server closed the connection at a frame boundary.
    Closed,
    /// A reply arrived for a different request id than expected.
    IdMismatch { sent: u64, got: u64 },
    /// The server answered with a typed error status.
    Remote(RemoteError),
}

impl fmt::Display for NetError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            NetError::Frame(e) => write!(f, "protocol error: {e}"),
            NetError::Io(e) => write!(f, "transport error: {e}"),
            NetError::Closed => write!(f, "server closed the connection"),
            NetError::IdMismatch { sent, got } => {
                write!(f, "reply id mismatch: sent {sent}, got {got}")
            }
            NetError::Remote(e) => write!(f, "server error: {e}"),
        }
    }
}

impl std::error::Error for NetError {}

/// A typed error the server sent back: the wire status byte plus the
/// human-readable message body.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct RemoteError {
    pub status: u8,
    pub message: String,
}

impl RemoteError {
    /// The `ServeError::kind` name the status byte maps to ("unknown" is
    /// unreachable for replies produced by this crate's server).
    pub fn kind(&self) -> &'static str {
        proto::status_name(self.status).unwrap_or("unknown")
    }
}

impl fmt::Display for RemoteError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "[{}] {}", self.kind(), self.message)
    }
}

/// One reply owed on a connection, queued in submission order. The
/// channel holding these IS the in-flight window.
enum ConnReply {
    /// Submitted into the pool; the receiver will yield exactly one result.
    Waiting(u64, mpsc::Receiver<Result<Tensor, ServeError>>),
    /// Resolved before (or instead of) pool submission.
    Ready(u64, Result<Tensor, ServeError>),
}

/// A TCP server speaking the `proto` framing over a shared [`Submitter`].
///
/// Dropping the server begins a stop; `stop()` joins the accept loop and
/// every connection thread.
pub struct NetServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    accept: Option<thread::JoinHandle<()>>,
}

impl NetServer {
    /// Bind `addr` (e.g. `"127.0.0.1:0"` for an ephemeral port) and start
    /// accepting. Connections submit into the pool behind `submitter`.
    pub fn start(submitter: Submitter, addr: &str, cfg: NetConfig) -> anyhow::Result<NetServer> {
        let listener = TcpListener::bind(addr)
            .map_err(|e| anyhow::anyhow!("bind {addr}: {e}"))?;
        let local = listener
            .local_addr()
            .map_err(|e| anyhow::anyhow!("local_addr: {e}"))?;
        listener
            .set_nonblocking(true)
            .map_err(|e| anyhow::anyhow!("set_nonblocking: {e}"))?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop_flag = Arc::clone(&stop);
        let counters = submitter.counters();
        let accept = thread::Builder::new()
            .name("nncg-net-accept".into())
            .spawn(move || accept_loop(listener, submitter, counters, cfg, stop_flag))
            .map_err(|e| anyhow::anyhow!("spawn accept thread: {e}"))?;
        Ok(NetServer { addr: local, stop, accept: Some(accept) })
    }

    /// The bound address (resolves the ephemeral port of `"...:0"` binds).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Flag the server to stop without waiting. Use before stopping the
    /// pool so in-flight frames are answered `Stopped` rather than racing
    /// new accepts against pool shutdown; follow with [`Self::stop`].
    pub fn begin_stop(&self) {
        self.stop.store(true, Ordering::SeqCst);
    }

    /// Stop accepting and join the accept loop and all connection threads.
    /// Bounded: idle connections notice within [`TICK`]; a connection
    /// mid-frame finishes within the read deadline.
    pub fn stop(mut self) {
        self.begin_stop();
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
    }
}

impl Drop for NetServer {
    fn drop(&mut self) {
        self.begin_stop();
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
    }
}

fn accept_loop(
    listener: TcpListener,
    submitter: Submitter,
    counters: Arc<ServeCounters>,
    cfg: NetConfig,
    stop: Arc<AtomicBool>,
) {
    let mut conns: Vec<thread::JoinHandle<()>> = Vec::new();
    while !stop.load(Ordering::SeqCst) {
        match listener.accept() {
            Ok((stream, _peer)) => {
                ServeCounters::bump(&counters.net_connections);
                let submitter = submitter.clone();
                let counters = Arc::clone(&counters);
                let cfg = cfg.clone();
                let stop = Arc::clone(&stop);
                let h = thread::Builder::new()
                    .name("nncg-net-conn".into())
                    .spawn(move || conn_loop(stream, submitter, counters, cfg, stop));
                match h {
                    Ok(h) => conns.push(h),
                    Err(_) => { /* spawn failed: connection dropped on the floor */ }
                }
                conns.retain(|h| !h.is_finished());
            }
            Err(e) if e.kind() == io::ErrorKind::WouldBlock => thread::sleep(TICK),
            Err(_) => thread::sleep(TICK),
        }
    }
    drop(listener);
    for h in conns {
        let _ = h.join();
    }
}

/// `Read` adapter giving one frame a hard completion deadline. The
/// underlying stream keeps its short [`TICK`] read timeout; this loops on
/// would-block until the deadline, then surfaces `TimedOut` — which the
/// decoder maps to [`FrameError::TimedOut`] (the slow-loris signal).
struct DeadlineReader<'a> {
    stream: &'a TcpStream,
    deadline: Instant,
}

impl Read for DeadlineReader<'_> {
    fn read(&mut self, buf: &mut [u8]) -> io::Result<usize> {
        loop {
            if Instant::now() >= self.deadline {
                return Err(io::Error::new(io::ErrorKind::TimedOut, "frame read deadline"));
            }
            match (&mut self.stream).read(buf) {
                Ok(n) => return Ok(n),
                Err(e)
                    if matches!(
                        e.kind(),
                        io::ErrorKind::WouldBlock
                            | io::ErrorKind::TimedOut
                            | io::ErrorKind::Interrupted
                    ) =>
                {
                    continue
                }
                Err(e) => return Err(e),
            }
        }
    }
}

fn conn_loop(
    stream: TcpStream,
    submitter: Submitter,
    counters: Arc<ServeCounters>,
    cfg: NetConfig,
    stop: Arc<AtomicBool>,
) {
    let _ = stream.set_nodelay(true);
    // Short tick so the idle wait can poll the stop flag; per-frame
    // deadlines are enforced by DeadlineReader on top of this.
    let _ = stream.set_read_timeout(Some(TICK));
    let _ = stream.set_write_timeout(Some(cfg.write_timeout));
    let writer_stream = match stream.try_clone() {
        Ok(s) => s,
        Err(_) => {
            ServeCounters::bump(&counters.net_dropped_conns);
            return;
        }
    };
    let (tx, rx) = mpsc::sync_channel::<ConnReply>(cfg.window.max(1));
    let writer_counters = Arc::clone(&counters);
    let writer_faults = cfg.faults.clone();
    let writer = thread::Builder::new()
        .name("nncg-net-write".into())
        .spawn(move || writer_loop(writer_stream, rx, writer_counters, writer_faults));
    let writer = match writer {
        Ok(w) => w,
        Err(_) => {
            ServeCounters::bump(&counters.net_dropped_conns);
            return;
        }
    };

    'conn: loop {
        // Idle wait for the first byte of the next frame: no deadline, but
        // the stop flag is polled every TICK.
        let first = loop {
            if stop.load(Ordering::SeqCst) {
                break 'conn;
            }
            let mut b = [0u8; 1];
            match (&stream).read(&mut b) {
                Ok(0) => break 'conn, // clean close at a frame boundary
                Ok(_) => break b[0],
                Err(e)
                    if matches!(
                        e.kind(),
                        io::ErrorKind::WouldBlock
                            | io::ErrorKind::TimedOut
                            | io::ErrorKind::Interrupted
                    ) =>
                {
                    continue
                }
                Err(_) => {
                    ServeCounters::bump(&counters.net_dropped_conns);
                    break 'conn;
                }
            }
        };

        // Fault seam: a frame has started arriving.
        if let Some(plan) = &cfg.faults {
            if let Some(d) = plan.maybe_delay(FaultSite::NetSlowRead) {
                thread::sleep(d);
            }
            if plan.should_fire(FaultSite::NetDropConn) {
                ServeCounters::bump(&counters.net_dropped_conns);
                break 'conn;
            }
        }

        let mut dr =
            DeadlineReader { stream: &stream, deadline: Instant::now() + cfg.read_timeout };
        match proto::read_request_resuming(first, &mut dr) {
            Ok(frame) => {
                // Frame accepted: from here it gets exactly one reply.
                ServeCounters::bump(&counters.net_frames);
                let id = frame.id;
                // Pre-submission registry check: an unknown model must not
                // consume a shard-queue slot (or count as a pool request).
                if !submitter.has_model(&frame.model) {
                    ServeCounters::bump(&counters.net_unknown_rejects);
                    let err = ServeError::ModelUnknown {
                        model: frame.model,
                        registered: submitter.registered_models(),
                    };
                    if tx.send(ConnReply::Ready(id, Err(err))).is_err() {
                        break 'conn;
                    }
                    continue;
                }
                let model = frame.model.clone();
                let entry = match frame.into_tensor() {
                    Ok(input) => match submitter.submit(&model, input, None) {
                        Ok(pool_rx) => ConnReply::Waiting(id, pool_rx),
                        Err(e) => ConnReply::Ready(id, Err(e)),
                    },
                    // Unreachable for frames this decoder accepted (shape
                    // is validated); kept typed rather than panicking.
                    Err(e) => ConnReply::Ready(
                        id,
                        Err(ServeError::EngineFailed { model, reason: e.to_string() }),
                    ),
                };
                // Blocking send = the in-flight window; backpressure stops
                // the reader until the writer drains a slot.
                if tx.send(entry).is_err() {
                    break 'conn;
                }
            }
            // Mid-frame transport failures: slow-loris deadline, client
            // disconnect, resets. The frame was never accepted, no reply.
            Err(FrameError::TimedOut) | Err(FrameError::Truncated) | Err(FrameError::Io(_)) => {
                ServeCounters::bump(&counters.net_dropped_conns);
                break 'conn;
            }
            // Protocol violations: typed rejection, connection closed.
            Err(_) => {
                ServeCounters::bump(&counters.net_bad_frames);
                break 'conn;
            }
        }
    }

    // Close the window; the writer drains every accepted frame (answering
    // still-queued pool work — `Stopped` if the pool shut down) then exits.
    drop(tx);
    let _ = writer.join();
    let _ = stream.shutdown(Shutdown::Both);
}

fn writer_loop(
    mut stream: TcpStream,
    rx: mpsc::Receiver<ConnReply>,
    counters: Arc<ServeCounters>,
    faults: Option<Arc<FaultPlan>>,
) {
    for entry in rx.iter() {
        let (id, result) = match entry {
            ConnReply::Ready(id, r) => (id, r),
            // The pool's ReplyGuard makes recv yield exactly once; a
            // severed sender (timed-out shutdown) still maps to Stopped.
            ConnReply::Waiting(id, pool_rx) => {
                (id, pool_rx.recv().unwrap_or(Err(ServeError::Stopped)))
            }
        };
        let buf = match &result {
            Ok(t) => proto::encode_ok(id, t).unwrap_or_else(|e| {
                proto::encode_err(
                    id,
                    &ServeError::EngineFailed {
                        model: String::new(),
                        reason: format!("output exceeds protocol limits: {e}"),
                    },
                )
            }),
            Err(e) => proto::encode_err(id, e),
        };
        let wrote = match faults
            .as_deref()
            .and_then(|p| p.maybe_delay(FaultSite::NetPartialWrite))
        {
            Some(delay) => {
                // Write the frame in two halves with a stall between them:
                // clients must reassemble a reply split mid-length-prefix.
                let mid = buf.len() / 2;
                stream.write_all(&buf[..mid]).and_then(|_| {
                    let _ = stream.flush();
                    thread::sleep(delay);
                    stream.write_all(&buf[mid..])
                })
            }
            None => stream.write_all(&buf),
        };
        match wrote {
            Ok(()) => ServeCounters::bump(&counters.net_replies),
            Err(_) => {
                // Client gone: remaining window entries still must be
                // resolved (pool receivers drained) so no reply is lost
                // pool-side, but nothing more can be written.
                ServeCounters::bump(&counters.net_dropped_conns);
                for entry in rx.iter() {
                    if let ConnReply::Waiting(_, pool_rx) = entry {
                        let _ = pool_rx.recv();
                    }
                }
                return;
            }
        }
    }
}

/// Blocking client for the `proto` framing, used by tests, the load bench
/// (`NNCG_LOAD_TCP=1`), and `nncg serve --listen`. Supports pipelining:
/// `send` several frames, then `read_reply` each (replies arrive in
/// submission order per connection).
pub struct NetClient {
    stream: TcpStream,
    next_id: u64,
}

impl NetClient {
    /// Connect and configure generous (30 s) transport timeouts.
    pub fn connect(addr: impl ToSocketAddrs) -> Result<NetClient, NetError> {
        let stream = TcpStream::connect(addr).map_err(NetError::Io)?;
        let _ = stream.set_nodelay(true);
        let _ = stream.set_read_timeout(Some(Duration::from_secs(30)));
        let _ = stream.set_write_timeout(Some(Duration::from_secs(30)));
        Ok(NetClient { stream, next_id: 0 })
    }

    /// Send one request frame; returns the request id to match the reply.
    pub fn send(&mut self, model: &str, input: &Tensor) -> Result<u64, NetError> {
        self.next_id += 1;
        let id = self.next_id;
        let buf = proto::encode_request(id, model, input.dims(), input.data())
            .map_err(NetError::Frame)?;
        self.stream.write_all(&buf).map_err(NetError::Io)?;
        Ok(id)
    }

    /// Read the next reply frame: `(request id, tensor or typed remote
    /// error)`. [`NetError::Closed`] when the server hung up cleanly.
    pub fn read_reply(&mut self) -> Result<(u64, Result<Tensor, RemoteError>), NetError> {
        match proto::read_response(&mut self.stream) {
            Ok(Some(f)) => match f.body {
                ResponseBody::Tensor { dims, data } => {
                    let t = Tensor::from_vec(&dims, data)
                        .map_err(|e| NetError::Frame(FrameError::Io(e.to_string())))?;
                    Ok((f.id, Ok(t)))
                }
                ResponseBody::Message(message) => {
                    Ok((f.id, Err(RemoteError { status: f.status, message })))
                }
            },
            Ok(None) => Err(NetError::Closed),
            Err(e) => Err(NetError::Frame(e)),
        }
    }

    /// One round trip: send, read, check the id echo.
    pub fn infer(&mut self, model: &str, input: &Tensor) -> Result<Tensor, NetError> {
        let sent = self.send(model, input)?;
        let (got, result) = self.read_reply()?;
        if got != sent {
            return Err(NetError::IdMismatch { sent, got });
        }
        result.map_err(NetError::Remote)
    }

    /// Write raw bytes, bypassing the encoder — the torture tests use this
    /// to send malformed and partial frames.
    pub fn send_raw(&mut self, bytes: &[u8]) -> Result<(), NetError> {
        self.stream.write_all(bytes).map_err(NetError::Io)
    }

    /// Half- or full-close the socket (mid-frame disconnect scenarios).
    pub fn shutdown(&self, how: Shutdown) -> io::Result<()> {
        self.stream.shutdown(how)
    }
}
