//! Model registry and request routing.

use crate::runtime::InferenceEngine;
use crate::tensor::Tensor;
use anyhow::{anyhow, Result};
use std::collections::HashMap;
use std::sync::{Arc, RwLock};

/// Maps model names to engines. Multiple names may share an engine, and a
/// model can be re-registered to hot-swap backends (e.g. interp → generated
/// C once compilation finishes). The registry is interior-mutable so a
/// background heal thread can swap engines on the same `Arc<Router>` the
/// serving workers read from.
#[derive(Default)]
pub struct Router {
    engines: RwLock<HashMap<String, Arc<dyn InferenceEngine>>>,
}

impl Router {
    pub fn new() -> Self {
        Router { engines: RwLock::new(HashMap::new()) }
    }

    fn read(&self) -> std::sync::RwLockReadGuard<'_, HashMap<String, Arc<dyn InferenceEngine>>> {
        self.engines.read().unwrap_or_else(|e| e.into_inner())
    }

    /// Register (or replace) a model's engine. Takes `&self`: hot-swapping
    /// while workers are serving is the intended use.
    pub fn register(&self, model: &str, engine: Arc<dyn InferenceEngine>) {
        self.engines
            .write()
            .unwrap_or_else(|e| e.into_inner())
            .insert(model.to_string(), engine);
    }

    pub fn engine(&self, model: &str) -> Result<Arc<dyn InferenceEngine>> {
        self.read().get(model).cloned().ok_or_else(|| {
            let have = self.models();
            if have.is_empty() {
                anyhow!("no engine registered for model {model:?} (registry is empty)")
            } else {
                anyhow!("no engine registered for model {model:?} (registered: {})", have.join(", "))
            }
        })
    }

    /// Route one inference.
    pub fn infer(&self, model: &str, input: &Tensor) -> Result<Tensor> {
        self.engine(model)?.infer(input)
    }

    /// Whether `model` has a registered engine — a lock-scoped existence
    /// check (no `Arc` clone) for pre-admission gates like the net
    /// front-end's unknown-model rejection.
    pub fn contains(&self, model: &str) -> bool {
        self.read().contains_key(model)
    }

    /// Registered model names, sorted.
    pub fn models(&self) -> Vec<String> {
        let mut names: Vec<String> = self.read().keys().cloned().collect();
        names.sort();
        names
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::zoo;
    use crate::interp::InterpEngine;

    #[test]
    fn register_and_route() {
        let r = Router::new();
        r.register("tiny", Arc::new(InterpEngine::new(zoo::tiny_test_net().with_random_weights(1)).unwrap()));
        assert_eq!(r.models(), vec!["tiny"]);
        let y = r.infer("tiny", &Tensor::zeros(&[8, 8, 1])).unwrap();
        assert_eq!(y.dims(), &[2, 2, 2]);
        assert!(r.infer("other", &Tensor::zeros(&[8, 8, 1])).is_err());
    }

    #[test]
    fn hot_swap_replaces_engine() {
        let r = Router::new();
        let a = Arc::new(InterpEngine::new(zoo::tiny_test_net().with_random_weights(1)).unwrap());
        let b = Arc::new(InterpEngine::new(zoo::tiny_test_net().with_random_weights(2)).unwrap());
        r.register("m", a);
        let y1 = r.infer("m", &Tensor::zeros(&[8, 8, 1])).unwrap();
        r.register("m", b);
        let y2 = r.infer("m", &Tensor::zeros(&[8, 8, 1])).unwrap();
        assert_ne!(y1, y2, "swapped engine should produce different outputs");
    }

    #[test]
    fn unknown_model_error_lists_registered_names() {
        let r = Router::new();
        let empty = r.engine("ghost").unwrap_err().to_string();
        assert!(empty.contains("registry is empty"), "{empty}");
        r.register("ball", Arc::new(InterpEngine::new(zoo::tiny_test_net().with_random_weights(1)).unwrap()));
        r.register("tiny", Arc::new(InterpEngine::new(zoo::tiny_test_net().with_random_weights(2)).unwrap()));
        let msg = r.engine("ghost").unwrap_err().to_string();
        assert!(msg.contains("ball") && msg.contains("tiny"), "{msg}");
    }

    #[test]
    fn hot_swap_under_concurrent_infer() {
        let r = Arc::new(Router::new());
        let a = Arc::new(InterpEngine::new(zoo::tiny_test_net().with_random_weights(1)).unwrap());
        let b = Arc::new(InterpEngine::new(zoo::tiny_test_net().with_random_weights(2)).unwrap());
        let x = Tensor::zeros(&[8, 8, 1]);
        let ref_a = a.infer(&x).unwrap();
        let ref_b = b.infer(&x).unwrap();
        r.register("m", a);

        let callers: Vec<_> = (0..4)
            .map(|_| {
                let r = Arc::clone(&r);
                let x = x.clone();
                std::thread::spawn(move || {
                    (0..50).map(|_| r.infer("m", &x).unwrap()).collect::<Vec<_>>()
                })
            })
            .collect();
        std::thread::sleep(std::time::Duration::from_millis(2));
        r.register("m", b);

        for h in callers {
            for y in h.join().unwrap() {
                assert!(
                    y == ref_a || y == ref_b,
                    "every reply must come from exactly one coherent engine"
                );
            }
        }
        // After the swap the router serves only engine B.
        assert_eq!(r.infer("m", &x).unwrap(), ref_b);
    }
}
