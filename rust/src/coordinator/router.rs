//! Model registry and request routing.

use crate::runtime::InferenceEngine;
use crate::tensor::Tensor;
use anyhow::{anyhow, Result};
use std::collections::HashMap;
use std::sync::Arc;

/// Maps model names to engines. Multiple names may share an engine, and a
/// model can be re-registered to hot-swap backends (e.g. interp → generated
/// C once compilation finishes).
#[derive(Default)]
pub struct Router {
    engines: HashMap<String, Arc<dyn InferenceEngine>>,
}

impl Router {
    pub fn new() -> Self {
        Router { engines: HashMap::new() }
    }

    /// Register (or replace) a model's engine.
    pub fn register(&mut self, model: &str, engine: Arc<dyn InferenceEngine>) {
        self.engines.insert(model.to_string(), engine);
    }

    pub fn engine(&self, model: &str) -> Result<Arc<dyn InferenceEngine>> {
        self.engines
            .get(model)
            .cloned()
            .ok_or_else(|| anyhow!("no engine registered for model {model:?} (have: {:?})", self.models()))
    }

    /// Route one inference.
    pub fn infer(&self, model: &str, input: &Tensor) -> Result<Tensor> {
        self.engine(model)?.infer(input)
    }

    /// Registered model names, sorted.
    pub fn models(&self) -> Vec<String> {
        let mut names: Vec<String> = self.engines.keys().cloned().collect();
        names.sort();
        names
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::zoo;
    use crate::interp::InterpEngine;

    #[test]
    fn register_and_route() {
        let mut r = Router::new();
        r.register("tiny", Arc::new(InterpEngine::new(zoo::tiny_test_net().with_random_weights(1)).unwrap()));
        assert_eq!(r.models(), vec!["tiny"]);
        let y = r.infer("tiny", &Tensor::zeros(&[8, 8, 1])).unwrap();
        assert_eq!(y.dims(), &[2, 2, 2]);
        assert!(r.infer("other", &Tensor::zeros(&[8, 8, 1])).is_err());
    }

    #[test]
    fn hot_swap_replaces_engine() {
        let mut r = Router::new();
        let a = Arc::new(InterpEngine::new(zoo::tiny_test_net().with_random_weights(1)).unwrap());
        let b = Arc::new(InterpEngine::new(zoo::tiny_test_net().with_random_weights(2)).unwrap());
        r.register("m", a);
        let y1 = r.infer("m", &Tensor::zeros(&[8, 8, 1])).unwrap();
        r.register("m", b);
        let y2 = r.infer("m", &Tensor::zeros(&[8, 8, 1])).unwrap();
        assert_ne!(y1, y2, "swapped engine should produce different outputs");
    }
}
