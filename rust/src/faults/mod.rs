//! Deterministic fault injection for the compile→load→serve pipeline.
//!
//! The paper's deployment story is a time-critical embedded vision loop
//! (§I-A): a hung cross-compiler, a failed `dlopen`, or a crashing engine
//! must degrade gracefully rather than wedge the frame loop. This module
//! provides the *test half* of that story: a seeded [`FaultPlan`] that the
//! `cc` and `coordinator` layers consult at their failure seams, so the
//! chaos suite (`rust/tests/chaos_serving.rs`) can drive every recovery
//! path deterministically.
//!
//! Design constraints:
//!
//! * **Zero-cost when off.** Production code holds an
//!   `Option<Arc<FaultPlan>>` that is `None` unless explicitly built or
//!   configured through the `NNCG_FAULTS` env var; the only overhead on the
//!   hot path is one `Option` branch.
//! * **Deterministic.** Count-based specs ([`FaultSpec::First`],
//!   [`FaultSpec::Every`]) fire on exact hit numbers; probabilistic specs
//!   draw from a per-site [`XorShift64`] stream seeded from
//!   `(plan seed, site name)`, so one site's draws never perturb another's.
//! * **Observable.** Per-site hit/fired counters let tests assert exactly
//!   how many faults were injected.

use crate::runtime::InferenceEngine;
use crate::tensor::Tensor;
use crate::util::{fxhash, XorShift64};
use anyhow::{bail, Result};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// A seam in the serving pipeline where a fault can be injected.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum FaultSite {
    /// `cc::CcDriver`: the compiler invocation fails outright (transient).
    CompileFail,
    /// `cc::CcDriver`: the compiler hangs (replaced by a `sleep` child that
    /// the wall-clock timeout machinery must kill).
    CompileSlow,
    /// `cc::CompiledCnn`: loading the compiled shared object fails.
    DlopenFail,
    /// `cc::ObjectCache`: a cached `.so` is corrupted on disk before the
    /// validity check runs (simulates torn writes / bad flash).
    CacheCorrupt,
    /// `FaultyEngine`: the inference call panics.
    EnginePanic,
    /// `FaultyEngine`: the inference call returns an error.
    EngineFail,
    /// `FaultyEngine`: the inference call sleeps for the plan's delay.
    LatencySpike,
    /// `coordinator::shard`: a shard worker panics between requests (the
    /// supervisor respawns it; the shard's queue survives and can be
    /// stolen by peers while the shard is down).
    ShardKill,
    /// `coordinator::shard`: the steal path sleeps for the plan's delay
    /// after choosing a victim, widening the window where two thieves
    /// race for the same backlog.
    StealRace,
    /// `coordinator::net`: the server drops the connection right after a
    /// frame's first byte arrives (mid-frame disconnect from the client's
    /// point of view; no reply for that frame, which was never accepted).
    NetDropConn,
    /// `coordinator::net`: a response frame is written in two halves with
    /// the plan's delay between them (clients must reassemble a reply
    /// split mid-length-prefix).
    NetPartialWrite,
    /// `coordinator::net`: the reader stalls for the plan's delay after a
    /// frame's first byte, eating into the per-frame read deadline.
    NetSlowRead,
}

/// All injectable sites, in stable order (indexes [`FaultPlan`] state).
pub const ALL_SITES: [FaultSite; 12] = [
    FaultSite::CompileFail,
    FaultSite::CompileSlow,
    FaultSite::DlopenFail,
    FaultSite::CacheCorrupt,
    FaultSite::EnginePanic,
    FaultSite::EngineFail,
    FaultSite::LatencySpike,
    FaultSite::ShardKill,
    FaultSite::StealRace,
    FaultSite::NetDropConn,
    FaultSite::NetPartialWrite,
    FaultSite::NetSlowRead,
];

impl FaultSite {
    fn idx(self) -> usize {
        match self {
            FaultSite::CompileFail => 0,
            FaultSite::CompileSlow => 1,
            FaultSite::DlopenFail => 2,
            FaultSite::CacheCorrupt => 3,
            FaultSite::EnginePanic => 4,
            FaultSite::EngineFail => 5,
            FaultSite::LatencySpike => 6,
            FaultSite::ShardKill => 7,
            FaultSite::StealRace => 8,
            FaultSite::NetDropConn => 9,
            FaultSite::NetPartialWrite => 10,
            FaultSite::NetSlowRead => 11,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            FaultSite::CompileFail => "compile-fail",
            FaultSite::CompileSlow => "compile-slow",
            FaultSite::DlopenFail => "dlopen-fail",
            FaultSite::CacheCorrupt => "cache-corrupt",
            FaultSite::EnginePanic => "engine-panic",
            FaultSite::EngineFail => "engine-fail",
            FaultSite::LatencySpike => "latency-spike",
            FaultSite::ShardKill => "shard-kill",
            FaultSite::StealRace => "steal-race",
            FaultSite::NetDropConn => "net-drop-conn",
            FaultSite::NetPartialWrite => "net-partial-write",
            FaultSite::NetSlowRead => "net-slow-read",
        }
    }

    pub fn from_name(s: &str) -> Option<FaultSite> {
        ALL_SITES.iter().copied().find(|site| site.name() == s)
    }
}

/// When a site fires, relative to its own hit counter (first hit is 1).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum FaultSpec {
    /// Never fire (the default for every site).
    Off,
    /// Fire on the first `n` hits, then never again.
    First(u64),
    /// Fire on every `n`-th hit (`Every(1)` = always).
    Every(u64),
    /// Fire with probability `p` per hit, drawn from the site's seeded
    /// stream.
    Prob(f64),
}

impl FaultSpec {
    fn fires(self, hit_no: u64, rng: &Mutex<XorShift64>) -> bool {
        match self {
            FaultSpec::Off => false,
            FaultSpec::First(n) => hit_no <= n,
            FaultSpec::Every(n) => n > 0 && hit_no % n == 0,
            FaultSpec::Prob(p) => {
                let mut rng = rng.lock().unwrap_or_else(|e| e.into_inner());
                (rng.next_f32() as f64) < p
            }
        }
    }

    /// Parse `"first:3"`, `"every:4"`, `"prob:0.25"`, `"always"`, `"off"`.
    pub fn parse(s: &str) -> Result<FaultSpec> {
        if s == "off" {
            return Ok(FaultSpec::Off);
        }
        if s == "always" {
            return Ok(FaultSpec::Every(1));
        }
        if let Some(n) = s.strip_prefix("first:") {
            return match n.parse() {
                Ok(n) => Ok(FaultSpec::First(n)),
                Err(_) => bail!("bad fault spec {s:?}: first:<count>"),
            };
        }
        if let Some(n) = s.strip_prefix("every:") {
            return match n.parse() {
                Ok(0) => bail!("bad fault spec {s:?}: every:<n> needs n >= 1"),
                Ok(n) => Ok(FaultSpec::Every(n)),
                Err(_) => bail!("bad fault spec {s:?}: every:<n>"),
            };
        }
        if let Some(p) = s.strip_prefix("prob:") {
            return match p.parse::<f64>() {
                Ok(p) if (0.0..=1.0).contains(&p) => Ok(FaultSpec::Prob(p)),
                _ => bail!("bad fault spec {s:?}: prob:<0..1>"),
            };
        }
        bail!("bad fault spec {s:?} (off|always|first:<n>|every:<n>|prob:<p>)")
    }
}

#[derive(Debug)]
struct SiteState {
    spec: FaultSpec,
    hits: AtomicU64,
    fired: AtomicU64,
    rng: Mutex<XorShift64>,
}

/// A seeded, deterministic fault-injection plan shared across the pipeline.
#[derive(Debug)]
pub struct FaultPlan {
    seed: u64,
    delay: Duration,
    /// When set, shard-scoped sites ([`FaultSite::ShardKill`],
    /// [`FaultSite::StealRace`]) only fire on this shard index, so a test
    /// can make exactly one shard sick deterministically.
    target_shard: Option<usize>,
    sites: Vec<SiteState>,
}

/// Builder for [`FaultPlan`]; see [`FaultPlan::builder`].
pub struct FaultPlanBuilder {
    seed: u64,
    delay: Duration,
    target_shard: Option<usize>,
    specs: Vec<(FaultSite, FaultSpec)>,
}

impl FaultPlanBuilder {
    /// Set the spec for one site (later calls override earlier ones).
    pub fn site(mut self, site: FaultSite, spec: FaultSpec) -> Self {
        self.specs.push((site, spec));
        self
    }

    /// Injected delay used by [`FaultSite::CompileSlow`] and
    /// [`FaultSite::LatencySpike`] (default 50 ms).
    pub fn delay(mut self, delay: Duration) -> Self {
        self.delay = delay;
        self
    }

    /// Restrict shard-scoped sites to one shard index (see
    /// [`FaultPlan::should_fire_at`]).
    pub fn target_shard(mut self, shard: usize) -> Self {
        self.target_shard = Some(shard);
        self
    }

    pub fn build(self) -> Arc<FaultPlan> {
        let mut specs = [FaultSpec::Off; 12];
        for (site, spec) in &self.specs {
            specs[site.idx()] = *spec;
        }
        let sites = ALL_SITES
            .iter()
            .map(|site| SiteState {
                spec: specs[site.idx()],
                hits: AtomicU64::new(0),
                fired: AtomicU64::new(0),
                // Independent per-site stream: interleaving across sites
                // cannot perturb any one site's draw sequence.
                rng: Mutex::new(XorShift64::new(self.seed ^ fxhash::hash_str(site.name()))),
            })
            .collect();
        Arc::new(FaultPlan {
            seed: self.seed,
            delay: self.delay,
            target_shard: self.target_shard,
            sites,
        })
    }
}

impl FaultPlan {
    pub fn builder(seed: u64) -> FaultPlanBuilder {
        FaultPlanBuilder {
            seed,
            delay: Duration::from_millis(50),
            target_shard: None,
            specs: Vec::new(),
        }
    }

    /// Parse a plan from a spec string, e.g.
    /// `"seed=42,delay-ms=100,engine-panic=first:3,compile-fail=prob:0.5"`.
    pub fn parse(spec: &str) -> Result<Arc<FaultPlan>> {
        let mut b = FaultPlan::builder(1);
        for part in spec.split(',').map(str::trim).filter(|p| !p.is_empty()) {
            let (key, value) = match part.split_once('=') {
                Some(kv) => kv,
                None => bail!("bad NNCG_FAULTS entry {part:?} (want key=value)"),
            };
            match key {
                "seed" => match value.parse() {
                    Ok(s) => b.seed = s,
                    Err(_) => bail!("bad seed {value:?} in fault spec"),
                },
                "delay-ms" => match value.parse() {
                    Ok(ms) => b.delay = Duration::from_millis(ms),
                    Err(_) => bail!("bad delay-ms {value:?} in fault spec"),
                },
                "target-shard" => match value.parse() {
                    Ok(s) => b.target_shard = Some(s),
                    Err(_) => bail!("bad target-shard {value:?} in fault spec"),
                },
                site_name => match FaultSite::from_name(site_name) {
                    Some(site) => b = b.site(site, FaultSpec::parse(value)?),
                    None => bail!(
                        "unknown fault site {site_name:?} (known: {})",
                        ALL_SITES.iter().map(|s| s.name()).collect::<Vec<_>>().join(", ")
                    ),
                },
            }
        }
        Ok(b.build())
    }

    /// Read a plan from `NNCG_FAULTS`; `Ok(None)` when unset or empty.
    pub fn from_env() -> Result<Option<Arc<FaultPlan>>> {
        match std::env::var("NNCG_FAULTS") {
            Ok(spec) if !spec.trim().is_empty() => Ok(Some(FaultPlan::parse(&spec)?)),
            _ => Ok(None),
        }
    }

    pub fn seed(&self) -> u64 {
        self.seed
    }

    /// Consult a site: counts the hit, decides per the site's spec, and
    /// counts the fire. Sites configured `Off` never touch the counters.
    pub fn should_fire(&self, site: FaultSite) -> bool {
        let s = &self.sites[site.idx()];
        if matches!(s.spec, FaultSpec::Off) {
            return false;
        }
        let hit_no = s.hits.fetch_add(1, Ordering::SeqCst) + 1;
        let fire = s.spec.fires(hit_no, &s.rng);
        if fire {
            s.fired.fetch_add(1, Ordering::SeqCst);
        }
        fire
    }

    /// Like [`FaultPlan::should_fire`] but returns the configured delay when
    /// firing (for [`FaultSite::CompileSlow`] / [`FaultSite::LatencySpike`]).
    pub fn maybe_delay(&self, site: FaultSite) -> Option<Duration> {
        if self.should_fire(site) {
            Some(self.delay)
        } else {
            None
        }
    }

    /// Shard-scoped consult: like [`FaultPlan::should_fire`], but when a
    /// `target_shard` is configured, other shards never fire (and never
    /// count a hit), so the site's hit sequence is deterministic for the
    /// targeted shard alone.
    pub fn should_fire_at(&self, site: FaultSite, shard: usize) -> bool {
        match self.target_shard {
            Some(t) if t != shard => false,
            _ => self.should_fire(site),
        }
    }

    /// Shard-scoped variant of [`FaultPlan::maybe_delay`].
    pub fn maybe_delay_at(&self, site: FaultSite, shard: usize) -> Option<Duration> {
        if self.should_fire_at(site, shard) {
            Some(self.delay)
        } else {
            None
        }
    }

    /// Times a site was consulted (only counted for non-`Off` specs).
    pub fn hits(&self, site: FaultSite) -> u64 {
        self.sites[site.idx()].hits.load(Ordering::SeqCst)
    }

    /// Times a site actually fired.
    pub fn fired(&self, site: FaultSite) -> u64 {
        self.sites[site.idx()].fired.load(Ordering::SeqCst)
    }

    /// One-line summary for logs.
    pub fn describe(&self) -> String {
        let mut parts = vec![format!("seed={}", self.seed)];
        for site in ALL_SITES {
            let s = &self.sites[site.idx()];
            if !matches!(s.spec, FaultSpec::Off) {
                parts.push(format!("{}={:?}", site.name(), s.spec));
            }
        }
        parts.join(",")
    }
}

/// An [`InferenceEngine`] wrapper that injects engine-level faults (panics,
/// errors, latency spikes) per a [`FaultPlan`]. Test/chaos harness only —
/// production engines are never wrapped unless faults are configured.
pub struct FaultyEngine {
    inner: Arc<dyn InferenceEngine>,
    plan: Arc<FaultPlan>,
    label: String,
}

impl FaultyEngine {
    pub fn new(inner: Arc<dyn InferenceEngine>, plan: Arc<FaultPlan>) -> Self {
        let label = format!("faulty({})", inner.name());
        FaultyEngine { inner, plan, label }
    }

    pub fn plan(&self) -> &Arc<FaultPlan> {
        &self.plan
    }
}

impl InferenceEngine for FaultyEngine {
    fn name(&self) -> &str {
        &self.label
    }

    fn infer(&self, input: &Tensor) -> Result<Tensor> {
        if let Some(d) = self.plan.maybe_delay(FaultSite::LatencySpike) {
            std::thread::sleep(d);
        }
        if self.plan.should_fire(FaultSite::EnginePanic) {
            panic!("injected engine panic ({})", self.label);
        }
        if self.plan.should_fire(FaultSite::EngineFail) {
            bail!("injected engine failure ({})", self.label);
        }
        self.inner.infer(input)
    }

    /// Batched forwarding that keeps fault determinism: the plan's engine
    /// sites are consulted once **per image**, exactly the sequence N single
    /// `infer` calls would produce, so a seeded chaos run fires the same
    /// faults whether or not batching is enabled. A fault anywhere in the
    /// batch fails/panics the whole batch — that is the real blast radius of
    /// a shared engine invocation, and what the chaos suite asserts.
    fn infer_batch(&self, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        for _ in inputs {
            if let Some(d) = self.plan.maybe_delay(FaultSite::LatencySpike) {
                std::thread::sleep(d);
            }
            if self.plan.should_fire(FaultSite::EnginePanic) {
                panic!("injected engine panic ({})", self.label);
            }
            if self.plan.should_fire(FaultSite::EngineFail) {
                bail!("injected engine failure ({})", self.label);
            }
        }
        self.inner.infer_batch(inputs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn off_plan_never_fires_and_never_counts() {
        let plan = FaultPlan::builder(1).build();
        for _ in 0..10 {
            assert!(!plan.should_fire(FaultSite::CompileFail));
        }
        assert_eq!(plan.hits(FaultSite::CompileFail), 0);
        assert_eq!(plan.fired(FaultSite::CompileFail), 0);
    }

    #[test]
    fn first_n_fires_exactly_n_times() {
        let plan = FaultPlan::builder(1).site(FaultSite::EngineFail, FaultSpec::First(3)).build();
        let fired: Vec<bool> = (0..6).map(|_| plan.should_fire(FaultSite::EngineFail)).collect();
        assert_eq!(fired, vec![true, true, true, false, false, false]);
        assert_eq!(plan.hits(FaultSite::EngineFail), 6);
        assert_eq!(plan.fired(FaultSite::EngineFail), 3);
    }

    #[test]
    fn every_n_fires_periodically() {
        let plan = FaultPlan::builder(1).site(FaultSite::LatencySpike, FaultSpec::Every(3)).build();
        let fired: Vec<bool> = (0..7).map(|_| plan.should_fire(FaultSite::LatencySpike)).collect();
        assert_eq!(fired, vec![false, false, true, false, false, true, false]);
    }

    #[test]
    fn prob_is_deterministic_per_seed_and_site() {
        let a = FaultPlan::builder(42).site(FaultSite::EnginePanic, FaultSpec::Prob(0.5)).build();
        let b = FaultPlan::builder(42).site(FaultSite::EnginePanic, FaultSpec::Prob(0.5)).build();
        let fa: Vec<bool> = (0..64).map(|_| a.should_fire(FaultSite::EnginePanic)).collect();
        let fb: Vec<bool> = (0..64).map(|_| b.should_fire(FaultSite::EnginePanic)).collect();
        assert_eq!(fa, fb);
        assert!(fa.iter().any(|&f| f) && fa.iter().any(|&f| !f), "p=0.5 over 64 draws");
        // A different seed gives a different pattern.
        let c = FaultPlan::builder(43).site(FaultSite::EnginePanic, FaultSpec::Prob(0.5)).build();
        let fc: Vec<bool> = (0..64).map(|_| c.should_fire(FaultSite::EnginePanic)).collect();
        assert_ne!(fa, fc);
    }

    #[test]
    fn parse_spec_strings() {
        let plan = FaultPlan::parse("seed=9,delay-ms=5,engine-panic=first:2,compile-fail=always").unwrap();
        assert_eq!(plan.seed(), 9);
        assert!(plan.should_fire(FaultSite::CompileFail));
        assert!(plan.should_fire(FaultSite::EnginePanic));
        assert!(plan.should_fire(FaultSite::EnginePanic));
        assert!(!plan.should_fire(FaultSite::EnginePanic));
        assert_eq!(plan.maybe_delay(FaultSite::LatencySpike), None);

        assert!(FaultPlan::parse("bogus-site=always").is_err());
        assert!(FaultPlan::parse("engine-panic=sometimes").is_err());
        assert!(FaultPlan::parse("seed=x").is_err());
        assert!(FaultPlan::parse("engine-panic").is_err());
        assert!(FaultSpec::parse("prob:1.5").is_err());
        assert!(FaultSpec::parse("every:0").is_err());
    }

    #[test]
    fn target_shard_scopes_shard_sites() {
        let plan = FaultPlan::builder(3)
            .site(FaultSite::ShardKill, FaultSpec::First(2))
            .target_shard(1)
            .build();
        // Non-target shards never fire and never consume hits.
        assert!(!plan.should_fire_at(FaultSite::ShardKill, 0));
        assert!(!plan.should_fire_at(FaultSite::ShardKill, 2));
        assert_eq!(plan.hits(FaultSite::ShardKill), 0);
        // The target shard sees the full First(2) sequence.
        assert!(plan.should_fire_at(FaultSite::ShardKill, 1));
        assert!(plan.should_fire_at(FaultSite::ShardKill, 1));
        assert!(!plan.should_fire_at(FaultSite::ShardKill, 1));
        assert_eq!(plan.fired(FaultSite::ShardKill), 2);
    }

    #[test]
    fn parse_target_shard_and_shard_sites() {
        let plan = FaultPlan::parse("seed=5,target-shard=2,shard-kill=first:1,steal-race=always")
            .unwrap();
        assert!(!plan.should_fire_at(FaultSite::ShardKill, 0));
        assert!(plan.should_fire_at(FaultSite::ShardKill, 2));
        assert!(plan.maybe_delay_at(FaultSite::StealRace, 2).is_some());
        assert!(plan.maybe_delay_at(FaultSite::StealRace, 1).is_none());
        assert!(FaultPlan::parse("target-shard=x").is_err());
    }

    #[test]
    fn site_names_round_trip() {
        for site in ALL_SITES {
            assert_eq!(FaultSite::from_name(site.name()), Some(site));
        }
        assert_eq!(FaultSite::from_name("meteor-strike"), None);
    }

    #[test]
    fn faulty_engine_injects_panics_errors_and_delays() {
        use crate::graph::zoo;
        use crate::interp::InterpEngine;

        let inner: Arc<dyn InferenceEngine> =
            Arc::new(InterpEngine::new(zoo::tiny_test_net().with_random_weights(3)).unwrap());
        let plan = FaultPlan::builder(7)
            .site(FaultSite::EngineFail, FaultSpec::First(1))
            .site(FaultSite::EnginePanic, FaultSpec::First(0)) // counted but off
            .build();
        let eng = FaultyEngine::new(Arc::clone(&inner), plan.clone());
        let x = Tensor::zeros(&[8, 8, 1]);
        assert!(eng.infer(&x).is_err(), "first call fails by injection");
        assert!(eng.infer(&x).is_ok(), "second call passes through");
        assert_eq!(plan.fired(FaultSite::EngineFail), 1);

        let plan = FaultPlan::builder(7).site(FaultSite::EnginePanic, FaultSpec::First(1)).build();
        let eng = FaultyEngine::new(inner, plan);
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| eng.infer(&x)));
        assert!(r.is_err(), "injected panic must unwind");
    }
}
