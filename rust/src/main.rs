//! `nncg` — leader binary: CLI over the code generator, engines, benches
//! and the serving coordinator.

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    match nncg::cli::run(&argv) {
        Ok(code) => std::process::exit(code),
        Err(e) => {
            eprintln!("error: {e:#}");
            std::process::exit(1);
        }
    }
}
