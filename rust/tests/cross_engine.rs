//! Cross-engine equivalence — the reproduction's strongest correctness
//! statement: the SAME trained weights produce the SAME function through
//! three entirely different execution paths:
//!
//! 1. NNCG-generated C (cc + dlopen)         — the paper's contribution,
//! 2. the naive Rust interpreter             — Eq. 1–6 transcription,
//! 3. the JAX/Pallas-authored HLO via PJRT   — the three-layer AOT bridge.
//!
//! Paths 1↔2 are always checked. Path 3 additionally requires the
//! artifacts built by `make artifacts`; those tests self-skip (with a
//! note) when artifacts are absent so `cargo test` works standalone.

use nncg::cc::CompiledCnn;
use nncg::codegen::CodegenOptions;
use nncg::experiments::{build_engine, default_artifacts_dir, default_weights_dir, default_work_dir, load_model};
use nncg::runtime::{EngineKind, InferenceEngine};
use nncg::tensor::Tensor;
use nncg::util::XorShift64;

fn artifacts_available(model: &str) -> bool {
    default_artifacts_dir().join(format!("{model}.hlo.txt")).exists()
}

fn weights_available(model: &str) -> bool {
    default_weights_dir().join(format!("{model}.nncgw")).exists()
}

/// |a - b| must be tiny relative to f32 conv accumulation error.
const TOL: f32 = 2e-4;

fn check_three_way(model_name: &str, trials: usize) {
    if !weights_available(model_name) || !artifacts_available(model_name) {
        eprintln!("SKIP three-way {model_name}: run `make artifacts` first");
        return;
    }
    let model = load_model(model_name, &default_weights_dir()).unwrap();
    let opts = CodegenOptions::sse3();
    let nncg = build_engine(EngineKind::Nncg, &model, &opts, &default_artifacts_dir(), &default_work_dir()).unwrap();
    let interp = build_engine(EngineKind::Interp, &model, &opts, &default_artifacts_dir(), &default_work_dir()).unwrap();
    let xla = build_engine(EngineKind::Xla, &model, &opts, &default_artifacts_dir(), &default_work_dir()).unwrap();

    let mut rng = XorShift64::new(0xE2E);
    for t in 0..trials {
        let x = Tensor::rand(model.input.dims(), 0.0, 1.0, &mut rng);
        let y_interp = interp.infer(&x).unwrap();
        let y_nncg = nncg.infer(&x).unwrap();
        let y_xla = xla.infer(&x).unwrap();
        let e_cn = y_nncg.max_abs_diff(&y_interp).unwrap();
        let e_xla = y_xla.max_abs_diff(&y_interp).unwrap();
        assert!(e_cn < TOL, "{model_name} trial {t}: C vs interp err {e_cn}");
        assert!(e_xla < TOL, "{model_name} trial {t}: XLA vs interp err {e_xla}");
    }
}

#[test]
fn three_way_equivalence_ball() {
    check_three_way("ball", 5);
}

#[test]
fn three_way_equivalence_pedestrian() {
    check_three_way("pedestrian", 3);
}

#[test]
fn three_way_equivalence_robot() {
    check_three_way("robot", 2);
}

/// Full option-matrix verification on the real paper models (the lib test
/// covers the tiny net; this is the heavyweight version).
#[test]
fn generated_c_matches_interp_on_paper_models_all_isas() {
    use nncg::codegen::{Isa, Unroll};
    for name in ["ball", "pedestrian"] {
        let model = load_model(name, &default_weights_dir()).unwrap();
        for isa in [Isa::Generic, Isa::Sse3] {
            for unroll in [Unroll::None, Unroll::KeepOuter2] {
                let opts = CodegenOptions { isa, unroll, ..Default::default() };
                let err =
                    nncg::cc::verify_against_interp(&model, &opts, default_work_dir(), 2, 7).unwrap();
                assert!(err < TOL, "{name} {}: err {err}", opts.tag());
            }
        }
    }
}

/// Full-unroll on the ball net (the paper's fastest configuration).
#[test]
fn full_unroll_ball_matches_interp() {
    let model = load_model("ball", &default_weights_dir()).unwrap();
    let err = nncg::cc::verify_against_interp(
        &model,
        &CodegenOptions::sse3_full_unroll(),
        default_work_dir(),
        3,
        13,
    )
    .unwrap();
    assert!(err < TOL, "err {err}");
}

/// Robot detector (BN folding + leaky ReLU) through generated C.
#[test]
fn robot_with_batchnorm_matches_interp() {
    let model = load_model("robot", &default_weights_dir()).unwrap();
    let err =
        nncg::cc::verify_against_interp(&model, &CodegenOptions::sse3(), default_work_dir(), 2, 3).unwrap();
    assert!(err < TOL, "err {err}");
}

/// Odd channel counts (c_out ∈ {3, 6, 10}) and strided Same-padded convs
/// through the full (isa × unroll × pad-mode × tile) matrix: generated C
/// must match the interpreter within TOL on every combination, padless
/// output must never reference the `nncg_pad` scratch buffer, and odd
/// channel counts must keep vector intrinsics under SSE (remainder lanes,
/// not a scalar cliff).
///
/// The matrix includes `Isa::Neon` rows: x86 CI cannot *execute* NEON, so
/// those rows assert generated-C structure instead of interpreter parity —
/// `arm_neon.h` header, fused `vfmaq_f32` taps, vector loads, and a scalar
/// remainder tail for the odd channel counts.
#[test]
fn odd_channel_strided_same_parity_across_pad_and_tile_matrix() {
    use nncg::codegen::{Isa, PadMode, TileMode, Unroll};
    use nncg::graph::{Activation, Layer, Model, Padding};
    let model = Model::new("oddmix", &[9, 8, 1])
        .push(Layer::conv2d(3, 3, 3, (2, 2), Padding::Same, Activation::Relu))
        .push(Layer::conv2d(6, 3, 3, (1, 1), Padding::Same, Activation::None))
        .push(Layer::leaky_relu(0.1))
        .push(Layer::conv2d(10, 2, 2, (2, 2), Padding::Same, Activation::None))
        .push(Layer::softmax())
        .with_random_weights(2027);
    let work = default_work_dir();
    for isa in [Isa::Generic, Isa::Sse3, Isa::Neon] {
        for unroll in [Unroll::None, Unroll::KeepOuter2, Unroll::KeepOuter1, Unroll::Full] {
            for pad_mode in [PadMode::Copy, PadMode::Padless] {
                for tile in [TileMode::Off, TileMode::Auto] {
                    let opts = CodegenOptions { isa, unroll, pad_mode, tile, ..Default::default() };
                    let src = nncg::codegen::generate_c(&model, &opts).unwrap();
                    if pad_mode == PadMode::Padless && unroll != Unroll::None {
                        assert!(
                            !src.contains("nncg_pad"),
                            "{}: padless output must not reference nncg_pad",
                            opts.tag()
                        );
                    }
                    if isa == Isa::Sse3 {
                        assert!(
                            src.contains("_mm_"),
                            "{}: odd channel counts must keep vector intrinsics",
                            opts.tag()
                        );
                    }
                    if isa == Isa::Neon {
                        // Structure-only: interpreter comparison can't run
                        // ARM code on this host.
                        assert!(src.contains("#include <arm_neon.h>"), "{}", opts.tag());
                        assert!(src.contains("vfmaq_f32"), "{}: NEON taps must fuse", opts.tag());
                        assert!(src.contains("vld1q_f32"), "{}", opts.tag());
                        assert!(
                            src.contains("float a ="),
                            "{}: odd channels need a scalar tail",
                            opts.tag()
                        );
                        assert!(!src.contains("_mm"), "{}: x86 leak into NEON output", opts.tag());
                        continue;
                    }
                    let err = nncg::cc::verify_against_interp(&model, &opts, &work, 2, 11).unwrap();
                    assert!(err < TOL, "{}: err {err}", opts.tag());
                }
            }
        }
    }
}

/// Locate a compiler able to syntax-check NEON C: a real ARM cross-gcc if
/// the image has one, else the host compiler with the checked-in
/// declaration-stub `arm_neon.h` (ci/stubs). Returns None when neither
/// exists (test self-skips).
fn neon_syntax_checker() -> Option<(String, Vec<String>)> {
    let have = |cmd: &str| {
        std::process::Command::new(cmd)
            .arg("--version")
            .output()
            .map(|o| o.status.success())
            .unwrap_or(false)
    };
    if have("aarch64-linux-gnu-gcc") {
        return Some(("aarch64-linux-gnu-gcc".to_string(), vec!["-fsyntax-only".into()]));
    }
    // 32-bit ARM gcc refuses arm_neon.h (and lacks vfmaq_f32) unless NEON
    // + VFPv4 are enabled explicitly.
    if have("arm-linux-gnueabihf-gcc") {
        return Some((
            "arm-linux-gnueabihf-gcc".to_string(),
            vec![
                "-fsyntax-only".into(),
                "-mfpu=neon-vfpv4".into(),
                "-mfloat-abi=hard".into(),
            ],
        ));
    }
    let stub = std::path::Path::new("ci/stubs/arm_neon.h");
    if stub.exists() {
        for cc in ["gcc", "cc", "clang"] {
            if have(cc) {
                return Some((
                    cc.to_string(),
                    vec!["-fsyntax-only".into(), "-isystem".into(), "ci/stubs".into()],
                ));
            }
        }
    }
    None
}

/// NEON-generated C for every paper model must be syntactically valid C —
/// checked with an ARM cross compiler when available, else against the
/// intrinsics declaration stub.
#[test]
fn neon_generated_c_for_paper_models_passes_syntax_check() {
    use nncg::codegen::{Isa, TileMode, Unroll};
    let Some((cc, flags)) = neon_syntax_checker() else {
        eprintln!("SKIP neon syntax check: no C compiler and no ci/stubs/arm_neon.h");
        return;
    };
    let dir = std::env::temp_dir().join("nncg-neon-syntax");
    std::fs::create_dir_all(&dir).unwrap();
    for name in nncg::graph::zoo::PAPER_MODELS {
        let model = load_model(name, &default_weights_dir()).unwrap();
        for (unroll, tile) in [
            (Unroll::KeepOuter2, TileMode::Auto),
            (Unroll::None, TileMode::Off),
            (Unroll::KeepOuter2, TileMode::Fixed2D(2, 4)),
        ] {
            let opts = CodegenOptions { isa: Isa::Neon, unroll, tile, ..Default::default() };
            let src = nncg::codegen::generate_c(&model, &opts).unwrap();
            let c_path = dir.join(format!("{name}-{}.c", opts.tag()));
            std::fs::write(&c_path, &src).unwrap();
            let out = std::process::Command::new(&cc)
                .args(&flags)
                .arg(&c_path)
                .output()
                .unwrap();
            assert!(
                out.status.success(),
                "{name} {}: {cc} rejected NEON output:\n{}",
                opts.tag(),
                String::from_utf8_lossy(&out.stderr)
            );
        }
    }
}

/// Aligned emission (the default) must match the interpreter exactly like
/// the unaligned baseline, and the two must differ only in the intended
/// ways (NNCG_ALIGN attribute + aligned intrinsic forms).
#[test]
fn aligned_emission_matches_interp_and_differs_only_in_alignment() {
    use nncg::codegen::AlignMode;
    let work = default_work_dir();
    for name in ["ball", "pedestrian"] {
        let model = load_model(name, &default_weights_dir()).unwrap();
        for align in [AlignMode::Auto, AlignMode::Off] {
            let opts = CodegenOptions { align, ..CodegenOptions::sse3() };
            let src = nncg::codegen::generate_c(&model, &opts).unwrap();
            assert_eq!(
                src.contains("NNCG_ALIGN"),
                align == AlignMode::Auto,
                "{name} {}",
                opts.tag()
            );
            if align == AlignMode::Off {
                assert!(!src.contains("_mm_load_ps("), "{name}: baseline must stay unaligned");
                assert!(!src.contains("_mm_store_ps("), "{name}: baseline must stay unaligned");
            }
            let err = nncg::cc::verify_against_interp(&model, &opts, &work, 2, 77).unwrap();
            assert!(err < TOL, "{name} {}: err {err}", opts.tag());
        }
    }
}

/// 2-D register blocks (`--tile 2x4`) through the compiled path: the conv
/// interior walks row pairs and still matches the interpreter.
#[test]
fn tile_2d_matches_interp_on_paper_models() {
    use nncg::codegen::TileMode;
    let work = default_work_dir();
    for name in ["ball", "pedestrian"] {
        let model = load_model(name, &default_weights_dir()).unwrap();
        let opts = CodegenOptions { tile: TileMode::Fixed2D(2, 4), ..CodegenOptions::sse3() };
        let src = nncg::codegen::generate_c(&model, &opts).unwrap();
        assert!(
            src.contains("i += 2)"),
            "{name}: expected a row-pair interior loop in {}",
            opts.tag()
        );
        let err = nncg::cc::verify_against_interp(&model, &opts, &work, 3, 29).unwrap();
        assert!(err < TOL, "{name} {}: err {err}", opts.tag());
    }
}

/// Paper models through the padless + tiled emission (the new default
/// fast path) against the interpreter.
#[test]
fn paper_models_padless_tiled_match_interp() {
    use nncg::codegen::{PadMode, TileMode};
    for name in ["ball", "pedestrian"] {
        let model = load_model(name, &default_weights_dir()).unwrap();
        let opts = CodegenOptions {
            pad_mode: PadMode::Padless,
            tile: TileMode::Auto,
            ..CodegenOptions::sse3()
        };
        let src = nncg::codegen::generate_c(&model, &opts).unwrap();
        assert!(!src.contains("nncg_pad"), "{name}: padless output references nncg_pad");
        let err = nncg::cc::verify_against_interp(&model, &opts, default_work_dir(), 2, 21).unwrap();
        assert!(err < TOL, "{name}: err {err}");
    }
}

/// The dlopen engine must be reusable across threads (coordinator workers).
#[test]
fn compiled_cnn_is_thread_safe() {
    let model = load_model("ball", &default_weights_dir()).unwrap();
    let cnn = std::sync::Arc::new(
        CompiledCnn::build(&model, &CodegenOptions::sse3(), default_work_dir()).unwrap(),
    );
    let mut rng = XorShift64::new(5);
    let x = Tensor::rand(&[16, 16, 1], 0.0, 1.0, &mut rng);
    let expected = cnn.infer(&x).unwrap();
    let handles: Vec<_> = (0..4)
        .map(|_| {
            let cnn = std::sync::Arc::clone(&cnn);
            let x = x.clone();
            let expected = expected.clone();
            std::thread::spawn(move || {
                for _ in 0..50 {
                    let y = cnn.infer(&x).unwrap();
                    assert_eq!(y, expected);
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
}
